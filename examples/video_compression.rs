//! Fig 8b: compression-vs-error curve on the high-speed-video tensor,
//! TT (SVD) vs nTT (BCD-NMF).
//!
//!     cargo run --release --example video_compression

use dntt::bench::workloads::{fig8_sweep, print_sweep, Fig8Data, PAPER_EPS};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    dntt::util::logging::init();
    let rows = fig8_sweep(Fig8Data::Video, &PAPER_EPS, 80, 4)?;
    print_sweep(&rows);
    // Looser eps ⇒ more compression for both methods (the paper's trend).
    for algo in ["TT", "nTT-BCD"] {
        let series: Vec<f64> = rows
            .iter()
            .filter(|r| r.algo == algo)
            .map(|r| r.compression)
            .collect();
        assert!(
            series.windows(2).all(|w| w[1] <= w[0] * 1.5 + 1e9),
            "{algo}: compression not roughly monotone vs eps"
        );
    }
    Ok(())
}
