//! Hierarchical-Tucker compression demo: decompose the same synthetic
//! non-negative tensor with both networks (nTT and nHT) on a 2x2x1x1
//! thread grid and compare compression and reconstruction error.
//!
//!     cargo run --release --example ht_compression

use dntt::coordinator::{run_job, Decomposition, InputSpec, JobConfig};
use dntt::dist::ProcGrid;
use dntt::ht::HtConfig;
use dntt::nmf::NmfConfig;
use dntt::ttrain::{SyntheticTt, TtConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    dntt::util::logging::init();
    let input = InputSpec::Synthetic(SyntheticTt::new(vec![12; 4], vec![3, 3, 3], 42));
    let grid = ProcGrid::new(vec![2, 2, 1, 1])?;
    let nmf = NmfConfig { max_iters: 120, ..Default::default() };

    let tt_job = JobConfig {
        tt: TtConfig { eps: 1e-4, nmf: nmf.clone(), ..Default::default() },
        ..JobConfig::new(input.clone(), grid.clone())
    };
    let tt = run_job(&tt_job)?;
    println!("{}", tt.summary());

    let ht_job = JobConfig {
        decomp: Decomposition::Ht,
        ht: HtConfig { eps: 1e-4, nmf, ..Default::default() },
        ..JobConfig::new(input, grid)
    };
    let ht = run_job(&ht_job)?;
    println!("{}", ht.summary());

    let (te, he) = (tt.rel_error.unwrap(), ht.rel_error.unwrap());
    println!("nTT: compression {:>8.1}x  rel error {te:.4}", tt.compression);
    println!("nHT: compression {:>8.1}x  rel error {he:.4}", ht.compression);
    assert!(tt.output.is_nonneg() && ht.output.is_nonneg(), "factors must stay non-negative");
    assert!(te < 0.1 && he < 0.1, "reconstruction error too high: tt {te}, ht {he}");
    println!("ht_compression OK: both networks reconstruct within 10%");
    Ok(())
}
