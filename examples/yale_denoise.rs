//! Fig 9: image denoising with SVD-TT vs NMF-TT (nTT).
//!
//! Generates the Yale-B-like face tensor, injects N(0, (0.12·peak)^2)
//! noise, decomposes at a sweep of fixed TT ranks with both methods, and
//! reports SSIM against the clean data — reproducing the paper's finding
//! that at matched ranks the non-negative TT reconstructs with equal or
//! better SSIM than the unconstrained TT.
//!
//!     cargo run --release --example yale_denoise

use dntt::bench::workloads::{denoise_run, print_denoise};
use dntt::data::FaceConfig;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    dntt::util::logging::init();
    let faces = FaceConfig { height: 24, width: 21, illuminations: 16, subjects: 10, seed: 3435 };
    let rows = denoise_run(&faces, 0.12, &[16, 12, 8, 6, 4, 2], 150)?;
    print_denoise(&rows);
    // The paper's qualitative claim: for given TT ranks, nTT SSIM >= TT SSIM
    // on most of the sweep (Fig 9: best 0.88 vs 0.85).
    let wins = rows.iter().filter(|r| r.ssim_ntt >= r.ssim_tt - 0.01).count();
    println!("\nnTT matches or beats TT SSIM on {}/{} rank settings", wins, rows.len());
    let best_tt = rows.iter().map(|r| r.ssim_tt).fold(0.0, f64::max);
    let best_ntt = rows.iter().map(|r| r.ssim_ntt).fold(0.0, f64::max);
    println!("best SSIM: TT {best_tt:.4} | nTT {best_ntt:.4}");
    Ok(())
}
