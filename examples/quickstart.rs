//! Quickstart: decompose a small synthetic non-negative tensor with the
//! distributed nTT on a 2x2x1x1 thread grid and verify the reconstruction.
//!
//!     cargo run --release --example quickstart

use dntt::coordinator::{run_job, InputSpec, JobConfig};
use dntt::dist::ProcGrid;
use dntt::nmf::NmfConfig;
use dntt::ttrain::{SyntheticTt, TtConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    dntt::util::logging::init();
    // A 16^4 tensor with known TT ranks (4,4,4), generated blockwise on
    // each rank (the full tensor is only materialized for the error check).
    let input = InputSpec::Synthetic(SyntheticTt::new(vec![16; 4], vec![4, 4, 4], 7));
    let grid = ProcGrid::new(vec![2, 2, 1, 1])?;
    let job = JobConfig {
        tt: TtConfig {
            eps: 1e-4, // per-stage rank-selection threshold
            nmf: NmfConfig { max_iters: 150, ..Default::default() },
            ..Default::default()
        },
        ..JobConfig::new(input, grid)
    };
    let report = run_job(&job)?;
    println!("{}", report.summary());
    assert!(report.output.is_nonneg(), "nTT cores must be non-negative");
    let err = report.rel_error.unwrap();
    assert!(err < 0.1, "reconstruction error too high: {err}");
    println!("quickstart OK: rel error {err:.4}, compression {:.1}x", report.compression);
    Ok(())
}
