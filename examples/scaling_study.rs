//! Figs 5-7: strong scaling, weak scaling and TT-rank scaling of the
//! distributed nTT, with the paper's compute/communication/I-O breakdown
//! (GR/MM/MAD/Norm/INIT vs AG/AR/RSC vs IO/Reshape) and the α-β cluster
//! model projecting thread-rank measurements onto a Grizzly-like machine.
//!
//!     cargo run --release --example scaling_study [-- --full]

use dntt::bench::workloads::{print_scaling, scaling_run, ScalingMode, ScalingParams};
use dntt::nmf::NmfAlgo;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    dntt::util::logging::init();
    let full = std::env::args().any(|a| a == "--full");
    // Scaled-down defaults (one physical core): 32^4 tensor, p = 16..64.
    let params = ScalingParams {
        shrink: if full { 4 } else { 8 },
        ks: if full { vec![1, 2, 3, 4, 5] } else { vec![1, 2, 3] },
        iters: if full { 100 } else { 5 },
        algos: vec![NmfAlgo::Bcd, NmfAlgo::Mu],
        ..Default::default()
    };

    println!("=== strong scaling (Fig 5) ===");
    let pts = scaling_run(ScalingMode::Strong, &params)?;
    print_scaling(&pts);

    println!("\n=== weak scaling (Fig 6) ===");
    let pts = scaling_run(ScalingMode::Weak, &params)?;
    print_scaling(&pts);

    println!("\n=== TT-rank scaling (Fig 7) ===");
    let params7 = ScalingParams {
        ranks_p_exp: if full { 5 } else { 2 },
        rank_sweep: vec![2, 4, 8, 16],
        ..params
    };
    let pts = scaling_run(ScalingMode::Ranks, &params7)?;
    print_scaling(&pts);
    Ok(())
}
