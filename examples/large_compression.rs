//! END-TO-END DRIVER (Fig 8c analogue): the full system on a real workload.
//!
//! Generates the paper's large synthetic tensor (1024x512^3 at --scale 1;
//! default --scale 8 -> 128x64^3 ~ 0.26 GB f64) *blockwise and distributed*
//! (never materializing the tensor on one rank), spills chunks through the
//! disk-backed chunk store (the Zarr path), runs the distributed nTT on a
//! 2x2x2x2 thread grid with the PJRT backend where artifact shapes match,
//! and reports the paper's headline metrics: compression ratio, per-stage
//! relative error, and the full compute/comm/IO time breakdown + cluster
//! model. Recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example large_compression [-- --scale 8]

use dntt::coordinator::{run_job, BackendChoice, InputSpec, JobConfig};
use dntt::dist::chunkstore::SpillMode;
use dntt::dist::ProcGrid;
use dntt::nmf::NmfConfig;
use dntt::ttrain::{SyntheticTt, TtConfig};
use std::path::{Path, PathBuf};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    dntt::util::logging::init();
    let args: Vec<String> = std::env::args().collect();
    let scale: usize = args
        .iter()
        .position(|a| a == "--scale")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let nd = |x: usize| (x / scale).max(8);
    let dims = vec![nd(1024), nd(512), nd(512), nd(512)];
    let ranks: Vec<usize> = [20usize, 30, 40].iter().map(|&r| r.min(nd(512) / 2)).collect();
    let nbytes = dims.iter().product::<usize>() * 8;
    println!(
        "workload: {:?} (ranks {:?}, {:.2} GB f64, scale {})",
        dims,
        ranks,
        nbytes as f64 / 1e9,
        scale
    );

    let spill_dir = std::env::temp_dir().join("dntt_e2e_spill");
    let job = JobConfig {
        tt: TtConfig {
            // Fixed ranks, as in the paper's 500 GB experiment.
            fixed_ranks: Some(ranks.clone()),
            nmf: NmfConfig { max_iters: 30, ..Default::default() },
            ..Default::default()
        },
        backend: if Path::new("artifacts/manifest.json").exists() {
            BackendChoice::Pjrt(PathBuf::from("artifacts"))
        } else {
            BackendChoice::Native
        },
        spill: SpillMode::Disk(spill_dir.clone()),
        check_error: dims.iter().product::<usize>() <= 20_000_000,
        ..JobConfig::new(
            InputSpec::Synthetic(SyntheticTt::new(dims, ranks, 500_000_000)),
            ProcGrid::new(vec![2, 2, 2, 2])?,
        )
    };
    let report = run_job(&job)?;
    println!("{}", report.summary());
    assert!(report.output.is_nonneg());
    assert!(report.compression > 100.0, "expected high compression, got {}", report.compression);
    println!(
        "E2E OK: compression {:.0}x, wall {:.1}s, pjrt hits {}",
        report.compression, report.wall_secs, report.pjrt_hits
    );
    let _ = std::fs::remove_dir_all(&spill_dir);
    Ok(())
}
