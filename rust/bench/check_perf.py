#!/usr/bin/env python3
"""Warn-only perf gate for the CI perf-smoke job.

Compares one or more `dntt-bench-v1` result files
(bench_results/BENCH_*.json, written by the harness; their case lists
are merged) against the committed baseline (rust/bench/baseline.json):

* every case listed under baseline `min_gflops` must reach its floor;
* every `min_ratio` entry (e.g. packed >= 2x blocked at 512^3) must hold.

With `--metrics`, instead sanity-checks a `dntt-metrics-v1` envelope
(written by `dntt decompose --metrics-out`): schema version, balanced
trace spans, per-collective byte residuals (zero by construction),
nonzero communication volume, and agreement between the counter totals
and the per-collective breakdown (both sides count the same call
sites, so AG+AR+RSC bytes must match exactly).

Always exits 0 — misses are surfaced as GitHub `::warning::`
annotations, not failures, until enough CI history exists to make the
gate strict (see DESIGN.md, "CI perf gate"). Stdlib only.

Usage: check_perf.py RESULTS_JSON [RESULTS_JSON...] BASELINE_JSON
       check_perf.py --metrics METRICS_JSON
"""

import json
import sys


def check_metrics(path: str) -> int:
    """Warn-only structural gate over one dntt-metrics-v1 envelope."""
    try:
        with open(path) as f:
            env = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"::warning::metrics gate skipped: {e}")
        return 0

    warned = 0

    def warn(msg: str) -> None:
        nonlocal warned
        print(f"::warning::metrics gate: {msg}")
        warned += 1

    fmt = env.get("format")
    if fmt != "dntt-metrics-v1":
        warn(f"unexpected envelope format {fmt!r}")
    trace = env.get("trace", {})
    if trace.get("open_spans", 0) != 0:
        warn(f"{trace['open_spans']} span(s) left open — unbalanced instrumentation")
    if trace.get("events", 0) <= 0:
        warn("trace recorded no events")
    if trace.get("rank_timelines", 0) < 1:
        warn("no rank timelines in the trace")

    rows = env.get("collectives", [])
    comm_bytes = 0
    for row in rows:
        if row.get("byte_residual", 0) != 0:
            warn(
                f"collective {row.get('cat')}: byte residual "
                f"{row['byte_residual']} (must be 0 by construction)"
            )
        comm_bytes += int(row.get("measured_bytes", 0))
    if comm_bytes <= 0:
        warn("zero communication bytes across all collectives")

    # Out-of-core accounting (DESIGN.md §2.12): a budgeted run must
    # report its peak-resident gauge, and the peak must respect the
    # budget — that ceiling is the acceptance criterion of the
    # out-of-core milestone, so a breach is worth a loud warning even
    # though this gate never fails the build.
    mem = env.get("memory")
    if mem is not None:
        peak = int(mem.get("peak_resident_bytes", 0))
        budget = mem.get("budget_bytes")
        if peak <= 0:
            warn("memory section present but the peak-resident gauge never moved")
        if budget is not None:
            if peak > int(budget):
                warn(
                    f"peak resident {peak} B exceeds the configured "
                    f"budget {budget} B — out-of-core streaming regressed"
                )
            else:
                print(f"  memory: peak resident {peak} B within budget {budget} B")

    totals = env.get("counters", {}).get("totals", {})
    ctr_bytes = sum(int(totals.get(k, 0)) for k in ("ag_bytes", "ar_bytes", "rsc_bytes"))
    if ctr_bytes != comm_bytes:
        warn(
            f"counter totals (AG+AR+RSC = {ctr_bytes} B) disagree with the "
            f"per-collective breakdown ({comm_bytes} B)"
        )
    else:
        print(f"  counters vs breakdown: {comm_bytes} comm bytes, consistent")

    print(f"metrics gate: {warned} warning(s) (warn-only, exit 0)")
    return 0


def main() -> int:
    if len(sys.argv) == 3 and sys.argv[1] == "--metrics":
        return check_metrics(sys.argv[2])
    if len(sys.argv) < 3:
        print(f"usage: {sys.argv[0]} RESULTS_JSON [RESULTS_JSON...] BASELINE_JSON", file=sys.stderr)
        return 0  # warn-only: never break the build on harness drift
    cases = {}
    sha = "unknown"
    try:
        for path in sys.argv[1:-1]:
            with open(path) as f:
                results = json.load(f)
            for c in results.get("cases", []):
                cases[c["name"]] = c
            sha = results.get("git_sha", sha)
        with open(sys.argv[-1]) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"::warning::perf gate skipped: {e}")
        return 0

    warned = 0

    for name, floor in baseline.get("min_gflops", {}).items():
        case = cases.get(name)
        if case is None:
            print(f"::warning::perf gate: case '{name}' missing from results ({sha})")
            warned += 1
            continue
        got = case.get("gflops", 0.0)
        verdict = "ok" if got >= floor else "BELOW FLOOR"
        print(f"  {name}: {got:.2f} GF/s (floor {floor:.2f}) {verdict}")
        if got < floor:
            print(
                f"::warning::perf regression: '{name}' at {got:.2f} GF/s "
                f"is below the {floor:.2f} GF/s baseline ({sha})"
            )
            warned += 1

    for ratio in baseline.get("min_ratio", []):
        num = cases.get(ratio["numerator"], {}).get("gflops", 0.0)
        den = cases.get(ratio["denominator"], {}).get("gflops", 0.0)
        if den <= 0.0:
            print(f"::warning::perf gate: ratio '{ratio['name']}' denominator missing ({sha})")
            warned += 1
            continue
        # Optional kernel-path tags: the ratio only means what it claims
        # if the cases ran on the paths the baseline expects (the bench
        # envelope records the path each case dispatched through).
        for side in ("numerator", "denominator"):
            want = ratio.get(f"{side}_kernel")
            got_k = cases.get(ratio[side], {}).get("kernel", "")
            if want is not None and got_k != want:
                print(
                    f"::warning::perf gate: ratio '{ratio['name']}' {side} "
                    f"ran on kernel '{got_k}', baseline expects '{want}' ({sha})"
                )
                warned += 1
        got = num / den
        verdict = "ok" if got >= ratio["min"] else "BELOW FLOOR"
        print(f"  {ratio['name']}: {got:.2f}x (floor {ratio['min']:.2f}x) {verdict}")
        if got < ratio["min"]:
            print(
                f"::warning::perf regression: '{ratio['name']}' at {got:.2f}x "
                f"is below the {ratio['min']:.2f}x floor ({sha})"
            )
            warned += 1

    print(f"perf gate: {warned} warning(s) (warn-only, exit 0)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
