#!/usr/bin/env python3
"""Warn-only perf gate for the CI perf-smoke job.

Compares one or more `dntt-bench-v1` result files
(bench_results/BENCH_*.json, written by the harness; their case lists
are merged) against the committed baseline (rust/bench/baseline.json):

* every case listed under baseline `min_gflops` must reach its floor;
* every `min_ratio` entry (e.g. packed >= 2x blocked at 512^3) must hold.

Always exits 0 — misses are surfaced as GitHub `::warning::`
annotations, not failures, until enough CI history exists to make the
gate strict (see DESIGN.md, "CI perf gate"). Stdlib only.

Usage: check_perf.py RESULTS_JSON [RESULTS_JSON...] BASELINE_JSON
"""

import json
import sys


def main() -> int:
    if len(sys.argv) < 3:
        print(f"usage: {sys.argv[0]} RESULTS_JSON [RESULTS_JSON...] BASELINE_JSON", file=sys.stderr)
        return 0  # warn-only: never break the build on harness drift
    cases = {}
    sha = "unknown"
    try:
        for path in sys.argv[1:-1]:
            with open(path) as f:
                results = json.load(f)
            for c in results.get("cases", []):
                cases[c["name"]] = c
            sha = results.get("git_sha", sha)
        with open(sys.argv[-1]) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"::warning::perf gate skipped: {e}")
        return 0

    warned = 0

    for name, floor in baseline.get("min_gflops", {}).items():
        case = cases.get(name)
        if case is None:
            print(f"::warning::perf gate: case '{name}' missing from results ({sha})")
            warned += 1
            continue
        got = case.get("gflops", 0.0)
        verdict = "ok" if got >= floor else "BELOW FLOOR"
        print(f"  {name}: {got:.2f} GF/s (floor {floor:.2f}) {verdict}")
        if got < floor:
            print(
                f"::warning::perf regression: '{name}' at {got:.2f} GF/s "
                f"is below the {floor:.2f} GF/s baseline ({sha})"
            )
            warned += 1

    for ratio in baseline.get("min_ratio", []):
        num = cases.get(ratio["numerator"], {}).get("gflops", 0.0)
        den = cases.get(ratio["denominator"], {}).get("gflops", 0.0)
        if den <= 0.0:
            print(f"::warning::perf gate: ratio '{ratio['name']}' denominator missing ({sha})")
            warned += 1
            continue
        got = num / den
        verdict = "ok" if got >= ratio["min"] else "BELOW FLOOR"
        print(f"  {ratio['name']}: {got:.2f}x (floor {ratio['min']:.2f}x) {verdict}")
        if got < ratio["min"]:
            print(
                f"::warning::perf regression: '{ratio['name']}' at {got:.2f}x "
                f"is below the {ratio['min']:.2f}x floor ({sha})"
            )
            warned += 1

    print(f"perf gate: {warned} warning(s) (warn-only, exit 0)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
