#!/usr/bin/env python3
"""CLI-reference coverage gate for the CI docs job.

Runs `dntt help`, parses the COMMANDS block, and **hard-fails** (exit 1)
if any subcommand has no section in `rust/docs/CLI.md` — a new
subcommand cannot land undocumented. Then runs `dntt <sub> --help` for
every subcommand (ArgSpec prints the usage to stderr and exits
nonzero — that is its help path, not an error here), extracts each
`--flag`, and surfaces flags missing from that subcommand's CLI.md
section as **warn-only** GitHub `::warning::` annotations.

Usage: check_cli_docs.py DNTT_BINARY CLI_MD

Stdlib only.
"""

import re
import subprocess
import sys


def subcommands(binary: str) -> list[str]:
    """Parse the COMMANDS block of `dntt help` (stdout, exit 0)."""
    out = subprocess.run(
        [binary, "help"], capture_output=True, text=True, check=True
    ).stdout
    names = []
    in_block = False
    for line in out.splitlines():
        if line.strip() == "COMMANDS:":
            in_block = True
            continue
        if in_block:
            if not line.strip():
                break
            names.append(line.split()[0])
    if not names:
        sys.exit(f"could not parse a COMMANDS block out of `{binary} help`")
    return names


def flags_of(binary: str, sub: str) -> list[str]:
    """Flags advertised by `dntt <sub> --help` (stderr, nonzero exit)."""
    r = subprocess.run([binary, sub, "--help"], capture_output=True, text=True)
    text = r.stderr + r.stdout
    flags = re.findall(r"^\s+--([a-z][a-z0-9-]*)", text, flags=re.MULTILINE)
    return [f for f in dict.fromkeys(flags) if f != "help"]


def section_of(doc: str, sub: str) -> str | None:
    """The CLI.md slice for one subcommand: from its `dntt <sub>` heading
    to the next subcommand heading (or EOF)."""
    heads = [
        (m.start(), m.group(1))
        for m in re.finditer(r"^#+ .*`?dntt ([a-z-]+)`?", doc, flags=re.MULTILINE)
    ]
    for i, (start, name) in enumerate(heads):
        if name == sub:
            end = heads[i + 1][0] if i + 1 < len(heads) else len(doc)
            return doc[start:end]
    return None


def main() -> int:
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    binary, doc_path = sys.argv[1], sys.argv[2]
    with open(doc_path) as f:
        doc = f.read()

    missing_cmds = []
    missing_flags = 0
    for sub in subcommands(binary):
        section = section_of(doc, sub)
        if section is None:
            missing_cmds.append(sub)
            continue
        for flag in flags_of(binary, sub):
            if f"--{flag}" not in section:
                print(
                    f"::warning::{doc_path}: `dntt {sub}` flag --{flag} "
                    "is not documented in its section"
                )
                missing_flags += 1

    if missing_cmds:
        for sub in missing_cmds:
            print(f"::error::{doc_path}: no section documents `dntt {sub}`")
        return 1
    print(
        f"cli docs gate: all subcommands documented, "
        f"{missing_flags} undocumented flag(s) (warn-only)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
