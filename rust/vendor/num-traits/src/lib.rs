//! Offline std-only subset of the `num-traits` crate.
//!
//! Provides exactly the trait surface `dntt::linalg::scalar` bounds on —
//! [`Float`], [`NumAssign`], [`FromPrimitive`] — implemented for `f32`
//! and `f64` by delegating to the std inherent methods (which always
//! take precedence over these trait methods, so no recursion). Swapping
//! the real `num-traits` back in is a one-line `Cargo.toml` change.

use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Rem, RemAssign, Sub, SubAssign};

/// Floating-point numbers: arithmetic, ordering, and the usual
/// transcendental / rounding methods.
pub trait Float:
    Copy
    + PartialEq
    + PartialOrd
    + Neg<Output = Self>
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Rem<Output = Self>
{
    fn zero() -> Self;
    fn one() -> Self;
    fn nan() -> Self;
    fn infinity() -> Self;
    fn neg_infinity() -> Self;
    fn epsilon() -> Self;
    fn min_positive_value() -> Self;

    fn is_nan(self) -> bool;
    fn is_finite(self) -> bool;
    fn is_sign_negative(self) -> bool;

    fn abs(self) -> Self;
    fn signum(self) -> Self;
    fn recip(self) -> Self;
    fn sqrt(self) -> Self;
    fn powi(self, n: i32) -> Self;
    fn powf(self, n: Self) -> Self;
    fn exp(self) -> Self;
    fn ln(self) -> Self;
    fn log2(self) -> Self;
    fn log10(self) -> Self;
    fn floor(self) -> Self;
    fn ceil(self) -> Self;
    fn round(self) -> Self;
    fn max(self, other: Self) -> Self;
    fn min(self, other: Self) -> Self;
    fn mul_add(self, a: Self, b: Self) -> Self;
    fn hypot(self, other: Self) -> Self;
}

macro_rules! impl_float {
    ($t:ty) => {
        impl Float for $t {
            #[inline]
            fn zero() -> Self {
                0.0
            }
            #[inline]
            fn one() -> Self {
                1.0
            }
            #[inline]
            fn nan() -> Self {
                <$t>::NAN
            }
            #[inline]
            fn infinity() -> Self {
                <$t>::INFINITY
            }
            #[inline]
            fn neg_infinity() -> Self {
                <$t>::NEG_INFINITY
            }
            #[inline]
            fn epsilon() -> Self {
                <$t>::EPSILON
            }
            #[inline]
            fn min_positive_value() -> Self {
                <$t>::MIN_POSITIVE
            }
            #[inline]
            fn is_nan(self) -> bool {
                <$t>::is_nan(self)
            }
            #[inline]
            fn is_finite(self) -> bool {
                <$t>::is_finite(self)
            }
            #[inline]
            fn is_sign_negative(self) -> bool {
                <$t>::is_sign_negative(self)
            }
            #[inline]
            fn abs(self) -> Self {
                <$t>::abs(self)
            }
            #[inline]
            fn signum(self) -> Self {
                <$t>::signum(self)
            }
            #[inline]
            fn recip(self) -> Self {
                <$t>::recip(self)
            }
            #[inline]
            fn sqrt(self) -> Self {
                <$t>::sqrt(self)
            }
            #[inline]
            fn powi(self, n: i32) -> Self {
                <$t>::powi(self, n)
            }
            #[inline]
            fn powf(self, n: Self) -> Self {
                <$t>::powf(self, n)
            }
            #[inline]
            fn exp(self) -> Self {
                <$t>::exp(self)
            }
            #[inline]
            fn ln(self) -> Self {
                <$t>::ln(self)
            }
            #[inline]
            fn log2(self) -> Self {
                <$t>::log2(self)
            }
            #[inline]
            fn log10(self) -> Self {
                <$t>::log10(self)
            }
            #[inline]
            fn floor(self) -> Self {
                <$t>::floor(self)
            }
            #[inline]
            fn ceil(self) -> Self {
                <$t>::ceil(self)
            }
            #[inline]
            fn round(self) -> Self {
                <$t>::round(self)
            }
            #[inline]
            fn max(self, other: Self) -> Self {
                <$t>::max(self, other)
            }
            #[inline]
            fn min(self, other: Self) -> Self {
                <$t>::min(self, other)
            }
            #[inline]
            fn mul_add(self, a: Self, b: Self) -> Self {
                <$t>::mul_add(self, a, b)
            }
            #[inline]
            fn hypot(self, other: Self) -> Self {
                <$t>::hypot(self, other)
            }
        }
    };
}

impl_float!(f32);
impl_float!(f64);

/// The compound-assignment operators, bundled like the real crate.
pub trait NumAssign:
    AddAssign<Self> + SubAssign<Self> + MulAssign<Self> + DivAssign<Self> + RemAssign<Self> + Sized
{
}

impl<T> NumAssign for T where
    T: AddAssign<T> + SubAssign<T> + MulAssign<T> + DivAssign<T> + RemAssign<T>
{
}

/// Conversion from primitive integers / floats.
pub trait FromPrimitive: Sized {
    fn from_i64(n: i64) -> Option<Self>;
    fn from_u64(n: u64) -> Option<Self>;
    fn from_f64(n: f64) -> Option<Self>;
    fn from_usize(n: usize) -> Option<Self> {
        Self::from_u64(n as u64)
    }
    fn from_f32(n: f32) -> Option<Self> {
        Self::from_f64(n as f64)
    }
}

macro_rules! impl_from_primitive {
    ($t:ty) => {
        impl FromPrimitive for $t {
            #[inline]
            fn from_i64(n: i64) -> Option<Self> {
                Some(n as $t)
            }
            #[inline]
            fn from_u64(n: u64) -> Option<Self> {
                Some(n as $t)
            }
            #[inline]
            fn from_f64(n: f64) -> Option<Self> {
                Some(n as $t)
            }
        }
    };
}

impl_from_primitive!(f32);
impl_from_primitive!(f64);

#[cfg(test)]
mod tests {
    use super::*;

    fn generic_norm<T: Float>(xs: &[T]) -> T {
        let mut s = T::zero();
        for &x in xs {
            s = x.mul_add(x, s);
        }
        s.sqrt()
    }

    #[test]
    fn float_surface_works_generically() {
        assert_eq!(generic_norm(&[3.0f64, 4.0]), 5.0);
        assert_eq!(generic_norm(&[3.0f32, 4.0]), 5.0);
        assert!(f64::nan().is_nan());
        assert_eq!((-2.5f64).abs(), 2.5);
        assert_eq!(Float::max(1.0f64, 2.0), 2.0);
    }

    #[test]
    fn num_assign_blanket_covers_floats() {
        fn takes<T: NumAssign + Float>(mut x: T) -> T {
            x += T::one();
            x *= x;
            x
        }
        assert_eq!(takes(1.0f64), 4.0);
    }

    #[test]
    fn from_primitive_roundtrips() {
        assert_eq!(f64::from_i64(-3), Some(-3.0));
        assert_eq!(f32::from_usize(7), Some(7.0));
        assert_eq!(f64::from_f64(0.5), Some(0.5));
    }
}
