//! Offline std-only subset of the `log` logging facade.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides exactly the surface the workspace uses: the five severity
//! macros, [`Level`] / [`LevelFilter`], the [`Log`] trait, and the global
//! `set_logger` / `set_max_level` registry. Semantics match the real
//! facade for that subset (same level ordering, same `max_level` fast
//! path), so swapping the real `log` crate back in is a one-line
//! `Cargo.toml` change.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Logging severity, most severe first (matches the `log` crate: a record
/// is enabled when `record.level() <= max_level`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    /// The filter that admits exactly this level and above.
    pub fn to_level_filter(self) -> LevelFilter {
        match self {
            Level::Error => LevelFilter::Error,
            Level::Warn => LevelFilter::Warn,
            Level::Info => LevelFilter::Info,
            Level::Debug => LevelFilter::Debug,
            Level::Trace => LevelFilter::Trace,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

/// Verbosity ceiling for the global logger.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl LevelFilter {
    fn from_usize(v: usize) -> LevelFilter {
        match v {
            1 => LevelFilter::Error,
            2 => LevelFilter::Warn,
            3 => LevelFilter::Info,
            4 => LevelFilter::Debug,
            5 => LevelFilter::Trace,
            _ => LevelFilter::Off,
        }
    }
}

/// Metadata of one log record.
#[derive(Clone, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn builder() -> MetadataBuilder<'a> {
        MetadataBuilder { level: Level::Info, target: "" }
    }
    pub fn level(&self) -> Level {
        self.level
    }
    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// Builder for [`Metadata`].
pub struct MetadataBuilder<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> MetadataBuilder<'a> {
    pub fn level(mut self, level: Level) -> Self {
        self.level = level;
        self
    }
    pub fn target(mut self, target: &'a str) -> Self {
        self.target = target;
        self
    }
    pub fn build(self) -> Metadata<'a> {
        Metadata { level: self.level, target: self.target }
    }
}

/// One log record: metadata plus the formatted message arguments.
#[derive(Clone, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }
    pub fn level(&self) -> Level {
        self.metadata.level
    }
    pub fn target(&self) -> &'a str {
        self.metadata.target
    }
    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata<'_>) -> bool {
        false
    }
    fn log(&self, _: &Record<'_>) {}
    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: Mutex<Option<&'static dyn Log>> = Mutex::new(None);
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    let mut slot = LOGGER.lock().unwrap();
    if slot.is_some() {
        return Err(SetLoggerError(()));
    }
    *slot = Some(logger);
    Ok(())
}

/// Set the global verbosity ceiling (records above it are skipped before
/// reaching the logger).
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global verbosity ceiling.
pub fn max_level() -> LevelFilter {
    LevelFilter::from_usize(MAX_LEVEL.load(Ordering::Relaxed))
}

/// The installed logger (a no-op logger when none is installed).
pub fn logger() -> &'static dyn Log {
    LOGGER.lock().unwrap().unwrap_or(&NOP)
}

/// Implementation detail of the macros.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if (level as usize) <= MAX_LEVEL.load(Ordering::Relaxed) {
        let record = Record { metadata: Metadata { level, target }, args };
        let l = logger();
        if l.enabled(record.metadata()) {
            l.log(&record);
        }
    }
}

/// Log at an explicit [`Level`].
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

/// Log at [`Level::Error`].
#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

/// Log at [`Level::Warn`].
#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

/// Log at [`Level::Info`].
#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

/// Log at [`Level::Debug`].
#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

/// Log at [`Level::Trace`].
#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_matches_facade() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Debug <= Level::Debug);
        assert_eq!(Level::Warn.to_level_filter(), LevelFilter::Warn);
    }

    #[test]
    fn max_level_gates_records() {
        set_max_level(LevelFilter::Warn);
        assert_eq!(max_level(), LevelFilter::Warn);
        // Debug (4) > Warn (2): skipped before the logger is consulted.
        debug!("not delivered {}", 1);
        set_max_level(LevelFilter::Off);
    }

    #[test]
    fn display_pads() {
        assert_eq!(format!("{:<5}", Level::Warn), "WARN ");
    }
}
