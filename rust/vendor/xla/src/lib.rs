//! API-compatible stub of the `xla` crate's PJRT surface.
//!
//! The offline build image ships no XLA C++ runtime, so this vendored
//! crate mirrors exactly the types and signatures
//! `dntt::runtime::pjrt` compiles against and fails fast at
//! [`PjRtClient::cpu`] with a descriptive error. The engine treats that
//! failure as "PJRT unavailable" and the coordinator falls back to the
//! native backend; artifact-gated tests skip themselves. Deploying
//! against the real bindings is a `Cargo.toml` swap (point the `xla`
//! dependency at the real crate) with no source changes.

use std::fmt;
use std::path::Path;

/// Stub error: every entry point reports the runtime as unavailable.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: XLA/PJRT runtime not available in this build \
             (vendored stub — link the real `xla` crate to enable it)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    /// The real crate initializes the PJRT CPU plugin here; the stub
    /// reports it missing.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    /// Compile an XLA computation for this client.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file (`*.hlo.txt` artifact).
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from an HLO module (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, loaded executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with one input literal per parameter; returns one buffer
    /// row per device.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer holding one execution output (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host-side tensor literal (stub).
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    /// Split a tuple literal into its elements.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::decompose_tuple"))
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn literal_surface_is_callable() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(Literal.to_vec::<f32>().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
    }
}
