//! The decomposition-service battery (ISSUE 8 acceptance): jobs run
//! through the [`JobServer`]'s shared rank pool are bitwise-identical to
//! solo [`run_job`] runs, resubmitting an identical config is a cache
//! hit that launches no ranks, an interrupted job resumes through the
//! server, and the priority/fair-share admission order is a
//! deterministic function of the submitted set.
//!
//! Under the `fault-inject` feature the battery also kills a served job
//! mid-run and proves the server-forced checkpoint brings it back
//! bitwise-identical.

mod common;

use common::{assert_cores_bitwise, assert_ht_nodes_bitwise, ht_cfg_fixed, unique_temp_dir};
use dntt::coordinator::{
    run_job, Decomposition, InputSpec, JobConfig, JobRequest, JobServer, Priority, ServerConfig,
};
use dntt::dist::ProcGrid;
use dntt::ht::SyntheticHt;
use dntt::nmf::NmfConfig;
use dntt::tensor::io::{load_artifact, Artifact};
use dntt::ttrain::{SyntheticTt, TtConfig};
use std::path::{Path, PathBuf};

/// A small TT job; `seed` varies the tensor, `grid` its parallelism.
fn tt_job(seed: u64, grid: Vec<usize>) -> JobConfig {
    JobConfig {
        tt: TtConfig {
            eps: 1e-6,
            nmf: NmfConfig { max_iters: 20, ..Default::default() },
            ..Default::default()
        },
        check_error: false,
        ..JobConfig::new(
            InputSpec::Synthetic(SyntheticTt::new(vec![6, 6, 6], vec![2, 2], seed)),
            ProcGrid::new(grid).unwrap(),
        )
    }
}

/// A small HT job on a 2×1×2 grid (4 ranks), dense synthetic-HT input.
fn ht_job(seed: u64) -> JobConfig {
    JobConfig {
        decomp: Decomposition::Ht,
        ht: ht_cfg_fixed(4, vec![2; 4]),
        check_error: false,
        ..JobConfig::new(
            InputSpec::Dense(std::sync::Arc::new(
                SyntheticHt::new(vec![4, 4, 4], 2, seed).dense(),
            )),
            ProcGrid::new(vec![2, 1, 2]).unwrap(),
        )
    }
}

fn server_over(cache_dir: &Path, pool: usize) -> JobServer {
    JobServer::new(ServerConfig::new(pool, cache_dir)).unwrap()
}

/// ISSUE acceptance: mixed-size jobs submitted concurrently through the
/// server — overcommitting the pool so they queue and share leases —
/// each produce output bitwise-identical to a solo `run_job`, and the
/// committed `.dntt` artifact matches the in-memory factors bitwise.
#[test]
fn concurrent_mixed_size_jobs_match_solo_bitwise() {
    let cache = unique_temp_dir("jobsrv_mixed");
    let srv = server_over(&cache, 8);
    // 4 + 2 + 4 = 10 ranks wanted > 8 pooled: the third job waits.
    let id_a = srv.submit(JobRequest::new(tt_job(1, vec![2, 2, 1]))).unwrap();
    let id_b = srv.submit(JobRequest::new(tt_job(2, vec![2, 1, 1]))).unwrap();
    let id_c = srv.submit(JobRequest::new(ht_job(3))).unwrap();
    srv.drain();

    let solo_a = run_job(&tt_job(1, vec![2, 2, 1])).unwrap();
    let solo_b = run_job(&tt_job(2, vec![2, 1, 1])).unwrap();
    let solo_c = run_job(&ht_job(3)).unwrap();

    for (id, solo, what) in
        [(id_a, &solo_a, "job a"), (id_b, &solo_b, "job b"), (id_c, &solo_c, "job c")]
    {
        let o = srv.outcome(id).expect("drained");
        assert!(o.is_ok(), "{what} failed: {:?}", o.error);
        assert!(!o.cache_hit && !o.coalesced, "{what} must actually execute");
        let rep = o.report.as_ref().expect("executed jobs carry a report");
        // In-memory factors: served == solo, bitwise.
        match solo.output.tt() {
            Some(tt) => assert_cores_bitwise(rep.output.tt().unwrap(), tt, what),
            None => assert_ht_nodes_bitwise(
                rep.output.ht().unwrap(),
                solo.output.ht().unwrap(),
                what,
            ),
        }
        // And the committed artifact stores exactly those factors.
        let art = load_artifact(o.artifact.as_ref().unwrap()).unwrap();
        match art {
            Artifact::Tt(tt) => {
                for (l, (ca, cb)) in
                    tt.cores().iter().zip(solo.output.tt().unwrap().tt.cores()).enumerate()
                {
                    assert_eq!(ca.as_slice(), cb.as_slice(), "{what}: artifact core {l}");
                }
            }
            Artifact::Ht(ht) => {
                for (t, (na, nb)) in
                    ht.nodes().iter().zip(solo.output.ht().unwrap().ht.nodes()).enumerate()
                {
                    assert_eq!(
                        na.mat().as_slice(),
                        nb.mat().as_slice(),
                        "{what}: artifact node {t}"
                    );
                }
            }
        }
    }
    assert_eq!(srv.stats().executed, 3);
    let _ = std::fs::remove_dir_all(&cache);
}

/// ISSUE acceptance: resubmitting an identical config — here through a
/// *fresh* server over the same cache directory — is a cache hit: no
/// lease is ever granted, and the artifact bytes are the ones the first
/// run committed.
#[test]
fn cache_hit_launches_no_ranks_and_returns_identical_artifact() {
    let cache = unique_temp_dir("jobsrv_hit");
    let job = || tt_job(5, vec![2, 1, 2]);

    let first = server_over(&cache, 4);
    let id1 = first.submit(JobRequest::new(job())).unwrap();
    first.drain();
    let o1 = first.outcome(id1).unwrap();
    assert!(o1.is_ok(), "seed run failed: {:?}", o1.error);
    let bytes1 = std::fs::read(o1.artifact.as_ref().unwrap()).unwrap();

    // A fresh server (new pool, empty stats) over the same cache.
    let second = server_over(&cache, 4);
    let id2 = second.submit(JobRequest::new(job())).unwrap();
    second.drain();
    let o2 = second.outcome(id2).unwrap();
    assert!(o2.cache_hit, "identical config must be served from the cache");
    assert_eq!(second.stats().leases_granted, 0, "a cache hit must launch no ranks");
    assert_eq!(second.stats().executed, 0);
    let bytes2 = std::fs::read(o2.artifact.as_ref().unwrap()).unwrap();
    assert_eq!(bytes1, bytes2, "cache hit must return the identical artifact");
    let _ = std::fs::remove_dir_all(&cache);
}

/// ISSUE acceptance: an interrupted job resumes through the server. The
/// server forces checkpointing into the cache's `ckpt/` directory, so
/// when the committed artifact is lost (here: deleted, modelling a crash
/// between checkpoint and commit), a resubmit re-executes *with resume*
/// and still lands bitwise on the solo result.
#[test]
fn interrupted_job_resumes_through_server() {
    let cache = unique_temp_dir("jobsrv_resume");
    let job = || tt_job(9, vec![2, 2, 1]);
    let fp = job().fingerprint();

    let first = server_over(&cache, 4);
    let id1 = first.submit(JobRequest::new(job())).unwrap();
    first.drain();
    assert!(first.outcome(id1).unwrap().is_ok());
    let ckpt_dir = first.cache().ckpt_dir(fp);
    assert!(
        std::fs::read_dir(&ckpt_dir).map(|rd| rd.count() > 0).unwrap_or(false),
        "server-forced checkpoint must exist at {ckpt_dir:?}"
    );
    // "Interrupt": the artifact never committed, the checkpoint survived.
    std::fs::remove_file(first.cache().artifact_path(fp)).unwrap();
    std::fs::remove_file(first.cache().meta_path(fp)).unwrap();
    drop(first);

    let second = server_over(&cache, 4);
    let id2 = second.submit(JobRequest::new(job())).unwrap();
    second.drain();
    let o2 = second.outcome(id2).unwrap();
    assert!(o2.is_ok(), "resumed run failed: {:?}", o2.error);
    assert!(!o2.cache_hit, "artifact was deleted — this must re-execute");
    assert_eq!(second.stats().executed, 1);

    let solo = run_job(&job()).unwrap();
    assert_cores_bitwise(
        o2.report.as_ref().unwrap().output.tt().unwrap(),
        solo.output.tt().unwrap(),
        "resumed-through-server vs solo",
    );
    let _ = std::fs::remove_dir_all(&cache);
}

/// ISSUE acceptance: the admission order is deterministic — a pure
/// function of the submitted set. Two independent servers (separate
/// caches, so both actually admit) given the same submissions in the
/// same order produce identical admission logs.
#[test]
fn priority_admission_order_is_deterministic() {
    let submit_all = |srv: &JobServer| {
        // Mixed priorities and tenants; seeds make each job distinct.
        for (seed, tenant, prio) in [
            (20, "a", Priority::Normal),
            (21, "a", Priority::Low),
            (22, "b", Priority::Normal),
            (23, "b", Priority::High),
            (24, "c", Priority::Normal),
        ] {
            srv.submit(
                JobRequest::new(tt_job(seed, vec![2, 1, 1])).tenant(tenant).priority(prio),
            )
            .unwrap();
        }
    };
    let run = |tag: &str| -> (Vec<String>, PathBuf) {
        let cache = unique_temp_dir(tag);
        let srv = server_over(&cache, 2); // fully serialized: order is visible
        submit_all(&srv);
        srv.drain();
        (srv.admission_log(), cache)
    };
    let (log1, c1) = run("jobsrv_order1");
    let (log2, c2) = run("jobsrv_order2");
    assert_eq!(log1, log2, "admission log must be deterministic");
    assert_eq!(log1.len(), 5);
    // High priority admits first, Low last, regardless of submit order.
    assert!(log1.first().unwrap().contains("prio=high"), "log: {log1:?}");
    assert!(log1.last().unwrap().contains("prio=low"), "log: {log1:?}");
    let _ = std::fs::remove_dir_all(&c1);
    let _ = std::fs::remove_dir_all(&c2);
}

/// Duplicate submissions inside one batch coalesce onto a single
/// execution whose outcome (and artifact) both submitters share.
#[test]
fn duplicates_in_flight_share_one_execution() {
    let cache = unique_temp_dir("jobsrv_dup");
    let srv = server_over(&cache, 4);
    let ids: Vec<_> = (0..3)
        .map(|_| srv.submit(JobRequest::new(tt_job(30, vec![2, 1, 2]))).unwrap())
        .collect();
    srv.drain();
    let s = srv.stats();
    assert_eq!(s.executed, 1, "identical configs must execute once");
    assert_eq!(s.cache_hits + s.coalesced, 2);
    let arts: Vec<_> = ids
        .iter()
        .map(|id| {
            let o = srv.outcome(*id).unwrap();
            assert!(o.is_ok(), "{:?}", o.error);
            o.artifact.clone().unwrap()
        })
        .collect();
    assert!(arts.windows(2).all(|w| w[0] == w[1]), "all submitters share the artifact");
    let _ = std::fs::remove_dir_all(&cache);
}

/// `fault-inject` half: a served job killed at a mid-run collective
/// recovers *through the server* — the forced checkpoint plus the
/// coordinator's relaunch loop reuse the same lease, and the final
/// factors are bitwise-identical to an uninterrupted solo run.
#[cfg(feature = "fault-inject")]
mod fault {
    use super::*;
    use dntt::dist::{faults, FaultPlan};

    #[test]
    fn served_job_killed_mid_run_recovers_bitwise() {
        let reference = run_job(&tt_job(40, vec![2, 2, 1])).unwrap();

        // Find the victim rank's collective count with a counting plan.
        let counter = FaultPlan::count_only();
        faults::arm(&counter);
        run_job(&tt_job(40, vec![2, 2, 1])).unwrap();
        faults::disarm();
        let total = counter.ops_seen(1);
        assert!(total > 10, "tiny job still runs {total} collectives");

        let cache = unique_temp_dir("jobsrv_kill");
        let srv = server_over(&cache, 4);
        let plan = FaultPlan::kill_at(1, total / 2);
        let id = srv
            .submit(JobRequest::new(tt_job(40, vec![2, 2, 1])).fault_plan(plan.clone()))
            .unwrap();
        srv.drain();
        assert_eq!(plan.fired_count(), 1, "the scheduled death must have fired");
        let o = srv.outcome(id).unwrap();
        assert!(o.is_ok(), "killed job did not recover: {:?}", o.error);
        assert_cores_bitwise(
            o.report.as_ref().unwrap().output.tt().unwrap(),
            reference.output.tt().unwrap(),
            "killed-through-server vs solo",
        );
        let _ = std::fs::remove_dir_all(&cache);
    }
}
