//! Integration: baselines vs nTT — the qualitative relationships the
//! paper's Fig 2 depends on must hold on this implementation.

use dntt::baselines::{ntucker_mu, tt_svd, tucker_hooi_fixed};
use dntt::nmf::NmfConfig;
use dntt::tensor::DenseTensor;
use dntt::ttrain::{ntt_serial, SyntheticTt, TtConfig};
use dntt::util::rng::Rng;

fn ntt_cfg(iters: usize) -> TtConfig {
    TtConfig {
        eps: 1e-6,
        nmf: NmfConfig { max_iters: iters, ..Default::default() },
        ..Default::default()
    }
}

/// On a TT-structured tensor, TT formats store far fewer parameters than
/// Tucker at matched (small) error — the core Fig-2 relationship.
#[test]
fn tt_compresses_better_than_tucker_on_tt_data() {
    let syn = SyntheticTt::new(vec![10, 10, 10, 10], vec![3, 3, 3], 1);
    let t = syn.dense();
    let tt = tt_svd(&t, 1e-8).unwrap();
    assert!(tt.rel_error(&t) < 1e-6);
    // Tucker needs multilinear ranks >= TT ranks; even at (3,3,3,3) its core
    // adds 3^4 params. Compare at ranks that give comparable error.
    let tucker = tucker_hooi_fixed(&t, &[3, 9, 9, 3], 2).unwrap();
    let terr = t.rel_error(&tucker.reconstruct());
    assert!(terr < 0.05, "tucker err {terr}");
    assert!(
        tt.compression_ratio() > tucker.compression_ratio(),
        "TT {} vs Tucker {}",
        tt.compression_ratio(),
        tucker.compression_ratio()
    );
}

/// nTT tracks TT closely in compression but keeps non-negativity; at equal
/// eps the SVD-TT error is a lower bound (Eckart-Young per stage).
#[test]
fn ntt_error_lower_bounded_by_tt() {
    let syn = SyntheticTt::new(vec![8, 8, 8], vec![3, 3], 2);
    let t = syn.dense();
    let tt = tt_svd(&t, 0.05).unwrap();
    let ntt = ntt_serial(&t, &TtConfig { eps: 0.05, ..ntt_cfg(200) }).unwrap();
    assert!(ntt.tt.rel_error(&t) + 1e-12 >= tt.rel_error(&t));
    assert!(ntt.tt.is_nonneg());
}

/// Non-negative Tucker is dominated by nTT on TT-structured data, mirroring
/// Fig 2's nTucker-vs-nTT gap.
#[test]
fn ntucker_worse_compression_than_ntt() {
    let syn = SyntheticTt::new(vec![8, 8, 8, 8], vec![2, 2, 2], 3);
    let t = syn.dense();
    let ntt = ntt_serial(&t, &ntt_cfg(150)).unwrap();
    let ntk = ntucker_mu(&t, &[2, 4, 4, 2], 150, 9).unwrap();
    let (e1, e2) = (ntt.tt.rel_error(&t), t.rel_error(&ntk.reconstruct()));
    // At comparable error, nTT stores fewer parameters.
    if e2 < 2.0 * e1.max(0.01) {
        assert!(ntt.tt.compression_ratio() > ntk.compression_ratio());
    }
}

/// A full-rank random tensor defeats all compressors at tight eps — sanity
/// that nothing "compresses" noise for free.
#[test]
fn random_tensor_incompressible_at_tight_eps() {
    let mut rng = Rng::new(4);
    let t = DenseTensor::<f64>::rand_uniform(&[6, 6, 6], &mut rng);
    let tt = tt_svd(&t, 1e-9).unwrap();
    assert!(tt.compression_ratio() <= 1.0 + 1e-9);
}
