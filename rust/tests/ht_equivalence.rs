//! Integration: the distributed non-negative hierarchical Tucker against
//! ground truth — serial ε-target reconstruction, serial-vs-distributed
//! equivalence, run-to-run/cross-rank bitwise determinism, coordinator
//! dispatch with per-tree-node stage reports, and the zero-row/column
//! prune path.

mod common;

use common::ht_cfg as cfg;
use dntt::coordinator::{run_job, Decomposition, InputSpec, JobConfig};
use dntt::dist::chunkstore::SpillMode;
use dntt::dist::{Comm, ProcGrid, SharedStore, TensorBlock};
use dntt::ht::{dist_nht, ht_serial, nht_on_threads, SyntheticHt};
use dntt::runtime::NativeBackend;
use dntt::tensor::DenseTensor;
use dntt::ttrain::driver::extract_block;
use std::sync::Arc;

/// (a) Serial HT hits the ε reconstruction target on a synthetic
/// rank-(2,…,2) tensor.
#[test]
fn serial_ht_meets_reconstruction_target() {
    let syn = SyntheticHt::new(vec![4, 5, 6, 4], 2, 11);
    let t = syn.dense();
    let out = ht_serial(&t, &cfg(400)).unwrap();
    assert!(out.ht.is_nonneg(), "nHT node matrices must be non-negative");
    // d = 4 → 7 tree nodes, 3 interior → 6 per-tree-node stage records.
    assert_eq!(out.ht.tree().len(), 7);
    assert_eq!(out.stages.len(), 6);
    let err = out.ht.rel_error(&t);
    assert!(err < 0.05, "serial HT rel err {err} above the ε target");
    // Rank selection stays bounded: NMF residual can inflate the exact
    // generator rank 2 on the deeper nodes (same effect the TT suite
    // documents), but never past the mode sizes.
    assert!(out.ht.ranks()[1..].iter().all(|&r| (1..=8).contains(&r)), "ranks {:?}", out.ht.ranks());
}

/// Fixed edge ranks recover the generator's exact rank chain without SVD.
#[test]
fn fixed_rank_ht_recovers_generator_ranks() {
    let syn = SyntheticHt::new(vec![4, 4, 6], 2, 21);
    let t = syn.dense();
    let mut c = cfg(300);
    c.fixed_ranks = Some(vec![2; 4]);
    let out = ht_serial(&t, &c).unwrap();
    assert!(out.stages.iter().all(|s| s.svd_eps.is_nan()));
    assert_eq!(out.ht.ranks()[0], 1);
    assert!(out.ht.ranks()[1..].iter().all(|&r| r == 2));
    assert!(out.ht.rel_error(&t) < 0.05);
}

/// (b) Serial vs distributed (p = 4): same selected ranks, same factors up
/// to the (fixed-order) reduction roundoff — the deterministic-collectives
/// guarantee the TT equivalence tests rely on. Exact bitwise identity
/// across *thread counts* is not attainable (partial sums associate
/// differently at p = 1 vs p = 4); bitwise identity within a world and
/// across repeated runs is asserted separately below.
#[test]
fn distributed_p4_matches_serial() {
    let syn = SyntheticHt::new(vec![4, 4, 6], 2, 13);
    let t = syn.dense();
    let serial = ht_serial(&t, &cfg(150)).unwrap();
    let grid = ProcGrid::new(vec![2, 1, 2]).unwrap();
    let dist = nht_on_threads(&t, &grid, &cfg(150)).unwrap();
    assert_eq!(serial.ht.ranks(), dist.ht.ranks());
    for (a, b) in serial.ht.nodes().iter().zip(dist.ht.nodes()) {
        for (x, y) in a.mat().as_slice().iter().zip(b.mat().as_slice()) {
            assert!((x - y).abs() < 1e-5, "serial {x} vs p=4 {y}");
        }
    }
    // Reconstructions agree too.
    assert!((serial.ht.rel_error(&t) - dist.ht.rel_error(&t)).abs() < 1e-4);
}

/// Within one p = 4 world every rank assembles bitwise-identical factors,
/// and two independent p = 4 runs are bitwise identical to each other
/// (deterministic rank-ordered collectives + deterministic init).
#[test]
fn p4_factors_bitwise_identical_across_ranks_and_runs() {
    let syn = SyntheticHt::new(vec![4, 6, 4], 2, 29);
    let t = syn.dense();
    let pg = ProcGrid::new(vec![2, 2, 1]).unwrap();
    let grid = pg.to_2d();
    let run_world = || {
        let t = t.clone();
        let pg = pg.clone();
        let c = cfg(80);
        let dims = t.dims().to_vec();
        let store = SharedStore::new(SpillMode::Memory);
        Comm::run(4, move |mut world| {
            let my = extract_block(&t, &pg, world.rank());
            let (mut row, mut col) = grid.make_subcomms(&mut world);
            dist_nht(
                &mut world, &mut row, &mut col, &store, &pg, grid, &dims,
                TensorBlock::Dense(my), &NativeBackend, &c,
                dntt::linalg::KernelCfg::default(), None,
            )
            .unwrap()
        })
    };
    let run1 = run_world();
    let run2 = run_world();
    let reference: Vec<Vec<f64>> =
        run1[0].ht.nodes().iter().map(|n| n.mat().as_slice().to_vec()).collect();
    for (who, out) in
        run1.iter().skip(1).map(|o| ("rank", o)).chain(run2.iter().map(|o| ("rerun", o)))
    {
        assert_eq!(out.ht.ranks(), run1[0].ht.ranks());
        for (got, want) in out.ht.nodes().iter().zip(&reference) {
            assert_eq!(got.mat().as_slice(), want.as_slice(), "{who}: factors must be bitwise identical");
        }
    }
}

/// `run_job` with `Decomposition::Ht` returns a JobReport carrying
/// per-tree-node timings.
#[test]
fn run_job_ht_reports_per_tree_node_stages() {
    let syn = SyntheticHt::new(vec![6, 4, 6], 2, 33);
    let job = JobConfig {
        decomp: Decomposition::Ht,
        ht: cfg(120),
        ..JobConfig::new(
            InputSpec::Dense(Arc::new(syn.dense())),
            ProcGrid::new(vec![2, 1, 2]).unwrap(),
        )
    };
    let rep = run_job(&job).unwrap();
    let out = rep.output.ht().expect("HT job must return an HT output");
    assert_eq!(out.stages.len(), 4); // two interior nodes × two edges
    for st in &out.stages {
        assert!(st.secs >= 0.0);
        assert!(st.node < out.ht.tree().len());
        assert!(!out.ht.tree().is_leaf(st.node));
    }
    assert!(rep.rel_error.unwrap() < 0.1);
    assert!(rep.compression > 0.0);
    let s = rep.summary();
    assert!(s.contains("decomp ht") && s.contains("HT edge ranks"));
}

/// The prune path: a tensor with an all-zero slice decomposes through the
/// pruned NMF and comes back with the slice exactly zero.
#[test]
fn ht_prunes_zero_slices() {
    let syn = SyntheticHt::new(vec![4, 4, 4], 2, 41);
    let mut t = syn.dense();
    let dims = t.dims().to_vec();
    for i1 in 0..dims[1] {
        for i2 in 0..dims[2] {
            t.set(&[2, i1, i2], 0.0);
        }
    }
    let mut c = cfg(250);
    c.prune = true;
    let out = ht_serial(&t, &c).unwrap();
    assert!(out.ht.is_nonneg());
    let err = out.ht.rel_error(&t);
    assert!(err < 0.05, "pruned HT rel err {err}");
    // The zero slice reconstructs as exact zeros.
    let rec: DenseTensor<f64> = out.ht.reconstruct();
    for i1 in 0..dims[1] {
        for i2 in 0..dims[2] {
            assert_eq!(rec.get(&[2, i1, i2]), 0.0);
        }
    }
}
