//! Property tests over the system's cross-module invariants (the library's
//! substitute for proptest — see `dntt::util::prop`).

mod common;

use common::unique_temp_dir;
use dntt::dist::checkpoint::{restore_array, snapshot_array, ArraySnapshot};
use dntt::dist::chunkstore::{Layout, SharedStore, SpillMode, TensorBlock};
use dntt::dist::{BlockDim, Comm, Grid2d};
use dntt::tensor::sparse::SparseChunk;
use dntt::util::json::Json;
use dntt::linalg::gemm::{gram_mt_m, matmul, matmul_at_b};
use dntt::linalg::Mat;
use dntt::nmf::{dist_nmf, NmfAlgo, NmfConfig};
use dntt::runtime::native::NativeBackend;
use dntt::tensor::DenseTensor;
use dntt::ttrain::{ntt_serial, SyntheticTt, TtConfig};
use dntt::util::prop::{check, check_cases};

/// Chunk-store views reproduce the logical array for every layout kind.
#[test]
fn prop_store_roundtrip_all_layouts() {
    check_cases(9001, 40, |rng| {
        let m = 1 + rng.below(15);
        let n = 1 + rng.below(15);
        let pr = 1 + rng.below(3);
        let pc = 1 + rng.below(3);
        let x = Mat::<f64>::rand_uniform(m, n, rng);
        // MatGrid publish + read-back.
        let layout = Layout::MatGrid { m, n, pr, pc };
        let store = SharedStore::new(SpillMode::Memory);
        let rows = BlockDim::new(m, pr);
        let cols = BlockDim::new(n, pc);
        for bi in 0..pr {
            for bj in 0..pc {
                let mut chunk = Vec::new();
                for i in 0..rows.size_of(bi) {
                    for j in 0..cols.size_of(bj) {
                        chunk.push(x[(rows.start_of(bi) + i, cols.start_of(bj) + j)]);
                    }
                }
                store.publish("x", &layout, bi * pc + bj, chunk).unwrap();
            }
        }
        if store.view("x").unwrap().to_dense() != x.as_slice() {
            return Err(format!("matgrid roundtrip {m}x{n} {pr}x{pc}"));
        }
        Ok(())
    });
}

/// Checkpoint snapshot → restore is the identity on the chunk store, for
/// random layout geometries (TensorGrid / MatGrid / HtGrid), randomly
/// mixed dense and sparse chunks, memory- and disk-backed stores:
/// bitwise-identical logical contents, preserved representation
/// (`has_sparse`, `nnz_estimate`) and exact byte accounting against the
/// spill formats.
#[test]
fn prop_snapshot_roundtrip_all_layouts() {
    check_cases(9008, 40, |rng| {
        // Random layout among the three publishable-geometry kinds.
        let layout = match rng.below(3) {
            0 => {
                let d = 2 + rng.below(2);
                let dims: Vec<usize> = (0..d).map(|_| 1 + rng.below(5)).collect();
                let grid: Vec<usize> =
                    dims.iter().map(|&n| 1 + rng.below(n.min(3))).collect();
                Layout::TensorGrid { dims, grid }
            }
            1 => Layout::MatGrid {
                m: 1 + rng.below(10),
                n: 1 + rng.below(10),
                pr: 1 + rng.below(3),
                pc: 1 + rng.below(3),
            },
            _ => Layout::HtGrid {
                r: 1 + rng.below(5),
                n: 1 + rng.below(10),
                pr: 1 + rng.below(2),
                pc: 1 + rng.below(3),
            },
        };
        let disk_store = rng.below(2) == 1;
        let dir = unique_temp_dir("prop_snap");
        let spill_dir = unique_temp_dir("prop_snap_spill");
        let store = SharedStore::new(if disk_store {
            SpillMode::Disk(spill_dir.clone())
        } else {
            SpillMode::Memory
        });
        // Publish every chunk, randomly dense or sparse.
        for c in 0..layout.num_chunks() {
            let len = layout.chunk_len(c);
            let block = if rng.below(2) == 0 {
                TensorBlock::Dense((0..len).map(|_| rng.uniform()).collect())
            } else {
                let idx: Vec<usize> = (0..len).filter(|_| rng.below(3) == 0).collect();
                let vals: Vec<f64> = idx.iter().map(|_| 1.0 + rng.uniform()).collect();
                TensorBlock::Sparse(SparseChunk::new(len, idx, vals).unwrap())
            };
            store.publish_block("a", &layout, c, block).map_err(|e| e.to_string())?;
        }
        let view = store.view("a").map_err(|e| e.to_string())?;
        let snap = snapshot_array(&dir, "a", &view).map_err(|e| e.to_string())?;
        // Byte accounting: every file's size equals both the manifest
        // record and what the spill format dictates.
        for meta in &snap.chunks {
            let want = match meta.nnz {
                None => 8 * meta.len as u64,
                Some(nnz) => 8 * (1 + 2 * nnz) as u64,
            };
            if meta.bytes != want {
                return Err(format!(
                    "{}: recorded {} bytes, format says {want}",
                    meta.file, meta.bytes
                ));
            }
            let on_disk = std::fs::metadata(dir.join(&meta.file)).map_err(|e| e.to_string())?.len();
            if on_disk != want {
                return Err(format!("{}: {on_disk} bytes on disk, expected {want}", meta.file));
            }
        }
        // The snapshot record survives a JSON text round trip.
        let snap2 = ArraySnapshot::from_json(
            &Json::parse(&snap.to_json().to_string()).map_err(|e| e.to_string())?,
        )
        .map_err(|e| e.to_string())?;
        if snap2 != snap {
            return Err("snapshot JSON roundtrip changed the record".into());
        }
        // Restore into a fresh store: bitwise-identical contents and
        // preserved representation.
        let store2 = SharedStore::new(SpillMode::Memory);
        restore_array(&dir, &snap2, &store2, "b").map_err(|e| e.to_string())?;
        let view2 = store2.view("b").map_err(|e| e.to_string())?;
        if view2.to_dense() != view.to_dense() {
            return Err(format!("restored contents differ for {layout:?}"));
        }
        if view2.has_sparse() != view.has_sparse()
            || view2.nnz_estimate() != view.nnz_estimate()
        {
            return Err("restored representation differs".into());
        }
        drop(view);
        drop(view2);
        let _ = std::fs::remove_dir_all(&dir);
        drop(store);
        let _ = std::fs::remove_dir_all(&spill_dir);
        Ok(())
    });
}

/// Distributed collectives equal serial reductions for random shapes.
#[test]
fn prop_collectives_match_serial() {
    check_cases(9002, 12, |rng| {
        let p = 1 + rng.below(6);
        let len = 1 + rng.below(50);
        let data: Vec<Vec<f64>> = (0..p).map(|_| (0..len).map(|_| rng.uniform()).collect()).collect();
        let want: Vec<f64> =
            (0..len).map(|i| data.iter().map(|d| d[i]).sum()).collect();
        let data2 = data.clone();
        let outs = Comm::run(p, move |mut c| {
            let mut v = data2[c.rank()].clone();
            c.all_reduce_sum(&mut v);
            v
        });
        for o in outs {
            for (a, b) in o.iter().zip(&want) {
                if (a - b).abs() > 1e-9 {
                    return Err(format!("allreduce mismatch {a} vs {b}"));
                }
            }
        }
        Ok(())
    });
}

/// Distributed gram / XHt / WtX equal their single-rank dense versions.
#[test]
fn prop_dist_products_match_dense() {
    check_cases(9003, 10, |rng| {
        let pr = 1 + rng.below(2);
        let pc = 1 + rng.below(3);
        let grid = Grid2d::new(pr, pc);
        let m = (1 + rng.below(6)) * 4;
        let n = (1 + rng.below(6)) * 4;
        let r = 1 + rng.below(4);
        let x = Mat::<f64>::rand_uniform(m, n, rng);
        let cfg = NmfConfig { rank: r, max_iters: 1, ..Default::default() };
        let x2 = x.clone();
        let outs = Comm::run(grid.size(), move |mut world| {
            let (i, j) = grid.coords(world.rank());
            let rows = BlockDim::new(m, grid.pr);
            let cols = BlockDim::new(n, grid.pc);
            let xb = Mat::from_fn(rows.size_of(i), cols.size_of(j), |a, b| {
                x2[(rows.start_of(i) + a, cols.start_of(j) + b)]
            });
            let (mut row, mut col) = grid.make_subcomms(&mut world);
            dist_nmf(&xb, m, n, grid, &mut world, &mut row, &mut col, &NativeBackend, &cfg)
                .unwrap()
        });
        // Reassemble W and H after one synchronized iteration and verify
        // the objective identity ties the distributed products together:
        // every rank reported identical stats.
        let obj0 = outs[0].stats.objective;
        for o in &outs {
            if (o.stats.objective - obj0).abs() > 1e-9 * (1.0 + obj0) {
                return Err("ranks disagree on objective".into());
            }
        }
        Ok(())
    });
}

/// gemm identities used throughout: (AᵀB)ᵀ == BᵀA, gram == AᵀA.
#[test]
fn prop_gemm_identities() {
    check(9004, |rng| {
        let m = 1 + rng.below(12);
        let n = 1 + rng.below(12);
        let r = 1 + rng.below(5);
        let a = Mat::<f64>::rand_uniform(m, r, rng);
        let b = Mat::<f64>::rand_uniform(m, n, rng);
        let atb = matmul_at_b(&a, &b); // r x n
        let bta = matmul_at_b(&b, &a); // n x r
        for i in 0..r {
            for j in 0..n {
                if (atb[(i, j)] - bta[(j, i)]).abs() > 1e-10 {
                    return Err("transpose identity failed".into());
                }
            }
        }
        let g = gram_mt_m(&a);
        let g2 = matmul(&a.transpose(), &a);
        for (x, y) in g.as_slice().iter().zip(g2.as_slice()) {
            if (x - y).abs() > 1e-10 {
                return Err("gram identity failed".into());
            }
        }
        Ok(())
    });
}

/// End-to-end TT property: for tensors generated with ranks ≤ R, the nTT at
/// tight eps (a) recovers ranks ≤ generated ranks (SVD bound), (b) keeps
/// cores non-negative, (c) compression matches Eq. 4.
#[test]
fn prop_ntt_recovers_structure() {
    check_cases(9005, 6, |rng| {
        let d = 3;
        let dims: Vec<usize> = (0..d).map(|_| 4 + rng.below(4)).collect();
        let ranks: Vec<usize> = (0..d - 1).map(|_| 1 + rng.below(3)).collect();
        let syn = SyntheticTt::new(dims.clone(), ranks.clone(), rng.next_u64());
        let t = syn.dense();
        // eps is set above the NMF residual floor: then every stage's tail
        // energy at the generated rank is below threshold and selection
        // cannot exceed the generator's ranks (at stage 0 this is exact
        // Eckart–Young; later stages see H's approximation error, which the
        // 3% margin absorbs).
        let out = ntt_serial(
            &t,
            &TtConfig {
                eps: 0.03,
                nmf: NmfConfig { max_iters: 150, ..Default::default() },
                ..Default::default()
            },
        )
        .map_err(|e| e.to_string())?;
        for (sel, gen) in out.tt.ranks()[1..d].iter().zip(&ranks) {
            if sel > gen {
                return Err(format!("rank {sel} exceeds generated {gen}"));
            }
        }
        if !out.tt.is_nonneg() {
            return Err("cores not nonneg".into());
        }
        let c = out.tt.compression_ratio();
        let full: f64 = dims.iter().map(|&n| n as f64).product();
        let params: f64 = (0..d)
            .map(|i| (dims[i] * out.tt.ranks()[i] * out.tt.ranks()[i + 1]) as f64)
            .sum();
        if (c - full / params).abs() > 1e-9 {
            return Err("Eq.4 mismatch".into());
        }
        Ok(())
    });
}

/// NMF objective history is non-increasing (accepted iterates) for all
/// three update rules on random low-rank data.
#[test]
fn prop_nmf_monotone_objective() {
    check_cases(9006, 8, |rng| {
        let m = 6 + rng.below(10);
        let n = 6 + rng.below(10);
        let r = 1 + rng.below(3);
        let a = Mat::<f64>::rand_uniform(m, r, rng);
        let b = Mat::<f64>::rand_uniform(r, n, rng);
        let x = matmul(&a, &b);
        for algo in [NmfAlgo::Bcd, NmfAlgo::Mu, NmfAlgo::Hals] {
            let cfg = NmfConfig { rank: r, max_iters: 40, algo, seed: rng.next_u64(), ..Default::default() };
            let x2 = x.clone();
            let cfg2 = cfg.clone();
            let outs = Comm::run(1, move |mut world| {
                let grid = Grid2d::new(1, 1);
                let (mut row, mut col) = grid.make_subcomms(&mut world);
                dist_nmf(&x2, x2.rows(), x2.cols(), grid, &mut world, &mut row, &mut col, &NativeBackend, &cfg2)
                    .unwrap()
            });
            let h = &outs[0].stats.history;
            for w in h.windows(2) {
                if w[1] > w[0] * (1.0 + 1e-9) + 1e-12 {
                    return Err(format!("{algo:?}: objective rose {} -> {}", w[0], w[1]));
                }
            }
        }
        Ok(())
    });
}

/// Kernel-dispatch contract (DESIGN.md §3.3): every kernel selection —
/// any [`KernelPath`] (available or not: unavailable paths downgrade to
/// scalar), any thread count — is *bitwise* identical to
/// [`matmul_naive`] on random shapes across all three packed layouts,
/// and the SpMM dispatchers are bitwise identical to their scalar
/// reference kernels at random densities. Shapes are biased toward the
/// MR/NR register-tile remainders (multiples of 8/4 and their ±1
/// neighbours) and the empty edges (0-sized dims), so the property
/// doubles as an out-of-bounds probe on the remainder tiles.
#[test]
fn prop_kernel_selections_bitwise_match_naive() {
    use dntt::linalg::gemm::{
        matmul_a_bt_packed_with, matmul_at_b_packed_with, matmul_naive, matmul_packed_with,
        GemmWorkspace,
    };
    use dntt::linalg::sparse::{
        sp_matmul, sp_matmul_a_bt, sp_matmul_a_bt_with, sp_matmul_at_b, sp_matmul_at_b_with,
        sp_matmul_with, SparseMat,
    };
    use dntt::linalg::{KernelCfg, KernelPath};
    use dntt::util::rng::Rng;

    /// Register-tile-hostile dimension: 0, tiny, or 8k / 8k±1.
    fn dim(rng: &mut Rng) -> usize {
        match rng.below(5) {
            0 => rng.below(2),                    // 0 or 1: empty / degenerate
            1 => 1 + rng.below(8),                // inside one register tile
            2 => 8 * (1 + rng.below(8)),          // exact MR multiples
            3 => 8 * (1 + rng.below(8)) + 1,      // one past a full tile
            _ => 8 * (1 + rng.below(8)) - 1,      // one short of a full tile
        }
    }

    check_cases(9009, 30, |rng| {
        let (m, k, n) = (dim(rng), dim(rng), dim(rng));
        // Mixed-sign entries: bitwise identity must not depend on the
        // non-negativity the NMF callers happen to provide.
        let a = Mat::<f64>::from_fn(m, k, |_, _| rng.uniform() - 0.5);
        let b = Mat::<f64>::from_fn(k, n, |_, _| rng.uniform() - 0.5);
        let want = matmul_naive(&a, &b);
        // Random selection, including paths this host cannot run.
        let path = KernelPath::ALL[rng.below(KernelPath::ALL.len())];
        let threads = 1 + rng.below(8);
        let sel = KernelCfg::new(path, threads);
        let mut ws = GemmWorkspace::<f64>::new();
        // Stale-filled output: the drivers must overwrite every element.
        let mut c = Mat::<f64>::from_fn(m, n, |_, _| f64::NAN);
        matmul_packed_with(&a, &b, &mut c, &mut ws, sel);
        if c.as_slice() != want.as_slice() {
            return Err(format!("A·B {m}x{k}x{n} {} t={threads} != naive", path.name()));
        }
        let at = a.transpose();
        c.as_mut_slice().fill(f64::NAN);
        matmul_at_b_packed_with(&at, &b, &mut c, &mut ws, sel);
        if c.as_slice() != want.as_slice() {
            return Err(format!("Aᵀ·B {m}x{k}x{n} {} t={threads} != naive", path.name()));
        }
        let bt = b.transpose();
        c.as_mut_slice().fill(f64::NAN);
        matmul_a_bt_packed_with(&a, &bt, &mut c, &mut ws, sel);
        if c.as_slice() != want.as_slice() {
            return Err(format!("A·Bᵀ {m}x{k}x{n} {} t={threads} != naive", path.name()));
        }
        // SpMM at a random density (incl. the all-zero and dense edges)
        // against the scalar reference kernels, same selection.
        let density = [0.0, 0.01, 0.3, 1.0][rng.below(4)];
        let x = Mat::<f64>::from_fn(m, k, |_, _| {
            if rng.uniform() < density { rng.uniform() - 0.5 } else { 0.0 }
        });
        let xs = SparseMat::from_dense(&x);
        let mut got = Mat::<f64>::from_fn(m, n, |_, _| f64::NAN);
        sp_matmul_with(&xs, &b, &mut got, sel);
        if got.as_slice() != sp_matmul(&xs, &b).as_slice() {
            return Err(format!("SpMM A·B d={density} {} t={threads}", path.name()));
        }
        let wmat = Mat::<f64>::from_fn(m, n, |_, _| rng.uniform() - 0.5);
        let mut got_t = Mat::<f64>::from_fn(k, n, |_, _| f64::NAN);
        sp_matmul_at_b_with(&xs, &wmat, &mut got_t, sel);
        if got_t.as_slice() != sp_matmul_at_b(&xs, &wmat).as_slice() {
            return Err(format!("SpMM Aᵀ·B d={density} {} t={threads}", path.name()));
        }
        let h = Mat::<f64>::from_fn(n, k, |_, _| rng.uniform() - 0.5);
        let mut got_bt = Mat::<f64>::from_fn(m, n, |_, _| f64::NAN);
        sp_matmul_a_bt_with(&xs, &h, &mut got_bt, sel);
        if got_bt.as_slice() != sp_matmul_a_bt(&xs, &h).as_slice() {
            return Err(format!("SpMM A·Bᵀ d={density} {} t={threads}", path.name()));
        }
        Ok(())
    });
}

/// Tensor reshape linearity: unfold-left then reshape back is the identity,
/// for arbitrary shapes.
#[test]
fn prop_unfold_roundtrip() {
    check(9007, |rng| {
        let d = 2 + rng.below(3);
        let dims: Vec<usize> = (0..d).map(|_| 1 + rng.below(5)).collect();
        let t = DenseTensor::<f64>::rand_uniform(&dims, rng);
        for k in 0..=d {
            let m = t.unfold_left(k);
            let back = DenseTensor::from_vec(&dims, m.into_vec()).map_err(|e| e.to_string())?;
            if back != t {
                return Err(format!("unfold_left({k}) roundtrip failed"));
            }
        }
        Ok(())
    });
}
