//! Integration: PJRT backend (AOT JAX/Pallas artifacts) vs native backend.
//!
//! Requires `make artifacts` to have produced `artifacts/manifest.json`;
//! tests that need artifacts are skipped (with a note) when missing so
//! `cargo test` stays meaningful before the first artifact build.

use dntt::linalg::Mat;
use dntt::runtime::backend::ComputeBackend;
use dntt::runtime::native::NativeBackend;
use dntt::runtime::pjrt::{pjrt_nmf_iter, PjrtBackend};
use dntt::util::rng::Rng;
use std::path::Path;
use std::sync::atomic::Ordering;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("NOTE: artifacts/manifest.json missing — run `make artifacts`; skipping");
        None
    }
}

fn close(a: &Mat<f64>, b: &Mat<f64>, tol: f64) {
    assert_eq!(a.shape(), b.shape());
    for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
        let scale = 1.0_f64.max(x.abs()).max(y.abs());
        assert!((x - y).abs() <= tol * scale, "{x} vs {y}");
    }
}

/// The f32 artifacts vs f64 native tolerance.
const TOL: f64 = 2e-4;

#[test]
fn pjrt_matches_native_on_manifest_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let pjrt = PjrtBackend::from_dir(dir).expect("pjrt engine");
    let native = NativeBackend;
    let mut rng = Rng::new(1);

    // Shapes present in the default preset: gram/bcd/mu 6x2, xht/wtx 4x6x2.
    let f = Mat::<f64>::rand_uniform(6, 2, &mut rng);
    close(&pjrt.gram(&f), &native.gram(&f), TOL);

    let x = Mat::<f64>::rand_uniform(4, 6, &mut rng);
    let ht = Mat::<f64>::rand_uniform(6, 2, &mut rng);
    close(&pjrt.xht(&x, &ht), &native.xht(&x, &ht), TOL);

    let w = Mat::<f64>::rand_uniform(4, 2, &mut rng);
    close(&pjrt.wtx(&x, &w), &native.wtx(&x, &w), TOL);

    let g = native.gram(&ht);
    let p = Mat::<f64>::rand_uniform(6, 2, &mut rng);
    let lip = g.fro_norm();
    close(&pjrt.bcd_update(&f, &g, &p, lip), &native.bcd_update(&f, &g, &p, lip), TOL);
    close(&pjrt.mu_update(&f, &g, &p), &native.mu_update(&f, &g, &p), TOL);

    let hits = pjrt.engine().stats.hits.load(Ordering::Relaxed);
    assert!(hits >= 5, "expected all ops on the XLA path, hits={hits}");
}

#[test]
fn pjrt_falls_back_on_unknown_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let pjrt = PjrtBackend::from_dir(dir).expect("pjrt engine");
    let mut rng = Rng::new(2);
    // 7x3 is deliberately not in any preset.
    let f = Mat::<f64>::rand_uniform(7, 3, &mut rng);
    let out = pjrt.gram(&f);
    close(&out, &NativeBackend.gram(&f), 1e-12);
    assert!(pjrt.engine().stats.misses.load(Ordering::Relaxed) >= 1);
}

#[test]
fn fused_nmf_iter_matches_stepwise() {
    let Some(dir) = artifacts_dir() else { return };
    let pjrt = PjrtBackend::from_dir(dir).expect("pjrt engine");
    let native = NativeBackend;
    let mut rng = Rng::new(3);
    // Preset shape: nmf_iter_bcd_8x12x2.
    let x = Mat::<f64>::rand_uniform(8, 12, &mut rng);
    let wm = Mat::<f64>::rand_uniform(8, 2, &mut rng);
    let htm = Mat::<f64>::rand_uniform(12, 2, &mut rng);

    let (w1, ht1, cross, quad) = pjrt_nmf_iter(&pjrt, &x, &wm, &htm).expect("artifact present");

    // Native step-by-step replication of model.nmf_iter_bcd.
    let hht = native.gram(&htm);
    let xht = native.xht(&x, &htm);
    let w2 = native.bcd_update(&wm, &hht, &xht, hht.fro_norm());
    let wtw = native.gram(&w2);
    let xtw = native.wtx(&x, &w2);
    let ht2 = native.bcd_update(&htm, &wtw, &xtw, wtw.fro_norm());
    close(&w1, &w2, TOL);
    close(&ht1, &ht2, TOL);

    let hht2 = native.gram(&ht2);
    let cross2: f64 =
        xtw.as_slice().iter().zip(ht2.as_slice()).map(|(a, b)| a * b).sum();
    let quad2: f64 =
        wtw.as_slice().iter().zip(hht2.as_slice()).map(|(a, b)| a * b).sum();
    assert!((cross - cross2).abs() < 1e-2 * (1.0 + cross2.abs()), "{cross} vs {cross2}");
    assert!((quad - quad2).abs() < 1e-2 * (1.0 + quad2.abs()), "{quad} vs {quad2}");
}

#[test]
fn dist_nmf_runs_on_pjrt_backend() {
    let Some(dir) = artifacts_dir() else { return };
    use dntt::dist::{Comm, Grid2d};
    use dntt::nmf::{dist_nmf, NmfConfig};
    use std::sync::Arc;

    // 2x2 grid over the quickstart stage-0 shapes (16^4 tensor): X is
    // 16x4096, blocks 8x2048. The backend falls back natively wherever a
    // shape is missing, so this asserts correctness end-to-end and that at
    // least some ops took the XLA path.
    let engine = dntt::runtime::PjrtEngine::start(dir).expect("engine");
    let x = {
        let mut rng = Rng::new(4);
        let a = Mat::<f64>::rand_uniform(16, 4, &mut rng);
        let b = Mat::<f64>::rand_uniform(4, 4096, &mut rng);
        dntt::linalg::gemm::matmul(&a, &b)
    };
    let grid = Grid2d::new(2, 2);
    let x2 = x.clone();
    let eng = Arc::clone(&engine);
    let outs = Comm::run(4, move |mut world| {
        let (i, j) = grid.coords(world.rank());
        let xb = Mat::from_fn(8, 2048, |a, b| x2[(i * 8 + a, j * 2048 + b)]);
        let (mut row, mut col) = grid.make_subcomms(&mut world);
        let backend = PjrtBackend::new(Arc::clone(&eng));
        let cfg = NmfConfig { rank: 4, max_iters: 30, ..Default::default() };
        dist_nmf(&xb, 16, 4096, grid, &mut world, &mut row, &mut col, &backend, &cfg).unwrap()
    });
    let rel = outs[0].stats.rel_err;
    assert!(rel < 0.1, "pjrt-backed dist NMF rel_err={rel}");
    let hits = engine.stats.hits.load(Ordering::Relaxed);
    assert!(hits > 0, "expected XLA hits in dist NMF, got 0");
}
