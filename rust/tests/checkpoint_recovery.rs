//! The crash-recovery battery: checkpointed runs are bitwise-neutral,
//! resumed runs are bitwise-identical to uninterrupted ones (TT and HT,
//! dense and sparse inputs), bad checkpoints (wrong config hash,
//! truncated snapshot files) are rejected, and — under the
//! `fault-inject` feature — a kill-at-every-collective sweep proves the
//! whole pipeline recovers from a rank death at *any* collective.
//!
//! The default build runs the checkpoint/resume tests plus the proof
//! that the fault hook is compiled out ([`dntt::dist::faults`]).

mod common;

use common::{
    assert_cores_bitwise, assert_ht_nodes_bitwise, ht_cfg_fixed, tt_cfg_fixed, unique_temp_dir,
};
use dntt::coordinator::{run_job, Decomposition, InputSpec, JobConfig, ResumeMode};
use dntt::dist::checkpoint::{self, CheckpointPolicy};
use dntt::dist::ProcGrid;
use dntt::ht::SyntheticHt;
use dntt::ttrain::{SyntheticSparse, SyntheticTt};
use std::path::{Path, PathBuf};

/// The small 2×2-grid TT job every recovery test runs (fixed ranks pin
/// the stage shapes; 4 iterations keep the sweep fast).
fn tt_job(ckpt: Option<PathBuf>, resume: ResumeMode) -> JobConfig {
    JobConfig {
        tt: tt_cfg_fixed(4, vec![2, 2]),
        checkpoint: ckpt.map(CheckpointPolicy::new),
        resume,
        ..JobConfig::new(
            InputSpec::Synthetic(SyntheticTt::new(vec![4, 4, 4], vec![2, 2], 7)),
            ProcGrid::new(vec![2, 2, 1]).unwrap(),
        )
    }
}

fn ht_job(ckpt: Option<PathBuf>, resume: ResumeMode) -> JobConfig {
    JobConfig {
        decomp: Decomposition::Ht,
        ht: ht_cfg_fixed(4, vec![2; 4]),
        checkpoint: ckpt.map(CheckpointPolicy::new),
        resume,
        ..JobConfig::new(
            InputSpec::Synthetic(SyntheticHt::new(vec![4, 4, 4], 2, 13).dense_spec()),
            ProcGrid::new(vec![2, 1, 2]).unwrap(),
        )
    }
}

/// Synthetic-HT tensors have no `InputSpec` constructor of their own;
/// wrap the dense tensor.
trait DenseSpec {
    fn dense_spec(&self) -> InputSpec;
}
impl DenseSpec for SyntheticHt {
    fn dense_spec(&self) -> InputSpec {
        InputSpec::Dense(std::sync::Arc::new(self.dense()))
    }
}

// Only the fault-injection half of the battery exercises the sparse job.
#[cfg_attr(not(feature = "fault-inject"), allow(dead_code))]
fn sparse_job(ckpt: Option<PathBuf>, resume: ResumeMode) -> JobConfig {
    JobConfig {
        tt: tt_cfg_fixed(4, vec![2, 2]),
        checkpoint: ckpt.map(CheckpointPolicy::new),
        resume,
        ..JobConfig::new(
            InputSpec::SyntheticSparse(SyntheticSparse::new(vec![6, 5, 4], 0.15, 77)),
            ProcGrid::new(vec![2, 1, 1]).unwrap(),
        )
    }
}

/// A snapshot file the current manifest actually references (earlier
/// stages' files also linger in the directory; truncating those would
/// not — and must not — trip validation).
fn referenced_chunk_file(dir: &Path) -> PathBuf {
    let man = checkpoint::read_manifest(dir).unwrap();
    let file = man.get("remainder_chunks").as_arr().unwrap()[0]
        .get("file")
        .as_str()
        .unwrap()
        .to_string();
    dir.join(file)
}

/// Checkpointing is bitwise-neutral: a TT job with stage snapshots on
/// produces the same cores as one without, and leaves a committed
/// manifest recording every loop stage.
#[test]
fn checkpointed_tt_run_is_bitwise_neutral() {
    let dir = unique_temp_dir("ckpt_neutral");
    let plain = run_job(&tt_job(None, ResumeMode::Off)).unwrap();
    let ckpt = run_job(&tt_job(Some(dir.clone()), ResumeMode::Off)).unwrap();
    assert_cores_bitwise(
        ckpt.output.tt().unwrap(),
        plain.output.tt().unwrap(),
        "checkpointed vs plain",
    );
    assert!(checkpoint::have_checkpoint(&dir));
    assert_eq!(checkpoint::stages_done(&dir), Some(2)); // d−1 loop stages
    let man = checkpoint::read_manifest(&dir).unwrap();
    assert_eq!(man.get("format").as_str(), Some("dntt-ckpt-v1"));
    assert_eq!(man.get("decomp").as_str(), Some("tt"));
    assert!(man.get("git_sha").as_str().is_some());
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--resume auto` against a completed checkpoint fast-replays the job:
/// every stage is skipped and the output is still bitwise identical.
#[test]
fn resume_replays_completed_tt_job_bitwise() {
    let dir = unique_temp_dir("ckpt_replay");
    let first = run_job(&tt_job(Some(dir.clone()), ResumeMode::Off)).unwrap();
    let replay = run_job(&tt_job(Some(dir.clone()), ResumeMode::Auto)).unwrap();
    assert_cores_bitwise(
        replay.output.tt().unwrap(),
        first.output.tt().unwrap(),
        "resumed replay vs first run",
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// HT: checkpoint neutrality and resume-replay, node matrices bitwise.
#[test]
fn ht_checkpoint_and_replay_are_bitwise_neutral() {
    let dir = unique_temp_dir("ckpt_ht");
    let plain = run_job(&ht_job(None, ResumeMode::Off)).unwrap();
    let ckpt = run_job(&ht_job(Some(dir.clone()), ResumeMode::Off)).unwrap();
    assert_ht_nodes_bitwise(
        ckpt.output.ht().unwrap(),
        plain.output.ht().unwrap(),
        "checkpointed vs plain HT",
    );
    assert_eq!(checkpoint::stages_done(&dir), Some(5)); // all tree nodes
    let replay = run_job(&ht_job(Some(dir.clone()), ResumeMode::Auto)).unwrap();
    assert_ht_nodes_bitwise(
        replay.output.ht().unwrap(),
        plain.output.ht().unwrap(),
        "HT resumed replay",
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// A manifest written by a different configuration (different NMF seed)
/// or a different input *tensor* (different generator seed — same dims,
/// same label) is rejected by the config-hash check before anything is
/// rehydrated.
#[test]
fn resume_rejects_config_hash_mismatch() {
    let dir = unique_temp_dir("ckpt_hash");
    run_job(&tt_job(Some(dir.clone()), ResumeMode::Off)).unwrap();
    let mut other = tt_job(Some(dir.clone()), ResumeMode::Auto);
    other.tt.nmf.seed = 43; // a different trajectory — the checkpoint is not ours
    let err = run_job(&other).unwrap_err();
    assert!(err.to_string().contains("config hash mismatch"), "{err}");
    // Same configuration, different data: the input identity (generator
    // seed) is part of the fingerprint too.
    let mut other_data = tt_job(Some(dir.clone()), ResumeMode::Auto);
    other_data.input =
        InputSpec::Synthetic(SyntheticTt::new(vec![4, 4, 4], vec![2, 2], 8));
    let err = run_job(&other_data).unwrap_err();
    assert!(err.to_string().contains("config hash mismatch"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Superseded per-stage remainder chunks are pruned once a newer manifest
/// commits: only the latest stage's files survive in the directory.
#[test]
fn stale_stage_chunks_are_pruned_after_commit() {
    let dir = unique_temp_dir("ckpt_prune");
    run_job(&tt_job(Some(dir.clone()), ResumeMode::Off)).unwrap();
    let names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .flatten()
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    assert!(
        names.iter().any(|n| n.starts_with("tt.rem.s2.r")),
        "latest stage's chunks must remain: {names:?}"
    );
    assert!(
        !names.iter().any(|n| n.starts_with("tt.rem.s1.r")),
        "superseded stage chunks must be pruned: {names:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// A truncated snapshot file is rejected by the byte-size validation.
#[test]
fn resume_rejects_truncated_snapshot_file() {
    let dir = unique_temp_dir("ckpt_trunc");
    run_job(&tt_job(Some(dir.clone()), ResumeMode::Off)).unwrap();
    let victim = referenced_chunk_file(&dir);
    let bytes = std::fs::read(&victim).unwrap();
    std::fs::write(&victim, &bytes[..bytes.len().saturating_sub(8)]).unwrap();
    let err = run_job(&tt_job(Some(dir.clone()), ResumeMode::Auto)).unwrap_err();
    assert!(err.to_string().contains("truncated"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `ResumeMode::Off` ignores whatever sits in the checkpoint directory —
/// even a manifest from a different job — and runs fresh.
#[test]
fn resume_off_ignores_existing_checkpoint() {
    let dir = unique_temp_dir("ckpt_off");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(checkpoint::manifest_path(&dir), "{\"format\": \"dntt-ckpt-v1\"}").unwrap();
    let plain = run_job(&tt_job(None, ResumeMode::Off)).unwrap();
    let fresh = run_job(&tt_job(Some(dir.clone()), ResumeMode::Off)).unwrap();
    assert_cores_bitwise(
        fresh.output.tt().unwrap(),
        plain.output.tt().unwrap(),
        "fresh run over stale checkpoint",
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// `every_iters` persists in-flight `W`/`H` snapshots per rank per stage.
#[test]
fn iteration_granular_snapshots_appear() {
    let dir = unique_temp_dir("ckpt_iters");
    let mut job = tt_job(Some(dir.clone()), ResumeMode::Off);
    job.checkpoint.as_mut().unwrap().every_iters = 2;
    run_job(&job).unwrap();
    for rank in 0..4 {
        for side in ["w", "h"] {
            let f = dir.join(format!("inflight.s0.r{rank}.{side}.chunk"));
            assert!(f.is_file(), "missing in-flight snapshot {f:?}");
            assert!(std::fs::metadata(&f).unwrap().len() > 0);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Default build: the fault hook is compiled out — `FAULT_INJECT_ENABLED`
/// is false and an armed would-fire plan never fires (the `Comm` hot path
/// carries no injection code).
#[cfg(not(feature = "fault-inject"))]
#[test]
fn default_build_compiles_fault_hook_out() {
    use dntt::dist::{faults, FaultPlan};
    assert!(!faults::FAULT_INJECT_ENABLED);
    let plan = FaultPlan::kill_at(0, 1); // would fire on the very first collective
    faults::arm(&plan);
    let rep = run_job(&tt_job(None, ResumeMode::Off));
    faults::disarm();
    assert!(rep.is_ok(), "default build must never fire injected faults");
    assert_eq!(plan.fired_count(), 0);
    assert!(plan.last_fired().is_none());
}

#[cfg(feature = "fault-inject")]
mod fault {
    use super::*;
    use crate::common::assert_close_slices;
    use dntt::dist::{faults, FaultPlan};
    use dntt::error::DnttError;

    /// Run `job` with `plan` armed (scoped to this thread's worlds).
    fn run_with_plan(
        job: &JobConfig,
        plan: &std::sync::Arc<FaultPlan>,
    ) -> dntt::error::Result<dntt::coordinator::JobReport> {
        faults::arm(plan);
        let out = run_job(job);
        faults::disarm();
        out
    }

    /// Victim dies, no checkpoint/resume configured: the coordinator
    /// surfaces the typed `RankLost` error with the exact death site.
    #[test]
    fn fault_without_resume_is_a_typed_rank_lost_error() {
        let plan = FaultPlan::kill_at(2, 9);
        let err = run_with_plan(&tt_job(None, ResumeMode::Off), &plan).unwrap_err();
        match err {
            DnttError::RankLost { rank, op } => {
                assert_eq!((rank, op), (2, 9));
            }
            other => panic!("expected RankLost, got: {other}"),
        }
        assert_eq!(plan.fired_count(), 1);
    }

    /// ISSUE acceptance (TT, dense): a job killed by the fault plan at an
    /// arbitrary mid-run collective resumes from its last checkpoint and
    /// yields factors bitwise-identical to the uninterrupted run.
    #[test]
    fn tt_killed_mid_run_resumes_bitwise_identical() {
        let reference = run_job(&tt_job(None, ResumeMode::Off)).unwrap();
        // Find the op range, then kill somewhere in the middle of it.
        let counter = FaultPlan::count_only();
        let dir0 = unique_temp_dir("ckpt_mid_count");
        run_with_plan(&tt_job(Some(dir0.clone()), ResumeMode::Off), &counter).unwrap();
        let total = counter.ops_seen(1);
        assert!(total > 10, "tiny job still runs {total} collectives");
        let dir = unique_temp_dir("ckpt_mid");
        let plan = FaultPlan::kill_at(1, total / 2);
        let rep = run_with_plan(&tt_job(Some(dir.clone()), ResumeMode::Auto), &plan).unwrap();
        assert_eq!(plan.fired_count(), 1, "the scheduled death must have fired");
        assert_cores_bitwise(
            rep.output.tt().unwrap(),
            reference.output.tt().unwrap(),
            "killed+resumed vs uninterrupted",
        );
        let _ = std::fs::remove_dir_all(&dir0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// ISSUE acceptance: the kill-at-every-collective sweep on the 2×2
    /// grid. For *every* collective of the victim rank, a job killed
    /// there and auto-resumed is bitwise-identical to the uninterrupted
    /// run. (Each kill fires once, so each swept run is: die at op k,
    /// relaunch from the last durable checkpoint, finish clean.)
    #[test]
    fn tt_kill_at_every_collective_sweep() {
        let reference = run_job(&tt_job(None, ResumeMode::Off)).unwrap();
        let ref_tt = reference.output.tt().unwrap();
        let counter = FaultPlan::count_only();
        let dir0 = unique_temp_dir("ckpt_sweep_count");
        run_with_plan(&tt_job(Some(dir0.clone()), ResumeMode::Off), &counter).unwrap();
        let _ = std::fs::remove_dir_all(&dir0);
        let victim = 1usize;
        let total = counter.ops_seen(victim);
        assert!(total > 0);
        for op in 1..=total {
            let dir = unique_temp_dir("ckpt_sweep");
            let plan = FaultPlan::kill_at(victim, op);
            let rep = run_with_plan(&tt_job(Some(dir.clone()), ResumeMode::Auto), &plan)
                .unwrap_or_else(|e| panic!("kill at op {op} did not recover: {e}"));
            assert_eq!(plan.fired_count(), 1, "kill at op {op} never fired");
            assert_cores_bitwise(rep.output.tt().unwrap(), ref_tt, &format!("kill at op {op}"));
            let _ = std::fs::remove_dir_all(&dir);
        }
        // And every rank recovers, probed at one early collective each.
        for victim in 0..4 {
            let dir = unique_temp_dir("ckpt_sweep_rank");
            let plan = FaultPlan::kill_at(victim, 5);
            let rep = run_with_plan(&tt_job(Some(dir.clone()), ResumeMode::Auto), &plan).unwrap();
            assert_cores_bitwise(rep.output.tt().unwrap(), ref_tt, &format!("victim {victim}"));
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    /// ISSUE acceptance (HT, dense): kills early, mid and late in the
    /// tree walk all resume to bitwise-identical node matrices.
    #[test]
    fn ht_killed_and_resumed_matches_uninterrupted() {
        let reference = run_job(&ht_job(None, ResumeMode::Off)).unwrap();
        let ref_ht = reference.output.ht().unwrap();
        let counter = FaultPlan::count_only();
        let dir0 = unique_temp_dir("ckpt_ht_count");
        run_with_plan(&ht_job(Some(dir0.clone()), ResumeMode::Off), &counter).unwrap();
        let _ = std::fs::remove_dir_all(&dir0);
        let victim = 2usize;
        let total = counter.ops_seen(victim);
        assert!(total > 3);
        for op in [1, total / 3, 2 * total / 3, total] {
            let dir = unique_temp_dir("ckpt_ht_kill");
            let plan = FaultPlan::kill_at(victim, op);
            let rep = run_with_plan(&ht_job(Some(dir.clone()), ResumeMode::Auto), &plan)
                .unwrap_or_else(|e| panic!("HT kill at op {op} did not recover: {e}"));
            assert_eq!(plan.fired_count(), 1, "HT kill at op {op} never fired");
            assert_ht_nodes_bitwise(
                rep.output.ht().unwrap(),
                ref_ht,
                &format!("HT kill at op {op}"),
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    /// ISSUE acceptance (TT, sparse input): the sparse stage-0 pipeline
    /// (sparse chunks, sparse reshape, SpMM kernels) recovers bitwise
    /// too, and the recovered job reports the same reconstruction error.
    #[test]
    fn sparse_tt_killed_and_resumed_matches_uninterrupted() {
        let reference = run_job(&sparse_job(None, ResumeMode::Off)).unwrap();
        let ref_tt = reference.output.tt().unwrap();
        let counter = FaultPlan::count_only();
        let dir0 = unique_temp_dir("ckpt_sp_count");
        run_with_plan(&sparse_job(Some(dir0.clone()), ResumeMode::Off), &counter).unwrap();
        let _ = std::fs::remove_dir_all(&dir0);
        let victim = 1usize;
        let total = counter.ops_seen(victim);
        for op in [1, total / 2, total] {
            let dir = unique_temp_dir("ckpt_sp_kill");
            let plan = FaultPlan::kill_at(victim, op);
            let rep = run_with_plan(&sparse_job(Some(dir.clone()), ResumeMode::Auto), &plan)
                .unwrap_or_else(|e| panic!("sparse kill at op {op} did not recover: {e}"));
            assert_cores_bitwise(
                rep.output.tt().unwrap(),
                ref_tt,
                &format!("sparse kill at op {op}"),
            );
            assert_close_slices(
                &[rep.rel_error.unwrap()],
                &[reference.rel_error.unwrap()],
                1e-15,
                "sparse recovered rel_error",
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    /// Multiple scheduled deaths within one job: each fires once, the
    /// coordinator relaunches after each, and the result is still exact.
    #[test]
    fn multiple_kills_in_one_job_all_recover() {
        let reference = run_job(&tt_job(None, ResumeMode::Off)).unwrap();
        let dir = unique_temp_dir("ckpt_multi");
        let plan = FaultPlan::new(vec![
            dntt::dist::faults::Kill { rank: 0, op: 20 },
            dntt::dist::faults::Kill { rank: 3, op: 40 },
            dntt::dist::faults::Kill { rank: 1, op: 60 },
        ]);
        let rep = run_with_plan(&tt_job(Some(dir.clone()), ResumeMode::Auto), &plan).unwrap();
        assert!(plan.fired_count() >= 1, "at least the first kill fires");
        assert_cores_bitwise(
            rep.output.tt().unwrap(),
            reference.output.tt().unwrap(),
            "multi-kill recovery",
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
