//! Integration: the full distributed nTT against ground truth, across
//! grids, backends, algorithms and spill modes.

mod common;

use common::{tt_cfg_algo as cfg, unique_temp_dir};
use dntt::coordinator::{run_job, BackendChoice, InputSpec, JobConfig};
use dntt::dist::chunkstore::SpillMode;
use dntt::dist::ProcGrid;
use dntt::nmf::{NmfAlgo, NmfConfig};
use dntt::ttrain::{ntt_serial, SyntheticTt, TtConfig};

/// Rank recovery + reconstruction across three different grids.
#[test]
fn grid_invariance_of_decomposition() {
    let syn = SyntheticTt::new(vec![8, 6, 4, 4], vec![3, 2, 2], 77);
    let mut results = Vec::new();
    for grid in [vec![1, 1, 1, 1], vec![2, 2, 1, 1], vec![2, 1, 2, 2]] {
        let job = JobConfig {
            tt: cfg(120, NmfAlgo::Bcd),
            ..JobConfig::new(InputSpec::Synthetic(syn.clone()), ProcGrid::new(grid).unwrap())
        };
        let rep = run_job(&job).unwrap();
        assert_eq!(rep.ranks, vec![1, 3, 2, 2, 1], "grid {:?}", rep.grid);
        results.push(rep.rel_error.unwrap());
    }
    // All grids converge to (nearly) the same quality.
    for w in results.windows(2) {
        assert!((w[0] - w[1]).abs() < 1e-3, "errors diverged: {results:?}");
    }
}

/// The nTT of a non-negative tensor has non-negative cores; TT-SVD does not
/// guarantee that (the paper's motivation).
#[test]
fn nonnegativity_invariant() {
    let syn = SyntheticTt::new(vec![6, 6, 6], vec![3, 3], 5);
    let t = syn.dense();
    let out = ntt_serial(&t, &cfg(80, NmfAlgo::Bcd)).unwrap();
    assert!(out.tt.is_nonneg());
    let svd_tt = dntt::baselines::tt_svd(&t, 1e-6).unwrap();
    assert!(!svd_tt.is_nonneg(), "SVD cores are signed in general");
    // Both reconstruct well.
    assert!(out.tt.rel_error(&t) < 0.05);
    assert!(svd_tt.rel_error(&t) < 1e-8);
}

/// MU and HALS also drive the full pipeline.
#[test]
fn alternative_update_rules() {
    let syn = SyntheticTt::new(vec![6, 6, 4], vec![2, 2], 9);
    let t = syn.dense();
    for algo in [NmfAlgo::Mu, NmfAlgo::Hals] {
        let out = ntt_serial(&t, &cfg(250, algo)).unwrap();
        // Stage-1 NMF residual inflates later-stage SVD rank selection for
        // the weaker update rules — ranks may exceed the generator's 2 but
        // must stay small, and the fit must still be good.
        assert_eq!(out.tt.ranks()[1], 2, "{algo:?}");
        assert!(out.tt.ranks()[2] <= 4, "{algo:?} ranks {:?}", out.tt.ranks());
        let err = out.tt.rel_error(&t);
        assert!(err < 0.15, "{algo:?} err={err}");
    }
}

/// Disk-spilled distributed run equals the in-memory run exactly
/// (same deterministic inits, same reduction structure).
#[test]
fn spill_mode_equivalence() {
    let syn = SyntheticTt::new(vec![4, 6, 4], vec![2, 2], 13);
    let grid = ProcGrid::new(vec![2, 1, 2]).unwrap();
    let dir = unique_temp_dir("tt_spill");
    let mk = |spill| JobConfig {
        tt: cfg(40, NmfAlgo::Bcd),
        spill,
        ..JobConfig::new(InputSpec::Synthetic(syn.clone()), grid.clone())
    };
    let a = run_job(&mk(SpillMode::Memory)).unwrap();
    let b = run_job(&mk(SpillMode::Disk(dir.clone()))).unwrap();
    assert_eq!(a.ranks, b.ranks);
    let (att, btt) = (a.output.tt().unwrap(), b.output.tt().unwrap());
    for (ca, cb) in att.tt.cores().iter().zip(btt.tt.cores()) {
        for (x, y) in ca.as_slice().iter().zip(cb.as_slice()) {
            assert!((x - y).abs() < 1e-12);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// PJRT backend through the full coordinator (quickstart shapes, so some
/// ops hit the XLA path) agrees with native within f32 tolerance.
#[test]
fn pjrt_coordinator_agrees_with_native() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("NOTE: artifacts missing; skipping");
        return;
    }
    let syn = SyntheticTt::new(vec![16, 16, 16, 16], vec![4, 4, 4], 7);
    let grid = ProcGrid::new(vec![1, 1, 1, 1]).unwrap();
    let mk = |backend| JobConfig {
        tt: TtConfig {
            fixed_ranks: Some(vec![4, 4, 4]),
            nmf: NmfConfig { max_iters: 25, ..Default::default() },
            ..Default::default()
        },
        backend,
        ..JobConfig::new(InputSpec::Synthetic(syn.clone()), grid.clone())
    };
    let native = run_job(&mk(BackendChoice::Native)).unwrap();
    let pjrt = run_job(&mk(BackendChoice::Pjrt("artifacts".into()))).unwrap();
    assert!(pjrt.pjrt_hits > 0, "no ops took the XLA path");
    let (e1, e2) = (native.rel_error.unwrap(), pjrt.rel_error.unwrap());
    assert!((e1 - e2).abs() < 5e-3, "native {e1} vs pjrt {e2}");
}

/// Compression ratio reported by the driver matches Eq. 4 recomputed here.
#[test]
fn compression_matches_eq4() {
    let syn = SyntheticTt::new(vec![8, 8, 8], vec![2, 3], 21);
    let out = ntt_serial(&syn.dense(), &cfg(30, NmfAlgo::Bcd)).unwrap();
    let dims = out.tt.dims();
    let ranks = out.tt.ranks();
    let full: f64 = dims.iter().map(|&n| n as f64).product();
    let params: f64 = (0..dims.len())
        .map(|i| (dims[i] * ranks[i] * ranks[i + 1]) as f64)
        .sum();
    assert!((out.tt.compression_ratio() - full / params).abs() < 1e-9);
}
