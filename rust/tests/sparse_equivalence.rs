//! Integration: the sparse pipeline against the dense reference —
//! sparse/dense NMF equivalence (ISSUE 4 acceptance: 1e-5 agreement plus
//! bitwise determinism across ranks and runs within a world), the
//! end-to-end sparse TT job vs the densified tensor, the pruned-NMF
//! sparse round-trip (exact zeros restored), sparse chunk spill, and the
//! COO ingest edge cases.

mod common;

use common::{block_of, sparse_rand, unique_temp_dir};
use dntt::coordinator::{run_job, Decomposition, InputSpec, JobConfig};
use dntt::dist::chunkstore::SpillMode;
use dntt::dist::{Comm, Grid2d, ProcGrid, SharedStore};
use dntt::linalg::gemm::matmul;
use dntt::linalg::sparse::SparseMat;
use dntt::linalg::{DenseOrSparse, Mat};
use dntt::nmf::{
    dist_nmf_pruned_x_ws, dist_nmf_sparse_ws, dist_nmf_ws, NmfConfig, NmfOutput, NmfWorkspace,
};
use dntt::runtime::NativeBackend;
use dntt::tensor::SparseTensor;
use dntt::ttrain::{ntt_sparse_on_threads, SyntheticSparse, TtConfig};

/// Run the distributed NMF on every rank of `grid`, dense or sparse
/// blocks, and return the per-rank outputs.
fn run_nmf(x: &Mat<f64>, grid: Grid2d, cfg: &NmfConfig, sparse: bool) -> Vec<NmfOutput> {
    let (m, n) = x.shape();
    let x = x.clone();
    let cfg = cfg.clone();
    Comm::run(grid.size(), move |mut world| {
        let xb = block_of(&x, grid, world.rank());
        let (mut row, mut col) = grid.make_subcomms(&mut world);
        let mut ws = NmfWorkspace::new();
        if sparse {
            let xs = SparseMat::from_dense(&xb);
            dist_nmf_sparse_ws(
                &xs, m, n, grid, &mut world, &mut row, &mut col, &NativeBackend, &cfg, &mut ws,
            )
            .unwrap()
        } else {
            dist_nmf_ws(
                &xb, m, n, grid, &mut world, &mut row, &mut col, &NativeBackend, &cfg, &mut ws,
            )
            .unwrap()
        }
    })
}

/// ISSUE 4 acceptance: `dist_nmf` on sparse-chunked X matches the dense
/// run on the densified X to reduction roundoff, on a multi-rank grid.
/// X is a sparse low-rank product so the BCD trajectory is contractive
/// and roundoff differences stay bounded.
#[test]
fn sparse_nmf_matches_dense_to_reduction_roundoff() {
    let x = matmul(&sparse_rand(26, 3, 0.25, 5), &sparse_rand(3, 33, 0.25, 6));
    assert!(x.as_slice().iter().filter(|&&v| v == 0.0).count() > x.len() / 2);
    let grid = Grid2d::new(2, 3);
    let cfg = NmfConfig { rank: 3, max_iters: 40, ..Default::default() };
    let sp = run_nmf(&x, grid, &cfg, true);
    let de = run_nmf(&x, grid, &cfg, false);
    for (a, b) in sp.iter().zip(&de) {
        assert_eq!(a.w_rows, b.w_rows);
        assert_eq!(a.h_cols, b.h_cols);
        assert!(a.w.is_nonneg() && a.ht.is_nonneg());
        for (p, q) in a.w.as_slice().iter().zip(b.w.as_slice()) {
            assert!((p - q).abs() < 1e-5, "W: {p} vs {q}");
        }
        for (p, q) in a.ht.as_slice().iter().zip(b.ht.as_slice()) {
            assert!((p - q).abs() < 1e-5, "H: {p} vs {q}");
        }
        assert!(
            (a.stats.objective - b.stats.objective).abs()
                <= 1e-6 * (1.0 + b.stats.objective)
        );
    }
}

/// ISSUE 4 acceptance: within a world, repeated sparse runs are bitwise
/// identical (deterministic SpMM order + deterministic collectives), and
/// the convergence stats are rank-identical.
#[test]
fn sparse_nmf_is_bitwise_deterministic_across_runs_and_ranks() {
    let x = sparse_rand(18, 24, 0.1, 9);
    let grid = Grid2d::new(2, 2);
    let cfg = NmfConfig { rank: 2, max_iters: 40, ..Default::default() };
    let a = run_nmf(&x, grid, &cfg, true);
    let b = run_nmf(&x, grid, &cfg, true);
    for (oa, ob) in a.iter().zip(&b) {
        assert_eq!(oa.w.as_slice(), ob.w.as_slice(), "rerun W must be bitwise identical");
        assert_eq!(oa.ht.as_slice(), ob.ht.as_slice(), "rerun H must be bitwise identical");
    }
    for o in &a {
        assert_eq!(o.stats.iters, a[0].stats.iters);
        assert_eq!(o.stats.objective.to_bits(), a[0].stats.objective.to_bits());
    }
}

/// End-to-end: a sparse TT job (blocks generated sparse, stage-0 kept
/// sparse through reshape and NMF) matches the dense job on the
/// densified tensor, through `run_job` on a 4-rank grid.
#[test]
fn sparse_tt_job_matches_densified_dense_job() {
    let syn = SyntheticSparse::new(vec![8, 6, 5], 0.1, 21);
    let grid = ProcGrid::new(vec![2, 2, 1]).unwrap();
    let tt_cfg = TtConfig {
        fixed_ranks: Some(vec![3, 3]),
        nmf: NmfConfig { max_iters: 50, ..Default::default() },
        ..Default::default()
    };
    let sparse_job = JobConfig {
        tt: tt_cfg.clone(),
        ..JobConfig::new(InputSpec::SyntheticSparse(syn.clone()), grid.clone())
    };
    let dense_job = JobConfig {
        tt: tt_cfg,
        ..JobConfig::new(
            InputSpec::Dense(std::sync::Arc::new(syn.dense())),
            grid.clone(),
        )
    };
    let sp = run_job(&sparse_job).unwrap();
    let de = run_job(&dense_job).unwrap();
    assert_eq!(sp.ranks, de.ranks);
    assert!(sp.output.is_nonneg());
    let (sp_tt, de_tt) = (sp.output.tt().unwrap(), de.output.tt().unwrap());
    for (a, b) in sp_tt.tt.cores().iter().zip(de_tt.tt.cores()) {
        for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }
    // Both reports checked the error against the same ground truth.
    let (e1, e2) = (sp.rel_error.unwrap(), de.rel_error.unwrap());
    assert!((e1 - e2).abs() < 1e-5, "{e1} vs {e2}");
}

/// The sparse driver wrapper: spill mode exercised via run_job is
/// covered above; here the thread wrapper runs the same decomposition
/// twice and must be bitwise-reproducible.
#[test]
fn sparse_tt_runs_are_reproducible() {
    let syn = SyntheticSparse::new(vec![6, 6, 4], 0.12, 33);
    let grid = ProcGrid::new(vec![2, 1, 2]).unwrap();
    let cfg = TtConfig {
        fixed_ranks: Some(vec![2, 2]),
        nmf: NmfConfig { max_iters: 40, ..Default::default() },
        ..Default::default()
    };
    let a = ntt_sparse_on_threads(&syn, &grid, &cfg).unwrap();
    let b = ntt_sparse_on_threads(&syn, &grid, &cfg).unwrap();
    for (ca, cb) in a.tt.cores().iter().zip(b.tt.cores()) {
        assert_eq!(ca.as_slice(), cb.as_slice(), "cores must be bitwise identical");
    }
}

/// HT on a sparse input: the root stage consumes the sparse block; the
/// result must be a valid non-negative HT with a finite error report.
#[test]
fn sparse_ht_job_runs_end_to_end() {
    let syn = SyntheticSparse::new(vec![6, 5, 4], 0.15, 13);
    let job = JobConfig {
        decomp: Decomposition::Ht,
        ht: dntt::ht::HtConfig {
            fixed_ranks: Some(vec![2; 4]),
            nmf: NmfConfig { max_iters: 40, ..Default::default() },
            ..Default::default()
        },
        ..JobConfig::new(
            InputSpec::SyntheticSparse(syn),
            ProcGrid::new(vec![2, 1, 1]).unwrap(),
        )
    };
    let rep = run_job(&job).unwrap();
    assert!(rep.output.is_nonneg());
    assert!(rep.rel_error.unwrap().is_finite());
    assert!(rep.compression > 0.0);
}

/// Pruned NMF on a sparse block: pruned rows/columns must round-trip
/// through the compress/restore store trips with exact zeros, and the
/// surviving factors must match the dense pruned path to roundoff.
#[test]
fn pruned_sparse_roundtrip_restores_exact_zeros() {
    let (m, n) = (12, 10);
    // Low-rank non-negative X with exact zero rows 3, 7 and column 4.
    let mut a = sparse_rand(m, 2, 0.9, 3);
    let mut b = sparse_rand(2, n, 0.9, 4);
    for &zr in &[3usize, 7] {
        a.row_mut(zr).iter_mut().for_each(|v| *v = 0.0);
    }
    for k in 0..2 {
        b[(k, 4)] = 0.0;
    }
    let x = matmul(&a, &b);
    let grid = Grid2d::new(2, 2);
    let cfg = NmfConfig { rank: 2, max_iters: 120, ..Default::default() };
    let run = |sparse: bool| {
        let x = x.clone();
        let cfg = cfg.clone();
        let store = SharedStore::new(SpillMode::Memory);
        Comm::run(4, move |mut world| {
            let xb = block_of(&x, grid, world.rank());
            let xblock = if sparse {
                DenseOrSparse::Sparse(SparseMat::from_dense(&xb))
            } else {
                DenseOrSparse::Dense(xb)
            };
            let (mut row, mut col) = grid.make_subcomms(&mut world);
            dist_nmf_pruned_x_ws(
                &xblock, m, n, grid, &mut world, &mut row, &mut col, &NativeBackend, &cfg,
                &store, "t", true, &mut NmfWorkspace::new(),
            )
            .unwrap()
        })
    };
    let assemble = |outs: &[NmfOutput]| {
        let mut w = Mat::zeros(m, 2);
        let mut h = Mat::zeros(2, n);
        for o in outs {
            for (li, gi) in (o.w_rows.0..o.w_rows.1).enumerate() {
                w.row_mut(gi).copy_from_slice(o.w.row(li));
            }
            for (lb, gb) in (o.h_cols.0..o.h_cols.1).enumerate() {
                for c in 0..2 {
                    h[(c, gb)] = o.ht[(lb, c)];
                }
            }
        }
        (w, h)
    };
    let (ws, hs) = assemble(&run(true));
    let (wd, hd) = assemble(&run(false));
    // Pruned rows/cols restored as exact zeros on the sparse path.
    assert!(ws.row(3).iter().all(|&v| v == 0.0));
    assert!(ws.row(7).iter().all(|&v| v == 0.0));
    assert!((0..2).all(|k| hs[(k, 4)] == 0.0));
    // Sparse and dense pruned paths agree to reduction roundoff.
    for (p, q) in ws.as_slice().iter().zip(wd.as_slice()) {
        assert!((p - q).abs() < 1e-5, "{p} vs {q}");
    }
    for (p, q) in hs.as_slice().iter().zip(hd.as_slice()) {
        assert!((p - q).abs() < 1e-5, "{p} vs {q}");
    }
    // And the fit is good.
    let mut d = matmul(&ws, &hs);
    d.sub_assign(&x);
    assert!(d.fro_norm() / x.fro_norm() < 0.05);
}

/// Sparse TT through a disk-spill store: identical cores to the
/// memory-store run (the spill format round-trips), exercised via
/// run_job's spill knob.
#[test]
fn sparse_job_disk_spill_matches_memory() {
    let syn = SyntheticSparse::new(vec![6, 4, 4], 0.12, 55);
    let grid = ProcGrid::new(vec![2, 1, 1]).unwrap();
    let dir = unique_temp_dir("sparse_spill");
    let mk = |spill: SpillMode| JobConfig {
        tt: TtConfig {
            fixed_ranks: Some(vec![2, 2]),
            nmf: NmfConfig { max_iters: 30, ..Default::default() },
            ..Default::default()
        },
        spill,
        ..JobConfig::new(InputSpec::SyntheticSparse(syn.clone()), grid.clone())
    };
    let mem = run_job(&mk(SpillMode::Memory)).unwrap();
    let disk = run_job(&mk(SpillMode::Disk(dir.clone()))).unwrap();
    let (mt, dt) = (mem.output.tt().unwrap(), disk.output.tt().unwrap());
    for (a, b) in mt.tt.cores().iter().zip(dt.tt.cores()) {
        assert_eq!(a.as_slice(), b.as_slice(), "spill must not change results");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Ingest edge cases from the ISSUE checklist: duplicate-coordinate
/// rejection, empty chunks, fully dense COO.
#[test]
fn coo_ingest_edge_cases() {
    // Duplicate coordinates rejected at both tensor and matrix level.
    assert!(SparseTensor::new(vec![3, 3], vec![(4, 1.0), (4, 2.0)]).is_err());
    assert!(SparseMat::from_coo(3, 3, vec![(1, 1, 1.0), (1, 1, 2.0)]).is_err());
    // Fully dense COO round-trips.
    let entries: Vec<(usize, f64)> = (0..9).map(|k| (k, (k + 1) as f64)).collect();
    let t = SparseTensor::new(vec![3, 3], entries).unwrap();
    assert_eq!(t.density(), 1.0);
    assert_eq!(
        t.to_dense().as_slice(),
        &(1..=9).map(|k| k as f64).collect::<Vec<_>>()[..]
    );
    // Empty tensor: zero nonzeros everywhere, blocks included.
    let e = SparseTensor::new(vec![4, 2], vec![]).unwrap();
    assert_eq!(e.nnz(), 0);
    let grid = ProcGrid::new(vec![2, 1]).unwrap();
    for r in 0..2 {
        let c = e.block_chunk(&grid, r);
        assert_eq!((c.len(), c.nnz()), (4, 0));
    }
}
