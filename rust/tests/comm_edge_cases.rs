//! Integration: communicator edge cases beyond the seed suites —
//! single-rank worlds, uneven (and empty) reduce_scatter partitions,
//! barrier reuse across phases, and varied gathers with an empty
//! contribution on one rank.

use dntt::dist::{Comm, Grid2d};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Every collective degenerates to the identity (of the local
/// contribution) on a single-rank world.
#[test]
fn single_rank_world_collectives() {
    let outs = Comm::run(1, |mut c| {
        assert_eq!((c.rank(), c.size()), (0, 1));
        c.barrier();
        let mut v = vec![1.5, -2.0, 0.25];
        c.all_reduce_sum(&mut v);
        assert_eq!(v, vec![1.5, -2.0, 0.25]);
        let s = c.all_reduce_scalar(3.5);
        assert_eq!(s, 3.5);
        let gathered = c.all_gather_varied(&[7.0, 8.0]);
        assert_eq!(gathered, vec![vec![7.0, 8.0]]);
        let scattered = c.reduce_scatter_uneven(&[1.0, 2.0, 3.0], &[3]).unwrap();
        assert_eq!(scattered, vec![1.0, 2.0, 3.0]);
        c.barrier();
        42usize
    });
    assert_eq!(outs, vec![42]);
}

/// Uneven reduce_scatter partitions, including a rank whose share is
/// empty: sums land in the right segments and the empty rank gets an
/// empty vector.
#[test]
fn reduce_scatter_uneven_partitions_with_empty_share() {
    let counts = [3usize, 0, 2];
    let outs = Comm::run(3, move |mut c| {
        // Rank r contributes [r+1, r+1, ...] over the 5 slots.
        let data = vec![(c.rank() + 1) as f64; 5];
        c.reduce_scatter_uneven(&data, &counts).unwrap()
    });
    // Column sums are 1+2+3 = 6 everywhere.
    assert_eq!(outs[0], vec![6.0, 6.0, 6.0]);
    assert_eq!(outs[1], Vec::<f64>::new());
    assert_eq!(outs[2], vec![6.0, 6.0]);
}

/// Mis-sized partitions are rejected with an error, not a deadlock.
#[test]
fn reduce_scatter_uneven_rejects_mismatches() {
    let outs = Comm::run(1, |mut c| {
        let wrong_rank_count = c.reduce_scatter_uneven(&[1.0, 2.0], &[1, 1]).is_err();
        let wrong_total = c.reduce_scatter_uneven(&[1.0, 2.0], &[3]).is_err();
        let divisible_ok = c.reduce_scatter_sum(&[1.0, 2.0, 3.0]).is_ok();
        (wrong_rank_count, wrong_total, divisible_ok)
    });
    assert_eq!(outs[0], (true, true, true)); // p=1 divides everything
    let outs = Comm::run(2, |mut c| {
        if c.rank() == 0 {
            // Validation happens before any exchange, so a single rank can
            // observe the error without desynchronizing the world.
            assert!(c.reduce_scatter_uneven(&[1.0], &[2, 2]).is_err());
        }
        c.barrier();
        true
    });
    assert!(outs.iter().all(|&x| x));
}

/// Barriers are reusable across phases: after the phase-k barrier, every
/// rank observes all phase-k contributions.
#[test]
fn barrier_reuse_across_phases() {
    let p = 4;
    let phases = 3;
    let counter = Arc::new(AtomicUsize::new(0));
    let c2 = Arc::clone(&counter);
    Comm::run(p, move |mut world| {
        for k in 0..phases {
            c2.fetch_add(1, Ordering::SeqCst);
            world.barrier();
            let seen = c2.load(Ordering::SeqCst);
            assert!(
                seen >= p * (k + 1),
                "after barrier {k}: saw {seen}, expected at least {}",
                p * (k + 1)
            );
            // A second barrier in the same phase must also work.
            world.barrier();
        }
    });
    assert_eq!(counter.load(Ordering::SeqCst), p * phases);
}

/// all_gather_varied with an empty slice on one rank: the empty part is
/// preserved in rank order on every rank.
#[test]
fn all_gather_varied_with_empty_rank() {
    let outs = Comm::run(3, |mut c| {
        let mine: Vec<f64> = match c.rank() {
            0 => vec![10.0, 11.0],
            1 => Vec::new(),
            _ => vec![30.0],
        };
        c.all_gather_varied(&mine)
    });
    for parts in &outs {
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0], vec![10.0, 11.0]);
        assert_eq!(parts[1], Vec::<f64>::new());
        assert_eq!(parts[2], vec![30.0]);
    }
    // Concatenating skips the empty contribution cleanly.
    let outs = Comm::run(3, |mut c| {
        let mine: Vec<f64> = if c.rank() == 1 { Vec::new() } else { vec![c.rank() as f64] };
        c.all_gather(&mine)
    });
    assert!(outs.iter().all(|o| o == &[0.0, 2.0]));
}

/// Degenerate grids (one row / one column) still produce working
/// sub-communicators whose reduces compose to the world reduce.
#[test]
fn degenerate_grid_subcomms() {
    for (pr, pc) in [(1usize, 4usize), (4, 1)] {
        let grid = Grid2d::new(pr, pc);
        let outs = Comm::run(grid.size(), move |mut world| {
            let (mut row, mut col) = grid.make_subcomms(&mut world);
            let v = (world.rank() + 1) as f64;
            let row_sum = row.all_reduce_scalar(v);
            let total = col.all_reduce_scalar(row_sum);
            (total, world.all_reduce_scalar(v))
        });
        for (composed, world_sum) in outs {
            assert_eq!(world_sum, 10.0, "grid {pr}x{pc}");
            assert_eq!(composed, 10.0, "grid {pr}x{pc}");
        }
    }
}
