//! Property-style validation of the packed register-blocked GEMM
//! microkernel against the naive reference, over odd / degenerate shapes
//! and both scalar widths, plus the NMF workspace-reuse determinism
//! guarantees (ISSUE 3 satellite: m,k,n ∈ {0,1,3,5,63,64,65}).
//!
//! The packed kernels promise *bitwise* equality with `matmul_naive`
//! (same multiply-then-add operation sequence, ascending k per output
//! element — see the reproducibility contract in `linalg/gemm.rs`), so
//! every comparison here is exact, not tolerance-based.

use dntt::dist::{Comm, Grid2d};
use dntt::linalg::gemm::{
    matmul, matmul_a_bt_packed_into, matmul_at_b_packed_into, matmul_blocked_into, matmul_into_ws,
    matmul_naive, matmul_packed_into, GemmWorkspace,
};
use dntt::linalg::{Mat, Scalar};
use dntt::nmf::{dist_nmf, dist_nmf_ws, NmfAlgo, NmfConfig, NmfWorkspace};
use dntt::runtime::native::NativeBackend;
use dntt::util::rng::Rng;

/// The satellite's edge-shape grid, 0-sized edges included.
const DIMS: [usize; 7] = [0, 1, 3, 5, 63, 64, 65];

fn rand_mat<T: Scalar>(rows: usize, cols: usize, rng: &mut Rng) -> Mat<T> {
    // Mix signs so zero-skip paths and cancellation are exercised.
    Mat::from_fn(rows, cols, |_, _| T::fromf(rng.uniform() * 2.0 - 1.0))
}

/// packed(A·B) == naive(A·B) bitwise for every (m, k, n) in DIMS³.
fn packed_matches_naive_all_shapes<T: Scalar>() {
    let mut rng = Rng::new(0xA0);
    let mut ws = GemmWorkspace::<T>::new();
    for &m in &DIMS {
        for &k in &DIMS {
            for &n in &DIMS {
                let a = rand_mat::<T>(m, k, &mut rng);
                let b = rand_mat::<T>(k, n, &mut rng);
                let naive = matmul_naive(&a, &b);
                let mut c = rand_mat::<T>(m, n, &mut rng); // stale contents must be overwritten
                matmul_packed_into(&a, &b, &mut c, &mut ws);
                assert_eq!(
                    c.as_slice(),
                    naive.as_slice(),
                    "{} packed != naive at {m}x{k}x{n}",
                    T::NAME
                );
            }
        }
    }
}

#[test]
fn packed_matches_naive_f64() {
    packed_matches_naive_all_shapes::<f64>();
}

#[test]
fn packed_matches_naive_f32() {
    packed_matches_naive_all_shapes::<f32>();
}

/// The transpose-loading variants hit the same bitwise contract through
/// their own packing loaders.
fn transpose_variants_match_naive<T: Scalar>() {
    let mut rng = Rng::new(0xB0);
    let mut ws = GemmWorkspace::<T>::new();
    for &m in &DIMS {
        for &k in &DIMS {
            for &n in &DIMS {
                // At·B: A stored k×m.
                let a = rand_mat::<T>(k, m, &mut rng);
                let b = rand_mat::<T>(k, n, &mut rng);
                let mut c = Mat::<T>::zeros(m, n);
                matmul_at_b_packed_into(&a, &b, &mut c, &mut ws);
                assert_eq!(
                    c.as_slice(),
                    matmul_naive(&a.transpose(), &b).as_slice(),
                    "{} at_b packed != naive at {m}x{k}x{n}",
                    T::NAME
                );
                // A·Bt: B stored n×k.
                let a = rand_mat::<T>(m, k, &mut rng);
                let b = rand_mat::<T>(n, k, &mut rng);
                let mut c = Mat::<T>::zeros(m, n);
                matmul_a_bt_packed_into(&a, &b, &mut c, &mut ws);
                assert_eq!(
                    c.as_slice(),
                    matmul_naive(&a, &b.transpose()).as_slice(),
                    "{} a_bt packed != naive at {m}x{k}x{n}",
                    T::NAME
                );
            }
        }
    }
}

#[test]
fn transpose_variants_match_naive_f64() {
    transpose_variants_match_naive::<f64>();
}

#[test]
fn transpose_variants_match_naive_f32() {
    transpose_variants_match_naive::<f32>();
}

/// The dispatching entry point agrees with both of its branches (to
/// rounding for the blocked branch, which uses FMA).
#[test]
fn dispatcher_agrees_with_both_kernels() {
    let mut rng = Rng::new(0xC0);
    let mut ws = GemmWorkspace::<f64>::new();
    for &(m, k, n) in &[(65usize, 64usize, 65usize), (5, 3, 5), (128, 40, 12), (1, 300, 1)] {
        let a = rand_mat::<f64>(m, k, &mut rng);
        let b = rand_mat::<f64>(k, n, &mut rng);
        let mut c = Mat::zeros(m, n);
        matmul_into_ws(&a, &b, &mut c, &mut ws);
        let mut blocked = Mat::zeros(m, n);
        matmul_blocked_into(&a, &b, &mut blocked);
        let scale = a.max_abs().max(1.0) * b.max_abs().max(1.0) * k as f64;
        for (x, y) in c.as_slice().iter().zip(blocked.as_slice()) {
            assert!((x - y).abs() <= 1e-12 * scale, "dispatch vs blocked: {x} vs {y}");
        }
    }
}

/// A workspace warmed on one shape must not perturb later products
/// (stale panel data is always overwritten or masked).
#[test]
fn workspace_carryover_is_bitwise_neutral() {
    let mut rng = Rng::new(0xD0);
    let mut warm = GemmWorkspace::<f64>::new();
    // Warm on a large shape, then verify every small/odd shape matches a
    // fresh-workspace run bitwise.
    let a = rand_mat::<f64>(130, 300, &mut rng);
    let b = rand_mat::<f64>(300, 40, &mut rng);
    let mut c = Mat::zeros(130, 40);
    matmul_packed_into(&a, &b, &mut c, &mut warm);
    for &m in &DIMS {
        for &n in &DIMS {
            let k = 65;
            let a = rand_mat::<f64>(m, k, &mut rng);
            let b = rand_mat::<f64>(k, n, &mut rng);
            let mut from_warm = Mat::zeros(m, n);
            matmul_packed_into(&a, &b, &mut from_warm, &mut warm);
            let mut from_fresh = Mat::zeros(m, n);
            matmul_packed_into(&a, &b, &mut from_fresh, &mut GemmWorkspace::new());
            assert_eq!(from_warm.as_slice(), from_fresh.as_slice(), "warm != fresh at {m}x{k}x{n}");
        }
    }
}

/// Two distributed NMF runs sharing one `NmfWorkspace` are bitwise
/// identical — to each other and to the transient-workspace wrapper —
/// for every update rule, on a multi-rank grid (the ISSUE 3 satellite's
/// workspace-reuse test).
#[test]
fn nmf_runs_from_shared_workspace_are_bitwise_identical() {
    let (m, n) = (26, 33);
    let mut rng = Rng::new(0xE0);
    let x = {
        let a = Mat::<f64>::rand_uniform(m, 3, &mut rng);
        let b = Mat::<f64>::rand_uniform(3, n, &mut rng);
        matmul(&a, &b)
    };
    for algo in [NmfAlgo::Bcd, NmfAlgo::Mu, NmfAlgo::Hals] {
        let grid = Grid2d::new(2, 2);
        let cfg = NmfConfig { rank: 3, max_iters: 30, algo, ..Default::default() };
        let x2 = x.clone();
        let outs = Comm::run(grid.size(), move |mut world| {
            let (i, j) = grid.coords(world.rank());
            let rows = dntt::dist::BlockDim::new(m, grid.pr);
            let cols = dntt::dist::BlockDim::new(n, grid.pc);
            let xb = Mat::from_fn(rows.size_of(i), cols.size_of(j), |a, b| {
                x2[(rows.start_of(i) + a, cols.start_of(j) + b)]
            });
            let (mut row, mut col) = grid.make_subcomms(&mut world);
            let mut ws = NmfWorkspace::new();
            let first = dist_nmf_ws(
                &xb, m, n, grid, &mut world, &mut row, &mut col, &NativeBackend, &cfg, &mut ws,
            )
            .unwrap();
            let second = dist_nmf_ws(
                &xb, m, n, grid, &mut world, &mut row, &mut col, &NativeBackend, &cfg, &mut ws,
            )
            .unwrap();
            let wrapper = dist_nmf(
                &xb, m, n, grid, &mut world, &mut row, &mut col, &NativeBackend, &cfg,
            )
            .unwrap();
            (first, second, wrapper)
        });
        for (first, second, wrapper) in &outs {
            assert_eq!(
                first.w.as_slice(),
                second.w.as_slice(),
                "{algo:?}: W differs between runs from the same workspace"
            );
            assert_eq!(
                first.ht.as_slice(),
                second.ht.as_slice(),
                "{algo:?}: H differs between runs from the same workspace"
            );
            assert_eq!(first.w.as_slice(), wrapper.w.as_slice(), "{algo:?}: ws vs wrapper W");
            assert_eq!(first.ht.as_slice(), wrapper.ht.as_slice(), "{algo:?}: ws vs wrapper H");
            assert_eq!(first.stats.iters, second.stats.iters);
            assert!(first.stats.objective == second.stats.objective);
        }
    }
}
