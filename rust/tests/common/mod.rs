//! Shared fixtures for the integration suites: the small-grid job/config
//! builders, synthetic block extraction, factor-comparison helpers and
//! unique temp-dir allocation that were previously copy-pasted across
//! `ht_equivalence.rs`, `sparse_equivalence.rs`, `integration_ttrain.rs`
//! and `integration_dist.rs`.
//!
//! Each integration binary compiles its own copy (`mod common;`), so not
//! every binary uses every helper — hence the file-wide `dead_code` allow.
#![allow(dead_code)]

use dntt::dist::{BlockDim, Grid2d};
use dntt::ht::HtConfig;
use dntt::linalg::Mat;
use dntt::nmf::{NmfAlgo, NmfConfig};
use dntt::ttrain::TtConfig;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

/// The tight-eps TT config the equivalence suites run (BCD, default tol).
pub fn tt_cfg(iters: usize) -> TtConfig {
    tt_cfg_algo(iters, NmfAlgo::Bcd)
}

/// [`tt_cfg`] with an explicit update rule.
pub fn tt_cfg_algo(iters: usize, algo: NmfAlgo) -> TtConfig {
    TtConfig {
        eps: 1e-6,
        nmf: NmfConfig { max_iters: iters, algo, ..Default::default() },
        ..Default::default()
    }
}

/// TT config with fixed ranks (skips the SVD — what the recovery and
/// sparse suites use to pin the stage shapes).
pub fn tt_cfg_fixed(iters: usize, ranks: Vec<usize>) -> TtConfig {
    TtConfig {
        fixed_ranks: Some(ranks),
        nmf: NmfConfig { max_iters: iters, ..Default::default() },
        ..Default::default()
    }
}

/// The tight-eps, tight-tol HT config of the HT equivalence suite.
pub fn ht_cfg(iters: usize) -> HtConfig {
    HtConfig {
        eps: 1e-6,
        nmf: NmfConfig { max_iters: iters, tol: 1e-12, ..Default::default() },
        ..Default::default()
    }
}

/// HT config with fixed edge ranks (two per interior node).
pub fn ht_cfg_fixed(iters: usize, ranks: Vec<usize>) -> HtConfig {
    HtConfig {
        fixed_ranks: Some(ranks),
        nmf: NmfConfig { max_iters: iters, ..Default::default() },
        ..Default::default()
    }
}

/// Block `(i, j)` of a full matrix under the `MatGrid` partition — the
/// per-rank input the distributed-NMF tests feed each rank.
pub fn block_of(x: &Mat<f64>, grid: Grid2d, rank: usize) -> Mat<f64> {
    let (m, n) = x.shape();
    let (i, j) = grid.coords(rank);
    let rows = BlockDim::new(m, grid.pr);
    let cols = BlockDim::new(n, grid.pc);
    Mat::from_fn(rows.size_of(i), cols.size_of(j), |a, b| {
        x[(rows.start_of(i) + a, cols.start_of(j) + b)]
    })
}

/// Dense non-negative matrix with exact zeros at the given density.
pub fn sparse_rand(m: usize, n: usize, density: f64, seed: u64) -> Mat<f64> {
    let mut rng = dntt::util::rng::Rng::new(seed);
    Mat::from_fn(m, n, |_, _| if rng.uniform() < density { 0.5 + rng.uniform() } else { 0.0 })
}

/// Element-wise closeness assertion with a labelled failure.
pub fn assert_close_slices(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() < tol, "{what}[{k}]: {x} vs {y} (tol {tol})");
    }
}

/// Bitwise identity assertion over TT cores.
pub fn assert_cores_bitwise(a: &dntt::ttrain::TtOutput, b: &dntt::ttrain::TtOutput, what: &str) {
    assert_eq!(a.tt.ranks(), b.tt.ranks(), "{what}: rank chains differ");
    for (l, (ca, cb)) in a.tt.cores().iter().zip(b.tt.cores()).enumerate() {
        assert_eq!(ca.as_slice(), cb.as_slice(), "{what}: core {l} must be bitwise identical");
    }
}

/// Bitwise identity assertion over HT node matrices.
pub fn assert_ht_nodes_bitwise(a: &dntt::ht::HtOutput, b: &dntt::ht::HtOutput, what: &str) {
    assert_eq!(a.ht.ranks(), b.ht.ranks(), "{what}: edge-rank chains differ");
    for (t, (na, nb)) in a.ht.nodes().iter().zip(b.ht.nodes()).enumerate() {
        assert_eq!(
            na.mat().as_slice(),
            nb.mat().as_slice(),
            "{what}: node {t} must be bitwise identical"
        );
    }
}

static TMP_COUNTER: AtomicUsize = AtomicUsize::new(0);

/// A fresh (removed-if-existing) temp directory unique to this process
/// *and* call site — safe for tests running in parallel within one
/// binary.
pub fn unique_temp_dir(tag: &str) -> PathBuf {
    let n = TMP_COUNTER.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!("dntt_{tag}_{}_{n}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// `.chunk` files remaining under `dir` (what the spill-cleanup test
/// counts; 0 for a cleanly dropped store).
pub fn chunk_files_in(dir: &std::path::Path) -> usize {
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.flatten()
                .filter(|e| e.file_name().to_string_lossy().ends_with(".chunk"))
                .count()
        })
        .unwrap_or(0)
}
