//! Kernel-conformance battery (ISSUE 9): every compiled-in SIMD path and
//! every thread count must be **bitwise identical** to the scalar
//! reference on every GEMM layout, every SpMM layout, and end to end
//! through the distributed NMF.
//!
//! The contract under test (see `linalg/simd.rs`): vector lanes map
//! across output columns (the NR direction) and threads partition output
//! row panels, so every output element sees the exact ascending-k
//! separate-multiply/add sequence of `matmul_naive` — SIMD width and
//! thread count change *which hardware* produces an element, never the
//! operation order behind it. Every comparison here is `assert_eq!` on
//! the raw slices, not tolerance-based.
//!
//! These tests force paths explicitly via `KernelCfg`, so they prove the
//! same thing no matter what `DNTT_KERNEL` says; the CI kernel-matrix
//! job additionally reruns the whole suite under `DNTT_KERNEL=scalar`
//! and `=auto` to force every *implicit* dispatch site too.

use dntt::dist::{Comm, Grid2d};
use dntt::linalg::gemm::{
    matmul, matmul_a_bt_packed_with, matmul_at_b_packed_with, matmul_naive, matmul_packed_with,
    GemmWorkspace,
};
use dntt::linalg::sparse::{
    sp_matmul, sp_matmul_a_bt, sp_matmul_a_bt_with, sp_matmul_at_b, sp_matmul_at_b_with,
    sp_matmul_with, SparseMat,
};
use dntt::linalg::{KernelCfg, KernelPath, Mat, Scalar};
use dntt::nmf::{dist_nmf_ws, NmfAlgo, NmfConfig, NmfWorkspace};
use dntt::runtime::native::NativeBackend;
use dntt::util::rng::Rng;

/// The satellite's edge-shape grid: zero, sub-tile, exact-tile (MR = 8,
/// NR = 4), one-past-tile, and the packing-block edges.
const DIMS: [usize; 12] = [0, 1, 3, 5, 7, 8, 15, 16, 17, 63, 64, 65];

/// Thread counts swept by the threaded conformance tests.
const THREADS: [usize; 4] = [1, 2, 4, 8];

fn rand_mat<T: Scalar>(rows: usize, cols: usize, rng: &mut Rng) -> Mat<T> {
    // Mixed signs: exercises cancellation, where operation *order* shows.
    Mat::from_fn(rows, cols, |_, _| T::fromf(rng.uniform() * 2.0 - 1.0))
}

/// Dense non-negative matrix with exact zeros at the given density.
fn sparse_x(m: usize, n: usize, density: f64, rng: &mut Rng) -> Mat<f64> {
    Mat::from_fn(m, n, |_, _| {
        if rng.uniform() < density {
            0.5 + rng.uniform()
        } else {
            0.0
        }
    })
}

/// Every available path × every (m, k, n) in DIMS³ × all three layouts:
/// bitwise equal to `matmul_naive` on the same logical product.
fn all_paths_match_naive_all_layouts<T: Scalar>() {
    let mut rng = Rng::new(0x91);
    let mut ws = GemmWorkspace::<T>::new();
    let paths = KernelPath::available();
    assert!(paths.contains(&KernelPath::Scalar));
    for &m in &DIMS {
        for &k in &DIMS {
            for &n in &DIMS {
                let a = rand_mat::<T>(m, k, &mut rng);
                let b = rand_mat::<T>(k, n, &mut rng);
                let naive = matmul_naive(&a, &b);
                let at = a.transpose(); // k×m storage for the Aᵀ·B layout
                let bt = b.transpose(); // n×k storage for the A·Bᵀ layout
                for &path in &paths {
                    let sel = KernelCfg::new(path, 1);
                    let mut c = rand_mat::<T>(m, n, &mut rng); // stale contents
                    matmul_packed_with(&a, &b, &mut c, &mut ws, sel);
                    assert_eq!(
                        c.as_slice(),
                        naive.as_slice(),
                        "{} {path:?} A*B != naive at {m}x{k}x{n}",
                        T::NAME
                    );
                    matmul_at_b_packed_with(&at, &b, &mut c, &mut ws, sel);
                    assert_eq!(
                        c.as_slice(),
                        naive.as_slice(),
                        "{} {path:?} At*B != naive at {m}x{k}x{n}",
                        T::NAME
                    );
                    matmul_a_bt_packed_with(&a, &bt, &mut c, &mut ws, sel);
                    assert_eq!(
                        c.as_slice(),
                        naive.as_slice(),
                        "{} {path:?} A*Bt != naive at {m}x{k}x{n}",
                        T::NAME
                    );
                }
            }
        }
    }
}

#[test]
fn all_paths_match_naive_all_layouts_f64() {
    all_paths_match_naive_all_layouts::<f64>();
}

#[test]
fn all_paths_match_naive_all_layouts_f32() {
    all_paths_match_naive_all_layouts::<f32>();
}

/// Unavailable paths are downgraded to scalar at the entry point, never
/// executed: forcing every enum variant is safe on every host and still
/// bitwise exact.
#[test]
fn forcing_unavailable_paths_is_safe_and_exact() {
    let mut rng = Rng::new(0x92);
    let mut ws = GemmWorkspace::<f64>::new();
    let a = rand_mat::<f64>(33, 65, &mut rng);
    let b = rand_mat::<f64>(65, 9, &mut rng);
    let naive = matmul_naive(&a, &b);
    for path in KernelPath::ALL {
        let mut c = Mat::zeros(33, 9);
        matmul_packed_with(&a, &b, &mut c, &mut ws, KernelCfg::new(path, 2));
        assert_eq!(c.as_slice(), naive.as_slice(), "{path:?} (possibly downgraded)");
    }
}

/// Threads partition MC-aligned output row panels: every (path × thread
/// count) is bitwise equal to the serial scalar run, including shapes
/// with more threads than panels and zero-sized edges.
#[test]
fn threaded_gemm_is_bitwise_identical_to_serial() {
    let mut rng = Rng::new(0x93);
    let mut ws = GemmWorkspace::<f64>::new();
    // m spans: below one MC panel (128), exactly MC, several panels +
    // remainder; plus degenerate k/n edges.
    for &(m, k, n) in &[
        (300usize, 65usize, 9usize),
        (128, 40, 4),
        (17, 300, 33),
        (513, 16, 7),
        (256, 0, 5),
        (0, 8, 8),
    ] {
        let a = rand_mat::<f64>(m, k, &mut rng);
        let b = rand_mat::<f64>(k, n, &mut rng);
        let naive = matmul_naive(&a, &b);
        for &path in &KernelPath::available() {
            for &t in &THREADS {
                let mut c = rand_mat::<f64>(m, n, &mut rng);
                matmul_packed_with(&a, &b, &mut c, &mut ws, KernelCfg::new(path, t));
                assert_eq!(
                    c.as_slice(),
                    naive.as_slice(),
                    "{path:?} t={t} != naive at {m}x{k}x{n}"
                );
            }
        }
    }
}

/// Every SpMM layout × path × thread count × density (empty, 1%, half,
/// full) is bitwise equal to the scalar `_into` reference — which the
/// seed test suite already proves equal to the dense naive product.
#[test]
fn spmm_all_paths_match_scalar_reference_across_densities() {
    let mut rng = Rng::new(0x94);
    let (m, k, r) = (67, 45, 5);
    for &density in &[0.0f64, 0.01, 0.5, 1.0] {
        let xd = sparse_x(m, k, density, &mut rng);
        let xs = SparseMat::from_dense(&xd);
        let bh = Mat::<f64>::rand_uniform(k, r, &mut rng); // X·B
        let bw = Mat::<f64>::rand_uniform(m, r, &mut rng); // Xᵀ·B
        let bt = Mat::<f64>::rand_uniform(r, k, &mut rng); // X·Bᵀ
        let want_ab = sp_matmul(&xs, &bh);
        let want_atb = sp_matmul_at_b(&xs, &bw);
        let want_abt = sp_matmul_a_bt(&xs, &bt);
        // The scalar path also matches the dense naive product bitwise
        // (zero-skip only ever drops exact +0.0·x terms).
        assert_eq!(want_ab.as_slice(), matmul_naive(&xd, &bh).as_slice(), "d={density}");
        for &path in &KernelPath::available() {
            for &t in &THREADS {
                let sel = KernelCfg::new(path, t);
                let mut out = rand_mat::<f64>(m, r, &mut rng);
                sp_matmul_with(&xs, &bh, &mut out, sel);
                assert_eq!(out.as_slice(), want_ab.as_slice(), "{path:?} t={t} d={density} A*B");
                let mut out = rand_mat::<f64>(k, r, &mut rng);
                sp_matmul_at_b_with(&xs, &bw, &mut out, sel);
                assert_eq!(out.as_slice(), want_atb.as_slice(), "{path:?} t={t} d={density} At*B");
                let mut out = rand_mat::<f64>(m, r, &mut rng);
                sp_matmul_a_bt_with(&xs, &bt, &mut out, sel);
                assert_eq!(out.as_slice(), want_abt.as_slice(), "{path:?} t={t} d={density} A*Bt");
            }
        }
    }
}

/// A workspace warmed by a *threaded* run must stay bitwise neutral for
/// whatever runs through it next (peer pack buffers and panel sizing
/// leave no residue), including after switching back to serial scalar.
#[test]
fn warm_threaded_workspace_is_bitwise_neutral() {
    let mut rng = Rng::new(0x95);
    let mut warm = GemmWorkspace::<f64>::new();
    let a = rand_mat::<f64>(300, 200, &mut rng);
    let b = rand_mat::<f64>(200, 24, &mut rng);
    let mut c = Mat::zeros(300, 24);
    let best = KernelPath::best_available();
    matmul_packed_with(&a, &b, &mut c, &mut warm, KernelCfg::new(best, 4));
    for &m in &[1usize, 8, 65, 130] {
        for &n in &[1usize, 4, 9] {
            let k = 65;
            let a = rand_mat::<f64>(m, k, &mut rng);
            let b = rand_mat::<f64>(k, n, &mut rng);
            for sel in [KernelCfg::scalar(), KernelCfg::new(best, 2)] {
                let mut from_warm = Mat::zeros(m, n);
                matmul_packed_with(&a, &b, &mut from_warm, &mut warm, sel);
                let mut from_fresh = Mat::zeros(m, n);
                matmul_packed_with(&a, &b, &mut from_fresh, &mut GemmWorkspace::new(), sel);
                assert_eq!(
                    from_warm.as_slice(),
                    from_fresh.as_slice(),
                    "warm != fresh at {m}x{k}x{n} ({:?} t={})",
                    sel.path,
                    sel.threads
                );
            }
        }
    }
}

/// End to end: a distributed NMF on a 2×2 grid pinned to forced-scalar
/// serial is bitwise identical to the same job on every available SIMD
/// path with 4 intra-rank threads, for every update rule.
#[test]
fn dist_nmf_is_bitwise_invariant_across_kernel_selections() {
    let (m, n) = (26, 33);
    let mut rng = Rng::new(0x96);
    let x = {
        let a = Mat::<f64>::rand_uniform(m, 3, &mut rng);
        let b = Mat::<f64>::rand_uniform(3, n, &mut rng);
        matmul(&a, &b)
    };
    let mut sels = vec![KernelCfg::scalar()];
    for path in KernelPath::available() {
        sels.push(KernelCfg::new(path, 4));
    }
    for algo in [NmfAlgo::Bcd, NmfAlgo::Mu, NmfAlgo::Hals] {
        let grid = Grid2d::new(2, 2);
        let cfg = NmfConfig { rank: 3, max_iters: 25, algo, ..Default::default() };
        let mut reference: Option<(Vec<f64>, Vec<f64>)> = None;
        for &sel in &sels {
            let (x2, cfg2) = (x.clone(), cfg.clone());
            let outs = Comm::run(grid.size(), move |mut world| {
                let (i, j) = grid.coords(world.rank());
                let rows = dntt::dist::BlockDim::new(m, grid.pr);
                let cols = dntt::dist::BlockDim::new(n, grid.pc);
                let xb = Mat::from_fn(rows.size_of(i), cols.size_of(j), |a, b| {
                    x2[(rows.start_of(i) + a, cols.start_of(j) + b)]
                });
                let (mut row, mut col) = grid.make_subcomms(&mut world);
                let mut ws = NmfWorkspace::with_kernel(sel);
                dist_nmf_ws(
                    &xb, m, n, grid, &mut world, &mut row, &mut col, &NativeBackend, &cfg2,
                    &mut ws,
                )
                .unwrap()
            });
            let got = (outs[0].w.as_slice().to_vec(), outs[0].ht.as_slice().to_vec());
            match &reference {
                None => reference = Some(got),
                Some((w, ht)) => {
                    assert_eq!(
                        &got.0, w,
                        "{algo:?}: W differs under {:?} t={}",
                        sel.path, sel.threads
                    );
                    assert_eq!(
                        &got.1, ht,
                        "{algo:?}: H differs under {:?} t={}",
                        sel.path, sel.threads
                    );
                }
            }
        }
    }
}
