//! Integration: the observability layer (`dntt::obs`) is *bitwise
//! neutral* — arming the per-rank event rings and counters must not
//! perturb a single bit of the factors — and its outputs are themselves
//! deterministic and well-formed: deterministic counters replay exactly
//! across reruns, ring overflow degrades to counted drops (never a wrong
//! answer), and the exported Chrome trace parses with balanced spans and
//! one timeline per rank.

mod common;

use common::{ht_cfg, tt_cfg};
use dntt::coordinator::{run_job, Decomposition, InputSpec, JobConfig, JobReport};
use dntt::dist::ProcGrid;
use dntt::obs::{Ctr, TraceConfig, ALL_CTRS, TRACE_ENABLED};
use dntt::ttrain::SyntheticTt;
use dntt::util::json::Json;

/// The p = 4 TT job every test here runs, with tracing on or off.
fn tt_job(trace: Option<TraceConfig>) -> JobConfig {
    JobConfig {
        tt: tt_cfg(60),
        trace,
        ..JobConfig::new(
            InputSpec::Synthetic(SyntheticTt::new(vec![6, 6, 6], vec![2, 2], 3)),
            ProcGrid::new(vec![2, 1, 2]).unwrap(),
        )
    }
}

/// The matching p = 4 HT job.
fn ht_job(trace: Option<TraceConfig>) -> JobConfig {
    JobConfig {
        decomp: Decomposition::Ht,
        ht: ht_cfg(80),
        trace,
        ..JobConfig::new(
            InputSpec::Synthetic(SyntheticTt::new(vec![6, 6, 6], vec![2, 2], 3)),
            ProcGrid::new(vec![2, 1, 2]).unwrap(),
        )
    }
}

/// Every factor entry of `a` and `b`, bit for bit.
fn assert_bitwise_equal(a: &JobReport, b: &JobReport) {
    assert_eq!(a.ranks, b.ranks, "selected ranks diverged");
    match (a.output.tt(), b.output.tt()) {
        (Some(x), Some(y)) => {
            for (ca, cb) in x.tt.cores().iter().zip(y.tt.cores()) {
                for (u, v) in ca.as_slice().iter().zip(cb.as_slice()) {
                    assert_eq!(u.to_bits(), v.to_bits(), "TT core entry diverged: {u} vs {v}");
                }
            }
        }
        _ => {
            let x = a.output.ht().expect("both reports are HT");
            let y = b.output.ht().expect("both reports are HT");
            for (na, nb) in x.ht.nodes().iter().zip(y.ht.nodes()) {
                for (u, v) in na.mat().as_slice().iter().zip(nb.mat().as_slice()) {
                    assert_eq!(u.to_bits(), v.to_bits(), "HT node entry diverged: {u} vs {v}");
                }
            }
        }
    }
}

/// (a) TT: a traced run and an untraced run of the same job produce
/// bitwise-identical cores — instrumentation never touches factor data.
#[test]
fn tt_traced_run_is_bitwise_identical_to_untraced() {
    let plain = run_job(&tt_job(None)).unwrap();
    let traced = run_job(&tt_job(Some(TraceConfig::default()))).unwrap();
    assert!(plain.obs.is_none());
    assert!(traced.obs.is_some());
    assert_bitwise_equal(&plain, &traced);
}

/// (b) Same guarantee down the HT driver's per-node path.
#[test]
fn ht_traced_run_is_bitwise_identical_to_untraced() {
    let plain = run_job(&ht_job(None)).unwrap();
    let traced = run_job(&ht_job(Some(TraceConfig::default()))).unwrap();
    assert_bitwise_equal(&plain, &traced);
}

/// (c) Deterministic counters (everything except the wall-clock `*Ns`
/// tallies) replay exactly across independent reruns, per rank.
#[test]
fn deterministic_counters_replay_across_reruns() {
    if !TRACE_ENABLED {
        return; // --no-default-features build: nothing is recorded.
    }
    let a = run_job(&tt_job(Some(TraceConfig::default()))).unwrap();
    let b = run_job(&tt_job(Some(TraceConfig::default()))).unwrap();
    let (oa, ob) = (a.obs.unwrap(), b.obs.unwrap());
    assert_eq!(oa.rank_ids(), vec![0, 1, 2, 3]);
    assert_eq!(oa.rank_ids(), ob.rank_ids());
    let (pa, pb) = (oa.per_rank_counters(), ob.per_rank_counters());
    assert_eq!(pa.len(), pb.len());
    for ((ra, ca), (rb, cb)) in pa.iter().zip(&pb) {
        assert_eq!(ra, rb);
        for c in ALL_CTRS {
            if c.is_deterministic() {
                assert_eq!(
                    ca[c as usize], cb[c as usize],
                    "counter {c:?} diverged on rank {ra}"
                );
            }
        }
    }
    // The job actually exercised the layer: collectives, NMF iterations
    // and flops all registered.
    assert!(oa.total(Ctr::ArCalls) > 0);
    assert!(oa.total(Ctr::AgCalls) > 0);
    assert!(oa.total(Ctr::NmfIters) > 0);
    assert!(oa.total(Ctr::GemmFlops) > 0);
    assert!(oa.events_total() > 0);
}

/// (d) A deliberately tiny ring overflows by *counting* drops — the run
/// still completes, factors are still bitwise right, no span leaks.
#[test]
fn ring_overflow_counts_drops_and_stays_correct() {
    if !TRACE_ENABLED {
        return;
    }
    let plain = run_job(&tt_job(None)).unwrap();
    let tiny = run_job(&tt_job(Some(TraceConfig { ring_capacity: 8 }))).unwrap();
    assert_bitwise_equal(&plain, &tiny);
    let obs = tiny.obs.unwrap();
    assert!(obs.dropped_total() > 0, "an 8-slot ring must overflow on this job");
    assert!(obs.events_total() <= 8 * obs.ranks.len() as u64);
    assert_eq!(obs.open_spans_total(), 0);
    // Counters are ring-independent: drops lose events, never tallies.
    let full = run_job(&tt_job(Some(TraceConfig::default()))).unwrap().obs.unwrap();
    for c in ALL_CTRS {
        if c.is_deterministic() {
            assert_eq!(obs.total(c), full.total(c), "counter {c:?} depends on ring size");
        }
    }
}

/// (e) The exported Chrome trace round-trips through the JSON parser and
/// is structurally sound: one metadata lane per rank, only "M"/"X"
/// phases, X events with nonnegative durations, balanced spans.
#[test]
fn chrome_trace_export_is_well_formed() {
    if !TRACE_ENABLED {
        return;
    }
    let rep = run_job(&tt_job(Some(TraceConfig::default()))).unwrap();
    let obs = rep.obs.as_ref().unwrap();
    assert_eq!(obs.open_spans_total(), 0, "clean run must close every span");
    let text = obs.chrome_trace_json().to_pretty();
    let parsed = Json::parse(&text).expect("exported trace must parse");
    assert_eq!(parsed.get("otherData").get("format").as_str(), Some("dntt-trace-v1"));
    let events = parsed.get("traceEvents").as_arr().expect("traceEvents array");
    let mut lanes = std::collections::BTreeSet::new();
    let mut x_events = 0usize;
    for ev in events {
        let ph = ev.get("ph").as_str().expect("every event has a phase");
        assert!(ph == "M" || ph == "X", "unexpected phase {ph}");
        let tid = ev.get("tid").as_usize().expect("every event has a tid");
        if ph == "M" {
            lanes.insert(tid);
        } else {
            x_events += 1;
            assert!(ev.get("ts").as_f64().expect("ts") >= 0.0);
            assert!(ev.get("dur").as_f64().expect("dur") >= 0.0);
            assert!(lanes.contains(&tid), "X event on rank {tid} without a timeline lane");
        }
    }
    // One timeline per rank of the 2x1x2 grid, all of them populated.
    assert_eq!(lanes.len(), 4);
    assert_eq!(x_events as u64, obs.events_total());
    // The metrics envelope rides the same report and stays versioned.
    let env = Json::parse(&rep.metrics_json().to_string()).unwrap();
    assert_eq!(env.get("format").as_str(), Some("dntt-metrics-v1"));
    assert!(env.get("counters").get("totals").as_obj().is_some());
}
