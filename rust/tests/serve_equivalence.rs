//! Tier-1: serve-layer equivalence — the batched query engine must be
//! *bitwise* indistinguishable from dense reconstruction.
//!
//! The contract under test (DESIGN.md §2.9): for every point, fiber and
//! slice query, [`dntt::serve::TtHandle`] / [`dntt::serve::HtHandle`]
//! produce the exact f64 bits of `reconstruct().get(idx)`, for sorted,
//! unsorted and duplicated batches, on fresh and warm workspaces, and
//! across a `dntt-tt-v1` save→load round trip. Rounding respects its ε
//! and rank budgets; structurally damaged artifacts surface as
//! [`DnttError::Artifact`], never as panics or silent zeros.

mod common;

use common::{assert_close_slices, unique_temp_dir};
use dntt::error::DnttError;
use dntt::linalg::Mat;
use dntt::serve::{
    truncate, tt_contract_all, tt_contract_matrix, tt_contract_vec, HtHandle, HtQueryWorkspace,
    QueryWorkspace, TtHandle,
};
use dntt::tensor::io::{load_artifact, save_artifact, Artifact};
use dntt::tensor::{DenseTensor, HtNode, HtTensor, TTensor};
use dntt::tensor::ht::DimTree;
use dntt::util::rng::Rng;

// --- Fixtures -------------------------------------------------------------
//
// Small enough that every matmul in `reconstruct()` stays on the blocked
// (non-packed) GEMM path, which is the op sequence the serve hot loops
// replay fma-for-fma; zero injection exercises the zero-skip branches on
// both sides.

/// Non-negative value with exact zeros at ~30% density.
fn sparse_val(rng: &mut Rng) -> f64 {
    if rng.uniform() < 0.3 {
        0.0
    } else {
        0.25 + rng.uniform()
    }
}

/// Hand-built TT over `[4, 5, 3]` with internal ranks `[2, 3]` and
/// injected exact zeros.
fn tt_fixture() -> TTensor<f64> {
    let mut rng = Rng::new(11);
    let cores = vec![
        Mat::from_fn(4, 2, |_, _| sparse_val(&mut rng)),
        Mat::from_fn(2 * 5, 3, |_, _| sparse_val(&mut rng)),
        Mat::from_fn(3 * 3, 1, |_, _| sparse_val(&mut rng)),
    ];
    TTensor::new(vec![4, 5, 3], cores).unwrap()
}

/// Hand-built HT over `[3, 4, 2, 5]` with every non-root edge rank 2 and
/// injected exact zeros.
fn ht_fixture() -> HtTensor<f64> {
    let mut rng = Rng::new(13);
    let dims = vec![3usize, 4, 2, 5];
    let tree = DimTree::balanced(dims.len());
    let mut nodes = Vec::with_capacity(tree.len());
    for t in 0..tree.len() {
        let rt = if t == 0 { 1 } else { 2 };
        let node = tree.node(t);
        nodes.push(if node.children.is_some() {
            HtNode::Transfer(Mat::from_fn(2, 2 * rt, |_, _| sparse_val(&mut rng)))
        } else {
            HtNode::Leaf(Mat::from_fn(dims[node.lo], rt, |_, _| sparse_val(&mut rng)))
        });
    }
    HtTensor::new(dims, tree, nodes).unwrap()
}

/// Every multi-index of `dims`, shuffled deterministically and salted
/// with duplicates — the worst case for the sorted-prefix cache.
fn shuffled_queries(dims: &[usize], rng: &mut Rng) -> Vec<Vec<usize>> {
    let total: usize = dims.iter().product();
    let mut qs: Vec<Vec<usize>> =
        (0..total).map(|lin| dntt::tensor::dense::multi_index(dims, lin)).collect();
    for i in (1..qs.len()).rev() {
        qs.swap(i, rng.below(i + 1));
    }
    // Duplicate a handful of entries (appended, so they arrive unsorted).
    for _ in 0..5 {
        let pick = qs[rng.below(qs.len())].clone();
        qs.push(pick);
    }
    qs
}

fn flatten(qs: &[Vec<usize>]) -> Vec<usize> {
    qs.iter().flatten().copied().collect()
}

fn assert_bits(got: f64, want: f64, what: &str) {
    assert_eq!(got.to_bits(), want.to_bits(), "{what}: {got} vs {want}");
}

// --- TT: point / fiber / slice vs dense ----------------------------------

#[test]
fn tt_batch_matches_dense_bitwise() {
    for (tag, tt) in [
        ("zeros", tt_fixture()),
        ("dense", TTensor::rand_uniform(&[4, 5, 3], &[2, 3], &mut Rng::new(21)).unwrap()),
    ] {
        let full = tt.reconstruct();
        let handle = TtHandle::new(tt);
        let mut rng = Rng::new(31);
        let qs = shuffled_queries(handle.dims(), &mut rng);
        let mut ws = QueryWorkspace::new();
        let mut out = Vec::new();
        handle.batch_into(&flatten(&qs), &mut ws, &mut out).unwrap();
        assert_eq!(out.len(), qs.len());
        for (q, v) in qs.iter().zip(&out) {
            assert_bits(*v, full.get(q), &format!("tt/{tag} batch at {q:?}"));
        }
    }
}

#[test]
fn tt_fiber_and_slice_match_dense_bitwise() {
    let tt = tt_fixture();
    let full = tt.reconstruct();
    let dims = tt.dims().to_vec();
    let handle = TtHandle::new(tt);
    let mut ws = QueryWorkspace::new();
    let anchor = vec![2usize, 3, 1];
    for mode in 0..dims.len() {
        let fib = handle.fiber(mode, &anchor, &mut ws).unwrap();
        assert_eq!(fib.len(), dims[mode]);
        for (k, v) in fib.iter().enumerate() {
            let mut idx = anchor.clone();
            idx[mode] = k;
            assert_bits(*v, full.get(&idx), &format!("tt fiber mode {mode} at {idx:?}"));
        }
        for index in 0..dims[mode] {
            let sl = handle.slice(mode, index, &mut ws).unwrap();
            let rest: Vec<usize> =
                (0..dims.len()).filter(|&m| m != mode).map(|m| dims[m]).collect();
            assert_eq!(sl.dims(), &rest[..]);
            for (lin, v) in sl.as_slice().iter().enumerate() {
                let mut idx = dntt::tensor::dense::multi_index(&rest, lin);
                idx.insert(mode, index);
                assert_bits(*v, full.get(&idx), &format!("tt slice {mode}={index} at {idx:?}"));
            }
        }
    }
}

// --- HT: point / fiber / slice vs dense ----------------------------------

#[test]
fn ht_batch_matches_dense_bitwise() {
    for (tag, ht) in [
        ("zeros", ht_fixture()),
        ("dense", HtTensor::rand_uniform(&[3, 4, 2, 5], 2, &mut Rng::new(23)).unwrap()),
    ] {
        let full = ht.reconstruct();
        let handle = HtHandle::new(ht);
        let mut rng = Rng::new(37);
        let qs = shuffled_queries(handle.dims(), &mut rng);
        let mut ws = HtQueryWorkspace::new();
        let mut out = Vec::new();
        handle.batch_into(&flatten(&qs), &mut ws, &mut out).unwrap();
        for (q, v) in qs.iter().zip(&out) {
            assert_bits(*v, full.get(q), &format!("ht/{tag} batch at {q:?}"));
        }
    }
}

#[test]
fn ht_fiber_and_slice_match_dense_bitwise() {
    let ht = ht_fixture();
    let full = ht.reconstruct();
    let dims = ht.dims().to_vec();
    let handle = HtHandle::new(ht);
    let mut ws = HtQueryWorkspace::new();
    let anchor = vec![1usize, 2, 0, 4];
    for mode in 0..dims.len() {
        let fib = handle.fiber(mode, &anchor, &mut ws).unwrap();
        for (k, v) in fib.iter().enumerate() {
            let mut idx = anchor.clone();
            idx[mode] = k;
            assert_bits(*v, full.get(&idx), &format!("ht fiber mode {mode} at {idx:?}"));
        }
        let sl = handle.slice(mode, anchor[mode], &mut ws).unwrap();
        let rest: Vec<usize> = (0..dims.len()).filter(|&m| m != mode).map(|m| dims[m]).collect();
        assert_eq!(sl.dims(), &rest[..]);
        for (lin, v) in sl.as_slice().iter().enumerate() {
            let ridx = dntt::tensor::dense::multi_index(&rest, lin);
            let mut idx = ridx.clone();
            idx.insert(mode, anchor[mode]);
            assert_bits(*v, full.get(&idx), &format!("ht slice mode {mode} at {idx:?}"));
        }
    }
}

// --- Workspace reuse ------------------------------------------------------

#[test]
fn warm_workspace_is_stable_and_bitwise_neutral() {
    let tt = tt_fixture();
    let handle = TtHandle::new(tt);
    let mut rng = Rng::new(41);
    let queries = flatten(&shuffled_queries(handle.dims(), &mut rng));
    let mut ws = QueryWorkspace::new();
    let (mut cold, mut warm) = (Vec::new(), Vec::new());
    handle.batch_into(&queries, &mut ws, &mut cold).unwrap();
    let cap = ws.capacity_bytes();
    for _ in 0..3 {
        handle.batch_into(&queries, &mut ws, &mut warm).unwrap();
        assert_eq!(ws.capacity_bytes(), cap, "warm TT batches must not reallocate");
        assert_eq!(
            cold.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            warm.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "warm TT batch must be bitwise identical to cold"
        );
    }

    let ht = ht_fixture();
    let hh = HtHandle::new(ht);
    let hqueries = flatten(&shuffled_queries(hh.dims(), &mut rng));
    let mut hws = HtQueryWorkspace::new();
    let (mut hcold, mut hwarm) = (Vec::new(), Vec::new());
    hh.batch_into(&hqueries, &mut hws, &mut hcold).unwrap();
    let hcap = hws.capacity_bytes();
    for _ in 0..3 {
        hh.batch_into(&hqueries, &mut hws, &mut hwarm).unwrap();
        assert_eq!(hws.capacity_bytes(), hcap, "warm HT batches must not reallocate");
        assert_eq!(
            hcold.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            hwarm.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "warm HT batch must be bitwise identical to cold"
        );
    }
}

// --- Rounding -------------------------------------------------------------

#[test]
fn truncate_respects_eps_and_rank_budget() {
    let mut rng = Rng::new(43);
    let tt = TTensor::<f64>::rand_uniform(&[6, 6, 6], &[4, 4], &mut rng).unwrap();
    let full = tt.reconstruct();
    let d = tt.dims().len();

    // Oseledets: per-stage eps ⇒ total relative error ≤ sqrt(d-1)·eps.
    for eps in [0.3, 0.05, 1e-10] {
        let r = truncate(&tt, eps, None).unwrap();
        assert!(
            r.rel_error(&full) <= eps * ((d - 1) as f64).sqrt() + 1e-9,
            "eps {eps}: rel error {} over budget",
            r.rel_error(&full)
        );
    }

    // A hard rank budget caps every internal rank, eps or no eps.
    for cap in [1usize, 2, 3] {
        let r = truncate(&tt, 0.0, Some(cap)).unwrap();
        assert!(r.ranks()[1..d].iter().all(|&rk| rk <= cap), "cap {cap}: ranks {:?}", r.ranks());
    }
}

// --- Artifact round trip + damage ----------------------------------------

#[test]
fn artifact_roundtrip_serves_bitwise_identically() {
    let dir = unique_temp_dir("serve_rt");
    std::fs::create_dir_all(&dir).unwrap();

    // TT: cores survive bitwise, so every query does too.
    let tt = tt_fixture();
    let path = dir.join("tt.dntt");
    save_artifact(&Artifact::Tt(tt.clone()), &path).unwrap();
    let Artifact::Tt(tt2) = load_artifact(&path).unwrap() else {
        panic!("kind sniffing returned the wrong artifact");
    };
    for (a, b) in tt.cores().iter().zip(tt2.cores()) {
        assert_eq!(a.as_slice(), b.as_slice(), "TT cores must round-trip bitwise");
    }
    let (ha, hb) = (TtHandle::new(tt), TtHandle::new(tt2));
    let mut rng = Rng::new(47);
    let queries = flatten(&shuffled_queries(ha.dims(), &mut rng));
    let (va, vb) = (ha.batch(&queries).unwrap(), hb.batch(&queries).unwrap());
    assert_eq!(
        va.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        vb.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "loaded TT must answer bitwise identically"
    );

    // HT: same contract through the kind-sniffing loader.
    let ht = ht_fixture();
    let hpath = dir.join("ht.dntt");
    save_artifact(&Artifact::Ht(ht.clone()), &hpath).unwrap();
    let Artifact::Ht(ht2) = load_artifact(&hpath).unwrap() else {
        panic!("kind sniffing returned the wrong artifact");
    };
    let (ga, gb) = (HtHandle::new(ht), HtHandle::new(ht2));
    let hqueries = flatten(&shuffled_queries(ga.dims(), &mut rng));
    let (wa, wb) = (ga.batch(&hqueries).unwrap(), gb.batch(&hqueries).unwrap());
    assert_eq!(
        wa.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        wb.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "loaded HT must answer bitwise identically"
    );

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn damaged_artifacts_are_typed_errors() {
    let dir = unique_temp_dir("serve_damage");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tt.dntt");
    save_artifact(&Artifact::Tt(tt_fixture()), &path).unwrap();
    let good = std::fs::read(&path).unwrap();

    // Flipped payload byte → CRC mismatch.
    let mut bad = good.clone();
    let mid = bad.len() / 2;
    bad[mid] ^= 0x5a;
    std::fs::write(&path, &bad).unwrap();
    assert!(
        matches!(load_artifact(&path), Err(DnttError::Artifact(_))),
        "corruption must be a typed artifact error"
    );

    // Truncation at several depths (header, payload, checksum).
    for keep in [3usize, 10, good.len() - 2] {
        std::fs::write(&path, &good[..keep]).unwrap();
        assert!(
            matches!(load_artifact(&path), Err(DnttError::Artifact(_))),
            "truncation to {keep} bytes must be a typed artifact error"
        );
    }

    // Wrong magic.
    let mut wrong = good.clone();
    wrong[0] = b'X';
    std::fs::write(&path, &wrong).unwrap();
    assert!(matches!(load_artifact(&path), Err(DnttError::Artifact(_))));

    // Missing file stays an I/O error — it is not a malformed artifact.
    assert!(matches!(load_artifact(&dir.join("absent.dntt")), Err(DnttError::Io(_))));

    std::fs::remove_dir_all(&dir).unwrap();
}

// --- Contractions vs dense references -------------------------------------

#[test]
fn contractions_match_dense_references() {
    let tt = tt_fixture();
    let full = tt.reconstruct();
    let dims = tt.dims().to_vec();
    let d = dims.len();
    let mut rng = Rng::new(53);

    // Full contraction with indicator vectors IS element lookup.
    let idx = [3usize, 1, 2];
    let indicators: Vec<Vec<f64>> = (0..d)
        .map(|m| (0..dims[m]).map(|i| if i == idx[m] { 1.0 } else { 0.0 }).collect())
        .collect();
    let picked = tt_contract_all(&tt, &indicators).unwrap();
    assert!((picked - full.get(&idx)).abs() < 1e-12);

    // General weights: compare against the explicit weighted sum.
    let vecs: Vec<Vec<f64>> =
        dims.iter().map(|&n| (0..n).map(|_| rng.uniform() - 0.5).collect()).collect();
    let got = tt_contract_all(&tt, &vecs).unwrap();
    let mut want = 0.0;
    for (lin, x) in full.as_slice().iter().enumerate() {
        let mi = dntt::tensor::dense::multi_index(&dims, lin);
        want += x * mi.iter().enumerate().map(|(m, &i)| vecs[m][i]).product::<f64>();
    }
    assert!((got - want).abs() < 1e-10 * (1.0 + want.abs()), "{got} vs {want}");

    // Single-mode vector contraction: the mode disappears; the data
    // matches a dense mode product with the 1×n row matrix (a size-1
    // mode changes dims, not the row-major layout).
    for mode in 0..d {
        let row = Mat::from_fn(1, dims[mode], |_, j| vecs[mode][j]);
        let want_t = full.mode_product(mode, &row);
        let got_t = tt_contract_vec(&tt, mode, &vecs[mode]).unwrap();
        let rest: Vec<usize> = (0..d).filter(|&m| m != mode).map(|m| dims[m]).collect();
        assert_eq!(got_t.dims(), &rest[..]);
        assert_close_slices(
            got_t.reconstruct().as_slice(),
            want_t.as_slice(),
            1e-10,
            &format!("tt_contract_vec mode {mode}"),
        );
    }

    // Mode-matrix contraction == dense mode product.
    for mode in 0..d {
        let u = Mat::<f64>::rand_uniform(2, dims[mode], &mut rng);
        let got_t = tt_contract_matrix(&tt, mode, &u).unwrap();
        assert_eq!(got_t.dims()[mode], 2);
        assert_close_slices(
            got_t.reconstruct().as_slice(),
            full.mode_product(mode, &u).as_slice(),
            1e-10,
            &format!("tt_contract_matrix mode {mode}"),
        );
    }

    // A 1-mode train cannot lose its only mode to a vector contraction.
    let one = TTensor::<f64>::new(vec![4], vec![Mat::from_fn(4, 1, |i, _| i as f64)]).unwrap();
    assert!(tt_contract_vec(&one, 0, &[1.0; 4]).is_err());
}

// --- DenseTensor round trip used above is itself exercised by slices ------

#[test]
fn slice_of_two_mode_train_is_a_vector() {
    // d = 2 boundary: a slice drops to a 1-D tensor.
    let mut rng = Rng::new(59);
    let tt = TTensor::<f64>::rand_uniform(&[4, 6], &[3], &mut rng).unwrap();
    let full = tt.reconstruct();
    let handle = TtHandle::new(tt);
    let mut ws = QueryWorkspace::new();
    let sl = handle.slice(0, 2, &mut ws).unwrap();
    assert_eq!(sl.dims(), &[6]);
    for (j, v) in sl.as_slice().iter().enumerate() {
        assert_bits(*v, full.get(&[2, j]), &format!("2-mode slice at j={j}"));
    }
    let _: DenseTensor<f64> = sl;
}
