//! Out-of-core integration suite: a dense input **larger than the
//! memory budget** must decompose successfully with the chunk-store's
//! peak-resident gauge under the budget, and every out-of-core path —
//! streamed Alg-1 reshapes, mmap-backed chunk reads, chunk-set file
//! ingest — must be **bitwise identical** to the all-resident reference
//! (DESIGN.md §2.12). Also: checkpoint/resume composes with
//! mmap-backed, budget-streamed jobs.

mod common;

use common::{
    assert_cores_bitwise, assert_ht_nodes_bitwise, ht_cfg_fixed, tt_cfg_fixed, unique_temp_dir,
};
use dntt::coordinator::{run_job, Decomposition, InputSpec, JobConfig, ResumeMode};
use dntt::dist::checkpoint::CheckpointPolicy;
use dntt::dist::chunkstore::{dist_reshape, Layout, SharedStore, SpillMode};
use dntt::dist::{Comm, ProcGrid};
use dntt::tensor::DenseTensor;
use dntt::ttrain::driver::extract_block;
use dntt::ttrain::SyntheticTt;
use dntt::util::rng::Rng;
use std::path::{Path, PathBuf};

/// 48·32·32·16 = 786 432 elements = 6 MiB of dense f64 — deliberately
/// larger than [`BUDGET`] so an all-resident run could not fit.
const DIMS: [usize; 4] = [48, 32, 32, 16];
/// The tiny out-of-core budget (4 MiB < the 6 MiB input).
const BUDGET: u64 = 4 << 20;

fn oo_grid() -> ProcGrid {
    ProcGrid::new(vec![2, 2, 1, 1]).unwrap()
}

/// Write the synthetic ground-truth tensor to disk as a dntt-chunks-v1
/// set (one chunk per rank of [`oo_grid`]) and return the directory.
fn chunk_set(tag: &str) -> PathBuf {
    let dir = unique_temp_dir(tag);
    let truth = SyntheticTt::new(DIMS.to_vec(), vec![4, 4, 4], 7);
    let cs = truth.write_chunks(&dir, &oo_grid()).unwrap();
    assert_eq!(cs.total_bytes(), (DIMS.iter().product::<usize>() * 8) as u64);
    dir
}

/// A fixed-rank TT job fed from an on-disk chunk set. `budget: None`
/// is the all-resident reference; `Some(b)` streams reshapes and
/// auto-upgrades the store to mmap-backed spill.
fn file_tt_job(dir: &Path, budget: Option<u64>) -> JobConfig {
    JobConfig {
        tt: tt_cfg_fixed(3, vec![2, 2, 2]),
        budget,
        check_error: false,
        ..JobConfig::new(InputSpec::from_chunks(dir).unwrap(), oo_grid())
    }
}

fn file_ht_job(dir: &Path, budget: Option<u64>) -> JobConfig {
    JobConfig {
        decomp: Decomposition::Ht,
        ht: ht_cfg_fixed(3, vec![2; 6]),
        budget,
        check_error: false,
        ..JobConfig::new(InputSpec::from_chunks(dir).unwrap(), oo_grid())
    }
}

/// The acceptance gate of the out-of-core milestone: a dense input
/// larger than the budget completes, the report carries the
/// peak-resident gauge, and the peak stayed under the budget (the
/// store was auto-upgraded to mmap-backed spill, so published chunks
/// page in on demand instead of pinning heap).
#[test]
fn budgeted_job_larger_than_budget_stays_under_budget() {
    let dir = chunk_set("oo_budget");
    let rep = run_job(&file_tt_job(&dir, Some(BUDGET))).unwrap();
    assert_eq!(rep.budget_bytes, Some(BUDGET));
    let peak = rep.peak_resident_bytes.expect("budgeted run must report its peak");
    assert!(peak > 0, "gauge never moved — nothing was accounted");
    assert!(
        peak <= BUDGET,
        "peak resident {peak} B exceeded the {BUDGET} B budget on a {} B input",
        DIMS.iter().product::<usize>() * 8
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Streamed ≡ resident, TT: the budgeted, mmap-backed, batch-streamed
/// run must reproduce the unbudgeted all-resident run bit for bit —
/// out-of-core is an execution strategy, never a numerics change. The
/// fingerprint ignores the budget for exactly this reason.
#[test]
fn streamed_tt_is_bitwise_identical_to_resident() {
    let dir = chunk_set("oo_tt_eq");
    let resident_job = file_tt_job(&dir, None);
    let streamed_job = file_tt_job(&dir, Some(BUDGET));
    assert_eq!(
        resident_job.fingerprint(),
        streamed_job.fingerprint(),
        "budget must be excluded from the job fingerprint"
    );
    let resident = run_job(&resident_job).unwrap();
    let streamed = run_job(&streamed_job).unwrap();
    assert_cores_bitwise(
        resident.output.tt().unwrap(),
        streamed.output.tt().unwrap(),
        "streamed vs resident TT",
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Streamed ≡ resident, HT: same proof through the hierarchical-Tucker
/// driver (its reshapes ride the same budgeted `dist_reshape_x` path).
#[test]
fn streamed_ht_is_bitwise_identical_to_resident() {
    let dir = chunk_set("oo_ht_eq");
    let resident = run_job(&file_ht_job(&dir, None)).unwrap();
    let streamed = run_job(&file_ht_job(&dir, Some(BUDGET))).unwrap();
    assert_ht_nodes_bitwise(
        resident.output.ht().unwrap(),
        streamed.output.ht().unwrap(),
        "streamed vs resident HT",
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The mmap read path itself: one distReshape through an
/// `SpillMode::Mmap` store must hand every rank the same bits as the
/// all-in-memory store. (Mapped reads are zero-copy *and* zero-cost on
/// the resident gauge — the data had better be identical.)
#[test]
fn mmap_reshape_matches_memory_reshape_bitwise() {
    let mut rng = Rng::new(23);
    let dims = vec![6, 4, 4, 2];
    let t = DenseTensor::<f64>::rand_uniform(&dims, &mut rng);
    let grid = ProcGrid::new(vec![2, 2, 1, 1]).unwrap();
    let g2 = grid.to_2d();
    let (m, n) = (6, 32);

    let run = |spill: SpillMode| {
        let store = SharedStore::new(spill);
        let stats = std::sync::Arc::clone(store.stats());
        let (t, grid, dims) = (t.clone(), grid.clone(), dims.clone());
        let blocks = Comm::run(4, move |mut world| {
            let my = extract_block(&t, &grid, world.rank());
            let layout = Layout::TensorGrid { dims: dims.clone(), grid: grid.dims().to_vec() };
            dist_reshape(&mut world, &store, "x", &layout, my, m, n, g2).unwrap()
        });
        (blocks, stats)
    };

    let (mem_blocks, _) = run(SpillMode::Memory);
    let dir = unique_temp_dir("oo_mmap");
    let (map_blocks, map_stats) = run(SpillMode::Mmap(dir.clone()));
    for (rank, (a, b)) in mem_blocks.iter().zip(&map_blocks).enumerate() {
        assert_eq!(
            a.as_slice(),
            b.as_slice(),
            "rank {rank}: mmap-backed reshape must be bitwise identical"
        );
    }
    // Mapped publishes spill to disk: the mmap store's resident peak is
    // strictly below the in-memory footprint of the published chunks.
    let dense_bytes = (dims.iter().product::<usize>() * 8) as u64;
    assert!(
        map_stats.peak_resident_bytes() < dense_bytes,
        "mmap store pinned {} B resident for a {} B tensor — nothing was spilled",
        map_stats.peak_resident_bytes(),
        dense_bytes
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Checkpoint/resume composes with out-of-core: a budgeted mmap-backed
/// job snapshots stages like any other, and `--resume auto` replays it
/// to the same bits. (The snapshot codec reads adopted chunk files
/// through the same spill byte format the store writes.)
#[test]
fn checkpoint_resume_replays_budgeted_file_job_bitwise() {
    let dir = chunk_set("oo_ckpt");
    let ckpt = unique_temp_dir("oo_ckpt_snap");
    let job = |resume| JobConfig {
        checkpoint: Some(CheckpointPolicy::new(ckpt.clone())),
        resume,
        ..file_tt_job(&dir, Some(BUDGET))
    };
    let first = run_job(&job(ResumeMode::Off)).unwrap();
    let replay = run_job(&job(ResumeMode::Auto)).unwrap();
    assert_cores_bitwise(
        first.output.tt().unwrap(),
        replay.output.tt().unwrap(),
        "resumed budgeted file job",
    );
    std::fs::remove_dir_all(&dir).unwrap();
    let _ = std::fs::remove_dir_all(&ckpt);
}
