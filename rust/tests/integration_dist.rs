//! Integration: the distributed substrate as a whole — collectives against
//! serial references, distReshape against the dense reshape semantics,
//! disk-spilled stores (including drop-time spill cleanup and its
//! `keep_spill` escape hatch), and the cost model's qualitative behaviour.

mod common;

use common::{chunk_files_in, unique_temp_dir};
use dntt::dist::chunkstore::{dist_reshape, Layout, SharedStore, SpillMode};
use dntt::dist::{BlockDim, Comm, CostModel, Grid2d, ProcGrid};
use dntt::tensor::DenseTensor;
use dntt::ttrain::driver::extract_block;
use dntt::util::rng::Rng;
use dntt::util::timer::Cat;

/// distReshape from a 4-D TensorGrid into the stage matrix must equal the
/// serial `reshape` (which in row-major is the identity on linear order).
#[test]
fn dist_reshape_matches_serial_4d() {
    let mut rng = Rng::new(10);
    let dims = vec![4, 6, 2, 3];
    let t = DenseTensor::<f64>::rand_uniform(&dims, &mut rng);
    let grid = ProcGrid::new(vec![2, 2, 1, 1]).unwrap();
    let g2 = grid.to_2d(); // 2x2
    let (m, n) = (4, 36);
    let serial = t.clone().reshape(&[m, n]).unwrap();

    let t2 = t.clone();
    let grid2 = grid.clone();
    let store = SharedStore::new(SpillMode::Memory);
    let blocks = Comm::run(4, move |mut world| {
        let my = extract_block(&t2, &grid2, world.rank());
        let layout =
            Layout::TensorGrid { dims: vec![4, 6, 2, 3], grid: grid2.dims().to_vec() };
        dist_reshape(&mut world, &store, "x", &layout, my, m, n, g2).unwrap()
    });
    let rows = BlockDim::new(m, 2);
    let cols = BlockDim::new(n, 2);
    for (rank, blk) in blocks.iter().enumerate() {
        let (i, j) = g2.coords(rank);
        for li in 0..blk.rows() {
            for lj in 0..blk.cols() {
                let want = serial.as_slice()
                    [(rows.start_of(i) + li) * n + cols.start_of(j) + lj];
                assert_eq!(blk[(li, lj)], want);
            }
        }
    }
}

/// The same reshape through a disk-backed store gives identical data and
/// records I/O bytes.
#[test]
fn dist_reshape_disk_spill_identical() {
    let mut rng = Rng::new(11);
    let dims = vec![4, 4, 4];
    let t = DenseTensor::<f64>::rand_uniform(&dims, &mut rng);
    let grid = ProcGrid::new(vec![2, 1, 2]).unwrap();
    let g2 = grid.to_2d();
    let dir = unique_temp_dir("it_spill");

    let run = |spill: SpillMode, t: DenseTensor<f64>, grid: ProcGrid| {
        let store = SharedStore::new(spill);
        Comm::run(4, move |mut world| {
            let my = extract_block(&t, &grid, world.rank());
            let layout =
                Layout::TensorGrid { dims: t.dims().to_vec(), grid: grid.dims().to_vec() };
            let out =
                dist_reshape(&mut world, &store, "x", &layout, my, 4, 16, g2).unwrap();
            (out, world.breakdown.bytes(Cat::Io))
        })
    };
    let mem = run(SpillMode::Memory, t.clone(), grid.clone());
    let disk = run(SpillMode::Disk(dir.clone()), t, grid);
    for ((a, _), (b, io_bytes)) in mem.iter().zip(disk.iter()) {
        assert_eq!(a.as_slice(), b.as_slice());
        assert!(*io_bytes > 0, "disk mode must record IO bytes");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Collectives compose: a row/col split of a 2-D grid partitions the world,
/// and world all_reduce == reduce over rows of reduced cols.
#[test]
fn grid_collectives_compose() {
    let grid = Grid2d::new(2, 3);
    let outs = Comm::run(6, move |mut world| {
        let v = (world.rank() + 1) as f64;
        let (mut row, mut col) = grid.make_subcomms(&mut world);
        let world_sum = world.all_reduce_scalar(v);
        let row_sum = row.all_reduce_scalar(v);
        let cross = col.all_reduce_scalar(row_sum);
        (world_sum, cross)
    });
    for (ws, cross) in outs {
        assert_eq!(ws, 21.0);
        assert_eq!(cross, 21.0, "col-reduce of row-reduces must equal world reduce");
    }
}

/// Cost model: strong-scaling comm time must grow with p at fixed volume,
/// and compute time is preserved.
#[test]
fn cost_model_qualitative() {
    let m = CostModel::default();
    let mut b = dntt::util::timer::Breakdown::new();
    b.add_secs(Cat::MatMul, 1.0);
    b.add_secs(Cat::AllReduce, 0.001);
    b.add_bytes(Cat::AllReduce, 64 << 20);
    let t16 = m.model_breakdown(&b, 16);
    let t256 = m.model_breakdown(&b, 256);
    assert_eq!(t16.secs(Cat::MatMul), 1.0);
    assert!(t256.comm_secs() > t16.comm_secs());
}

/// Dropping a store deletes the spill files of every array still stored
/// (an erroring job must not litter the spill directory); the
/// `keep_spill` escape hatch preserves them for post-mortems.
#[test]
fn store_drop_cleans_spill_files_unless_kept() {
    let l = Layout::MatGrid { m: 2, n: 2, pr: 1, pc: 1 };
    // Default: cleanup on drop.
    let dir = unique_temp_dir("drop_clean");
    {
        let store = SharedStore::new(SpillMode::Disk(dir.clone()));
        store.publish("a", &l, 0, vec![1.0; 4]).unwrap();
        store.publish("b", &l, 0, vec![2.0; 4]).unwrap();
        assert_eq!(chunk_files_in(&dir), 2);
        // `a` is never removed by the "job" — drop must clean it up.
        store.remove("b");
        assert_eq!(chunk_files_in(&dir), 1);
    }
    assert_eq!(chunk_files_in(&dir), 0, "drop must delete remaining spill files");
    // Escape hatch: keep_spill leaves the files for inspection.
    let dir2 = unique_temp_dir("drop_keep");
    {
        let store = SharedStore::new(SpillMode::Disk(dir2.clone()));
        store.set_keep_spill(true);
        assert!(store.keep_spill());
        store.publish("a", &l, 0, vec![1.0; 4]).unwrap();
    }
    assert_eq!(chunk_files_in(&dir2), 1, "keep_spill must preserve spill files");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir2);
}

/// End to end: after a disk-spilled `run_job` the spill directory holds
/// no chunk files (the drivers remove arrays as they consume them, and
/// the store's drop sweeps anything left).
#[test]
fn disk_spill_job_leaves_spill_dir_empty() {
    use dntt::coordinator::{run_job, InputSpec, JobConfig};
    use dntt::nmf::NmfConfig;
    use dntt::ttrain::{SyntheticTt, TtConfig};
    let dir = unique_temp_dir("job_spill_empty");
    let job = JobConfig {
        tt: TtConfig {
            fixed_ranks: Some(vec![2, 2]),
            nmf: NmfConfig { max_iters: 10, ..Default::default() },
            ..Default::default()
        },
        spill: SpillMode::Disk(dir.clone()),
        ..JobConfig::new(
            InputSpec::Synthetic(SyntheticTt::new(vec![4, 4, 4], vec![2, 2], 3)),
            ProcGrid::new(vec![2, 1, 2]).unwrap(),
        )
    };
    run_job(&job).unwrap();
    assert_eq!(chunk_files_in(&dir), 0, "spill dir must be empty after the job");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Thread-rank worlds are reusable and deterministic across runs.
#[test]
fn comm_world_deterministic() {
    for _ in 0..3 {
        let sums = Comm::run(8, |mut c| {
            let mut v = vec![c.rank() as f64; 4];
            c.all_reduce_sum(&mut v);
            v[0]
        });
        assert!(sums.iter().all(|&s| s == 28.0));
    }
}
