//! Tucker format (core tensor + factor matrices).
//!
//! Used by the Fig-2 baselines: Tucker/HOOI and non-negative Tucker. The
//! storage count `O(d·n·r + r^d)` versus TT's `O(d·n·r²)` is exactly the
//! comparison the paper's background section makes.

use crate::error::{DnttError, Result};
use crate::linalg::{Mat, Scalar};
use crate::tensor::dense::DenseTensor;

/// Tucker decomposition: `A ≈ G ×_1 U1 ×_2 U2 … ×_d Ud` with core
/// `G: r_1×…×r_d` and factors `U_i: n_i × r_i`.
#[derive(Clone, Debug)]
pub struct Tucker<T: Scalar = f64> {
    pub core: DenseTensor<T>,
    pub factors: Vec<Mat<T>>,
}

impl<T: Scalar> Tucker<T> {
    pub fn new(core: DenseTensor<T>, factors: Vec<Mat<T>>) -> Result<Self> {
        if core.ndim() != factors.len() {
            return Err(DnttError::shape("Tucker: one factor per mode required"));
        }
        for (k, f) in factors.iter().enumerate() {
            if f.cols() != core.dims()[k] {
                return Err(DnttError::shape(format!(
                    "Tucker factor {k}: cols {} != core dim {}",
                    f.cols(),
                    core.dims()[k]
                )));
            }
        }
        Ok(Tucker { core, factors })
    }

    /// Tensor dimensions `n_i` of the represented tensor.
    pub fn dims(&self) -> Vec<usize> {
        self.factors.iter().map(|f| f.rows()).collect()
    }

    /// Multilinear ranks `r_i`.
    pub fn ranks(&self) -> &[usize] {
        self.core.dims()
    }

    /// Stored parameters: `Σ n_i·r_i + Π r_i`.
    pub fn num_params(&self) -> usize {
        self.factors.iter().map(|f| f.len()).sum::<usize>() + self.core.len()
    }

    /// Compression ratio `Π n_i / params`.
    pub fn compression_ratio(&self) -> f64 {
        let full: f64 = self.dims().iter().map(|&n| n as f64).product();
        full / self.num_params() as f64
    }

    /// Dense reconstruction via successive mode products.
    pub fn reconstruct(&self) -> DenseTensor<T> {
        let mut t = self.core.clone();
        for (k, u) in self.factors.iter().enumerate() {
            t = t.mode_product(k, u);
        }
        t
    }

    pub fn is_nonneg(&self) -> bool {
        self.core.is_nonneg() && self.factors.iter().all(|f| f.is_nonneg())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identity_factors_reconstruct_core() {
        let mut rng = Rng::new(1);
        let core = DenseTensor::<f64>::rand_uniform(&[3, 4, 2], &mut rng);
        let factors = vec![Mat::eye(3), Mat::eye(4), Mat::eye(2)];
        let t = Tucker::new(core.clone(), factors).unwrap();
        assert_eq!(t.reconstruct(), core);
        assert_eq!(t.dims(), vec![3, 4, 2]);
    }

    #[test]
    fn param_count() {
        let mut rng = Rng::new(2);
        let core = DenseTensor::<f64>::rand_uniform(&[2, 2, 2], &mut rng);
        let factors = vec![
            Mat::<f64>::rand_uniform(5, 2, &mut rng),
            Mat::<f64>::rand_uniform(6, 2, &mut rng),
            Mat::<f64>::rand_uniform(7, 2, &mut rng),
        ];
        let t = Tucker::new(core, factors).unwrap();
        assert_eq!(t.num_params(), 8 + 10 + 12 + 14);
        let full = 5.0 * 6.0 * 7.0;
        assert!((t.compression_ratio() - full / 44.0).abs() < 1e-12);
    }

    #[test]
    fn shape_validation() {
        let core = DenseTensor::<f64>::zeros(&[2, 2]);
        assert!(Tucker::new(core.clone(), vec![Mat::zeros(4, 2)]).is_err());
        assert!(Tucker::new(core, vec![Mat::zeros(4, 2), Mat::zeros(4, 3)]).is_err());
    }

    #[test]
    fn rank1_tucker_matches_outer_product() {
        let core = DenseTensor::<f64>::from_vec(&[1, 1], vec![2.0]).unwrap();
        let u = Mat::<f64>::from_vec(2, 1, vec![1.0, 3.0]);
        let v = Mat::<f64>::from_vec(2, 1, vec![5.0, 7.0]);
        let t = Tucker::new(core, vec![u, v]).unwrap();
        let full = t.reconstruct();
        assert_eq!(full.as_slice(), &[10.0, 14.0, 30.0, 42.0]);
    }
}
