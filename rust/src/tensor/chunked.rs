//! The **`dntt-chunks-v1`** on-disk chunked ingest format.
//!
//! The paper's premise is decomposing tensors too large for one node's
//! memory; pyDNTNK leans on zarr/Dask chunked storage for the same
//! reason. This module is our equivalent: a directory of per-chunk
//! files in the **existing spill byte formats** — dense chunks as raw
//! little-endian `f64`, sparse chunks as the
//! `[nnz: u64 | idx: u64 × nnz | vals: f64 × nnz]` record — plus a
//! `manifest.json` carrying shapes, chunk grid, and per-file CRC-32.
//!
//! Reusing the spill formats is the point: an ingest chunk file *is*
//! already a valid chunk-store spill file and a valid checkpoint block
//! file, so [`crate::dist::SharedStore`] **adopts** it in place
//! ([`crate::dist::TensorBlock::DiskDense`]) — no translation pass, no
//! heap copy — and checkpoint/restore round-trips through the same
//! bytes. See DESIGN.md §2.12.
//!
//! ```text
//! <dir>/manifest.json      — format tag, dims, grid, per-chunk meta
//! <dir>/chunk.<c>.bin      — chunk c under Layout::TensorGrid{dims,grid}
//! ```
//!
//! The chunk grid of a v1 chunk set must equal the processor grid of
//! the job that consumes it (chunk `c` feeds rank `c`); re-chunking is
//! a future extension. Writers stream one chunk at a time
//! ([`ChunkWriter`]), so generating a chunk set never needs the full
//! tensor resident — that is how `dntt datagen` writes a
//! larger-than-RAM synthetic input.

use crate::dist::chunkstore::{Layout, TensorBlock};
use crate::error::{DnttError, Result};
use crate::tensor::io::{crc32, f64s_to_le_bytes};
use crate::tensor::sparse::SparseChunk;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Format tag stamped in (and required of) every manifest.
pub const CHUNKS_FORMAT: &str = "dntt-chunks-v1";

/// Representation of one stored chunk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkKind {
    /// Raw little-endian `f64`, row-major over the chunk block.
    Dense,
    /// The sparse spill record (sorted indices over the same order).
    Sparse,
}

/// Per-chunk manifest entry.
#[derive(Clone, Debug)]
struct ChunkMeta {
    file: String,
    kind: ChunkKind,
    elems: usize,
    /// Stored nonzeros (sparse chunks only).
    nnz: Option<usize>,
    crc: u32,
}

impl ChunkMeta {
    fn expect_bytes(&self) -> u64 {
        match self.kind {
            ChunkKind::Dense => 8 * self.elems as u64,
            ChunkKind::Sparse => 8 * (1 + 2 * self.nnz.unwrap_or(0)) as u64,
        }
    }
}

fn manifest_err(msg: impl Into<String>) -> DnttError {
    DnttError::Artifact(format!("dntt-chunks-v1: {}", msg.into()))
}

fn fnv1a(bytes: impl Iterator<Item = u8>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// An opened, validated chunk set: the read side of the format.
pub struct ChunkSet {
    dir: PathBuf,
    dims: Vec<usize>,
    grid: Vec<usize>,
    chunks: Vec<ChunkMeta>,
}

impl ChunkSet {
    /// Open `<dir>/manifest.json` and validate it: format tag, dims/grid
    /// agreement, chunk count, per-chunk element counts against the
    /// implied [`Layout::TensorGrid`], and each chunk file's size
    /// against its byte format. Contents are *not* read here — CRC
    /// verification is the separate, full-read [`ChunkSet::verify`].
    pub fn open(dir: &Path) -> Result<ChunkSet> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            manifest_err(format!("cannot read {manifest_path:?}: {e}"))
        })?;
        let j = Json::parse(&text).map_err(|e| manifest_err(format!("bad manifest: {e}")))?;
        if j.get("format").as_str() != Some(CHUNKS_FORMAT) {
            return Err(manifest_err(format!(
                "format tag {:?} (expected {CHUNKS_FORMAT:?})",
                j.get("format").as_str().unwrap_or("<missing>")
            )));
        }
        let dims: Vec<usize> = j
            .get("dims")
            .as_arr()
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();
        let grid: Vec<usize> = j
            .get("grid")
            .as_arr()
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();
        if dims.is_empty() || dims.len() != grid.len() {
            return Err(manifest_err(format!(
                "dims {dims:?} and grid {grid:?} must be non-empty and equal length"
            )));
        }
        if dims.iter().any(|&d| d == 0) || grid.iter().any(|&g| g == 0) {
            return Err(manifest_err("zero extent in dims or grid"));
        }
        let layout = Layout::TensorGrid { dims: dims.clone(), grid: grid.clone() };
        let want_chunks = layout.num_chunks();
        let arr = j
            .get("chunks")
            .as_arr()
            .ok_or_else(|| manifest_err("missing chunks array"))?;
        if arr.len() != want_chunks {
            return Err(manifest_err(format!(
                "{} chunk entries for a {want_chunks}-chunk grid",
                arr.len()
            )));
        }
        let mut chunks = Vec::with_capacity(arr.len());
        for (c, e) in arr.iter().enumerate() {
            let file = e
                .get("file")
                .as_str()
                .ok_or_else(|| manifest_err(format!("chunk {c}: missing file")))?
                .to_string();
            if file.contains('/') || file.contains("..") {
                return Err(manifest_err(format!("chunk {c}: unsafe file name {file:?}")));
            }
            let kind = match e.get("kind").as_str() {
                Some("dense") => ChunkKind::Dense,
                Some("sparse") => ChunkKind::Sparse,
                other => {
                    return Err(manifest_err(format!("chunk {c}: bad kind {other:?}")))
                }
            };
            let elems = e
                .get("elems")
                .as_usize()
                .ok_or_else(|| manifest_err(format!("chunk {c}: missing elems")))?;
            if elems != layout.chunk_len(c) {
                return Err(manifest_err(format!(
                    "chunk {c}: {elems} elements, layout expects {}",
                    layout.chunk_len(c)
                )));
            }
            let nnz = match kind {
                ChunkKind::Dense => None,
                ChunkKind::Sparse => Some(
                    e.get("nnz")
                        .as_usize()
                        .ok_or_else(|| manifest_err(format!("chunk {c}: sparse without nnz")))?,
                ),
            };
            let crc = u32::from_str_radix(
                e.get("crc32").as_str().unwrap_or(""),
                16,
            )
            .map_err(|_| manifest_err(format!("chunk {c}: missing or bad crc32")))?;
            let meta = ChunkMeta { file, kind, elems, nnz, crc };
            let path = dir.join(&meta.file);
            let got = std::fs::metadata(&path)
                .map_err(|e| manifest_err(format!("chunk {c}: cannot stat {path:?}: {e}")))?
                .len();
            if got != meta.expect_bytes() {
                return Err(manifest_err(format!(
                    "chunk {c}: file {path:?} is {got} bytes, format expects {}",
                    meta.expect_bytes()
                )));
            }
            chunks.push(meta);
        }
        Ok(ChunkSet { dir: dir.to_path_buf(), dims, grid, chunks })
    }

    /// Tensor dimensions.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Chunk grid (one chunk per consuming rank).
    pub fn grid(&self) -> &[usize] {
        &self.grid
    }

    /// Number of chunks.
    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// The layout the chunks tile.
    pub fn layout(&self) -> Layout {
        Layout::TensorGrid { dims: self.dims.clone(), grid: self.grid.clone() }
    }

    /// Total dense element count.
    pub fn total_elems(&self) -> usize {
        self.dims.iter().product()
    }

    /// Total bytes of chunk files on disk.
    pub fn total_bytes(&self) -> u64 {
        self.chunks.iter().map(ChunkMeta::expect_bytes).sum()
    }

    /// Chunk `c` as a disk-adopting [`TensorBlock`]: the consuming rank
    /// publishes it into the chunk store without reading it to the heap.
    pub fn block(&self, c: usize) -> Result<TensorBlock> {
        let meta = self
            .chunks
            .get(c)
            .ok_or_else(|| manifest_err(format!("chunk {c} out of range")))?;
        let path = self.dir.join(&meta.file);
        Ok(match meta.kind {
            ChunkKind::Dense => TensorBlock::DiskDense { path, len: meta.elems },
            ChunkKind::Sparse => TensorBlock::DiskSparse {
                path,
                len: meta.elems,
                nnz: meta.nnz.unwrap_or(0),
            },
        })
    }

    /// Full-read integrity check of chunk `c` against its manifest
    /// CRC-32. Streams one chunk — callers loop `0..num_chunks()` for a
    /// whole-set check without ever holding two chunks.
    pub fn verify(&self, c: usize) -> Result<()> {
        let meta = self
            .chunks
            .get(c)
            .ok_or_else(|| manifest_err(format!("chunk {c} out of range")))?;
        let path = self.dir.join(&meta.file);
        let bytes = std::fs::read(&path)?;
        let got = crc32(&bytes);
        if got != meta.crc {
            return Err(manifest_err(format!(
                "chunk {c}: CRC mismatch in {path:?} ({got:08x} vs manifest {:08x})",
                meta.crc
            )));
        }
        Ok(())
    }

    /// Content identity of the chunk set: FNV-1a over the format tag,
    /// dims, grid, and every chunk's kind/shape/CRC. Two chunk sets
    /// with identical contents hash identically regardless of
    /// directory, so [`crate::coordinator::JobConfig::fingerprint`]
    /// stays content-addressed without re-reading the data.
    pub fn identity(&self) -> u64 {
        let mut desc = format!("{CHUNKS_FORMAT}|{:?}|{:?}", self.dims, self.grid);
        for m in &self.chunks {
            desc.push_str(&format!(
                "|{:?}:{}:{}:{:08x}",
                m.kind,
                m.elems,
                m.nnz.unwrap_or(0),
                m.crc
            ));
        }
        fnv1a(desc.bytes())
    }
}

/// The write side: stream chunks to disk one at a time, then commit the
/// manifest. Dropping a writer without [`ChunkWriter::finish`] leaves
/// no manifest — an interrupted write is an unreadable (pure-miss)
/// directory, never a half-valid chunk set.
pub struct ChunkWriter {
    dir: PathBuf,
    layout: Layout,
    dims: Vec<usize>,
    grid: Vec<usize>,
    chunks: Vec<Option<ChunkMeta>>,
}

impl ChunkWriter {
    /// Start a chunk set at `dir` (created if needed; an existing
    /// manifest there is an error — chunk sets are immutable once
    /// finished).
    pub fn create(dir: &Path, dims: &[usize], grid: &[usize]) -> Result<ChunkWriter> {
        if dims.is_empty() || dims.len() != grid.len() {
            return Err(DnttError::config(format!(
                "chunk writer: dims {dims:?} and grid {grid:?} must be non-empty and equal length"
            )));
        }
        if dims.iter().any(|&d| d == 0) || grid.iter().any(|&g| g == 0) {
            return Err(DnttError::config("chunk writer: zero extent in dims or grid"));
        }
        if dims.iter().zip(grid).any(|(&d, &g)| g > d) {
            return Err(DnttError::config(format!(
                "chunk writer: grid {grid:?} splits finer than dims {dims:?}"
            )));
        }
        std::fs::create_dir_all(dir)?;
        if dir.join("manifest.json").exists() {
            return Err(DnttError::config(format!(
                "chunk writer: {dir:?} already holds a finished chunk set"
            )));
        }
        let layout = Layout::TensorGrid { dims: dims.to_vec(), grid: grid.to_vec() };
        let n = layout.num_chunks();
        Ok(ChunkWriter {
            dir: dir.to_path_buf(),
            layout,
            dims: dims.to_vec(),
            grid: grid.to_vec(),
            chunks: (0..n).map(|_| None).collect(),
        })
    }

    fn put(&mut self, c: usize, bytes: &[u8], kind: ChunkKind, elems: usize, nnz: Option<usize>) -> Result<()> {
        if c >= self.chunks.len() {
            return Err(DnttError::config(format!(
                "chunk writer: chunk {c} out of range for {} chunks",
                self.chunks.len()
            )));
        }
        if elems != self.layout.chunk_len(c) {
            return Err(DnttError::shape(format!(
                "chunk writer: chunk {c} has {elems} elements, layout expects {}",
                self.layout.chunk_len(c)
            )));
        }
        let file = format!("chunk.{c}.bin");
        std::fs::write(self.dir.join(&file), bytes)?;
        self.chunks[c] = Some(ChunkMeta { file, kind, elems, nnz, crc: crc32(bytes) });
        Ok(())
    }

    /// Write chunk `c` from a dense row-major buffer.
    pub fn write_dense(&mut self, c: usize, data: &[f64]) -> Result<()> {
        self.put(c, &f64s_to_le_bytes(data), ChunkKind::Dense, data.len(), None)
    }

    /// Write chunk `c` from a sparse chunk (nnz-scaled file).
    pub fn write_sparse(&mut self, c: usize, data: &SparseChunk) -> Result<()> {
        self.put(
            c,
            &data.to_spill_bytes(),
            ChunkKind::Sparse,
            data.len(),
            Some(data.nnz()),
        )
    }

    /// Commit: every chunk must have been written. The manifest goes
    /// through a tmp-file + rename so a crash mid-commit leaves no
    /// `manifest.json` (an openable chunk set is always complete).
    pub fn finish(self) -> Result<ChunkSet> {
        let mut chunks = Vec::with_capacity(self.chunks.len());
        for (c, m) in self.chunks.iter().enumerate() {
            match m {
                Some(m) => chunks.push(m.clone()),
                None => {
                    return Err(DnttError::config(format!(
                        "chunk writer: chunk {c} was never written"
                    )))
                }
            }
        }
        let entries: Vec<Json> = chunks
            .iter()
            .map(|m| {
                let mut pairs = vec![
                    ("file", Json::Str(m.file.clone())),
                    (
                        "kind",
                        Json::Str(
                            match m.kind {
                                ChunkKind::Dense => "dense",
                                ChunkKind::Sparse => "sparse",
                            }
                            .to_string(),
                        ),
                    ),
                    ("elems", Json::Num(m.elems as f64)),
                    ("crc32", Json::Str(format!("{:08x}", m.crc))),
                ];
                if let Some(nnz) = m.nnz {
                    pairs.push(("nnz", Json::Num(nnz as f64)));
                }
                Json::obj(pairs)
            })
            .collect();
        let manifest = Json::obj(vec![
            ("format", Json::Str(CHUNKS_FORMAT.to_string())),
            ("dims", Json::arr_usize(&self.dims)),
            ("grid", Json::arr_usize(&self.grid)),
            ("chunks", Json::Arr(entries)),
        ]);
        let tmp = self.dir.join("manifest.json.tmp");
        let dst = self.dir.join("manifest.json");
        std::fs::write(&tmp, manifest.to_pretty())?;
        std::fs::rename(&tmp, &dst)?;
        Ok(ChunkSet { dir: self.dir, dims: self.dims, grid: self.grid, chunks })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("dntt_chunks_{tag}_{}", std::process::id()))
    }

    #[test]
    fn write_open_roundtrip_dense_and_sparse() {
        let dir = tmpdir("rt");
        let _ = std::fs::remove_dir_all(&dir);
        // dims [4, 3] on grid [2, 1]: two 6-element chunks.
        let mut w = ChunkWriter::create(&dir, &[4, 3], &[2, 1]).unwrap();
        let top: Vec<f64> = (0..6).map(|x| x as f64 * 0.25).collect();
        w.write_dense(0, &top).unwrap();
        let bottom = SparseChunk::new(6, vec![1, 4], vec![7.0, -8.0]).unwrap();
        w.write_sparse(1, &bottom).unwrap();
        let cs = w.finish().unwrap();
        assert_eq!(cs.dims(), &[4, 3]);
        assert_eq!(cs.num_chunks(), 2);
        assert_eq!(cs.total_elems(), 12);
        cs.verify(0).unwrap();
        cs.verify(1).unwrap();
        // Re-open from disk: identical metadata and identity.
        let again = ChunkSet::open(&dir).unwrap();
        assert_eq!(again.identity(), cs.identity());
        // Blocks adopt the files with the right shapes.
        match again.block(0).unwrap() {
            TensorBlock::DiskDense { len, .. } => assert_eq!(len, 6),
            _ => panic!("chunk 0 should be dense"),
        }
        match again.block(1).unwrap() {
            TensorBlock::DiskSparse { len, nnz, .. } => {
                assert_eq!((len, nnz), (6, 2));
            }
            _ => panic!("chunk 1 should be sparse"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn incomplete_or_corrupt_sets_are_rejected() {
        let dir = tmpdir("bad");
        let _ = std::fs::remove_dir_all(&dir);
        // No manifest yet → open fails (interrupted writer = pure miss).
        let mut w = ChunkWriter::create(&dir, &[2, 2], &[2, 1]).unwrap();
        w.write_dense(0, &[1.0, 2.0]).unwrap();
        assert!(ChunkSet::open(&dir).is_err());
        // Finishing with a missing chunk fails.
        assert!(w.finish().is_err());
        // Complete it properly.
        let mut w2 = ChunkWriter::create(&dir, &[2, 2], &[2, 1]).unwrap();
        w2.write_dense(0, &[1.0, 2.0]).unwrap();
        w2.write_dense(1, &[3.0, 4.0]).unwrap();
        let cs = w2.finish().unwrap();
        let id = cs.identity();
        // A second writer refuses to clobber a finished set.
        assert!(ChunkWriter::create(&dir, &[2, 2], &[2, 1]).is_err());
        // Flip a byte: size still matches, so open succeeds but verify
        // catches the corruption, and identity is unchanged (manifest-
        // derived).
        let path = dir.join("chunk.1.bin");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let cs2 = ChunkSet::open(&dir).unwrap();
        assert_eq!(cs2.identity(), id);
        assert!(cs2.verify(1).is_err());
        cs2.verify(0).unwrap();
        // Truncate the file: open now fails on the size check.
        std::fs::write(&path, &bytes[..8]).unwrap();
        assert!(ChunkSet::open(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn writer_validates_shapes_and_grid() {
        let dir = tmpdir("val");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(ChunkWriter::create(&dir.join("a"), &[4], &[2, 1]).is_err()); // length mismatch
        assert!(ChunkWriter::create(&dir.join("b"), &[], &[]).is_err()); // empty
        assert!(ChunkWriter::create(&dir.join("c"), &[2, 2], &[4, 1]).is_err()); // grid > dim
        let mut w = ChunkWriter::create(&dir.join("d"), &[4, 3], &[2, 1]).unwrap();
        assert!(w.write_dense(2, &[0.0; 6]).is_err()); // chunk out of range
        assert!(w.write_dense(0, &[0.0; 5]).is_err()); // wrong element count
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn identity_tracks_content_not_location() {
        let d1 = tmpdir("id1");
        let d2 = tmpdir("id2");
        let d3 = tmpdir("id3");
        for d in [&d1, &d2, &d3] {
            let _ = std::fs::remove_dir_all(d);
        }
        let write = |dir: &Path, scale: f64| {
            let mut w = ChunkWriter::create(dir, &[2, 2], &[2, 1]).unwrap();
            w.write_dense(0, &[1.0 * scale, 2.0]).unwrap();
            w.write_dense(1, &[3.0, 4.0]).unwrap();
            w.finish().unwrap()
        };
        let a = write(&d1, 1.0);
        let b = write(&d2, 1.0);
        let c = write(&d3, 2.0);
        assert_eq!(a.identity(), b.identity()); // same content, different dir
        assert_ne!(a.identity(), c.identity()); // different content
        for d in [&d1, &d2, &d3] {
            let _ = std::fs::remove_dir_all(d);
        }
    }
}
