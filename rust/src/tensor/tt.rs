//! Tensor-train (TT) format.
//!
//! `A ≈ G(1) ∘ G(2) ∘ … ∘ G(d)` with cores `G(i): r_{i-1} × n_i × r_i`,
//! `r_0 = r_d = 1` (Eq. 1–2 of the paper). Cores are stored as matrices of
//! shape `(r_{i-1}·n_i) × r_i` — exactly the `W` factors the NMF sweep
//! produces — with helpers for element access, full reconstruction, storage
//! accounting and the paper's compression ratio (Eq. 4).

use crate::error::{DnttError, Result};
use crate::linalg::gemm::matmul;
use crate::linalg::{Mat, Scalar};
use crate::tensor::dense::DenseTensor;

/// A tensor train: `cores[i]` holds core `i` flattened to
/// `(r_{i-1}·n_i) × r_i` (row-major over `(k_{i-1}, j_i)` pairs).
///
/// ```
/// use dntt::tensor::TTensor;
/// use dntt::util::rng::Rng;
///
/// let mut rng = Rng::new(7);
/// let tt = TTensor::<f64>::rand_uniform(&[3, 4, 5], &[2, 2], &mut rng).unwrap();
/// assert_eq!(tt.ranks(), &[1, 2, 2, 1]);
/// let full = tt.reconstruct();            // contract back to a dense tensor
/// assert_eq!(full.dims(), &[3, 4, 5]);
/// assert!(tt.rel_error(&full) < 1e-12);   // exact up to roundoff
/// assert!(tt.compression_ratio() > 1.0);
/// ```
#[derive(Clone, Debug)]
pub struct TTensor<T: Scalar = f64> {
    dims: Vec<usize>,
    ranks: Vec<usize>, // length d+1, ranks[0] = ranks[d] = 1
    cores: Vec<Mat<T>>,
}

impl<T: Scalar> TTensor<T> {
    /// Assemble from core matrices; validates the chain shapes.
    pub fn new(dims: Vec<usize>, cores: Vec<Mat<T>>) -> Result<Self> {
        if dims.len() != cores.len() || dims.is_empty() {
            return Err(DnttError::shape("TT: need one core per mode"));
        }
        let d = dims.len();
        let mut ranks = Vec::with_capacity(d + 1);
        ranks.push(1usize);
        for (i, core) in cores.iter().enumerate() {
            let r_prev = *ranks.last().unwrap();
            if core.rows() % (r_prev * dims[i]) != 0 && core.rows() != r_prev * dims[i] {
                return Err(DnttError::shape(format!(
                    "core {i}: rows {} != r_prev {} * n_i {}",
                    core.rows(),
                    r_prev,
                    dims[i]
                )));
            }
            if core.rows() != r_prev * dims[i] {
                return Err(DnttError::shape(format!(
                    "core {i}: rows {} != {}x{}",
                    core.rows(),
                    r_prev,
                    dims[i]
                )));
            }
            ranks.push(core.cols());
        }
        if *ranks.last().unwrap() != 1 {
            return Err(DnttError::shape("TT: final rank must be 1"));
        }
        Ok(TTensor { dims, ranks, cores })
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// TT ranks `r_0..r_d` (length d+1, both ends 1).
    pub fn ranks(&self) -> &[usize] {
        &self.ranks
    }

    pub fn cores(&self) -> &[Mat<T>] {
        &self.cores
    }

    /// Core `i` as a `(r_{i-1}·n_i) × r_i` matrix.
    pub fn core(&self, i: usize) -> &Mat<T> {
        &self.cores[i]
    }

    /// Number of stored parameters: `Σ r_{i-1}·n_i·r_i`.
    pub fn num_params(&self) -> usize {
        self.cores.iter().map(|c| c.len()).sum()
    }

    /// Compression ratio `Π n_i / Σ n_i·r_{i-1}·r_i` (Eq. 4) — against
    /// the *dense* element count.
    pub fn compression_ratio(&self) -> f64 {
        let full: f64 = self.dims.iter().map(|&n| n as f64).product();
        self.compression_ratio_vs(full)
    }

    /// Compression ratio against an explicit input storage size (in
    /// elements) — for sparse inputs pass the nnz, so the reported ratio
    /// reflects what was actually stored, not the dense bounding box.
    pub fn compression_ratio_vs(&self, input_elems: f64) -> f64 {
        input_elems / self.num_params() as f64
    }

    /// All cores elementwise non-negative (the nTT invariant).
    pub fn is_nonneg(&self) -> bool {
        self.cores.iter().all(|c| c.is_nonneg())
    }

    /// Evaluate a single element (Eq. 2): cost `O(d · r²)`.
    pub fn element(&self, idx: &[usize]) -> T {
        assert_eq!(idx.len(), self.dims.len());
        // v starts as the i1-th row of core 1 (1×r1) and is propagated.
        let mut v: Vec<T> = self.cores[0].row(idx[0]).to_vec();
        for (m, core) in self.cores.iter().enumerate().skip(1) {
            let r_prev = self.ranks[m];
            let r_next = self.ranks[m + 1];
            let mut out = vec![T::zero(); r_next];
            for (k, &vk) in v.iter().enumerate().take(r_prev) {
                if vk == T::zero() {
                    continue;
                }
                // Row (k, idx[m]) of the flattened core.
                let row = core.row(k * self.dims[m] + idx[m]);
                for (j, o) in out.iter_mut().enumerate() {
                    *o = row[j].fma(vk, *o);
                }
            }
            v = out;
        }
        debug_assert_eq!(v.len(), 1);
        v[0]
    }

    /// Full dense reconstruction `G(1)∘…∘G(d)` via a chain of matrix
    /// products: maintains `B: (n_1⋯n_m) × r_m` and multiplies by the next
    /// reshaped core. Cost `O(Π n · max r²)`, memory one full tensor.
    pub fn reconstruct(&self) -> DenseTensor<T> {
        // B ← core 1: n1 × r1.
        let mut b = self.cores[0].clone();
        for (m, core) in self.cores.iter().enumerate().skip(1) {
            let r_prev = self.ranks[m];
            let n_m = self.dims[m];
            let r_next = self.ranks[m + 1];
            // core as r_prev × (n_m·r_next): need B·Ĝ where Ĝ flattens (n_m,r_next).
            // cores[m] is (r_prev·n_m) × r_next row-major: entry ((k,j), r).
            // Reinterpret as r_prev × (n_m·r_next) — same memory layout.
            let g = core.clone().reshaped(r_prev, n_m * r_next);
            let prod = matmul(&b, &g); // (N_prev) × (n_m·r_next)
            let rows = prod.rows() * n_m;
            b = prod.reshaped(rows, r_next);
        }
        debug_assert_eq!(b.cols(), 1);
        let data = b.into_vec();
        DenseTensor::from_vec(&self.dims, data).expect("TT reconstruct shape")
    }

    /// Relative reconstruction error vs a reference tensor (Eq. 3).
    pub fn rel_error(&self, reference: &DenseTensor<T>) -> f64 {
        reference.rel_error(&self.reconstruct())
    }

    /// Generate a random TT with given dims/ranks, uniform [0,1) cores —
    /// the paper's §IV-A synthetic-data construction (before assembling).
    pub fn rand_uniform(dims: &[usize], inner_ranks: &[usize], rng: &mut crate::util::rng::Rng) -> Result<Self> {
        if inner_ranks.len() + 1 != dims.len() {
            return Err(DnttError::shape(format!(
                "need {} inner ranks for {} dims",
                dims.len() - 1,
                dims.len()
            )));
        }
        let mut ranks = vec![1usize];
        ranks.extend_from_slice(inner_ranks);
        ranks.push(1);
        let cores = (0..dims.len())
            .map(|i| Mat::rand_uniform(ranks[i] * dims[i], ranks[i + 1], rng))
            .collect();
        TTensor::new(dims.to_vec(), cores)
    }

    pub fn cast<U: Scalar>(&self) -> TTensor<U> {
        TTensor {
            dims: self.dims.clone(),
            ranks: self.ranks.clone(),
            cores: self.cores.iter().map(|c| c.cast()).collect(),
        }
    }
}

/// Compression ratio from dims + ranks without building a TT (Eq. 4).
pub fn compression_ratio(dims: &[usize], ranks: &[usize]) -> f64 {
    assert_eq!(ranks.len(), dims.len() + 1);
    let full: f64 = dims.iter().map(|&n| n as f64).product();
    let params: f64 =
        dims.iter().enumerate().map(|(i, &n)| (n * ranks[i] * ranks[i + 1]) as f64).sum();
    full / params
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn construction_validates_chain() {
        let dims = vec![3, 4];
        let good = vec![Mat::<f64>::zeros(3, 2), Mat::<f64>::zeros(8, 1)];
        assert!(TTensor::new(dims.clone(), good).is_ok());
        let bad = vec![Mat::<f64>::zeros(3, 2), Mat::<f64>::zeros(7, 1)];
        assert!(TTensor::new(dims.clone(), bad).is_err());
        let bad_end = vec![Mat::<f64>::zeros(3, 2), Mat::<f64>::zeros(8, 2)];
        assert!(TTensor::new(dims, bad_end).is_err());
    }

    #[test]
    fn element_matches_reconstruct() {
        check(601, |rng| {
            let d = 2 + rng.below(3);
            let dims: Vec<usize> = (0..d).map(|_| 2 + rng.below(4)).collect();
            let ranks: Vec<usize> = (0..d - 1).map(|_| 1 + rng.below(3)).collect();
            let tt = TTensor::<f64>::rand_uniform(&dims, &ranks, rng).unwrap();
            let full = tt.reconstruct();
            for _ in 0..5 {
                let idx: Vec<usize> = dims.iter().map(|&n| rng.below(n)).collect();
                let a = tt.element(&idx);
                let b = full.get(&idx);
                if (a - b).abs() > 1e-9 * (1.0 + b.abs()) {
                    return Err(format!("element {idx:?}: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rank_one_tt_is_outer_product() {
        // dims [2,2], ranks [1]: A[i,j] = u[i]·v[j].
        let u = Mat::<f64>::from_vec(2, 1, vec![2.0, 3.0]);
        let v = Mat::<f64>::from_vec(2, 1, vec![5.0, 7.0]);
        let tt = TTensor::new(vec![2, 2], vec![u, v]).unwrap();
        let full = tt.reconstruct();
        assert_eq!(full.as_slice(), &[10.0, 14.0, 15.0, 21.0]);
    }

    #[test]
    fn compression_ratio_formula() {
        // 32^4 with ranks (1,10,10,10,1): params = 32*10 + 10*32*10 + 10*32*10 + 10*32.
        let dims = [32usize; 4];
        let ranks = [1usize, 10, 10, 10, 1];
        let c = compression_ratio(&dims, &ranks);
        let params = 32 * 10 + 3200 + 3200 + 320;
        assert!((c - (32f64.powi(4) / params as f64)).abs() < 1e-9);
        let mut rng = Rng::new(1);
        let tt = TTensor::<f64>::rand_uniform(&dims, &ranks[1..4], &mut rng).unwrap();
        assert_eq!(tt.num_params(), params);
        assert!((tt.compression_ratio() - c).abs() < 1e-12);
    }

    #[test]
    fn compression_ratio_vs_counts_sparse_storage() {
        // 32^4, ranks (1,10,10,10,1): dense basis = 32^4, a 1%-dense input
        // stores only ~nnz elements — the honest ratio shrinks 100×.
        let dims = [32usize; 4];
        let mut rng = Rng::new(6);
        let tt = TTensor::<f64>::rand_uniform(&dims, &[10, 10, 10], &mut rng).unwrap();
        let dense_elems = 32f64.powi(4);
        assert!((tt.compression_ratio_vs(dense_elems) - tt.compression_ratio()).abs() < 1e-12);
        let nnz = dense_elems * 0.01;
        let honest = tt.compression_ratio_vs(nnz);
        assert!((honest - tt.compression_ratio() * 0.01).abs() < 1e-9);
        assert!(honest < tt.compression_ratio());
    }

    #[test]
    fn uniform_cores_nonneg_reconstruction_nonneg() {
        let mut rng = Rng::new(2);
        let tt = TTensor::<f64>::rand_uniform(&[3, 3, 3], &[2, 2], &mut rng).unwrap();
        assert!(tt.is_nonneg());
        assert!(tt.reconstruct().is_nonneg());
    }

    #[test]
    fn rel_error_of_exact_tt_is_zero() {
        let mut rng = Rng::new(3);
        let tt = TTensor::<f64>::rand_uniform(&[4, 5, 3], &[2, 3], &mut rng).unwrap();
        let full = tt.reconstruct();
        assert!(tt.rel_error(&full) < 1e-12);
    }

    #[test]
    fn ranks_recorded() {
        let mut rng = Rng::new(4);
        let tt = TTensor::<f64>::rand_uniform(&[4, 5, 6, 7], &[2, 3, 4], &mut rng).unwrap();
        assert_eq!(tt.ranks(), &[1, 2, 3, 4, 1]);
        assert_eq!(tt.dims(), &[4, 5, 6, 7]);
    }
}
