//! Dense d-dimensional tensor in row-major (C) order.
//!
//! The host-side container for whole tensors (synthetic generators, small
//! baselines, reconstruction checks). Large distributed tensors never
//! materialize through this type — they live in the chunk store — but the
//! semantics of `reshape`/`unfold` here define what the distributed
//! versions must agree with (and tests enforce that agreement).

use crate::error::{DnttError, Result};
use crate::linalg::{Mat, Scalar};
use crate::util::rng::Rng;

/// Dense tensor with shape `dims`, stored row-major (last index fastest).
#[derive(Clone, Debug, PartialEq)]
pub struct DenseTensor<T: Scalar = f64> {
    dims: Vec<usize>,
    data: Vec<T>,
}

/// Row-major linear index of `idx` within `dims`.
pub fn linear_index(dims: &[usize], idx: &[usize]) -> usize {
    debug_assert_eq!(dims.len(), idx.len());
    let mut lin = 0;
    for (d, i) in dims.iter().zip(idx.iter()) {
        debug_assert!(i < d);
        lin = lin * d + i;
    }
    lin
}

/// Inverse of [`linear_index`].
pub fn multi_index(dims: &[usize], mut lin: usize) -> Vec<usize> {
    let mut idx = vec![0; dims.len()];
    for k in (0..dims.len()).rev() {
        idx[k] = lin % dims[k];
        lin /= dims[k];
    }
    idx
}

impl<T: Scalar> DenseTensor<T> {
    pub fn zeros(dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        DenseTensor { dims: dims.to_vec(), data: vec![T::zero(); n] }
    }

    pub fn from_vec(dims: &[usize], data: Vec<T>) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(DnttError::shape(format!(
                "dims {:?} product {} != buffer len {}",
                dims,
                n,
                data.len()
            )));
        }
        Ok(DenseTensor { dims: dims.to_vec(), data })
    }

    /// Uniform [0,1) entries.
    pub fn rand_uniform(dims: &[usize], rng: &mut Rng) -> Self {
        let n: usize = dims.iter().product();
        DenseTensor { dims: dims.to_vec(), data: (0..n).map(|_| T::fromf(rng.uniform())).collect() }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }
    pub fn ndim(&self) -> usize {
        self.dims.len()
    }
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    pub fn as_slice(&self) -> &[T] {
        &self.data
    }
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.data
    }
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    pub fn get(&self, idx: &[usize]) -> T {
        self.data[linear_index(&self.dims, idx)]
    }
    pub fn set(&mut self, idx: &[usize], v: T) {
        self.data[linear_index(&self.dims, idx)] = v;
    }

    /// Reshape (row-major order preserved; zero-copy).
    pub fn reshape(self, dims: &[usize]) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != self.data.len() {
            return Err(DnttError::shape(format!(
                "cannot reshape {:?} ({} elems) to {:?} ({n} elems)",
                self.dims,
                self.data.len(),
                dims
            )));
        }
        Ok(DenseTensor { dims: dims.to_vec(), data: self.data })
    }

    /// Left unfolding after `k` modes: matrix of shape
    /// `(n_1⋯n_k) × (n_{k+1}⋯n_d)`. For row-major data this is zero-copy.
    ///
    /// The TT sweep (Alg 2) uses `k = 1` on the current remainder tensor.
    pub fn unfold_left(&self, k: usize) -> Mat<T> {
        assert!(k <= self.dims.len());
        let rows: usize = self.dims[..k].iter().product();
        let cols: usize = self.dims[k..].iter().product();
        Mat::from_vec(rows, cols, self.data.clone())
    }

    /// Mode-k unfolding in the Kolda–Bader sense: rows indexed by mode `k`,
    /// columns by the remaining modes in order (used by Tucker/HOOI).
    pub fn unfold_mode(&self, k: usize) -> Mat<T> {
        let d = self.dims.len();
        assert!(k < d);
        let nk = self.dims[k];
        let ncols = self.data.len() / nk;
        let mut out = Mat::zeros(nk, ncols);
        // Iterate all elements; compute (row=i_k, col=position among other modes).
        let mut idx = vec![0usize; d];
        for lin in 0..self.data.len() {
            // Column index: row-major order over dims without mode k.
            let mut col = 0;
            for (m, &i) in idx.iter().enumerate() {
                if m != k {
                    col = col * self.dims[m] + i;
                }
            }
            out[(idx[k], col)] = self.data[lin];
            // Increment row-major multi-index.
            for m in (0..d).rev() {
                idx[m] += 1;
                if idx[m] < self.dims[m] {
                    break;
                }
                idx[m] = 0;
            }
        }
        out
    }

    /// Inverse of [`unfold_mode`].
    pub fn fold_mode(mat: &Mat<T>, k: usize, dims: &[usize]) -> Self {
        let d = dims.len();
        assert!(k < d);
        assert_eq!(mat.rows(), dims[k]);
        let mut t = DenseTensor::zeros(dims);
        let mut idx = vec![0usize; d];
        for lin in 0..t.data.len() {
            let mut col = 0;
            for (m, &i) in idx.iter().enumerate() {
                if m != k {
                    col = col * dims[m] + i;
                }
            }
            t.data[lin] = mat[(idx[k], col)];
            for m in (0..d).rev() {
                idx[m] += 1;
                if idx[m] < dims[m] {
                    break;
                }
                idx[m] = 0;
            }
        }
        t
    }

    /// Mode-k product with a matrix: `(A ×_k U)` where `U: q × n_k`.
    pub fn mode_product(&self, k: usize, u: &Mat<T>) -> Self {
        assert_eq!(u.cols(), self.dims[k], "mode_product: dim mismatch");
        let unf = self.unfold_mode(k);
        let prod = crate::linalg::gemm::matmul(u, &unf);
        let mut new_dims = self.dims.clone();
        new_dims[k] = u.rows();
        Self::fold_mode(&prod, k, &new_dims)
    }

    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| x.tof() * x.tof()).sum::<f64>().sqrt()
    }

    /// Relative Frobenius error `‖self − other‖ / ‖self‖` (Eq. 3).
    pub fn rel_error(&self, other: &Self) -> f64 {
        assert_eq!(self.dims, other.dims);
        let diff: f64 = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| {
                let d = a.tof() - b.tof();
                d * d
            })
            .sum();
        diff.sqrt() / self.fro_norm().max(1e-300)
    }

    pub fn is_nonneg(&self) -> bool {
        self.data.iter().all(|&x| x >= T::zero())
    }

    /// Element-wise map in place.
    pub fn map_inplace(&mut self, f: impl Fn(T) -> T) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    pub fn cast<U: Scalar>(&self) -> DenseTensor<U> {
        DenseTensor {
            dims: self.dims.clone(),
            data: self.data.iter().map(|&x| U::fromf(x.tof())).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn linear_index_roundtrip() {
        check(501, |rng| {
            let d = 1 + rng.below(5);
            let dims: Vec<usize> = (0..d).map(|_| 1 + rng.below(6)).collect();
            let n: usize = dims.iter().product();
            let lin = rng.below(n);
            let idx = multi_index(&dims, lin);
            if linear_index(&dims, &idx) != lin {
                return Err(format!("roundtrip failed dims={dims:?} lin={lin}"));
            }
            Ok(())
        });
    }

    #[test]
    fn get_set() {
        let mut t = DenseTensor::<f64>::zeros(&[2, 3, 4]);
        t.set(&[1, 2, 3], 7.0);
        assert_eq!(t.get(&[1, 2, 3]), 7.0);
        assert_eq!(t.as_slice()[1 * 12 + 2 * 4 + 3], 7.0);
    }

    #[test]
    fn unfold_left_is_reshape() {
        let mut rng = Rng::new(1);
        let t = DenseTensor::<f64>::rand_uniform(&[3, 4, 5], &mut rng);
        let m = t.unfold_left(1);
        assert_eq!(m.shape(), (3, 20));
        assert_eq!(m.as_slice(), t.as_slice());
        let m2 = t.unfold_left(2);
        assert_eq!(m2.shape(), (12, 5));
    }

    #[test]
    fn unfold_fold_mode_roundtrip() {
        check(502, |rng| {
            let d = 2 + rng.below(3);
            let dims: Vec<usize> = (0..d).map(|_| 1 + rng.below(5)).collect();
            let t = DenseTensor::<f64>::rand_uniform(&dims, rng);
            for k in 0..d {
                let m = t.unfold_mode(k);
                let t2 = DenseTensor::fold_mode(&m, k, &dims);
                if t2 != t {
                    return Err(format!("mode {k} roundtrip failed for dims {dims:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mode_product_identity() {
        let mut rng = Rng::new(2);
        let t = DenseTensor::<f64>::rand_uniform(&[3, 4, 5], &mut rng);
        let i = Mat::<f64>::eye(4);
        let p = t.mode_product(1, &i);
        assert_eq!(p, t);
    }

    #[test]
    fn mode_product_shape_and_values() {
        // 2x2 tensor as matrix: mode-0 product == U * T.
        let t = DenseTensor::<f64>::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let u = Mat::<f64>::from_vec(1, 2, vec![1.0, 1.0]);
        let p = t.mode_product(0, &u);
        assert_eq!(p.dims(), &[1, 2]);
        assert_eq!(p.as_slice(), &[4.0, 6.0]);
    }

    #[test]
    fn rel_error_zero_for_identical() {
        let mut rng = Rng::new(3);
        let t = DenseTensor::<f64>::rand_uniform(&[4, 4, 4], &mut rng);
        assert_eq!(t.rel_error(&t.clone()), 0.0);
    }

    #[test]
    fn reshape_checks_size() {
        let t = DenseTensor::<f64>::zeros(&[2, 3]);
        assert!(t.clone().reshape(&[3, 2]).is_ok());
        assert!(t.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn from_vec_validates() {
        assert!(DenseTensor::<f64>::from_vec(&[2, 2], vec![0.0; 3]).is_err());
    }
}
