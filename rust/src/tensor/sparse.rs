//! Chunked sparse tensors: COO ingest → sorted per-chunk views.
//!
//! The paper motivates TT for "extra-large high-dimensional data"
//! (density, population, probability tensors) and real instances of those
//! are overwhelmingly sparse. This module is the ingest side of the
//! crate's sparse pipeline:
//!
//! * [`SparseTensor`] — an N-d COO container (sorted global row-major
//!   linear indices + values). Ingest **rejects duplicate coordinates**
//!   and drops explicit zeros, so `nnz` always counts structural
//!   nonzeros.
//! * [`SparseChunk`] — one chunk's view: a sorted sparse vector over the
//!   chunk's dense row-major order. This is the unit the chunk store
//!   ([`crate::dist::SharedStore`]) publishes and spills, and what
//!   [`SparseTensor::block_chunk`] extracts per rank under a
//!   `Layout::TensorGrid` partition.
//!
//! The matrix-shaped CSR format the NMF kernels consume lives in
//! [`crate::linalg::sparse`]; a [`SparseChunk`] of a stage matrix block
//! converts losslessly into it (both are sorted row-major coordinate
//! sets). See `rust/DESIGN.md` §2.7 for the full sparse-storage
//! contract.

use crate::dist::{BlockDim, ProcGrid};
use crate::error::{DnttError, Result};
use crate::tensor::DenseTensor;

/// A sparse vector over a dense row-major chunk of `len` elements:
/// strictly-increasing indices `idx` with matching nonzero `vals`.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseChunk {
    len: usize,
    idx: Vec<usize>,
    vals: Vec<f64>,
}

impl SparseChunk {
    /// Build from parallel index/value vectors. Indices must be strictly
    /// increasing (sorted, duplicate-free) and `< len`; explicit zero
    /// values are dropped.
    pub fn new(len: usize, idx: Vec<usize>, vals: Vec<f64>) -> Result<SparseChunk> {
        if idx.len() != vals.len() {
            return Err(DnttError::shape(format!(
                "sparse chunk: {} indices vs {} values",
                idx.len(),
                vals.len()
            )));
        }
        let mut prev: Option<usize> = None;
        for &i in &idx {
            if i >= len {
                return Err(DnttError::shape(format!(
                    "sparse chunk: index {i} out of range for length {len}"
                )));
            }
            if let Some(p) = prev {
                if i <= p {
                    return Err(DnttError::shape(format!(
                        "sparse chunk: indices not strictly increasing at {i} \
                         (duplicate coordinate?)"
                    )));
                }
            }
            prev = Some(i);
        }
        if vals.iter().any(|&v| v == 0.0) {
            let (idx, vals) = idx
                .into_iter()
                .zip(vals)
                .filter(|&(_, v)| v != 0.0)
                .unzip();
            return Ok(SparseChunk { len, idx, vals });
        }
        Ok(SparseChunk { len, idx, vals })
    }

    /// The all-zero chunk of `len` elements.
    pub fn empty(len: usize) -> SparseChunk {
        SparseChunk { len, idx: Vec::new(), vals: Vec::new() }
    }

    /// Sparsify a dense buffer (exact zeros dropped).
    pub fn from_dense(data: &[f64]) -> SparseChunk {
        let mut idx = Vec::new();
        let mut vals = Vec::new();
        for (i, &v) in data.iter().enumerate() {
            if v != 0.0 {
                idx.push(i);
                vals.push(v);
            }
        }
        SparseChunk { len: data.len(), idx, vals }
    }

    /// Logical (dense) length of the chunk.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the logical chunk has zero elements.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// `nnz / len` (1.0 for a zero-length chunk, which stores nothing).
    pub fn density(&self) -> f64 {
        if self.len == 0 {
            1.0
        } else {
            self.idx.len() as f64 / self.len as f64
        }
    }

    /// Sorted nonzero indices.
    pub fn idx(&self) -> &[usize] {
        &self.idx
    }

    /// Values matching [`SparseChunk::idx`].
    pub fn vals(&self) -> &[f64] {
        &self.vals
    }

    /// Element at dense position `i` (0.0 when not stored).
    pub fn get(&self, i: usize) -> f64 {
        debug_assert!(i < self.len);
        match self.idx.binary_search(&i) {
            Ok(k) => self.vals[k],
            Err(_) => 0.0,
        }
    }

    /// Densify.
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.len];
        for (&i, &v) in self.idx.iter().zip(&self.vals) {
            out[i] = v;
        }
        out
    }

    /// Visit the nonzeros with dense index in `[start, start + n)`, in
    /// ascending index order. `f` receives the *absolute* dense index.
    pub fn for_range(&self, start: usize, n: usize, mut f: impl FnMut(usize, f64)) {
        let lo = self.idx.partition_point(|&i| i < start);
        for k in lo..self.idx.len() {
            let i = self.idx[k];
            if i >= start + n {
                break;
            }
            f(i, self.vals[k]);
        }
    }

    /// Write the dense contents of `[start, start + dst.len())` into
    /// `dst` (zero-filled, then scattered).
    pub fn scatter_range(&self, start: usize, dst: &mut [f64]) {
        dst.fill(0.0);
        self.for_range(start, dst.len(), |i, v| dst[i - start] = v);
    }

    /// Squared Frobenius norm of the chunk.
    pub fn fro_norm_sq(&self) -> f64 {
        self.vals.iter().map(|&v| v * v).sum()
    }

    /// Decompose into `(len, idx, vals)`.
    pub fn into_parts(self) -> (usize, Vec<usize>, Vec<f64>) {
        (self.len, self.idx, self.vals)
    }

    /// Encode as the sparse spill record
    /// `[nnz: u64 | idx: u64 × nnz | vals: f64 × nnz]` (little-endian) —
    /// the one byte format shared by chunk-store spill files, checkpoint
    /// block files, and `dntt-chunks-v1` ingest chunks.
    pub fn to_spill_bytes(&self) -> Vec<u8> {
        let nnz = self.nnz();
        let mut bytes = Vec::with_capacity(8 * (1 + 2 * nnz));
        bytes.extend_from_slice(&(nnz as u64).to_le_bytes());
        for &i in &self.idx {
            bytes.extend_from_slice(&(i as u64).to_le_bytes());
        }
        for &v in &self.vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes
    }

    /// Decode a [`SparseChunk::to_spill_bytes`] record for a chunk of
    /// `len` logical elements, re-validating the invariants (sorted,
    /// in-range, duplicate-free indices) so a corrupt file surfaces as
    /// an error instead of silently wrong data.
    pub fn from_spill_bytes(len: usize, bytes: &[u8]) -> Result<SparseChunk> {
        if bytes.len() < 8 {
            return Err(DnttError::Artifact("sparse record shorter than its header".into()));
        }
        let nnz = u64::from_le_bytes(bytes[..8].try_into().unwrap()) as usize;
        if bytes.len() != 8 * (1 + 2 * nnz) {
            return Err(DnttError::Artifact(format!(
                "sparse record of {} bytes disagrees with nnz {nnz}",
                bytes.len()
            )));
        }
        let mut idx = Vec::with_capacity(nnz);
        for b in bytes[8..8 * (1 + nnz)].chunks_exact(8) {
            idx.push(u64::from_le_bytes(b.try_into().unwrap()) as usize);
        }
        let mut vals = Vec::with_capacity(nnz);
        for b in bytes[8 * (1 + nnz)..].chunks_exact(8) {
            vals.push(f64::from_le_bytes(b.try_into().unwrap()));
        }
        SparseChunk::new(len, idx, vals)
    }
}

/// An N-d sparse tensor in COO form, sorted by global row-major linear
/// index. The sparse analogue of [`DenseTensor`] for ingest and
/// blockwise distribution (it is never required to fit densified).
///
/// ```
/// use dntt::tensor::SparseTensor;
///
/// let t = SparseTensor::from_entries(
///     vec![4, 3],
///     &[(vec![0, 1], 2.0), (vec![3, 2], 5.0)],
/// ).unwrap();
/// assert_eq!(t.nnz(), 2);
/// assert_eq!(t.get(&[3, 2]), 5.0);
/// assert_eq!(t.get(&[1, 1]), 0.0);
/// // Duplicate coordinates are rejected, not silently aggregated.
/// assert!(SparseTensor::from_entries(
///     vec![4, 3],
///     &[(vec![0, 0], 1.0), (vec![0, 0], 2.0)],
/// ).is_err());
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct SparseTensor {
    dims: Vec<usize>,
    idx: Vec<usize>,
    vals: Vec<f64>,
}

impl SparseTensor {
    /// Build from `(linear index, value)` pairs (any order). Duplicate
    /// coordinates are rejected — aggregating duplicates silently would
    /// hide ingest bugs; callers that want accumulation must pre-combine.
    /// Explicit zeros are dropped after the duplicate check.
    pub fn new(dims: Vec<usize>, entries: Vec<(usize, f64)>) -> Result<SparseTensor> {
        let total: usize = dims.iter().product();
        let mut entries = entries;
        entries.sort_unstable_by_key(|&(i, _)| i);
        for pair in entries.windows(2) {
            if pair[0].0 == pair[1].0 {
                return Err(DnttError::shape(format!(
                    "sparse tensor: duplicate coordinate at linear index {}",
                    pair[0].0
                )));
            }
        }
        if let Some(&(last, _)) = entries.last() {
            if last >= total {
                return Err(DnttError::shape(format!(
                    "sparse tensor: linear index {last} out of range for dims {dims:?}"
                )));
            }
        }
        let (idx, vals) = entries.into_iter().filter(|&(_, v)| v != 0.0).unzip();
        Ok(SparseTensor { dims, idx, vals })
    }

    /// Build from multi-index coordinates.
    pub fn from_entries(dims: Vec<usize>, entries: &[(Vec<usize>, f64)]) -> Result<SparseTensor> {
        let mut lin = Vec::with_capacity(entries.len());
        for (gidx, v) in entries {
            if gidx.len() != dims.len() || gidx.iter().zip(&dims).any(|(&i, &d)| i >= d) {
                return Err(DnttError::shape(format!(
                    "sparse tensor: coordinate {gidx:?} invalid for dims {dims:?}"
                )));
            }
            lin.push((crate::tensor::dense::linear_index(&dims, gidx), *v));
        }
        SparseTensor::new(dims, lin)
    }

    /// Tensor dimensions.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Total (dense) element count.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    /// True for a zero-element tensor.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// `nnz / len`.
    pub fn density(&self) -> f64 {
        if self.len() == 0 {
            1.0
        } else {
            self.nnz() as f64 / self.len() as f64
        }
    }

    /// Element at multi-index `gidx` (0.0 when not stored).
    pub fn get(&self, gidx: &[usize]) -> f64 {
        let lin = crate::tensor::dense::linear_index(&self.dims, gidx);
        match self.idx.binary_search(&lin) {
            Ok(k) => self.vals[k],
            Err(_) => 0.0,
        }
    }

    /// Densify (small tensors / tests).
    pub fn to_dense(&self) -> DenseTensor<f64> {
        let mut data = vec![0.0; self.len()];
        for (&i, &v) in self.idx.iter().zip(&self.vals) {
            data[i] = v;
        }
        DenseTensor::from_vec(&self.dims, data).expect("consistent dims")
    }

    /// This rank's `Layout::TensorGrid` block as a sparse chunk: the
    /// nonzeros falling inside the block, re-indexed to the block's local
    /// row-major order. Global row-major order restricted to a block is
    /// still lexicographic in the (offset-shifted) multi-index, so the
    /// output is sorted by construction.
    pub fn block_chunk(&self, grid: &ProcGrid, rank: usize) -> SparseChunk {
        let d = self.dims.len();
        let coords = grid.coords(rank);
        let bds: Vec<BlockDim> = self
            .dims
            .iter()
            .zip(grid.dims())
            .map(|(&n, &p)| BlockDim::new(n, p))
            .collect();
        let lo: Vec<usize> = bds.iter().zip(&coords).map(|(b, &c)| b.start_of(c)).collect();
        let sz: Vec<usize> = bds.iter().zip(&coords).map(|(b, &c)| b.size_of(c)).collect();
        let total: usize = sz.iter().product();
        let mut idx = Vec::new();
        let mut vals = Vec::new();
        let mut gidx = vec![0usize; d];
        'next: for (&lin, &v) in self.idx.iter().zip(&self.vals) {
            let mut rem = lin;
            for k in (0..d).rev() {
                gidx[k] = rem % self.dims[k];
                rem /= self.dims[k];
            }
            let mut loc = 0usize;
            for k in 0..d {
                let within = gidx[k].wrapping_sub(lo[k]);
                if within >= sz[k] {
                    continue 'next;
                }
                loc = loc * sz[k] + within;
            }
            idx.push(loc);
            vals.push(v);
        }
        SparseChunk { len: total, idx, vals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ingest_validates_and_drops_zeros() {
        assert!(SparseChunk::new(4, vec![0, 2], vec![1.0]).is_err()); // len mismatch
        assert!(SparseChunk::new(4, vec![0, 4], vec![1.0, 2.0]).is_err()); // range
        assert!(SparseChunk::new(4, vec![2, 2], vec![1.0, 2.0]).is_err()); // duplicate
        assert!(SparseChunk::new(4, vec![2, 1], vec![1.0, 2.0]).is_err()); // unsorted
        let c = SparseChunk::new(4, vec![0, 1, 3], vec![1.0, 0.0, 2.0]).unwrap();
        assert_eq!(c.nnz(), 2); // explicit zero dropped
        assert_eq!(c.to_dense(), vec![1.0, 0.0, 0.0, 2.0]);
        assert_eq!(c.get(0), 1.0);
        assert_eq!(c.get(1), 0.0);
    }

    #[test]
    fn chunk_edge_cases() {
        // Empty chunk: zero nonzeros.
        let e = SparseChunk::empty(5);
        assert_eq!((e.len(), e.nnz()), (5, 0));
        assert_eq!(e.to_dense(), vec![0.0; 5]);
        assert_eq!(e.density(), 0.0);
        // Fully dense chunk round-trips.
        let data = vec![1.0, 2.0, 3.0];
        let f = SparseChunk::from_dense(&data);
        assert_eq!(f.density(), 1.0);
        assert_eq!(f.to_dense(), data);
        // Zero-length chunk.
        let z = SparseChunk::empty(0);
        assert!(z.is_empty());
        assert_eq!(z.density(), 1.0);
    }

    #[test]
    fn chunk_range_helpers() {
        let c = SparseChunk::from_dense(&[0.0, 1.0, 0.0, 2.0, 3.0, 0.0]);
        let mut seen = Vec::new();
        c.for_range(1, 3, |i, v| seen.push((i, v)));
        assert_eq!(seen, vec![(1, 1.0), (3, 2.0)]);
        let mut dst = [9.0; 3];
        c.scatter_range(2, &mut dst);
        assert_eq!(dst, [0.0, 2.0, 3.0]);
        assert_eq!(c.fro_norm_sq(), 1.0 + 4.0 + 9.0);
    }

    #[test]
    fn spill_record_roundtrips_and_validates() {
        let c = SparseChunk::new(6, vec![1, 3, 5], vec![1.5, -2.0, 4.0]).unwrap();
        let bytes = c.to_spill_bytes();
        assert_eq!(bytes.len(), 8 * 7);
        let back = SparseChunk::from_spill_bytes(6, &bytes).unwrap();
        assert_eq!(back, c);
        // Empty chunk: just the header.
        let e = SparseChunk::empty(4);
        assert_eq!(e.to_spill_bytes().len(), 8);
        assert_eq!(SparseChunk::from_spill_bytes(4, &e.to_spill_bytes()).unwrap(), e);
        // Corruption is detected: truncated, size/nnz mismatch, bad index.
        assert!(SparseChunk::from_spill_bytes(6, &bytes[..bytes.len() - 8]).is_err());
        assert!(SparseChunk::from_spill_bytes(6, &bytes[..4]).is_err());
        assert!(SparseChunk::from_spill_bytes(4, &bytes).is_err()); // idx 5 out of range
    }

    #[test]
    fn tensor_ingest_rejects_duplicates() {
        let err = SparseTensor::new(vec![2, 3], vec![(1, 1.0), (1, 2.0)]);
        assert!(err.is_err());
        // Duplicates are rejected even when one value is zero.
        let err = SparseTensor::from_entries(
            vec![2, 3],
            &[(vec![0, 1], 0.0), (vec![0, 1], 5.0)],
        );
        assert!(err.is_err());
        assert!(SparseTensor::new(vec![2, 3], vec![(6, 1.0)]).is_err()); // range
    }

    #[test]
    fn tensor_roundtrip_and_density() {
        let t = SparseTensor::from_entries(
            vec![2, 3],
            &[(vec![0, 1], 2.0), (vec![1, 2], 3.0), (vec![1, 0], 0.0)],
        )
        .unwrap();
        assert_eq!(t.nnz(), 2); // explicit zero dropped after dup check
        assert_eq!(t.get(&[0, 1]), 2.0);
        assert_eq!(t.get(&[1, 0]), 0.0);
        assert!((t.density() - 2.0 / 6.0).abs() < 1e-15);
        let d = t.to_dense();
        assert_eq!(d.as_slice(), &[0.0, 2.0, 0.0, 0.0, 0.0, 3.0]);
    }

    #[test]
    fn block_chunks_tile_the_tensor() {
        // 4x3 tensor on a 2x1 grid; nonzeros on both blocks.
        let t = SparseTensor::from_entries(
            vec![4, 3],
            &[
                (vec![0, 2], 1.0),
                (vec![1, 0], 2.0),
                (vec![2, 1], 3.0),
                (vec![3, 2], 4.0),
            ],
        )
        .unwrap();
        let grid = ProcGrid::new(vec![2, 1]).unwrap();
        let full = t.to_dense();
        for r in 0..2 {
            let chunk = t.block_chunk(&grid, r);
            assert_eq!(chunk.len(), 6);
            // Dense block extracted the classic way must agree.
            let want: Vec<f64> = (0..2)
                .flat_map(|i| (0..3).map(move |j| (i, j)))
                .map(|(i, j)| full.get(&[r * 2 + i, j]))
                .collect();
            assert_eq!(chunk.to_dense(), want);
        }
    }
}
