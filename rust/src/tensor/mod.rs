//! Tensor containers: dense N-d tensors, sparse COO tensors with chunked
//! views ([`sparse`]), the tensor-train format (the paper's output
//! representation), the hierarchical Tucker format (the second pyDNTNK
//! network, produced by `crate::ht`), the Tucker format (baselines),
//! and the on-disk chunked ingest format `dntt-chunks-v1` ([`chunked`])
//! for tensors too large to materialize.

pub mod chunked;
pub mod dense;
pub mod ht;
pub mod tt;
pub mod io;
pub mod sparse;
pub mod tucker;

pub use chunked::{ChunkKind, ChunkSet, ChunkWriter, CHUNKS_FORMAT};
pub use dense::DenseTensor;
pub use ht::{DimTree, HtNode, HtTensor};
pub use sparse::{SparseChunk, SparseTensor};
pub use tt::TTensor;
pub use tucker::Tucker;
