//! Tensor containers: dense N-d tensors, the tensor-train format (the
//! paper's output representation) and the Tucker format (baselines).

pub mod dense;
pub mod tt;
pub mod io;
pub mod tucker;

pub use dense::DenseTensor;
pub use tt::TTensor;
pub use tucker::Tucker;
