//! Binary persistence for tensors and tensor networks — the
//! **`dntt-tt-v1`** artifact codec.
//!
//! A decomposition is only useful if the compressed representation can be
//! stored and reloaded — this module gives the tensor formats a simple,
//! versioned, endian-stable container (`.dntt`):
//!
//! ```text
//! magic "DNTT" | u32 version (= 1) | u32 kind | payload | u32 CRC-32
//! kind 1 (TT):    u64 d | dims d×u64 | ranks (d+1)×u64 | cores f64 LE
//! kind 2 (dense): u64 d | dims d×u64 | elements f64 LE (row-major)
//! kind 3 (HT):    u64 d | dims d×u64 | u64 nodes | per node
//!                 (lo, hi, has_children, lc, rc) ×u64 | per node
//!                 (tag leaf=0/transfer=1, rows, cols) ×u64 + data f64 LE
//! ```
//!
//! Everything is written through a CRC-checked footer, so truncation and
//! bit corruption are detected; any structural defect (bad magic/version/
//! kind/CRC, short payload) is reported as the typed
//! [`DnttError::Artifact`] so callers can distinguish a damaged artifact
//! from an ordinary I/O failure. [`Artifact`] + [`save_artifact`] /
//! [`load_artifact`] wrap the two servable kinds (TT and HT) behind one
//! entry point — the persistence layer under `dntt decompose --out` and
//! `dntt query`.

use crate::error::{DnttError, Result};
use crate::linalg::Mat;
use crate::tensor::ht::{DimTree, HtNode, TreeNode};
use crate::tensor::{DenseTensor, HtTensor, TTensor};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"DNTT";
const VERSION: u32 = 1;
const KIND_TT: u32 = 1;
const KIND_DENSE: u32 = 2;
const KIND_HT: u32 = 3;

fn artifact_err(msg: impl Into<String>) -> DnttError {
    DnttError::Artifact(msg.into())
}

/// Simple CRC-32 (IEEE, bitwise) — enough to catch truncation/corruption.
/// Shared with the `dntt-chunks-v1` ingest manifest
/// ([`crate::tensor::chunked`]), which stamps the same checksum per
/// chunk file.
pub(crate) fn crc32(data: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Encode f64s as the dense spill/chunk byte format: raw little-endian,
/// in order. One codec shared by the chunk store's spill files, the
/// checkpoint block files, and `dntt-chunks-v1` ingest chunks — byte
/// compatibility between the three is what lets spilled chunks be
/// adopted and snapshotted without translation.
pub(crate) fn f64s_to_le_bytes(data: &[f64]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(data.len() * 8);
    for x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    bytes
}

/// Decode the dense spill/chunk byte format. Trailing partial records
/// are ignored by construction (`chunks_exact`); callers validate the
/// total size against the expected element count.
pub(crate) fn le_bytes_to_f64s(bytes: &[u8]) -> Vec<f64> {
    bytes
        .chunks_exact(8)
        .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
        .collect()
}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new(kind: u32) -> Self {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&kind.to_le_bytes());
        Writer { buf }
    }
    fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn f64s(&mut self, xs: &[f64]) {
        self.buf.reserve(xs.len() * 8);
        for x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn finish(mut self, path: &Path) -> Result<()> {
        let crc = crc32(&self.buf);
        self.buf.extend_from_slice(&crc.to_le_bytes());
        let mut f = std::fs::File::create(path)?;
        f.write_all(&self.buf)?;
        Ok(())
    }
}

struct Reader {
    buf: Vec<u8>,
    pos: usize,
}

impl Reader {
    /// Open and integrity-check the container; the payload kind must be
    /// one of `kinds`. Returns the reader positioned at the payload and
    /// the actual kind. All structural defects surface as
    /// [`DnttError::Artifact`]; only failing to read the file at all is
    /// an I/O error.
    fn open_any(path: &Path, kinds: &[u32]) -> Result<(Self, u32)> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        if buf.len() < 16 {
            return Err(artifact_err("file too short for a .dntt container"));
        }
        let body = &buf[..buf.len() - 4];
        let stored = u32::from_le_bytes(buf[buf.len() - 4..].try_into().unwrap());
        if crc32(body) != stored {
            return Err(artifact_err("CRC mismatch (truncated or corrupted file)"));
        }
        if &buf[..4] != MAGIC {
            return Err(artifact_err("not a .dntt file (bad magic)"));
        }
        let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(artifact_err(format!("unsupported version {version}")));
        }
        let k = u32::from_le_bytes(buf[8..12].try_into().unwrap());
        if !kinds.contains(&k) {
            return Err(artifact_err(format!("wrong payload kind {k} (expected one of {kinds:?})")));
        }
        buf.truncate(buf.len() - 4);
        Ok((Reader { buf, pos: 12 }, k))
    }
    fn open(path: &Path, kind: u32) -> Result<Self> {
        Ok(Self::open_any(path, &[kind])?.0)
    }
    fn u64(&mut self) -> Result<u64> {
        if self.pos + 8 > self.buf.len() {
            return Err(artifact_err("short read (payload ends early)"));
        }
        let x = u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        Ok(x)
    }
    fn f64s(&mut self, n: usize) -> Result<Vec<f64>> {
        let end = n
            .checked_mul(8)
            .and_then(|b| self.pos.checked_add(b))
            .ok_or_else(|| artifact_err("implausible payload length"))?;
        if end > self.buf.len() {
            return Err(artifact_err("short read (payload ends early)"));
        }
        let out = self.buf[self.pos..self.pos + 8 * n]
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        self.pos += 8 * n;
        Ok(out)
    }
}

/// Save a tensor train.
pub fn save_tt(tt: &TTensor<f64>, path: &Path) -> Result<()> {
    let mut w = Writer::new(KIND_TT);
    w.u64(tt.dims().len() as u64);
    for &n in tt.dims() {
        w.u64(n as u64);
    }
    for &r in tt.ranks() {
        w.u64(r as u64);
    }
    for core in tt.cores() {
        w.f64s(core.as_slice());
    }
    w.finish(path)
}

/// Load a tensor train.
pub fn load_tt(path: &Path) -> Result<TTensor<f64>> {
    let mut r = Reader::open(path, KIND_TT)?;
    let d = r.u64()? as usize;
    if d == 0 || d > 64 {
        return Err(DnttError::shape(format!("implausible order {d}")));
    }
    let dims: Vec<usize> = (0..d).map(|_| r.u64().map(|x| x as usize)).collect::<Result<_>>()?;
    let ranks: Vec<usize> =
        (0..=d).map(|_| r.u64().map(|x| x as usize)).collect::<Result<_>>()?;
    let mut cores = Vec::with_capacity(d);
    for i in 0..d {
        let rows = ranks[i]
            .checked_mul(dims[i])
            .ok_or_else(|| artifact_err("TT payload: implausible core shape"))?;
        let n = rows
            .checked_mul(ranks[i + 1])
            .ok_or_else(|| artifact_err("TT payload: implausible core shape"))?;
        let data = r.f64s(n)?;
        cores.push(Mat::from_vec(rows, ranks[i + 1], data));
    }
    TTensor::new(dims, cores)
}

/// Save a dense tensor.
pub fn save_dense(t: &DenseTensor<f64>, path: &Path) -> Result<()> {
    let mut w = Writer::new(KIND_DENSE);
    w.u64(t.ndim() as u64);
    for &n in t.dims() {
        w.u64(n as u64);
    }
    w.f64s(t.as_slice());
    w.finish(path)
}

/// Load a dense tensor.
pub fn load_dense(path: &Path) -> Result<DenseTensor<f64>> {
    let mut r = Reader::open(path, KIND_DENSE)?;
    let d = r.u64()? as usize;
    if d == 0 || d > 64 {
        return Err(DnttError::shape(format!("implausible order {d}")));
    }
    let dims: Vec<usize> = (0..d).map(|_| r.u64().map(|x| x as usize)).collect::<Result<_>>()?;
    let n: usize = dims
        .iter()
        .try_fold(1usize, |acc, &x| acc.checked_mul(x))
        .ok_or_else(|| artifact_err("dense payload: implausible dims"))?;
    let data = r.f64s(n)?;
    DenseTensor::from_vec(&dims, data)
}

/// Save a hierarchical Tucker tensor (kind 3): the explicit dimension
/// tree followed by every node payload.
pub fn save_ht(ht: &HtTensor<f64>, path: &Path) -> Result<()> {
    let mut w = Writer::new(KIND_HT);
    w.u64(ht.dims().len() as u64);
    for &n in ht.dims() {
        w.u64(n as u64);
    }
    let tree = ht.tree();
    w.u64(tree.len() as u64);
    for t in 0..tree.len() {
        let node = tree.node(t);
        w.u64(node.lo as u64);
        w.u64(node.hi as u64);
        match node.children {
            None => {
                w.u64(0);
                w.u64(0);
                w.u64(0);
            }
            Some((l, r)) => {
                w.u64(1);
                w.u64(l as u64);
                w.u64(r as u64);
            }
        }
    }
    for payload in ht.nodes() {
        let (tag, mat) = match payload {
            HtNode::Leaf(u) => (0u64, u),
            HtNode::Transfer(b) => (1u64, b),
        };
        w.u64(tag);
        w.u64(mat.rows() as u64);
        w.u64(mat.cols() as u64);
        w.f64s(mat.as_slice());
    }
    w.finish(path)
}

/// Load a hierarchical Tucker tensor. The tree and shape chain are
/// re-validated by [`DimTree::from_nodes`] and `HtTensor::new`.
pub fn load_ht(path: &Path) -> Result<HtTensor<f64>> {
    let mut r = Reader::open(path, KIND_HT)?;
    let d = r.u64()? as usize;
    if d == 0 || d > 64 {
        return Err(DnttError::shape(format!("implausible order {d}")));
    }
    let dims: Vec<usize> = (0..d).map(|_| r.u64().map(|x| x as usize)).collect::<Result<_>>()?;
    let nn = r.u64()? as usize;
    if nn != 2 * d - 1 {
        return Err(artifact_err(format!("HT payload: {nn} tree nodes for {d} modes")));
    }
    let mut tree_nodes = Vec::with_capacity(nn);
    for _ in 0..nn {
        let lo = r.u64()? as usize;
        let hi = r.u64()? as usize;
        let has_children = r.u64()?;
        let (l, rc) = (r.u64()? as usize, r.u64()? as usize);
        let children = match has_children {
            0 => None,
            1 => Some((l, rc)),
            other => return Err(artifact_err(format!("HT payload: bad children flag {other}"))),
        };
        tree_nodes.push(TreeNode { lo, hi, children });
    }
    let tree = DimTree::from_nodes(tree_nodes)?;
    let mut payloads = Vec::with_capacity(nn);
    for t in 0..nn {
        let tag = r.u64()?;
        let rows = r.u64()? as usize;
        let cols = r.u64()? as usize;
        let n = rows
            .checked_mul(cols)
            .ok_or_else(|| artifact_err("HT payload: implausible node shape"))?;
        let data = r.f64s(n)?;
        let mat = Mat::from_vec(rows, cols, data);
        payloads.push(match (tag, tree.is_leaf(t)) {
            (0, true) => HtNode::Leaf(mat),
            (1, false) => HtNode::Transfer(mat),
            _ => {
                return Err(artifact_err(format!(
                    "HT payload: node {t} tag {tag} does not match the tree"
                )))
            }
        });
    }
    HtTensor::new(dims, tree, payloads)
}

/// A servable decomposition artifact — either tensor network, behind one
/// save/load entry point (the payload of `dntt decompose --out` and the
/// input of `dntt query`).
#[derive(Clone, Debug)]
pub enum Artifact {
    Tt(TTensor<f64>),
    Ht(HtTensor<f64>),
}

impl Artifact {
    /// `"tt"` or `"ht"`.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Artifact::Tt(_) => "tt",
            Artifact::Ht(_) => "ht",
        }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Artifact::Tt(t) => t.dims(),
            Artifact::Ht(h) => h.dims(),
        }
    }

    /// Stored parameters across all cores / node payloads.
    pub fn num_params(&self) -> usize {
        match self {
            Artifact::Tt(t) => t.num_params(),
            Artifact::Ht(h) => h.num_params(),
        }
    }
}

/// Save either tensor-network artifact.
pub fn save_artifact(a: &Artifact, path: &Path) -> Result<()> {
    match a {
        Artifact::Tt(t) => save_tt(t, path),
        Artifact::Ht(h) => save_ht(h, path),
    }
}

/// Load a servable artifact (TT or HT; a dense payload is rejected with
/// the typed [`DnttError::Artifact`]).
///
/// ```
/// use dntt::tensor::io::{load_artifact, save_artifact, Artifact};
/// use dntt::tensor::TTensor;
/// use dntt::util::rng::Rng;
///
/// let mut rng = Rng::new(3);
/// let tt = TTensor::<f64>::rand_uniform(&[3, 4], &[2], &mut rng).unwrap();
/// let path = std::env::temp_dir().join(format!("doc_artifact_{}.dntt", std::process::id()));
/// save_artifact(&Artifact::Tt(tt), &path).unwrap();
/// let back = load_artifact(&path).unwrap();
/// assert_eq!(back.kind_name(), "tt");
/// assert_eq!(back.dims(), &[3, 4]);
/// let _ = std::fs::remove_file(&path);
/// ```
pub fn load_artifact(path: &Path) -> Result<Artifact> {
    let (_, kind) = Reader::open_any(path, &[KIND_TT, KIND_HT])?;
    match kind {
        KIND_TT => Ok(Artifact::Tt(load_tt(path)?)),
        _ => Ok(Artifact::Ht(load_ht(path)?)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("dntt_io_{}_{name}", std::process::id()))
    }

    #[test]
    fn tt_roundtrip() {
        let mut rng = Rng::new(1);
        let tt = TTensor::<f64>::rand_uniform(&[4, 5, 6], &[2, 3], &mut rng).unwrap();
        let p = tmp("tt.dntt");
        save_tt(&tt, &p).unwrap();
        let back = load_tt(&p).unwrap();
        assert_eq!(back.dims(), tt.dims());
        assert_eq!(back.ranks(), tt.ranks());
        for (a, b) in tt.cores().iter().zip(back.cores()) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::new(2);
        let t = DenseTensor::<f64>::rand_uniform(&[3, 7, 2], &mut rng);
        let p = tmp("dense.dntt");
        save_dense(&t, &p).unwrap();
        assert_eq!(load_dense(&p).unwrap(), t);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn corruption_detected() {
        let mut rng = Rng::new(3);
        let tt = TTensor::<f64>::rand_uniform(&[3, 3], &[2], &mut rng).unwrap();
        let p = tmp("corrupt.dntt");
        save_tt(&tt, &p).unwrap();
        // Flip a byte in the middle.
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load_tt(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn truncation_detected() {
        let mut rng = Rng::new(4);
        let tt = TTensor::<f64>::rand_uniform(&[3, 3], &[2], &mut rng).unwrap();
        let p = tmp("trunc.dntt");
        save_tt(&tt, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 9]).unwrap();
        assert!(load_tt(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn kind_mismatch_detected() {
        let mut rng = Rng::new(5);
        let t = DenseTensor::<f64>::rand_uniform(&[2, 2], &mut rng);
        let p = tmp("kind.dntt");
        save_dense(&t, &p).unwrap();
        assert!(load_tt(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn ht_roundtrip_bitwise() {
        let mut rng = Rng::new(6);
        let ht = HtTensor::<f64>::rand_uniform(&[4, 3, 5, 2, 3], 3, &mut rng).unwrap();
        let p = tmp("ht.dntt");
        save_ht(&ht, &p).unwrap();
        let back = load_ht(&p).unwrap();
        assert_eq!(back.dims(), ht.dims());
        assert_eq!(back.tree(), ht.tree());
        assert_eq!(back.ranks(), ht.ranks());
        for (a, b) in ht.nodes().iter().zip(back.nodes()) {
            assert_eq!(a.mat().shape(), b.mat().shape());
            for (x, y) in a.mat().as_slice().iter().zip(b.mat().as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn artifact_dispatches_on_kind() {
        let mut rng = Rng::new(7);
        let tt = TTensor::<f64>::rand_uniform(&[3, 4], &[2], &mut rng).unwrap();
        let ht = HtTensor::<f64>::rand_uniform(&[3, 4], 2, &mut rng).unwrap();
        let pt = tmp("art_tt.dntt");
        let ph = tmp("art_ht.dntt");
        save_artifact(&Artifact::Tt(tt), &pt).unwrap();
        save_artifact(&Artifact::Ht(ht), &ph).unwrap();
        assert_eq!(load_artifact(&pt).unwrap().kind_name(), "tt");
        assert_eq!(load_artifact(&ph).unwrap().kind_name(), "ht");
        let _ = std::fs::remove_file(&pt);
        let _ = std::fs::remove_file(&ph);
    }

    #[test]
    fn structural_defects_are_typed_artifact_errors() {
        use crate::error::DnttError;
        let mut rng = Rng::new(8);
        let tt = TTensor::<f64>::rand_uniform(&[3, 3], &[2], &mut rng).unwrap();
        let p = tmp("typed.dntt");
        save_tt(&tt, &p).unwrap();
        // Corruption.
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[bytes.len() / 2] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(matches!(load_artifact(&p), Err(DnttError::Artifact(_))));
        // A dense payload is not servable.
        let t = DenseTensor::<f64>::rand_uniform(&[2, 2], &mut rng);
        save_dense(&t, &p).unwrap();
        assert!(matches!(load_artifact(&p), Err(DnttError::Artifact(_))));
        // Missing file stays an I/O error.
        let _ = std::fs::remove_file(&p);
        assert!(matches!(load_artifact(&p), Err(DnttError::Io(_))));
    }
}
