//! Binary persistence for tensors and tensor trains.
//!
//! A decomposition is only useful if the compressed representation can be
//! stored and reloaded — this module gives the TT format a simple,
//! versioned, endian-stable container (`.dntt`):
//!
//! ```text
//! magic "DNTT" | u32 version | u32 kind | u64 d
//! dims: d × u64 | ranks: (d+1) × u64
//! cores: concatenated f64 LE, core i = (r_{i-1}·n_i·r_i) values
//! ```
//!
//! Dense tensors use kind=2 with the same header minus ranks. Everything is
//! written through a CRC-checked footer so truncated files are detected.

use crate::error::{DnttError, Result};
use crate::linalg::Mat;
use crate::tensor::{DenseTensor, TTensor};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"DNTT";
const VERSION: u32 = 1;
const KIND_TT: u32 = 1;
const KIND_DENSE: u32 = 2;

/// Simple CRC-32 (IEEE, bitwise) — enough to catch truncation/corruption.
fn crc32(data: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new(kind: u32) -> Self {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&kind.to_le_bytes());
        Writer { buf }
    }
    fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn f64s(&mut self, xs: &[f64]) {
        self.buf.reserve(xs.len() * 8);
        for x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn finish(mut self, path: &Path) -> Result<()> {
        let crc = crc32(&self.buf);
        self.buf.extend_from_slice(&crc.to_le_bytes());
        let mut f = std::fs::File::create(path)?;
        f.write_all(&self.buf)?;
        Ok(())
    }
}

struct Reader {
    buf: Vec<u8>,
    pos: usize,
}

impl Reader {
    fn open(path: &Path, kind: u32) -> Result<Self> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        if buf.len() < 16 {
            return Err(DnttError::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "file too short",
            )));
        }
        let body = &buf[..buf.len() - 4];
        let stored = u32::from_le_bytes(buf[buf.len() - 4..].try_into().unwrap());
        if crc32(body) != stored {
            return Err(DnttError::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "CRC mismatch (truncated or corrupted file)",
            )));
        }
        if &buf[..4] != MAGIC {
            return Err(DnttError::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "not a .dntt file",
            )));
        }
        let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(DnttError::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("unsupported version {version}"),
            )));
        }
        let k = u32::from_le_bytes(buf[8..12].try_into().unwrap());
        if k != kind {
            return Err(DnttError::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("wrong payload kind {k} (expected {kind})"),
            )));
        }
        buf.truncate(buf.len() - 4);
        Ok(Reader { buf, pos: 12 })
    }
    fn u64(&mut self) -> Result<u64> {
        if self.pos + 8 > self.buf.len() {
            return Err(DnttError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "short read",
            )));
        }
        let x = u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        Ok(x)
    }
    fn f64s(&mut self, n: usize) -> Result<Vec<f64>> {
        if self.pos + 8 * n > self.buf.len() {
            return Err(DnttError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "short read",
            )));
        }
        let out = self.buf[self.pos..self.pos + 8 * n]
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        self.pos += 8 * n;
        Ok(out)
    }
}

/// Save a tensor train.
pub fn save_tt(tt: &TTensor<f64>, path: &Path) -> Result<()> {
    let mut w = Writer::new(KIND_TT);
    w.u64(tt.dims().len() as u64);
    for &n in tt.dims() {
        w.u64(n as u64);
    }
    for &r in tt.ranks() {
        w.u64(r as u64);
    }
    for core in tt.cores() {
        w.f64s(core.as_slice());
    }
    w.finish(path)
}

/// Load a tensor train.
pub fn load_tt(path: &Path) -> Result<TTensor<f64>> {
    let mut r = Reader::open(path, KIND_TT)?;
    let d = r.u64()? as usize;
    if d == 0 || d > 64 {
        return Err(DnttError::shape(format!("implausible order {d}")));
    }
    let dims: Vec<usize> = (0..d).map(|_| r.u64().map(|x| x as usize)).collect::<Result<_>>()?;
    let ranks: Vec<usize> =
        (0..=d).map(|_| r.u64().map(|x| x as usize)).collect::<Result<_>>()?;
    let mut cores = Vec::with_capacity(d);
    for i in 0..d {
        let rows = ranks[i] * dims[i];
        let data = r.f64s(rows * ranks[i + 1])?;
        cores.push(Mat::from_vec(rows, ranks[i + 1], data));
    }
    TTensor::new(dims, cores)
}

/// Save a dense tensor.
pub fn save_dense(t: &DenseTensor<f64>, path: &Path) -> Result<()> {
    let mut w = Writer::new(KIND_DENSE);
    w.u64(t.ndim() as u64);
    for &n in t.dims() {
        w.u64(n as u64);
    }
    w.f64s(t.as_slice());
    w.finish(path)
}

/// Load a dense tensor.
pub fn load_dense(path: &Path) -> Result<DenseTensor<f64>> {
    let mut r = Reader::open(path, KIND_DENSE)?;
    let d = r.u64()? as usize;
    if d == 0 || d > 64 {
        return Err(DnttError::shape(format!("implausible order {d}")));
    }
    let dims: Vec<usize> = (0..d).map(|_| r.u64().map(|x| x as usize)).collect::<Result<_>>()?;
    let n: usize = dims.iter().product();
    let data = r.f64s(n)?;
    DenseTensor::from_vec(&dims, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("dntt_io_{}_{name}", std::process::id()))
    }

    #[test]
    fn tt_roundtrip() {
        let mut rng = Rng::new(1);
        let tt = TTensor::<f64>::rand_uniform(&[4, 5, 6], &[2, 3], &mut rng).unwrap();
        let p = tmp("tt.dntt");
        save_tt(&tt, &p).unwrap();
        let back = load_tt(&p).unwrap();
        assert_eq!(back.dims(), tt.dims());
        assert_eq!(back.ranks(), tt.ranks());
        for (a, b) in tt.cores().iter().zip(back.cores()) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::new(2);
        let t = DenseTensor::<f64>::rand_uniform(&[3, 7, 2], &mut rng);
        let p = tmp("dense.dntt");
        save_dense(&t, &p).unwrap();
        assert_eq!(load_dense(&p).unwrap(), t);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn corruption_detected() {
        let mut rng = Rng::new(3);
        let tt = TTensor::<f64>::rand_uniform(&[3, 3], &[2], &mut rng).unwrap();
        let p = tmp("corrupt.dntt");
        save_tt(&tt, &p).unwrap();
        // Flip a byte in the middle.
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load_tt(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn truncation_detected() {
        let mut rng = Rng::new(4);
        let tt = TTensor::<f64>::rand_uniform(&[3, 3], &[2], &mut rng).unwrap();
        let p = tmp("trunc.dntt");
        save_tt(&tt, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 9]).unwrap();
        assert!(load_tt(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn kind_mismatch_detected() {
        let mut rng = Rng::new(5);
        let t = DenseTensor::<f64>::rand_uniform(&[2, 2], &mut rng);
        let p = tmp("kind.dntt");
        save_dense(&t, &p).unwrap();
        assert!(load_tt(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
