//! Hierarchical Tucker (HT) format over a balanced binary dimension tree.
//!
//! The other canonical linear-storage tensor network of the pyDNTNK
//! family (Cichocki, arXiv:1407.3124 §4): modes are organized in a
//! balanced binary [`DimTree`]; every leaf stores a factor `U: n_i × r`
//! and every interior node a transfer tensor coupling its two child
//! edges to its parent edge. Storage is `Σ n_i·r + Σ r³`-shaped — linear
//! in `d` — versus the exponential `Π n_i` of the dense tensor.
//!
//! # Index conventions (shared with the `crate::ht` driver)
//!
//! Every tree node `t` has a *parent-edge rank* `r_t` (root: `r = 1`) and
//! represents a matrix `V_t: n_{S_t} × r_t` whose rows are row-major over
//! the node's mode range `S_t = [lo, hi)`. An interior node with children
//! `(left, right)` factorizes in two steps:
//!
//! 1. `M1 = reshape(V_t) : n_left × (n_right·r_t) ≈ W1·H1` — `W1` is the
//!    left child's `V` (edge rank `r1`);
//! 2. `M2[i2, (j1,k)] = H1[j1, (i2,k)] : n_right × (r1·r_t) ≈ W2·H2` —
//!    `W2` is the right child's `V` (edge rank `r2`) and
//!    **`H2: r2 × (r1·r_t)` is the node's transfer tensor** `B_t` with
//!    `B_t[j2, (j1, k)]` coupling (left edge, right edge, parent edge).
//!
//! Reconstruction inverts the two steps bottom-up (see
//! [`HtTensor::reconstruct`]). Non-negative node matrices compose into a
//! non-negative tensor, mirroring the nTT invariant.

use crate::error::{DnttError, Result};
use crate::linalg::gemm::matmul;
use crate::linalg::{Mat, Scalar};
use crate::tensor::dense::DenseTensor;

/// One node of a dimension tree: the mode range `[lo, hi)` it covers and
/// its children (leaves cover a single mode and have none).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TreeNode {
    pub lo: usize,
    pub hi: usize,
    /// Child node ids in the owning [`DimTree`] (left covers the first
    /// ⌈q/2⌉ modes of the range).
    pub children: Option<(usize, usize)>,
}

/// A balanced binary dimension tree in BFS (level) order.
///
/// Node 0 is the root covering all `d` modes; every interior node splits
/// its range into a first half of `⌈q/2⌉` modes and the remainder; leaves
/// are single modes. BFS ids mean a parent always precedes its children,
/// which is the processing order of the level-by-level HT sweep.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DimTree {
    nodes: Vec<TreeNode>,
}

impl DimTree {
    /// The balanced tree over `d ≥ 1` modes (`2d − 1` nodes).
    pub fn balanced(d: usize) -> DimTree {
        assert!(d >= 1, "dimension tree needs at least one mode");
        let mut nodes = vec![TreeNode { lo: 0, hi: d, children: None }];
        let mut cur = 0;
        while cur < nodes.len() {
            let (lo, hi) = (nodes[cur].lo, nodes[cur].hi);
            if hi - lo >= 2 {
                let mid = lo + (hi - lo).div_ceil(2);
                let l = nodes.len();
                nodes.push(TreeNode { lo, hi: mid, children: None });
                nodes.push(TreeNode { lo: mid, hi, children: None });
                nodes[cur].children = Some((l, l + 1));
            }
            cur += 1;
        }
        DimTree { nodes }
    }

    /// Rebuild a tree from explicit nodes (BFS-style ids: children after
    /// their parent) — the deserialization path of
    /// [`crate::tensor::io::load_artifact`]. Validates the invariants
    /// [`DimTree::balanced`] guarantees: node 0 is a root starting at
    /// mode 0, interior nodes split their range contiguously between two
    /// later nodes, leaves cover exactly one mode, and every non-root
    /// node is referenced exactly once.
    pub fn from_nodes(nodes: Vec<TreeNode>) -> Result<DimTree> {
        if nodes.is_empty() {
            return Err(DnttError::shape("dimension tree needs at least one node"));
        }
        if nodes[0].lo != 0 {
            return Err(DnttError::shape("dimension tree root must start at mode 0"));
        }
        let mut referenced = vec![0usize; nodes.len()];
        for (t, node) in nodes.iter().enumerate() {
            if node.lo >= node.hi {
                return Err(DnttError::shape(format!("tree node {t}: empty mode range")));
            }
            match node.children {
                None => {
                    if node.hi - node.lo != 1 {
                        return Err(DnttError::shape(format!(
                            "tree leaf {t} covers {} modes",
                            node.hi - node.lo
                        )));
                    }
                }
                Some((l, r)) => {
                    if l <= t || r <= t || l >= nodes.len() || r >= nodes.len() || l == r {
                        return Err(DnttError::shape(format!(
                            "tree node {t}: invalid child ids ({l}, {r})"
                        )));
                    }
                    if nodes[l].lo != node.lo
                        || nodes[l].hi != nodes[r].lo
                        || nodes[r].hi != node.hi
                    {
                        return Err(DnttError::shape(format!(
                            "tree node {t}: children do not partition [{}, {})",
                            node.lo, node.hi
                        )));
                    }
                    referenced[l] += 1;
                    referenced[r] += 1;
                }
            }
        }
        if referenced[0] != 0 || referenced[1..].iter().any(|&c| c != 1) {
            return Err(DnttError::shape("dimension tree is not a single-rooted tree"));
        }
        Ok(DimTree { nodes })
    }

    /// Number of nodes (`2d − 1` for `d` leaves).
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True only for the degenerate zero-node tree (never constructed by
    /// [`DimTree::balanced`]).
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Node `t` (BFS id).
    pub fn node(&self, t: usize) -> TreeNode {
        self.nodes[t]
    }

    /// True when node `t` covers a single mode.
    pub fn is_leaf(&self, t: usize) -> bool {
        self.nodes[t].children.is_none()
    }

    /// Number of leaves (= number of tensor modes).
    pub fn num_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.children.is_none()).count()
    }

    /// Number of interior nodes (`d − 1`).
    pub fn num_interior(&self) -> usize {
        self.len() - self.num_leaves()
    }
}

/// Payload of one tree node.
#[derive(Clone, Debug)]
pub enum HtNode<T: Scalar = f64> {
    /// Interior node: the transfer tensor `B: r2 × (r1·rt)` (row-major),
    /// where `r1`/`r2` are the child edge ranks and `rt` the parent edge
    /// rank (see the module docs for the index convention).
    Transfer(Mat<T>),
    /// Leaf: the factor `U: n_i × rt`.
    Leaf(Mat<T>),
}

impl<T: Scalar> HtNode<T> {
    /// The stored matrix (transfer tensor or leaf factor).
    pub fn mat(&self) -> &Mat<T> {
        match self {
            HtNode::Transfer(b) => b,
            HtNode::Leaf(u) => u,
        }
    }
}

/// A hierarchical Tucker tensor: a [`DimTree`] plus one [`HtNode`] per
/// tree node.
///
/// ```
/// use dntt::tensor::HtTensor;
/// use dntt::util::rng::Rng;
///
/// let mut rng = Rng::new(7);
/// let ht = HtTensor::<f64>::rand_uniform(&[3, 4, 5, 2], 2, &mut rng).unwrap();
/// assert_eq!(ht.ranks()[0], 1);            // root edge rank is always 1
/// let full = ht.reconstruct();             // contract the tree bottom-up
/// assert_eq!(full.dims(), &[3, 4, 5, 2]);
/// assert!(ht.rel_error(&full) < 1e-12);
/// assert!(ht.is_nonneg());                 // uniform [0,1) node matrices
/// ```
#[derive(Clone, Debug)]
pub struct HtTensor<T: Scalar = f64> {
    dims: Vec<usize>,
    tree: DimTree,
    nodes: Vec<HtNode<T>>,
    /// Parent-edge rank of every node (BFS order; `ranks[0] == 1`).
    ranks: Vec<usize>,
}

impl<T: Scalar> HtTensor<T> {
    /// Assemble from per-node payloads; validates the shape chain and the
    /// root edge rank.
    pub fn new(dims: Vec<usize>, tree: DimTree, nodes: Vec<HtNode<T>>) -> Result<Self> {
        if dims.is_empty() {
            return Err(DnttError::shape("HT: need at least one mode"));
        }
        if tree.len() != nodes.len() {
            return Err(DnttError::shape(format!(
                "HT: {} payloads for a {}-node tree",
                nodes.len(),
                tree.len()
            )));
        }
        if tree.num_leaves() != dims.len() {
            return Err(DnttError::shape(format!(
                "HT: tree has {} leaves, tensor has {} modes",
                tree.num_leaves(),
                dims.len()
            )));
        }
        let mut ranks = vec![0usize; tree.len()];
        let root_rank = edge_rank_checked(&dims, &tree, &nodes, 0, &mut ranks)?;
        if root_rank != 1 {
            return Err(DnttError::shape(format!(
                "HT: root edge rank must be 1, got {root_rank}"
            )));
        }
        Ok(HtTensor { dims, tree, nodes, ranks })
    }

    /// A random HT tensor with every non-root edge rank equal to `rank`
    /// and uniform [0,1) node matrices — the synthetic-workload generator
    /// (`crate::ht::SyntheticHt`).
    pub fn rand_uniform(dims: &[usize], rank: usize, rng: &mut crate::util::rng::Rng) -> Result<Self> {
        if dims.len() < 2 {
            return Err(DnttError::shape("HT generator needs at least 2 modes"));
        }
        if rank == 0 {
            return Err(DnttError::config("HT generator rank must be ≥ 1"));
        }
        let tree = DimTree::balanced(dims.len());
        let mut nodes = Vec::with_capacity(tree.len());
        for t in 0..tree.len() {
            let rt = if t == 0 { 1 } else { rank };
            let node = tree.node(t);
            nodes.push(if node.children.is_some() {
                // B: r2 × (r1·rt) with r1 = r2 = rank.
                HtNode::Transfer(Mat::rand_uniform(rank, rank * rt, rng))
            } else {
                HtNode::Leaf(Mat::rand_uniform(dims[node.lo], rt, rng))
            });
        }
        HtTensor::new(dims.to_vec(), tree, nodes)
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn tree(&self) -> &DimTree {
        &self.tree
    }

    /// Payload of tree node `t`.
    pub fn node(&self, t: usize) -> &HtNode<T> {
        &self.nodes[t]
    }

    pub fn nodes(&self) -> &[HtNode<T>] {
        &self.nodes
    }

    /// Parent-edge rank of every tree node, in BFS node order
    /// (`ranks()[0]` is the root's trivial rank 1).
    pub fn ranks(&self) -> &[usize] {
        &self.ranks
    }

    /// Number of stored parameters (all leaf factors + transfer tensors).
    pub fn num_params(&self) -> usize {
        self.nodes.iter().map(|n| n.mat().len()).sum()
    }

    /// Compression ratio `Π n_i / num_params` (the HT analogue of Eq. 4)
    /// — against the *dense* element count.
    pub fn compression_ratio(&self) -> f64 {
        let full: f64 = self.dims.iter().map(|&n| n as f64).product();
        self.compression_ratio_vs(full)
    }

    /// Compression ratio against an explicit input storage size (in
    /// elements) — for sparse inputs pass the nnz, so the reported ratio
    /// reflects what was actually stored, not the dense bounding box.
    pub fn compression_ratio_vs(&self, input_elems: f64) -> f64 {
        input_elems / self.num_params() as f64
    }

    /// All node matrices elementwise non-negative (the nHT invariant).
    pub fn is_nonneg(&self) -> bool {
        self.nodes.iter().all(|n| n.mat().is_nonneg())
    }

    /// Product of the mode sizes node `t` covers.
    fn n_modes(&self, t: usize) -> usize {
        let node = self.tree.node(t);
        self.dims[node.lo..node.hi].iter().product()
    }

    /// The matrix `V_t: n_{S_t} × r_t` of node `t`, reconstructed
    /// bottom-up (flat, row-major).
    fn array(&self, t: usize) -> Vec<T> {
        match self.tree.node(t).children {
            None => self.nodes[t].mat().as_slice().to_vec(),
            Some((lc, rc)) => {
                let (r1, r2, rt) = (self.ranks[lc], self.ranks[rc], self.ranks[t]);
                let (n1, n2) = (self.n_modes(lc), self.n_modes(rc));
                let u1 = Mat::from_vec(n1, r1, self.array(lc));
                let u2 = Mat::from_vec(n2, r2, self.array(rc));
                let b = match &self.nodes[t] {
                    HtNode::Transfer(b) => b,
                    HtNode::Leaf(_) => unreachable!("validated in new()"),
                };
                // Invert step 2: M2 = U2·B is n2 × (r1·rt), then un-permute
                // back to H1: r1 × (n2·rt).
                let m2 = matmul(&u2, b);
                let mut h1 = Mat::zeros(r1, n2 * rt);
                for i2 in 0..n2 {
                    for j1 in 0..r1 {
                        for k in 0..rt {
                            h1[(j1, i2 * rt + k)] = m2[(i2, j1 * rt + k)];
                        }
                    }
                }
                // Invert step 1: V_t = U1·H1, flat in (i1, i2, k) order.
                matmul(&u1, &h1).into_vec()
            }
        }
    }

    /// Full dense reconstruction by contracting the tree bottom-up.
    /// Cost `O(Π n · max r²)`, memory one full tensor.
    pub fn reconstruct(&self) -> DenseTensor<T> {
        let data = self.array(0);
        DenseTensor::from_vec(&self.dims, data).expect("HT reconstruct shape")
    }

    /// Relative reconstruction error vs a reference tensor (Eq. 3).
    pub fn rel_error(&self, reference: &DenseTensor<T>) -> f64 {
        reference.rel_error(&self.reconstruct())
    }
}

/// Recursive shape validation; fills `ranks` and returns node `t`'s
/// parent-edge rank.
fn edge_rank_checked<T: Scalar>(
    dims: &[usize],
    tree: &DimTree,
    nodes: &[HtNode<T>],
    t: usize,
    ranks: &mut [usize],
) -> Result<usize> {
    let node = tree.node(t);
    let rank = match (&nodes[t], node.children) {
        (HtNode::Leaf(u), None) => {
            if u.rows() != dims[node.lo] {
                return Err(DnttError::shape(format!(
                    "HT leaf {t}: factor has {} rows, mode {} has size {}",
                    u.rows(),
                    node.lo,
                    dims[node.lo]
                )));
            }
            if u.cols() == 0 {
                return Err(DnttError::shape(format!("HT leaf {t}: zero edge rank")));
            }
            u.cols()
        }
        (HtNode::Transfer(b), Some((lc, rc))) => {
            let r1 = edge_rank_checked(dims, tree, nodes, lc, ranks)?;
            let r2 = edge_rank_checked(dims, tree, nodes, rc, ranks)?;
            if b.rows() != r2 {
                return Err(DnttError::shape(format!(
                    "HT node {t}: transfer has {} rows, right edge rank is {r2}",
                    b.rows()
                )));
            }
            if b.cols() % r1 != 0 || b.cols() == 0 {
                return Err(DnttError::shape(format!(
                    "HT node {t}: transfer has {} cols, not a multiple of left edge rank {r1}",
                    b.cols()
                )));
            }
            b.cols() / r1
        }
        _ => {
            return Err(DnttError::shape(format!(
                "HT node {t}: payload kind does not match the tree (leaf vs interior)"
            )))
        }
    };
    ranks[t] = rank;
    Ok(rank)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn balanced_tree_shapes() {
        let t2 = DimTree::balanced(2);
        assert_eq!(t2.len(), 3);
        assert_eq!(t2.node(0).children, Some((1, 2)));
        assert_eq!((t2.node(1).lo, t2.node(1).hi), (0, 1));
        assert_eq!((t2.node(2).lo, t2.node(2).hi), (1, 2));

        let t4 = DimTree::balanced(4);
        assert_eq!(t4.len(), 7);
        assert_eq!((t4.node(1).lo, t4.node(1).hi), (0, 2));
        assert_eq!((t4.node(2).lo, t4.node(2).hi), (2, 4));
        assert_eq!(t4.num_leaves(), 4);
        assert_eq!(t4.num_interior(), 3);

        // Odd splits put the extra mode on the left; BFS ids follow levels.
        let t5 = DimTree::balanced(5);
        assert_eq!(t5.len(), 9);
        assert_eq!((t5.node(1).lo, t5.node(1).hi), (0, 3));
        assert_eq!((t5.node(2).lo, t5.node(2).hi), (3, 5));
        for t in 0..t5.len() {
            if let Some((l, r)) = t5.node(t).children {
                assert!(l > t && r > t, "children must come after the parent");
                assert_eq!(t5.node(l).hi, t5.node(r).lo);
            }
        }
    }

    #[test]
    fn d2_reconstruction_matches_manual_contraction() {
        // dims [3, 4], all edge ranks 2: A[i,j] = Σ_{j1,j2} U1[i,j1]·U2[j,j2]·B[j2,j1].
        let mut rng = Rng::new(7);
        let ht = HtTensor::<f64>::rand_uniform(&[3, 4], 2, &mut rng).unwrap();
        let u1 = ht.node(1).mat();
        let u2 = ht.node(2).mat();
        let b = ht.node(0).mat(); // r2 × (r1·1)
        let full = ht.reconstruct();
        for i in 0..3 {
            for j in 0..4 {
                let mut want = 0.0;
                for j1 in 0..2 {
                    for j2 in 0..2 {
                        want += u1[(i, j1)] * u2[(j, j2)] * b[(j2, j1)];
                    }
                }
                let got = full.get(&[i, j]);
                assert!((got - want).abs() < 1e-12, "A[{i},{j}]: {got} vs {want}");
            }
        }
    }

    #[test]
    fn rand_uniform_reconstructs_nonneg() {
        let mut rng = Rng::new(3);
        let ht = HtTensor::<f64>::rand_uniform(&[4, 3, 5, 2], 2, &mut rng).unwrap();
        assert!(ht.is_nonneg());
        assert_eq!(ht.ranks()[0], 1);
        assert!(ht.ranks()[1..].iter().all(|&r| r == 2));
        let full = ht.reconstruct();
        assert_eq!(full.dims(), &[4, 3, 5, 2]);
        assert!(full.is_nonneg());
        assert!(ht.compression_ratio().is_finite() && ht.compression_ratio() > 0.0);
    }

    #[test]
    fn num_params_counts_all_nodes() {
        let mut rng = Rng::new(4);
        let ht = HtTensor::<f64>::rand_uniform(&[3, 3, 3], 2, &mut rng).unwrap();
        // Tree: root [0,3) → ([0,2), leaf 2); [0,2) → leaf 0, leaf 1.
        // Payloads: root B 2×2, node1 B 2×(2·2), leaf2 3×2, leaf0 3×2, leaf1 3×2.
        assert_eq!(ht.num_params(), 4 + 8 + 6 + 6 + 6);
    }

    #[test]
    fn from_nodes_roundtrips_and_validates() {
        for d in 1..=9 {
            let tree = DimTree::balanced(d);
            let rebuilt = DimTree::from_nodes((0..tree.len()).map(|t| tree.node(t)).collect());
            assert_eq!(rebuilt.unwrap(), tree, "d = {d}");
        }
        // Children must come after the parent and partition its range.
        let cyclic = vec![TreeNode { lo: 0, hi: 2, children: Some((0, 1)) }, TreeNode {
            lo: 0,
            hi: 2,
            children: None,
        }];
        assert!(DimTree::from_nodes(cyclic).is_err());
        let gap = vec![
            TreeNode { lo: 0, hi: 3, children: Some((1, 2)) },
            TreeNode { lo: 0, hi: 1, children: None },
            TreeNode { lo: 2, hi: 3, children: None },
        ];
        assert!(DimTree::from_nodes(gap).is_err());
        let fat_leaf = vec![TreeNode { lo: 0, hi: 2, children: None }];
        assert!(DimTree::from_nodes(fat_leaf).is_err());
        assert!(DimTree::from_nodes(Vec::new()).is_err());
    }

    #[test]
    fn compression_ratio_vs_counts_sparse_storage() {
        let mut rng = Rng::new(11);
        let ht = HtTensor::<f64>::rand_uniform(&[8, 8, 8, 8], 3, &mut rng).unwrap();
        let dense = 8f64.powi(4);
        assert!((ht.compression_ratio_vs(dense) - ht.compression_ratio()).abs() < 1e-12);
        let honest = ht.compression_ratio_vs(dense * 0.1);
        assert!((honest - ht.compression_ratio() * 0.1).abs() < 1e-9);
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        let tree = DimTree::balanced(2);
        let ok = vec![
            HtNode::Transfer(Mat::<f64>::zeros(2, 2)), // r2=2, r1·rt = 2·1
            HtNode::Leaf(Mat::<f64>::zeros(3, 2)),
            HtNode::Leaf(Mat::<f64>::zeros(4, 2)),
        ];
        assert!(HtTensor::new(vec![3, 4], tree.clone(), ok.clone()).is_ok());
        // Root edge rank != 1.
        let bad_root = vec![
            HtNode::Transfer(Mat::<f64>::zeros(2, 4)),
            HtNode::Leaf(Mat::<f64>::zeros(3, 2)),
            HtNode::Leaf(Mat::<f64>::zeros(4, 2)),
        ];
        assert!(HtTensor::new(vec![3, 4], tree.clone(), bad_root).is_err());
        // Leaf rows mismatch the mode size.
        let bad_leaf = vec![
            HtNode::Transfer(Mat::<f64>::zeros(2, 2)),
            HtNode::Leaf(Mat::<f64>::zeros(5, 2)),
            HtNode::Leaf(Mat::<f64>::zeros(4, 2)),
        ];
        assert!(HtTensor::new(vec![3, 4], tree.clone(), bad_leaf).is_err());
        // Payload kind mismatch.
        let bad_kind = vec![
            HtNode::Leaf(Mat::<f64>::zeros(12, 1)),
            HtNode::Leaf(Mat::<f64>::zeros(3, 2)),
            HtNode::Leaf(Mat::<f64>::zeros(4, 2)),
        ];
        assert!(HtTensor::new(vec![3, 4], tree, bad_kind).is_err());
    }

    #[test]
    fn exact_ht_has_zero_rel_error_vs_itself() {
        let mut rng = Rng::new(9);
        let ht = HtTensor::<f64>::rand_uniform(&[4, 5, 3], 3, &mut rng).unwrap();
        let full = ht.reconstruct();
        assert!(ht.rel_error(&full) < 1e-12);
    }
}
