//! # dntt — Distributed Non-Negative Tensor Train Decomposition
//!
//! A production-grade reproduction of *"Distributed Non-Negative Tensor
//! Train Decomposition"* (Bhattarai et al., LANL 2020) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed coordinator: thread-rank
//!   communicator with MPI-style collectives, chunked array store with
//!   global reshape (Alg 1) over dense **and sparse** chunks, distributed
//!   SVD rank selection, distributed BCD/MU NMF (Algs 3–6) with
//!   per-chunk dense/sparse kernel dispatch, and two tensor-network
//!   drivers: the tensor train (Alg 2, `ttrain`) and the hierarchical
//!   Tucker (`ht`) over the balanced dimension tree — the same
//!   two-network family as LANL's pyDNTNK. The `serve` layer turns a
//!   finished decomposition into a batch-queryable artifact
//!   (point/fiber/slice queries, TT contraction, rounding to an ε or
//!   rank budget) persisted through `tensor::io`. Above the single-job
//!   path, `coordinator::server` runs decomposition as a *service*: a
//!   `JobServer` schedules queued jobs onto a shared `dist::RankPool`
//!   with priority/fair-share admission and a fingerprint-keyed result
//!   cache (`serve::cache`), fed by the on-disk `dntt-job-v1` spool and
//!   the `dntt submit`/`serve` CLI (see `rust/OPERATIONS.md`).
//! * **L2/L1 (`python/compile/`)** — the NMF inner iteration as a JAX
//!   graph built from Pallas kernels, AOT-lowered to HLO text at build time.
//! * **Runtime (`runtime`)** — loads the AOT artifacts through the `xla`
//!   crate's PJRT CPU client; Python is never on the execution path.
//!
//! See `rust/ARCHITECTURE.md` for the module map and data flow, and
//! `rust/DESIGN.md` for the full system inventory, the `dist` API
//! contract (sparse chunk storage in §2.7), and the experiment index
//! (each figure's bench target and CLI command).

// Keep rustdoc references like `crate::dist::Layout::HtGrid` honest.
#![deny(rustdoc::broken_intra_doc_links)]

pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod error;
pub mod ht;
pub mod linalg;
pub mod nmf;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod ttrain;
pub mod util;

pub use error::Result;
