//! A pool of reusable worker ranks for running many SPMD worlds.
//!
//! [`Comm::run`] owns the classic spawn-and-join shape: `p` threads are
//! born for one job and die with it. A decomposition *service* (the
//! [`crate::coordinator::JobServer`]) instead keeps a fixed pool of
//! long-lived worker threads and **leases** subsets of them to successive
//! jobs: a job needing `p` ranks takes a [`Lease`] of `p` workers, runs
//! any number of worlds on it (relaunch attempts after a lost rank reuse
//! the same lease), and returns the workers to the pool when dropped —
//! so several jobs of mixed size execute concurrently on one bounded set
//! of OS threads.
//!
//! # Determinism and isolation
//!
//! A leased world is **bitwise-identical** to a spawned one: both
//! launchers route every rank through the same
//! `comm::run_rank_body`, world ranks `0..p` are assigned by lease
//! position (never by physical worker id), each world gets a fresh
//! rendezvous table, and the numerics depend only on the rank-ordered
//! collective semantics of [`Comm`] — not on which OS thread hosts a
//! rank (asserted by `pooled_world_matches_spawned_bitwise` below and by
//! `tests/job_server.rs` end to end). Rank-scoped state (fault plans,
//! trace rings, log prefixes) is installed and torn down per world, so a
//! reused worker leaks nothing between jobs. Concurrent leases share
//! nothing but the free-list mutex: each world has its own
//! `WorldState`, and a panic (or injected rank death) poisons only its
//! own world — the workers survive and return to the pool.
//!
//! Like [`Comm::run`], [`Lease::run_world`] snapshots the fault plan and
//! trace collector armed on the *calling* thread, which is how the job
//! server scopes per-job tracing: each job's runner thread arms its own
//! collector before launching the world (see [`crate::obs`]).

use crate::dist::comm::{run_rank_body, Comm, WorldState};
use std::panic::resume_unwind;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// One unit of work shipped to a pool worker (a fully-bound rank body).
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Free-list shared between the pool and its outstanding leases.
struct PoolShared {
    free: Mutex<Vec<usize>>,
    cv: Condvar,
}

/// A fixed set of long-lived worker threads that host SPMD ranks.
///
/// Dropping the pool shuts the workers down and joins them; any
/// outstanding [`Lease`] keeps its workers' channels alive, so the drop
/// blocks until every lease has been released.
pub struct RankPool {
    shared: Arc<PoolShared>,
    senders: Vec<Sender<Task>>,
    threads: Vec<JoinHandle<()>>,
}

impl RankPool {
    /// Spawn a pool of `workers` rank threads.
    pub fn new(workers: usize) -> RankPool {
        assert!(workers > 0, "RankPool needs at least one worker");
        let shared = Arc::new(PoolShared {
            free: Mutex::new((0..workers).rev().collect()),
            cv: Condvar::new(),
        });
        let mut senders = Vec::with_capacity(workers);
        let mut threads = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, rx) = channel::<Task>();
            senders.push(tx);
            let handle = std::thread::Builder::new()
                .name(format!("dntt-pool-{i}"))
                .spawn(move || {
                    // Run tasks until the pool drops our sender (and every
                    // lease holding a clone of it has been released).
                    while let Ok(task) = rx.recv() {
                        task();
                    }
                })
                .expect("spawning pool worker");
            threads.push(handle);
        }
        RankPool { shared, senders, threads }
    }

    /// Total number of workers in the pool.
    pub fn size(&self) -> usize {
        self.senders.len()
    }

    /// Workers not currently leased.
    pub fn available(&self) -> usize {
        self.shared.free.lock().unwrap().len()
    }

    /// Lease `p` workers if that many are free right now (the job
    /// server's admission primitive — it decides *which* job gets
    /// capacity, so this never blocks).
    pub fn try_lease(&self, p: usize) -> Option<Lease> {
        assert!(p > 0, "a lease needs at least one rank");
        if p > self.size() {
            return None;
        }
        let mut free = self.shared.free.lock().unwrap();
        if free.len() < p {
            return None;
        }
        let ids: Vec<usize> = free.split_off(free.len() - p);
        drop(free);
        Some(self.make_lease(ids))
    }

    /// Lease `p` workers, blocking until enough are free. Panics if the
    /// pool is smaller than `p` (that can never succeed).
    pub fn lease(&self, p: usize) -> Lease {
        assert!(p > 0, "a lease needs at least one rank");
        assert!(
            p <= self.size(),
            "lease of {p} ranks exceeds pool of {} workers",
            self.size()
        );
        let mut free = self.shared.free.lock().unwrap();
        while free.len() < p {
            free = self.shared.cv.wait(free).unwrap();
        }
        let ids: Vec<usize> = free.split_off(free.len() - p);
        drop(free);
        self.make_lease(ids)
    }

    fn make_lease(&self, ids: Vec<usize>) -> Lease {
        let senders = ids.iter().map(|&i| self.senders[i].clone()).collect();
        Lease { shared: Arc::clone(&self.shared), senders, ids }
    }
}

impl Drop for RankPool {
    fn drop(&mut self) {
        // Closing our senders ends each worker's recv loop once every
        // lease clone is gone too.
        self.senders.clear();
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

/// An exclusive claim on `p` pool workers, valid for any number of world
/// launches. Returned to the pool on drop.
pub struct Lease {
    shared: Arc<PoolShared>,
    senders: Vec<Sender<Task>>,
    ids: Vec<usize>,
}

impl Lease {
    /// Number of ranks this lease can host.
    pub fn size(&self) -> usize {
        self.ids.len()
    }

    /// Run one SPMD world of `self.size()` ranks on the leased workers
    /// and return the per-rank results in rank order — the pooled
    /// equivalent of [`Comm::run`], including its panic semantics: if
    /// any rank panics the world is poisoned, every rank unwinds, and
    /// the first panic payload (in rank order) is re-raised here after
    /// **all** ranks have finished, so the workers are guaranteed idle
    /// again before the caller observes the failure.
    ///
    /// `'static` bounds (unlike [`Comm::run`]) because the closure
    /// crosses into long-lived worker threads; share state via `Arc`.
    pub fn run_world<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce(Comm) -> T + Clone + Send + 'static,
    {
        let p = self.size();
        let world = Arc::new(WorldState::new());
        // Same caller-thread snapshot as Comm::run: the fault plan and
        // trace collector armed on the launching thread scope this world.
        let plan = crate::dist::faults::armed();
        let obs = crate::obs::armed();
        let (tx, rx) = channel::<(usize, std::thread::Result<T>)>();
        for (rank, sender) in self.senders.iter().enumerate() {
            let f = f.clone();
            let ws = Arc::clone(&world);
            let plan = plan.clone();
            let obs = obs.clone();
            let tx = tx.clone();
            let task: Task = Box::new(move || {
                let out = run_rank_body(ws, plan, obs, rank, p, f);
                let _ = tx.send((rank, out));
            });
            sender.send(task).expect("pool worker died");
        }
        drop(tx);
        // The receive loop ends when every task (each holding a sender
        // clone) has completed — a barrier guaranteeing worker idleness.
        let mut slots: Vec<Option<std::thread::Result<T>>> = (0..p).map(|_| None).collect();
        for (rank, out) in rx {
            slots[rank] = Some(out);
        }
        let mut outs = Vec::with_capacity(p);
        for slot in slots {
            match slot.expect("every rank reports exactly once") {
                Ok(v) => outs.push(v),
                Err(payload) => resume_unwind(payload),
            }
        }
        outs
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        let mut free = self.shared.free.lock().unwrap();
        free.extend(self.ids.drain(..));
        drop(free);
        self.shared.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    /// A rank body with non-trivial float reductions whose result is
    /// sensitive to any change in collective order or membership.
    fn world_body(mut c: Comm) -> Vec<f64> {
        let mut v = vec![0.1 * (c.rank() as f64 + 1.0); 4];
        c.all_reduce_sum(&mut v);
        let g = c.all_gather(&v[..2]);
        let s = c.all_reduce_scalar(g.iter().sum());
        v.push(s);
        v
    }

    #[test]
    fn pooled_world_matches_spawned_bitwise() {
        let spawned = Comm::run(4, world_body);
        let pool = RankPool::new(6);
        let lease = pool.lease(4);
        let pooled = lease.run_world(world_body);
        assert_eq!(pooled.len(), 4);
        for (a, b) in spawned.iter().zip(&pooled) {
            assert_eq!(a.as_slice(), b.as_slice(), "pooled ranks must match spawned bitwise");
        }
    }

    #[test]
    fn lease_accounting_and_reuse() {
        let pool = RankPool::new(5);
        assert_eq!(pool.size(), 5);
        assert_eq!(pool.available(), 5);
        let a = pool.lease(2);
        let b = pool.try_lease(2).expect("capacity for a second lease");
        assert_eq!(pool.available(), 1);
        assert!(pool.try_lease(2).is_none(), "only one worker left");
        // Successive worlds on one lease reuse the same workers.
        let first = a.run_world(|c| c.rank());
        let second = a.run_world(|c| c.rank() * 10);
        assert_eq!(first, vec![0, 1]);
        assert_eq!(second, vec![0, 10]);
        drop(a);
        drop(b);
        assert_eq!(pool.available(), 5);
    }

    #[test]
    fn concurrent_leases_run_isolated_worlds() {
        let pool = Arc::new(RankPool::new(4));
        let p2 = Arc::clone(&pool);
        let a = pool.lease(2);
        let t = std::thread::spawn(move || {
            let b = p2.lease(2);
            b.run_world(|mut c| {
                let mut v = vec![2.0];
                c.all_reduce_sum(&mut v);
                v[0]
            })
        });
        let ra = a.run_world(|mut c| {
            let mut v = vec![1.0];
            c.all_reduce_sum(&mut v);
            v[0]
        });
        let rb = t.join().unwrap();
        assert_eq!(ra, vec![2.0, 2.0]);
        assert_eq!(rb, vec![4.0, 4.0]);
    }

    #[test]
    fn panicking_world_poisons_but_pool_survives() {
        let pool = RankPool::new(3);
        let lease = pool.lease(3);
        let result = catch_unwind(AssertUnwindSafe(|| {
            lease.run_world(|mut c| {
                if c.rank() == 2 {
                    panic!("boom");
                }
                c.barrier(); // would deadlock without poisoning
            })
        }));
        assert!(result.is_err(), "the panic must propagate to the launcher");
        // The same lease (and therefore the same workers) still hosts a
        // healthy follow-up world.
        let again = lease.run_world(|c| c.rank() + 100);
        assert_eq!(again, vec![100, 101, 102]);
    }

    #[test]
    fn blocking_lease_waits_for_release() {
        let pool = Arc::new(RankPool::new(2));
        let held = pool.lease(2);
        let p2 = Arc::clone(&pool);
        let t = std::thread::spawn(move || {
            let l = p2.lease(1); // blocks until `held` drops
            l.run_world(|c| c.size())
        });
        // Give the waiter a moment to block, then release.
        std::thread::sleep(std::time::Duration::from_millis(20));
        drop(held);
        assert_eq!(t.join().unwrap(), vec![1]);
    }
}
