//! Deterministic fault injection at collective boundaries.
//!
//! Exascale runs lose ranks routinely; testing the recovery path requires
//! making "rank 3 dies during stage 2's 7th collective" a *deterministic,
//! replayable* event. A [`FaultPlan`] schedules rank deaths keyed by
//! `(world rank, per-rank collective count)`: every [`crate::dist::Comm`]
//! collective on a rank increments that rank's op counter, and when a
//! scheduled `(rank, op)` pair is reached the rank panics with a
//! [`RankLostPanic`] payload. The existing poison-on-panic machinery then
//! unwinds the whole world, and the coordinator surfaces the event as the
//! typed [`crate::error::DnttError::RankLost`] — resumable under
//! `--resume auto` from the last durable checkpoint
//! (see [`crate::dist::checkpoint`]).
//!
//! # Zero-cost default
//!
//! All injection plumbing is compiled **only** under the `fault-inject`
//! cargo feature. In a default build the `on_collective` hook is an empty
//! `#[inline(always)]` function and [`arm`] / [`armed`] are no-ops, so
//! the `Comm` hot path carries no fault-injection code whatsoever —
//! asserted by the default-features test in `tests/checkpoint_recovery.rs`
//! via [`FAULT_INJECT_ENABLED`].
//!
//! # Determinism contract
//!
//! Collectives execute in SPMD program order, so a rank's op counter is a
//! pure function of the job configuration: the same plan against the same
//! job kills the same collective every time. Counters are **per attempt**
//! (they reset when a new world starts), while each [`Kill`] fires at most
//! once per plan — so a relaunched world replays past the original death
//! site instead of dying there forever.
//!
//! # Scoping
//!
//! [`arm`] installs the plan in a *caller-thread-local* slot; only worlds
//! started from that thread (i.e. `Comm::run` called on it) observe the
//! plan. Tests running concurrently on other threads are unaffected.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// `true` when the crate was built with `--features fault-inject`.
pub const FAULT_INJECT_ENABLED: bool = cfg!(feature = "fault-inject");

/// One scheduled rank death: world rank `rank` panics immediately before
/// entering its `op`-th collective (1-based, counted per rank across the
/// world communicator and all sub-communicators alike).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Kill {
    pub rank: usize,
    pub op: u64,
}

/// Panic payload of an injected rank death (what distinguishes a
/// scheduled fault from a genuine bug when the coordinator inspects a
/// poisoned world).
#[derive(Clone, Copy, Debug)]
pub struct RankLostPanic {
    pub rank: usize,
    pub op: u64,
}

/// A deterministic schedule of rank deaths plus per-rank op accounting.
///
/// Construct with [`FaultPlan::new`] / [`FaultPlan::kill_at`] /
/// [`FaultPlan::seeded`], install with [`arm`], and inspect afterwards
/// with [`FaultPlan::fired_count`] / [`FaultPlan::last_fired`] /
/// [`FaultPlan::ops_seen`]. An empty plan is a pure op counter — useful
/// for sizing a kill-at-every-collective sweep.
pub struct FaultPlan {
    kills: Vec<Kill>,
    /// 0 = pending, 1 = fired; parallel to `kills`. Only consulted by
    /// the feature-gated `try_fire` (dead in default builds by design).
    #[cfg_attr(not(feature = "fault-inject"), allow(dead_code))]
    fired: Vec<AtomicU64>,
    fired_count: AtomicU64,
    /// Index+1 of the most recently fired kill (0 = none).
    last_fired: AtomicU64,
    /// Max collective count observed per rank (merged at rank exit).
    ops_seen: Mutex<Vec<u64>>,
}

impl FaultPlan {
    /// A plan with the given kill schedule.
    pub fn new(kills: Vec<Kill>) -> Arc<FaultPlan> {
        let fired = kills.iter().map(|_| AtomicU64::new(0)).collect();
        Arc::new(FaultPlan {
            kills,
            fired,
            fired_count: AtomicU64::new(0),
            last_fired: AtomicU64::new(0),
            ops_seen: Mutex::new(Vec::new()),
        })
    }

    /// A single scheduled death.
    pub fn kill_at(rank: usize, op: u64) -> Arc<FaultPlan> {
        FaultPlan::new(vec![Kill { rank, op }])
    }

    /// An empty plan (no deaths): arms pure op counting.
    pub fn count_only() -> Arc<FaultPlan> {
        FaultPlan::new(Vec::new())
    }

    /// One seeded death: the victim rank and op index are a pure function
    /// of `(seed, world, max_op)`, so a failure report is replayable from
    /// the seed alone.
    pub fn seeded(seed: u64, world: usize, max_op: u64) -> Arc<FaultPlan> {
        assert!(world > 0 && max_op > 0, "seeded fault plan needs a non-empty domain");
        let mut rng = crate::util::rng::Rng::new(seed ^ 0xFAu64.wrapping_shl(56));
        let rank = rng.below(world);
        let op = 1 + (rng.next_u64() % max_op);
        FaultPlan::kill_at(rank, op)
    }

    /// Parse a CLI plan: `"rank:op[,rank:op…]"` or `"seed:<u64>"` (the
    /// seeded form needs the world size to pick a victim).
    pub fn from_cli(s: &str, world: usize) -> Result<Arc<FaultPlan>, String> {
        if let Some(seed) = s.strip_prefix("seed:") {
            let seed: u64 = seed.trim().parse().map_err(|_| format!("bad fault seed '{seed}'"))?;
            return Ok(FaultPlan::seeded(seed, world, 10_000));
        }
        let mut kills = Vec::new();
        for part in s.split(',') {
            let (r, o) = part
                .split_once(':')
                .ok_or_else(|| format!("bad fault spec '{part}' (want rank:op)"))?;
            let rank: usize =
                r.trim().parse().map_err(|_| format!("bad fault rank '{r}'"))?;
            let op: u64 = o.trim().parse().map_err(|_| format!("bad fault op '{o}'"))?;
            if rank >= world {
                return Err(format!("fault rank {rank} out of range for {world} ranks"));
            }
            if op == 0 {
                return Err("fault op is 1-based; 0 never fires".into());
            }
            kills.push(Kill { rank, op });
        }
        Ok(FaultPlan::new(kills))
    }

    /// The scheduled kills.
    pub fn kills(&self) -> &[Kill] {
        &self.kills
    }

    /// How many scheduled kills have fired so far.
    pub fn fired_count(&self) -> u64 {
        self.fired_count.load(Ordering::SeqCst)
    }

    /// The most recently fired kill, if any.
    pub fn last_fired(&self) -> Option<Kill> {
        match self.last_fired.load(Ordering::SeqCst) {
            0 => None,
            k => Some(self.kills[(k - 1) as usize]),
        }
    }

    /// Max collective count observed on `rank` across all worlds this
    /// plan was armed for (0 if the rank never ran).
    pub fn ops_seen(&self, rank: usize) -> u64 {
        let seen = self.ops_seen.lock().unwrap();
        seen.get(rank).copied().unwrap_or(0)
    }

    /// Record a rank's final op count (max-merged; called at rank exit).
    #[cfg(feature = "fault-inject")]
    fn record_ops(&self, rank: usize, ops: u64) {
        let mut seen = self.ops_seen.lock().unwrap();
        if seen.len() <= rank {
            seen.resize(rank + 1, 0);
        }
        seen[rank] = seen[rank].max(ops);
    }

    /// Fire the first pending kill matching `(rank, op)`, if any.
    /// Returns the kill to panic with (the caller does the panicking so
    /// the unwind starts outside the plan's own locks).
    #[cfg(feature = "fault-inject")]
    fn try_fire(&self, rank: usize, op: u64) -> Option<Kill> {
        for (k, kill) in self.kills.iter().enumerate() {
            if kill.rank == rank
                && kill.op == op
                && self.fired[k]
                    .compare_exchange(0, 1, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
            {
                self.fired_count.fetch_add(1, Ordering::SeqCst);
                self.last_fired.store((k + 1) as u64, Ordering::SeqCst);
                return Some(*kill);
            }
        }
        None
    }
}

// ---------------------------------------------------------------------------
// Feature-gated plumbing. Under the default build every function below is
// an inline no-op (and `armed` returns `None`), so the communicator hot
// path compiles to exactly the seed code.
// ---------------------------------------------------------------------------

#[cfg(feature = "fault-inject")]
mod plumbing {
    use super::{FaultPlan, RankLostPanic};
    use std::cell::RefCell;
    use std::sync::Arc;

    struct RankState {
        plan: Arc<FaultPlan>,
        rank: usize,
        ops: u64,
    }

    thread_local! {
        /// Coordinator-thread slot: the plan worlds started from this
        /// thread will observe.
        static ARMED: RefCell<Option<Arc<FaultPlan>>> = const { RefCell::new(None) };
        /// Rank-thread slot: this rank's plan + op counter.
        static RANK: RefCell<Option<RankState>> = const { RefCell::new(None) };
    }

    pub fn arm(plan: &Arc<FaultPlan>) {
        ARMED.with(|a| *a.borrow_mut() = Some(Arc::clone(plan)));
    }

    pub fn disarm() {
        ARMED.with(|a| *a.borrow_mut() = None);
    }

    pub fn armed() -> Option<Arc<FaultPlan>> {
        ARMED.with(|a| a.borrow().clone())
    }

    pub fn enter_rank(plan: Option<Arc<FaultPlan>>, rank: usize) {
        RANK.with(|r| {
            *r.borrow_mut() = plan.map(|plan| RankState { plan, rank, ops: 0 });
        });
    }

    pub fn exit_rank() {
        RANK.with(|r| {
            if let Some(st) = r.borrow_mut().take() {
                st.plan.record_ops(st.rank, st.ops);
            }
        });
    }

    pub fn on_collective() {
        let fire = RANK.with(|r| {
            let mut r = r.borrow_mut();
            let st = r.as_mut()?;
            st.ops += 1;
            st.plan.try_fire(st.rank, st.ops)
        });
        if let Some(kill) = fire {
            log::warn!(
                "fault injection: rank {} dies at collective #{}",
                kill.rank,
                kill.op
            );
            std::panic::panic_any(RankLostPanic { rank: kill.rank, op: kill.op });
        }
    }
}

#[cfg(not(feature = "fault-inject"))]
mod plumbing {
    use super::FaultPlan;
    use std::sync::Arc;

    /// No-op without the `fault-inject` feature (the plan is never
    /// consulted, so a would-fire kill cannot fire).
    pub fn arm(_plan: &Arc<FaultPlan>) {}

    pub fn disarm() {}

    pub fn armed() -> Option<Arc<FaultPlan>> {
        None
    }

    #[inline(always)]
    pub fn enter_rank(_plan: Option<Arc<FaultPlan>>, _rank: usize) {}

    #[inline(always)]
    pub fn exit_rank() {}

    /// The `Comm` hot-path hook: literally empty in default builds.
    #[inline(always)]
    pub fn on_collective() {}
}

pub use plumbing::{arm, armed, disarm};
pub(crate) use plumbing::{enter_rank, exit_rank, on_collective};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plan_is_reproducible_and_in_range() {
        let a = FaultPlan::seeded(9, 4, 50);
        let b = FaultPlan::seeded(9, 4, 50);
        assert_eq!(a.kills(), b.kills());
        let k = a.kills()[0];
        assert!(k.rank < 4);
        assert!((1..=50).contains(&k.op));
        // Seeds spread over the domain: some other seed picks another site.
        assert!(
            (10..30).any(|s| FaultPlan::seeded(s, 4, 50).kills() != a.kills()),
            "seeded plans are not all identical"
        );
    }

    #[test]
    fn cli_parse_accepts_both_forms() {
        let p = FaultPlan::from_cli("1:7,0:3", 4).unwrap();
        assert_eq!(
            p.kills(),
            &[Kill { rank: 1, op: 7 }, Kill { rank: 0, op: 3 }]
        );
        assert!(FaultPlan::from_cli("seed:42", 4).is_ok());
        assert!(FaultPlan::from_cli("9:1", 4).is_err()); // rank out of range
        assert!(FaultPlan::from_cli("0:0", 4).is_err()); // op is 1-based
        assert!(FaultPlan::from_cli("nonsense", 4).is_err());
    }

    #[test]
    fn fresh_plan_reports_nothing_fired() {
        let p = FaultPlan::kill_at(2, 5);
        assert_eq!(p.fired_count(), 0);
        assert!(p.last_fired().is_none());
        assert_eq!(p.ops_seen(2), 0);
    }

    #[cfg(feature = "fault-inject")]
    #[test]
    fn kill_fires_exactly_once_and_counts_ops() {
        use crate::dist::Comm;
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let plan = FaultPlan::kill_at(1, 2);
        arm(&plan);
        let result = catch_unwind(AssertUnwindSafe(|| {
            Comm::run(2, |mut c| {
                c.barrier();
                c.barrier();
                c.barrier();
            })
        }));
        disarm();
        assert!(result.is_err(), "injected death must unwind the world");
        assert_eq!(plan.fired_count(), 1);
        assert_eq!(plan.last_fired(), Some(Kill { rank: 1, op: 2 }));
        // Rank 0 survived to its poison check; its op count was recorded.
        assert!(plan.ops_seen(0) >= 1);
        // A second world with the same (consumed) plan runs clean.
        arm(&plan);
        let outs = Comm::run(2, |mut c| {
            c.barrier();
            c.barrier();
            c.barrier();
            c.rank()
        });
        disarm();
        assert_eq!(outs, vec![0, 1]);
        assert_eq!(plan.fired_count(), 1, "kills fire at most once");
        assert_eq!(plan.ops_seen(1), 3);
    }

    #[cfg(feature = "fault-inject")]
    #[test]
    fn unarmed_worlds_never_fire() {
        use crate::dist::Comm;
        let plan = FaultPlan::kill_at(0, 1);
        // Not armed: the plan is never consulted.
        let outs = Comm::run(2, |mut c| {
            c.barrier();
            c.rank()
        });
        assert_eq!(outs, vec![0, 1]);
        assert_eq!(plan.fired_count(), 0);
    }
}
