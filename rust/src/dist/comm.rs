//! The SPMD thread-rank communicator.
//!
//! [`Comm::run`] spawns `p` OS threads ("ranks") that execute the same
//! closure — the thread-rank analogue of `mpiexec -n p` in the paper's
//! mpi4py implementation. Ranks communicate only through MPI-style
//! collectives (`all_reduce_sum`, `all_gather_varied`,
//! `reduce_scatter_uneven`, …), so the SPMD code in `nmf::dist`,
//! `ttrain::rankselect` and `ttrain::driver` is structured exactly like a
//! real MPI program and could be retargeted to one communicator-call for
//! communicator-call.
//!
//! # Semantics (the contract the rest of the crate compiles against)
//!
//! * Every collective is **bulk-synchronous**: all members of a
//!   communicator must call the same sequence of collectives in the same
//!   order (SPMD discipline). A rank that diverges deadlocks its peers; a
//!   rank that panics *poisons* the world so every other rank panics
//!   instead of hanging (important for `cargo test` robustness).
//! * Reductions are **deterministic and rank-identical**: contributions
//!   are combined in rank order `0..p` on every rank, so all ranks obtain
//!   bitwise-identical results. Tests rely on this to compare `p = 1`
//!   and `p > 1` runs exactly.
//! * Every collective records wall time and payload bytes into the public
//!   [`Breakdown`] under the paper's cost categories (AG / AR / RSC),
//!   which is what Figs 5–7 plot and what [`crate::dist::CostModel`]
//!   extrapolates to a cluster.
//!
//! Collectives are implemented over a shared rendezvous table (one slot
//! per `(communicator, sequence-number)` pair) rather than point-to-point
//! queues; with `p` ≤ a few dozen thread ranks the `O(p²)` copy cost of
//! the dense exchange is irrelevant next to the GEMMs it synchronizes.

use crate::error::{DnttError, Result};
use crate::util::timer::{Breakdown, Cat};
use std::any::Any;
use std::collections::HashMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How long a waiting rank sleeps between poison-flag checks. Collectives
/// are woken by `notify_all` when the last member arrives; the timeout
/// only bounds how long a rank can be stuck behind a crashed peer.
const POISON_POLL: Duration = Duration::from_millis(25);

/// Key of one in-flight collective: (communicator id, op sequence number).
type SlotKey = (u64, u64);

/// One in-flight collective exchange.
struct Slot {
    items: Vec<Option<Box<dyn Any + Send>>>,
    deposited: usize,
    taken: usize,
}

impl Slot {
    fn new(size: usize) -> Self {
        Slot { items: (0..size).map(|_| None).collect(), deposited: 0, taken: 0 }
    }
}

/// State shared by every rank of one SPMD world (and all of its
/// sub-communicators) — whether the world's ranks are freshly spawned
/// threads ([`Comm::run`]) or leased pool workers
/// ([`crate::dist::RankPool`]).
pub(crate) struct WorldState {
    slots: Mutex<HashMap<SlotKey, Slot>>,
    cv: Condvar,
    poisoned: AtomicBool,
}

impl WorldState {
    pub(crate) fn new() -> Self {
        WorldState {
            slots: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            poisoned: AtomicBool::new(false),
        }
    }

    pub(crate) fn poison(&self) {
        self.poisoned.store(true, Ordering::SeqCst);
        let _guard = self.slots.lock().unwrap_or_else(|e| e.into_inner());
        self.cv.notify_all();
    }

    fn check_poison(&self) {
        if self.poisoned.load(Ordering::SeqCst) {
            panic!("SPMD world poisoned: another rank panicked inside Comm::run");
        }
    }
}

/// An MPI-style communicator handle for one thread rank.
///
/// Obtained from [`Comm::run`] (the world) or
/// [`crate::dist::Grid2d::make_subcomms`] (row/column sub-communicators).
/// All methods that communicate take `&mut self` because each handle
/// carries its own op-sequence counter and its own cost [`Breakdown`].
pub struct Comm {
    shared: Arc<WorldState>,
    /// Communicator id; equal on all members, distinct between
    /// communicators that are alive at the same time.
    id: u64,
    rank: usize,
    size: usize,
    /// Per-handle op counter; advances in lockstep across members because
    /// collectives are called in SPMD order.
    seq: u64,
    /// Next child-communicator id to hand out (world handles only).
    next_child: u64,
    /// Per-rank accumulated cost categories (public by design: SPMD code
    /// charges its local compute phases here too).
    pub breakdown: Breakdown,
}

impl Comm {
    /// Run `f` on `p` thread ranks and return the per-rank results in rank
    /// order. Blocks until every rank finishes.
    ///
    /// `f` must be `Clone` because each rank runs its own copy (captured
    /// state that must be *shared* rather than duplicated should be
    /// wrapped in `Arc`, e.g. [`crate::dist::SharedStore`]). If any rank
    /// panics the world is poisoned, all ranks unwind, and the panic is
    /// propagated to the caller.
    pub fn run<T, F>(p: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: FnOnce(Comm) -> T + Clone + Send,
    {
        assert!(p > 0, "Comm::run needs at least one rank");
        let shared = Arc::new(WorldState::new());
        // Snapshot the caller thread's fault plan and trace collector
        // (always `None` without their features) so injected deaths and
        // recorded traces are scoped to worlds started from the arming
        // thread.
        let fault_plan = crate::dist::faults::armed();
        let obs_collector = crate::obs::armed();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..p)
                .map(|rank| {
                    let f = f.clone();
                    let ws = Arc::clone(&shared);
                    let plan = fault_plan.clone();
                    let obs = obs_collector.clone();
                    scope.spawn(move || run_rank_body(ws, plan, obs, rank, p, f))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(Ok(v)) => v,
                    Ok(Err(payload)) => resume_unwind(payload),
                    Err(payload) => resume_unwind(payload),
                })
                .collect()
        })
    }

    /// This rank's index within the communicator, in `0..size()`.
    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks in the communicator.
    #[inline]
    pub fn size(&self) -> usize {
        self.size
    }

    /// Create a sub-communicator handle over the same world.
    ///
    /// Used by [`crate::dist::Grid2d::make_subcomms`]; `id` must be equal
    /// on all members and unique among live communicators.
    pub(crate) fn subcomm(&self, id: u64, rank: usize, size: usize) -> Comm {
        debug_assert!(rank < size);
        Comm {
            shared: Arc::clone(&self.shared),
            id,
            rank,
            size,
            seq: 0,
            next_child: u64::MAX,
            breakdown: Breakdown::new(),
        }
    }

    /// Reserve `n` child-communicator ids (SPMD-identical on all ranks
    /// because every rank performs the same reservations in the same
    /// order). Returns the first reserved id.
    pub(crate) fn alloc_child_ids(&mut self, n: u64) -> u64 {
        assert!(
            self.next_child != u64::MAX,
            "sub-communicators cannot currently spawn their own sub-communicators"
        );
        let base = self.next_child;
        self.next_child += n;
        base
    }

    /// The rendezvous primitive every collective is built on: deposit
    /// `value`, wait for all members, return everyone's contribution in
    /// rank order. Identical result vector on every member.
    fn exchange<T: Clone + Send + 'static>(&mut self, value: T) -> Vec<T> {
        // Deterministic fault injection fires here, before any shared
        // state is touched — an empty inline no-op in default builds
        // (see `dist::faults`).
        crate::dist::faults::on_collective();
        let key: SlotKey = (self.id, self.seq);
        self.seq += 1;
        let mut slots = self.shared.slots.lock().unwrap();
        {
            let slot = slots.entry(key).or_insert_with(|| Slot::new(self.size));
            debug_assert!(
                slot.items[self.rank].is_none(),
                "collective misuse: rank {} deposited twice into op {:?}",
                self.rank,
                key
            );
            slot.items[self.rank] = Some(Box::new(value));
            slot.deposited += 1;
            if slot.deposited == self.size {
                self.shared.cv.notify_all();
            }
        }
        loop {
            self.shared.check_poison();
            if slots.get(&key).map(|s| s.deposited == self.size).unwrap_or(false) {
                break;
            }
            slots = self.shared.cv.wait_timeout(slots, POISON_POLL).unwrap().0;
        }
        let out: Vec<T> = {
            let slot = slots.get(&key).expect("collective slot vanished");
            slot.items
                .iter()
                .map(|it| {
                    it.as_ref()
                        .expect("collective slot incomplete")
                        .downcast_ref::<T>()
                        .expect("collective type mismatch between ranks")
                        .clone()
                })
                .collect()
        };
        let all_taken = {
            let slot = slots.get_mut(&key).expect("collective slot vanished");
            slot.taken += 1;
            slot.taken == self.size
        };
        if all_taken {
            slots.remove(&key);
        }
        out
    }

    /// Abort the whole world: every rank blocked in a collective panics
    /// instead of waiting forever (the thread-rank `MPI_Abort`).
    ///
    /// For *rank-divergent* failures — e.g. one rank's spill write failing
    /// while its peers proceed into a barrier — where returning an error
    /// from just this rank would deadlock the SPMD program. Symmetric
    /// errors (same validation failing on every rank) should return
    /// `Err` normally instead.
    pub fn abort(&self, reason: &str) {
        log::error!("SPMD abort by rank {}: {reason}", self.rank);
        self.shared.poison();
    }

    /// Synchronize all members. Reusable any number of times; charged to
    /// the `Other` category (barriers separate phases, they are not one of
    /// the paper's plotted costs).
    pub fn barrier(&mut self) {
        let span = crate::obs::span_begin();
        let t0 = Instant::now();
        let _ = self.exchange(());
        self.breakdown.add_secs(Cat::Other, t0.elapsed().as_secs_f64());
        crate::obs::end_collective(span, Cat::Other, 0);
    }

    /// Element-wise sum of `data` over all members, written back into
    /// `data` (MPI `MPI_Allreduce(+)`). Every rank sums contributions in
    /// rank order, so results are bitwise identical across ranks.
    pub fn all_reduce_sum(&mut self, data: &mut [f64]) {
        let span = crate::obs::span_begin();
        let t0 = Instant::now();
        let parts = self.exchange(data.to_vec());
        data.iter_mut().for_each(|x| *x = 0.0);
        for part in &parts {
            debug_assert_eq!(part.len(), data.len(), "all_reduce_sum length mismatch");
            for (d, s) in data.iter_mut().zip(part) {
                *d += *s;
            }
        }
        self.breakdown.add_secs(Cat::AllReduce, t0.elapsed().as_secs_f64());
        self.breakdown.add_bytes(Cat::AllReduce, (data.len() * 8) as u64);
        crate::obs::end_collective(span, Cat::AllReduce, (data.len() * 8) as u64);
    }

    /// Sum one scalar over all members (in rank order on every rank).
    pub fn all_reduce_scalar(&mut self, x: f64) -> f64 {
        let span = crate::obs::span_begin();
        let t0 = Instant::now();
        let sum: f64 = self.exchange(x).iter().sum();
        self.breakdown.add_secs(Cat::AllReduce, t0.elapsed().as_secs_f64());
        self.breakdown.add_bytes(Cat::AllReduce, 8);
        crate::obs::end_collective(span, Cat::AllReduce, 8);
        sum
    }

    /// Gather equal-size contributions and concatenate them in rank order
    /// (MPI `MPI_Allgather`).
    pub fn all_gather(&mut self, data: &[f64]) -> Vec<f64> {
        let parts = self.all_gather_varied(data);
        let mut out = Vec::with_capacity(parts.iter().map(Vec::len).sum());
        for p in parts {
            out.extend_from_slice(&p);
        }
        out
    }

    /// Gather possibly different-size contributions; returns one `Vec` per
    /// rank, in rank order (MPI `MPI_Allgatherv`). Empty contributions are
    /// allowed.
    pub fn all_gather_varied(&mut self, data: &[f64]) -> Vec<Vec<f64>> {
        let span = crate::obs::span_begin();
        let t0 = Instant::now();
        let parts = self.exchange(data.to_vec());
        let total: usize = parts.iter().map(Vec::len).sum();
        self.breakdown.add_secs(Cat::AllGather, t0.elapsed().as_secs_f64());
        self.breakdown.add_bytes(Cat::AllGather, (total * 8) as u64);
        crate::obs::end_collective(span, Cat::AllGather, (total * 8) as u64);
        parts
    }

    /// Gather one arbitrary `Clone + Send` value per rank, in rank order.
    /// Used for metadata (e.g. merging per-rank [`Breakdown`]s); payload
    /// bytes are not tracked because the size is unknown.
    pub fn all_gather_any<T: Clone + Send + 'static>(&mut self, value: T) -> Vec<T> {
        let span = crate::obs::span_begin();
        let t0 = Instant::now();
        let parts = self.exchange(value);
        self.breakdown.add_secs(Cat::AllGather, t0.elapsed().as_secs_f64());
        crate::obs::end_collective(span, Cat::AllGather, 0);
        parts
    }

    /// Reduce (sum) full-length contributions, then scatter contiguous
    /// segments of `counts[k]` elements to rank `k` (MPI
    /// `MPI_Reduce_scatter`). `counts` must have one entry per rank (zeros
    /// allowed) and sum to `data.len()`, identically on every rank.
    pub fn reduce_scatter_uneven(&mut self, data: &[f64], counts: &[usize]) -> Result<Vec<f64>> {
        if counts.len() != self.size {
            return Err(DnttError::Comm(format!(
                "reduce_scatter_uneven: {} counts for {} ranks",
                counts.len(),
                self.size
            )));
        }
        let total: usize = counts.iter().sum();
        if total != data.len() {
            return Err(DnttError::Comm(format!(
                "reduce_scatter_uneven: counts sum to {total}, buffer has {}",
                data.len()
            )));
        }
        let span = crate::obs::span_begin();
        let t0 = Instant::now();
        let parts = self.exchange(data.to_vec());
        let offset: usize = counts[..self.rank].iter().sum();
        let mine = counts[self.rank];
        let mut out = vec![0.0; mine];
        for part in &parts {
            debug_assert_eq!(part.len(), data.len(), "reduce_scatter length mismatch");
            for (d, s) in out.iter_mut().zip(&part[offset..offset + mine]) {
                *d += *s;
            }
        }
        self.breakdown.add_secs(Cat::ReduceScatter, t0.elapsed().as_secs_f64());
        self.breakdown.add_bytes(Cat::ReduceScatter, (data.len() * 8) as u64);
        crate::obs::end_collective(span, Cat::ReduceScatter, (data.len() * 8) as u64);
        Ok(out)
    }

    /// Even [`Comm::reduce_scatter_uneven`]: `data.len()` must be a
    /// multiple of `size()`; rank `k` receives elements
    /// `[k·len/p, (k+1)·len/p)` of the sum.
    pub fn reduce_scatter_sum(&mut self, data: &[f64]) -> Result<Vec<f64>> {
        if data.len() % self.size != 0 {
            return Err(DnttError::Comm(format!(
                "reduce_scatter_sum: buffer of {} not divisible by {} ranks",
                data.len(),
                self.size
            )));
        }
        let each = data.len() / self.size;
        let counts = vec![each; self.size];
        self.reduce_scatter_uneven(data, &counts)
    }
}

/// The shared per-rank body of every SPMD world launch: construct this
/// rank's world [`Comm`] handle, install the rank-scoped fault/trace/log
/// state, run `f` under `catch_unwind`, tear the state back down, and
/// poison the world on panic so peers blocked in collectives unwind too.
///
/// Both world launchers route through here — [`Comm::run`] (fresh scoped
/// threads) and [`crate::dist::Lease::run_world`] (leased pool workers) —
/// so a rank behaves identically regardless of which thread hosts it, and
/// a reused pool worker carries no rank state between jobs (the
/// enter/exit pairs are strictly scoped to this call).
pub(crate) fn run_rank_body<T, F>(
    shared: Arc<WorldState>,
    plan: Option<Arc<crate::dist::faults::FaultPlan>>,
    obs: Option<Arc<crate::obs::TraceCollector>>,
    rank: usize,
    size: usize,
    f: F,
) -> std::thread::Result<T>
where
    F: FnOnce(Comm) -> T,
{
    let comm = Comm {
        shared: Arc::clone(&shared),
        id: 0,
        rank,
        size,
        seq: 0,
        next_child: 1,
        breakdown: Breakdown::new(),
    };
    crate::dist::faults::enter_rank(plan, rank);
    crate::obs::enter_rank(obs, rank);
    crate::util::logging::set_thread_rank(rank);
    let out = catch_unwind(AssertUnwindSafe(|| f(comm)));
    crate::util::logging::clear_thread_rank();
    crate::obs::exit_rank();
    crate::dist::faults::exit_rank();
    if out.is_err() {
        shared.poison();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_rank_order() {
        let outs = Comm::run(5, |c| c.rank() * 10);
        assert_eq!(outs, vec![0, 10, 20, 30, 40]);
    }

    #[test]
    fn all_reduce_bitwise_identical_across_ranks() {
        let outs = Comm::run(4, |mut c| {
            let mut v = vec![0.1 * (c.rank() as f64 + 1.0); 3];
            c.all_reduce_sum(&mut v);
            v
        });
        for o in &outs[1..] {
            assert_eq!(o.as_slice(), outs[0].as_slice(), "ranks must agree bitwise");
        }
    }

    #[test]
    fn reduce_scatter_sum_even_split() {
        let outs = Comm::run(2, |mut c| {
            let data = vec![1.0, 2.0, 3.0, 4.0];
            c.reduce_scatter_sum(&data).unwrap()
        });
        assert_eq!(outs[0], vec![2.0, 4.0]);
        assert_eq!(outs[1], vec![6.0, 8.0]);
    }

    #[test]
    fn reduce_scatter_rejects_bad_counts() {
        let outs = Comm::run(1, |mut c| {
            let bad_len = c.reduce_scatter_uneven(&[1.0, 2.0], &[1]).is_err();
            let bad_ranks = c.reduce_scatter_uneven(&[1.0], &[1, 0]).is_err();
            (bad_len, bad_ranks)
        });
        assert_eq!(outs[0], (true, true));
    }

    #[test]
    fn gather_any_carries_structs() {
        let outs = Comm::run(3, |mut c| {
            let mut b = Breakdown::new();
            b.add_secs(Cat::MatMul, c.rank() as f64);
            let all = c.all_gather_any(b);
            all.iter().map(|x| x.secs(Cat::MatMul)).sum::<f64>()
        });
        assert!(outs.iter().all(|&s| s == 3.0));
    }

    #[test]
    fn breakdown_records_collective_costs() {
        let outs = Comm::run(2, |mut c| {
            let mut v = vec![1.0; 8];
            c.all_reduce_sum(&mut v);
            let _ = c.all_gather(&v);
            let _ = c.reduce_scatter_sum(&v).unwrap();
            (
                c.breakdown.calls(Cat::AllReduce),
                c.breakdown.calls(Cat::AllGather),
                c.breakdown.calls(Cat::ReduceScatter),
                c.breakdown.bytes(Cat::AllReduce),
            )
        });
        assert_eq!(outs[0], (1, 1, 1, 64));
    }

    #[test]
    fn panicking_rank_poisons_instead_of_hanging() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            Comm::run(2, |mut c| {
                if c.rank() == 1 {
                    panic!("boom");
                }
                c.barrier(); // would deadlock without poisoning
            })
        }));
        assert!(result.is_err());
    }
}
