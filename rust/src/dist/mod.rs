//! The distributed substrate: SPMD communicator, processor-grid layouts,
//! the shared chunk store with the Alg-1 global reshape, and the α-β
//! cluster cost model.
//!
//! This module is the API the rest of the crate compiles against:
//!
//! * [`Comm`] — thread-rank SPMD world ([`Comm::run`]) with MPI-style
//!   collectives and per-category cost accounting;
//! * [`ProcGrid`] / [`Grid2d`] / [`BlockDim`] — the d-dim tensor grid,
//!   its 2-D collapse for the NMF stages (with row/column
//!   sub-communicators via [`Grid2d::make_subcomms`]), and the 1-D block
//!   partition both are built from;
//! * [`chunkstore`] — [`SharedStore`] (+ [`SpillMode`] disk spill) and
//!   [`dist_reshape`], the paper's Algorithm 1;
//! * [`checkpoint`] — the `dntt-ckpt-v1` snapshot/resume subsystem
//!   ([`CheckpointPolicy`], stage snapshots, manifest validation);
//! * [`faults`] — deterministic fault injection at collective boundaries
//!   (compiled under the `fault-inject` cargo feature; a no-op otherwise);
//! * [`pool`] — [`RankPool`]/[`Lease`], long-lived worker ranks leased to
//!   successive jobs by the job server (bitwise-equivalent to
//!   [`Comm::run`] per world);
//! * [`CostModel`] — projects thread-rank measurements onto a cluster.
//!
//! The full contract (collective semantics, determinism guarantees,
//! layout definitions, spill behavior, checkpoint format) is documented
//! in `rust/DESIGN.md` and in the submodules' rustdoc.

pub mod checkpoint;
pub mod chunkstore;
pub mod comm;
pub mod costmodel;
pub mod faults;
pub mod pool;
pub mod topology;

pub use checkpoint::{CheckpointPolicy, CkptCtx};
pub use chunkstore::{
    dist_reshape, dist_reshape_x, Layout, SharedStore, SpillMode, StoreView, TensorBlock,
};
pub use comm::Comm;
pub use costmodel::CostModel;
pub use faults::FaultPlan;
pub use pool::{Lease, RankPool};
pub use topology::{BlockDim, Grid2d, ProcGrid};
