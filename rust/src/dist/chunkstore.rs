//! The chunked array store and the distributed global reshape (Alg 1).
//!
//! Between TT sweep stages the remainder array must be *globally*
//! redistributed: each rank owns a chunk under the current [`Layout`] and
//! needs its block of the next stage matrix under the 2-D `MatGrid`
//! distribution. The paper does this through a Zarr chunk store shared by
//! all MPI ranks; here [`SharedStore`] plays that role for thread ranks,
//! with an optional out-of-core [`SpillMode::Disk`] backend whose traffic
//! is accounted under the `IO` cost category.
//!
//! # Layouts
//!
//! A [`Layout`] maps the store's chunks onto one *logical row-major
//! array*; `Layout::locate` sends a global linear index to
//! `(chunk, offset within chunk)`:
//!
//! * [`Layout::TensorGrid`] — the input tensor blocked over the d-dim
//!   [`crate::dist::ProcGrid`]; chunk `r` is world rank `r`'s block,
//!   itself row-major (what [`crate::ttrain::driver::extract_block`]
//!   produces).
//! * [`Layout::MatGrid`] — an `m × n` matrix 2-D-blocked over a
//!   `pr × pc` [`crate::dist::Grid2d`].
//! * [`Layout::HtGrid`] — the NMF output `H: r × n` held transposed:
//!   rank `(i, j)` stores the `nh × r` row-major block `(Hʲ)ⁱᵀ` of
//!   `nmf::dist`. The logical array is `H` itself in row-major order,
//!   which *is* the next remainder tensor of Alg 2 — so the next stage's
//!   [`dist_reshape`] can consume `H` without any pre-pass.
//! * [`Layout::WGrid`] — the NMF output `W: m × r` distributed by rows in
//!   world-rank order: rank `(i, j)` stores the `mw × r` block `(Wⁱ)ʲ`.
//!   The logical array is `W` row-major — the left-child hand-off of the
//!   hierarchical-Tucker sweep (`crate::ht`).
//! * [`Layout::HtPermuted`] — the same chunks as an [`Layout::HtGrid`],
//!   but presenting the *permuted* logical order the HT right-child
//!   matricization needs (left-edge index moved from rows to columns).
//!
//! # Sparse chunks
//!
//! Every layout's chunks can be published **dense** (`Vec<f64>`, the
//! chunk's row-major buffer) or **sparse**
//! ([`crate::tensor::SparseChunk`], a sorted index/value view over the
//! same order), freely mixed within one array; [`TensorBlock`] is the
//! either-representation type the drivers hand in. Sparse chunks spill
//! in an nnz-sized record format and are read back through the same
//! [`StoreView`] (`read_into` zero-fills and scatters;
//! [`StoreView::read_nonzeros`] walks nonzeros directly).
//! [`dist_reshape_x`] assembles its output block as CSR when the global
//! stored density is at most [`SPARSE_RESHAPE_CUTOFF`]. The full
//! contract lives in `rust/DESIGN.md` §2.7.
//!
//! # Collective protocol
//!
//! [`dist_reshape`] is the one-call version of Alg 1: every rank
//! publishes its chunk, barriers, assembles its target block through a
//! [`StoreView`], barriers again, and rank 0 drops the array from the
//! store. `publish`/`view`/`remove` are also usable directly (the driver
//! does so for the final core gather).

use crate::dist::comm::Comm;
use crate::dist::topology::{BlockDim, Grid2d};
use crate::error::{DnttError, Result};
use crate::linalg::sparse::SparseMat;
use crate::linalg::{DenseOrSparse, Mat};
use crate::tensor::sparse::SparseChunk;
use crate::util::timer::Cat;
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Where published chunks live.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpillMode {
    /// Chunks stay in memory (shared by reference between ranks).
    Memory,
    /// Chunks are written to `<dir>/<name>.<chunk>.chunk` as little-endian
    /// `f64` and dropped from memory — the out-of-core path. Reads are
    /// counted by [`StoreView::disk_bytes_read`].
    Disk(PathBuf),
}

/// How a named array's chunks tile its logical row-major order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Layout {
    /// A dense tensor of shape `dims` blocked over the processor grid
    /// `grid` (same length, row-major rank order, per-mode [`BlockDim`]
    /// partition). Chunk data is the block in row-major order.
    TensorGrid { dims: Vec<usize>, grid: Vec<usize> },
    /// An `m × n` row-major matrix 2-D-blocked over a `pr × pc` grid;
    /// chunk `i·pc + j` is block `(i, j)` in row-major order.
    MatGrid { m: usize, n: usize, pr: usize, pc: usize },
    /// The transposed-H layout: logical array `H: r × n` (row-major);
    /// chunk `i·pc + j` holds columns
    /// `[cols.start_of(j) + sub.start_of(i), …)` of `H` — where
    /// `cols = BlockDim(n, pc)` and `sub = BlockDim(cols.size_of(j), pr)`
    /// — stored **transposed** as an `nh × r` row-major block.
    HtGrid { r: usize, n: usize, pr: usize, pc: usize },
    /// The row-distributed-W layout: logical array `W: m × r` (row-major);
    /// chunk `i·pc + j` holds rows
    /// `[rows.start_of(i) + sub.start_of(j), …)` of `W` — where
    /// `rows = BlockDim(m, pr)` and `sub = BlockDim(rows.size_of(i), pc)`
    /// — as an `mw × r` row-major block (the `(Wⁱ)ʲ` distribution of
    /// `nmf::dist`).
    WGrid { m: usize, r: usize, pr: usize, pc: usize },
    /// A permuted view of an NMF output `H: r × (n2·rt)` that keeps the
    /// chunks of `HtGrid { r, n: n2·rt, pr, pc }` but reorders the logical
    /// array from `H`'s row-major `(j1, i2, k)` to `(i2, j1, k)`: element
    /// `lin = (i2·r + j1)·rt + k` is `H[j1, i2·rt + k]`. This is the
    /// right-child matricization hand-off of the hierarchical-Tucker
    /// driver (`crate::ht`): the left-edge index `j1` and the parent-edge
    /// index `k` move to the columns so the next NMF factors over `i2`.
    HtPermuted { r: usize, n2: usize, rt: usize, pr: usize, pc: usize },
}

impl Layout {
    /// The `HtGrid` layout an [`Layout::HtPermuted`] shares its chunks
    /// with.
    fn permuted_inner(&self) -> Layout {
        match self {
            Layout::HtPermuted { r, n2, rt, pr, pc } => {
                Layout::HtGrid { r: *r, n: n2 * rt, pr: *pr, pc: *pc }
            }
            _ => unreachable!("permuted_inner is only defined for HtPermuted"),
        }
    }

    /// Total number of elements in the logical array.
    pub fn total_len(&self) -> usize {
        match self {
            Layout::TensorGrid { dims, .. } => dims.iter().product(),
            Layout::MatGrid { m, n, .. } => m * n,
            Layout::HtGrid { r, n, .. } => r * n,
            Layout::WGrid { m, r, .. } => m * r,
            Layout::HtPermuted { r, n2, rt, .. } => r * n2 * rt,
        }
    }

    /// Number of chunks the layout is split into.
    pub fn num_chunks(&self) -> usize {
        match self {
            Layout::TensorGrid { grid, .. } => grid.iter().product(),
            Layout::MatGrid { pr, pc, .. }
            | Layout::HtGrid { pr, pc, .. }
            | Layout::WGrid { pr, pc, .. }
            | Layout::HtPermuted { pr, pc, .. } => pr * pc,
        }
    }

    /// Number of elements in chunk `c`.
    pub fn chunk_len(&self, c: usize) -> usize {
        match self {
            Layout::TensorGrid { dims, grid } => {
                let mut rem = c;
                let mut coords = vec![0; grid.len()];
                for k in (0..grid.len()).rev() {
                    coords[k] = rem % grid[k];
                    rem /= grid[k];
                }
                dims.iter()
                    .zip(grid)
                    .zip(&coords)
                    .map(|((&n, &p), &ci)| BlockDim::new(n, p).size_of(ci))
                    .product()
            }
            Layout::MatGrid { m, n, pr, pc } => {
                let (i, j) = (c / pc, c % pc);
                BlockDim::new(*m, *pr).size_of(i) * BlockDim::new(*n, *pc).size_of(j)
            }
            Layout::HtGrid { r, n, pr, pc } => {
                let (i, j) = (c / pc, c % pc);
                let cols = BlockDim::new(*n, *pc);
                BlockDim::new(cols.size_of(j), *pr).size_of(i) * r
            }
            Layout::WGrid { m, r, pr, pc } => {
                let (i, j) = (c / pc, c % pc);
                let rows = BlockDim::new(*m, *pr);
                BlockDim::new(rows.size_of(i), *pc).size_of(j) * r
            }
            Layout::HtPermuted { .. } => self.permuted_inner().chunk_len(c),
        }
    }

    /// Map a global linear index of the logical row-major array to
    /// `(chunk, offset within chunk)`.
    pub fn locate(&self, lin: usize) -> (usize, usize) {
        let (chunk, offset, _) = self.locate_run(lin);
        (chunk, offset)
    }

    /// Like [`Layout::locate`], but also returns the number of consecutive
    /// linear indices starting at `lin` that map to *consecutive offsets in
    /// the same chunk* — the unit of contiguous copying. Runs follow the
    /// fastest axis: the last tensor mode within its block (`TensorGrid`),
    /// the columns within a column block (`MatGrid`); `HtGrid` stores `H`
    /// transposed so its runs are single elements.
    pub fn locate_run(&self, lin: usize) -> (usize, usize, usize) {
        debug_assert!(lin < self.total_len());
        match self {
            Layout::TensorGrid { dims, grid } => {
                let d = dims.len();
                let mut gidx = vec![0; d];
                let mut rem = lin;
                for k in (0..d).rev() {
                    gidx[k] = rem % dims[k];
                    rem /= dims[k];
                }
                let mut chunk = 0;
                let mut offset = 0;
                let mut run = 1;
                for k in 0..d {
                    let bd = BlockDim::new(dims[k], grid[k]);
                    let c = bd.owner_of(gidx[k]);
                    chunk = chunk * grid[k] + c;
                    offset = offset * bd.size_of(c) + (gidx[k] - bd.start_of(c));
                    if k == d - 1 {
                        // Contiguous along the last mode until its block ends.
                        run = bd.end_of(c) - gidx[k];
                    }
                }
                (chunk, offset, run)
            }
            Layout::MatGrid { n, m, pr, pc } => {
                let (gi, gj) = (lin / n, lin % n);
                let rows = BlockDim::new(*m, *pr);
                let cols = BlockDim::new(*n, *pc);
                let (i, j) = (rows.owner_of(gi), cols.owner_of(gj));
                let offset = (gi - rows.start_of(i)) * cols.size_of(j) + (gj - cols.start_of(j));
                (i * pc + j, offset, cols.end_of(j) - gj)
            }
            Layout::HtGrid { r, n, pr, pc } => {
                let (row, gcol) = (lin / n, lin % n);
                let cols = BlockDim::new(*n, *pc);
                let j = cols.owner_of(gcol);
                let within = gcol - cols.start_of(j);
                let sub = BlockDim::new(cols.size_of(j), *pr);
                let i = sub.owner_of(within);
                let local_col = within - sub.start_of(i);
                // Chunk data is nh × r row-major (H transposed): consecutive
                // columns of H are r elements apart, so runs are length 1.
                (i * pc + j, local_col * r + row, 1)
            }
            Layout::WGrid { m, r, pr, pc } => {
                let (grow, gcol) = (lin / r, lin % r);
                let rows = BlockDim::new(*m, *pr);
                let i = rows.owner_of(grow);
                let within = grow - rows.start_of(i);
                let sub = BlockDim::new(rows.size_of(i), *pc);
                let j = sub.owner_of(within);
                let local_row = within - sub.start_of(j);
                // Chunks are mw × r row-major blocks: contiguous to the end
                // of the current row.
                (i * pc + j, local_row * r + gcol, r - gcol)
            }
            Layout::HtPermuted { r, n2, rt, .. } => {
                let (i2, rem) = (lin / (r * rt), lin % (r * rt));
                let (j1, k) = (rem / rt, rem % rt);
                // Element (i2, j1, k) of the permuted array is H[j1, i2·rt+k].
                let h_lin = j1 * (n2 * rt) + i2 * rt + k;
                let (chunk, offset, _) = self.permuted_inner().locate_run(h_lin);
                // The permutation breaks contiguity (and HtGrid runs are
                // single elements anyway).
                (chunk, offset, 1)
            }
        }
    }
}

/// One rank's chunk of a distributed array, dense or sparse — what the
/// drivers feed into [`SharedStore::publish_block`] / [`dist_reshape_x`].
/// Dense and sparse chunks may coexist within one stored array (ranks
/// decide independently how to represent their block).
pub enum TensorBlock {
    /// The chunk's dense row-major buffer.
    Dense(Vec<f64>),
    /// The chunk as a sorted sparse vector over the same row-major order.
    Sparse(SparseChunk),
}

impl TensorBlock {
    /// Logical (dense) element count of the chunk.
    pub fn len(&self) -> usize {
        match self {
            TensorBlock::Dense(v) => v.len(),
            TensorBlock::Sparse(s) => s.len(),
        }
    }

    /// True when the chunk has no logical elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One published chunk.
enum ChunkData {
    Mem(Arc<Vec<f64>>),
    Disk(PathBuf),
    MemSparse(Arc<SparseChunk>),
    DiskSparse { path: PathBuf, len: usize, nnz: usize },
}

struct Entry {
    layout: Layout,
    chunks: Vec<Option<ChunkData>>,
}

/// A named-array store shared by all ranks of a world.
///
/// [`SharedStore::new`] returns an `Arc` handle because each rank closure
/// of [`Comm::run`] captures its own clone of the handle while all ranks
/// must address the same store. Concurrent `publish` calls to distinct
/// chunks are safe; the publish → barrier → [`SharedStore::view`]
/// discipline (what [`dist_reshape`] does internally) makes the data race
/// free.
pub struct SharedStore {
    spill: SpillMode,
    entries: Mutex<HashMap<String, Entry>>,
    /// When set, drop-time cleanup leaves spill files on disk.
    keep_spill: std::sync::atomic::AtomicBool,
}

impl SharedStore {
    /// Create a store (see [`SpillMode`] for where chunks live).
    pub fn new(spill: SpillMode) -> Arc<SharedStore> {
        Arc::new(SharedStore {
            spill,
            entries: Mutex::new(HashMap::new()),
            keep_spill: std::sync::atomic::AtomicBool::new(false),
        })
    }

    /// The store's spill configuration.
    pub fn spill_mode(&self) -> &SpillMode {
        &self.spill
    }

    /// Escape hatch for drop-time cleanup: when `true`, spill files of
    /// arrays still stored at drop are left on disk (for post-mortem
    /// inspection of an out-of-core run).
    pub fn set_keep_spill(&self, keep: bool) {
        self.keep_spill.store(keep, std::sync::atomic::Ordering::Relaxed);
    }

    /// Current [`SharedStore::set_keep_spill`] setting.
    pub fn keep_spill(&self) -> bool {
        self.keep_spill.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Validate chunk index, chunk length and (pre-spill) layout
    /// agreement for a publish of `data_len` logical elements.
    fn check_publish(
        &self,
        name: &str,
        layout: &Layout,
        chunk: usize,
        data_len: usize,
    ) -> Result<()> {
        if chunk >= layout.num_chunks() {
            return Err(DnttError::shape(format!(
                "publish {name}: chunk {chunk} out of range for {} chunks",
                layout.num_chunks()
            )));
        }
        let want = layout.chunk_len(chunk);
        if data_len != want {
            return Err(DnttError::shape(format!(
                "publish {name}: chunk {chunk} has {data_len} elements, layout expects {want}"
            )));
        }
        // Validate layout agreement before touching the filesystem so a
        // clashing publish cannot leak an orphan spill file.
        let entries = self.entries.lock().unwrap();
        if let Some(entry) = entries.get(name) {
            if entry.layout != *layout {
                return Err(Self::layout_clash(name));
            }
        }
        Ok(())
    }

    fn layout_clash(name: &str) -> DnttError {
        DnttError::shape(format!("publish {name}: layout disagrees with the first publisher"))
    }

    /// Insert a stored chunk, handling the lost-race-with-conflicting-
    /// first-publisher case (spill files of the loser are deleted).
    fn insert_chunk(
        &self,
        name: &str,
        layout: &Layout,
        chunk: usize,
        stored: ChunkData,
    ) -> Result<()> {
        let mut entries = self.entries.lock().unwrap();
        let entry = entries.entry(name.to_string()).or_insert_with(|| Entry {
            layout: layout.clone(),
            chunks: (0..layout.num_chunks()).map(|_| None).collect(),
        });
        if entry.layout != *layout {
            match &stored {
                ChunkData::Disk(path) | ChunkData::DiskSparse { path, .. } => {
                    let _ = std::fs::remove_file(path);
                }
                _ => {}
            }
            return Err(Self::layout_clash(name));
        }
        entry.chunks[chunk] = Some(stored);
        Ok(())
    }

    fn spill_path(&self, dir: &std::path::Path, name: &str, chunk: usize) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        Ok(dir.join(format!("{name}.{chunk}.chunk")))
    }

    /// Publish chunk `chunk` of array `name` under `layout`.
    ///
    /// The first publisher fixes the layout; later publishers must pass an
    /// equal layout. `data.len()` must match `layout.chunk_len(chunk)`.
    /// In disk mode the data is written out and dropped from memory.
    /// `name` must be filesystem-safe (the crate uses names like
    /// `"tt.stage0"`).
    pub fn publish(&self, name: &str, layout: &Layout, chunk: usize, data: Vec<f64>) -> Result<()> {
        self.check_publish(name, layout, chunk, data.len())?;
        let span = crate::obs::span_begin();
        let logical_bytes = (data.len() * 8) as u64;
        let mut spill_bytes = 0u64;
        let stored = match &self.spill {
            SpillMode::Memory => ChunkData::Mem(Arc::new(data)),
            SpillMode::Disk(dir) => {
                let path = self.spill_path(dir, name, chunk)?;
                let mut bytes = Vec::with_capacity(data.len() * 8);
                for x in &data {
                    bytes.extend_from_slice(&x.to_le_bytes());
                }
                std::fs::write(&path, &bytes)?;
                spill_bytes = bytes.len() as u64;
                ChunkData::Disk(path)
            }
        };
        crate::obs::end_store_write(span, logical_bytes, spill_bytes);
        self.insert_chunk(name, layout, chunk, stored)
    }

    /// Publish a **sparse** chunk of array `name` under `layout`. The
    /// chunk's logical length must match `layout.chunk_len(chunk)`; its
    /// index/value pairs cover the same row-major order a dense publish
    /// would. Sparse and dense chunks may be mixed freely within one
    /// array. In disk mode the spill file holds
    /// `[nnz: u64 | idx: u64 × nnz | vals: f64 × nnz]` little-endian, so
    /// spill traffic scales with `nnz`, not the dense chunk size.
    pub fn publish_sparse(
        &self,
        name: &str,
        layout: &Layout,
        chunk: usize,
        data: SparseChunk,
    ) -> Result<()> {
        self.check_publish(name, layout, chunk, data.len())?;
        let span = crate::obs::span_begin();
        // Sparse payloads are accounted at their stored size (nnz-scaled),
        // not the dense-equivalent chunk size.
        let logical_bytes = (8 * (1 + 2 * data.nnz())) as u64;
        let mut spill_bytes = 0u64;
        let stored = match &self.spill {
            SpillMode::Memory => ChunkData::MemSparse(Arc::new(data)),
            SpillMode::Disk(dir) => {
                let path = self.spill_path(dir, name, chunk)?;
                let (len, nnz) = (data.len(), data.nnz());
                let mut bytes = Vec::with_capacity(8 * (1 + 2 * nnz));
                bytes.extend_from_slice(&(nnz as u64).to_le_bytes());
                for &i in data.idx() {
                    bytes.extend_from_slice(&(i as u64).to_le_bytes());
                }
                for &v in data.vals() {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                std::fs::write(&path, &bytes)?;
                spill_bytes = bytes.len() as u64;
                ChunkData::DiskSparse { path, len, nnz }
            }
        };
        crate::obs::end_store_write(span, logical_bytes, spill_bytes);
        self.insert_chunk(name, layout, chunk, stored)
    }

    /// Publish either representation of a chunk (the driver-facing form).
    pub fn publish_block(
        &self,
        name: &str,
        layout: &Layout,
        chunk: usize,
        data: TensorBlock,
    ) -> Result<()> {
        match data {
            TensorBlock::Dense(v) => self.publish(name, layout, chunk, v),
            TensorBlock::Sparse(s) => self.publish_sparse(name, layout, chunk, s),
        }
    }

    /// Open a read view of array `name`. Errors if the array is unknown or
    /// not all chunks have been published yet (callers barrier between the
    /// last publish and the first view).
    pub fn view(&self, name: &str) -> Result<StoreView> {
        let entries = self.entries.lock().unwrap();
        let entry = entries
            .get(name)
            .ok_or_else(|| DnttError::Comm(format!("store view: no array named '{name}'")))?;
        let mut slots = Vec::with_capacity(entry.chunks.len());
        for (c, chunk) in entry.chunks.iter().enumerate() {
            match chunk {
                Some(ChunkData::Mem(data)) => slots.push(ViewSlot::Mem(Arc::clone(data))),
                Some(ChunkData::Disk(path)) => {
                    slots.push(ViewSlot::Disk { path: path.clone(), cache: RefCell::new(None) })
                }
                Some(ChunkData::MemSparse(data)) => {
                    slots.push(ViewSlot::MemSparse(Arc::clone(data)))
                }
                Some(ChunkData::DiskSparse { path, len, nnz }) => slots.push(ViewSlot::DiskSparse {
                    path: path.clone(),
                    len: *len,
                    nnz: *nnz,
                    cache: RefCell::new(None),
                }),
                None => {
                    return Err(DnttError::Comm(format!(
                        "store view: array '{name}' is missing chunk {c} (publish not complete?)"
                    )))
                }
            }
        }
        Ok(StoreView { layout: entry.layout.clone(), slots, bytes_read: Cell::new(0) })
    }

    /// Drop array `name` (and delete its spill files). Missing names are
    /// ignored. Live [`StoreView`]s of a memory-mode array stay valid;
    /// disk-mode views must be dropped first (ranks barrier before the
    /// owning rank removes).
    pub fn remove(&self, name: &str) {
        let entry = self.entries.lock().unwrap().remove(name);
        if let Some(entry) = entry {
            for chunk in entry.chunks.into_iter().flatten() {
                match chunk {
                    ChunkData::Disk(path) | ChunkData::DiskSparse { path, .. } => {
                        let _ = std::fs::remove_file(path);
                    }
                    _ => {}
                }
            }
        }
    }
}

impl Drop for SharedStore {
    /// Delete the spill files of every array still stored — a crashed or
    /// early-erroring job must not leave `.chunk` litter in the spill
    /// directory (the happy path removes arrays as it consumes them, so
    /// this is usually a no-op). [`SharedStore::set_keep_spill`] opts out.
    fn drop(&mut self) {
        if self.keep_spill() {
            return;
        }
        let entries = self.entries.get_mut().unwrap_or_else(|e| e.into_inner());
        for entry in entries.values() {
            for chunk in entry.chunks.iter().flatten() {
                match chunk {
                    ChunkData::Disk(path) | ChunkData::DiskSparse { path, .. } => {
                        let _ = std::fs::remove_file(path);
                    }
                    _ => {}
                }
            }
        }
    }
}

enum ViewSlot {
    Mem(Arc<Vec<f64>>),
    Disk { path: PathBuf, cache: RefCell<Option<Vec<f64>>> },
    MemSparse(Arc<SparseChunk>),
    DiskSparse { path: PathBuf, len: usize, nnz: usize, cache: RefCell<Option<SparseChunk>> },
}

/// A chunk's contents as seen through [`StoreView::with_loaded`].
enum Loaded<'a> {
    Dense(&'a [f64]),
    Sparse(&'a SparseChunk),
}

/// A read snapshot of one stored array.
///
/// Disk-mode chunks are loaded lazily (whole chunks at a time) and cached
/// for the life of the view; loaded bytes accumulate in
/// [`StoreView::disk_bytes_read`]. A view is a single-rank object — it is
/// deliberately `!Sync` (interior caches), matching its use inside one
/// rank closure.
pub struct StoreView {
    layout: Layout,
    slots: Vec<ViewSlot>,
    bytes_read: Cell<u64>,
}

impl StoreView {
    /// Layout the array was published under.
    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    /// Total logical element count.
    pub fn len(&self) -> usize {
        self.layout.total_len()
    }

    /// True when the logical array has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes loaded from spill files so far (0 in memory mode).
    pub fn disk_bytes_read(&self) -> u64 {
        self.bytes_read.get()
    }

    /// True when at least one chunk was published sparse.
    pub fn has_sparse(&self) -> bool {
        self.slots.iter().any(|s| {
            matches!(s, ViewSlot::MemSparse(_) | ViewSlot::DiskSparse { .. })
        })
    }

    /// Upper bound on stored nonzeros: sparse chunks contribute their
    /// `nnz`, dense chunks their full length (their contents are not
    /// scanned). Identical on every rank viewing the same array, so it is
    /// safe to branch on collectively (what [`dist_reshape_x`] does).
    pub fn nnz_estimate(&self) -> usize {
        self.slots
            .iter()
            .enumerate()
            .map(|(c, s)| match s {
                ViewSlot::Mem(_) | ViewSlot::Disk { .. } => self.layout.chunk_len(c),
                ViewSlot::MemSparse(d) => d.nnz(),
                ViewSlot::DiskSparse { nnz, .. } => *nnz,
            })
            .sum()
    }

    /// Element at global linear index `lin` of the logical row-major
    /// array.
    ///
    /// # Panics
    /// Panics if a spill file disappeared or is malformed (the spill
    /// directory must outlive every view of it).
    pub fn get(&self, lin: usize) -> f64 {
        let (chunk, offset) = self.layout.locate(lin);
        self.with_loaded(chunk, |data| match data {
            Loaded::Dense(d) => d[offset],
            Loaded::Sparse(s) => s.get(offset),
        })
    }

    /// Copy `dst.len()` consecutive logical elements starting at `lin`
    /// into `dst`, chunk-contiguous run by run (the hot path of
    /// [`dist_reshape`] — constant index arithmetic per run, not per
    /// element). Sparse chunks zero-fill the run and scatter their
    /// nonzeros.
    pub fn read_into(&self, lin: usize, dst: &mut [f64]) {
        crate::obs::count(crate::obs::Ctr::StoreReadBytes, (dst.len() * 8) as u64);
        let mut done = 0;
        while done < dst.len() {
            let (chunk, offset, run) = self.layout.locate_run(lin + done);
            let take = run.min(dst.len() - done);
            self.with_loaded(chunk, |data| match data {
                Loaded::Dense(d) => {
                    dst[done..done + take].copy_from_slice(&d[offset..offset + take]);
                }
                Loaded::Sparse(s) => s.scatter_range(offset, &mut dst[done..done + take]),
            });
            done += take;
        }
    }

    /// Visit the nonzeros of the logical range `[lin, lin + n)` in
    /// ascending order; `f` receives `(offset within the range, value)`.
    /// Sparse chunks walk their index lists; dense chunks are scanned.
    /// The sparse assembly path of [`dist_reshape_x`] and the pruned-NMF
    /// compress step are built on this.
    pub fn read_nonzeros(&self, lin: usize, n: usize, mut f: impl FnMut(usize, f64)) {
        let mut done = 0;
        while done < n {
            let (chunk, offset, run) = self.layout.locate_run(lin + done);
            let take = run.min(n - done);
            self.with_loaded(chunk, |data| match data {
                Loaded::Dense(d) => {
                    for (k, &v) in d[offset..offset + take].iter().enumerate() {
                        if v != 0.0 {
                            f(done + k, v);
                        }
                    }
                }
                Loaded::Sparse(s) => {
                    s.for_range(offset, take, |i, v| f(done + (i - offset), v));
                }
            });
            done += take;
        }
    }

    /// Clone one chunk under its stored representation (what the
    /// checkpoint subsystem snapshots — see
    /// [`crate::dist::checkpoint::snapshot_array`]).
    pub fn chunk_block(&self, chunk: usize) -> TensorBlock {
        self.with_loaded(chunk, |data| match data {
            Loaded::Dense(d) => TensorBlock::Dense(d.to_vec()),
            Loaded::Sparse(s) => TensorBlock::Sparse(s.clone()),
        })
    }

    /// Assemble the whole logical array in row-major order. Intended for
    /// final gathers and tests; large arrays should be consumed blockwise
    /// via [`dist_reshape`] instead.
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.len()];
        self.read_into(0, &mut out);
        out
    }

    fn load_bytes(&self, path: &std::path::Path) -> Vec<u8> {
        let span = crate::obs::span_begin();
        let bytes = std::fs::read(path).unwrap_or_else(|e| {
            panic!("chunk store: failed to read spill file {path:?}: {e}")
        });
        self.bytes_read.set(self.bytes_read.get() + bytes.len() as u64);
        crate::obs::end_store_read(span, bytes.len() as u64);
        bytes
    }

    fn with_loaded<R>(&self, chunk: usize, f: impl FnOnce(Loaded<'_>) -> R) -> R {
        match &self.slots[chunk] {
            ViewSlot::Mem(data) => f(Loaded::Dense(data.as_slice())),
            ViewSlot::MemSparse(data) => f(Loaded::Sparse(data.as_ref())),
            ViewSlot::Disk { path, cache } => {
                let mut cache = cache.borrow_mut();
                if cache.is_none() {
                    let bytes = self.load_bytes(path);
                    assert!(
                        bytes.len() % 8 == 0,
                        "chunk store: spill file {path:?} is not a whole number of f64s"
                    );
                    let data: Vec<f64> = bytes
                        .chunks_exact(8)
                        .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
                        .collect();
                    *cache = Some(data);
                }
                f(Loaded::Dense(cache.as_ref().unwrap().as_slice()))
            }
            ViewSlot::DiskSparse { path, len, nnz, cache } => {
                let mut cache = cache.borrow_mut();
                if cache.is_none() {
                    let bytes = self.load_bytes(path);
                    assert!(
                        bytes.len() == 8 * (1 + 2 * nnz),
                        "chunk store: sparse spill file {path:?} has the wrong size"
                    );
                    let stored_nnz =
                        u64::from_le_bytes(bytes[..8].try_into().unwrap()) as usize;
                    assert_eq!(stored_nnz, *nnz, "chunk store: sparse spill nnz mismatch");
                    let mut idx = Vec::with_capacity(*nnz);
                    for b in bytes[8..8 * (1 + nnz)].chunks_exact(8) {
                        idx.push(u64::from_le_bytes(b.try_into().unwrap()) as usize);
                    }
                    let mut vals = Vec::with_capacity(*nnz);
                    for b in bytes[8 * (1 + nnz)..].chunks_exact(8) {
                        vals.push(f64::from_le_bytes(b.try_into().unwrap()));
                    }
                    let data = SparseChunk::new(*len, idx, vals).unwrap_or_else(|e| {
                        panic!("chunk store: corrupt sparse spill file {path:?}: {e}")
                    });
                    *cache = Some(data);
                }
                f(Loaded::Sparse(cache.as_ref().unwrap()))
            }
        }
    }
}

/// Global-density cutoff for [`dist_reshape_x`]'s output representation:
/// when at least one source chunk is sparse and the stored-nonzero
/// estimate is at most this fraction of the logical size, the assembled
/// stage-matrix block is returned sparse (CSR). Above it, scattering
/// into a dense block is both smaller and faster for the kernels.
pub const SPARSE_RESHAPE_CUTOFF: f64 = 0.25;

/// Alg 1: globally reshape/redistribute the array held as `my_data` under
/// `layout` into this rank's block of the `m × n` stage matrix on `grid`.
///
/// Collective over `world` (`grid.size() == world.size()`); `my_data` is
/// the chunk for `world.rank()`. Because every layout's logical order is
/// row-major and a row-major reshape is the identity on linear order, the
/// returned block `(i, j) = grid.coords(world.rank())` satisfies
/// `block[(li, lj)] == A[rows.start_of(i) + li, cols.start_of(j) + lj]`
/// for the serial reshape `A` of the logical array (`rows`/`cols` the
/// [`BlockDim`]s of `m`/`n` over `pr`/`pc`) — asserted against the dense
/// reshape in `tests/integration_dist.rs`.
///
/// Cost accounting on `world.breakdown`: publish and spill reads under
/// `IO` (bytes included), index mapping + block assembly under `Reshape`.
/// The store entry `name` is removed before returning — rank 0 drops it
/// between two trailing barriers, so the same name may be safely reused
/// by the next collective call.
///
/// ```
/// use dntt::dist::{dist_reshape, Comm, Grid2d, Layout, SharedStore, SpillMode};
///
/// // A 4×2 matrix held as two row blocks, redistributed as the 2×4
/// // reshape's row blocks on a 2×1 grid (same row-major linear order).
/// let store = SharedStore::new(SpillMode::Memory);
/// let grid = Grid2d::new(2, 1);
/// let layout = Layout::MatGrid { m: 4, n: 2, pr: 2, pc: 1 };
/// let blocks = Comm::run(2, move |mut world| {
///     let r = world.rank();
///     let mine: Vec<f64> = (0..4).map(|k| (4 * r + k) as f64).collect();
///     dist_reshape(&mut world, &store, "a", &layout, mine, 2, 4, grid).unwrap()
/// });
/// assert_eq!(blocks[0].as_slice(), &[0.0, 1.0, 2.0, 3.0]);
/// assert_eq!(blocks[1].as_slice(), &[4.0, 5.0, 6.0, 7.0]);
/// ```
#[allow(clippy::too_many_arguments)]
pub fn dist_reshape(
    world: &mut Comm,
    store: &SharedStore,
    name: &str,
    layout: &Layout,
    my_data: Vec<f64>,
    m: usize,
    n: usize,
    grid: Grid2d,
) -> Result<Mat<f64>> {
    match dist_reshape_x(world, store, name, layout, TensorBlock::Dense(my_data), m, n, grid)? {
        DenseOrSparse::Dense(block) => Ok(block),
        // Unreachable in practice: with no sparse chunk published the
        // assembly is always dense.
        DenseOrSparse::Sparse(s) => Ok(s.to_dense()),
    }
}

/// [`dist_reshape`] for dense **or sparse** chunks: publishes whichever
/// representation this rank holds and assembles the target block sparse
/// (CSR) when the array's global stored density is at most
/// [`SPARSE_RESHAPE_CUTOFF`], dense otherwise. The decision is a pure
/// function of the (barrier-synchronized) store state, so every rank in
/// the world takes the same branch.
#[allow(clippy::too_many_arguments)]
pub fn dist_reshape_x(
    world: &mut Comm,
    store: &SharedStore,
    name: &str,
    layout: &Layout,
    my_data: TensorBlock,
    m: usize,
    n: usize,
    grid: Grid2d,
) -> Result<DenseOrSparse> {
    if layout.total_len() != m * n {
        return Err(DnttError::shape(format!(
            "dist_reshape {name}: layout has {} elements, target is {m}x{n}",
            layout.total_len()
        )));
    }
    if grid.size() != world.size() {
        return Err(DnttError::Comm(format!(
            "dist_reshape {name}: grid {}x{} vs world of {}",
            grid.pr,
            grid.pc,
            world.size()
        )));
    }
    if layout.num_chunks() != world.size() {
        return Err(DnttError::Comm(format!(
            "dist_reshape {name}: layout has {} chunks for {} ranks",
            layout.num_chunks(),
            world.size()
        )));
    }
    let rank = world.rank();

    let t0 = Instant::now();
    if let Err(e) = store.publish_block(name, layout, rank, my_data) {
        // Divergent failure (e.g. this rank's spill write failed): peers
        // are already heading into the barrier — abort so they fail fast
        // instead of deadlocking.
        world.abort(&format!("dist_reshape {name}: publish failed: {e}"));
        return Err(e);
    }
    world.breakdown.add_secs(Cat::Io, t0.elapsed().as_secs_f64());
    world.barrier();

    let view = store.view(name)?;
    let (i, j) = grid.coords(rank);
    let rows = BlockDim::new(m, grid.pr);
    let cols = BlockDim::new(n, grid.pc);
    let (r0, c0) = (rows.start_of(i), cols.start_of(j));
    let width = cols.size_of(j);
    let my_rows = rows.size_of(i);
    let want_sparse = view.has_sparse()
        && (view.nnz_estimate() as f64) <= SPARSE_RESHAPE_CUTOFF * (m * n) as f64;
    let t1 = Instant::now();
    let block = if want_sparse {
        let mut idx = Vec::new();
        let mut vals = Vec::new();
        for li in 0..my_rows {
            let base = li * width;
            view.read_nonzeros((r0 + li) * n + c0, width, |off, v| {
                idx.push(base + off);
                vals.push(v);
            });
        }
        world.breakdown.add_bytes(Cat::Reshape, (vals.len() * 16) as u64);
        match SparseMat::from_linear(my_rows, width, &idx, &vals) {
            Ok(sm) => DenseOrSparse::Sparse(sm),
            Err(e) => {
                // Unreachable (indices are sorted by construction), but a
                // silent early return would strand peers in the trailing
                // barriers — same discipline as the publish failure above.
                world.abort(&format!("dist_reshape {name}: sparse assembly failed: {e}"));
                return Err(e);
            }
        }
    } else {
        let mut block = Mat::zeros(my_rows, width);
        for li in 0..my_rows {
            view.read_into((r0 + li) * n + c0, block.row_mut(li));
        }
        world.breakdown.add_bytes(Cat::Reshape, (block.len() * 8) as u64);
        DenseOrSparse::Dense(block)
    };
    world.breakdown.add_secs(Cat::Reshape, t1.elapsed().as_secs_f64());
    world.breakdown.add_bytes(Cat::Io, view.disk_bytes_read());
    drop(view);

    // Two barriers around the drop: the first keeps the owner from
    // removing while peers still read; the second keeps peers from
    // republishing the same name before it is removed.
    world.barrier();
    if rank == 0 {
        store.remove(name);
    }
    world.barrier();
    Ok(block)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_grid_locate_is_block_row_major() {
        // dims [4, 3], grid [2, 1]: chunk 0 = rows 0..2, chunk 1 = rows 2..4.
        let l = Layout::TensorGrid { dims: vec![4, 3], grid: vec![2, 1] };
        assert_eq!(l.total_len(), 12);
        assert_eq!(l.num_chunks(), 2);
        assert_eq!(l.chunk_len(0), 6);
        assert_eq!(l.locate(0), (0, 0));
        assert_eq!(l.locate(5), (0, 5));
        assert_eq!(l.locate(6), (1, 0));
        assert_eq!(l.locate(11), (1, 5));
    }

    #[test]
    fn mat_grid_locate_uneven() {
        // 3x5 over 2x2: blocks (2x3, 2x2, 1x3, 1x2).
        let l = Layout::MatGrid { m: 3, n: 5, pr: 2, pc: 2 };
        assert_eq!(
            (0..4).map(|c| l.chunk_len(c)).collect::<Vec<_>>(),
            vec![6, 4, 3, 2]
        );
        // element (2, 4) = lin 14 -> chunk (1,1), local (0,1).
        assert_eq!(l.locate(14), (3, 1));
        // element (0, 3) = lin 3 -> chunk (0,1), local (0,0).
        assert_eq!(l.locate(3), (1, 0));
    }

    #[test]
    fn locate_run_spans_to_block_edges() {
        let l = Layout::MatGrid { m: 3, n: 5, pr: 2, pc: 2 };
        // Row 0: a 3-wide run in chunk (0,0), then a 2-wide run in (0,1).
        assert_eq!(l.locate_run(0), (0, 0, 3));
        assert_eq!(l.locate_run(3), (1, 0, 2));
        let t = Layout::TensorGrid { dims: vec![4, 6], grid: vec![2, 3] };
        // lin 2 = index (0, 2): column block 1 spans 2..4 → run of 2.
        assert_eq!(t.locate_run(2), (1, 0, 2));
        // HtGrid is transposed: runs never exceed one element.
        let h = Layout::HtGrid { r: 3, n: 4, pr: 1, pc: 2 };
        for lin in 0..h.total_len() {
            assert_eq!(h.locate_run(lin).2, 1);
        }
    }

    #[test]
    fn ht_grid_roundtrips_through_store() {
        // H: 2x5 over a 1x2 grid, pr=1 -> chunk j holds cols of block j,
        // transposed.
        let (r, n, pr, pc) = (2usize, 5usize, 1usize, 2usize);
        let l = Layout::HtGrid { r, n, pr, pc };
        let h: Vec<f64> = (0..r * n).map(|x| x as f64).collect(); // row-major H
        let store = SharedStore::new(SpillMode::Memory);
        let cols = BlockDim::new(n, pc);
        for j in 0..pc {
            let nj = cols.size_of(j);
            // nh x r row-major transposed block (pr = 1 -> whole col block).
            let mut chunk = Vec::with_capacity(nj * r);
            for lc in 0..nj {
                for row in 0..r {
                    chunk.push(h[row * n + cols.start_of(j) + lc]);
                }
            }
            store.publish("h", &l, j, chunk).unwrap();
        }
        assert_eq!(store.view("h").unwrap().to_dense(), h);
    }

    #[test]
    fn w_grid_roundtrips_through_store() {
        // W: 5x2 over a 2x2 grid: block-row 0 = rows 0..3 (sub-split 2|1),
        // block-row 1 = rows 3..5 (sub-split 1|1).
        let (m, r, pr, pc) = (5usize, 2usize, 2usize, 2usize);
        let l = Layout::WGrid { m, r, pr, pc };
        assert_eq!(l.total_len(), 10);
        assert_eq!(l.num_chunks(), 4);
        assert_eq!(
            (0..4).map(|c| l.chunk_len(c)).collect::<Vec<_>>(),
            vec![4, 2, 2, 2]
        );
        let w: Vec<f64> = (0..m * r).map(|x| x as f64).collect();
        let store = SharedStore::new(SpillMode::Memory);
        let rows = BlockDim::new(m, pr);
        for i in 0..pr {
            let sub = BlockDim::new(rows.size_of(i), pc);
            for j in 0..pc {
                let g0 = rows.start_of(i) + sub.start_of(j);
                let chunk: Vec<f64> =
                    w[g0 * r..(g0 + sub.size_of(j)) * r].to_vec();
                store.publish("w", &l, i * pc + j, chunk).unwrap();
            }
        }
        assert_eq!(store.view("w").unwrap().to_dense(), w);
        // Runs extend to the end of a row.
        assert_eq!(l.locate_run(0).2, 2);
        assert_eq!(l.locate_run(1).2, 1);
    }

    #[test]
    fn ht_permuted_presents_permuted_order() {
        // H: r=2 x (n2*rt = 3*2 = 6) over a 1x2 grid, published in the
        // HtGrid chunking; the permuted view must read (i2, j1, k) order.
        let (r, n2, rt, pr, pc) = (2usize, 3usize, 2usize, 1usize, 2usize);
        let n = n2 * rt;
        let perm = Layout::HtPermuted { r, n2, rt, pr, pc };
        assert_eq!(perm.total_len(), r * n);
        let inner = Layout::HtGrid { r, n, pr, pc };
        let h: Vec<f64> = (0..r * n).map(|x| x as f64).collect(); // row-major H
        let store = SharedStore::new(SpillMode::Memory);
        let cols = BlockDim::new(n, pc);
        for j in 0..pc {
            let nj = cols.size_of(j);
            let mut chunk = Vec::with_capacity(nj * r);
            for lc in 0..nj {
                for row in 0..r {
                    chunk.push(h[row * n + cols.start_of(j) + lc]);
                }
            }
            // Chunk shapes agree between the inner and permuted layouts.
            assert_eq!(inner.chunk_len(j), chunk.len());
            store.publish("hp", &perm, j, chunk).unwrap();
        }
        let mut want = Vec::with_capacity(r * n);
        for i2 in 0..n2 {
            for j1 in 0..r {
                for k in 0..rt {
                    want.push(h[j1 * n + i2 * rt + k]);
                }
            }
        }
        assert_eq!(store.view("hp").unwrap().to_dense(), want);
        for lin in 0..perm.total_len() {
            assert_eq!(perm.locate_run(lin).2, 1);
        }
    }

    #[test]
    fn publish_validates_shapes() {
        let l = Layout::MatGrid { m: 2, n: 2, pr: 1, pc: 1 };
        let store = SharedStore::new(SpillMode::Memory);
        assert!(store.publish("x", &l, 1, vec![0.0; 4]).is_err()); // bad chunk
        assert!(store.publish("x", &l, 0, vec![0.0; 3]).is_err()); // bad len
        assert!(store.publish("x", &l, 0, vec![0.0; 4]).is_ok());
        let other = Layout::MatGrid { m: 4, n: 1, pr: 1, pc: 1 };
        assert!(store.publish("x", &other, 0, vec![0.0; 4]).is_err()); // layout clash
    }

    #[test]
    fn view_requires_all_chunks() {
        let l = Layout::MatGrid { m: 2, n: 2, pr: 2, pc: 1 };
        let store = SharedStore::new(SpillMode::Memory);
        store.publish("x", &l, 0, vec![1.0, 2.0]).unwrap();
        assert!(store.view("x").is_err());
        store.publish("x", &l, 1, vec![3.0, 4.0]).unwrap();
        assert_eq!(store.view("x").unwrap().to_dense(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(store.view("y").is_err());
    }

    #[test]
    fn disk_spill_roundtrip_counts_bytes() {
        let dir = std::env::temp_dir().join(format!("dntt_cs_unit_{}", std::process::id()));
        let l = Layout::MatGrid { m: 2, n: 3, pr: 1, pc: 1 };
        let store = SharedStore::new(SpillMode::Disk(dir.clone()));
        let data: Vec<f64> = (0..6).map(|x| x as f64 * 0.5).collect();
        store.publish("x", &l, 0, data.clone()).unwrap();
        let view = store.view("x").unwrap();
        assert_eq!(view.to_dense(), data);
        assert_eq!(view.disk_bytes_read(), 48);
        // Cached: a second read does not re-load.
        let _ = view.get(0);
        assert_eq!(view.disk_bytes_read(), 48);
        drop(view);
        store.remove("x");
        assert!(!dir.join("x.0.chunk").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mixed_dense_and_sparse_chunks_coexist() {
        // 4x3 over 2x1: chunk 0 dense, chunk 1 sparse — one array.
        let l = Layout::MatGrid { m: 4, n: 3, pr: 2, pc: 1 };
        let store = SharedStore::new(SpillMode::Memory);
        let top: Vec<f64> = (0..6).map(|x| x as f64).collect();
        store.publish("x", &l, 0, top.clone()).unwrap();
        let bottom = SparseChunk::new(6, vec![1, 4], vec![7.0, 8.0]).unwrap();
        store.publish_sparse("x", &l, 1, bottom).unwrap();
        let view = store.view("x").unwrap();
        assert!(view.has_sparse());
        assert_eq!(view.nnz_estimate(), 6 + 2);
        let mut want = top;
        want.extend_from_slice(&[0.0, 7.0, 0.0, 0.0, 8.0, 0.0]);
        assert_eq!(view.to_dense(), want);
        assert_eq!(view.get(7), 7.0);
        assert_eq!(view.get(6), 0.0);
        // read_nonzeros over a range straddling both chunks.
        let mut seen = Vec::new();
        view.read_nonzeros(5, 3, |off, v| seen.push((off, v)));
        assert_eq!(seen, vec![(0, 5.0), (2, 7.0)]);
    }

    #[test]
    fn sparse_publish_validates_shapes() {
        let l = Layout::MatGrid { m: 2, n: 2, pr: 1, pc: 1 };
        let store = SharedStore::new(SpillMode::Memory);
        // Wrong logical length.
        let short = SparseChunk::new(3, vec![0], vec![1.0]).unwrap();
        assert!(store.publish_sparse("x", &l, 0, short).is_err());
        // Empty chunk (zero nonzeros) is legal.
        store.publish_sparse("x", &l, 0, SparseChunk::empty(4)).unwrap();
        let view = store.view("x").unwrap();
        assert_eq!(view.nnz_estimate(), 0);
        assert_eq!(view.to_dense(), vec![0.0; 4]);
    }

    #[test]
    fn sparse_disk_spill_roundtrips_and_counts_nnz_bytes() {
        let dir = std::env::temp_dir().join(format!("dntt_cs_sp_unit_{}", std::process::id()));
        let l = Layout::MatGrid { m: 2, n: 4, pr: 1, pc: 1 };
        let store = SharedStore::new(SpillMode::Disk(dir.clone()));
        let chunk = SparseChunk::new(8, vec![0, 3, 6], vec![1.5, -2.0, 4.0]).unwrap();
        store.publish_sparse("s", &l, 0, chunk.clone()).unwrap();
        let view = store.view("s").unwrap();
        assert_eq!(view.nnz_estimate(), 3);
        assert_eq!(view.to_dense(), chunk.to_dense());
        // Spill file is nnz-sized: 8 * (1 + 2*3) bytes, read once.
        assert_eq!(view.disk_bytes_read(), 8 * 7);
        let _ = view.get(3);
        assert_eq!(view.disk_bytes_read(), 8 * 7);
        drop(view);
        store.remove("s");
        assert!(!dir.join("s.0.chunk").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reshape_x_goes_sparse_below_cutoff_only() {
        use crate::dist::Grid2d;
        // 2 ranks, 4x4 logical array as two 2x4 MatGrid chunks, reshaped
        // to 4x4 on a 2x1 grid.
        let run = |nnz_per_rank: usize| {
            let layout = Layout::MatGrid { m: 4, n: 4, pr: 2, pc: 1 };
            let store = SharedStore::new(SpillMode::Memory);
            let grid = Grid2d::new(2, 1);
            Comm::run(2, move |mut world| {
                let idx: Vec<usize> = (0..nnz_per_rank).collect();
                let vals: Vec<f64> = (0..nnz_per_rank).map(|k| (k + 1) as f64).collect();
                let chunk = SparseChunk::new(8, idx, vals).unwrap();
                dist_reshape_x(
                    &mut world, &store, "r", &layout, TensorBlock::Sparse(chunk), 4, 4, grid,
                )
                .unwrap()
            })
        };
        // 2 nnz per rank → density 4/16 = cutoff → sparse.
        for b in run(2) {
            assert!(b.is_sparse());
            assert_eq!(b.shape(), (2, 4));
        }
        // 5 nnz per rank → density 10/16 > cutoff → dense, same values.
        let dense = run(5);
        assert!(!dense[0].is_sparse());
        assert_eq!(dense[0].to_dense().as_slice(), &[1.0, 2.0, 3.0, 4.0, 5.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn reshape_x_sparse_matches_dense_assembly() {
        use crate::dist::Grid2d;
        // Same logical array published sparse vs dense must assemble to
        // identical blocks (the sparse one merely stored as CSR).
        let layout = Layout::MatGrid { m: 4, n: 6, pr: 2, pc: 2 };
        let grid = Grid2d::new(2, 2);
        let full: Vec<f64> = (0..24)
            .map(|k| if k % 5 == 0 { (k + 1) as f64 } else { 0.0 })
            .collect();
        let run = |sparse: bool| {
            let layout = layout.clone();
            let full = full.clone();
            let store = SharedStore::new(SpillMode::Memory);
            Comm::run(4, move |mut world| {
                let view_chunk = {
                    // Build this rank's MatGrid chunk from the full array.
                    let (i, j) = (world.rank() / 2, world.rank() % 2);
                    let rows = BlockDim::new(4, 2);
                    let cols = BlockDim::new(6, 2);
                    let mut data = Vec::new();
                    for li in 0..rows.size_of(i) {
                        for lj in 0..cols.size_of(j) {
                            data.push(
                                full[(rows.start_of(i) + li) * 6 + cols.start_of(j) + lj],
                            );
                        }
                    }
                    data
                };
                let block = if sparse {
                    TensorBlock::Sparse(SparseChunk::from_dense(&view_chunk))
                } else {
                    TensorBlock::Dense(view_chunk)
                };
                dist_reshape_x(&mut world, &store, "e", &layout, block, 6, 4, grid).unwrap()
            })
        };
        let a = run(true);
        let b = run(false);
        for (x, y) in a.iter().zip(&b) {
            assert!(x.is_sparse() && !y.is_sparse());
            assert_eq!(x.to_dense().as_slice(), y.to_dense().as_slice());
        }
    }
}
