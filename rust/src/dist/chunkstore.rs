//! The chunked array store and the distributed global reshape (Alg 1).
//!
//! Between TT sweep stages the remainder array must be *globally*
//! redistributed: each rank owns a chunk under the current [`Layout`] and
//! needs its block of the next stage matrix under the 2-D `MatGrid`
//! distribution. The paper does this through a Zarr chunk store shared by
//! all MPI ranks; here [`SharedStore`] plays that role for thread ranks,
//! with an optional out-of-core [`SpillMode::Disk`] backend whose traffic
//! is accounted under the `IO` cost category.
//!
//! # Layouts
//!
//! A [`Layout`] maps the store's chunks onto one *logical row-major
//! array*; `Layout::locate` sends a global linear index to
//! `(chunk, offset within chunk)`:
//!
//! * [`Layout::TensorGrid`] — the input tensor blocked over the d-dim
//!   [`crate::dist::ProcGrid`]; chunk `r` is world rank `r`'s block,
//!   itself row-major (what [`crate::ttrain::driver::extract_block`]
//!   produces).
//! * [`Layout::MatGrid`] — an `m × n` matrix 2-D-blocked over a
//!   `pr × pc` [`crate::dist::Grid2d`].
//! * [`Layout::HtGrid`] — the NMF output `H: r × n` held transposed:
//!   rank `(i, j)` stores the `nh × r` row-major block `(Hʲ)ⁱᵀ` of
//!   `nmf::dist`. The logical array is `H` itself in row-major order,
//!   which *is* the next remainder tensor of Alg 2 — so the next stage's
//!   [`dist_reshape`] can consume `H` without any pre-pass.
//! * [`Layout::WGrid`] — the NMF output `W: m × r` distributed by rows in
//!   world-rank order: rank `(i, j)` stores the `mw × r` block `(Wⁱ)ʲ`.
//!   The logical array is `W` row-major — the left-child hand-off of the
//!   hierarchical-Tucker sweep (`crate::ht`).
//! * [`Layout::HtPermuted`] — the same chunks as an [`Layout::HtGrid`],
//!   but presenting the *permuted* logical order the HT right-child
//!   matricization needs (left-edge index moved from rows to columns).
//!
//! # Sparse chunks
//!
//! Every layout's chunks can be published **dense** (`Vec<f64>`, the
//! chunk's row-major buffer) or **sparse**
//! ([`crate::tensor::SparseChunk`], a sorted index/value view over the
//! same order), freely mixed within one array; [`TensorBlock`] is the
//! either-representation type the drivers hand in. Sparse chunks spill
//! in an nnz-sized record format and are read back through the same
//! [`StoreView`] (`read_into` zero-fills and scatters;
//! [`StoreView::read_nonzeros`] walks nonzeros directly).
//! [`dist_reshape_x`] assembles its output block as CSR when the global
//! stored density is at most [`SPARSE_RESHAPE_CUTOFF`]. The full
//! contract lives in `rust/DESIGN.md` §2.7.
//!
//! # Collective protocol
//!
//! [`dist_reshape`] is the one-call version of Alg 1: every rank
//! publishes its chunk, barriers, assembles its target block through a
//! [`StoreView`], barriers again, and rank 0 drops the array from the
//! store. `publish`/`view`/`remove` are also usable directly (the driver
//! does so for the final core gather).
//!
//! # Out-of-core mode
//!
//! Three pieces make a larger-than-RAM tensor decomposable on one box
//! (DESIGN.md §2.12):
//!
//! * **Chunk adoption** — [`TensorBlock::DiskDense`] /
//!   [`TensorBlock::DiskSparse`] publish a chunk that already sits on
//!   disk in the spill byte format (the `dntt-chunks-v1` ingest files of
//!   [`crate::tensor::chunked`]). The store references the file in place:
//!   nothing is copied to the heap and the file is never deleted by
//!   `remove`/drop (the store does not own it).
//! * **[`SpillMode::Mmap`]** — identical on-disk files and formats as
//!   [`SpillMode::Disk`], but [`StoreView`] memory-maps dense chunk
//!   files instead of materializing a `Vec<f64>` per chunk, so reads
//!   page in on demand and mapped bytes never count as resident.
//!   Sparse spill files are still parsed by copy (nnz-scaled).
//! * **Budgeted assembly** — with [`SharedStore::set_budget`] set, the
//!   dense assembly of [`dist_reshape_x`] loads source chunks in
//!   bounded batches (evicting between batches) instead of caching the
//!   whole array per view. Every element is copied exactly once from
//!   the same source value regardless of the batch partition, so the
//!   result is bitwise-identical to the unbudgeted path.
//!
//! [`MemStats`] is the shared gauge behind all of this: resident heap
//! bytes the store pins (in-memory chunks + view caches of spill loads),
//! its high-water mark, and live owned spill-file bytes. The peak feeds
//! the `dntt-metrics-v1` envelope (`memory.peak_resident_bytes`).

use crate::dist::comm::Comm;
use crate::dist::topology::{BlockDim, Grid2d};
use crate::error::{DnttError, Result};
use crate::linalg::sparse::SparseMat;
use crate::linalg::{DenseOrSparse, Mat};
use crate::tensor::sparse::SparseChunk;
use crate::util::timer::Cat;
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Where published chunks live.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpillMode {
    /// Chunks stay in memory (shared by reference between ranks).
    Memory,
    /// Chunks are written to `<dir>/<name>.<chunk>.chunk` as little-endian
    /// `f64` and dropped from memory — the out-of-core path. Reads are
    /// counted by [`StoreView::disk_bytes_read`].
    Disk(PathBuf),
    /// Same on-disk files and byte formats as [`SpillMode::Disk`], but
    /// views **memory-map** dense chunk files instead of reading them
    /// into a `Vec<f64>`, so chunk data pages in on demand and never
    /// counts against the resident budget. Sparse chunks are parsed by
    /// copy (their heap cost is nnz-scaled). On targets without mmap
    /// support (non-unix or big-endian) this degrades to the
    /// [`SpillMode::Disk`] read path — same bytes, same results.
    Mmap(PathBuf),
}

impl SpillMode {
    /// The spill directory of an on-disk mode (`None` for memory).
    pub fn dir(&self) -> Option<&std::path::Path> {
        match self {
            SpillMode::Memory => None,
            SpillMode::Disk(d) | SpillMode::Mmap(d) => Some(d),
        }
    }
}

/// How a named array's chunks tile its logical row-major order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Layout {
    /// A dense tensor of shape `dims` blocked over the processor grid
    /// `grid` (same length, row-major rank order, per-mode [`BlockDim`]
    /// partition). Chunk data is the block in row-major order.
    TensorGrid { dims: Vec<usize>, grid: Vec<usize> },
    /// An `m × n` row-major matrix 2-D-blocked over a `pr × pc` grid;
    /// chunk `i·pc + j` is block `(i, j)` in row-major order.
    MatGrid { m: usize, n: usize, pr: usize, pc: usize },
    /// The transposed-H layout: logical array `H: r × n` (row-major);
    /// chunk `i·pc + j` holds columns
    /// `[cols.start_of(j) + sub.start_of(i), …)` of `H` — where
    /// `cols = BlockDim(n, pc)` and `sub = BlockDim(cols.size_of(j), pr)`
    /// — stored **transposed** as an `nh × r` row-major block.
    HtGrid { r: usize, n: usize, pr: usize, pc: usize },
    /// The row-distributed-W layout: logical array `W: m × r` (row-major);
    /// chunk `i·pc + j` holds rows
    /// `[rows.start_of(i) + sub.start_of(j), …)` of `W` — where
    /// `rows = BlockDim(m, pr)` and `sub = BlockDim(rows.size_of(i), pc)`
    /// — as an `mw × r` row-major block (the `(Wⁱ)ʲ` distribution of
    /// `nmf::dist`).
    WGrid { m: usize, r: usize, pr: usize, pc: usize },
    /// A permuted view of an NMF output `H: r × (n2·rt)` that keeps the
    /// chunks of `HtGrid { r, n: n2·rt, pr, pc }` but reorders the logical
    /// array from `H`'s row-major `(j1, i2, k)` to `(i2, j1, k)`: element
    /// `lin = (i2·r + j1)·rt + k` is `H[j1, i2·rt + k]`. This is the
    /// right-child matricization hand-off of the hierarchical-Tucker
    /// driver (`crate::ht`): the left-edge index `j1` and the parent-edge
    /// index `k` move to the columns so the next NMF factors over `i2`.
    HtPermuted { r: usize, n2: usize, rt: usize, pr: usize, pc: usize },
}

impl Layout {
    /// The `HtGrid` layout an [`Layout::HtPermuted`] shares its chunks
    /// with.
    fn permuted_inner(&self) -> Layout {
        match self {
            Layout::HtPermuted { r, n2, rt, pr, pc } => {
                Layout::HtGrid { r: *r, n: n2 * rt, pr: *pr, pc: *pc }
            }
            _ => unreachable!("permuted_inner is only defined for HtPermuted"),
        }
    }

    /// Total number of elements in the logical array.
    pub fn total_len(&self) -> usize {
        match self {
            Layout::TensorGrid { dims, .. } => dims.iter().product(),
            Layout::MatGrid { m, n, .. } => m * n,
            Layout::HtGrid { r, n, .. } => r * n,
            Layout::WGrid { m, r, .. } => m * r,
            Layout::HtPermuted { r, n2, rt, .. } => r * n2 * rt,
        }
    }

    /// Number of chunks the layout is split into.
    pub fn num_chunks(&self) -> usize {
        match self {
            Layout::TensorGrid { grid, .. } => grid.iter().product(),
            Layout::MatGrid { pr, pc, .. }
            | Layout::HtGrid { pr, pc, .. }
            | Layout::WGrid { pr, pc, .. }
            | Layout::HtPermuted { pr, pc, .. } => pr * pc,
        }
    }

    /// Number of elements in chunk `c`.
    pub fn chunk_len(&self, c: usize) -> usize {
        match self {
            Layout::TensorGrid { dims, grid } => {
                let mut rem = c;
                let mut coords = vec![0; grid.len()];
                for k in (0..grid.len()).rev() {
                    coords[k] = rem % grid[k];
                    rem /= grid[k];
                }
                dims.iter()
                    .zip(grid)
                    .zip(&coords)
                    .map(|((&n, &p), &ci)| BlockDim::new(n, p).size_of(ci))
                    .product()
            }
            Layout::MatGrid { m, n, pr, pc } => {
                let (i, j) = (c / pc, c % pc);
                BlockDim::new(*m, *pr).size_of(i) * BlockDim::new(*n, *pc).size_of(j)
            }
            Layout::HtGrid { r, n, pr, pc } => {
                let (i, j) = (c / pc, c % pc);
                let cols = BlockDim::new(*n, *pc);
                BlockDim::new(cols.size_of(j), *pr).size_of(i) * r
            }
            Layout::WGrid { m, r, pr, pc } => {
                let (i, j) = (c / pc, c % pc);
                let rows = BlockDim::new(*m, *pr);
                BlockDim::new(rows.size_of(i), *pc).size_of(j) * r
            }
            Layout::HtPermuted { .. } => self.permuted_inner().chunk_len(c),
        }
    }

    /// Map a global linear index of the logical row-major array to
    /// `(chunk, offset within chunk)`.
    pub fn locate(&self, lin: usize) -> (usize, usize) {
        let (chunk, offset, _) = self.locate_run(lin);
        (chunk, offset)
    }

    /// Like [`Layout::locate`], but also returns the number of consecutive
    /// linear indices starting at `lin` that map to *consecutive offsets in
    /// the same chunk* — the unit of contiguous copying. Runs follow the
    /// fastest axis: the last tensor mode within its block (`TensorGrid`),
    /// the columns within a column block (`MatGrid`); `HtGrid` stores `H`
    /// transposed so its runs are single elements.
    pub fn locate_run(&self, lin: usize) -> (usize, usize, usize) {
        debug_assert!(lin < self.total_len());
        match self {
            Layout::TensorGrid { dims, grid } => {
                let d = dims.len();
                let mut gidx = vec![0; d];
                let mut rem = lin;
                for k in (0..d).rev() {
                    gidx[k] = rem % dims[k];
                    rem /= dims[k];
                }
                let mut chunk = 0;
                let mut offset = 0;
                let mut run = 1;
                for k in 0..d {
                    let bd = BlockDim::new(dims[k], grid[k]);
                    let c = bd.owner_of(gidx[k]);
                    chunk = chunk * grid[k] + c;
                    offset = offset * bd.size_of(c) + (gidx[k] - bd.start_of(c));
                    if k == d - 1 {
                        // Contiguous along the last mode until its block ends.
                        run = bd.end_of(c) - gidx[k];
                    }
                }
                (chunk, offset, run)
            }
            Layout::MatGrid { n, m, pr, pc } => {
                let (gi, gj) = (lin / n, lin % n);
                let rows = BlockDim::new(*m, *pr);
                let cols = BlockDim::new(*n, *pc);
                let (i, j) = (rows.owner_of(gi), cols.owner_of(gj));
                let offset = (gi - rows.start_of(i)) * cols.size_of(j) + (gj - cols.start_of(j));
                (i * pc + j, offset, cols.end_of(j) - gj)
            }
            Layout::HtGrid { r, n, pr, pc } => {
                let (row, gcol) = (lin / n, lin % n);
                let cols = BlockDim::new(*n, *pc);
                let j = cols.owner_of(gcol);
                let within = gcol - cols.start_of(j);
                let sub = BlockDim::new(cols.size_of(j), *pr);
                let i = sub.owner_of(within);
                let local_col = within - sub.start_of(i);
                // Chunk data is nh × r row-major (H transposed): consecutive
                // columns of H are r elements apart, so runs are length 1.
                (i * pc + j, local_col * r + row, 1)
            }
            Layout::WGrid { m, r, pr, pc } => {
                let (grow, gcol) = (lin / r, lin % r);
                let rows = BlockDim::new(*m, *pr);
                let i = rows.owner_of(grow);
                let within = grow - rows.start_of(i);
                let sub = BlockDim::new(rows.size_of(i), *pc);
                let j = sub.owner_of(within);
                let local_row = within - sub.start_of(j);
                // Chunks are mw × r row-major blocks: contiguous to the end
                // of the current row.
                (i * pc + j, local_row * r + gcol, r - gcol)
            }
            Layout::HtPermuted { r, n2, rt, .. } => {
                let (i2, rem) = (lin / (r * rt), lin % (r * rt));
                let (j1, k) = (rem / rt, rem % rt);
                // Element (i2, j1, k) of the permuted array is H[j1, i2·rt+k].
                let h_lin = j1 * (n2 * rt) + i2 * rt + k;
                let (chunk, offset, _) = self.permuted_inner().locate_run(h_lin);
                // The permutation breaks contiguity (and HtGrid runs are
                // single elements anyway).
                (chunk, offset, 1)
            }
        }
    }
}

/// One rank's chunk of a distributed array, dense or sparse — what the
/// drivers feed into [`SharedStore::publish_block`] / [`dist_reshape_x`].
/// Dense and sparse chunks may coexist within one stored array (ranks
/// decide independently how to represent their block).
pub enum TensorBlock {
    /// The chunk's dense row-major buffer.
    Dense(Vec<f64>),
    /// The chunk as a sorted sparse vector over the same row-major order.
    Sparse(SparseChunk),
    /// A dense chunk **already on disk** as raw little-endian `f64`
    /// (the spill byte format — what `dntt-chunks-v1` ingest files
    /// hold). Publishing adopts the file in place: it is never read to
    /// the heap at publish time and never deleted by the store.
    DiskDense { path: PathBuf, len: usize },
    /// A sparse chunk already on disk in the sparse spill record format
    /// `[nnz: u64 | idx: u64 × nnz | vals: f64 × nnz]` (little-endian).
    /// Adopted in place like [`TensorBlock::DiskDense`].
    DiskSparse { path: PathBuf, len: usize, nnz: usize },
}

impl TensorBlock {
    /// Logical (dense) element count of the chunk.
    pub fn len(&self) -> usize {
        match self {
            TensorBlock::Dense(v) => v.len(),
            TensorBlock::Sparse(s) => s.len(),
            TensorBlock::DiskDense { len, .. } | TensorBlock::DiskSparse { len, .. } => *len,
        }
    }

    /// True when the chunk has no logical elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One published chunk. `owned: false` marks an adopted ingest file the
/// store must never delete (see [`TensorBlock::DiskDense`]).
enum ChunkData {
    Mem(Arc<Vec<f64>>),
    Disk { path: PathBuf, len: usize, owned: bool },
    MemSparse(Arc<SparseChunk>),
    DiskSparse { path: PathBuf, len: usize, nnz: usize, owned: bool },
}

/// Heap bytes a resident dense buffer of `len` elements pins.
fn dense_resident_cost(len: usize) -> u64 {
    (len * 8) as u64
}

/// Heap bytes a resident [`SparseChunk`] of `nnz` stored entries pins
/// (8-byte index + 8-byte value per entry; the fixed header is ignored).
fn sparse_resident_cost(nnz: usize) -> u64 {
    (nnz * 16) as u64
}

impl ChunkData {
    /// Resident heap bytes this stored chunk pins while in the store.
    fn resident_cost(&self) -> u64 {
        match self {
            ChunkData::Mem(d) => dense_resident_cost(d.len()),
            ChunkData::MemSparse(s) => sparse_resident_cost(s.nnz()),
            ChunkData::Disk { .. } | ChunkData::DiskSparse { .. } => 0,
        }
    }

    /// Bytes of the spill file this chunk **owns** (0 for in-memory and
    /// adopted chunks).
    fn spill_cost(&self) -> u64 {
        match self {
            ChunkData::Disk { len, owned: true, .. } => (len * 8) as u64,
            ChunkData::DiskSparse { nnz, owned: true, .. } => (8 * (1 + 2 * nnz)) as u64,
            _ => 0,
        }
    }

    /// The backing spill file, owned or adopted.
    fn spill_path(&self) -> Option<&std::path::Path> {
        match self {
            ChunkData::Disk { path, .. } | ChunkData::DiskSparse { path, .. } => Some(path),
            _ => None,
        }
    }

    /// Delete the backing spill file if this chunk owns one.
    fn delete_spill_file(&self) {
        match self {
            ChunkData::Disk { path, owned: true, .. }
            | ChunkData::DiskSparse { path, owned: true, .. } => {
                let _ = std::fs::remove_file(path);
            }
            _ => {}
        }
    }
}

/// Shared resident/spill byte gauges for one [`SharedStore`] and every
/// [`StoreView`] opened from it.
///
/// `resident` counts heap bytes the store currently pins: in-memory
/// chunks plus view caches of spill loads. Memory-mapped chunks are
/// **not** resident — the OS pages them below the budget. Transient
/// encode buffers inside `publish` (bounded by one chunk) and the
/// caller-owned stage-matrix blocks are outside the gauge; DESIGN.md
/// §2.12 states the full accounting contract.
pub struct MemStats {
    resident: AtomicU64,
    peak: AtomicU64,
    spill: AtomicU64,
}

impl MemStats {
    fn new() -> Arc<MemStats> {
        Arc::new(MemStats {
            resident: AtomicU64::new(0),
            peak: AtomicU64::new(0),
            spill: AtomicU64::new(0),
        })
    }

    fn add_resident(&self, bytes: u64) {
        let now = self.resident.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    fn sub_resident(&self, bytes: u64) {
        self.resident.fetch_sub(bytes, Ordering::Relaxed);
    }

    fn add_spill(&self, bytes: u64) {
        self.spill.fetch_add(bytes, Ordering::Relaxed);
    }

    fn sub_spill(&self, bytes: u64) {
        self.spill.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Heap bytes the store currently pins.
    pub fn resident_bytes(&self) -> u64 {
        self.resident.load(Ordering::Relaxed)
    }

    /// High-water mark of [`MemStats::resident_bytes`] over the store's
    /// lifetime.
    pub fn peak_resident_bytes(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    /// Bytes of live spill files the store owns (adopted ingest files
    /// are excluded — the store neither wrote nor deletes them).
    pub fn spill_file_bytes(&self) -> u64 {
        self.spill.load(Ordering::Relaxed)
    }
}

struct Entry {
    layout: Layout,
    chunks: Vec<Option<ChunkData>>,
}

/// A named-array store shared by all ranks of a world.
///
/// [`SharedStore::new`] returns an `Arc` handle because each rank closure
/// of [`Comm::run`] captures its own clone of the handle while all ranks
/// must address the same store. Concurrent `publish` calls to distinct
/// chunks are safe; the publish → barrier → [`SharedStore::view`]
/// discipline (what [`dist_reshape`] does internally) makes the data race
/// free.
pub struct SharedStore {
    spill: SpillMode,
    entries: Mutex<HashMap<String, Entry>>,
    /// When set, drop-time cleanup leaves spill files on disk.
    keep_spill: AtomicBool,
    /// Resident/peak/spill gauges, shared with every view.
    stats: Arc<MemStats>,
    /// Soft memory budget in bytes (0 = unbudgeted). Governs the batch
    /// size of [`dist_reshape_x`]'s dense assembly.
    budget: AtomicU64,
}

impl SharedStore {
    /// Create a store (see [`SpillMode`] for where chunks live).
    pub fn new(spill: SpillMode) -> Arc<SharedStore> {
        Arc::new(SharedStore {
            spill,
            entries: Mutex::new(HashMap::new()),
            keep_spill: AtomicBool::new(false),
            stats: MemStats::new(),
            budget: AtomicU64::new(0),
        })
    }

    /// The store's spill configuration.
    pub fn spill_mode(&self) -> &SpillMode {
        &self.spill
    }

    /// The store's shared byte gauges (resident / peak / owned spill).
    pub fn stats(&self) -> &Arc<MemStats> {
        &self.stats
    }

    /// Convenience accessor: the high-water mark of resident store
    /// bytes — what `dntt-metrics-v1` reports as
    /// `memory.peak_resident_bytes`.
    pub fn peak_resident_bytes(&self) -> u64 {
        self.stats.peak_resident_bytes()
    }

    /// Set (or clear) the soft memory budget in bytes. A set budget
    /// makes [`dist_reshape_x`] assemble dense blocks in bounded
    /// batches sized to `budget / world_size` per rank.
    pub fn set_budget(&self, budget: Option<u64>) {
        self.budget.store(budget.unwrap_or(0), Ordering::Relaxed);
    }

    /// The configured memory budget, if any.
    pub fn budget(&self) -> Option<u64> {
        match self.budget.load(Ordering::Relaxed) {
            0 => None,
            b => Some(b),
        }
    }

    /// Escape hatch for drop-time cleanup: when `true`, spill files of
    /// arrays still stored at drop are left on disk (for post-mortem
    /// inspection of an out-of-core run).
    pub fn set_keep_spill(&self, keep: bool) {
        self.keep_spill.store(keep, std::sync::atomic::Ordering::Relaxed);
    }

    /// Current [`SharedStore::set_keep_spill`] setting.
    pub fn keep_spill(&self) -> bool {
        self.keep_spill.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Validate chunk index, chunk length and (pre-spill) layout
    /// agreement for a publish of `data_len` logical elements.
    fn check_publish(
        &self,
        name: &str,
        layout: &Layout,
        chunk: usize,
        data_len: usize,
    ) -> Result<()> {
        if chunk >= layout.num_chunks() {
            return Err(DnttError::shape(format!(
                "publish {name}: chunk {chunk} out of range for {} chunks",
                layout.num_chunks()
            )));
        }
        let want = layout.chunk_len(chunk);
        if data_len != want {
            return Err(DnttError::shape(format!(
                "publish {name}: chunk {chunk} has {data_len} elements, layout expects {want}"
            )));
        }
        // Validate layout agreement before touching the filesystem so a
        // clashing publish cannot leak an orphan spill file.
        let entries = self.entries.lock().unwrap();
        if let Some(entry) = entries.get(name) {
            if entry.layout != *layout {
                return Err(Self::layout_clash(name));
            }
        }
        Ok(())
    }

    fn layout_clash(name: &str) -> DnttError {
        DnttError::shape(format!("publish {name}: layout disagrees with the first publisher"))
    }

    /// Insert a stored chunk, handling the lost-race-with-conflicting-
    /// first-publisher case (the loser's own spill file is deleted) and
    /// re-publish accounting (the superseded chunk's bytes are released
    /// and its spill file reclaimed before the replacement is counted).
    fn insert_chunk(
        &self,
        name: &str,
        layout: &Layout,
        chunk: usize,
        stored: ChunkData,
    ) -> Result<()> {
        let mut entries = self.entries.lock().unwrap();
        let entry = entries.entry(name.to_string()).or_insert_with(|| Entry {
            layout: layout.clone(),
            chunks: (0..layout.num_chunks()).map(|_| None).collect(),
        });
        if entry.layout != *layout {
            // Dense and sparse spills share the `{name}.{chunk}.chunk`
            // path, so a winner may already reference the very file the
            // loser wrote — deleting it then would corrupt the stored
            // array. Only delete when no chunk of the winning entry
            // points at the same file.
            let loser_path = stored.spill_path();
            let clashes = loser_path.is_some()
                && entry.chunks.iter().flatten().any(|c| c.spill_path() == loser_path);
            if !clashes {
                stored.delete_spill_file();
            }
            return Err(Self::layout_clash(name));
        }
        if let Some(old) = entry.chunks[chunk].take() {
            // Re-publish of an existing chunk: release the superseded
            // bytes first so the gauges never double-count, and reclaim
            // the old spill file unless the new chunk reuses its path
            // (same name + index in disk mode overwrites in place).
            self.stats.sub_resident(old.resident_cost());
            self.stats.sub_spill(old.spill_cost());
            if old.spill_path() != stored.spill_path() {
                old.delete_spill_file();
            }
        }
        self.stats.add_resident(stored.resident_cost());
        self.stats.add_spill(stored.spill_cost());
        entry.chunks[chunk] = Some(stored);
        Ok(())
    }

    fn spill_path(&self, dir: &std::path::Path, name: &str, chunk: usize) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        Ok(dir.join(format!("{name}.{chunk}.chunk")))
    }

    /// Publish chunk `chunk` of array `name` under `layout`.
    ///
    /// The first publisher fixes the layout; later publishers must pass an
    /// equal layout. `data.len()` must match `layout.chunk_len(chunk)`.
    /// In disk mode the data is written out and dropped from memory.
    /// `name` must be filesystem-safe (the crate uses names like
    /// `"tt.stage0"`).
    pub fn publish(&self, name: &str, layout: &Layout, chunk: usize, data: Vec<f64>) -> Result<()> {
        self.check_publish(name, layout, chunk, data.len())?;
        let span = crate::obs::span_begin();
        let logical_bytes = (data.len() * 8) as u64;
        let mut spill_bytes = 0u64;
        let stored = match &self.spill {
            SpillMode::Memory => ChunkData::Mem(Arc::new(data)),
            SpillMode::Disk(dir) | SpillMode::Mmap(dir) => {
                let path = self.spill_path(dir, name, chunk)?;
                let bytes = crate::tensor::io::f64s_to_le_bytes(&data);
                std::fs::write(&path, &bytes)?;
                spill_bytes = bytes.len() as u64;
                ChunkData::Disk { path, len: data.len(), owned: true }
            }
        };
        crate::obs::end_store_write(span, logical_bytes, spill_bytes);
        self.insert_chunk(name, layout, chunk, stored)
    }

    /// Publish a **sparse** chunk of array `name` under `layout`. The
    /// chunk's logical length must match `layout.chunk_len(chunk)`; its
    /// index/value pairs cover the same row-major order a dense publish
    /// would. Sparse and dense chunks may be mixed freely within one
    /// array. In disk mode the spill file holds
    /// `[nnz: u64 | idx: u64 × nnz | vals: f64 × nnz]` little-endian, so
    /// spill traffic scales with `nnz`, not the dense chunk size.
    pub fn publish_sparse(
        &self,
        name: &str,
        layout: &Layout,
        chunk: usize,
        data: SparseChunk,
    ) -> Result<()> {
        self.check_publish(name, layout, chunk, data.len())?;
        let span = crate::obs::span_begin();
        // Sparse payloads are accounted at their stored size (nnz-scaled),
        // not the dense-equivalent chunk size.
        let logical_bytes = (8 * (1 + 2 * data.nnz())) as u64;
        let mut spill_bytes = 0u64;
        let stored = match &self.spill {
            SpillMode::Memory => ChunkData::MemSparse(Arc::new(data)),
            SpillMode::Disk(dir) | SpillMode::Mmap(dir) => {
                let path = self.spill_path(dir, name, chunk)?;
                let (len, nnz) = (data.len(), data.nnz());
                let bytes = data.to_spill_bytes();
                std::fs::write(&path, &bytes)?;
                spill_bytes = bytes.len() as u64;
                ChunkData::DiskSparse { path, len, nnz, owned: true }
            }
        };
        crate::obs::end_store_write(span, logical_bytes, spill_bytes);
        self.insert_chunk(name, layout, chunk, stored)
    }

    /// Publish either representation of a chunk (the driver-facing
    /// form). The on-disk variants are **adopted**: the store references
    /// the existing file in place under any spill mode — no heap copy at
    /// publish time, and the file survives `remove`/drop (the ingest
    /// chunk set stays reusable). The file's size is validated against
    /// the expected byte format before insertion.
    pub fn publish_block(
        &self,
        name: &str,
        layout: &Layout,
        chunk: usize,
        data: TensorBlock,
    ) -> Result<()> {
        match data {
            TensorBlock::Dense(v) => self.publish(name, layout, chunk, v),
            TensorBlock::Sparse(s) => self.publish_sparse(name, layout, chunk, s),
            TensorBlock::DiskDense { path, len } => {
                self.adopt(name, layout, chunk, path, len, None)
            }
            TensorBlock::DiskSparse { path, len, nnz } => {
                self.adopt(name, layout, chunk, path, len, Some(nnz))
            }
        }
    }

    /// Adopt a chunk file already on disk in the spill byte format (see
    /// [`TensorBlock::DiskDense`]).
    fn adopt(
        &self,
        name: &str,
        layout: &Layout,
        chunk: usize,
        path: PathBuf,
        len: usize,
        nnz: Option<usize>,
    ) -> Result<()> {
        self.check_publish(name, layout, chunk, len)?;
        let want = match nnz {
            None => 8 * len as u64,
            Some(z) => 8 * (1 + 2 * z) as u64,
        };
        let got = std::fs::metadata(&path)?.len();
        if got != want {
            return Err(DnttError::Artifact(format!(
                "publish {name}: adopted chunk file {path:?} is {got} bytes, format expects {want}"
            )));
        }
        let stored = match nnz {
            None => ChunkData::Disk { path, len, owned: false },
            Some(z) => ChunkData::DiskSparse { path, len, nnz: z, owned: false },
        };
        self.insert_chunk(name, layout, chunk, stored)
    }

    /// Open a read view of array `name`. Errors if the array is unknown or
    /// not all chunks have been published yet (callers barrier between the
    /// last publish and the first view).
    pub fn view(&self, name: &str) -> Result<StoreView> {
        let entries = self.entries.lock().unwrap();
        let entry = entries
            .get(name)
            .ok_or_else(|| DnttError::Comm(format!("store view: no array named '{name}'")))?;
        let mapped = matches!(self.spill, SpillMode::Mmap(_));
        let mut slots = Vec::with_capacity(entry.chunks.len());
        for (c, chunk) in entry.chunks.iter().enumerate() {
            match chunk {
                Some(ChunkData::Mem(data)) => slots.push(ViewSlot::Mem(Arc::clone(data))),
                Some(ChunkData::Disk { path, len, .. }) => {
                    if mapped {
                        slots.push(ViewSlot::Mapped {
                            path: path.clone(),
                            len: *len,
                            map: RefCell::new(None),
                        })
                    } else {
                        slots.push(ViewSlot::Disk {
                            path: path.clone(),
                            len: *len,
                            cache: RefCell::new(None),
                        })
                    }
                }
                Some(ChunkData::MemSparse(data)) => {
                    slots.push(ViewSlot::MemSparse(Arc::clone(data)))
                }
                Some(ChunkData::DiskSparse { path, len, nnz, .. }) => {
                    slots.push(ViewSlot::DiskSparse {
                        path: path.clone(),
                        len: *len,
                        nnz: *nnz,
                        cache: RefCell::new(None),
                    })
                }
                None => {
                    return Err(DnttError::Comm(format!(
                        "store view: array '{name}' is missing chunk {c} (publish not complete?)"
                    )))
                }
            }
        }
        Ok(StoreView {
            layout: entry.layout.clone(),
            slots,
            bytes_read: Cell::new(0),
            stats: Arc::clone(&self.stats),
        })
    }

    /// Drop array `name` (and delete its spill files). Missing names are
    /// ignored. Live [`StoreView`]s of a memory-mode array stay valid;
    /// disk-mode views must be dropped first (ranks barrier before the
    /// owning rank removes).
    pub fn remove(&self, name: &str) {
        let entry = self.entries.lock().unwrap().remove(name);
        if let Some(entry) = entry {
            for chunk in entry.chunks.into_iter().flatten() {
                self.stats.sub_resident(chunk.resident_cost());
                self.stats.sub_spill(chunk.spill_cost());
                chunk.delete_spill_file();
            }
        }
    }
}

impl Drop for SharedStore {
    /// Delete the owned spill files of every array still stored — a
    /// crashed or early-erroring job must not leave `.chunk` litter in
    /// the spill directory (the happy path removes arrays as it consumes
    /// them, so this is usually a no-op). Adopted ingest files are never
    /// deleted. [`SharedStore::set_keep_spill`] opts out.
    fn drop(&mut self) {
        if self.keep_spill() {
            return;
        }
        let entries = self.entries.get_mut().unwrap_or_else(|e| e.into_inner());
        for entry in entries.values() {
            for chunk in entry.chunks.iter().flatten() {
                chunk.delete_spill_file();
            }
        }
    }
}

enum ViewSlot {
    Mem(Arc<Vec<f64>>),
    Disk { path: PathBuf, len: usize, cache: RefCell<Option<Vec<f64>>> },
    MemSparse(Arc<SparseChunk>),
    DiskSparse { path: PathBuf, len: usize, nnz: usize, cache: RefCell<Option<SparseChunk>> },
    /// A dense spill chunk viewed under [`SpillMode::Mmap`]: mapped (or
    /// fallback-read) lazily on first access.
    Mapped { path: PathBuf, len: usize, map: RefCell<Option<mmap::DenseSource>> },
}

/// A chunk's contents as seen through [`StoreView::with_loaded`].
enum Loaded<'a> {
    Dense(&'a [f64]),
    Sparse(&'a SparseChunk),
}

/// A read snapshot of one stored array.
///
/// Disk-mode chunks are loaded lazily (whole chunks at a time) and cached
/// for the life of the view; loaded bytes accumulate in
/// [`StoreView::disk_bytes_read`]. A view is a single-rank object — it is
/// deliberately `!Sync` (interior caches), matching its use inside one
/// rank closure.
pub struct StoreView {
    layout: Layout,
    slots: Vec<ViewSlot>,
    bytes_read: Cell<u64>,
    stats: Arc<MemStats>,
}

impl StoreView {
    /// Layout the array was published under.
    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    /// Number of chunks in the viewed array.
    pub fn num_chunks(&self) -> usize {
        self.slots.len()
    }

    /// Total logical element count.
    pub fn len(&self) -> usize {
        self.layout.total_len()
    }

    /// True when the logical array has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes loaded from spill files so far (0 in memory mode).
    pub fn disk_bytes_read(&self) -> u64 {
        self.bytes_read.get()
    }

    /// True when at least one chunk was published sparse.
    pub fn has_sparse(&self) -> bool {
        self.slots.iter().any(|s| {
            matches!(s, ViewSlot::MemSparse(_) | ViewSlot::DiskSparse { .. })
        })
    }

    /// Upper bound on stored nonzeros: sparse chunks contribute their
    /// `nnz`, dense chunks their full length (their contents are not
    /// scanned). Identical on every rank viewing the same array, so it is
    /// safe to branch on collectively (what [`dist_reshape_x`] does).
    pub fn nnz_estimate(&self) -> usize {
        self.slots
            .iter()
            .enumerate()
            .map(|(c, s)| match s {
                ViewSlot::Mem(_) | ViewSlot::Disk { .. } | ViewSlot::Mapped { .. } => {
                    self.layout.chunk_len(c)
                }
                ViewSlot::MemSparse(d) => d.nnz(),
                ViewSlot::DiskSparse { nnz, .. } => *nnz,
            })
            .sum()
    }

    /// Heap bytes loading chunk `c` would pin: 0 for chunks that are
    /// shared in memory, already cached, or memory-mapped (mapped pages
    /// are the OS's to reclaim); the decoded size for un-cached spill
    /// chunks. The budgeted assembly of [`dist_reshape_x`] batches on
    /// this.
    pub fn load_cost(&self, c: usize) -> u64 {
        match &self.slots[c] {
            ViewSlot::Mem(_) | ViewSlot::MemSparse(_) => 0,
            ViewSlot::Disk { len, cache, .. } => {
                if cache.borrow().is_some() {
                    0
                } else {
                    dense_resident_cost(*len)
                }
            }
            ViewSlot::DiskSparse { nnz, cache, .. } => {
                if cache.borrow().is_some() {
                    0
                } else {
                    sparse_resident_cost(*nnz)
                }
            }
            ViewSlot::Mapped { len, map, .. } => {
                // Supported targets map at zero heap cost; the fallback
                // read costs the decoded buffer like a Disk slot.
                if mmap::SUPPORTED || map.borrow().is_some() {
                    0
                } else {
                    dense_resident_cost(*len)
                }
            }
        }
    }

    /// True when chunk `c` is currently backed by an actual memory
    /// mapping (false before first access, for non-`Mmap` stores, and
    /// on the fallback-read path).
    pub fn chunk_is_mapped(&self, c: usize) -> bool {
        match &self.slots[c] {
            ViewSlot::Mapped { map, .. } => {
                map.borrow().as_ref().map(mmap::DenseSource::is_mapped).unwrap_or(false)
            }
            _ => false,
        }
    }

    /// Drop chunk `c`'s cached load (no-op for in-memory chunks),
    /// releasing its resident bytes — or unmapping it. The next access
    /// re-loads; values are unchanged (spill files are immutable while
    /// viewed).
    pub fn evict(&self, c: usize) {
        self.release_slot(&self.slots[c]);
    }

    fn release_slot(&self, slot: &ViewSlot) {
        match slot {
            ViewSlot::Disk { cache, .. } => {
                if let Some(d) = cache.borrow_mut().take() {
                    self.stats.sub_resident(dense_resident_cost(d.len()));
                }
            }
            ViewSlot::DiskSparse { cache, .. } => {
                if let Some(s) = cache.borrow_mut().take() {
                    self.stats.sub_resident(sparse_resident_cost(s.nnz()));
                }
            }
            ViewSlot::Mapped { map, .. } => {
                if let Some(src) = map.borrow_mut().take() {
                    self.stats.sub_resident(src.resident_cost());
                }
            }
            ViewSlot::Mem(_) | ViewSlot::MemSparse(_) => {}
        }
    }

    /// Partition the chunk indices into consecutive batches whose summed
    /// [`StoreView::load_cost`] stays within `headroom` bytes — always
    /// at least one chunk per batch so progress is made even when a
    /// single chunk exceeds it. `None` yields one batch of everything.
    pub fn plan_batches(&self, headroom: Option<u64>) -> Vec<Vec<usize>> {
        let nc = self.slots.len();
        let headroom = match headroom {
            None => return vec![(0..nc).collect()],
            Some(h) => h,
        };
        let mut batches = Vec::new();
        let mut cur: Vec<usize> = Vec::new();
        let mut cost = 0u64;
        for c in 0..nc {
            let lc = self.load_cost(c);
            if !cur.is_empty() && cost + lc > headroom {
                batches.push(std::mem::take(&mut cur));
                cost = 0;
            }
            cur.push(c);
            cost += lc;
        }
        if !cur.is_empty() {
            batches.push(cur);
        }
        batches
    }

    /// Element at global linear index `lin` of the logical row-major
    /// array.
    ///
    /// # Panics
    /// Panics if a spill file disappeared or is malformed (the spill
    /// directory must outlive every view of it).
    pub fn get(&self, lin: usize) -> f64 {
        let (chunk, offset) = self.layout.locate(lin);
        self.with_loaded(chunk, |data| match data {
            Loaded::Dense(d) => d[offset],
            Loaded::Sparse(s) => s.get(offset),
        })
    }

    /// Copy `dst.len()` consecutive logical elements starting at `lin`
    /// into `dst`, chunk-contiguous run by run (the hot path of
    /// [`dist_reshape`] — constant index arithmetic per run, not per
    /// element). Sparse chunks zero-fill the run and scatter their
    /// nonzeros.
    pub fn read_into(&self, lin: usize, dst: &mut [f64]) {
        crate::obs::count(crate::obs::Ctr::StoreReadBytes, (dst.len() * 8) as u64);
        let mut done = 0;
        while done < dst.len() {
            let (chunk, offset, run) = self.layout.locate_run(lin + done);
            let take = run.min(dst.len() - done);
            self.with_loaded(chunk, |data| match data {
                Loaded::Dense(d) => {
                    dst[done..done + take].copy_from_slice(&d[offset..offset + take]);
                }
                Loaded::Sparse(s) => s.scatter_range(offset, &mut dst[done..done + take]),
            });
            done += take;
        }
    }

    /// [`StoreView::read_into`] restricted to source chunks marked in
    /// `include` (indexed by chunk): runs owned by excluded chunks are
    /// skipped — not loaded, not counted, `dst` untouched there. The
    /// budgeted assembly of [`dist_reshape_x`] calls this once per
    /// batch; the batches partition the chunks, so the union of passes
    /// writes every element exactly once from the same source value —
    /// bitwise-identical to one unrestricted [`StoreView::read_into`],
    /// with the same total `StoreReadBytes`.
    pub fn read_into_chunks(&self, lin: usize, dst: &mut [f64], include: &[bool]) {
        let mut done = 0;
        while done < dst.len() {
            let (chunk, offset, run) = self.layout.locate_run(lin + done);
            let take = run.min(dst.len() - done);
            if include[chunk] {
                crate::obs::count(crate::obs::Ctr::StoreReadBytes, (take * 8) as u64);
                self.with_loaded(chunk, |data| match data {
                    Loaded::Dense(d) => {
                        dst[done..done + take].copy_from_slice(&d[offset..offset + take]);
                    }
                    Loaded::Sparse(s) => s.scatter_range(offset, &mut dst[done..done + take]),
                });
            }
            done += take;
        }
    }

    /// Visit the nonzeros of the logical range `[lin, lin + n)` in
    /// ascending order; `f` receives `(offset within the range, value)`.
    /// Sparse chunks walk their index lists; dense chunks are scanned.
    /// The sparse assembly path of [`dist_reshape_x`] and the pruned-NMF
    /// compress step are built on this.
    pub fn read_nonzeros(&self, lin: usize, n: usize, mut f: impl FnMut(usize, f64)) {
        let mut done = 0;
        while done < n {
            let (chunk, offset, run) = self.layout.locate_run(lin + done);
            let take = run.min(n - done);
            self.with_loaded(chunk, |data| match data {
                Loaded::Dense(d) => {
                    for (k, &v) in d[offset..offset + take].iter().enumerate() {
                        if v != 0.0 {
                            f(done + k, v);
                        }
                    }
                }
                Loaded::Sparse(s) => {
                    s.for_range(offset, take, |i, v| f(done + (i - offset), v));
                }
            });
            done += take;
        }
    }

    /// Clone one chunk under its stored representation (what the
    /// checkpoint subsystem snapshots — see
    /// [`crate::dist::checkpoint::snapshot_array`]).
    pub fn chunk_block(&self, chunk: usize) -> TensorBlock {
        self.with_loaded(chunk, |data| match data {
            Loaded::Dense(d) => TensorBlock::Dense(d.to_vec()),
            Loaded::Sparse(s) => TensorBlock::Sparse(s.clone()),
        })
    }

    /// Assemble the whole logical array in row-major order. Intended for
    /// final gathers and tests; large arrays should be consumed blockwise
    /// via [`dist_reshape`] instead.
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.len()];
        self.read_into(0, &mut out);
        out
    }

    fn load_bytes(&self, path: &std::path::Path) -> Vec<u8> {
        let span = crate::obs::span_begin();
        let bytes = std::fs::read(path).unwrap_or_else(|e| {
            panic!("chunk store: failed to read spill file {path:?}: {e}")
        });
        self.bytes_read.set(self.bytes_read.get() + bytes.len() as u64);
        crate::obs::end_store_read(span, bytes.len() as u64);
        bytes
    }

    fn with_loaded<R>(&self, chunk: usize, f: impl FnOnce(Loaded<'_>) -> R) -> R {
        match &self.slots[chunk] {
            ViewSlot::Mem(data) => f(Loaded::Dense(data.as_slice())),
            ViewSlot::MemSparse(data) => f(Loaded::Sparse(data.as_ref())),
            ViewSlot::Disk { path, len, cache } => {
                let mut cache = cache.borrow_mut();
                if cache.is_none() {
                    let bytes = self.load_bytes(path);
                    assert!(
                        bytes.len() == len * 8,
                        "chunk store: spill file {path:?} is {} bytes, expected {}",
                        bytes.len(),
                        len * 8
                    );
                    let data = crate::tensor::io::le_bytes_to_f64s(&bytes);
                    self.stats.add_resident(dense_resident_cost(data.len()));
                    *cache = Some(data);
                }
                f(Loaded::Dense(cache.as_ref().unwrap().as_slice()))
            }
            ViewSlot::DiskSparse { path, len, nnz, cache } => {
                let mut cache = cache.borrow_mut();
                if cache.is_none() {
                    let bytes = self.load_bytes(path);
                    let data = SparseChunk::from_spill_bytes(*len, &bytes).unwrap_or_else(|e| {
                        panic!("chunk store: corrupt sparse spill file {path:?}: {e}")
                    });
                    assert_eq!(data.nnz(), *nnz, "chunk store: sparse spill nnz mismatch");
                    self.stats.add_resident(sparse_resident_cost(data.nnz()));
                    *cache = Some(data);
                }
                f(Loaded::Sparse(cache.as_ref().unwrap()))
            }
            ViewSlot::Mapped { path, len, map } => {
                let mut map = map.borrow_mut();
                if map.is_none() {
                    let span = crate::obs::span_begin();
                    let src = mmap::DenseSource::open(path, *len).unwrap_or_else(|e| {
                        panic!("chunk store: failed to map spill file {path:?}: {e}")
                    });
                    let nbytes = (len * 8) as u64;
                    // Mapped chunks count as spill reads (the pages do
                    // come off disk) but pin no heap unless the mmap
                    // fallback kicked in.
                    self.bytes_read.set(self.bytes_read.get() + nbytes);
                    crate::obs::count(crate::obs::Ctr::StoreMmapBytes, nbytes);
                    self.stats.add_resident(src.resident_cost());
                    crate::obs::end_store_read(span, nbytes);
                    *map = Some(src);
                }
                f(Loaded::Dense(map.as_ref().unwrap().as_slice()))
            }
        }
    }
}

impl Drop for StoreView {
    /// Release the resident bytes of every cached spill load (and every
    /// mapping) this view holds, so [`MemStats::resident_bytes`] only
    /// ever counts live caches.
    fn drop(&mut self) {
        for slot in &self.slots {
            self.release_slot(slot);
        }
    }
}

/// Raw-libc memory mapping for [`SpillMode::Mmap`] — the build is
/// offline (no `memmap2`), so the two syscalls are declared directly.
/// Mappings are read-only and private; a chunk file must stay intact
/// while mapped (the store's existing "spill dir outlives every view"
/// rule). Unsupported targets (non-unix or big-endian, where the
/// little-endian spill bytes cannot be reinterpreted in place) fall back
/// to a buffered read with identical results.
mod mmap {
    use std::path::Path;

    /// True when this target maps files in place.
    #[cfg(all(unix, target_endian = "little"))]
    pub const SUPPORTED: bool = true;
    #[cfg(not(all(unix, target_endian = "little")))]
    pub const SUPPORTED: bool = false;

    #[cfg(all(unix, target_endian = "little"))]
    mod sys {
        use std::ffi::c_void;
        use std::os::raw::c_int;

        pub const PROT_READ: c_int = 1;
        pub const MAP_PRIVATE: c_int = 2;

        extern "C" {
            pub fn mmap(
                addr: *mut c_void,
                len: usize,
                prot: c_int,
                flags: c_int,
                fd: c_int,
                offset: i64,
            ) -> *mut c_void;
            pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        }
    }

    #[cfg(all(unix, target_endian = "little"))]
    pub struct Mapping {
        ptr: *mut std::ffi::c_void,
        bytes: usize,
    }

    #[cfg(all(unix, target_endian = "little"))]
    impl Drop for Mapping {
        fn drop(&mut self) {
            // SAFETY: `ptr`/`bytes` came from a successful mmap and are
            // unmapped exactly once.
            unsafe {
                sys::munmap(self.ptr, self.bytes);
            }
        }
    }

    /// A dense chunk's f64s: memory-mapped in place when the target
    /// supports it, copied to the heap otherwise.
    pub enum DenseSource {
        #[cfg(all(unix, target_endian = "little"))]
        Mapped(Mapping),
        Copied(Vec<f64>),
    }

    impl DenseSource {
        /// Map (or fallback-read) `path`, which must hold exactly `len`
        /// little-endian f64s.
        pub fn open(path: &Path, len: usize) -> std::io::Result<DenseSource> {
            #[cfg(all(unix, target_endian = "little"))]
            {
                if len > 0 {
                    if let Some(m) = Self::try_map(path, len)? {
                        return Ok(DenseSource::Mapped(m));
                    }
                }
            }
            let bytes = std::fs::read(path)?;
            if bytes.len() != len * 8 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("chunk file is {} bytes, expected {}", bytes.len(), len * 8),
                ));
            }
            Ok(DenseSource::Copied(crate::tensor::io::le_bytes_to_f64s(&bytes)))
        }

        #[cfg(all(unix, target_endian = "little"))]
        fn try_map(path: &Path, len: usize) -> std::io::Result<Option<Mapping>> {
            use std::os::fd::AsRawFd;
            let f = std::fs::File::open(path)?;
            let actual = f.metadata()?.len();
            let bytes = len * 8;
            if actual != bytes as u64 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("chunk file is {actual} bytes, expected {bytes}"),
                ));
            }
            // SAFETY: read-only private mapping of a regular file we
            // just opened; length matches the file size. The mapping is
            // page-aligned, which satisfies f64 alignment.
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    bytes,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    f.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize == -1 {
                // MAP_FAILED: report "not mappable" and let the caller
                // fall back to a read rather than failing the job.
                return Ok(None);
            }
            Ok(Some(Mapping { ptr, bytes }))
        }

        /// The chunk's elements (zero-copy when mapped).
        pub fn as_slice(&self) -> &[f64] {
            match self {
                #[cfg(all(unix, target_endian = "little"))]
                // SAFETY: the mapping is page-aligned, read-only, lives
                // as long as `self`, and spans exactly `bytes` of
                // little-endian f64 data on a little-endian target.
                DenseSource::Mapped(m) => unsafe {
                    std::slice::from_raw_parts(m.ptr as *const f64, m.bytes / 8)
                },
                DenseSource::Copied(v) => v.as_slice(),
            }
        }

        /// Heap bytes this source pins (0 when mapped).
        pub fn resident_cost(&self) -> u64 {
            match self {
                #[cfg(all(unix, target_endian = "little"))]
                DenseSource::Mapped(_) => 0,
                DenseSource::Copied(v) => (v.len() * 8) as u64,
            }
        }

        /// True when backed by an actual mapping (tests assert the
        /// supported path really maps).
        pub fn is_mapped(&self) -> bool {
            match self {
                #[cfg(all(unix, target_endian = "little"))]
                DenseSource::Mapped(_) => true,
                DenseSource::Copied(_) => false,
            }
        }
    }
}

/// Global-density cutoff for [`dist_reshape_x`]'s output representation:
/// when at least one source chunk is sparse and the stored-nonzero
/// estimate is at most this fraction of the logical size, the assembled
/// stage-matrix block is returned sparse (CSR). Above it, scattering
/// into a dense block is both smaller and faster for the kernels.
pub const SPARSE_RESHAPE_CUTOFF: f64 = 0.25;

/// Alg 1: globally reshape/redistribute the array held as `my_data` under
/// `layout` into this rank's block of the `m × n` stage matrix on `grid`.
///
/// Collective over `world` (`grid.size() == world.size()`); `my_data` is
/// the chunk for `world.rank()`. Because every layout's logical order is
/// row-major and a row-major reshape is the identity on linear order, the
/// returned block `(i, j) = grid.coords(world.rank())` satisfies
/// `block[(li, lj)] == A[rows.start_of(i) + li, cols.start_of(j) + lj]`
/// for the serial reshape `A` of the logical array (`rows`/`cols` the
/// [`BlockDim`]s of `m`/`n` over `pr`/`pc`) — asserted against the dense
/// reshape in `tests/integration_dist.rs`.
///
/// Cost accounting on `world.breakdown`: publish and spill reads under
/// `IO` (bytes included), index mapping + block assembly under `Reshape`.
/// The store entry `name` is removed before returning — rank 0 drops it
/// between two trailing barriers, so the same name may be safely reused
/// by the next collective call.
///
/// ```
/// use dntt::dist::{dist_reshape, Comm, Grid2d, Layout, SharedStore, SpillMode};
///
/// // A 4×2 matrix held as two row blocks, redistributed as the 2×4
/// // reshape's row blocks on a 2×1 grid (same row-major linear order).
/// let store = SharedStore::new(SpillMode::Memory);
/// let grid = Grid2d::new(2, 1);
/// let layout = Layout::MatGrid { m: 4, n: 2, pr: 2, pc: 1 };
/// let blocks = Comm::run(2, move |mut world| {
///     let r = world.rank();
///     let mine: Vec<f64> = (0..4).map(|k| (4 * r + k) as f64).collect();
///     dist_reshape(&mut world, &store, "a", &layout, mine, 2, 4, grid).unwrap()
/// });
/// assert_eq!(blocks[0].as_slice(), &[0.0, 1.0, 2.0, 3.0]);
/// assert_eq!(blocks[1].as_slice(), &[4.0, 5.0, 6.0, 7.0]);
/// ```
#[allow(clippy::too_many_arguments)]
pub fn dist_reshape(
    world: &mut Comm,
    store: &SharedStore,
    name: &str,
    layout: &Layout,
    my_data: Vec<f64>,
    m: usize,
    n: usize,
    grid: Grid2d,
) -> Result<Mat<f64>> {
    match dist_reshape_x(world, store, name, layout, TensorBlock::Dense(my_data), m, n, grid)? {
        DenseOrSparse::Dense(block) => Ok(block),
        // Unreachable in practice: with no sparse chunk published the
        // assembly is always dense.
        DenseOrSparse::Sparse(s) => Ok(s.to_dense()),
    }
}

/// [`dist_reshape`] for dense **or sparse** chunks: publishes whichever
/// representation this rank holds and assembles the target block sparse
/// (CSR) when the array's global stored density is at most
/// [`SPARSE_RESHAPE_CUTOFF`], dense otherwise. The decision is a pure
/// function of the (barrier-synchronized) store state, so every rank in
/// the world takes the same branch.
#[allow(clippy::too_many_arguments)]
pub fn dist_reshape_x(
    world: &mut Comm,
    store: &SharedStore,
    name: &str,
    layout: &Layout,
    my_data: TensorBlock,
    m: usize,
    n: usize,
    grid: Grid2d,
) -> Result<DenseOrSparse> {
    if layout.total_len() != m * n {
        return Err(DnttError::shape(format!(
            "dist_reshape {name}: layout has {} elements, target is {m}x{n}",
            layout.total_len()
        )));
    }
    if grid.size() != world.size() {
        return Err(DnttError::Comm(format!(
            "dist_reshape {name}: grid {}x{} vs world of {}",
            grid.pr,
            grid.pc,
            world.size()
        )));
    }
    if layout.num_chunks() != world.size() {
        return Err(DnttError::Comm(format!(
            "dist_reshape {name}: layout has {} chunks for {} ranks",
            layout.num_chunks(),
            world.size()
        )));
    }
    let rank = world.rank();

    let t0 = Instant::now();
    if let Err(e) = store.publish_block(name, layout, rank, my_data) {
        // Divergent failure (e.g. this rank's spill write failed): peers
        // are already heading into the barrier — abort so they fail fast
        // instead of deadlocking.
        world.abort(&format!("dist_reshape {name}: publish failed: {e}"));
        return Err(e);
    }
    world.breakdown.add_secs(Cat::Io, t0.elapsed().as_secs_f64());
    world.barrier();

    let view = store.view(name)?;
    let (i, j) = grid.coords(rank);
    let rows = BlockDim::new(m, grid.pr);
    let cols = BlockDim::new(n, grid.pc);
    let (r0, c0) = (rows.start_of(i), cols.start_of(j));
    let width = cols.size_of(j);
    let my_rows = rows.size_of(i);
    let want_sparse = view.has_sparse()
        && (view.nnz_estimate() as f64) <= SPARSE_RESHAPE_CUTOFF * (m * n) as f64;
    let t1 = Instant::now();
    let block = if want_sparse {
        let mut idx = Vec::new();
        let mut vals = Vec::new();
        for li in 0..my_rows {
            let base = li * width;
            view.read_nonzeros((r0 + li) * n + c0, width, |off, v| {
                idx.push(base + off);
                vals.push(v);
            });
        }
        world.breakdown.add_bytes(Cat::Reshape, (vals.len() * 16) as u64);
        match SparseMat::from_linear(my_rows, width, &idx, &vals) {
            Ok(sm) => DenseOrSparse::Sparse(sm),
            Err(e) => {
                // Unreachable (indices are sorted by construction), but a
                // silent early return would strand peers in the trailing
                // barriers — same discipline as the publish failure above.
                world.abort(&format!("dist_reshape {name}: sparse assembly failed: {e}"));
                return Err(e);
            }
        }
    } else {
        // Budgeted streaming assembly: cap this rank's cached spill
        // loads at its share of the store budget and sweep the block
        // once per chunk batch, evicting between batches. With no
        // budget this is one batch over all chunks — the classic path.
        // Either way every element is copied exactly once from the same
        // source value, so the result is independent of the partition.
        let mut block = Mat::zeros(my_rows, width);
        let headroom = store.budget().map(|b| (b / world.size() as u64).max(1));
        let batches = view.plan_batches(headroom);
        crate::obs::count(crate::obs::Ctr::ReshapeBatches, batches.len() as u64);
        let multi = batches.len() > 1;
        let mut include = vec![false; view.num_chunks()];
        for batch in &batches {
            for &c in batch {
                include[c] = true;
            }
            for li in 0..my_rows {
                view.read_into_chunks((r0 + li) * n + c0, block.row_mut(li), &include);
            }
            for &c in batch {
                include[c] = false;
                if multi {
                    view.evict(c);
                }
            }
        }
        world.breakdown.add_bytes(Cat::Reshape, (block.len() * 8) as u64);
        DenseOrSparse::Dense(block)
    };
    world.breakdown.add_secs(Cat::Reshape, t1.elapsed().as_secs_f64());
    world.breakdown.add_bytes(Cat::Io, view.disk_bytes_read());
    drop(view);

    // Two barriers around the drop: the first keeps the owner from
    // removing while peers still read; the second keeps peers from
    // republishing the same name before it is removed.
    world.barrier();
    if rank == 0 {
        store.remove(name);
    }
    world.barrier();
    Ok(block)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_grid_locate_is_block_row_major() {
        // dims [4, 3], grid [2, 1]: chunk 0 = rows 0..2, chunk 1 = rows 2..4.
        let l = Layout::TensorGrid { dims: vec![4, 3], grid: vec![2, 1] };
        assert_eq!(l.total_len(), 12);
        assert_eq!(l.num_chunks(), 2);
        assert_eq!(l.chunk_len(0), 6);
        assert_eq!(l.locate(0), (0, 0));
        assert_eq!(l.locate(5), (0, 5));
        assert_eq!(l.locate(6), (1, 0));
        assert_eq!(l.locate(11), (1, 5));
    }

    #[test]
    fn mat_grid_locate_uneven() {
        // 3x5 over 2x2: blocks (2x3, 2x2, 1x3, 1x2).
        let l = Layout::MatGrid { m: 3, n: 5, pr: 2, pc: 2 };
        assert_eq!(
            (0..4).map(|c| l.chunk_len(c)).collect::<Vec<_>>(),
            vec![6, 4, 3, 2]
        );
        // element (2, 4) = lin 14 -> chunk (1,1), local (0,1).
        assert_eq!(l.locate(14), (3, 1));
        // element (0, 3) = lin 3 -> chunk (0,1), local (0,0).
        assert_eq!(l.locate(3), (1, 0));
    }

    #[test]
    fn locate_run_spans_to_block_edges() {
        let l = Layout::MatGrid { m: 3, n: 5, pr: 2, pc: 2 };
        // Row 0: a 3-wide run in chunk (0,0), then a 2-wide run in (0,1).
        assert_eq!(l.locate_run(0), (0, 0, 3));
        assert_eq!(l.locate_run(3), (1, 0, 2));
        let t = Layout::TensorGrid { dims: vec![4, 6], grid: vec![2, 3] };
        // lin 2 = index (0, 2): column block 1 spans 2..4 → run of 2.
        assert_eq!(t.locate_run(2), (1, 0, 2));
        // HtGrid is transposed: runs never exceed one element.
        let h = Layout::HtGrid { r: 3, n: 4, pr: 1, pc: 2 };
        for lin in 0..h.total_len() {
            assert_eq!(h.locate_run(lin).2, 1);
        }
    }

    #[test]
    fn ht_grid_roundtrips_through_store() {
        // H: 2x5 over a 1x2 grid, pr=1 -> chunk j holds cols of block j,
        // transposed.
        let (r, n, pr, pc) = (2usize, 5usize, 1usize, 2usize);
        let l = Layout::HtGrid { r, n, pr, pc };
        let h: Vec<f64> = (0..r * n).map(|x| x as f64).collect(); // row-major H
        let store = SharedStore::new(SpillMode::Memory);
        let cols = BlockDim::new(n, pc);
        for j in 0..pc {
            let nj = cols.size_of(j);
            // nh x r row-major transposed block (pr = 1 -> whole col block).
            let mut chunk = Vec::with_capacity(nj * r);
            for lc in 0..nj {
                for row in 0..r {
                    chunk.push(h[row * n + cols.start_of(j) + lc]);
                }
            }
            store.publish("h", &l, j, chunk).unwrap();
        }
        assert_eq!(store.view("h").unwrap().to_dense(), h);
    }

    #[test]
    fn w_grid_roundtrips_through_store() {
        // W: 5x2 over a 2x2 grid: block-row 0 = rows 0..3 (sub-split 2|1),
        // block-row 1 = rows 3..5 (sub-split 1|1).
        let (m, r, pr, pc) = (5usize, 2usize, 2usize, 2usize);
        let l = Layout::WGrid { m, r, pr, pc };
        assert_eq!(l.total_len(), 10);
        assert_eq!(l.num_chunks(), 4);
        assert_eq!(
            (0..4).map(|c| l.chunk_len(c)).collect::<Vec<_>>(),
            vec![4, 2, 2, 2]
        );
        let w: Vec<f64> = (0..m * r).map(|x| x as f64).collect();
        let store = SharedStore::new(SpillMode::Memory);
        let rows = BlockDim::new(m, pr);
        for i in 0..pr {
            let sub = BlockDim::new(rows.size_of(i), pc);
            for j in 0..pc {
                let g0 = rows.start_of(i) + sub.start_of(j);
                let chunk: Vec<f64> =
                    w[g0 * r..(g0 + sub.size_of(j)) * r].to_vec();
                store.publish("w", &l, i * pc + j, chunk).unwrap();
            }
        }
        assert_eq!(store.view("w").unwrap().to_dense(), w);
        // Runs extend to the end of a row.
        assert_eq!(l.locate_run(0).2, 2);
        assert_eq!(l.locate_run(1).2, 1);
    }

    #[test]
    fn ht_permuted_presents_permuted_order() {
        // H: r=2 x (n2*rt = 3*2 = 6) over a 1x2 grid, published in the
        // HtGrid chunking; the permuted view must read (i2, j1, k) order.
        let (r, n2, rt, pr, pc) = (2usize, 3usize, 2usize, 1usize, 2usize);
        let n = n2 * rt;
        let perm = Layout::HtPermuted { r, n2, rt, pr, pc };
        assert_eq!(perm.total_len(), r * n);
        let inner = Layout::HtGrid { r, n, pr, pc };
        let h: Vec<f64> = (0..r * n).map(|x| x as f64).collect(); // row-major H
        let store = SharedStore::new(SpillMode::Memory);
        let cols = BlockDim::new(n, pc);
        for j in 0..pc {
            let nj = cols.size_of(j);
            let mut chunk = Vec::with_capacity(nj * r);
            for lc in 0..nj {
                for row in 0..r {
                    chunk.push(h[row * n + cols.start_of(j) + lc]);
                }
            }
            // Chunk shapes agree between the inner and permuted layouts.
            assert_eq!(inner.chunk_len(j), chunk.len());
            store.publish("hp", &perm, j, chunk).unwrap();
        }
        let mut want = Vec::with_capacity(r * n);
        for i2 in 0..n2 {
            for j1 in 0..r {
                for k in 0..rt {
                    want.push(h[j1 * n + i2 * rt + k]);
                }
            }
        }
        assert_eq!(store.view("hp").unwrap().to_dense(), want);
        for lin in 0..perm.total_len() {
            assert_eq!(perm.locate_run(lin).2, 1);
        }
    }

    #[test]
    fn publish_validates_shapes() {
        let l = Layout::MatGrid { m: 2, n: 2, pr: 1, pc: 1 };
        let store = SharedStore::new(SpillMode::Memory);
        assert!(store.publish("x", &l, 1, vec![0.0; 4]).is_err()); // bad chunk
        assert!(store.publish("x", &l, 0, vec![0.0; 3]).is_err()); // bad len
        assert!(store.publish("x", &l, 0, vec![0.0; 4]).is_ok());
        let other = Layout::MatGrid { m: 4, n: 1, pr: 1, pc: 1 };
        assert!(store.publish("x", &other, 0, vec![0.0; 4]).is_err()); // layout clash
    }

    #[test]
    fn view_requires_all_chunks() {
        let l = Layout::MatGrid { m: 2, n: 2, pr: 2, pc: 1 };
        let store = SharedStore::new(SpillMode::Memory);
        store.publish("x", &l, 0, vec![1.0, 2.0]).unwrap();
        assert!(store.view("x").is_err());
        store.publish("x", &l, 1, vec![3.0, 4.0]).unwrap();
        assert_eq!(store.view("x").unwrap().to_dense(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(store.view("y").is_err());
    }

    #[test]
    fn disk_spill_roundtrip_counts_bytes() {
        let dir = std::env::temp_dir().join(format!("dntt_cs_unit_{}", std::process::id()));
        let l = Layout::MatGrid { m: 2, n: 3, pr: 1, pc: 1 };
        let store = SharedStore::new(SpillMode::Disk(dir.clone()));
        let data: Vec<f64> = (0..6).map(|x| x as f64 * 0.5).collect();
        store.publish("x", &l, 0, data.clone()).unwrap();
        let view = store.view("x").unwrap();
        assert_eq!(view.to_dense(), data);
        assert_eq!(view.disk_bytes_read(), 48);
        // Cached: a second read does not re-load.
        let _ = view.get(0);
        assert_eq!(view.disk_bytes_read(), 48);
        drop(view);
        store.remove("x");
        assert!(!dir.join("x.0.chunk").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mixed_dense_and_sparse_chunks_coexist() {
        // 4x3 over 2x1: chunk 0 dense, chunk 1 sparse — one array.
        let l = Layout::MatGrid { m: 4, n: 3, pr: 2, pc: 1 };
        let store = SharedStore::new(SpillMode::Memory);
        let top: Vec<f64> = (0..6).map(|x| x as f64).collect();
        store.publish("x", &l, 0, top.clone()).unwrap();
        let bottom = SparseChunk::new(6, vec![1, 4], vec![7.0, 8.0]).unwrap();
        store.publish_sparse("x", &l, 1, bottom).unwrap();
        let view = store.view("x").unwrap();
        assert!(view.has_sparse());
        assert_eq!(view.nnz_estimate(), 6 + 2);
        let mut want = top;
        want.extend_from_slice(&[0.0, 7.0, 0.0, 0.0, 8.0, 0.0]);
        assert_eq!(view.to_dense(), want);
        assert_eq!(view.get(7), 7.0);
        assert_eq!(view.get(6), 0.0);
        // read_nonzeros over a range straddling both chunks.
        let mut seen = Vec::new();
        view.read_nonzeros(5, 3, |off, v| seen.push((off, v)));
        assert_eq!(seen, vec![(0, 5.0), (2, 7.0)]);
    }

    #[test]
    fn sparse_publish_validates_shapes() {
        let l = Layout::MatGrid { m: 2, n: 2, pr: 1, pc: 1 };
        let store = SharedStore::new(SpillMode::Memory);
        // Wrong logical length.
        let short = SparseChunk::new(3, vec![0], vec![1.0]).unwrap();
        assert!(store.publish_sparse("x", &l, 0, short).is_err());
        // Empty chunk (zero nonzeros) is legal.
        store.publish_sparse("x", &l, 0, SparseChunk::empty(4)).unwrap();
        let view = store.view("x").unwrap();
        assert_eq!(view.nnz_estimate(), 0);
        assert_eq!(view.to_dense(), vec![0.0; 4]);
    }

    #[test]
    fn sparse_disk_spill_roundtrips_and_counts_nnz_bytes() {
        let dir = std::env::temp_dir().join(format!("dntt_cs_sp_unit_{}", std::process::id()));
        let l = Layout::MatGrid { m: 2, n: 4, pr: 1, pc: 1 };
        let store = SharedStore::new(SpillMode::Disk(dir.clone()));
        let chunk = SparseChunk::new(8, vec![0, 3, 6], vec![1.5, -2.0, 4.0]).unwrap();
        store.publish_sparse("s", &l, 0, chunk.clone()).unwrap();
        let view = store.view("s").unwrap();
        assert_eq!(view.nnz_estimate(), 3);
        assert_eq!(view.to_dense(), chunk.to_dense());
        // Spill file is nnz-sized: 8 * (1 + 2*3) bytes, read once.
        assert_eq!(view.disk_bytes_read(), 8 * 7);
        let _ = view.get(3);
        assert_eq!(view.disk_bytes_read(), 8 * 7);
        drop(view);
        store.remove("s");
        assert!(!dir.join("s.0.chunk").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reshape_x_goes_sparse_below_cutoff_only() {
        use crate::dist::Grid2d;
        // 2 ranks, 4x4 logical array as two 2x4 MatGrid chunks, reshaped
        // to 4x4 on a 2x1 grid.
        let run = |nnz_per_rank: usize| {
            let layout = Layout::MatGrid { m: 4, n: 4, pr: 2, pc: 1 };
            let store = SharedStore::new(SpillMode::Memory);
            let grid = Grid2d::new(2, 1);
            Comm::run(2, move |mut world| {
                let idx: Vec<usize> = (0..nnz_per_rank).collect();
                let vals: Vec<f64> = (0..nnz_per_rank).map(|k| (k + 1) as f64).collect();
                let chunk = SparseChunk::new(8, idx, vals).unwrap();
                dist_reshape_x(
                    &mut world, &store, "r", &layout, TensorBlock::Sparse(chunk), 4, 4, grid,
                )
                .unwrap()
            })
        };
        // 2 nnz per rank → density 4/16 = cutoff → sparse.
        for b in run(2) {
            assert!(b.is_sparse());
            assert_eq!(b.shape(), (2, 4));
        }
        // 5 nnz per rank → density 10/16 > cutoff → dense, same values.
        let dense = run(5);
        assert!(!dense[0].is_sparse());
        assert_eq!(dense[0].to_dense().as_slice(), &[1.0, 2.0, 3.0, 4.0, 5.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn reshape_x_sparse_matches_dense_assembly() {
        use crate::dist::Grid2d;
        // Same logical array published sparse vs dense must assemble to
        // identical blocks (the sparse one merely stored as CSR).
        let layout = Layout::MatGrid { m: 4, n: 6, pr: 2, pc: 2 };
        let grid = Grid2d::new(2, 2);
        let full: Vec<f64> = (0..24)
            .map(|k| if k % 5 == 0 { (k + 1) as f64 } else { 0.0 })
            .collect();
        let run = |sparse: bool| {
            let layout = layout.clone();
            let full = full.clone();
            let store = SharedStore::new(SpillMode::Memory);
            Comm::run(4, move |mut world| {
                let view_chunk = {
                    // Build this rank's MatGrid chunk from the full array.
                    let (i, j) = (world.rank() / 2, world.rank() % 2);
                    let rows = BlockDim::new(4, 2);
                    let cols = BlockDim::new(6, 2);
                    let mut data = Vec::new();
                    for li in 0..rows.size_of(i) {
                        for lj in 0..cols.size_of(j) {
                            data.push(
                                full[(rows.start_of(i) + li) * 6 + cols.start_of(j) + lj],
                            );
                        }
                    }
                    data
                };
                let block = if sparse {
                    TensorBlock::Sparse(SparseChunk::from_dense(&view_chunk))
                } else {
                    TensorBlock::Dense(view_chunk)
                };
                dist_reshape_x(&mut world, &store, "e", &layout, block, 6, 4, grid).unwrap()
            })
        };
        let a = run(true);
        let b = run(false);
        for (x, y) in a.iter().zip(&b) {
            assert!(x.is_sparse() && !y.is_sparse());
            assert_eq!(x.to_dense().as_slice(), y.to_dense().as_slice());
        }
    }

    #[test]
    fn mmap_reads_match_disk_and_memory_bitwise() {
        let dir = std::env::temp_dir().join(format!("dntt_cs_mm_{}", std::process::id()));
        let l = Layout::MatGrid { m: 4, n: 5, pr: 2, pc: 1 };
        let data0: Vec<f64> = (0..10).map(|x| (x as f64).sin()).collect();
        let data1: Vec<f64> = (0..10).map(|x| (x as f64).cos()).collect();
        let mut outs = Vec::new();
        for mode in [
            SpillMode::Memory,
            SpillMode::Disk(dir.join("d")),
            SpillMode::Mmap(dir.join("m")),
        ] {
            let store = SharedStore::new(mode);
            store.publish("x", &l, 0, data0.clone()).unwrap();
            store.publish("x", &l, 1, data1.clone()).unwrap();
            let view = store.view("x").unwrap();
            let dense = view.to_dense();
            assert_eq!(view.get(7).to_bits(), dense[7].to_bits());
            let mut seen = Vec::new();
            view.read_nonzeros(3, 9, |off, v| seen.push((off, v.to_bits())));
            outs.push((dense.iter().map(|v| v.to_bits()).collect::<Vec<_>>(), seen));
        }
        for w in outs.windows(2) {
            assert_eq!(w[0], w[1]);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mmap_mode_maps_dense_chunks_at_zero_heap_cost() {
        let dir = std::env::temp_dir().join(format!("dntt_cs_map_{}", std::process::id()));
        let l = Layout::MatGrid { m: 1, n: 4, pr: 1, pc: 1 };
        let store = SharedStore::new(SpillMode::Mmap(dir.clone()));
        store.publish("x", &l, 0, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let view = store.view("x").unwrap();
        assert!(!view.chunk_is_mapped(0)); // lazy: nothing mapped yet
        assert_eq!(view.to_dense(), vec![1.0, 2.0, 3.0, 4.0]);
        if cfg!(all(unix, target_endian = "little")) {
            assert!(view.chunk_is_mapped(0));
            // Mapped bytes pin no heap.
            assert_eq!(store.stats().resident_bytes(), 0);
            assert_eq!(view.load_cost(0), 0);
        }
        // Eviction unmaps; the next access remaps with the same values.
        view.evict(0);
        assert!(!view.chunk_is_mapped(0));
        assert_eq!(view.get(2), 3.0);
        drop(view);
        store.remove("x");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn republish_releases_superseded_bytes_memory_mode() {
        let l = Layout::MatGrid { m: 2, n: 2, pr: 1, pc: 1 };
        let store = SharedStore::new(SpillMode::Memory);
        let stats = Arc::clone(store.stats());
        assert_eq!(stats.resident_bytes(), 0);
        store.publish("x", &l, 0, vec![1.0; 4]).unwrap();
        assert_eq!(stats.resident_bytes(), 32);
        // Republish of the same chunk must not double-count.
        store.publish("x", &l, 0, vec![2.0; 4]).unwrap();
        assert_eq!(stats.resident_bytes(), 32);
        // Sparse over dense: resident drops to the nnz-scaled cost.
        let sp = SparseChunk::new(4, vec![1], vec![5.0]).unwrap();
        store.publish_sparse("x", &l, 0, sp).unwrap();
        assert_eq!(stats.resident_bytes(), 16);
        store.remove("x");
        assert_eq!(stats.resident_bytes(), 0);
        assert!(stats.peak_resident_bytes() >= 32);
    }

    #[test]
    fn republish_reclaims_superseded_spill_bytes_disk_mode() {
        let dir = std::env::temp_dir().join(format!("dntt_cs_rep_{}", std::process::id()));
        let l = Layout::MatGrid { m: 2, n: 2, pr: 1, pc: 1 };
        let store = SharedStore::new(SpillMode::Disk(dir.clone()));
        let stats = Arc::clone(store.stats());
        store.publish("x", &l, 0, vec![1.0; 4]).unwrap();
        assert_eq!(stats.spill_file_bytes(), 32);
        // Dense → sparse republish rewrites the same path: the gauge
        // follows the new record size, no orphan file is left behind.
        let sp = SparseChunk::new(4, vec![0, 2], vec![3.0, 4.0]).unwrap();
        store.publish_sparse("x", &l, 0, sp).unwrap();
        assert_eq!(stats.spill_file_bytes(), 8 * 5);
        assert_eq!(std::fs::metadata(dir.join("x.0.chunk")).unwrap().len(), 40);
        let view = store.view("x").unwrap();
        assert_eq!(view.to_dense(), vec![3.0, 0.0, 4.0, 0.0]);
        drop(view);
        store.remove("x");
        assert_eq!(stats.spill_file_bytes(), 0);
        assert!(!dir.join("x.0.chunk").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn view_caches_count_and_release_resident_bytes() {
        let dir = std::env::temp_dir().join(format!("dntt_cs_gauge_{}", std::process::id()));
        let store = SharedStore::new(SpillMode::Disk(dir.clone()));
        let stats = Arc::clone(store.stats());
        let l = Layout::MatGrid { m: 2, n: 3, pr: 2, pc: 1 };
        store.publish("x", &l, 0, vec![1.0, 2.0, 3.0]).unwrap();
        store.publish("x", &l, 1, vec![4.0, 5.0, 6.0]).unwrap();
        assert_eq!(stats.resident_bytes(), 0); // everything spilled
        let view = store.view("x").unwrap();
        assert_eq!(view.load_cost(0), 24);
        let _ = view.get(0); // loads chunk 0
        assert_eq!(stats.resident_bytes(), 24);
        assert_eq!(view.load_cost(0), 0); // cached now
        let _ = view.get(3); // loads chunk 1
        assert_eq!(stats.resident_bytes(), 48);
        view.evict(0);
        assert_eq!(stats.resident_bytes(), 24);
        drop(view); // view drop releases the remaining cache
        assert_eq!(stats.resident_bytes(), 0);
        assert_eq!(stats.peak_resident_bytes(), 48);
        store.remove("x");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn adopted_chunk_files_survive_remove_and_drop() {
        let dir = std::env::temp_dir().join(format!("dntt_cs_adopt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ingest.bin");
        std::fs::write(&path, crate::tensor::io::f64s_to_le_bytes(&[1.0, 2.0, 3.0, 4.0]))
            .unwrap();
        let l = Layout::MatGrid { m: 2, n: 2, pr: 1, pc: 1 };
        {
            let store = SharedStore::new(SpillMode::Mmap(dir.join("spill")));
            store
                .publish_block("x", &l, 0, TensorBlock::DiskDense { path: path.clone(), len: 4 })
                .unwrap();
            // Adoption pins no heap and owns no spill bytes.
            assert_eq!(store.stats().resident_bytes(), 0);
            assert_eq!(store.stats().spill_file_bytes(), 0);
            let view = store.view("x").unwrap();
            assert_eq!(view.to_dense(), vec![1.0, 2.0, 3.0, 4.0]);
            drop(view);
            store.remove("x");
            assert!(path.exists(), "adopted ingest file must survive remove");
            // A file whose size disagrees with the format is rejected.
            let l3 = Layout::MatGrid { m: 1, n: 3, pr: 1, pc: 1 };
            assert!(store
                .publish_block("y", &l3, 0, TensorBlock::DiskDense { path: path.clone(), len: 3 })
                .is_err());
        }
        assert!(path.exists(), "adopted ingest file must survive store drop");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn budgeted_reshape_is_bitwise_identical_and_bounded() {
        use crate::dist::Grid2d;
        // 8x8 array as four row blocks, reshaped onto a 1x4 column grid
        // so every rank reads from every source chunk.
        let layout = Layout::MatGrid { m: 8, n: 8, pr: 4, pc: 1 };
        let grid = Grid2d::new(1, 4);
        let run = |budget: Option<u64>| {
            let layout = layout.clone();
            let dir = std::env::temp_dir().join(format!(
                "dntt_cs_bud_{}_{}",
                std::process::id(),
                budget.unwrap_or(0)
            ));
            let store = SharedStore::new(SpillMode::Disk(dir.clone()));
            store.set_budget(budget);
            let stats = Arc::clone(store.stats());
            let blocks = Comm::run(4, move |mut world| {
                let r = world.rank();
                let mine: Vec<f64> = (0..16).map(|k| ((16 * r + k) as f64).sqrt()).collect();
                dist_reshape(&mut world, &store, "b", &layout, mine, 8, 8, grid).unwrap()
            });
            let peak = stats.peak_resident_bytes();
            let _ = std::fs::remove_dir_all(&dir);
            (blocks, peak)
        };
        let (resident, _peak_free) = run(None);
        // 512-byte budget → 128 bytes per rank → one chunk per batch.
        let (streamed, peak_budget) = run(Some(512));
        for (a, b) in resident.iter().zip(&streamed) {
            for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert!(peak_budget <= 512, "peak {peak_budget} exceeds the 512-byte budget");
    }
}
