//! The α-β cluster cost model (§IV-B).
//!
//! Thread ranks measure *work* (GEMM seconds, collective payload bytes)
//! faithfully but measure *communication time* as shared-memory copies.
//! [`CostModel::model_breakdown`] projects a measured [`Breakdown`] onto a
//! `p`-node cluster: compute categories keep their measured time, while
//! each communication category is re-priced as
//!
//! ```text
//! t(cat) = calls · α · ⌈log₂ p⌉  +  bytes · volume(cat, p) / bandwidth
//! ```
//!
//! with `volume = 2(p−1)/p` for all_reduce (reduce + broadcast sweep) and
//! `(p−1)/p` for all_gather / reduce_scatter — the standard
//! latency-bandwidth costs of tree/ring collectives. Spilled-chunk `IO`
//! is re-priced against the filesystem bandwidth. Both terms grow with
//! `p` at fixed volume, reproducing the paper's strong-scaling
//! communication trend (asserted in `tests/integration_dist.rs`).

use crate::util::timer::{Breakdown, Cat, ALL_CATS};

/// Latency-bandwidth model of a target cluster.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    /// Per-message latency of one collective hop, seconds.
    pub alpha: f64,
    /// Interconnect bandwidth, bytes/second.
    pub bandwidth: f64,
    /// Parallel-filesystem bandwidth per rank, bytes/second (spilled IO).
    pub disk_bandwidth: f64,
    /// Multiplier on measured compute time (1.0 = cluster cores match the
    /// measuring machine).
    pub compute_scale: f64,
}

impl Default for CostModel {
    /// A Grizzly-like commodity cluster: ~1 µs MPI latency, 100 Gb/s
    /// interconnect, 500 MB/s parallel filesystem per rank, compute as
    /// measured.
    fn default() -> Self {
        CostModel { alpha: 1.0e-6, bandwidth: 12.5e9, disk_bandwidth: 500.0e6, compute_scale: 1.0 }
    }
}

impl CostModel {
    /// Modeled seconds for one communication category at `p` ranks.
    pub fn comm_secs(&self, cat: Cat, calls: u64, bytes: u64, p: usize) -> f64 {
        let p = p.max(1);
        let hops = (p.max(2) as f64).log2().ceil();
        let volume = match cat {
            Cat::AllReduce => 2.0,
            _ => 1.0,
        } * (p as f64 - 1.0)
            / p as f64;
        calls as f64 * self.alpha * hops + bytes as f64 * volume / self.bandwidth
    }

    /// Project a measured per-rank breakdown onto a `p`-rank cluster.
    ///
    /// Compute categories (GR/MM/MAD/Norm/INIT, SVD, Reshape, Other) keep
    /// their measured seconds (scaled by `compute_scale` for the
    /// NMF-kernel categories); AG/AR/RSC are re-priced by
    /// [`CostModel::comm_secs`]; `IO` with recorded bytes is re-priced
    /// against `disk_bandwidth`. Call and byte counters carry over.
    pub fn model_breakdown(&self, measured: &Breakdown, p: usize) -> Breakdown {
        let mut out = Breakdown::new();
        for &cat in &ALL_CATS {
            let secs = measured.secs(cat);
            let calls = measured.calls(cat);
            let bytes = measured.bytes(cat);
            if secs == 0.0 && calls == 0 && bytes == 0 {
                continue;
            }
            let modeled = if cat.is_comm() {
                self.comm_secs(cat, calls, bytes, p)
            } else if cat == Cat::Io && bytes > 0 {
                bytes as f64 / self.disk_bandwidth
            } else if cat.is_compute() {
                secs * self.compute_scale
            } else {
                secs
            };
            out.add_secs_untallied(cat, modeled);
            out.add_bytes(cat, bytes);
            out.add_calls(cat, calls);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_time_is_preserved() {
        let mut b = Breakdown::new();
        b.add_secs(Cat::MatMul, 2.5);
        b.add_secs(Cat::Gram, 0.5);
        let m = CostModel::default();
        let out = m.model_breakdown(&b, 64);
        assert_eq!(out.secs(Cat::MatMul), 2.5);
        assert_eq!(out.secs(Cat::Gram), 0.5);
        assert_eq!(out.calls(Cat::MatMul), 1);
    }

    #[test]
    fn comm_grows_with_p_at_fixed_volume() {
        let m = CostModel::default();
        let mut b = Breakdown::new();
        b.add_secs(Cat::AllGather, 1e-3);
        b.add_bytes(Cat::AllGather, 1 << 30);
        let prev = m.model_breakdown(&b, 2).comm_secs();
        let mut last = prev;
        for p in [4, 16, 64, 256] {
            let t = m.model_breakdown(&b, p).comm_secs();
            assert!(t > last, "comm time must grow: p={p}, {t} vs {last}");
            last = t;
        }
    }

    #[test]
    fn allreduce_costs_double_volume() {
        let m = CostModel::default();
        let ar = m.comm_secs(Cat::AllReduce, 0, 1 << 20, 16);
        let ag = m.comm_secs(Cat::AllGather, 0, 1 << 20, 16);
        assert!((ar - 2.0 * ag).abs() < 1e-12);
    }

    #[test]
    fn disk_io_repriced_by_bandwidth() {
        let m = CostModel::default();
        let mut b = Breakdown::new();
        b.add_secs(Cat::Io, 1e-4);
        b.add_bytes(Cat::Io, 500_000_000);
        let out = m.model_breakdown(&b, 8);
        assert!((out.secs(Cat::Io) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bytes_only_category_gets_no_phantom_calls() {
        let mut b = Breakdown::new();
        b.add_bytes(Cat::AllGather, 4096);
        let out = CostModel::default().model_breakdown(&b, 8);
        assert_eq!(out.calls(Cat::AllGather), 0);
        assert!(out.secs(Cat::AllGather) > 0.0);
        assert_eq!(out.bytes(Cat::AllGather), 4096);
    }

    #[test]
    fn call_counters_carry_over() {
        let mut b = Breakdown::new();
        for _ in 0..5 {
            b.add_secs(Cat::AllReduce, 1e-5);
        }
        let out = CostModel::default().model_breakdown(&b, 4);
        assert_eq!(out.calls(Cat::AllReduce), 5);
    }
}
