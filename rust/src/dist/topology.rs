//! Processor-grid topology: the d-dimensional tensor grid, its 2-D
//! collapse for the NMF stage, and the 1-D block distribution.
//!
//! The paper distributes the input tensor over a `p_1 × ⋯ × p_d` grid
//! ([`ProcGrid`]) and every stage matrix over the collapsed
//! `p_1 × (p_2⋯p_d)` grid ([`ProcGrid::to_2d`], a [`Grid2d`]). Both grids
//! linearize ranks **row-major** (last coordinate fastest), matching the
//! row-major data layout everywhere else in the crate. [`BlockDim`] is the
//! shared 1-D block partition: `n` items over `p` parts, contiguous, the
//! first `n mod p` parts one element larger — uneven and empty blocks are
//! first-class (tests exercise `13×17` over `2×3`).

use crate::dist::comm::Comm;
use crate::error::{DnttError, Result};

/// Contiguous block distribution of `n` items over `p` parts.
///
/// Part `i` holds `[start_of(i), end_of(i))`; sizes differ by at most one
/// and parts beyond `n` (when `p > n`) are empty.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockDim {
    n: usize,
    p: usize,
}

impl BlockDim {
    /// Distribution of `n` items over `p ≥ 1` parts.
    pub fn new(n: usize, p: usize) -> Self {
        assert!(p >= 1, "BlockDim needs at least one part");
        BlockDim { n, p }
    }

    /// Total item count.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of parts.
    #[inline]
    pub fn parts(&self) -> usize {
        self.p
    }

    /// Number of items in part `i`.
    #[inline]
    pub fn size_of(&self, i: usize) -> usize {
        debug_assert!(i < self.p);
        self.n / self.p + usize::from(i < self.n % self.p)
    }

    /// First global index of part `i`.
    #[inline]
    pub fn start_of(&self, i: usize) -> usize {
        debug_assert!(i < self.p);
        i * (self.n / self.p) + i.min(self.n % self.p)
    }

    /// One past the last global index of part `i`.
    #[inline]
    pub fn end_of(&self, i: usize) -> usize {
        self.start_of(i) + self.size_of(i)
    }

    /// The part that owns global index `g < n`.
    #[inline]
    pub fn owner_of(&self, g: usize) -> usize {
        debug_assert!(g < self.n);
        let q = self.n / self.p;
        let r = self.n % self.p;
        let boundary = (q + 1) * r; // first r parts have q+1 items
        if g < boundary {
            g / (q + 1)
        } else {
            r + (g - boundary) / q
        }
    }
}

/// A `d`-dimensional processor grid over the tensor modes.
///
/// Ranks are linearized row-major: rank = coords[0]·(p_2⋯p_d) + … .
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProcGrid {
    dims: Vec<usize>,
}

impl ProcGrid {
    /// A grid with the given per-mode extents (all ≥ 1, at least 1 mode).
    pub fn new(dims: Vec<usize>) -> Result<ProcGrid> {
        if dims.is_empty() {
            return Err(DnttError::config("processor grid needs at least one mode"));
        }
        if dims.iter().any(|&d| d == 0) {
            return Err(DnttError::config(format!("processor grid {dims:?} has a zero extent")));
        }
        Ok(ProcGrid { dims })
    }

    /// The paper's scaling-study grid `2^k × 2 × ⋯ × 2` over `d` modes
    /// (Figs 5–7 use `d = 4`, k = 1..=5).
    pub fn paper_grid(k: usize, d: usize) -> Result<ProcGrid> {
        if d == 0 {
            return Err(DnttError::config("paper_grid needs at least one mode"));
        }
        let mut dims = vec![2; d];
        dims[0] = 1usize << k;
        ProcGrid::new(dims)
    }

    /// Per-mode grid extents.
    #[inline]
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Number of modes.
    #[inline]
    pub fn ndim(&self) -> usize {
        self.dims.len()
    }

    /// Total rank count (product of extents).
    pub fn size(&self) -> usize {
        self.dims.iter().product()
    }

    /// Row-major grid coordinates of `rank`.
    pub fn coords(&self, rank: usize) -> Vec<usize> {
        debug_assert!(rank < self.size());
        let mut c = vec![0; self.dims.len()];
        let mut rem = rank;
        for k in (0..self.dims.len()).rev() {
            c[k] = rem % self.dims[k];
            rem /= self.dims[k];
        }
        c
    }

    /// Inverse of [`ProcGrid::coords`].
    pub fn rank_of(&self, coords: &[usize]) -> usize {
        debug_assert_eq!(coords.len(), self.dims.len());
        coords.iter().zip(&self.dims).fold(0, |acc, (&c, &d)| {
            debug_assert!(c < d);
            acc * d + c
        })
    }

    /// Collapse to the 2-D NMF grid: `p_r = p_1`, `p_c = p_2⋯p_d`
    /// (Alg 2 reshapes every stage matrix onto this grid). Rank numbering
    /// is preserved: a rank's 2-D coordinates are
    /// `(coords[0], row-major(coords[1..]))`.
    pub fn to_2d(&self) -> Grid2d {
        let pr = self.dims[0];
        let pc: usize = self.dims[1..].iter().product::<usize>().max(1);
        Grid2d::new(pr, pc)
    }
}

/// A 2-D `p_r × p_c` processor grid (the NMF stage grid), row-major.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grid2d {
    /// Row count (block-rows of the stage matrix).
    pub pr: usize,
    /// Column count (block-columns of the stage matrix).
    pub pc: usize,
}

impl Grid2d {
    /// A `pr × pc` grid (both ≥ 1).
    pub fn new(pr: usize, pc: usize) -> Self {
        assert!(pr >= 1 && pc >= 1, "Grid2d extents must be at least 1");
        Grid2d { pr, pc }
    }

    /// Total rank count.
    #[inline]
    pub fn size(&self) -> usize {
        self.pr * self.pc
    }

    /// `(i, j)` grid coordinates of `rank` (row-major).
    #[inline]
    pub fn coords(&self, rank: usize) -> (usize, usize) {
        debug_assert!(rank < self.size());
        (rank / self.pc, rank % self.pc)
    }

    /// Inverse of [`Grid2d::coords`].
    #[inline]
    pub fn rank_of(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < self.pr && j < self.pc);
        i * self.pc + j
    }

    /// Split the world into this grid's row and column communicators.
    ///
    /// Collective: every world rank must call it, and `world.size()` must
    /// equal `self.size()`. For world rank `(i, j)`:
    /// * the **row** communicator spans the ranks of grid row `i`; its
    ///   internal rank is `j` (size `pc`);
    /// * the **column** communicator spans grid column `j`; its internal
    ///   rank is `i` (size `pr`).
    ///
    /// The sub-communicators partition the world, so a column-reduce of
    /// row-reduces equals a world reduce (asserted in
    /// `tests/integration_dist.rs`). May be called repeatedly; each call
    /// reserves fresh communicator ids. Sub-communicators cannot
    /// currently be split further.
    pub fn make_subcomms(&self, world: &mut Comm) -> (Comm, Comm) {
        assert_eq!(
            self.size(),
            world.size(),
            "grid {}x{} does not cover a world of {} ranks",
            self.pr,
            self.pc,
            world.size()
        );
        let (i, j) = self.coords(world.rank());
        let base = world.alloc_child_ids((self.pr + self.pc) as u64);
        let row = world.subcomm(base + i as u64, j, self.pc);
        let col = world.subcomm(base + self.pr as u64 + j as u64, i, self.pr);
        (row, col)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blockdim_partitions_exactly() {
        for (n, p) in [(10, 3), (17, 5), (4, 4), (3, 7), (0, 2), (1, 1)] {
            let bd = BlockDim::new(n, p);
            let total: usize = (0..p).map(|i| bd.size_of(i)).sum();
            assert_eq!(total, n, "n={n} p={p}");
            let mut next = 0;
            for i in 0..p {
                assert_eq!(bd.start_of(i), next, "n={n} p={p} i={i}");
                next = bd.end_of(i);
            }
            for g in 0..n {
                let o = bd.owner_of(g);
                assert!(bd.start_of(o) <= g && g < bd.end_of(o), "n={n} p={p} g={g}");
            }
        }
    }

    #[test]
    fn blockdim_uneven_sizes_differ_by_at_most_one() {
        let bd = BlockDim::new(13, 3);
        assert_eq!((bd.size_of(0), bd.size_of(1), bd.size_of(2)), (5, 4, 4));
    }

    #[test]
    fn procgrid_roundtrip_and_to_2d() {
        let g = ProcGrid::new(vec![2, 3, 2]).unwrap();
        assert_eq!(g.size(), 12);
        for r in 0..g.size() {
            assert_eq!(g.rank_of(&g.coords(r)), r);
        }
        let g2 = g.to_2d();
        assert_eq!((g2.pr, g2.pc), (2, 6));
        // 2-D coords are (first coord, row-major of the rest).
        for r in 0..g.size() {
            let c = g.coords(r);
            let (i, j) = g2.coords(r);
            assert_eq!(i, c[0]);
            assert_eq!(j, c[1] * 2 + c[2]);
        }
    }

    #[test]
    fn procgrid_rejects_degenerate() {
        assert!(ProcGrid::new(vec![]).is_err());
        assert!(ProcGrid::new(vec![2, 0, 2]).is_err());
    }

    #[test]
    fn paper_grid_shapes() {
        let g = ProcGrid::paper_grid(1, 4).unwrap();
        assert_eq!(g.dims(), &[2, 2, 2, 2]);
        assert_eq!(g.size(), 16);
        let g = ProcGrid::paper_grid(3, 4).unwrap();
        assert_eq!(g.dims(), &[8, 2, 2, 2]);
        assert_eq!(g.size(), 64);
    }

    #[test]
    fn grid2d_rank_numbering() {
        let g = Grid2d::new(2, 3);
        assert_eq!(g.coords(0), (0, 0));
        assert_eq!(g.coords(4), (1, 1));
        assert_eq!(g.rank_of(1, 2), 5);
    }

    #[test]
    fn subcomms_partition_world() {
        let grid = Grid2d::new(2, 2);
        let outs = Comm::run(4, move |mut world| {
            let (row, col) = grid.make_subcomms(&mut world);
            (row.rank(), row.size(), col.rank(), col.size())
        });
        // world rank 0=(0,0), 1=(0,1), 2=(1,0), 3=(1,1)
        assert_eq!(outs[0], (0, 2, 0, 2));
        assert_eq!(outs[1], (1, 2, 0, 2));
        assert_eq!(outs[2], (0, 2, 1, 2));
        assert_eq!(outs[3], (1, 2, 1, 2));
    }
}
