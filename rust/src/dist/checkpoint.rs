//! `dntt-ckpt-v1`: versioned on-disk snapshots that make every
//! decomposition resumable.
//!
//! The TT sweep and the HT tree walk are stage-structured: after each
//! completed stage the *entire* job state is (a) the replicated outputs so
//! far (TT cores / resolved HT tree nodes), (b) the distributed hand-off
//! arrays (the next remainder `H` for TT; the pending child `W` arrays for
//! HT), and (c) the per-stage convergence records. A checkpoint persists
//! exactly that: every rank writes its chunk of each distributed array in
//! the chunk store's spill byte format (dense: raw little-endian `f64`;
//! sparse: `[nnz | idx | vals]` — see
//! [`crate::dist::chunkstore::SpillMode`]), and rank 0 commits a
//! `manifest.json` (write-to-temp + atomic rename) recording the format
//! version, a configuration fingerprint, the git sha, the layouts, every
//! file's byte size, and the bit-exact stage statistics.
//!
//! # Resume contract
//!
//! A resumed driver validates the manifest (format string, config hash,
//! decomposition/world/grid/dims agreement, and the byte size of **every**
//! referenced file — so truncation is rejected symmetrically on all ranks
//! before any rank commits to the resume path), rehydrates its state, and
//! re-enters the sweep at the first incomplete stage. Because snapshots
//! round-trip chunks byte-exactly and every stage's computation is a
//! deterministic function of its input array and the configuration
//! (deterministic rank-ordered collectives + index-keyed factor init), a
//! job killed at an arbitrary collective and resumed from its last
//! checkpoint produces factors **bitwise identical** to an uninterrupted
//! run — the guarantee `tests/checkpoint_recovery.rs` asserts against the
//! fault-injection layer ([`crate::dist::faults`]).
//!
//! Iteration-granular snapshots ([`CheckpointPolicy::every_iters`],
//! wired through [`crate::nmf::dist::IterObserver`]) additionally persist
//! the in-flight `W`/`H` of the current NMF every N iterations. They
//! bound the work lost to a crash for external consumers; the resume path
//! itself restarts the interrupted stage from its beginning — bitwise
//! equivalence is defined at stage boundaries.

use crate::dist::chunkstore::{Layout, SharedStore, StoreView, TensorBlock};
use crate::dist::comm::Comm;
use crate::dist::topology::Grid2d;
use crate::error::{DnttError, Result};
use crate::ht::driver::HtStageStats;
use crate::linalg::Mat;
use crate::nmf::NmfStats;
use crate::tensor::ht::HtNode;
use crate::tensor::sparse::SparseChunk;
use crate::ttrain::driver::StageStats;
use crate::util::json::Json;
use crate::util::timer::Cat;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// The snapshot format identifier every manifest carries.
pub const CKPT_FORMAT: &str = "dntt-ckpt-v1";

/// When to write snapshots.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CheckpointPolicy {
    /// Snapshot directory (created on first write).
    pub dir: PathBuf,
    /// Snapshot after every N completed stages (TT stages / HT tree
    /// nodes). 0 disables stage snapshots; the default is 1 — every
    /// stage boundary, which is what makes resumed runs bitwise-exact.
    pub every_stages: usize,
    /// Persist the in-flight NMF `W`/`H` every N iterations (0 = off).
    pub every_iters: usize,
}

impl CheckpointPolicy {
    /// Checkpoint into `dir` at every stage boundary.
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        CheckpointPolicy { dir: dir.into(), every_stages: 1, every_iters: 0 }
    }
}

/// Per-rank checkpoint context the coordinator hands the drivers: the
/// policy, the job's configuration fingerprint
/// ([`crate::coordinator::JobConfig::fingerprint`]) and whether this
/// launch should try to resume from an existing manifest.
#[derive(Clone)]
pub struct CkptCtx {
    pub policy: CheckpointPolicy,
    pub config_hash: u64,
    pub resume: bool,
}

impl CkptCtx {
    /// The iteration-granular observer for one NMF stage (None when
    /// `every_iters` is 0). `tag` namespaces the in-flight files per
    /// stage (e.g. `"s0"`, `"n1a"`).
    pub fn iter_ckpt(&self, rank: usize, tag: &str) -> Option<IterCkpt> {
        (self.policy.every_iters > 0).then(|| IterCkpt {
            dir: self.policy.dir.clone(),
            every: self.policy.every_iters,
            rank,
            tag: tag.to_string(),
        })
    }

    /// Should a snapshot be written after `done` completed stages?
    pub fn stage_due(&self, done: usize) -> bool {
        self.policy.every_stages > 0 && done % self.policy.every_stages == 0
    }
}

/// The build's git sha, if the build system provided one.
pub fn git_sha() -> &'static str {
    option_env!("DNTT_GIT_SHA").unwrap_or("unknown")
}

// ---------------------------------------------------------------------------
// Bit-exact scalar codec: factor-adjacent floats are stored as 16-hex-digit
// bit patterns so NaN `svd_eps` and full-precision objectives survive the
// JSON round trip unchanged.
// ---------------------------------------------------------------------------

fn bits_json(v: f64) -> Json {
    Json::Str(format!("{:016x}", v.to_bits()))
}

fn bits_from(j: &Json, what: &str) -> Result<f64> {
    let s = j.as_str().ok_or_else(|| {
        DnttError::config(format!("checkpoint manifest: {what} is not a bit string"))
    })?;
    let b = u64::from_str_radix(s, 16)
        .map_err(|_| DnttError::config(format!("checkpoint manifest: bad bit string for {what}")))?;
    Ok(f64::from_bits(b))
}

fn req_usize(j: &Json, what: &str) -> Result<usize> {
    j.as_usize()
        .ok_or_else(|| DnttError::config(format!("checkpoint manifest: missing {what}")))
}

fn req_usize_arr(j: &Json, what: &str) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| DnttError::config(format!("checkpoint manifest: missing {what}")))?
        .iter()
        .map(|x| {
            x.as_usize().ok_or_else(|| {
                DnttError::config(format!("checkpoint manifest: {what} has a non-integer entry"))
            })
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Layout serialization (all five chunk-store layouts).
// ---------------------------------------------------------------------------

/// Serialize a [`Layout`] for the manifest.
pub fn layout_to_json(l: &Layout) -> Json {
    match l {
        Layout::TensorGrid { dims, grid } => Json::obj(vec![
            ("kind", Json::Str("tensor_grid".into())),
            ("dims", Json::arr_usize(dims)),
            ("grid", Json::arr_usize(grid)),
        ]),
        Layout::MatGrid { m, n, pr, pc } => Json::obj(vec![
            ("kind", Json::Str("mat_grid".into())),
            ("shape", Json::arr_usize(&[*m, *n, *pr, *pc])),
        ]),
        Layout::HtGrid { r, n, pr, pc } => Json::obj(vec![
            ("kind", Json::Str("ht_grid".into())),
            ("shape", Json::arr_usize(&[*r, *n, *pr, *pc])),
        ]),
        Layout::WGrid { m, r, pr, pc } => Json::obj(vec![
            ("kind", Json::Str("w_grid".into())),
            ("shape", Json::arr_usize(&[*m, *r, *pr, *pc])),
        ]),
        Layout::HtPermuted { r, n2, rt, pr, pc } => Json::obj(vec![
            ("kind", Json::Str("ht_permuted".into())),
            ("shape", Json::arr_usize(&[*r, *n2, *rt, *pr, *pc])),
        ]),
    }
}

/// Parse a [`Layout`] back from its manifest form.
pub fn layout_from_json(j: &Json) -> Result<Layout> {
    let kind = j
        .get("kind")
        .as_str()
        .ok_or_else(|| DnttError::config("checkpoint manifest: layout missing kind"))?;
    let shape = |n: usize| -> Result<Vec<usize>> {
        let s = req_usize_arr(j.get("shape"), "layout shape")?;
        if s.len() != n {
            return Err(DnttError::config(format!(
                "checkpoint manifest: layout '{kind}' wants {n} extents, got {}",
                s.len()
            )));
        }
        Ok(s)
    };
    match kind {
        "tensor_grid" => Ok(Layout::TensorGrid {
            dims: req_usize_arr(j.get("dims"), "layout dims")?,
            grid: req_usize_arr(j.get("grid"), "layout grid")?,
        }),
        "mat_grid" => {
            let s = shape(4)?;
            Ok(Layout::MatGrid { m: s[0], n: s[1], pr: s[2], pc: s[3] })
        }
        "ht_grid" => {
            let s = shape(4)?;
            Ok(Layout::HtGrid { r: s[0], n: s[1], pr: s[2], pc: s[3] })
        }
        "w_grid" => {
            let s = shape(4)?;
            Ok(Layout::WGrid { m: s[0], r: s[1], pr: s[2], pc: s[3] })
        }
        "ht_permuted" => {
            let s = shape(5)?;
            Ok(Layout::HtPermuted { r: s[0], n2: s[1], rt: s[2], pr: s[3], pc: s[4] })
        }
        other => {
            Err(DnttError::config(format!("checkpoint manifest: unknown layout kind '{other}'")))
        }
    }
}

// ---------------------------------------------------------------------------
// Chunk files: the chunk store's spill byte formats, verbatim.
// ---------------------------------------------------------------------------

/// Manifest record of one snapshot chunk file.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChunkMeta {
    /// File name, relative to the checkpoint directory.
    pub file: String,
    /// Exact byte size (what truncation detection validates).
    pub bytes: u64,
    /// Logical (dense) element count of the chunk.
    pub len: usize,
    /// `Some(nnz)` for a sparse chunk, `None` for dense.
    pub nnz: Option<usize>,
}

impl ChunkMeta {
    /// The byte size the format dictates for this chunk (dense: 8·len,
    /// sparse: 8·(1 + 2·nnz)).
    fn expect_bytes(&self) -> u64 {
        match self.nnz {
            None => 8 * self.len as u64,
            Some(nnz) => 8 * (1 + 2 * nnz) as u64,
        }
    }

    fn to_json(&self) -> Json {
        let mut f = vec![
            ("file", Json::Str(self.file.clone())),
            ("bytes", Json::Num(self.bytes as f64)),
            ("len", Json::Num(self.len as f64)),
        ];
        if let Some(nnz) = self.nnz {
            f.push(("nnz", Json::Num(nnz as f64)));
        }
        Json::obj(f)
    }

    fn from_json(j: &Json) -> Result<ChunkMeta> {
        Ok(ChunkMeta {
            file: j
                .get("file")
                .as_str()
                .ok_or_else(|| DnttError::config("checkpoint manifest: chunk missing file"))?
                .to_string(),
            bytes: req_usize(j.get("bytes"), "chunk bytes")? as u64,
            len: req_usize(j.get("len"), "chunk len")?,
            nnz: j.get("nnz").as_usize(),
        })
    }
}

/// Write + fsync. The commit protocol's durability claim is only as good
/// as the data actually reaching stable storage before the manifest
/// rename — the size-only resume validation cannot detect a
/// post-power-loss zero-filled page, so every snapshot file is synced.
/// Shared with [`crate::serve::ResultCache`], whose commit protocol makes
/// the same claim.
pub(crate) fn write_bytes_durable(path: &Path, bytes: &[u8]) -> Result<u64> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    Ok(bytes.len() as u64)
}

/// Best-effort directory fsync: makes the renames inside `dir` (manifest
/// and replicated-file commits) durable too.
pub(crate) fn sync_dir(dir: &Path) {
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
}

fn write_f64_file(path: &Path, data: &[f64]) -> Result<u64> {
    write_bytes_durable(path, &crate::tensor::io::f64s_to_le_bytes(data))
}

/// Write a replicated-output file (core / HT node matrix) via temp file +
/// atomic rename: an already-committed manifest may reference this very
/// path (the content is immutable across snapshots), so a crash mid-write
/// must never leave it truncated. With `reuse_ok` (the directory's
/// committed manifest carries our config hash, so an existing file at the
/// expected size is bitwise what we would write — the content is a
/// deterministic function of the configuration) the write is skipped
/// entirely, keeping snapshot IO linear instead of O(stages²).
fn write_replicated(path: &Path, data: &[f64], reuse_ok: bool) -> Result<u64> {
    let want = (data.len() * 8) as u64;
    if reuse_ok && std::fs::metadata(path).map(|m| m.len() == want).unwrap_or(false) {
        return Ok(want);
    }
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    let bytes = write_f64_file(&tmp, data)?;
    std::fs::rename(&tmp, path)?;
    Ok(bytes)
}

/// Does the directory's committed manifest belong to this job? Decides
/// whether existing replicated files can be reused by
/// [`write_replicated`] (a foreign or absent manifest forces rewrites).
fn dir_is_ours(dir: &Path, config_hash: u64) -> bool {
    read_manifest(dir)
        .ok()
        .and_then(|m| {
            m.get("config_hash").as_str().and_then(|s| u64::from_str_radix(s, 16).ok())
        })
        == Some(config_hash)
}

/// Best-effort removal of per-stage snapshot chunk files superseded by a
/// just-committed manifest (files matching `prefix` without the current
/// stage's `keep_marker`). Runs on rank 0 *after* the manifest rename, so
/// nothing a committed manifest references is ever removed — without
/// this, every stage's distributed remainder would accumulate on disk.
fn prune_stale(dir: &Path, prefix: &str, keep_marker: &str) {
    if let Ok(rd) = std::fs::read_dir(dir) {
        for entry in rd.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name.starts_with(prefix) && !name.contains(keep_marker) {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }
}

fn read_f64_file(path: &Path, want_len: usize) -> Result<Vec<f64>> {
    let bytes = std::fs::read(path)?;
    if bytes.len() != want_len * 8 {
        return Err(DnttError::config(format!(
            "checkpoint: snapshot file {path:?} is truncated or corrupt ({} bytes, expected {})",
            bytes.len(),
            want_len * 8
        )));
    }
    Ok(bytes.chunks_exact(8).map(|b| f64::from_le_bytes(b.try_into().unwrap())).collect())
}

/// Write one chunk in the spill byte format; returns the byte size.
pub fn write_block_file(path: &Path, block: &TensorBlock) -> Result<u64> {
    match block {
        TensorBlock::Dense(v) => write_f64_file(path, v),
        TensorBlock::Sparse(s) => write_bytes_durable(path, &s.to_spill_bytes()),
        // Adopted chunk files are already in the spill format: snapshot by
        // copying the bytes (size-validated), no decode needed.
        TensorBlock::DiskDense { path: src, len } => {
            let bytes = std::fs::read(src)?;
            if bytes.len() != len * 8 {
                return Err(DnttError::config(format!(
                    "checkpoint: adopted chunk file {src:?} is truncated or corrupt"
                )));
            }
            write_bytes_durable(path, &bytes)
        }
        TensorBlock::DiskSparse { path: src, len: _, nnz } => {
            let bytes = std::fs::read(src)?;
            if bytes.len() != 8 * (1 + 2 * nnz) {
                return Err(DnttError::config(format!(
                    "checkpoint: adopted sparse chunk file {src:?} is truncated or corrupt"
                )));
            }
            write_bytes_durable(path, &bytes)
        }
    }
}

/// Read a chunk back under the representation its [`ChunkMeta`] records.
pub fn read_block_file(path: &Path, meta: &ChunkMeta) -> Result<TensorBlock> {
    match meta.nnz {
        None => Ok(TensorBlock::Dense(read_f64_file(path, meta.len)?)),
        Some(nnz) => {
            let bytes = std::fs::read(path)?;
            if bytes.len() != 8 * (1 + 2 * nnz) {
                return Err(DnttError::config(format!(
                    "checkpoint: sparse snapshot file {path:?} is truncated or corrupt"
                )));
            }
            // The shared spill codec validates the nnz header and record
            // sizes (and SparseChunk::new re-validates the indices).
            Ok(TensorBlock::Sparse(SparseChunk::from_spill_bytes(meta.len, &bytes)?))
        }
    }
}

fn block_nnz(b: &TensorBlock) -> Option<usize> {
    match b {
        TensorBlock::Dense(_) | TensorBlock::DiskDense { .. } => None,
        TensorBlock::Sparse(s) => Some(s.nnz()),
        TensorBlock::DiskSparse { nnz, .. } => Some(*nnz),
    }
}

/// Validate a referenced file's existence and exact byte size (also
/// cross-checked against what the format dictates for its `len`/`nnz`).
fn check_file(dir: &Path, meta: &ChunkMeta) -> Result<()> {
    if meta.bytes != meta.expect_bytes() {
        return Err(DnttError::config(format!(
            "checkpoint: manifest record for {} is inconsistent ({} bytes for len {} nnz {:?})",
            meta.file, meta.bytes, meta.len, meta.nnz
        )));
    }
    let path = dir.join(&meta.file);
    let md = std::fs::metadata(&path).map_err(|e| {
        DnttError::config(format!("checkpoint: missing snapshot file {path:?}: {e}"))
    })?;
    if md.len() != meta.bytes {
        return Err(DnttError::config(format!(
            "checkpoint: snapshot file {path:?} is truncated or corrupt \
             ({} bytes, manifest says {})",
            md.len(),
            meta.bytes
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Whole-array snapshots (store-level; also what the property tests drive).
// ---------------------------------------------------------------------------

/// A stored array's snapshot: its layout and one [`ChunkMeta`] per chunk.
#[derive(Clone, Debug, PartialEq)]
pub struct ArraySnapshot {
    pub layout: Layout,
    pub chunks: Vec<ChunkMeta>,
}

impl ArraySnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("layout", layout_to_json(&self.layout)),
            ("chunks", Json::Arr(self.chunks.iter().map(ChunkMeta::to_json).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ArraySnapshot> {
        let layout = layout_from_json(j.get("layout"))?;
        let chunks = j
            .get("chunks")
            .as_arr()
            .ok_or_else(|| DnttError::config("checkpoint manifest: array missing chunks"))?
            .iter()
            .map(ChunkMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(ArraySnapshot { layout, chunks })
    }
}

/// Snapshot every chunk of a stored array to `dir` (files named
/// `<prefix>.c<chunk>.chunk`), preserving each chunk's dense/sparse
/// representation byte-exactly.
pub fn snapshot_array(dir: &Path, prefix: &str, view: &StoreView) -> Result<ArraySnapshot> {
    std::fs::create_dir_all(dir)?;
    let layout = view.layout().clone();
    let mut chunks = Vec::with_capacity(layout.num_chunks());
    for c in 0..layout.num_chunks() {
        let block = view.chunk_block(c);
        let file = format!("{prefix}.c{c}.chunk");
        let bytes = write_block_file(&dir.join(&file), &block)?;
        chunks.push(ChunkMeta { file, bytes, len: block.len(), nnz: block_nnz(&block) });
    }
    Ok(ArraySnapshot { layout, chunks })
}

/// Restore a snapshot into `store` under `name`, validating every file's
/// byte size first. Chunks come back under their original representation.
pub fn restore_array(
    dir: &Path,
    snap: &ArraySnapshot,
    store: &SharedStore,
    name: &str,
) -> Result<()> {
    if snap.chunks.len() != snap.layout.num_chunks() {
        return Err(DnttError::config(format!(
            "checkpoint: array snapshot has {} chunks, layout wants {}",
            snap.chunks.len(),
            snap.layout.num_chunks()
        )));
    }
    for meta in &snap.chunks {
        check_file(dir, meta)?;
    }
    for (c, meta) in snap.chunks.iter().enumerate() {
        let block = read_block_file(&dir.join(&meta.file), meta)?;
        store.publish_block(name, &snap.layout, c, block)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Manifest plumbing.
// ---------------------------------------------------------------------------

/// Path of the manifest inside a checkpoint directory.
pub fn manifest_path(dir: &Path) -> PathBuf {
    dir.join("manifest.json")
}

/// True when `dir` holds a committed manifest.
pub fn have_checkpoint(dir: &Path) -> bool {
    manifest_path(dir).is_file()
}

/// Read and format-check the manifest.
pub fn read_manifest(dir: &Path) -> Result<Json> {
    let path = manifest_path(dir);
    let text = std::fs::read_to_string(&path)
        .map_err(|e| DnttError::config(format!("checkpoint: cannot read {path:?}: {e}")))?;
    let man = Json::parse(&text)
        .map_err(|e| DnttError::config(format!("checkpoint: {path:?} is not valid JSON: {e}")))?;
    match man.get("format").as_str() {
        Some(CKPT_FORMAT) => Ok(man),
        Some(other) => Err(DnttError::config(format!(
            "checkpoint: {path:?} has format '{other}', this build reads '{CKPT_FORMAT}'"
        ))),
        None => Err(DnttError::config(format!("checkpoint: {path:?} carries no format field"))),
    }
}

/// Commit the manifest atomically (temp file + fsync + rename + directory
/// fsync), so a crash during a snapshot leaves either the previous
/// manifest or the new one — never a torn file — and the rename (plus any
/// earlier replicated-file renames in the same directory) is itself
/// durable.
fn write_manifest(dir: &Path, man: &Json) -> Result<()> {
    let tmp = dir.join("manifest.json.tmp");
    write_bytes_durable(&tmp, man.to_pretty().as_bytes())?;
    std::fs::rename(&tmp, manifest_path(dir))?;
    sync_dir(dir);
    Ok(())
}

/// Completed-stage count of the checkpoint in `dir`, if one exists
/// (TT stages or HT tree nodes — whichever the manifest records).
pub fn stages_done(dir: &Path) -> Option<usize> {
    let man = read_manifest(dir).ok()?;
    man.get("stages_done").as_usize().or_else(|| man.get("nodes_done").as_usize())
}

/// Remove the manifest and every snapshot file in `dir` (non-recursive;
/// errors ignored — cleanup is best-effort).
pub fn clear(dir: &Path) {
    let _ = std::fs::remove_file(manifest_path(dir));
    if let Ok(rd) = std::fs::read_dir(dir) {
        for entry in rd.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name.ends_with(".chunk") || name.ends_with(".bin") || name.ends_with(".tmp") {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }
}

/// The header fields every manifest carries, validated on resume. The
/// config-hash check is first: a mismatch means the checkpoint belongs to
/// a different job and nothing else in it can be trusted.
fn validate_header(
    man: &Json,
    ctx: &CkptCtx,
    decomp: &str,
    world: usize,
    dims: &[usize],
    grid: Grid2d,
) -> Result<()> {
    let hash = man
        .get("config_hash")
        .as_str()
        .and_then(|s| u64::from_str_radix(s, 16).ok())
        .ok_or_else(|| DnttError::config("checkpoint manifest: missing config_hash"))?;
    if hash != ctx.config_hash {
        return Err(DnttError::config(format!(
            "checkpoint config hash mismatch: manifest {hash:016x}, job {:016x} — \
             this checkpoint was written by a different job configuration",
            ctx.config_hash
        )));
    }
    if man.get("decomp").as_str() != Some(decomp) {
        return Err(DnttError::config("checkpoint manifest: decomposition kind mismatch"));
    }
    if req_usize(man.get("world"), "world")? != world {
        return Err(DnttError::config("checkpoint manifest: world size mismatch"));
    }
    if req_usize_arr(man.get("dims"), "dims")? != dims {
        return Err(DnttError::config("checkpoint manifest: tensor dims mismatch"));
    }
    if req_usize_arr(man.get("grid"), "grid")? != [grid.pr, grid.pc] {
        return Err(DnttError::config("checkpoint manifest: 2-D grid mismatch"));
    }
    // A build mismatch is not an error (rebuilding identical sources is
    // routine), but numerics may have changed between builds — surface
    // it: the bitwise-resume guarantee is per build.
    if let Some(sha) = man.get("git_sha").as_str() {
        if sha != git_sha() {
            log::warn!(
                "checkpoint was written by build {sha}, this build is {}; \
                 the bitwise-resume guarantee holds only within one build",
                git_sha()
            );
        }
    }
    Ok(())
}

fn header_fields(
    ctx: &CkptCtx,
    decomp: &str,
    world: usize,
    dims: &[usize],
    grid: Grid2d,
) -> Vec<(&'static str, Json)> {
    vec![
        ("format", Json::Str(CKPT_FORMAT.into())),
        ("git_sha", Json::Str(git_sha().into())),
        ("config_hash", Json::Str(format!("{:016x}", ctx.config_hash))),
        ("decomp", Json::Str(decomp.into())),
        ("world", Json::Num(world as f64)),
        ("dims", Json::arr_usize(dims)),
        ("grid", Json::arr_usize(&[grid.pr, grid.pc])),
    ]
}

// ---------------------------------------------------------------------------
// Bit-exact stage statistics.
// ---------------------------------------------------------------------------

fn nmf_stats_to_json(s: &NmfStats) -> Json {
    Json::obj(vec![
        ("iters", Json::Num(s.iters as f64)),
        ("restarts", Json::Num(s.restarts as f64)),
        ("objective", bits_json(s.objective)),
        ("rel_err", bits_json(s.rel_err)),
        ("history", Json::Arr(s.history.iter().map(|&v| bits_json(v)).collect())),
    ])
}

fn nmf_stats_from_json(j: &Json) -> Result<NmfStats> {
    let history = j
        .get("history")
        .as_arr()
        .ok_or_else(|| DnttError::config("checkpoint manifest: nmf stats missing history"))?
        .iter()
        .map(|b| bits_from(b, "history entry"))
        .collect::<Result<Vec<_>>>()?;
    Ok(NmfStats {
        iters: req_usize(j.get("iters"), "nmf iters")?,
        restarts: req_usize(j.get("restarts"), "nmf restarts")?,
        objective: bits_from(j.get("objective"), "nmf objective")?,
        rel_err: bits_from(j.get("rel_err"), "nmf rel_err")?,
        history,
    })
}

fn tt_stage_to_json(s: &StageStats) -> Json {
    Json::obj(vec![
        ("mode", Json::Num(s.mode as f64)),
        ("m", Json::Num(s.m as f64)),
        ("n", Json::Num(s.n as f64)),
        ("rank", Json::Num(s.rank as f64)),
        ("svd_eps", bits_json(s.svd_eps)),
        ("nmf", nmf_stats_to_json(&s.nmf)),
    ])
}

fn tt_stage_from_json(j: &Json) -> Result<StageStats> {
    Ok(StageStats {
        mode: req_usize(j.get("mode"), "stage mode")?,
        m: req_usize(j.get("m"), "stage m")?,
        n: req_usize(j.get("n"), "stage n")?,
        rank: req_usize(j.get("rank"), "stage rank")?,
        svd_eps: bits_from(j.get("svd_eps"), "stage svd_eps")?,
        nmf: nmf_stats_from_json(j.get("nmf"))?,
    })
}

fn ht_stage_to_json(s: &HtStageStats) -> Json {
    Json::obj(vec![
        ("node", Json::Num(s.node as f64)),
        ("modes", Json::arr_usize(&[s.modes.0, s.modes.1])),
        ("left", Json::Bool(s.left)),
        ("m", Json::Num(s.m as f64)),
        ("n", Json::Num(s.n as f64)),
        ("rank", Json::Num(s.rank as f64)),
        ("svd_eps", bits_json(s.svd_eps)),
        ("nmf", nmf_stats_to_json(&s.nmf)),
        ("secs", bits_json(s.secs)),
    ])
}

fn ht_stage_from_json(j: &Json) -> Result<HtStageStats> {
    let modes = req_usize_arr(j.get("modes"), "stage modes")?;
    if modes.len() != 2 {
        return Err(DnttError::config("checkpoint manifest: stage modes must be [lo, hi]"));
    }
    Ok(HtStageStats {
        node: req_usize(j.get("node"), "stage node")?,
        modes: (modes[0], modes[1]),
        left: j
            .get("left")
            .as_bool()
            .ok_or_else(|| DnttError::config("checkpoint manifest: stage missing left"))?,
        m: req_usize(j.get("m"), "stage m")?,
        n: req_usize(j.get("n"), "stage n")?,
        rank: req_usize(j.get("rank"), "stage rank")?,
        svd_eps: bits_from(j.get("svd_eps"), "stage svd_eps")?,
        nmf: nmf_stats_from_json(j.get("nmf"))?,
        secs: bits_from(j.get("secs"), "stage secs")?,
    })
}

// ---------------------------------------------------------------------------
// TT driver snapshots.
// ---------------------------------------------------------------------------

/// State a resumed TT sweep re-enters with.
pub struct TtResume {
    pub stages_done: usize,
    pub cores: Vec<Mat<f64>>,
    pub stages: Vec<StageStats>,
    pub layout: Layout,
    pub my_chunk: TensorBlock,
    pub r_prev: usize,
    pub s_rest: usize,
}

/// Collective TT stage snapshot: every rank writes its remainder chunk,
/// the chunk records are gathered, and rank 0 writes the cores plus the
/// manifest commit. The trailing barrier guarantees no rank runs ahead of
/// a durable manifest.
#[allow(clippy::too_many_arguments)]
pub fn save_tt_stage(
    world: &mut Comm,
    ctx: &CkptCtx,
    stages_done: usize,
    cores: &[Mat<f64>],
    stages: &[StageStats],
    layout: &Layout,
    my_chunk: &TensorBlock,
    r_prev: usize,
    s_rest: usize,
    dims: &[usize],
    grid: Grid2d,
) -> Result<()> {
    let dir = &ctx.policy.dir;
    let rank = world.rank();
    let span = crate::obs::span_begin();
    let t0 = Instant::now();
    let meta = (|| -> Result<ChunkMeta> {
        std::fs::create_dir_all(dir)?;
        let file = format!("tt.rem.s{stages_done}.r{rank}.chunk");
        let bytes = write_block_file(&dir.join(&file), my_chunk)?;
        Ok(ChunkMeta { file, bytes, len: my_chunk.len(), nnz: block_nnz(my_chunk) })
    })();
    let meta = match meta {
        Ok(m) => m,
        Err(e) => {
            // Rank-divergent IO failure: peers are heading into the
            // gather — abort so they fail fast instead of deadlocking
            // (same discipline as dist_reshape's publish).
            world.abort(&format!("checkpoint: chunk write failed on rank {rank}: {e}"));
            return Err(e);
        }
    };
    world.breakdown.add_secs(Cat::Io, t0.elapsed().as_secs_f64());
    world.breakdown.add_bytes(Cat::Io, meta.bytes);
    let my_bytes = meta.bytes;
    let metas = world.all_gather_any(meta);
    if rank == 0 {
        let t1 = Instant::now();
        let reuse_ok = dir_is_ours(dir, ctx.config_hash);
        let committed = (|| -> Result<()> {
            let mut core_entries = Vec::with_capacity(cores.len());
            for (l, c) in cores.iter().enumerate() {
                let file = format!("tt.core{l}.bin");
                let bytes = write_replicated(&dir.join(&file), c.as_slice(), reuse_ok)?;
                core_entries.push(Json::obj(vec![
                    ("file", Json::Str(file)),
                    ("rows", Json::Num(c.rows() as f64)),
                    ("cols", Json::Num(c.cols() as f64)),
                    ("bytes", Json::Num(bytes as f64)),
                ]));
            }
            let mut fields = header_fields(ctx, "tt", world.size(), dims, grid);
            fields.extend(vec![
                ("stages_done", Json::Num(stages_done as f64)),
                ("r_prev", Json::Num(r_prev as f64)),
                ("s_rest", Json::Num(s_rest as f64)),
                ("remainder_layout", layout_to_json(layout)),
                (
                    "remainder_chunks",
                    Json::Arr(metas.iter().map(ChunkMeta::to_json).collect()),
                ),
                ("cores", Json::Arr(core_entries)),
                ("stages", Json::Arr(stages.iter().map(tt_stage_to_json).collect())),
            ]);
            write_manifest(dir, &Json::obj(fields))
        })();
        world.breakdown.add_secs(Cat::Io, t1.elapsed().as_secs_f64());
        if let Err(e) = committed {
            world.abort(&format!("checkpoint: manifest commit failed: {e}"));
            return Err(e);
        }
        // The new manifest is durable; earlier stages' remainder chunks
        // are no longer referenced by anything.
        prune_stale(dir, "tt.rem.s", &format!(".s{stages_done}.r"));
        log::info!("checkpoint: committed {stages_done} TT stage(s) to {dir:?}");
    }
    world.barrier();
    // Commit latency spans close after the barrier: a commit is only
    // durable once every rank has seen it.
    crate::obs::end_ckpt(span, my_bytes);
    Ok(())
}

/// Load the TT resume state from `ctx.policy.dir`, or `Ok(None)` when no
/// manifest exists. Validation (hash, topology, every file's byte size)
/// runs identically on every rank before any file content is read, so a
/// bad checkpoint is rejected symmetrically.
pub fn load_tt(
    ctx: &CkptCtx,
    world_rank: usize,
    world_size: usize,
    dims: &[usize],
    grid: Grid2d,
) -> Result<Option<TtResume>> {
    let dir = &ctx.policy.dir;
    if !have_checkpoint(dir) {
        return Ok(None);
    }
    let man = read_manifest(dir)?;
    validate_header(&man, ctx, "tt", world_size, dims, grid)?;
    let stages_done = req_usize(man.get("stages_done"), "stages_done")?;
    let layout = layout_from_json(man.get("remainder_layout"))?;
    let chunk_metas = man
        .get("remainder_chunks")
        .as_arr()
        .ok_or_else(|| DnttError::config("checkpoint manifest: missing remainder_chunks"))?
        .iter()
        .map(ChunkMeta::from_json)
        .collect::<Result<Vec<_>>>()?;
    if chunk_metas.len() != world_size {
        return Err(DnttError::config(format!(
            "checkpoint manifest: {} remainder chunks for {world_size} ranks",
            chunk_metas.len()
        )));
    }
    for meta in &chunk_metas {
        check_file(dir, meta)?;
    }
    let core_entries = man
        .get("cores")
        .as_arr()
        .ok_or_else(|| DnttError::config("checkpoint manifest: missing cores"))?;
    let mut core_shapes = Vec::with_capacity(core_entries.len());
    for e in core_entries {
        let rows = req_usize(e.get("rows"), "core rows")?;
        let cols = req_usize(e.get("cols"), "core cols")?;
        let file = e
            .get("file")
            .as_str()
            .ok_or_else(|| DnttError::config("checkpoint manifest: core missing file"))?
            .to_string();
        check_file(
            dir,
            &ChunkMeta {
                file: file.clone(),
                bytes: (rows * cols * 8) as u64,
                len: rows * cols,
                nnz: None,
            },
        )?;
        core_shapes.push((file, rows, cols));
    }
    let stages = man
        .get("stages")
        .as_arr()
        .ok_or_else(|| DnttError::config("checkpoint manifest: missing stages"))?
        .iter()
        .map(tt_stage_from_json)
        .collect::<Result<Vec<_>>>()?;
    // Content reads come after the symmetric validation phase; a failure
    // here can be rank-divergent (one rank's file goes bad underneath
    // us), so panic — poisoning the world — instead of returning an Err
    // that would strand peers in their first collective. Same policy as
    // the chunk store's spill reads.
    let mut cores = Vec::with_capacity(core_shapes.len());
    for (file, rows, cols) in core_shapes {
        let data = read_f64_file(&dir.join(&file), rows * cols)
            .unwrap_or_else(|e| panic!("checkpoint: core file {file} unreadable: {e}"));
        cores.push(Mat::from_vec(rows, cols, data));
    }
    let meta = &chunk_metas[world_rank];
    let my_chunk = read_block_file(&dir.join(&meta.file), meta)
        .unwrap_or_else(|e| panic!("checkpoint: chunk file {} unreadable: {e}", meta.file));
    Ok(Some(TtResume {
        stages_done,
        cores,
        stages,
        layout,
        my_chunk,
        r_prev: req_usize(man.get("r_prev"), "r_prev")?,
        s_rest: req_usize(man.get("s_rest"), "s_rest")?,
    }))
}

// ---------------------------------------------------------------------------
// HT driver snapshots.
// ---------------------------------------------------------------------------

/// State a resumed HT tree walk re-enters with.
pub struct HtResume {
    pub nodes_done: usize,
    pub payload: Vec<Option<HtNode<f64>>>,
    pub pending: Vec<Option<(Layout, TensorBlock, usize)>>,
    pub stages: Vec<HtStageStats>,
}

/// Collective HT node snapshot: the per-rank chunks of every pending child
/// array, the resolved node payloads, and the manifest commit — same
/// protocol as [`save_tt_stage`].
#[allow(clippy::too_many_arguments)]
pub fn save_ht_node(
    world: &mut Comm,
    ctx: &CkptCtx,
    nodes_done: usize,
    payload: &[Option<HtNode<f64>>],
    pending: &[Option<(Layout, TensorBlock, usize)>],
    stages: &[HtStageStats],
    dims: &[usize],
    grid: Grid2d,
) -> Result<()> {
    let dir = &ctx.policy.dir;
    let rank = world.rank();
    let span = crate::obs::span_begin();
    let t0 = Instant::now();
    let my_metas = (|| -> Result<Vec<(usize, ChunkMeta)>> {
        std::fs::create_dir_all(dir)?;
        let mut out = Vec::new();
        for (idx, entry) in pending.iter().enumerate() {
            if let Some((_, data, _)) = entry {
                let file = format!("ht.pend.n{idx}.s{nodes_done}.r{rank}.chunk");
                let bytes = write_block_file(&dir.join(&file), data)?;
                out.push((idx, ChunkMeta { file, bytes, len: data.len(), nnz: block_nnz(data) }));
            }
        }
        Ok(out)
    })();
    let my_metas = match my_metas {
        Ok(m) => m,
        Err(e) => {
            world.abort(&format!("checkpoint: chunk write failed on rank {rank}: {e}"));
            return Err(e);
        }
    };
    world.breakdown.add_secs(Cat::Io, t0.elapsed().as_secs_f64());
    world
        .breakdown
        .add_bytes(Cat::Io, my_metas.iter().map(|(_, m)| m.bytes).sum::<u64>());
    let all_metas = world.all_gather_any(my_metas.clone());
    if rank == 0 {
        let t1 = Instant::now();
        let reuse_ok = dir_is_ours(dir, ctx.config_hash);
        let committed = (|| -> Result<()> {
            let mut node_entries = Vec::new();
            for (idx, p) in payload.iter().enumerate() {
                if let Some(node) = p {
                    let (kind, m) = match node {
                        HtNode::Leaf(m) => ("leaf", m),
                        HtNode::Transfer(m) => ("transfer", m),
                    };
                    let file = format!("ht.node{idx}.bin");
                    let bytes = write_replicated(&dir.join(&file), m.as_slice(), reuse_ok)?;
                    node_entries.push(Json::obj(vec![
                        ("node", Json::Num(idx as f64)),
                        ("kind", Json::Str(kind.into())),
                        ("rows", Json::Num(m.rows() as f64)),
                        ("cols", Json::Num(m.cols() as f64)),
                        ("file", Json::Str(file)),
                        ("bytes", Json::Num(bytes as f64)),
                    ]));
                }
            }
            // Every rank carries the same pending indices in the same
            // order (SPMD), so position k of each rank's gathered vector
            // is the same array.
            let mut pending_entries = Vec::new();
            for (k, (idx, _)) in my_metas.iter().enumerate() {
                let (layout, _, rt) = pending[*idx].as_ref().expect("pending entry present");
                let chunks: Vec<Json> =
                    all_metas.iter().map(|v| v[k].1.to_json()).collect();
                pending_entries.push(Json::obj(vec![
                    ("node", Json::Num(*idx as f64)),
                    ("rt", Json::Num(*rt as f64)),
                    ("layout", layout_to_json(layout)),
                    ("chunks", Json::Arr(chunks)),
                ]));
            }
            let mut fields = header_fields(ctx, "ht", world.size(), dims, grid);
            fields.extend(vec![
                ("nodes_done", Json::Num(nodes_done as f64)),
                ("payload", Json::Arr(node_entries)),
                ("pending", Json::Arr(pending_entries)),
                ("stages", Json::Arr(stages.iter().map(ht_stage_to_json).collect())),
            ]);
            write_manifest(dir, &Json::obj(fields))
        })();
        world.breakdown.add_secs(Cat::Io, t1.elapsed().as_secs_f64());
        if let Err(e) = committed {
            world.abort(&format!("checkpoint: manifest commit failed: {e}"));
            return Err(e);
        }
        // The new manifest is durable; pending-chunk files from earlier
        // node boundaries are no longer referenced by anything.
        prune_stale(dir, "ht.pend.", &format!(".s{nodes_done}.r"));
        log::info!("checkpoint: committed {nodes_done} HT node(s) to {dir:?}");
    }
    world.barrier();
    // Same post-barrier close as save_tt_stage: latency includes the
    // durability fence.
    crate::obs::end_ckpt(span, my_metas.iter().map(|(_, m)| m.bytes).sum());
    Ok(())
}

/// Load the HT resume state, or `Ok(None)` when no manifest exists.
/// `tree_len` sizes the payload/pending vectors (the caller's
/// [`crate::tensor::DimTree`]).
pub fn load_ht(
    ctx: &CkptCtx,
    world_rank: usize,
    world_size: usize,
    dims: &[usize],
    grid: Grid2d,
    tree_len: usize,
) -> Result<Option<HtResume>> {
    let dir = &ctx.policy.dir;
    if !have_checkpoint(dir) {
        return Ok(None);
    }
    let man = read_manifest(dir)?;
    validate_header(&man, ctx, "ht", world_size, dims, grid)?;
    let nodes_done = req_usize(man.get("nodes_done"), "nodes_done")?;
    if nodes_done > tree_len {
        return Err(DnttError::config("checkpoint manifest: nodes_done exceeds the tree"));
    }

    // Symmetric validation of every referenced file first.
    let node_entries = man
        .get("payload")
        .as_arr()
        .ok_or_else(|| DnttError::config("checkpoint manifest: missing payload"))?;
    for e in node_entries {
        let rows = req_usize(e.get("rows"), "node rows")?;
        let cols = req_usize(e.get("cols"), "node cols")?;
        let file = e
            .get("file")
            .as_str()
            .ok_or_else(|| DnttError::config("checkpoint manifest: node missing file"))?;
        check_file(
            dir,
            &ChunkMeta {
                file: file.to_string(),
                bytes: (rows * cols * 8) as u64,
                len: rows * cols,
                nnz: None,
            },
        )?;
    }
    let pending_entries = man
        .get("pending")
        .as_arr()
        .ok_or_else(|| DnttError::config("checkpoint manifest: missing pending"))?;
    let mut pending_parsed = Vec::new();
    for e in pending_entries {
        let idx = req_usize(e.get("node"), "pending node")?;
        if idx >= tree_len {
            return Err(DnttError::config("checkpoint manifest: pending node out of range"));
        }
        let rt = req_usize(e.get("rt"), "pending rt")?;
        let layout = layout_from_json(e.get("layout"))?;
        let chunks = e
            .get("chunks")
            .as_arr()
            .ok_or_else(|| DnttError::config("checkpoint manifest: pending missing chunks"))?
            .iter()
            .map(ChunkMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        if chunks.len() != world_size {
            return Err(DnttError::config(format!(
                "checkpoint manifest: pending node {idx} has {} chunks for {world_size} ranks",
                chunks.len()
            )));
        }
        for meta in &chunks {
            check_file(dir, meta)?;
        }
        pending_parsed.push((idx, rt, layout, chunks));
    }

    // Rehydrate. Content reads come after the symmetric validation
    // phase; a failure here can be rank-divergent, so panic (poisoning
    // the world) instead of stranding peers — same policy as `load_tt`.
    let mut payload: Vec<Option<HtNode<f64>>> = (0..tree_len).map(|_| None).collect();
    for e in node_entries {
        let idx = req_usize(e.get("node"), "node idx")?;
        if idx >= tree_len {
            return Err(DnttError::config("checkpoint manifest: payload node out of range"));
        }
        let rows = req_usize(e.get("rows"), "node rows")?;
        let cols = req_usize(e.get("cols"), "node cols")?;
        let file = e.get("file").as_str().unwrap();
        let data = read_f64_file(&dir.join(file), rows * cols)
            .unwrap_or_else(|e| panic!("checkpoint: node file {file} unreadable: {e}"));
        let m = Mat::from_vec(rows, cols, data);
        payload[idx] = Some(match e.get("kind").as_str() {
            Some("leaf") => HtNode::Leaf(m),
            Some("transfer") => HtNode::Transfer(m),
            _ => return Err(DnttError::config("checkpoint manifest: bad node kind")),
        });
    }
    let mut pending: Vec<Option<(Layout, TensorBlock, usize)>> =
        (0..tree_len).map(|_| None).collect();
    for (idx, rt, layout, chunks) in pending_parsed {
        let meta = &chunks[world_rank];
        let block = read_block_file(&dir.join(&meta.file), meta)
            .unwrap_or_else(|e| panic!("checkpoint: chunk file {} unreadable: {e}", meta.file));
        pending[idx] = Some((layout, block, rt));
    }
    let stages = man
        .get("stages")
        .as_arr()
        .ok_or_else(|| DnttError::config("checkpoint manifest: missing stages"))?
        .iter()
        .map(ht_stage_from_json)
        .collect::<Result<Vec<_>>>()?;
    Ok(Some(HtResume { nodes_done, payload, pending, stages }))
}

// ---------------------------------------------------------------------------
// Iteration-granular in-flight snapshots.
// ---------------------------------------------------------------------------

/// The [`crate::nmf::dist::IterObserver`] the drivers install when
/// [`CheckpointPolicy::every_iters`] > 0: every N accepted iterations it
/// overwrites `inflight.<tag>.r<rank>.{w,h}.chunk` with this rank's
/// current factors (raw `f64` LE). IO failures are swallowed with a
/// warning — an error from inside the iteration loop would be
/// rank-divergent and strand peers mid-collective.
pub struct IterCkpt {
    dir: PathBuf,
    every: usize,
    rank: usize,
    tag: String,
}

impl crate::nmf::dist::IterObserver for IterCkpt {
    fn on_iter(&mut self, iter: usize, w: &Mat<f64>, ht: &Mat<f64>) {
        if iter == 0 || iter % self.every != 0 {
            return;
        }
        let write = (|| -> Result<()> {
            std::fs::create_dir_all(&self.dir)?;
            write_f64_file(
                &self.dir.join(format!("inflight.{}.r{}.w.chunk", self.tag, self.rank)),
                w.as_slice(),
            )?;
            write_f64_file(
                &self.dir.join(format!("inflight.{}.r{}.h.chunk", self.tag, self.rank)),
                ht.as_slice(),
            )?;
            Ok(())
        })();
        if let Err(e) = write {
            log::warn!("in-flight NMF checkpoint failed (continuing without it): {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::chunkstore::SpillMode;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dntt_ckpt_unit_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn layout_json_roundtrips_all_variants() {
        let layouts = vec![
            Layout::TensorGrid { dims: vec![4, 6, 2], grid: vec![2, 1, 2] },
            Layout::MatGrid { m: 5, n: 7, pr: 2, pc: 3 },
            Layout::HtGrid { r: 3, n: 9, pr: 2, pc: 2 },
            Layout::WGrid { m: 8, r: 2, pr: 2, pc: 2 },
            Layout::HtPermuted { r: 2, n2: 3, rt: 4, pr: 1, pc: 2 },
        ];
        for l in layouts {
            let j = layout_to_json(&l);
            // Survive a full text round trip too (what the manifest does).
            let j2 = Json::parse(&j.to_string()).unwrap();
            assert_eq!(layout_from_json(&j2).unwrap(), l);
        }
        assert!(layout_from_json(&Json::obj(vec![("kind", Json::Str("xx".into()))])).is_err());
        // Malformed extents are rejected, not silently clamped.
        let bad = Json::obj(vec![
            ("kind", Json::Str("tensor_grid".into())),
            ("dims", Json::Arr(vec![Json::Num(4.0), Json::Str("oops".into())])),
            ("grid", Json::arr_usize(&[1, 1])),
        ]);
        let err = layout_from_json(&bad).unwrap_err();
        assert!(err.to_string().contains("non-integer"), "{err}");
    }

    #[test]
    fn block_files_roundtrip_both_representations() {
        let dir = tmp("blocks");
        std::fs::create_dir_all(&dir).unwrap();
        let dense = TensorBlock::Dense(vec![0.5, -1.25, 0.0, 3.0]);
        let db = write_block_file(&dir.join("d.chunk"), &dense).unwrap();
        assert_eq!(db, 32);
        let dm = ChunkMeta { file: "d.chunk".into(), bytes: db, len: 4, nnz: None };
        match read_block_file(&dir.join("d.chunk"), &dm).unwrap() {
            TensorBlock::Dense(v) => assert_eq!(v, vec![0.5, -1.25, 0.0, 3.0]),
            _ => panic!("dense chunk came back sparse"),
        }
        let sp = TensorBlock::Sparse(SparseChunk::new(6, vec![1, 4], vec![7.0, 8.5]).unwrap());
        let sb = write_block_file(&dir.join("s.chunk"), &sp).unwrap();
        assert_eq!(sb, 8 * 5);
        let sm = ChunkMeta { file: "s.chunk".into(), bytes: sb, len: 6, nnz: Some(2) };
        match read_block_file(&dir.join("s.chunk"), &sm).unwrap() {
            TensorBlock::Sparse(s) => {
                assert_eq!((s.len(), s.idx(), s.vals()), (6, &[1usize, 4][..], &[7.0, 8.5][..]))
            }
            _ => panic!("sparse chunk came back dense"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bit_codec_preserves_nan_and_precision() {
        for v in [f64::NAN, 0.1 + 0.2, -0.0, f64::INFINITY, 1.0 / 3.0] {
            let back = bits_from(&bits_json(v), "t").unwrap();
            assert_eq!(back.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn array_snapshot_roundtrips_mixed_chunks() {
        let dir = tmp("array");
        let l = Layout::MatGrid { m: 4, n: 3, pr: 2, pc: 1 };
        let store = SharedStore::new(SpillMode::Memory);
        store.publish("x", &l, 0, (0..6).map(|k| k as f64).collect()).unwrap();
        store
            .publish_sparse("x", &l, 1, SparseChunk::new(6, vec![2, 5], vec![9.0, -3.0]).unwrap())
            .unwrap();
        let view = store.view("x").unwrap();
        let snap = snapshot_array(&dir, "x", &view).unwrap();
        // Byte accounting matches the spill formats.
        assert_eq!(snap.chunks[0].bytes, 48);
        assert_eq!(snap.chunks[1].bytes, 8 * 5);
        // JSON round trip of the snapshot record.
        let snap2 = ArraySnapshot::from_json(&Json::parse(&snap.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(snap2, snap);
        let store2 = SharedStore::new(SpillMode::Memory);
        restore_array(&dir, &snap2, &store2, "y").unwrap();
        let view2 = store2.view("y").unwrap();
        assert_eq!(view2.to_dense(), view.to_dense());
        assert_eq!(view2.has_sparse(), view.has_sparse());
        assert_eq!(view2.nnz_estimate(), view.nnz_estimate());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restore_rejects_truncated_files() {
        let dir = tmp("trunc");
        let l = Layout::MatGrid { m: 2, n: 2, pr: 1, pc: 1 };
        let store = SharedStore::new(SpillMode::Memory);
        store.publish("x", &l, 0, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let snap = snapshot_array(&dir, "x", &store.view("x").unwrap()).unwrap();
        // Truncate the file behind the manifest's back.
        let path = dir.join(&snap.chunks[0].file);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 8]).unwrap();
        let store2 = SharedStore::new(SpillMode::Memory);
        let err = restore_array(&dir, &snap, &store2, "y").unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_requires_format_field() {
        let dir = tmp("fmt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(manifest_path(&dir), "{\"format\": \"dntt-ckpt-v9\"}").unwrap();
        assert!(read_manifest(&dir).unwrap_err().to_string().contains("dntt-ckpt-v9"));
        std::fs::write(manifest_path(&dir), "{}").unwrap();
        assert!(read_manifest(&dir).is_err());
        std::fs::write(manifest_path(&dir), "not json").unwrap();
        assert!(read_manifest(&dir).is_err());
        clear(&dir);
        assert!(!have_checkpoint(&dir));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_json_roundtrip_is_bit_exact() {
        let s = StageStats {
            mode: 1,
            m: 12,
            n: 30,
            rank: 3,
            svd_eps: f64::NAN,
            nmf: NmfStats {
                iters: 7,
                objective: 0.1 + 0.2,
                rel_err: 1.0 / 3.0,
                restarts: 2,
                history: vec![1.5, 0.25 + 1e-17, 0.125],
            },
        };
        let j = Json::parse(&tt_stage_to_json(&s).to_string()).unwrap();
        let back = tt_stage_from_json(&j).unwrap();
        assert_eq!(back.svd_eps.to_bits(), s.svd_eps.to_bits());
        assert_eq!(back.nmf.objective.to_bits(), s.nmf.objective.to_bits());
        assert_eq!(back.nmf.history.len(), 3);
        for (a, b) in back.nmf.history.iter().zip(&s.nmf.history) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
