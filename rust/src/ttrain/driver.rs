//! The distributed non-negative tensor-train driver (Alg 2).
//!
//! Sweeps modes left-to-right; at stage `l` the remainder (logical shape
//! `r_{l-1} × n_l ⋯ n_d`) is redistributed by [`dist_reshape`] into the
//! stage matrix `X: (r_{l-1}·n_l) × (n_{l+1}⋯n_d)` on the 2-D grid, the TT
//! rank is selected by the distributed ε-threshold SVD, the distributed
//! BCD/MU/HALS NMF factorizes `X ≈ W·H`, `W` is all_gathered into core
//! `G(l)`, and the distributed `H` becomes the next remainder. The final
//! `H` is gathered as core `G(d)`.

use crate::dist::checkpoint::{self, CkptCtx};
use crate::dist::{dist_reshape_x, Comm, Grid2d, Layout, ProcGrid, SharedStore, TensorBlock};
use crate::error::{DnttError, Result};
use crate::linalg::{KernelCfg, Mat};
use crate::nmf::{dist_nmf_pruned_x_obs_ws, IterObserver, NmfConfig, NmfStats, NmfWorkspace};
use crate::runtime::backend::ComputeBackend;
use crate::tensor::TTensor;
use crate::ttrain::rankselect::{dist_rank_select, RankSelectConfig};
use crate::util::timer::{Breakdown, Cat};
use std::sync::Arc;

/// Tensor-train decomposition parameters.
#[derive(Clone, Debug)]
pub struct TtConfig {
    /// Per-stage relative-error threshold ε for rank selection.
    pub eps: f64,
    /// Fixed TT ranks (skips the SVD — the paper's scaling experiments fix
    /// ranks to isolate NMF cost). Length must be `d-1`.
    pub fixed_ranks: Option<Vec<usize>>,
    /// NMF settings (`rank` is overridden per stage).
    pub nmf: NmfConfig,
    /// Rank-selection settings (`eps` is overridden from `self.eps`).
    pub rank_select: RankSelectConfig,
    /// Prune all-zero rows/columns of each stage matrix before the NMF
    /// (see [`crate::nmf::dist_nmf_pruned`]). Changes the factor
    /// initialization indices, so results differ numerically (not in
    /// quality) from an unpruned run when pruning triggers.
    pub prune: bool,
}

impl Default for TtConfig {
    fn default() -> Self {
        TtConfig {
            eps: 0.01,
            fixed_ranks: None,
            nmf: NmfConfig::default(),
            rank_select: RankSelectConfig::default(),
            prune: false,
        }
    }
}

/// Per-stage record.
#[derive(Clone, Debug)]
pub struct StageStats {
    pub mode: usize,
    /// Stage matrix shape.
    pub m: usize,
    pub n: usize,
    /// Selected (or fixed) TT rank.
    pub rank: usize,
    /// `sqrt(tail/total)` the SVD heuristic achieved (NaN when fixed).
    pub svd_eps: f64,
    /// NMF convergence record.
    pub nmf: NmfStats,
}

/// Decomposition result (identical on every rank).
pub struct TtOutput {
    pub tt: TTensor<f64>,
    pub stages: Vec<StageStats>,
    /// Critical-path (max-over-ranks) cost breakdown.
    pub breakdown: Breakdown,
}

/// Run the distributed nTT on this rank (collective).
///
/// * `my_block` — this rank's chunk of the input tensor under
///   `Layout::TensorGrid { dims, grid: proc_grid.dims() }`, dense or
///   sparse ([`TensorBlock`]). A sparse chunk keeps the first stage
///   matrix sparse end to end (reshape → rank-select → NMF) whenever the
///   global density clears the reshape cutoff; every later stage
///   consumes the dense NMF factors.
/// * `grid` — the 2-D NMF grid (must satisfy `grid.size() == world.size()`
///   and be the collapse of `proc_grid`).
/// * `ckpt` — optional checkpoint context
///   ([`crate::dist::checkpoint::CkptCtx`]): snapshot the sweep state per
///   the policy, and — when its `resume` flag is set and a valid
///   `dntt-ckpt-v1` manifest exists — skip completed stages, rehydrating
///   the cores and this rank's remainder chunk byte-exactly so the
///   resumed run's factors are bitwise identical to an uninterrupted one.
/// * `kernel` — GEMM/SpMM kernel selection (SIMD path + intra-rank
///   threads) pinned to this rank's workspace. Bitwise-neutral:
///   every selection yields factors identical to
///   [`KernelCfg::scalar`]. Pass [`KernelCfg::default`] for the
///   env-aware auto choice (`DNTT_KERNEL` honored).
#[allow(clippy::too_many_arguments)]
pub fn dist_ntt(
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    store: &Arc<SharedStore>,
    proc_grid: &ProcGrid,
    grid: Grid2d,
    dims: &[usize],
    my_block: TensorBlock,
    backend: &dyn ComputeBackend,
    cfg: &TtConfig,
    kernel: KernelCfg,
    ckpt: Option<&CkptCtx>,
) -> Result<TtOutput> {
    let d = dims.len();
    if d < 2 {
        return Err(DnttError::shape("tensor train needs at least 2 modes"));
    }
    if let Some(fr) = &cfg.fixed_ranks {
        if fr.len() != d - 1 {
            return Err(DnttError::config(format!(
                "fixed_ranks needs {} entries, got {}",
                d - 1,
                fr.len()
            )));
        }
    }
    if grid.size() != world.size() {
        return Err(DnttError::Comm("grid size != world size".into()));
    }

    let mut cores: Vec<Mat<f64>> = Vec::with_capacity(d);
    let mut stages: Vec<StageStats> = Vec::with_capacity(d - 1);
    let mut cur_layout = Layout::TensorGrid { dims: dims.to_vec(), grid: proc_grid.dims().to_vec() };
    let mut cur_data: TensorBlock = my_block;
    let mut r_prev = 1usize;
    let mut s_rest: usize = dims.iter().product();
    let mut start_stage = 0usize;
    // Resume: rehydrate the sweep state from the last durable snapshot
    // and skip the completed stages (validation is symmetric across
    // ranks — see `checkpoint::load_tt`). A missing manifest means a
    // fresh start, not an error.
    if let Some(cx) = ckpt {
        if cx.resume {
            if let Some(res) = checkpoint::load_tt(cx, world.rank(), world.size(), dims, grid)? {
                cores = res.cores;
                stages = res.stages;
                cur_layout = res.layout;
                cur_data = res.my_chunk;
                r_prev = res.r_prev;
                s_rest = res.s_rest;
                start_stage = res.stages_done;
                log::info!(
                    "resuming TT sweep from checkpoint: {start_stage}/{} stages done",
                    d - 1
                );
            }
        }
    }
    // One workspace per rank, shared by every stage NMF: the packed-GEMM
    // panels and update temporaries warm up once and are reused, so the
    // sweep's inner iterations allocate nothing. The kernel selection is
    // pinned here and rides the workspace through every stage.
    let mut ws = NmfWorkspace::with_kernel(kernel);

    for l in start_stage..d - 1 {
        let stage_span = crate::obs::span_begin();
        let n_l = dims[l];
        let m = r_prev * n_l;
        let ncols = s_rest / n_l;
        // --- Alg 2 line 4: distributed reshape into the stage matrix
        // (assembled sparse when the published chunks are sparse enough).
        let x = dist_reshape_x(
            world, store, &format!("tt.stage{l}"), &cur_layout, cur_data, m, ncols, grid,
        )?;

        // --- Lines 5–6: rank selection. The SVD has no sparse path, so a
        // sparse stage block is densified locally for this step only
        // (skipped entirely under `fixed_ranks`, the usual sparse setup).
        let (rank, svd_eps) = match &cfg.fixed_ranks {
            Some(fr) => (fr[l].max(1), f64::NAN),
            None => {
                let xd = x.dense_view();
                let rs = RankSelectConfig { eps: cfg.eps, ..cfg.rank_select.clone() };
                let sel = dist_rank_select(&xd, m, ncols, grid, world, row, col, &rs)?;
                (sel.rank, sel.achieved_eps)
            }
        };

        // --- Line 7: distributed NMF (optionally zero-row/col pruned),
        // dispatched per block representation.
        let nmf_cfg = NmfConfig { rank, seed: cfg.nmf.seed.wrapping_add(l as u64), ..cfg.nmf.clone() };
        let mut iter_obs = ckpt.and_then(|cx| cx.iter_ckpt(world.rank(), &format!("s{l}")));
        let out = dist_nmf_pruned_x_obs_ws(
            &x, m, ncols, grid, world, row, col, backend, &nmf_cfg,
            store, &format!("tt.stage{l}"), cfg.prune, &mut ws,
            iter_obs.as_mut().map(|o| o as &mut dyn IterObserver),
        )?;

        // --- Line 8: gather W into core G(l). World-rank order concatenates
        // W blocks in global row order (see nmf::dist block layout).
        let parts = world.all_gather_varied(out.w.as_slice());
        let mut wfull = Vec::with_capacity(m * rank);
        for p in &parts {
            wfull.extend_from_slice(p);
        }
        cores.push(Mat::from_vec(m, rank, wfull));

        stages.push(StageStats { mode: l, m, n: ncols, rank, svd_eps, nmf: out.stats });

        // --- Line 10: H becomes the next remainder (kept distributed;
        // the factors are dense, so later stages run the dense path).
        cur_layout = Layout::HtGrid { r: rank, n: ncols, pr: grid.pr, pc: grid.pc };
        cur_data = TensorBlock::Dense(out.ht.into_vec());
        r_prev = rank;
        s_rest = ncols;

        // Stage-boundary snapshot: the full sweep state is durable before
        // the next stage starts, so a crash anywhere later resumes here.
        if let Some(cx) = ckpt {
            if cx.stage_due(l + 1) {
                checkpoint::save_tt_stage(
                    world, cx, l + 1, &cores, &stages, &cur_layout, &cur_data, r_prev, s_rest,
                    dims, grid,
                )?;
            }
        }
        crate::obs::end_stage(stage_span, &format!("tt.stage{l}"));
    }

    // --- Line 11: gather the final H as core G(d) ((r_{d-1}·n_d) × 1).
    let final_span = crate::obs::span_begin();
    let rank_id = world.rank();
    let t0 = std::time::Instant::now();
    store.publish_block("tt.final", &cur_layout, rank_id, cur_data)?;
    world.breakdown.add_secs(Cat::Io, t0.elapsed().as_secs_f64());
    world.barrier();
    let view = store.view("tt.final")?;
    let t1 = std::time::Instant::now();
    let hfull = view.to_dense(); // r_prev × n_d row-major = flattened G(d)
    world.breakdown.add_secs(Cat::Reshape, t1.elapsed().as_secs_f64());
    world.breakdown.add_bytes(Cat::Io, view.disk_bytes_read());
    drop(view);
    world.barrier();
    if rank_id == 0 {
        store.remove("tt.final");
    }
    cores.push(Mat::from_vec(r_prev * dims[d - 1], 1, hfull));
    crate::obs::end_stage(final_span, "tt.final");

    // Merge sub-communicator costs, then take the critical path over ranks.
    world.breakdown.merge_sum(&row.breakdown.clone());
    world.breakdown.merge_sum(&col.breakdown.clone());
    let all = world.all_gather_any(world.breakdown.clone());
    let mut merged = Breakdown::new();
    for b in &all {
        merged.merge_max(b);
    }

    Ok(TtOutput { tt: TTensor::new(dims.to_vec(), cores)?, stages, breakdown: merged })
}

/// Convenience wrapper: decompose a replicated dense tensor on `p` thread
/// ranks arranged as `proc_grid` (tests, examples, small data).
pub fn ntt_on_threads(
    tensor: &crate::tensor::DenseTensor<f64>,
    proc_grid: &ProcGrid,
    cfg: &TtConfig,
) -> Result<TtOutput> {
    use crate::dist::chunkstore::SpillMode;
    let dims = tensor.dims().to_vec();
    let grid = proc_grid.to_2d();
    let store = SharedStore::new(SpillMode::Memory);
    let pg = proc_grid.clone();
    let cfg = cfg.clone();
    let tensor = tensor.clone();
    let mut outs = Comm::run(proc_grid.size(), move |mut world| {
        let my = extract_block(&tensor, &pg, world.rank());
        let (mut row, mut col) = grid.make_subcomms(&mut world);
        dist_ntt(
            &mut world,
            &mut row,
            &mut col,
            &store,
            &pg,
            grid,
            &dims,
            TensorBlock::Dense(my),
            &crate::runtime::native::NativeBackend,
            &cfg,
            KernelCfg::default(),
            None,
        )
    });
    outs.swap_remove(0)
}

/// Convenience wrapper for sparse inputs: decompose a
/// [`crate::ttrain::SyntheticSparse`] tensor on `p` thread ranks, every
/// rank generating its own sparse chunk (the full tensor is never
/// materialized).
pub fn ntt_sparse_on_threads(
    syn: &crate::ttrain::datagen::SyntheticSparse,
    proc_grid: &ProcGrid,
    cfg: &TtConfig,
) -> Result<TtOutput> {
    use crate::dist::chunkstore::SpillMode;
    let dims = syn.dims.clone();
    let grid = proc_grid.to_2d();
    let store = SharedStore::new(SpillMode::Memory);
    let pg = proc_grid.clone();
    let cfg = cfg.clone();
    let syn = syn.clone();
    let mut outs = Comm::run(proc_grid.size(), move |mut world| {
        let my = syn.block(&pg, world.rank());
        let (mut row, mut col) = grid.make_subcomms(&mut world);
        dist_ntt(
            &mut world,
            &mut row,
            &mut col,
            &store,
            &pg,
            grid,
            &dims,
            TensorBlock::Sparse(my),
            &crate::runtime::native::NativeBackend,
            &cfg,
            KernelCfg::default(),
            None,
        )
    });
    outs.swap_remove(0)
}

/// Serial (single-rank) nTT.
pub fn ntt_serial(
    tensor: &crate::tensor::DenseTensor<f64>,
    cfg: &TtConfig,
) -> Result<TtOutput> {
    let grid = ProcGrid::new(vec![1; tensor.ndim()])?;
    ntt_on_threads(tensor, &grid, cfg)
}

/// Extract the `TensorGrid` block of `rank` from a dense tensor.
pub fn extract_block(
    t: &crate::tensor::DenseTensor<f64>,
    grid: &ProcGrid,
    rank: usize,
) -> Vec<f64> {
    use crate::dist::BlockDim;
    let dims = t.dims();
    let coords = grid.coords(rank);
    let bds: Vec<BlockDim> = dims
        .iter()
        .zip(grid.dims().iter())
        .map(|(&n, &p)| BlockDim::new(n, p))
        .collect();
    let block_dims: Vec<usize> = bds.iter().zip(&coords).map(|(b, &c)| b.size_of(c)).collect();
    let total: usize = block_dims.iter().product();
    let mut out = Vec::with_capacity(total);
    let mut lidx = vec![0usize; dims.len()];
    for _ in 0..total {
        let gidx: Vec<usize> = lidx
            .iter()
            .zip(bds.iter().zip(&coords))
            .map(|(&li, (b, &c))| b.start_of(c) + li)
            .collect();
        out.push(t.get(&gidx));
        // increment local index row-major
        for k in (0..dims.len()).rev() {
            lidx[k] += 1;
            if lidx[k] < block_dims[k] {
                break;
            }
            lidx[k] = 0;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ttrain::datagen::SyntheticTt;

    fn cfg_iters(iters: usize) -> TtConfig {
        TtConfig {
            eps: 1e-6,
            nmf: NmfConfig { max_iters: iters, tol: 1e-12, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn recovers_ranks_and_reconstructs_serial() {
        let syn = SyntheticTt::new(vec![4, 5, 6], vec![2, 3], 11);
        let t = syn.dense();
        let out = ntt_serial(&t, &cfg_iters(400)).unwrap();
        assert_eq!(out.tt.ranks(), &[1, 2, 3, 1]);
        assert!(out.tt.is_nonneg());
        let err = out.tt.rel_error(&t);
        assert!(err < 0.05, "rel err {err}");
    }

    #[test]
    fn distributed_matches_serial() {
        let syn = SyntheticTt::new(vec![4, 4, 6], vec![2, 2], 13);
        let t = syn.dense();
        let serial = ntt_serial(&t, &cfg_iters(150)).unwrap();
        let grid = ProcGrid::new(vec![2, 1, 2]).unwrap();
        let dist = ntt_on_threads(&t, &grid, &cfg_iters(150)).unwrap();
        assert_eq!(serial.tt.ranks(), dist.tt.ranks());
        // Same deterministic init ⇒ same cores up to reduction roundoff.
        for (a, b) in serial.tt.cores().iter().zip(dist.tt.cores()) {
            for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn fixed_ranks_skip_svd() {
        let syn = SyntheticTt::new(vec![4, 4, 4], vec![2, 2], 17);
        let t = syn.dense();
        let mut cfg = cfg_iters(100);
        cfg.fixed_ranks = Some(vec![3, 3]);
        let out = ntt_serial(&t, &cfg).unwrap();
        assert_eq!(out.tt.ranks(), &[1, 3, 3, 1]);
        assert!(out.stages.iter().all(|s| s.svd_eps.is_nan()));
    }

    #[test]
    fn stage_shapes_follow_alg2() {
        let syn = SyntheticTt::new(vec![3, 4, 5, 6], vec![2, 2, 2], 19);
        let t = syn.dense();
        let out = ntt_serial(&t, &cfg_iters(60)).unwrap();
        // stage 0: m = n1 = 3, n = 4*5*6
        assert_eq!((out.stages[0].m, out.stages[0].n), (3, 120));
        // stage 1: m = r1*n2, n = 5*6
        let r1 = out.stages[0].rank;
        assert_eq!((out.stages[1].m, out.stages[1].n), (r1 * 4, 30));
        // stage 2: m = r2*n3, n = 6
        let r2 = out.stages[1].rank;
        assert_eq!((out.stages[2].m, out.stages[2].n), (r2 * 5, 6));
        // compression ratio consistent with Eq. 4
        let c = out.tt.compression_ratio();
        assert!(c > 0.0 && c.is_finite());
    }

    #[test]
    fn breakdown_populated() {
        let syn = SyntheticTt::new(vec![4, 4, 4], vec![2, 2], 23);
        let t = syn.dense();
        let grid = ProcGrid::new(vec![2, 2, 1]).unwrap();
        let out = ntt_on_threads(&t, &grid, &cfg_iters(20)).unwrap();
        let b = &out.breakdown;
        assert!(b.secs(Cat::MatMul) > 0.0);
        assert!(b.calls(Cat::AllReduce) > 0);
        assert!(b.calls(Cat::AllGather) > 0);
        assert!(b.calls(Cat::ReduceScatter) > 0);
        assert!(b.secs(Cat::Reshape) > 0.0);
    }

    #[test]
    fn two_mode_tensor_is_plain_nmf() {
        let syn = SyntheticTt::new(vec![8, 9], vec![2], 29);
        let t = syn.dense();
        let out = ntt_serial(&t, &cfg_iters(300)).unwrap();
        assert_eq!(out.tt.ranks(), &[1, 2, 1]);
        assert!(out.tt.rel_error(&t) < 0.05);
    }

    #[test]
    fn pruning_zero_slices_preserves_quality() {
        // Zero out slice i0 = 1 of the first mode: the stage-0 matrix has
        // an all-zero row that the prune path must drop and restore.
        let syn = SyntheticTt::new(vec![4, 4, 4], vec![2, 2], 37);
        let mut t = syn.dense();
        let dims = t.dims().to_vec();
        for i1 in 0..dims[1] {
            for i2 in 0..dims[2] {
                t.set(&[1, i1, i2], 0.0);
            }
        }
        let mut cfg = cfg_iters(250);
        cfg.prune = true;
        let out = ntt_serial(&t, &cfg).unwrap();
        assert!(out.tt.is_nonneg());
        // The zero slice comes back as an exactly-zero core row.
        assert!(out.tt.core(0).row(1).iter().all(|&v| v == 0.0));
        let err = out.tt.rel_error(&t);
        assert!(err < 0.05, "pruned rel err {err}");
    }

    #[test]
    fn rejects_bad_config() {
        let syn = SyntheticTt::new(vec![4, 4, 4], vec![2, 2], 31);
        let t = syn.dense();
        let mut cfg = cfg_iters(5);
        cfg.fixed_ranks = Some(vec![2]); // wrong length
        assert!(ntt_serial(&t, &cfg).is_err());
    }

    #[test]
    fn sparse_input_matches_densified_run() {
        use crate::ttrain::datagen::SyntheticSparse;
        let syn = SyntheticSparse::new(vec![6, 5, 4], 0.15, 77);
        let t = syn.dense();
        let mut cfg = cfg_iters(80);
        cfg.fixed_ranks = Some(vec![2, 2]);
        let grid = ProcGrid::new(vec![2, 1, 1]).unwrap();
        let sp = ntt_sparse_on_threads(&syn, &grid, &cfg).unwrap();
        let de = ntt_on_threads(&t, &grid, &cfg).unwrap();
        assert_eq!(sp.tt.ranks(), de.tt.ranks());
        // The sparse stage-0 path must agree with the dense run on the
        // densified tensor to reduction roundoff.
        for (a, b) in sp.tt.cores().iter().zip(de.tt.cores()) {
            for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                assert!((x - y).abs() < 1e-5, "{x} vs {y}");
            }
        }
        assert!(sp.tt.is_nonneg());
    }
}
