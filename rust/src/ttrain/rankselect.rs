//! Distributed SVD-based TT-rank selection (Alg 2 lines 5–6).
//!
//! The paper selects each TT rank as the smallest `k` with
//! `sqrt(σ_{k+1}²+…+σ_N²)/sqrt(σ_1²+…+σ_N²) ≤ ε`. Only singular values are
//! needed, never factors, so the distributed SVD reduces to a randomized
//! range sketch (Halko–Martinsson–Tropp):
//!
//! 1. `Y = X·Ω` with a seeded Gaussian `Ω: n×k` — local GEMM + row-comm
//!    all_reduce + col-comm all_gather (Y is `m×k`, small);
//! 2. `Q = qr(Y).q` locally (deterministic, identical on all ranks);
//! 3. `B = Qᵀ·X` — local GEMM + col-comm all_reduce (kept distributed);
//! 4. `σ = sqrt(eig(B·Bᵀ))` after a world all_reduce of the `k×k` Gram.
//!
//! When `k = min(m, n)` the sketch is exact (Q spans the full column
//! space); otherwise the top-k values are accurate and the *tail energy*
//! is recovered exactly from `‖X‖²_F − Σσᵢ²` (a cheap all_reduce), which is
//! all the ε-threshold needs. If the threshold is not reached within `k`
//! values the sketch doubles and retries (up to `min(m,n)`).

use crate::dist::{BlockDim, Comm, Grid2d};
use crate::error::Result;
use crate::linalg::eig::sym_eig;
use crate::linalg::gemm::{gram_m_mt, matmul, matmul_at_b};
use crate::linalg::qr::thin_qr;
use crate::linalg::Mat;
use crate::util::timer::Cat;

/// Rank-selection parameters.
#[derive(Clone, Debug)]
pub struct RankSelectConfig {
    /// Target relative-error threshold ε.
    pub eps: f64,
    /// Cap on the returned rank (paper TT ranks are ≤ 40; default 128).
    pub max_rank: usize,
    /// Oversampling columns added to the sketch.
    pub oversample: usize,
    /// Sketch seed (deterministic across ranks).
    pub seed: u64,
}

impl Default for RankSelectConfig {
    fn default() -> Self {
        RankSelectConfig { eps: 0.01, max_rank: 128, oversample: 10, seed: 777 }
    }
}

/// Deterministic standard-normal entry for `Ω[(row, col)]`.
#[inline]
fn gauss_entry(seed: u64, row: usize, col: usize) -> f64 {
    #[inline]
    fn u(seed: u64, row: usize, col: usize, salt: u64) -> f64 {
        let mut z = seed ^ salt.wrapping_mul(0xD6E8_FEB8_6659_FD93);
        z ^= (row as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z ^= (col as u64).wrapping_mul(0x94D0_49BB_1331_11EB);
        z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut x = z;
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
        (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
    let u1 = u(seed, row, col, 1).max(1e-300);
    let u2 = u(seed, row, col, 2);
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

/// Result of the distributed rank selection.
#[derive(Clone, Debug)]
pub struct RankSelection {
    /// The selected TT rank `r_l`.
    pub rank: usize,
    /// Leading singular values (length = sketch size actually used).
    pub singular_values: Vec<f64>,
    /// Achieved tail bound `sqrt(tail/total)` at the selected rank.
    pub achieved_eps: f64,
}

/// Distributed ε-threshold rank selection on the `m×n` matrix whose local
/// block (on grid position derived from `world.rank()`) is `x`.
/// Collective over `world`/`row`/`col`.
#[allow(clippy::too_many_arguments)]
pub fn dist_rank_select(
    x: &Mat<f64>,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    cfg: &RankSelectConfig,
) -> Result<RankSelection> {
    let (i, j) = grid.coords(world.rank());
    let rows = BlockDim::new(m, grid.pr);
    let cols = BlockDim::new(n, grid.pc);
    debug_assert_eq!((x.rows(), x.cols()), (rows.size_of(i), cols.size_of(j)));

    // Exact total energy.
    let t0 = std::time::Instant::now();
    let local_sq = x.fro_norm_sq();
    world.breakdown.add_secs(Cat::Norm, t0.elapsed().as_secs_f64());
    let total = world.all_reduce_scalar(local_sq);
    if total <= 0.0 {
        return Ok(RankSelection { rank: 1, singular_values: vec![0.0], achieved_eps: 0.0 });
    }

    let nmin = m.min(n);
    let mut k = (cfg.max_rank + cfg.oversample).min(nmin);
    loop {
        let sigma = sketch_singular_values(x, m, n, grid, world, row, col, cfg.seed, k)?;
        // Smallest rank whose tail energy is under eps (bounded by max_rank).
        let mut cum = 0.0;
        let mut chosen = None;
        for (idx, s) in sigma.iter().enumerate() {
            cum += s * s;
            let tail = ((total - cum).max(0.0) / total).sqrt();
            if tail <= cfg.eps {
                chosen = Some((idx + 1, tail));
                break;
            }
            if idx + 1 >= cfg.max_rank {
                chosen = Some((cfg.max_rank, tail));
                break;
            }
        }
        match chosen {
            Some((rank, achieved)) => {
                return Ok(RankSelection { rank, singular_values: sigma, achieved_eps: achieved })
            }
            None if k >= nmin => {
                // Even the full spectrum can't reach eps (eps below noise
                // floor): return full rank.
                let cum: f64 = sigma.iter().map(|s| s * s).sum();
                let achieved = ((total - cum).max(0.0) / total).sqrt();
                return Ok(RankSelection {
                    rank: sigma.len().min(cfg.max_rank).max(1),
                    singular_values: sigma,
                    achieved_eps: achieved,
                });
            }
            None => {
                k = (k * 2).min(nmin);
                log::debug!("rank selection: sketch too small, doubling to {k}");
            }
        }
    }
}

/// Top-`k` singular values of the distributed matrix via a randomized
/// range sketch (see module docs). Identical on every rank.
#[allow(clippy::too_many_arguments)]
fn sketch_singular_values(
    x: &Mat<f64>,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    seed: u64,
    k: usize,
) -> Result<Vec<f64>> {
    let (i, j) = grid.coords(world.rank());
    let rows = BlockDim::new(m, grid.pr);
    let cols = BlockDim::new(n, grid.pc);

    // Ω block for my columns.
    let t0 = std::time::Instant::now();
    let omega_j =
        Mat::from_fn(x.cols(), k, |lb, c| gauss_entry(seed, cols.start_of(j) + lb, c));
    // Y_loc = X^(i,j) · Ω_j.
    let mut y = matmul(x, &omega_j);
    world.breakdown.add_secs(Cat::Svd, t0.elapsed().as_secs_f64());
    // Sum over the block-row (row comm), then assemble full Y (col comm).
    row.all_reduce_sum(y.as_mut_slice());
    let parts = col.all_gather_varied(y.as_slice());
    let mut yfull = Vec::with_capacity(m * k);
    for p in &parts {
        yfull.extend_from_slice(p);
    }
    let yfull = Mat::from_vec(m, k, yfull);

    // Q = qr(Y).q — every rank computes the same Q.
    let t1 = std::time::Instant::now();
    let q = thin_qr(&yfull).q; // m × k
    let qi = q.rows_slice(rows.start_of(i), rows.start_of(i) + rows.size_of(i));
    // Partial B^(j) = Q^(i)ᵀ · X^(i,j)  (k × n_j).
    let mut b = matmul_at_b(&qi, x);
    world.breakdown.add_secs(Cat::Svd, t1.elapsed().as_secs_f64());
    col.all_reduce_sum(b.as_mut_slice());

    // G = B·Bᵀ summed over column blocks (only one rank per column block
    // contributes to avoid double counting).
    let t2 = std::time::Instant::now();
    let mut g = if col.rank() == 0 { gram_m_mt(&b) } else { Mat::zeros(k, k) };
    world.breakdown.add_secs(Cat::Svd, t2.elapsed().as_secs_f64());
    world.all_reduce_sum(g.as_mut_slice());

    let t3 = std::time::Instant::now();
    let vals = sym_eig(&g).values;
    world.breakdown.add_secs(Cat::Svd, t3.elapsed().as_secs_f64());
    Ok(vals.into_iter().map(|l| l.max(0.0).sqrt()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::thin_svd;
    use crate::util::rng::Rng;

    /// Run dist_rank_select on a full matrix over a grid.
    fn run(x: &Mat<f64>, grid: Grid2d, cfg: &RankSelectConfig) -> RankSelection {
        let (m, n) = x.shape();
        let x = x.clone();
        let cfg = cfg.clone();
        let outs = Comm::run(grid.size(), move |mut world| {
            let (i, j) = grid.coords(world.rank());
            let rows = BlockDim::new(m, grid.pr);
            let cols = BlockDim::new(n, grid.pc);
            let xb = Mat::from_fn(rows.size_of(i), cols.size_of(j), |a, b| {
                x[(rows.start_of(i) + a, cols.start_of(j) + b)]
            });
            let (mut row, mut col) = grid.make_subcomms(&mut world);
            dist_rank_select(&xb, m, n, grid, &mut world, &mut row, &mut col, &cfg).unwrap()
        });
        // All ranks must agree.
        for o in &outs[1..] {
            assert_eq!(o.rank, outs[0].rank);
        }
        outs[0].clone()
    }

    fn low_rank(m: usize, n: usize, r: usize, seed: u64) -> Mat<f64> {
        let mut rng = Rng::new(seed);
        let a = Mat::<f64>::rand_uniform(m, r, &mut rng);
        let b = Mat::<f64>::rand_uniform(r, n, &mut rng);
        matmul(&a, &b)
    }

    #[test]
    fn exact_rank_detected() {
        let x = low_rank(20, 30, 4, 1);
        let sel = run(&x, Grid2d::new(2, 2), &RankSelectConfig { eps: 1e-8, ..Default::default() });
        assert_eq!(sel.rank, 4);
        assert!(sel.achieved_eps <= 1e-8);
    }

    #[test]
    fn sigma_matches_serial_svd() {
        let mut rng = Rng::new(2);
        let x = Mat::<f64>::rand_uniform(18, 24, &mut rng);
        let sel = run(&x, Grid2d::new(3, 2), &RankSelectConfig { eps: 0.0, max_rank: 18, oversample: 18, ..Default::default() });
        let svd = thin_svd(&x);
        for (a, b) in sel.singular_values.iter().zip(svd.s.iter()).take(18) {
            assert!((a - b).abs() < 1e-6 * (1.0 + b), "{a} vs {b}");
        }
    }

    #[test]
    fn looser_eps_gives_smaller_rank() {
        let x = low_rank(30, 40, 8, 3);
        let tight = run(&x, Grid2d::new(2, 2), &RankSelectConfig { eps: 1e-8, ..Default::default() });
        let loose = run(&x, Grid2d::new(2, 2), &RankSelectConfig { eps: 0.3, ..Default::default() });
        assert!(loose.rank <= tight.rank);
        assert!(loose.rank >= 1);
    }

    #[test]
    fn max_rank_caps_selection() {
        let mut rng = Rng::new(4);
        let x = Mat::<f64>::rand_uniform(30, 30, &mut rng); // full rank
        let sel = run(
            &x,
            Grid2d::new(1, 1),
            &RankSelectConfig { eps: 1e-12, max_rank: 5, ..Default::default() },
        );
        assert_eq!(sel.rank, 5);
        assert!(sel.achieved_eps > 1e-12);
    }

    #[test]
    fn zero_matrix_rank_one() {
        let x = Mat::<f64>::zeros(8, 8);
        let sel = run(&x, Grid2d::new(2, 2), &RankSelectConfig::default());
        assert_eq!(sel.rank, 1);
    }

    #[test]
    fn grid_invariance() {
        let x = low_rank(24, 36, 5, 5);
        let cfg = RankSelectConfig { eps: 1e-6, ..Default::default() };
        let a = run(&x, Grid2d::new(1, 1), &cfg);
        let b = run(&x, Grid2d::new(2, 3), &cfg);
        assert_eq!(a.rank, b.rank);
        for (x1, x2) in a.singular_values.iter().zip(b.singular_values.iter()).take(5) {
            assert!((x1 - x2).abs() < 1e-6 * (1.0 + x1));
        }
    }
}
