//! Synthetic tensor generation (§IV-A of the paper).
//!
//! A ground-truth tensor train with prescribed dims and ranks is sampled
//! with uniform [0,1) cores and the full tensor is its contraction. In the
//! distributed setting every rank generates the (small) cores from the
//! shared seed and contracts *only its own block* — the index-restricted
//! cores form a valid TT whose reconstruction is exactly the block. This is
//! communication-free and numerically identical to the paper's distributed
//! matmul chain.

use crate::dist::{BlockDim, ProcGrid};
use crate::error::Result;
use crate::linalg::Mat;
use crate::tensor::{DenseTensor, TTensor};
use crate::util::rng::Rng;

/// Ground-truth description of a synthetic TT tensor.
#[derive(Clone, Debug)]
pub struct SyntheticTt {
    pub dims: Vec<usize>,
    pub ranks: Vec<usize>, // inner ranks, length d-1
    pub seed: u64,
}

impl SyntheticTt {
    pub fn new(dims: Vec<usize>, ranks: Vec<usize>, seed: u64) -> Self {
        assert_eq!(ranks.len() + 1, dims.len());
        SyntheticTt { dims, ranks, seed }
    }

    /// The paper's strong-scaling workload: 256⁴ with ranks (10,10,10),
    /// scaled down by `shrink` per mode.
    pub fn paper_strong_scaling(shrink: usize) -> Self {
        let n = (256 / shrink.max(1)).max(4);
        SyntheticTt::new(vec![n; 4], vec![10, 10, 10], 20190020)
    }

    /// Generate the ground-truth TT (cores only; cheap).
    pub fn ground_truth(&self) -> TTensor<f64> {
        let mut rng = Rng::new(self.seed);
        TTensor::rand_uniform(&self.dims, &self.ranks, &mut rng).expect("synthetic TT")
    }

    /// Full dense tensor (small cases / tests).
    pub fn dense(&self) -> DenseTensor<f64> {
        self.ground_truth().reconstruct()
    }

    /// This rank's `TensorGrid` block of the full tensor: restrict every
    /// core to the block's index range along its mode and contract.
    pub fn block(&self, grid: &ProcGrid, rank: usize) -> Result<Vec<f64>> {
        let tt = self.ground_truth();
        let coords = grid.coords(rank);
        let mut block_dims = Vec::with_capacity(self.dims.len());
        let mut cores = Vec::with_capacity(self.dims.len());
        let mut r_prev = 1usize;
        for (k, core) in tt.cores().iter().enumerate() {
            let bd = BlockDim::new(self.dims[k], grid.dims()[k]);
            let (lo, len) = (bd.start_of(coords[k]), bd.size_of(coords[k]));
            let r_next = core.cols();
            // Rows of the flattened core are (prev_rank_index, mode_index);
            // keep mode indices in [lo, lo+len).
            let mut sub = Mat::<f64>::zeros(r_prev * len, r_next);
            for kk in 0..r_prev {
                for (li, gi) in (lo..lo + len).enumerate() {
                    sub.row_mut(kk * len + li).copy_from_slice(core.row(kk * self.dims[k] + gi));
                }
            }
            cores.push(sub);
            block_dims.push(len);
            r_prev = r_next;
        }
        let block_tt = TTensor::new(block_dims, cores)?;
        Ok(block_tt.reconstruct().into_vec())
    }

    /// Total elements.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Size in bytes at f64.
    pub fn nbytes(&self) -> usize {
        self.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dense::multi_index;
    use crate::util::prop::check;

    #[test]
    fn blocks_tile_the_dense_tensor() {
        check(901, |rng| {
            let d = 2 + rng.below(3);
            let dims: Vec<usize> = (0..d).map(|_| 2 + rng.below(5)).collect();
            let ranks: Vec<usize> = (0..d - 1).map(|_| 1 + rng.below(3)).collect();
            let grid_dims: Vec<usize> = dims.iter().map(|&n| 1 + rng.below(n.min(3))).collect();
            let syn = SyntheticTt::new(dims.clone(), ranks, rng.next_u64());
            let grid = ProcGrid::new(grid_dims.clone()).unwrap();
            let full = syn.dense();
            // Reassemble all blocks and compare element-wise.
            for r in 0..grid.size() {
                let block = syn.block(&grid, r).unwrap();
                let coords = grid.coords(r);
                let bds: Vec<BlockDim> = dims
                    .iter()
                    .zip(grid_dims.iter())
                    .map(|(&n, &p)| BlockDim::new(n, p))
                    .collect();
                let block_dims: Vec<usize> =
                    bds.iter().zip(&coords).map(|(bd, &c)| bd.size_of(c)).collect();
                for (loff, &v) in block.iter().enumerate() {
                    let lidx = multi_index(&block_dims, loff);
                    let gidx: Vec<usize> = lidx
                        .iter()
                        .zip(bds.iter().zip(&coords))
                        .map(|(&li, (bd, &c))| bd.start_of(c) + li)
                        .collect();
                    let want = full.get(&gidx);
                    if (v - want).abs() > 1e-10 * (1.0 + want.abs()) {
                        return Err(format!("block {r} mismatch at {gidx:?}: {v} vs {want}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_across_calls() {
        let syn = SyntheticTt::new(vec![4, 4, 4], vec![2, 2], 99);
        assert_eq!(syn.dense().as_slice(), syn.dense().as_slice());
        let grid = ProcGrid::new(vec![2, 1, 2]).unwrap();
        assert_eq!(syn.block(&grid, 1).unwrap(), syn.block(&grid, 1).unwrap());
    }

    #[test]
    fn nonneg_by_construction() {
        let syn = SyntheticTt::new(vec![5, 6, 4], vec![3, 2], 7);
        assert!(syn.dense().is_nonneg());
    }

    #[test]
    fn paper_workload_scaled() {
        let s = SyntheticTt::paper_strong_scaling(4);
        assert_eq!(s.dims, vec![64; 4]);
        assert_eq!(s.ranks, vec![10, 10, 10]);
        assert_eq!(s.nbytes(), 64usize.pow(4) * 8);
    }
}
