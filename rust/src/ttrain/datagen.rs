//! Synthetic tensor generation (§IV-A of the paper).
//!
//! A ground-truth tensor train with prescribed dims and ranks is sampled
//! with uniform [0,1) cores and the full tensor is its contraction. In the
//! distributed setting every rank generates the (small) cores from the
//! shared seed and contracts *only its own block* — the index-restricted
//! cores form a valid TT whose reconstruction is exactly the block. This is
//! communication-free and numerically identical to the paper's distributed
//! matmul chain.
//!
//! [`SyntheticSparse`] is the sparse counterpart: a hash-gated random
//! tensor with controllable density whose per-rank blocks are generated
//! directly as [`SparseChunk`]s (grid-invariant, communication-free, and
//! never materialized densely in the distributed path).

use crate::dist::{BlockDim, ProcGrid};
use crate::error::Result;
use crate::linalg::Mat;
use crate::tensor::sparse::{SparseChunk, SparseTensor};
use crate::tensor::{DenseTensor, TTensor};
use crate::util::rng::Rng;

/// Ground-truth description of a synthetic TT tensor.
#[derive(Clone, Debug)]
pub struct SyntheticTt {
    pub dims: Vec<usize>,
    pub ranks: Vec<usize>, // inner ranks, length d-1
    pub seed: u64,
}

impl SyntheticTt {
    pub fn new(dims: Vec<usize>, ranks: Vec<usize>, seed: u64) -> Self {
        assert_eq!(ranks.len() + 1, dims.len());
        SyntheticTt { dims, ranks, seed }
    }

    /// The paper's strong-scaling workload: 256⁴ with ranks (10,10,10),
    /// scaled down by `shrink` per mode.
    pub fn paper_strong_scaling(shrink: usize) -> Self {
        let n = (256 / shrink.max(1)).max(4);
        SyntheticTt::new(vec![n; 4], vec![10, 10, 10], 20190020)
    }

    /// Generate the ground-truth TT (cores only; cheap).
    pub fn ground_truth(&self) -> TTensor<f64> {
        let mut rng = Rng::new(self.seed);
        TTensor::rand_uniform(&self.dims, &self.ranks, &mut rng).expect("synthetic TT")
    }

    /// Full dense tensor (small cases / tests).
    pub fn dense(&self) -> DenseTensor<f64> {
        self.ground_truth().reconstruct()
    }

    /// This rank's `TensorGrid` block of the full tensor: restrict every
    /// core to the block's index range along its mode and contract.
    pub fn block(&self, grid: &ProcGrid, rank: usize) -> Result<Vec<f64>> {
        let tt = self.ground_truth();
        let coords = grid.coords(rank);
        let mut block_dims = Vec::with_capacity(self.dims.len());
        let mut cores = Vec::with_capacity(self.dims.len());
        let mut r_prev = 1usize;
        for (k, core) in tt.cores().iter().enumerate() {
            let bd = BlockDim::new(self.dims[k], grid.dims()[k]);
            let (lo, len) = (bd.start_of(coords[k]), bd.size_of(coords[k]));
            let r_next = core.cols();
            // Rows of the flattened core are (prev_rank_index, mode_index);
            // keep mode indices in [lo, lo+len).
            let mut sub = Mat::<f64>::zeros(r_prev * len, r_next);
            for kk in 0..r_prev {
                for (li, gi) in (lo..lo + len).enumerate() {
                    sub.row_mut(kk * len + li).copy_from_slice(core.row(kk * self.dims[k] + gi));
                }
            }
            cores.push(sub);
            block_dims.push(len);
            r_prev = r_next;
        }
        let block_tt = TTensor::new(block_dims, cores)?;
        Ok(block_tt.reconstruct().into_vec())
    }

    /// Total elements.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Size in bytes at f64.
    pub fn nbytes(&self) -> usize {
        self.len() * 8
    }

    /// Write this tensor to `dir` as a `dntt-chunks-v1` chunk set tiled
    /// on `grid`, generating one chunk at a time — the full tensor is
    /// never resident, so datagen scales to inputs larger than RAM
    /// (`dntt datagen`). Chunk bytes are exactly what [`Self::block`]
    /// produces, so a job fed from the chunk set is bitwise-identical to
    /// one generating blocks in memory.
    pub fn write_chunks(
        &self,
        dir: &std::path::Path,
        grid: &ProcGrid,
    ) -> Result<crate::tensor::ChunkSet> {
        let mut w = crate::tensor::ChunkWriter::create(dir, &self.dims, grid.dims())?;
        for rank in 0..grid.size() {
            w.write_dense(rank, &self.block(grid, rank)?)?;
        }
        w.finish()
    }
}

/// SplitMix64-style hash → U(0,1), a pure function of `(seed, tag, lin)`
/// so every rank sees the same global tensor regardless of the grid.
#[inline]
fn hash_u01(seed: u64, tag: u64, lin: usize) -> f64 {
    let mut z = seed ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z ^= (lin as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut x = z;
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Ground-truth description of a synthetic **sparse** tensor with
/// controllable density: element `lin` is nonzero iff a seeded hash gate
/// fires (probability `density`), with a second hash drawing its
/// U(0.5, 1.5) value — non-negative and bounded away from zero so the
/// sparsity pattern is exact. Deterministic and grid-invariant like
/// [`SyntheticTt`]; used by the sparse-path equivalence tests, the
/// `sparse_vs_dense` bench and the CLI's `--input sparse`.
#[derive(Clone, Debug)]
pub struct SyntheticSparse {
    pub dims: Vec<usize>,
    /// Expected fraction of nonzero elements, in (0, 1].
    pub density: f64,
    pub seed: u64,
}

impl SyntheticSparse {
    pub fn new(dims: Vec<usize>, density: f64, seed: u64) -> Self {
        assert!(!dims.is_empty(), "SyntheticSparse needs at least one mode");
        assert!(
            density > 0.0 && density <= 1.0,
            "SyntheticSparse density must be in (0, 1], got {density}"
        );
        SyntheticSparse { dims, density, seed }
    }

    /// Value at global linear index `lin` (0.0 off the sparsity pattern).
    #[inline]
    pub fn value_at(&self, lin: usize) -> f64 {
        if hash_u01(self.seed, 1, lin) < self.density {
            0.5 + hash_u01(self.seed, 2, lin)
        } else {
            0.0
        }
    }

    /// Total (dense) element count.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Exact nonzero count, by walking the hash gate (`O(len)` — small
    /// tensors only). Nonzero draws are in `[0.5, 1.5)`, so the gate
    /// fully determines the pattern.
    pub fn nnz_exact(&self) -> usize {
        (0..self.len()).filter(|&lin| self.value_at(lin) != 0.0).count()
    }

    /// Stored element count for compression accounting: the exact nnz up
    /// to 20M elements (the same cutoff `run_job` uses for error
    /// checking), the expectation `density·len` beyond it.
    pub fn storage_nnz(&self) -> f64 {
        const EXACT_COUNT_LIMIT: usize = 20_000_000;
        if self.len() <= EXACT_COUNT_LIMIT {
            self.nnz_exact() as f64
        } else {
            self.density * self.len() as f64
        }
    }

    /// The full tensor in COO form (small cases / tests).
    pub fn sparse(&self) -> SparseTensor {
        let entries: Vec<(usize, f64)> = (0..self.len())
            .filter_map(|lin| {
                let v = self.value_at(lin);
                (v != 0.0).then_some((lin, v))
            })
            .collect();
        SparseTensor::new(self.dims.clone(), entries).expect("unique by construction")
    }

    /// Full dense tensor (small cases / tests).
    pub fn dense(&self) -> DenseTensor<f64> {
        let data: Vec<f64> = (0..self.len()).map(|lin| self.value_at(lin)).collect();
        DenseTensor::from_vec(&self.dims, data).expect("consistent dims")
    }

    /// This rank's `TensorGrid` block as a sparse chunk, generated
    /// directly from the hash (no global materialization). Identical to
    /// `self.sparse().block_chunk(grid, rank)` — asserted in the tests.
    pub fn block(&self, grid: &ProcGrid, rank: usize) -> SparseChunk {
        let d = self.dims.len();
        let coords = grid.coords(rank);
        let bds: Vec<BlockDim> = self
            .dims
            .iter()
            .zip(grid.dims())
            .map(|(&n, &p)| BlockDim::new(n, p))
            .collect();
        let lo: Vec<usize> = bds.iter().zip(&coords).map(|(b, &c)| b.start_of(c)).collect();
        let sz: Vec<usize> = bds.iter().zip(&coords).map(|(b, &c)| b.size_of(c)).collect();
        let total: usize = sz.iter().product();
        let mut idx = Vec::new();
        let mut vals = Vec::new();
        let mut lidx = vec![0usize; d];
        for loc in 0..total {
            // Global linear index of this local element.
            let mut glin = 0usize;
            for k in 0..d {
                glin = glin * self.dims[k] + lo[k] + lidx[k];
            }
            let v = self.value_at(glin);
            if v != 0.0 {
                idx.push(loc);
                vals.push(v);
            }
            // Increment the local index row-major.
            for k in (0..d).rev() {
                lidx[k] += 1;
                if lidx[k] < sz[k] {
                    break;
                }
                lidx[k] = 0;
            }
        }
        SparseChunk::new(total, idx, vals).expect("sorted by construction")
    }

    /// Sparse counterpart of [`SyntheticTt::write_chunks`]: one sparse
    /// chunk generated and written at a time (nnz-scaled files).
    pub fn write_chunks(
        &self,
        dir: &std::path::Path,
        grid: &ProcGrid,
    ) -> Result<crate::tensor::ChunkSet> {
        let mut w = crate::tensor::ChunkWriter::create(dir, &self.dims, grid.dims())?;
        for rank in 0..grid.size() {
            w.write_sparse(rank, &self.block(grid, rank))?;
        }
        w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dense::multi_index;
    use crate::util::prop::check;

    #[test]
    fn blocks_tile_the_dense_tensor() {
        check(901, |rng| {
            let d = 2 + rng.below(3);
            let dims: Vec<usize> = (0..d).map(|_| 2 + rng.below(5)).collect();
            let ranks: Vec<usize> = (0..d - 1).map(|_| 1 + rng.below(3)).collect();
            let grid_dims: Vec<usize> = dims.iter().map(|&n| 1 + rng.below(n.min(3))).collect();
            let syn = SyntheticTt::new(dims.clone(), ranks, rng.next_u64());
            let grid = ProcGrid::new(grid_dims.clone()).unwrap();
            let full = syn.dense();
            // Reassemble all blocks and compare element-wise.
            for r in 0..grid.size() {
                let block = syn.block(&grid, r).unwrap();
                let coords = grid.coords(r);
                let bds: Vec<BlockDim> = dims
                    .iter()
                    .zip(grid_dims.iter())
                    .map(|(&n, &p)| BlockDim::new(n, p))
                    .collect();
                let block_dims: Vec<usize> =
                    bds.iter().zip(&coords).map(|(bd, &c)| bd.size_of(c)).collect();
                for (loff, &v) in block.iter().enumerate() {
                    let lidx = multi_index(&block_dims, loff);
                    let gidx: Vec<usize> = lidx
                        .iter()
                        .zip(bds.iter().zip(&coords))
                        .map(|(&li, (bd, &c))| bd.start_of(c) + li)
                        .collect();
                    let want = full.get(&gidx);
                    if (v - want).abs() > 1e-10 * (1.0 + want.abs()) {
                        return Err(format!("block {r} mismatch at {gidx:?}: {v} vs {want}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_across_calls() {
        let syn = SyntheticTt::new(vec![4, 4, 4], vec![2, 2], 99);
        assert_eq!(syn.dense().as_slice(), syn.dense().as_slice());
        let grid = ProcGrid::new(vec![2, 1, 2]).unwrap();
        assert_eq!(syn.block(&grid, 1).unwrap(), syn.block(&grid, 1).unwrap());
    }

    #[test]
    fn nonneg_by_construction() {
        let syn = SyntheticTt::new(vec![5, 6, 4], vec![3, 2], 7);
        assert!(syn.dense().is_nonneg());
    }

    #[test]
    fn paper_workload_scaled() {
        let s = SyntheticTt::paper_strong_scaling(4);
        assert_eq!(s.dims, vec![64; 4]);
        assert_eq!(s.ranks, vec![10, 10, 10]);
        assert_eq!(s.nbytes(), 64usize.pow(4) * 8);
    }

    #[test]
    fn sparse_blocks_match_coo_chunking() {
        let syn = SyntheticSparse::new(vec![5, 4, 3], 0.3, 41);
        let coo = syn.sparse();
        assert_eq!(coo.to_dense().as_slice(), syn.dense().as_slice());
        let grid = ProcGrid::new(vec![2, 2, 1]).unwrap();
        for r in 0..grid.size() {
            assert_eq!(syn.block(&grid, r), coo.block_chunk(&grid, r));
        }
    }

    #[test]
    fn sparse_density_tracks_request() {
        for &density in &[0.01, 0.1, 0.5] {
            let syn = SyntheticSparse::new(vec![32, 32, 16], density, 7);
            let got = syn.sparse().density();
            assert!(
                (got - density).abs() < 0.05 * (1.0 + density),
                "requested {density}, generated {got}"
            );
        }
        // Nonzero values are bounded away from zero (pattern is exact).
        let syn = SyntheticSparse::new(vec![8, 8], 0.4, 9);
        for (gi, v) in (0..64).map(|l| (l, syn.value_at(l))) {
            assert!(v == 0.0 || v >= 0.5, "value {v} at {gi}");
        }
    }

    #[test]
    fn nnz_exact_matches_coo_and_feeds_storage() {
        let syn = SyntheticSparse::new(vec![12, 9, 7], 0.15, 42);
        let nnz = syn.nnz_exact();
        assert_eq!(nnz, syn.sparse().nnz());
        // Below the exactness cutoff, storage is the exact count.
        assert_eq!(syn.storage_nnz(), nnz as f64);
        // The hash gate tracks the requested density (loose check).
        let frac = nnz as f64 / syn.len() as f64;
        assert!((frac - 0.15).abs() < 0.05, "observed density {frac}");
    }

    #[test]
    fn write_chunks_stores_exact_block_bytes() {
        let base = std::env::temp_dir().join(format!("dntt_datagen_chunks_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let syn = SyntheticTt::new(vec![6, 4, 4], vec![2, 2], 11);
        let grid = ProcGrid::new(vec![2, 1, 2]).unwrap();
        let dir = base.join("tt");
        let cs = syn.write_chunks(&dir, &grid).unwrap();
        assert_eq!(cs.num_chunks(), grid.size());
        for r in 0..grid.size() {
            cs.verify(r).unwrap();
            // The chunk file is byte-for-byte the in-memory block.
            let bytes = std::fs::read(dir.join(format!("chunk.{r}.bin"))).unwrap();
            let want = syn.block(&grid, r).unwrap();
            assert_eq!(bytes.len(), want.len() * 8);
            for (b, w) in bytes.chunks_exact(8).zip(&want) {
                assert_eq!(u64::from_le_bytes(b.try_into().unwrap()), w.to_bits());
            }
        }
        // Sparse chunk sets verify too (format correctness).
        let ssyn = SyntheticSparse::new(vec![6, 6], 0.3, 5);
        let g2 = ProcGrid::new(vec![2, 1]).unwrap();
        let scs = ssyn.write_chunks(&base.join("sp"), &g2).unwrap();
        for r in 0..g2.size() {
            scs.verify(r).unwrap();
        }
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn sparse_is_deterministic_and_grid_invariant() {
        let syn = SyntheticSparse::new(vec![6, 6], 0.2, 3);
        assert_eq!(syn.dense().as_slice(), syn.dense().as_slice());
        let g1 = ProcGrid::new(vec![2, 1]).unwrap();
        let g2 = ProcGrid::new(vec![1, 3]).unwrap();
        // Reassembling blocks from different grids gives the same tensor.
        let full = syn.dense();
        for grid in [g1, g2] {
            for r in 0..grid.size() {
                let chunk = syn.block(&grid, r);
                let coords = grid.coords(r);
                let bds: Vec<BlockDim> = syn
                    .dims
                    .iter()
                    .zip(grid.dims())
                    .map(|(&n, &p)| BlockDim::new(n, p))
                    .collect();
                let dense = chunk.to_dense();
                let cols = bds[1].size_of(coords[1]);
                for (loc, &v) in dense.iter().enumerate() {
                    let gi = bds[0].start_of(coords[0]) + loc / cols;
                    let gj = bds[1].start_of(coords[1]) + loc % cols;
                    assert_eq!(v, full.get(&[gi, gj]));
                }
            }
        }
    }
}
