//! The paper's contribution: distributed non-negative tensor-train
//! decomposition — rank selection (distributed ε-threshold SVD), the
//! Alg-2 sweep driver, and the §IV-A synthetic workload generator.

pub mod datagen;
pub mod driver;
pub mod rankselect;
pub mod round;

pub use datagen::{SyntheticSparse, SyntheticTt};
pub use driver::{
    dist_ntt, ntt_on_threads, ntt_serial, ntt_sparse_on_threads, StageStats, TtConfig, TtOutput,
};
pub use rankselect::{dist_rank_select, RankSelectConfig, RankSelection};
pub use round::tt_round;
