//! TT-rounding (recompression) — Oseledets' Algorithm 2 (2011).
//!
//! After an nTT sweep the ranks chosen per stage can be loose (e.g. when
//! the NMF residual inflated a later stage's SVD selection, or when fixed
//! ranks were conservative). Rounding re-orthogonalizes the train
//! right-to-left with QR and then truncates left-to-right with SVD at a
//! prescribed tolerance, producing the (near-)optimal ranks for the tensor
//! *represented by the train* without ever densifying it.
//!
//! Note: rounding is an SVD procedure, so non-negativity of cores is NOT
//! preserved — the paper leaves non-negative rank reduction as future work;
//! we expose rounding for the TT-SVD baseline and for storage-oriented use
//! where signs are acceptable (documented at the call site).

use crate::error::Result;
use crate::tensor::TTensor;

/// Recompress `tt` to relative tolerance `eps` (per-stage threshold, as in
/// the decomposition sweep). Returns a new train with ranks ≤ the input's.
///
/// This is the `eps`-only special case of [`crate::serve::truncate`],
/// which also accepts a hard rank budget; the sweep implementation lives
/// there (right-to-left RQ orthogonalization, then a left-to-right SVD
/// truncation sweep).
pub fn tt_round(tt: &TTensor<f64>, eps: f64) -> Result<TTensor<f64>> {
    crate::serve::truncate(tt, eps, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rounding_is_lossless_at_zero_eps() {
        let mut rng = Rng::new(1);
        let tt = TTensor::<f64>::rand_uniform(&[4, 5, 3], &[2, 2], &mut rng).unwrap();
        let full = tt.reconstruct();
        let rounded = tt_round(&tt, 1e-12).unwrap();
        assert!(rounded.rel_error(&full) < 1e-9);
        // Ranks cannot grow.
        for (a, b) in rounded.ranks().iter().zip(tt.ranks()) {
            assert!(a <= b);
        }
    }

    #[test]
    fn rounding_shrinks_inflated_ranks() {
        // Build a rank-2 tensor but represent it with rank-5 cores by
        // zero-padding: rounding must find the true rank 2.
        let mut rng = Rng::new(2);
        let small = TTensor::<f64>::rand_uniform(&[4, 4, 4], &[2, 2], &mut rng).unwrap();
        let full = small.reconstruct();
        // Re-decompose at inflated fixed ranks via TT-SVD.
        let fat = crate::baselines::ttsvd::tt_svd_fixed(&full, &[4, 4]).unwrap();
        assert_eq!(fat.ranks(), &[1, 4, 4, 1]);
        let rounded = tt_round(&fat, 1e-8).unwrap();
        assert_eq!(rounded.ranks(), &[1, 2, 2, 1], "ranks {:?}", rounded.ranks());
        assert!(rounded.rel_error(&full) < 1e-7);
    }

    #[test]
    fn eps_controls_rounding_error() {
        let mut rng = Rng::new(3);
        let tt = TTensor::<f64>::rand_uniform(&[6, 6, 6], &[4, 4], &mut rng).unwrap();
        let full = tt.reconstruct();
        let loose = tt_round(&tt, 0.2).unwrap();
        let tight = tt_round(&tt, 1e-10).unwrap();
        assert!(loose.num_params() <= tight.num_params());
        assert!(tight.rel_error(&full) <= loose.rel_error(&full) + 1e-12);
        // Oseledets bound: per-stage eps ⇒ total ≤ sqrt(d-1)·eps.
        assert!(loose.rel_error(&full) <= 0.2 * (2.0f64).sqrt() + 1e-9);
    }

    #[test]
    fn two_mode_round() {
        let mut rng = Rng::new(4);
        let tt = TTensor::<f64>::rand_uniform(&[8, 9], &[5], &mut rng).unwrap();
        let full = tt.reconstruct();
        let r = tt_round(&tt, 1e-10).unwrap();
        assert!(r.rel_error(&full) < 1e-8);
    }
}
