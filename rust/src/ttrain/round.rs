//! TT-rounding (recompression) — Oseledets' Algorithm 2 (2011).
//!
//! After an nTT sweep the ranks chosen per stage can be loose (e.g. when
//! the NMF residual inflated a later stage's SVD selection, or when fixed
//! ranks were conservative). Rounding re-orthogonalizes the train
//! right-to-left with QR and then truncates left-to-right with SVD at a
//! prescribed tolerance, producing the (near-)optimal ranks for the tensor
//! *represented by the train* without ever densifying it.
//!
//! Note: rounding is an SVD procedure, so non-negativity of cores is NOT
//! preserved — the paper leaves non-negative rank reduction as future work;
//! we expose rounding for the TT-SVD baseline and for storage-oriented use
//! where signs are acceptable (documented at the call site).

use crate::error::Result;
use crate::linalg::gemm::matmul;
use crate::linalg::qr::thin_qr;
use crate::linalg::svd::{rank_for_eps, thin_svd};
use crate::linalg::Mat;
use crate::tensor::TTensor;

/// Recompress `tt` to relative tolerance `eps` (per-stage threshold, as in
/// the decomposition sweep). Returns a new train with ranks ≤ the input's.
pub fn tt_round(tt: &TTensor<f64>, eps: f64) -> Result<TTensor<f64>> {
    let d = tt.dims().len();
    if d == 1 {
        return TTensor::new(tt.dims().to_vec(), tt.cores().to_vec());
    }
    let dims = tt.dims().to_vec();
    let in_ranks = tt.ranks().to_vec();

    // --- Right-to-left orthogonalization: make cores 2..d right-orthogonal,
    // accumulating the non-orthogonal part into the previous core.
    // Core i is stored (r_{i-1}·n_i) × r_i; for right-orthogonalization we
    // work with its r_{i-1} × (n_i·r_i) view and QR its transpose.
    let mut cores: Vec<Mat<f64>> = tt.cores().to_vec();
    let mut ranks = in_ranks.clone();
    for i in (1..d).rev() {
        let r_prev = ranks[i];
        let r_next = ranks[i + 1];
        // View core i as r_prev × (n_i · r_next).
        let ci = cores[i].clone().reshaped(r_prev, dims[i] * r_next);
        // QR of the transpose: ciᵀ = Q R  ⇒  ci = Rᵀ Qᵀ with Qᵀ row-orthogonal.
        let qr = thin_qr(&ci.transpose());
        let k = qr.q.cols(); // = min(r_prev, n_i·r_next)
        // New core i = Qᵀ reshaped to (k·n_i) × r_next.
        cores[i] = qr.q.transpose().reshaped(k * dims[i], r_next);
        // Fold Rᵀ (r_prev × k) into core i-1: (r_{i-2}·n_{i-1}) × r_prev · Rᵀ.
        let rt = qr.r.transpose();
        cores[i - 1] = matmul(&cores[i - 1], &rt);
        ranks[i] = k;
    }

    // --- Left-to-right truncation sweep.
    for i in 0..d - 1 {
        let rows = ranks[i] * dims[i];
        let ci = cores[i].clone().reshaped(rows, ranks[i + 1]);
        let svd = thin_svd(&ci);
        let r_new = rank_for_eps(&svd.s, eps).min(svd.s.len()).max(1);
        let tr = svd.truncate(r_new);
        cores[i] = tr.u.clone();
        // Carry Σ Vᵀ into the next core: (r_new × r_old) · core_{i+1}-view.
        let mut sv = tr.vt.clone();
        for c in 0..r_new {
            let s = tr.s[c];
            for v in sv.row_mut(c) {
                *v *= s;
            }
        }
        // core_{i+1} viewed r_old × (n_{i+1}·r_{i+2}).
        let next = cores[i + 1].clone().reshaped(ranks[i + 1], dims[i + 1] * ranks[i + 2]);
        let folded = matmul(&sv, &next); // r_new × (n·r)
        cores[i + 1] = folded.reshaped(r_new * dims[i + 1], ranks[i + 2]);
        ranks[i + 1] = r_new;
    }

    TTensor::new(dims, cores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rounding_is_lossless_at_zero_eps() {
        let mut rng = Rng::new(1);
        let tt = TTensor::<f64>::rand_uniform(&[4, 5, 3], &[2, 2], &mut rng).unwrap();
        let full = tt.reconstruct();
        let rounded = tt_round(&tt, 1e-12).unwrap();
        assert!(rounded.rel_error(&full) < 1e-9);
        // Ranks cannot grow.
        for (a, b) in rounded.ranks().iter().zip(tt.ranks()) {
            assert!(a <= b);
        }
    }

    #[test]
    fn rounding_shrinks_inflated_ranks() {
        // Build a rank-2 tensor but represent it with rank-5 cores by
        // zero-padding: rounding must find the true rank 2.
        let mut rng = Rng::new(2);
        let small = TTensor::<f64>::rand_uniform(&[4, 4, 4], &[2, 2], &mut rng).unwrap();
        let full = small.reconstruct();
        // Re-decompose at inflated fixed ranks via TT-SVD.
        let fat = crate::baselines::ttsvd::tt_svd_fixed(&full, &[4, 4]).unwrap();
        assert_eq!(fat.ranks(), &[1, 4, 4, 1]);
        let rounded = tt_round(&fat, 1e-8).unwrap();
        assert_eq!(rounded.ranks(), &[1, 2, 2, 1], "ranks {:?}", rounded.ranks());
        assert!(rounded.rel_error(&full) < 1e-7);
    }

    #[test]
    fn eps_controls_rounding_error() {
        let mut rng = Rng::new(3);
        let tt = TTensor::<f64>::rand_uniform(&[6, 6, 6], &[4, 4], &mut rng).unwrap();
        let full = tt.reconstruct();
        let loose = tt_round(&tt, 0.2).unwrap();
        let tight = tt_round(&tt, 1e-10).unwrap();
        assert!(loose.num_params() <= tight.num_params());
        assert!(tight.rel_error(&full) <= loose.rel_error(&full) + 1e-12);
        // Oseledets bound: per-stage eps ⇒ total ≤ sqrt(d-1)·eps.
        assert!(loose.rel_error(&full) <= 0.2 * (2.0f64).sqrt() + 1e-9);
    }

    #[test]
    fn two_mode_round() {
        let mut rng = Rng::new(4);
        let tt = TTensor::<f64>::rand_uniform(&[8, 9], &[5], &mut rng).unwrap();
        let full = tt.reconstruct();
        let r = tt_round(&tt, 1e-10).unwrap();
        assert!(r.rel_error(&full) < 1e-8);
    }
}
