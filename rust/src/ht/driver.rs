//! The distributed non-negative hierarchical Tucker driver.
//!
//! Processes the balanced [`DimTree`] level-by-level (BFS node order —
//! SPMD-deterministic on every rank). Each tree node `t` owns a
//! distributed matrix `V_t: n_{S_t} × r_t` (the root owns the input
//! tensor, `r = 1`); an interior node runs **two** stages:
//!
//! 1. **left edge** — [`dist_reshape`] the node array into
//!    `M1: n_left × (n_right·r_t)` on the 2-D grid, select the edge rank
//!    with the distributed ε-threshold SVD, factorize `M1 ≈ W1·H1` with
//!    the distributed NMF; `W1` (kept distributed under
//!    [`Layout::WGrid`]) becomes the left child's array;
//! 2. **right edge** — reshape `H1` through [`Layout::HtPermuted`] into
//!    `M2: n_right × (r1·r_t)`, select, factorize `M2 ≈ W2·H2`; `W2`
//!    becomes the right child's array and the small `H2` is gathered on
//!    every rank as the node's transfer tensor.
//!
//! Leaves gather their `n_i × r_t` array as the leaf factor. The result
//! is an [`HtTensor`] identical on every rank, with per-tree-node stage
//! records and the same critical-path cost breakdown the TT driver
//! reports.
//!
//! Out-of-core jobs need no special handling here: every reshape above
//! goes through [`dist_reshape_x`], which — when the [`SharedStore`]
//! carries a memory budget — streams the source chunks in bounded
//! batches and maps spilled chunks instead of loading them, bitwise
//! identically to the resident path (DESIGN.md §2.12). The driver only
//! ever sees the assembled stage matrix.

use crate::dist::checkpoint::{self, CkptCtx};
use crate::dist::{
    dist_reshape, dist_reshape_x, Comm, Grid2d, Layout, ProcGrid, SharedStore, TensorBlock,
};
use crate::error::{DnttError, Result};
use crate::linalg::{DenseOrSparse, KernelCfg, Mat};
use crate::nmf::{dist_nmf_pruned_x_obs_ws, IterObserver, NmfConfig, NmfStats, NmfWorkspace};
use crate::runtime::backend::ComputeBackend;
use crate::tensor::ht::{DimTree, HtNode, HtTensor};
use crate::ttrain::rankselect::{dist_rank_select, RankSelectConfig};
use crate::util::timer::{Breakdown, Cat};
use std::sync::Arc;
use std::time::Instant;

/// Hierarchical-Tucker decomposition parameters.
#[derive(Clone, Debug)]
pub struct HtConfig {
    /// Per-stage relative-error threshold ε for rank selection.
    pub eps: f64,
    /// Fixed edge ranks (skips the SVD): two per interior node in BFS
    /// node order — left edge then right edge. Length must be `2(d−1)`.
    pub fixed_ranks: Option<Vec<usize>>,
    /// NMF settings (`rank` is overridden per stage).
    pub nmf: NmfConfig,
    /// Rank-selection settings (`eps` is overridden from `self.eps`).
    pub rank_select: RankSelectConfig,
    /// Prune all-zero rows/columns of each stage matrix before the NMF
    /// (see [`crate::nmf::dist_nmf_pruned`]).
    pub prune: bool,
}

impl Default for HtConfig {
    fn default() -> Self {
        HtConfig {
            eps: 0.01,
            fixed_ranks: None,
            nmf: NmfConfig::default(),
            rank_select: RankSelectConfig::default(),
            prune: false,
        }
    }
}

/// Record of one per-node NMF stage (two per interior tree node).
#[derive(Clone, Debug)]
pub struct HtStageStats {
    /// Interior tree-node id (BFS order of [`DimTree::balanced`]).
    pub node: usize,
    /// Mode range `[lo, hi)` the node covers.
    pub modes: (usize, usize),
    /// `true` for the left-edge stage (`M1`), `false` for the right
    /// (`M2`).
    pub left: bool,
    /// Stage matricization shape.
    pub m: usize,
    pub n: usize,
    /// Selected (or fixed) edge rank.
    pub rank: usize,
    /// `sqrt(tail/total)` the SVD heuristic achieved (NaN when fixed).
    pub svd_eps: f64,
    /// NMF convergence record.
    pub nmf: NmfStats,
    /// Wall seconds of this stage on this rank (reshape + select + NMF).
    pub secs: f64,
}

/// Decomposition result (identical on every rank).
pub struct HtOutput {
    pub ht: HtTensor<f64>,
    /// Per-tree-node stage records, BFS node order (left edge first).
    pub stages: Vec<HtStageStats>,
    /// Critical-path (max-over-ranks) cost breakdown.
    pub breakdown: Breakdown,
}

/// Publish-gather a distributed array on every rank (the HT analogue of
/// the TT driver's final core gather).
fn gather_full(
    world: &mut Comm,
    store: &SharedStore,
    name: &str,
    layout: &Layout,
    my_chunk: TensorBlock,
) -> Result<Vec<f64>> {
    let rank = world.rank();
    let t0 = Instant::now();
    if let Err(e) = store.publish_block(name, layout, rank, my_chunk) {
        world.abort(&format!("{name}: publish failed: {e}"));
        return Err(e);
    }
    world.breakdown.add_secs(Cat::Io, t0.elapsed().as_secs_f64());
    world.barrier();
    let view = store.view(name)?;
    let t1 = Instant::now();
    let full = view.to_dense();
    world.breakdown.add_secs(Cat::Reshape, t1.elapsed().as_secs_f64());
    world.breakdown.add_bytes(Cat::Io, view.disk_bytes_read());
    drop(view);
    world.barrier();
    if rank == 0 {
        store.remove(name);
    }
    world.barrier();
    Ok(full)
}

/// Run the distributed nHT on this rank (collective).
///
/// * `my_block` — this rank's chunk of the input tensor under
///   `Layout::TensorGrid { dims, grid: proc_grid.dims() }`.
/// * `grid` — the 2-D NMF grid (must satisfy `grid.size() == world.size()`
///   and be the collapse of `proc_grid`).
/// * `ckpt` — optional checkpoint context
///   ([`crate::dist::checkpoint::CkptCtx`]): snapshot the tree-walk state
///   after every N nodes, and resume (skipping resolved nodes) when a
///   valid `dntt-ckpt-v1` manifest exists.
/// * `kernel` — GEMM/SpMM kernel selection (SIMD path + intra-rank
///   threads) pinned to this rank's workspace; bitwise-neutral. Pass
///   [`KernelCfg::default`] for the env-aware auto choice.
#[allow(clippy::too_many_arguments)]
pub fn dist_nht(
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    store: &Arc<SharedStore>,
    proc_grid: &ProcGrid,
    grid: Grid2d,
    dims: &[usize],
    my_block: TensorBlock,
    backend: &dyn ComputeBackend,
    cfg: &HtConfig,
    kernel: KernelCfg,
    ckpt: Option<&CkptCtx>,
) -> Result<HtOutput> {
    let d = dims.len();
    if d < 2 {
        return Err(DnttError::shape("hierarchical Tucker needs at least 2 modes"));
    }
    if grid.size() != world.size() {
        return Err(DnttError::Comm("grid size != world size".into()));
    }
    let tree = DimTree::balanced(d);
    let n_edges = 2 * tree.num_interior();
    if let Some(fr) = &cfg.fixed_ranks {
        if fr.len() != n_edges {
            return Err(DnttError::config(format!(
                "fixed_ranks needs {n_edges} entries (two per interior node), got {}",
                fr.len()
            )));
        }
    }

    // Per-node pending array: (layout of the distributed V_t, this rank's
    // chunk, parent edge rank r_t). BFS ids guarantee a parent resolves
    // before its children are reached. Only the root chunk can be sparse
    // (children receive dense NMF factors).
    let mut pending: Vec<Option<(Layout, TensorBlock, usize)>> =
        (0..tree.len()).map(|_| None).collect();
    pending[0] = Some((
        Layout::TensorGrid { dims: dims.to_vec(), grid: proc_grid.dims().to_vec() },
        my_block,
        1,
    ));
    let mut payload: Vec<Option<HtNode<f64>>> = (0..tree.len()).map(|_| None).collect();
    let mut stages: Vec<HtStageStats> = Vec::with_capacity(n_edges);
    let mut start_node = 0usize;
    // Resume: rehydrate the tree-walk state (resolved payloads + pending
    // child arrays) from the last durable snapshot and skip the completed
    // nodes. A missing manifest means a fresh start.
    if let Some(cx) = ckpt {
        if cx.resume {
            if let Some(res) =
                checkpoint::load_ht(cx, world.rank(), world.size(), dims, grid, tree.len())?
            {
                payload = res.payload;
                pending = res.pending;
                stages = res.stages;
                start_node = res.nodes_done;
                log::info!(
                    "resuming HT tree walk from checkpoint: {start_node}/{} nodes done",
                    tree.len()
                );
            }
        }
    }
    // Cursor into fixed_ranks (2 per interior node); on resume, advance
    // past the interior nodes already resolved.
    let mut edge = 2 * (0..start_node).filter(|&t| !tree.is_leaf(t)).count();
    // One workspace per rank, shared by every per-edge NMF of the tree
    // walk (left and right stages alike) — zero allocation once warm.
    // The kernel selection is pinned here and rides the workspace.
    let mut ws = NmfWorkspace::with_kernel(kernel);

    for t in start_node..tree.len() {
        let (layout, data, rt) = pending[t].take().expect("BFS processing order");
        let node = tree.node(t);
        match node.children {
            None => {
                // Leaf: the array *is* the factor U: n_i × r_t.
                let span = crate::obs::span_begin();
                let n_i = dims[node.lo];
                let full = gather_full(world, store, &format!("ht.leaf{t}"), &layout, data)?;
                payload[t] = Some(HtNode::Leaf(Mat::from_vec(n_i, rt, full)));
                crate::obs::end_stage(span, &format!("ht.leaf{t}"));
            }
            Some((lc, rc)) => {
                let mid = tree.node(lc).hi;
                let n1: usize = dims[node.lo..mid].iter().product();
                let n2: usize = dims[mid..node.hi].iter().product();

                // --- Left edge: M1 = n1 × (n2·rt) ≈ W1·H1. The block may
                // arrive sparse at the root; the reshape keeps it sparse
                // when the global density clears the cutoff.
                let span = crate::obs::span_begin();
                let t0 = Instant::now();
                let x1 = dist_reshape_x(
                    world, store, &format!("ht.n{t}.a"), &layout, data, n1, n2 * rt, grid,
                )?;
                let (r1, eps1) = match &cfg.fixed_ranks {
                    Some(fr) => (fr[edge].max(1), f64::NAN),
                    None => {
                        // The SVD has no sparse path: densify locally for
                        // rank selection only.
                        let xd = x1.dense_view();
                        let rs = RankSelectConfig { eps: cfg.eps, ..cfg.rank_select.clone() };
                        let sel =
                            dist_rank_select(&xd, n1, n2 * rt, grid, world, row, col, &rs)?;
                        (sel.rank, sel.achieved_eps)
                    }
                };
                let cfg1 = NmfConfig {
                    rank: r1,
                    seed: cfg.nmf.seed.wrapping_add(2 * t as u64),
                    ..cfg.nmf.clone()
                };
                let mut obs1 = ckpt.and_then(|cx| cx.iter_ckpt(world.rank(), &format!("n{t}a")));
                let o1 = dist_nmf_pruned_x_obs_ws(
                    &x1, n1, n2 * rt, grid, world, row, col, backend, &cfg1,
                    store, &format!("ht.n{t}.a"), cfg.prune, &mut ws,
                    obs1.as_mut().map(|o| o as &mut dyn IterObserver),
                )?;
                stages.push(HtStageStats {
                    node: t,
                    modes: (node.lo, node.hi),
                    left: true,
                    m: n1,
                    n: n2 * rt,
                    rank: r1,
                    svd_eps: eps1,
                    nmf: o1.stats.clone(),
                    secs: t0.elapsed().as_secs_f64(),
                });
                pending[lc] = Some((
                    Layout::WGrid { m: n1, r: r1, pr: grid.pr, pc: grid.pc },
                    TensorBlock::Dense(o1.w.into_vec()),
                    r1,
                ));
                crate::obs::end_stage(span, &format!("ht.n{t}.a"));

                // --- Right edge: M2 = permuted H1 = n2 × (r1·rt) ≈ W2·H2.
                let span = crate::obs::span_begin();
                let t0 = Instant::now();
                let perm = Layout::HtPermuted { r: r1, n2, rt, pr: grid.pr, pc: grid.pc };
                let x2 = dist_reshape(
                    world, store, &format!("ht.n{t}.b"), &perm, o1.ht.into_vec(), n2,
                    r1 * rt, grid,
                )?;
                let (r2, eps2) = match &cfg.fixed_ranks {
                    Some(fr) => (fr[edge + 1].max(1), f64::NAN),
                    None => {
                        let rs = RankSelectConfig { eps: cfg.eps, ..cfg.rank_select.clone() };
                        let sel =
                            dist_rank_select(&x2, n2, r1 * rt, grid, world, row, col, &rs)?;
                        (sel.rank, sel.achieved_eps)
                    }
                };
                let cfg2 = NmfConfig {
                    rank: r2,
                    seed: cfg.nmf.seed.wrapping_add(2 * t as u64 + 1),
                    ..cfg.nmf.clone()
                };
                let x2 = DenseOrSparse::Dense(x2);
                let mut obs2 = ckpt.and_then(|cx| cx.iter_ckpt(world.rank(), &format!("n{t}b")));
                let o2 = dist_nmf_pruned_x_obs_ws(
                    &x2, n2, r1 * rt, grid, world, row, col, backend, &cfg2,
                    store, &format!("ht.n{t}.b"), cfg.prune, &mut ws,
                    obs2.as_mut().map(|o| o as &mut dyn IterObserver),
                )?;
                stages.push(HtStageStats {
                    node: t,
                    modes: (node.lo, node.hi),
                    left: false,
                    m: n2,
                    n: r1 * rt,
                    rank: r2,
                    svd_eps: eps2,
                    nmf: o2.stats.clone(),
                    secs: t0.elapsed().as_secs_f64(),
                });
                pending[rc] = Some((
                    Layout::WGrid { m: n2, r: r2, pr: grid.pr, pc: grid.pc },
                    TensorBlock::Dense(o2.w.into_vec()),
                    r2,
                ));

                // --- Transfer tensor: gather the small H2 everywhere.
                let blay = Layout::HtGrid { r: r2, n: r1 * rt, pr: grid.pr, pc: grid.pc };
                let bfull = gather_full(
                    world,
                    store,
                    &format!("ht.n{t}.t"),
                    &blay,
                    TensorBlock::Dense(o2.ht.into_vec()),
                )?;
                payload[t] = Some(HtNode::Transfer(Mat::from_vec(r2, r1 * rt, bfull)));
                crate::obs::end_stage(span, &format!("ht.n{t}.b"));
                edge += 2;
            }
        }

        // Node-boundary snapshot: resolved payloads + the pending child
        // arrays are durable before the next node starts.
        if let Some(cx) = ckpt {
            if cx.stage_due(t + 1) {
                checkpoint::save_ht_node(
                    world, cx, t + 1, &payload, &pending, &stages, dims, grid,
                )?;
            }
        }
    }

    // Merge sub-communicator costs, then take the critical path over ranks.
    world.breakdown.merge_sum(&row.breakdown.clone());
    world.breakdown.merge_sum(&col.breakdown.clone());
    let all = world.all_gather_any(world.breakdown.clone());
    let mut merged = Breakdown::new();
    for b in &all {
        merged.merge_max(b);
    }

    let nodes: Vec<HtNode<f64>> =
        payload.into_iter().map(|p| p.expect("every node resolved")).collect();
    Ok(HtOutput { ht: HtTensor::new(dims.to_vec(), tree, nodes)?, stages, breakdown: merged })
}

/// Convenience wrapper: decompose a replicated dense tensor on `p` thread
/// ranks arranged as `proc_grid` (tests, examples, small data).
pub fn nht_on_threads(
    tensor: &crate::tensor::DenseTensor<f64>,
    proc_grid: &ProcGrid,
    cfg: &HtConfig,
) -> Result<HtOutput> {
    use crate::dist::chunkstore::SpillMode;
    let dims = tensor.dims().to_vec();
    let grid = proc_grid.to_2d();
    let store = SharedStore::new(SpillMode::Memory);
    let pg = proc_grid.clone();
    let cfg = cfg.clone();
    let tensor = tensor.clone();
    let mut outs = Comm::run(proc_grid.size(), move |mut world| {
        let my = crate::ttrain::driver::extract_block(&tensor, &pg, world.rank());
        let (mut row, mut col) = grid.make_subcomms(&mut world);
        dist_nht(
            &mut world,
            &mut row,
            &mut col,
            &store,
            &pg,
            grid,
            &dims,
            TensorBlock::Dense(my),
            &crate::runtime::native::NativeBackend,
            &cfg,
            KernelCfg::default(),
            None,
        )
    });
    outs.swap_remove(0)
}

/// Serial (single-rank) nHT — the reference implementation the
/// equivalence tests compare the distributed runs against.
pub fn ht_serial(
    tensor: &crate::tensor::DenseTensor<f64>,
    cfg: &HtConfig,
) -> Result<HtOutput> {
    let grid = ProcGrid::new(vec![1; tensor.ndim()])?;
    nht_on_threads(tensor, &grid, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ht::datagen::SyntheticHt;

    fn cfg_iters(iters: usize) -> HtConfig {
        HtConfig {
            eps: 1e-6,
            nmf: NmfConfig { max_iters: iters, tol: 1e-12, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn recovers_ranks_and_reconstructs_serial() {
        let syn = SyntheticHt::new(vec![4, 5, 6], 2, 11);
        let t = syn.dense();
        let out = ht_serial(&t, &cfg_iters(400)).unwrap();
        assert!(out.ht.is_nonneg());
        // d = 3: tree is root{0..3} -> ({0..2} -> leaf0, leaf1; leaf2),
        // two interior nodes, four stages.
        assert_eq!(out.ht.tree().len(), 5);
        assert_eq!(out.stages.len(), 4);
        let err = out.ht.rel_error(&t);
        assert!(err < 0.05, "rel err {err}");
    }

    #[test]
    fn fixed_ranks_skip_svd_and_set_edges() {
        let syn = SyntheticHt::new(vec![4, 4, 4], 2, 17);
        let t = syn.dense();
        let mut cfg = cfg_iters(120);
        cfg.fixed_ranks = Some(vec![2; 4]);
        let out = ht_serial(&t, &cfg).unwrap();
        assert!(out.stages.iter().all(|s| s.svd_eps.is_nan()));
        assert_eq!(out.ht.ranks()[0], 1);
        assert!(out.ht.ranks()[1..].iter().all(|&r| r == 2));
    }

    #[test]
    fn stage_shapes_follow_the_tree() {
        // dims [3,4,5,6], fixed edge ranks 2: root M1 = 12×30, M2 = 30×2;
        // node [0,2) (rt=2): 3×8, 4×4; node [2,4) (rt=2): 5×12, 6×4.
        let syn = SyntheticHt::new(vec![3, 4, 5, 6], 2, 19);
        let t = syn.dense();
        let mut cfg = cfg_iters(60);
        cfg.fixed_ranks = Some(vec![2; 6]);
        let out = ht_serial(&t, &cfg).unwrap();
        let shapes: Vec<(usize, usize, bool)> =
            out.stages.iter().map(|s| (s.m, s.n, s.left)).collect();
        assert_eq!(
            shapes,
            vec![
                (12, 30, true),
                (30, 2, false),
                (3, 8, true),
                (4, 4, false),
                (5, 12, true),
                (6, 4, false),
            ]
        );
        assert_eq!(out.stages[2].node, 1);
        assert_eq!(out.stages[4].modes, (2, 4));
    }

    #[test]
    fn distributed_matches_serial() {
        let syn = SyntheticHt::new(vec![4, 4, 6], 2, 13);
        let t = syn.dense();
        let serial = ht_serial(&t, &cfg_iters(150)).unwrap();
        let grid = ProcGrid::new(vec![2, 1, 2]).unwrap();
        let dist = nht_on_threads(&t, &grid, &cfg_iters(150)).unwrap();
        assert_eq!(serial.ht.ranks(), dist.ht.ranks());
        // Same deterministic init ⇒ same node matrices up to reduction
        // roundoff.
        for (a, b) in serial.ht.nodes().iter().zip(dist.ht.nodes()) {
            for (x, y) in a.mat().as_slice().iter().zip(b.mat().as_slice()) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn breakdown_populated() {
        let syn = SyntheticHt::new(vec![4, 4, 4], 2, 23);
        let t = syn.dense();
        let grid = ProcGrid::new(vec![2, 2, 1]).unwrap();
        let out = nht_on_threads(&t, &grid, &cfg_iters(20)).unwrap();
        let b = &out.breakdown;
        assert!(b.secs(Cat::MatMul) > 0.0);
        assert!(b.calls(Cat::AllReduce) > 0);
        assert!(b.calls(Cat::AllGather) > 0);
        assert!(b.calls(Cat::ReduceScatter) > 0);
        assert!(b.secs(Cat::Reshape) > 0.0);
    }

    #[test]
    fn rejects_bad_config() {
        let syn = SyntheticHt::new(vec![4, 4, 4], 2, 31);
        let t = syn.dense();
        let mut cfg = cfg_iters(5);
        cfg.fixed_ranks = Some(vec![2; 3]); // needs 2·(d−1) = 4
        assert!(ht_serial(&t, &cfg).is_err());
        // Single-mode tensors have no tree to split.
        let one = crate::tensor::DenseTensor::<f64>::zeros(&[5]);
        assert!(ht_serial(&one, &cfg_iters(5)).is_err());
    }
}
