//! Synthetic hierarchical-Tucker workload generation.
//!
//! A ground-truth [`HtTensor`] with prescribed dims and a uniform edge
//! rank is sampled with uniform [0,1) node matrices and the full tensor
//! is its contraction — the HT analogue of
//! [`crate::ttrain::SyntheticTt`]. Every matricization the HT sweep
//! factorizes then has exact non-negative rank ≤ the generator rank, so
//! the ε-threshold rank selection and the NMF can recover the network.

use crate::tensor::{DenseTensor, HtTensor};
use crate::util::rng::Rng;

/// Ground-truth description of a synthetic HT tensor.
#[derive(Clone, Debug)]
pub struct SyntheticHt {
    pub dims: Vec<usize>,
    /// Uniform non-root edge rank.
    pub rank: usize,
    pub seed: u64,
}

impl SyntheticHt {
    pub fn new(dims: Vec<usize>, rank: usize, seed: u64) -> Self {
        assert!(dims.len() >= 2, "SyntheticHt needs at least 2 modes");
        assert!(rank >= 1, "SyntheticHt rank must be ≥ 1");
        SyntheticHt { dims, rank, seed }
    }

    /// Generate the ground-truth HT (node matrices only; cheap).
    pub fn ground_truth(&self) -> HtTensor<f64> {
        let mut rng = Rng::new(self.seed);
        HtTensor::rand_uniform(&self.dims, self.rank, &mut rng).expect("synthetic HT")
    }

    /// Full dense tensor (small cases / tests).
    pub fn dense(&self) -> DenseTensor<f64> {
        self.ground_truth().reconstruct()
    }

    /// Total elements.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Size in bytes at f64.
    pub fn nbytes(&self) -> usize {
        self.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_nonneg() {
        let syn = SyntheticHt::new(vec![4, 4, 4], 2, 99);
        assert_eq!(syn.dense().as_slice(), syn.dense().as_slice());
        assert!(syn.dense().is_nonneg());
        assert_eq!(syn.len(), 64);
        assert_eq!(syn.nbytes(), 512);
    }

    #[test]
    fn ground_truth_ranks_are_uniform() {
        let syn = SyntheticHt::new(vec![3, 4, 5, 6], 3, 7);
        let ht = syn.ground_truth();
        assert_eq!(ht.ranks()[0], 1);
        assert!(ht.ranks()[1..].iter().all(|&r| r == 3));
        assert_eq!(ht.reconstruct().dims(), &[3, 4, 5, 6]);
    }
}
