//! Distributed non-negative **Hierarchical Tucker** decomposition — the
//! second tensor network of the pyDNTNK family, alongside the tensor
//! train (`crate::ttrain`).
//!
//! HT organizes the modes in a balanced binary dimension tree
//! ([`crate::tensor::DimTree`]) and factorizes the tensor level-by-level
//! down the tree, one distributed NMF per tree edge, reusing the whole
//! SPMD substrate: [`crate::dist::dist_reshape`] (with the
//! [`crate::dist::Layout::WGrid`] / [`crate::dist::Layout::HtPermuted`]
//! hand-off layouts) for the per-level matricizations,
//! [`crate::ttrain::dist_rank_select`] for the ε-threshold edge-rank
//! estimation, and [`crate::nmf::dist_nmf`] (BCD/MU/HALS, optionally
//! zero-row/column pruned) for the non-negative factor updates. The
//! output [`HtTensor`] stores leaf factors and per-node transfer
//! tensors; see `rust/DESIGN.md` §2.6 for the full contract.

pub mod datagen;
pub mod driver;

pub use crate::tensor::ht::{DimTree, HtNode, HtTensor};
pub use datagen::SyntheticHt;
pub use driver::{dist_nht, ht_serial, nht_on_threads, HtConfig, HtOutput, HtStageStats};
