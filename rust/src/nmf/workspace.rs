//! Reusable per-rank workspace for the distributed NMF hot loop.
//!
//! Every multiplicative-update / BCD / HALS iteration of
//! [`crate::nmf::dist::dist_nmf_ws`] runs its local compute — the packed
//! GEMMs, the Gram products, the update rules, and the gathered-factor
//! staging — entirely inside one [`NmfWorkspace`]. Buffers are resized in
//! place ([`Mat::reset`]), so after the first iteration warms them up to
//! their high-water sizes the compute path performs **zero heap
//! allocation**. The communicator's internal channel buffers are the one
//! deliberate exception (see DESIGN.md §Workspace contract).
//!
//! The TT and HT drivers allocate one workspace per rank and thread it
//! through every stage NMF, so buffer capacity is shared across stages
//! (sized by the largest stage matrix seen so far).
//!
//! Reuse never changes results: every buffer is fully overwritten before
//! it is read, so a warm workspace is bitwise identical to a fresh one
//! (asserted in `tests/gemm_kernels.rs`).

use crate::linalg::simd::KernelCfg;
use crate::linalg::Mat;
use crate::runtime::backend::KernelWorkspace;

/// Scratch buffers threaded through one distributed NMF (and reused
/// across the stage NMFs of a TT/HT decomposition).
#[derive(Default)]
pub struct NmfWorkspace {
    /// Backend kernel scratch: GEMM packing panels + the `F·G` temporary.
    pub kernel: KernelWorkspace,
    /// Gathered factor staging (`Ht^(j)` / `W^(i)` concatenated in rank
    /// order before the local GEMM).
    pub gathered: Mat<f64>,
    /// Local GEMM product (`X·Ht` / `Xᵀ·W`) fed to the reduce-scatter.
    pub prod: Mat<f64>,
    /// Per-column L1 sums for the W-normalization step (`r` entries).
    pub colsums: Vec<f64>,
}

impl NmfWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Workspace whose GEMM/SpMM calls run an explicit kernel selection
    /// (SIMD path + intra-rank threads). `new()` keeps the env-aware
    /// default (auto path, 1 thread). Selection is bitwise-neutral, so a
    /// warm workspace re-pinned to another path stays bitwise identical.
    pub fn with_kernel(sel: KernelCfg) -> Self {
        let mut ws = Self::default();
        ws.kernel.gemm.set_kernel(sel);
        ws
    }

    /// Kernel selection threaded through the backend calls.
    pub fn kernel_sel(&self) -> KernelCfg {
        self.kernel.gemm.kernel()
    }

    /// Bytes currently reserved across all buffers (diagnostic).
    pub fn capacity_bytes(&self) -> usize {
        self.kernel.gemm.capacity_bytes()
            + 8 * (self.kernel.fg.len()
                + self.gathered.len()
                + self.prod.len()
                + self.colsums.capacity())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_grows_with_use() {
        let mut ws = NmfWorkspace::new();
        assert_eq!(ws.capacity_bytes(), 0);
        ws.gathered.reset(10, 4);
        ws.colsums.resize(4, 0.0);
        assert!(ws.capacity_bytes() >= 8 * (40 + 4));
    }
}
