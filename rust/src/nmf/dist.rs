//! The SPMD distributed-NMF engine (Algs 3–6).
//!
//! Every rank `(i,j)` of the `p_r × p_c` grid holds:
//! * `X^(i,j)` — its block of `X` (`m_i × n_j`, uneven blocks allowed);
//! * `(Wⁱ)ʲ`  — rows `j`-th sub-block of `W^(i)` (`mw × r`);
//! * `(Hʲ)ⁱᵀ` — the transposed `i`-th sub-block of `H^(j)` (`nh × r`).
//!
//! The three global products (Algs 4–6) map to:
//! * Gram:  local `FᵀF` + world all_reduce                       (GR + AR)
//! * X·Hᵀ:  col-comm all_gather(Ht) → local GEMM → row-comm
//!          reduce_scatter                                       (AG+MM+RSC)
//! * Wᵀ·X:  row-comm all_gather(W)  → local GEMM → col-comm
//!          reduce_scatter                                       (AG+MM+RSC)
//!
//! Factor initialization is a pure function of `(seed, global row, column)`
//! so any grid shape produces the *same global factors* — this is what lets
//! tests assert that `p = 1` and `p = 4` runs converge identically.
//!
//! ## Allocation discipline
//!
//! All local compute of the iteration loops goes through a reusable
//! [`NmfWorkspace`] ([`dist_nmf_ws`]): packed-GEMM panels, Gram/product
//! outputs, update temporaries and the gathered-factor staging buffer are
//! resized in place, so after the first iteration the compute path
//! performs no heap allocation. Workspace reuse is bitwise-neutral —
//! every buffer is fully written before it is read.
//!
//! ## Sparse blocks
//!
//! The local `X` block may be stored sparse (CSR,
//! [`crate::linalg::SparseMat`]) — [`dist_nmf_sparse_ws`] /
//! [`dist_nmf_x_ws`] run the identical SPMD protocol with the `X·Hᵀ` and
//! `Xᵀ·W` products dispatched to the zero-allocation SpMM kernels. Only
//! those two products (plus `‖X‖²`) touch `X`, so the factors, comms and
//! update rules are shared verbatim between the dense and sparse paths.

use crate::dist::{BlockDim, Comm, Grid2d};
use crate::error::{DnttError, Result};
use crate::linalg::sparse::SparseMat;
use crate::linalg::{DenseOrSparse, Mat};
use crate::nmf::workspace::NmfWorkspace;
use crate::nmf::{NmfAlgo, NmfConfig, NmfStats};
use crate::runtime::backend::ComputeBackend;
use crate::util::timer::Cat;

/// Borrowed view of this rank's `X` block, dense or sparse — the private
/// dispatch handle threaded through the SPMD loops. The block only ever
/// enters the math through `X·Hᵀ`, `Xᵀ·W` and `‖X‖²`, so these three
/// dispatch points are the entire sparse/dense fork.
#[derive(Clone, Copy)]
pub(crate) enum XRef<'a> {
    Dense(&'a Mat<f64>),
    Sparse(&'a SparseMat),
}

impl XRef<'_> {
    pub(crate) fn rows(&self) -> usize {
        match self {
            XRef::Dense(m) => m.rows(),
            XRef::Sparse(s) => s.rows(),
        }
    }

    pub(crate) fn cols(&self) -> usize {
        match self {
            XRef::Dense(m) => m.cols(),
            XRef::Sparse(s) => s.cols(),
        }
    }

    pub(crate) fn fro_norm_sq(&self) -> f64 {
        match self {
            XRef::Dense(m) => m.fro_norm_sq(),
            XRef::Sparse(s) => s.fro_norm_sq(),
        }
    }
}

/// The [`XRef`] of an owned [`DenseOrSparse`] block.
pub(crate) fn xref_of(x: &DenseOrSparse) -> XRef<'_> {
    match x {
        DenseOrSparse::Dense(m) => XRef::Dense(m),
        DenseOrSparse::Sparse(s) => XRef::Sparse(s),
    }
}

/// Observer invoked at the end of every NMF iteration, on every rank, in
/// SPMD order — the checkpoint subsystem's iteration-granular hook
/// ([`crate::dist::checkpoint::IterCkpt`] persists the in-flight `W`/`H`
/// every N iterations through it).
///
/// Implementations must not communicate (they run inside the iteration
/// loop between collectives) and must swallow their own failures (a
/// rank-divergent error raised here would strand peers mid-collective).
pub trait IterObserver {
    /// `iter` is the 1-based count of completed iterations; `w`/`ht` are
    /// this rank's current factor blocks.
    fn on_iter(&mut self, iter: usize, w: &Mat<f64>, ht: &Mat<f64>);
}

/// Result of a distributed NMF on one rank.
pub struct NmfOutput {
    /// This rank's rows of `W` (`mw × r`).
    pub w: Mat<f64>,
    /// This rank's transposed columns of `H` (`nh × r`).
    pub ht: Mat<f64>,
    /// Global row range of `w` within `W` and column range of `ht` within `H`.
    pub w_rows: (usize, usize),
    pub h_cols: (usize, usize),
    pub stats: NmfStats,
}

/// Deterministic U(0,1) init value for factor entry `(global_row, col)` —
/// identical across any processor grid.
#[inline]
fn init_value(seed: u64, tag: u64, grow: usize, col: usize) -> f64 {
    let mut z = seed ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z ^= (grow as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^= (col as u64).wrapping_mul(0x94D0_49BB_1331_11EB);
    // SplitMix64 finalizer.
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut x = z;
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

fn init_factor(seed: u64, tag: u64, gstart: usize, rows: usize, r: usize) -> Mat<f64> {
    Mat::from_fn(rows, r, |i, c| init_value(seed, tag, gstart + i, c))
}

/// SPMD context: local block + comms + workspace + index arithmetic.
struct Ctx<'a> {
    x: XRef<'a>,
    backend: &'a dyn ComputeBackend,
    world: &'a mut Comm,
    row: &'a mut Comm,
    col: &'a mut Comm,
    ws: &'a mut NmfWorkspace,
    r: usize,
    /// W sub-block sizes across my row comm (per j), in *elements* (rows·r).
    w_counts: Vec<usize>,
    /// H sub-block sizes across my col comm (per i), in *elements*.
    h_counts: Vec<usize>,
}

impl<'a> Ctx<'a> {
    /// Per-path flop counter for the kernel selection this workspace
    /// dispatches through (tiny shapes may still take the scalar blocked
    /// fallback; attribution follows the selected path).
    fn path_ctr(&self) -> crate::obs::Ctr {
        crate::obs::path_ctr(self.ws.kernel.gemm.kernel().path.validated())
    }

    /// Global Gram `FᵀF` of a factor distributed by rows over the world,
    /// into the caller's reused `r × r` buffer.
    fn gram_global_into(&mut self, f: &Mat<f64>, g: &mut Mat<f64>) {
        let t0 = std::time::Instant::now();
        self.backend.gram_into(f, g, &mut self.ws.kernel);
        self.world.breakdown.add_secs(Cat::Gram, t0.elapsed().as_secs_f64());
        let flops = (2 * f.rows() * self.r * self.r) as u64;
        crate::obs::count(crate::obs::Ctr::GemmFlops, flops);
        crate::obs::count(self.path_ctr(), flops);
        self.world.all_reduce_sum(g.as_mut_slice());
    }

    /// Distributed `X·Hᵀ` (Alg 5) into the caller's reused `mw × r`
    /// buffer.
    fn dist_xht_into(&mut self, ht: &Mat<f64>, out: &mut Mat<f64>) -> Result<()> {
        // Gather H^(j) across the column communicator.
        let parts = self.col.all_gather_varied(ht.as_slice());
        let nj: usize = parts.iter().map(|p| p.len()).sum::<usize>() / self.r;
        let pctr = self.path_ctr();
        let ws = &mut *self.ws;
        ws.gathered.resize_for_overwrite(nj, self.r);
        let mut off = 0;
        for p in &parts {
            ws.gathered.as_mut_slice()[off..off + p.len()].copy_from_slice(p);
            off += p.len();
        }
        // Local V = X^(i,j) · Ht^(j) (kernel dispatched per block kind).
        let t0 = std::time::Instant::now();
        match self.x {
            XRef::Dense(x) => {
                self.backend.xht_into(x, &ws.gathered, &mut ws.prod, &mut ws.kernel);
                let flops = (2 * x.rows() * x.cols() * self.r) as u64;
                crate::obs::count(crate::obs::Ctr::GemmFlops, flops);
                crate::obs::count(pctr, flops);
            }
            XRef::Sparse(x) => {
                self.backend.xht_sparse_into(x, &ws.gathered, &mut ws.prod, &mut ws.kernel);
                let flops = (2 * x.nnz() * self.r) as u64;
                crate::obs::count(crate::obs::Ctr::SpmmFlops, flops);
                crate::obs::count(pctr, flops);
            }
        }
        self.world.breakdown.add_secs(Cat::MatMul, t0.elapsed().as_secs_f64());
        // Reduce-scatter across the row communicator into W's distribution.
        let mine = self.row.reduce_scatter_uneven(ws.prod.as_slice(), &self.w_counts)?;
        out.resize_for_overwrite(mine.len() / self.r, self.r);
        out.as_mut_slice().copy_from_slice(&mine);
        Ok(())
    }

    /// Distributed `Wᵀ·X` (Alg 6) into the caller's reused `nh × r`
    /// buffer (the transposed (WᵀX) block).
    fn dist_wtx_into(&mut self, w: &Mat<f64>, out: &mut Mat<f64>) -> Result<()> {
        // Gather W^(i) across the row communicator.
        let parts = self.row.all_gather_varied(w.as_slice());
        let mi: usize = parts.iter().map(|p| p.len()).sum::<usize>() / self.r;
        let pctr = self.path_ctr();
        let ws = &mut *self.ws;
        ws.gathered.resize_for_overwrite(mi, self.r);
        let mut off = 0;
        for p in &parts {
            ws.gathered.as_mut_slice()[off..off + p.len()].copy_from_slice(p);
            off += p.len();
        }
        // Local Y = X^(i,j)ᵀ · W^(i)  (the transposed (WᵀX) block).
        let t0 = std::time::Instant::now();
        match self.x {
            XRef::Dense(x) => {
                self.backend.wtx_into(x, &ws.gathered, &mut ws.prod, &mut ws.kernel);
                let flops = (2 * x.rows() * x.cols() * self.r) as u64;
                crate::obs::count(crate::obs::Ctr::GemmFlops, flops);
                crate::obs::count(pctr, flops);
            }
            XRef::Sparse(x) => {
                self.backend.wtx_sparse_into(x, &ws.gathered, &mut ws.prod, &mut ws.kernel);
                let flops = (2 * x.nnz() * self.r) as u64;
                crate::obs::count(crate::obs::Ctr::SpmmFlops, flops);
                crate::obs::count(pctr, flops);
            }
        }
        self.world.breakdown.add_secs(Cat::MatMul, t0.elapsed().as_secs_f64());
        // Reduce-scatter across the column communicator into H's distribution.
        let mine = self.col.reduce_scatter_uneven(ws.prod.as_slice(), &self.h_counts)?;
        out.resize_for_overwrite(mine.len() / self.r, self.r);
        out.as_mut_slice().copy_from_slice(&mine);
        Ok(())
    }

    /// Global squared Frobenius norm of a row-distributed factor.
    fn global_fro_sq(&mut self, f: &Mat<f64>) -> f64 {
        let t0 = std::time::Instant::now();
        let local = f.fro_norm_sq();
        self.world.breakdown.add_secs(Cat::Norm, t0.elapsed().as_secs_f64());
        self.world.all_reduce_scalar(local)
    }

    /// Objective `½‖X − WH‖²` from cached pieces:
    /// `½(‖X‖² − 2·Σ_b ⟨(XᵀW)_b, Ht_b⟩ + ⟨WᵀW, HHᵀ⟩)`.
    fn objective(&mut self, xtw: &Mat<f64>, ht: &Mat<f64>, wtw: &Mat<f64>, hht: &Mat<f64>, xsq: f64) -> f64 {
        let t0 = std::time::Instant::now();
        let mut cross = 0.0;
        for (a, b) in xtw.as_slice().iter().zip(ht.as_slice()) {
            cross += a * b;
        }
        self.world.breakdown.add_secs(Cat::Norm, t0.elapsed().as_secs_f64());
        let cross = self.world.all_reduce_scalar(cross);
        let mut quad = 0.0;
        for (a, b) in wtw.as_slice().iter().zip(hht.as_slice()) {
            quad += a * b;
        }
        0.5 * (xsq - 2.0 * cross + quad).max(0.0)
    }

    /// Per-column global inverse L1 norms of a row-distributed factor,
    /// written into `ws.colsums` (`1/s`, or `1.0` for vanishing columns).
    fn col_l1_inv(&mut self, f: &Mat<f64>) {
        let t0 = std::time::Instant::now();
        let sums = &mut self.ws.colsums;
        sums.clear();
        sums.resize(self.r, 0.0);
        for i in 0..f.rows() {
            for (c, s) in sums.iter_mut().enumerate() {
                *s += f.row(i)[c].abs();
            }
        }
        self.world.breakdown.add_secs(Cat::Norm, t0.elapsed().as_secs_f64());
        self.world.all_reduce_sum(sums);
        for s in self.ws.colsums.iter_mut() {
            *s = if *s > 1e-300 { 1.0 / *s } else { 1.0 };
        }
    }
}

fn scale_cols(f: &mut Mat<f64>, scale: &[f64]) {
    for i in 0..f.rows() {
        for (c, &s) in scale.iter().enumerate() {
            f.row_mut(i)[c] *= s;
        }
    }
}

/// Run the distributed NMF on this rank with a transient workspace.
/// Collective over `world` (`row`/`col` must be the grid sub-communicators
/// of `world`). `x` is this rank's `m_i × n_j` block of the `m×n` matrix.
#[allow(clippy::too_many_arguments)]
pub fn dist_nmf(
    x: &Mat<f64>,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    backend: &dyn ComputeBackend,
    cfg: &NmfConfig,
) -> Result<NmfOutput> {
    dist_nmf_ws(x, m, n, grid, world, row, col, backend, cfg, &mut NmfWorkspace::new())
}

/// [`dist_nmf`] with a caller-owned [`NmfWorkspace`] — the form the TT/HT
/// drivers use so all stage NMFs share one set of buffers. Results are
/// bitwise identical whether the workspace is fresh or warm.
///
/// ```
/// use dntt::dist::{Comm, Grid2d};
/// use dntt::linalg::Mat;
/// use dntt::nmf::{dist_nmf_ws, NmfConfig, NmfWorkspace};
/// use dntt::runtime::NativeBackend;
///
/// let grid = Grid2d::new(1, 1); // single rank: the whole X is the block
/// let x = Mat::from_fn(6, 5, |i, j| ((i + 2 * j) % 7) as f64);
/// let outs = Comm::run(1, move |mut world| {
///     let (mut row, mut col) = grid.make_subcomms(&mut world);
///     let cfg = NmfConfig { rank: 2, max_iters: 30, ..Default::default() };
///     dist_nmf_ws(&x, 6, 5, grid, &mut world, &mut row, &mut col,
///                 &NativeBackend, &cfg, &mut NmfWorkspace::new()).unwrap()
/// });
/// assert_eq!(outs[0].w.shape(), (6, 2));
/// assert_eq!(outs[0].ht.shape(), (5, 2));
/// assert!(outs[0].w.is_nonneg() && outs[0].ht.is_nonneg());
/// ```
#[allow(clippy::too_many_arguments)]
pub fn dist_nmf_ws(
    x: &Mat<f64>,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    backend: &dyn ComputeBackend,
    cfg: &NmfConfig,
    ws: &mut NmfWorkspace,
) -> Result<NmfOutput> {
    dist_nmf_xref_ws(XRef::Dense(x), m, n, grid, world, row, col, backend, cfg, ws)
}

/// [`dist_nmf_ws`] on a **sparse** (CSR) local block: identical SPMD
/// protocol, with the two `X`-side products routed through the SpMM
/// kernels ([`crate::runtime::backend::ComputeBackend::xht_sparse_into`]
/// / `wtx_sparse_into`). On a sparse block whose zeros are exact, the
/// result agrees with the dense run on the densified block to reduction
/// roundoff (asserted at 1e-5 in `tests/sparse_equivalence.rs`), and is
/// bitwise deterministic across ranks and repeated runs at a fixed grid.
#[allow(clippy::too_many_arguments)]
pub fn dist_nmf_sparse_ws(
    x: &SparseMat,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    backend: &dyn ComputeBackend,
    cfg: &NmfConfig,
    ws: &mut NmfWorkspace,
) -> Result<NmfOutput> {
    dist_nmf_xref_ws(XRef::Sparse(x), m, n, grid, world, row, col, backend, cfg, ws)
}

/// Per-chunk dispatch entry: run on whichever representation the reshape
/// produced (see [`crate::dist::dist_reshape_x`]). This is what the TT
/// and HT drivers call, so a sparse stage matrix flows through the same
/// code path as a dense one. The stage matrix is caller-owned and fully
/// resident by the time it lands here — budgeted out-of-core execution
/// bounds the *reshape's* working set (DESIGN.md §2.12), not the NMF's,
/// so the factorization itself is byte-for-byte budget-oblivious.
#[allow(clippy::too_many_arguments)]
pub fn dist_nmf_x_ws(
    x: &DenseOrSparse,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    backend: &dyn ComputeBackend,
    cfg: &NmfConfig,
    ws: &mut NmfWorkspace,
) -> Result<NmfOutput> {
    dist_nmf_xref_ws(xref_of(x), m, n, grid, world, row, col, backend, cfg, ws)
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn dist_nmf_xref_ws(
    x: XRef<'_>,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    backend: &dyn ComputeBackend,
    cfg: &NmfConfig,
    ws: &mut NmfWorkspace,
) -> Result<NmfOutput> {
    dist_nmf_xref_obs_ws(x, m, n, grid, world, row, col, backend, cfg, ws, None)
}

/// [`dist_nmf_xref_ws`] with an optional per-iteration [`IterObserver`]
/// (the checkpoint hook). The observer is called after every completed
/// iteration and never changes the math — runs with and without one are
/// bitwise identical.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dist_nmf_xref_obs_ws(
    x: XRef<'_>,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    backend: &dyn ComputeBackend,
    cfg: &NmfConfig,
    ws: &mut NmfWorkspace,
    obs: Option<&mut dyn IterObserver>,
) -> Result<NmfOutput> {
    if cfg.rank == 0 {
        return Err(DnttError::config("NMF rank must be ≥ 1"));
    }
    let r = cfg.rank;
    let (i, j) = grid.coords(world.rank());
    let rows = BlockDim::new(m, grid.pr);
    let cols = BlockDim::new(n, grid.pc);
    let (mi, nj) = (rows.size_of(i), cols.size_of(j));
    if (x.rows(), x.cols()) != (mi, nj) {
        return Err(DnttError::shape(format!(
            "rank {}: X block is {}x{}, expected {}x{}",
            world.rank(),
            x.rows(),
            x.cols(),
            mi,
            nj
        )));
    }
    // W rows: sub-split of my block-row's rows across the row comm.
    let wsub = BlockDim::new(mi, grid.pc);
    let w_g0 = rows.start_of(i) + wsub.start_of(j);
    let mw = wsub.size_of(j);
    // H cols: sub-split of my block-col's cols across the col comm.
    let hsub = BlockDim::new(nj, grid.pr);
    let h_g0 = cols.start_of(j) + hsub.start_of(i);
    let nh = hsub.size_of(i);

    let mut ctx = Ctx {
        x,
        backend,
        world,
        row,
        col,
        ws,
        r,
        w_counts: (0..grid.pc).map(|jj| wsub.size_of(jj) * r).collect(),
        h_counts: (0..grid.pr).map(|ii| hsub.size_of(ii) * r).collect(),
    };

    // --- Initialization (Alg 3 lines 1–4) ------------------------------
    let t0 = std::time::Instant::now();
    let mut w = init_factor(cfg.seed, 1, w_g0, mw, r);
    let mut ht = init_factor(cfg.seed, 2, h_g0, nh, r);
    ctx.world.breakdown.add_secs(Cat::Init, t0.elapsed().as_secs_f64());

    let t = std::time::Instant::now();
    let local_xsq = x.fro_norm_sq();
    ctx.world.breakdown.add_secs(Cat::Norm, t.elapsed().as_secs_f64());
    let xsq = ctx.world.all_reduce_scalar(local_xsq);
    let xnorm = xsq.sqrt();
    // Normalize: ‖W‖ = ‖H‖ = sqrt(‖X‖).
    let wn = ctx.global_fro_sq(&w).sqrt();
    let hn = ctx.global_fro_sq(&ht).sqrt();
    if wn > 0.0 {
        w.scale(xnorm.sqrt() / wn);
    }
    if hn > 0.0 {
        ht.scale(xnorm.sqrt() / hn);
    }

    let mut stats = NmfStats {
        iters: 0,
        objective: 0.5 * xsq,
        rel_err: 1.0,
        restarts: 0,
        history: Vec::with_capacity(cfg.max_iters),
    };

    match cfg.algo {
        NmfAlgo::Bcd => bcd_loop(&mut ctx, &mut w, &mut ht, xsq, cfg, &mut stats, obs)?,
        NmfAlgo::Mu => mu_loop(&mut ctx, &mut w, &mut ht, xsq, cfg, &mut stats, obs)?,
        NmfAlgo::Hals => hals_loop(&mut ctx, &mut w, &mut ht, xsq, cfg, &mut stats, obs)?,
    }

    stats.rel_err = (2.0 * stats.objective).max(0.0).sqrt() / xnorm.max(1e-300);
    Ok(NmfOutput {
        w,
        ht,
        w_rows: (w_g0, w_g0 + mw),
        h_cols: (h_g0, h_g0 + nh),
        stats,
    })
}

/// Alg 3: BCD with extrapolation and correction.
///
/// All per-iteration state lives in buffers allocated once up front; the
/// loop body only resizes them in place.
fn bcd_loop(
    ctx: &mut Ctx<'_>,
    w: &mut Mat<f64>,
    ht: &mut Mat<f64>,
    xsq: f64,
    cfg: &NmfConfig,
    stats: &mut NmfStats,
    mut obs: Option<&mut dyn IterObserver>,
) -> Result<()> {
    let delta = cfg.delta;
    let r = ctx.r;
    // Momentum state (fixed shapes; refreshed in place each iteration).
    let mut wm = w.clone();
    let mut htm = ht.clone();
    let mut w_prev = w.clone();
    let mut ht_prev = ht.clone();
    // Loop-carried products.
    let mut hht = Mat::zeros(r, r);
    let mut wtw = Mat::zeros(r, r);
    let mut xht = Mat::zeros(w.rows(), r);
    let mut xtw = Mat::zeros(ht.rows(), r);

    // Line 3: HHᵀ and XHᵀ for the first W update.
    ctx.gram_global_into(&htm, &mut hht);
    ctx.dist_xht_into(&htm, &mut xht)?;

    let mut t = 1.0f64;
    let mut obj = 0.5 * xsq; // line 4
    let mut prev_lip_w = hht.fro_norm().max(1e-300);
    let mut prev_lip_h = 1.0f64;

    for _l in 0..cfg.max_iters {
        let span = crate::obs::span_begin();
        // --- W given H (lines 6–10) --------------------------------
        let lip_w = hht.fro_norm().max(1e-300);
        let tu = std::time::Instant::now();
        ctx.backend.bcd_update_into(&wm, &hht, &xht, lip_w, w, &mut ctx.ws.kernel);
        ctx.world.breakdown.add_secs(Cat::Mad, tu.elapsed().as_secs_f64());
        if cfg.normalize {
            // Line 9, norm-preserving form: W columns to unit L1, fold the
            // scale into the momentum/previous state so the next H-update
            // (which re-fits H against the normalized W) stays consistent.
            ctx.col_l1_inv(w);
            scale_cols(w, &ctx.ws.colsums);
            scale_cols(&mut w_prev, &ctx.ws.colsums);
        }
        ctx.gram_global_into(w, &mut wtw); // line 10
        ctx.dist_wtx_into(w, &mut xtw)?; // line 12

        // --- H given W (lines 11–14) --------------------------------
        let lip_h = wtw.fro_norm().max(1e-300);
        let tu = std::time::Instant::now();
        ctx.backend.bcd_update_into(&htm, &wtw, &xtw, lip_h, ht, &mut ctx.ws.kernel);
        ctx.world.breakdown.add_secs(Cat::Mad, tu.elapsed().as_secs_f64());

        // Lines 15–16: refresh HHᵀ, XHᵀ with the new H.
        ctx.gram_global_into(ht, &mut hht);
        ctx.dist_xht_into(ht, &mut xht)?;

        let obj_new = ctx.objective(&xtw, ht, &wtw, &hht, xsq);

        if obj_new >= obj {
            // --- Correction (lines 17–20): revert to the last accepted
            // iterate and restart the momentum sequence.
            w.copy_from(&w_prev);
            ht.copy_from(&ht_prev);
            wm.copy_from(w);
            htm.copy_from(ht);
            ctx.gram_global_into(ht, &mut hht);
            ctx.dist_xht_into(ht, &mut xht)?;
            t = 1.0;
            stats.restarts += 1;
        } else {
            // --- Extrapolation (lines 21–27).
            let t_new = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
            let wgt = (t - 1.0) / t_new;
            let w_w = wgt.min(delta * (prev_lip_w / lip_w).sqrt());
            let w_h = wgt.min(delta * (prev_lip_h / lip_h).sqrt());
            let tu = std::time::Instant::now();
            // Every element of wm/htm is overwritten, so no copy first.
            for (m_, (cur, prev)) in
                wm.as_mut_slice().iter_mut().zip(w.as_slice().iter().zip(w_prev.as_slice()))
            {
                *m_ = cur + w_w * (cur - prev);
            }
            for (m_, (cur, prev)) in
                htm.as_mut_slice().iter_mut().zip(ht.as_slice().iter().zip(ht_prev.as_slice()))
            {
                *m_ = cur + w_h * (cur - prev);
            }
            ctx.world.breakdown.add_secs(Cat::Mad, tu.elapsed().as_secs_f64());
            w_prev.copy_from(w);
            ht_prev.copy_from(ht);
            t = t_new;
            let rel_change = (obj - obj_new).abs() / (0.5 * xsq).max(1e-300);
            obj = obj_new;
            prev_lip_w = lip_w;
            prev_lip_h = lip_h;
            if cfg.tol > 0.0 && rel_change < cfg.tol {
                stats.iters += 1;
                stats.history.push(obj);
                if let Some(o) = obs.as_mut() {
                    // The converging iteration is observed too (MU/HALS
                    // observe before their break; keep BCD consistent).
                    o.on_iter(stats.iters, w, ht);
                }
                crate::obs::end_iter(span, stats.iters as u64);
                break;
            }
        }
        stats.iters += 1;
        stats.history.push(obj);
        if let Some(o) = obs.as_mut() {
            o.on_iter(stats.iters, w, ht);
        }
        crate::obs::end_iter(span, stats.iters as u64);
    }
    // Return the last *accepted* iterate.
    *w = w_prev;
    *ht = ht_prev;
    stats.objective = obj;
    Ok(())
}

/// Multiplicative updates (the paper's MU comparison). In-place updates
/// through the workspace: the iteration allocates nothing after warm-up.
fn mu_loop(
    ctx: &mut Ctx<'_>,
    w: &mut Mat<f64>,
    ht: &mut Mat<f64>,
    xsq: f64,
    cfg: &NmfConfig,
    stats: &mut NmfStats,
    mut obs: Option<&mut dyn IterObserver>,
) -> Result<()> {
    let r = ctx.r;
    let mut hht = Mat::zeros(r, r);
    let mut wtw = Mat::zeros(r, r);
    let mut xht = Mat::zeros(w.rows(), r);
    let mut xtw = Mat::zeros(ht.rows(), r);
    let mut obj = 0.5 * xsq;
    // HHᵀ is loop-carried: the end-of-iteration refresh (for the
    // objective) is exactly the Gram the next W-update needs, so it is
    // computed once per iteration, not twice.
    ctx.gram_global_into(ht, &mut hht);
    for _l in 0..cfg.max_iters {
        let span = crate::obs::span_begin();
        ctx.dist_xht_into(ht, &mut xht)?;
        let tu = std::time::Instant::now();
        ctx.backend.mu_update_inplace(w, &hht, &xht, &mut ctx.ws.kernel);
        ctx.world.breakdown.add_secs(Cat::Mad, tu.elapsed().as_secs_f64());

        ctx.gram_global_into(w, &mut wtw);
        ctx.dist_wtx_into(w, &mut xtw)?;
        let tu = std::time::Instant::now();
        ctx.backend.mu_update_inplace(ht, &wtw, &xtw, &mut ctx.ws.kernel);
        ctx.world.breakdown.add_secs(Cat::Mad, tu.elapsed().as_secs_f64());

        // Refresh HHᵀ with the new H for the objective (and next iter).
        ctx.gram_global_into(ht, &mut hht);
        let obj_new = ctx.objective(&xtw, ht, &wtw, &hht, xsq);
        let rel = (obj - obj_new).abs() / (0.5 * xsq).max(1e-300);
        obj = obj_new;
        stats.iters += 1;
        stats.history.push(obj);
        if let Some(o) = obs.as_mut() {
            o.on_iter(stats.iters, w, ht);
        }
        crate::obs::end_iter(span, stats.iters as u64);
        if cfg.tol > 0.0 && rel < cfg.tol {
            break;
        }
    }
    stats.objective = obj;
    Ok(())
}

/// HALS: per-column closed-form updates (local once the global Gram and
/// product blocks are in place — no extra communication per column).
fn hals_loop(
    ctx: &mut Ctx<'_>,
    w: &mut Mat<f64>,
    ht: &mut Mat<f64>,
    xsq: f64,
    cfg: &NmfConfig,
    stats: &mut NmfStats,
    mut obs: Option<&mut dyn IterObserver>,
) -> Result<()> {
    let r = ctx.r;
    let mut hht = Mat::zeros(r, r);
    let mut wtw = Mat::zeros(r, r);
    let mut xht = Mat::zeros(w.rows(), r);
    let mut xtw = Mat::zeros(ht.rows(), r);
    let mut obj = 0.5 * xsq;
    // HHᵀ is loop-carried (see mu_loop): one global Gram per iteration.
    ctx.gram_global_into(ht, &mut hht);
    for _l in 0..cfg.max_iters {
        let span = crate::obs::span_begin();
        ctx.dist_xht_into(ht, &mut xht)?;
        let tu = std::time::Instant::now();
        hals_update(w, &hht, &xht, r);
        ctx.world.breakdown.add_secs(Cat::Mad, tu.elapsed().as_secs_f64());

        ctx.gram_global_into(w, &mut wtw);
        ctx.dist_wtx_into(w, &mut xtw)?;
        let tu = std::time::Instant::now();
        hals_update(ht, &wtw, &xtw, r);
        ctx.world.breakdown.add_secs(Cat::Mad, tu.elapsed().as_secs_f64());

        ctx.gram_global_into(ht, &mut hht);
        let obj_new = ctx.objective(&xtw, ht, &wtw, &hht, xsq);
        let rel = (obj - obj_new).abs() / (0.5 * xsq).max(1e-300);
        obj = obj_new;
        stats.iters += 1;
        stats.history.push(obj);
        if let Some(o) = obs.as_mut() {
            o.on_iter(stats.iters, w, ht);
        }
        crate::obs::end_iter(span, stats.iters as u64);
        if cfg.tol > 0.0 && rel < cfg.tol {
            break;
        }
    }
    stats.objective = obj;
    Ok(())
}

/// One HALS sweep over columns: `f_c ← max(0, f_c + (p_c − F·g_c)/g_cc)`.
fn hals_update(f: &mut Mat<f64>, g: &Mat<f64>, p: &Mat<f64>, r: usize) {
    for c in 0..r {
        let gcc = g[(c, c)].max(1e-300);
        for i in 0..f.rows() {
            let frow = f.row(i);
            let mut fg = 0.0;
            for k in 0..r {
                fg += frow[k] * g[(k, c)];
            }
            let v = frow[c] + (p[(i, c)] - fg) / gcc;
            f.row_mut(i)[c] = if v > 0.0 { v } else { 0.0 };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::BlockDim;
    use crate::linalg::gemm::matmul;
    use crate::runtime::native::NativeBackend;

    /// Run dist_nmf over `grid` on a full matrix; returns (W, H, stats)
    /// reassembled globally.
    fn run_dist(
        x: &Mat<f64>,
        grid: Grid2d,
        cfg: &NmfConfig,
    ) -> (Mat<f64>, Mat<f64>, NmfStats) {
        let (m, n) = x.shape();
        let x = x.clone();
        let r = cfg.rank;
        let cfg = cfg.clone();
        let outs = Comm::run(grid.size(), move |mut world| {
            let (i, j) = grid.coords(world.rank());
            let rows = BlockDim::new(m, grid.pr);
            let cols = BlockDim::new(n, grid.pc);
            let xb = Mat::from_fn(rows.size_of(i), cols.size_of(j), |a, b| {
                x[(rows.start_of(i) + a, cols.start_of(j) + b)]
            });
            let (mut row, mut col) = grid.make_subcomms(&mut world);
            dist_nmf(&xb, m, n, grid, &mut world, &mut row, &mut col, &NativeBackend, &cfg)
                .unwrap()
        });
        let mut wfull = Mat::zeros(m, r);
        let mut hfull = Mat::zeros(r, n);
        for o in &outs {
            for (li, gi) in (o.w_rows.0..o.w_rows.1).enumerate() {
                wfull.row_mut(gi).copy_from_slice(o.w.row(li));
            }
            for (lb, gb) in (o.h_cols.0..o.h_cols.1).enumerate() {
                for c in 0..r {
                    hfull[(c, gb)] = o.ht[(lb, c)];
                }
            }
        }
        (wfull, hfull, outs[0].stats.clone())
    }

    fn low_rank_x(m: usize, n: usize, r: usize, seed: u64) -> Mat<f64> {
        let mut rng = crate::util::rng::Rng::new(seed);
        let a = Mat::<f64>::rand_uniform(m, r, &mut rng);
        let b = Mat::<f64>::rand_uniform(r, n, &mut rng);
        matmul(&a, &b)
    }

    fn fit_err(x: &Mat<f64>, w: &Mat<f64>, h: &Mat<f64>) -> f64 {
        let mut d = matmul(w, h);
        d.sub_assign(x);
        d.fro_norm() / x.fro_norm()
    }

    #[test]
    fn bcd_converges_on_low_rank_serial() {
        let x = low_rank_x(24, 30, 3, 1);
        let cfg = NmfConfig { rank: 3, max_iters: 300, ..Default::default() };
        let (w, h, stats) = run_dist(&x, Grid2d::new(1, 1), &cfg);
        assert!(w.is_nonneg() && h.is_nonneg());
        let err = fit_err(&x, &w, &h);
        assert!(err < 1e-3, "err={err}, stats={stats:?}");
        assert!((stats.rel_err - err).abs() < 1e-6);
    }

    #[test]
    fn bcd_objective_monotone_over_accepted() {
        let x = low_rank_x(20, 25, 4, 2);
        let cfg = NmfConfig { rank: 4, max_iters: 120, ..Default::default() };
        let (_, _, stats) = run_dist(&x, Grid2d::new(1, 1), &cfg);
        // The history records the running best (correction reverts), so it
        // must be non-increasing.
        for w in stats.history.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-12), "objective increased: {} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn dist_matches_serial_bcd() {
        let x = low_rank_x(12, 18, 2, 3);
        let cfg = NmfConfig { rank: 2, max_iters: 40, ..Default::default() };
        let (w1, h1, s1) = run_dist(&x, Grid2d::new(1, 1), &cfg);
        let (w2, h2, s2) = run_dist(&x, Grid2d::new(2, 3), &cfg);
        // Same deterministic init → same trajectory up to reduction order.
        assert!((s1.objective - s2.objective).abs() <= 1e-6 * (1.0 + s1.objective));
        for (a, b) in w1.as_slice().iter().zip(w2.as_slice()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        for (a, b) in h1.as_slice().iter().zip(h2.as_slice()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn mu_converges_and_matches_across_grids() {
        let x = low_rank_x(16, 14, 3, 4);
        let cfg =
            NmfConfig { rank: 3, max_iters: 200, algo: NmfAlgo::Mu, ..Default::default() };
        let (w1, h1, s1) = run_dist(&x, Grid2d::new(1, 1), &cfg);
        let (_, _, s2) = run_dist(&x, Grid2d::new(2, 2), &cfg);
        assert!(s1.rel_err < 0.05, "mu rel_err={}", s1.rel_err);
        assert!((s1.objective - s2.objective).abs() <= 1e-6 * (1.0 + s1.objective));
        assert!(w1.is_nonneg() && h1.is_nonneg());
    }

    #[test]
    fn hals_converges() {
        let x = low_rank_x(16, 14, 3, 5);
        let cfg =
            NmfConfig { rank: 3, max_iters: 150, algo: NmfAlgo::Hals, ..Default::default() };
        let (w, h, s) = run_dist(&x, Grid2d::new(1, 1), &cfg);
        assert!(s.rel_err < 1e-2, "hals rel_err={}", s.rel_err);
        assert!(w.is_nonneg() && h.is_nonneg());
    }

    #[test]
    fn uneven_blocks_work() {
        // 13 x 17 over a 2x3 grid: every block dimension is uneven.
        let x = low_rank_x(13, 17, 2, 6);
        let cfg = NmfConfig { rank: 2, max_iters: 60, ..Default::default() };
        let (w, h, s) = run_dist(&x, Grid2d::new(2, 3), &cfg);
        assert_eq!(w.shape(), (13, 2));
        assert_eq!(h.shape(), (2, 17));
        assert!(s.rel_err < 0.05, "rel_err={}", s.rel_err);
    }

    #[test]
    fn early_stop_with_tol() {
        let x = low_rank_x(20, 20, 2, 7);
        let cfg = NmfConfig { rank: 2, max_iters: 500, tol: 1e-8, ..Default::default() };
        let (_, _, s) = run_dist(&x, Grid2d::new(1, 1), &cfg);
        assert!(s.iters < 500, "should early-stop, ran {}", s.iters);
    }

    #[test]
    fn rank_one_factorization() {
        // Rank-1 outer product is recovered by rank-1 NMF.
        let x = low_rank_x(10, 12, 1, 8);
        let cfg = NmfConfig { rank: 1, max_iters: 100, ..Default::default() };
        let (_, _, s) = run_dist(&x, Grid2d::new(2, 2), &cfg);
        assert!(s.rel_err < 1e-4, "rel_err={}", s.rel_err);
    }

    #[test]
    fn init_is_grid_invariant() {
        let a = init_factor(9, 1, 5, 4, 3);
        let b = init_factor(9, 1, 7, 2, 3);
        // rows 7,8 of the global factor must agree.
        assert_eq!(a.row(2), b.row(0));
        assert_eq!(a.row(3), b.row(1));
        for &v in a.as_slice() {
            assert!((0.0..1.0).contains(&v));
        }
    }

    /// Every update rule through a shared warm workspace must be bitwise
    /// identical to the transient-workspace wrapper.
    #[test]
    fn warm_workspace_is_bitwise_identical() {
        for algo in [NmfAlgo::Bcd, NmfAlgo::Mu, NmfAlgo::Hals] {
            let x = low_rank_x(14, 19, 2, 10);
            let cfg = NmfConfig { rank: 2, max_iters: 25, algo, ..Default::default() };
            let grid = Grid2d::new(1, 1);
            let x2 = x.clone();
            let cfg2 = cfg.clone();
            let outs = Comm::run(1, move |mut world| {
                let (mut row, mut col) = grid.make_subcomms(&mut world);
                let mut ws = NmfWorkspace::new();
                let a = dist_nmf_ws(
                    &x2, 14, 19, grid, &mut world, &mut row, &mut col, &NativeBackend,
                    &cfg2, &mut ws,
                )
                .unwrap();
                // Second run reuses the warm workspace.
                let b = dist_nmf_ws(
                    &x2, 14, 19, grid, &mut world, &mut row, &mut col, &NativeBackend,
                    &cfg2, &mut ws,
                )
                .unwrap();
                // And the transient-workspace wrapper.
                let c = dist_nmf(
                    &x2, 14, 19, grid, &mut world, &mut row, &mut col, &NativeBackend, &cfg2,
                )
                .unwrap();
                (a, b, c)
            });
            let (a, b, c) = &outs[0];
            assert_eq!(a.w.as_slice(), b.w.as_slice(), "{algo:?}: warm vs fresh W");
            assert_eq!(a.ht.as_slice(), b.ht.as_slice(), "{algo:?}: warm vs fresh H");
            assert_eq!(a.w.as_slice(), c.w.as_slice(), "{algo:?}: ws vs wrapper W");
            assert_eq!(a.ht.as_slice(), c.ht.as_slice(), "{algo:?}: ws vs wrapper H");
        }
    }
}
