//! Distributed zero-row / zero-column pruning (a pyDNTNK feature).
//!
//! Sparse-ish real data produces stage matrices with entirely zero rows
//! or columns (empty pixels, silent channels). A zero row of `X` forces
//! the matching row of `W` to zero in any exact factorization `X ≈ W·H`
//! (and a zero column forces a zero column of `H`), so those rows/columns
//! can be removed *before* the NMF — shrinking every Gram/GEMM of the
//! inner loop — and re-inserted as zeros afterwards.
//!
//! [`dist_nmf_pruned`] is the drop-in collective wrapper the TT and HT
//! drivers call: it detects all-zero global rows/columns with two
//! world `all_reduce`s, redistributes the surviving sub-matrix through
//! the [`SharedStore`] (the block partition of the pruned matrix does not
//! coincide with the pruned blocks of the full one), runs
//! [`crate::nmf::dist_nmf`], and restores full-size distributed factors
//! the same way. When nothing can be pruned it degenerates to a plain
//! `dist_nmf` call (detection cost only). Note the pruned factorization
//! is *not* bitwise-identical to the unpruned one — factor initialization
//! is a function of global indices, which shift under pruning.
//!
//! Sparse blocks ([`dist_nmf_pruned_x_ws`]) run the same protocol with
//! the block kept sparse end to end: detection walks the CSR nonzeros,
//! the compress round-trip publishes sparse chunks and rebuilds the
//! pruned matrix as CSR, and the restored factors carry **exact zeros**
//! at pruned rows/columns exactly as in the dense path (asserted in
//! `tests/sparse_equivalence.rs`).

use crate::dist::{BlockDim, Comm, Grid2d, Layout, SharedStore, TensorBlock};
use crate::error::Result;
use crate::linalg::sparse::SparseMat;
use crate::linalg::{DenseOrSparse, Mat};
use crate::nmf::dist::{dist_nmf_xref_obs_ws, xref_of, IterObserver, NmfOutput, XRef};
use crate::nmf::workspace::NmfWorkspace;
use crate::nmf::NmfConfig;
use crate::runtime::backend::ComputeBackend;
use crate::tensor::sparse::SparseChunk;
use crate::util::timer::Cat;
use std::time::Instant;

/// Which global rows/columns of an `m × n` matrix survive pruning.
///
/// Identical on every rank (built from deterministic collectives).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PruneMap {
    /// Surviving global row indices, ascending.
    pub kept_rows: Vec<usize>,
    /// Surviving global column indices, ascending.
    pub kept_cols: Vec<usize>,
    pub full_m: usize,
    pub full_n: usize,
}

impl PruneMap {
    /// True when nothing was pruned.
    pub fn is_identity(&self) -> bool {
        self.kept_rows.len() == self.full_m && self.kept_cols.len() == self.full_n
    }

    /// Row count of the pruned matrix.
    pub fn pruned_m(&self) -> usize {
        self.kept_rows.len()
    }

    /// Column count of the pruned matrix.
    pub fn pruned_n(&self) -> usize {
        self.kept_cols.len()
    }

    /// Re-insert zero rows into a `m' × r` factor of the pruned matrix
    /// (row `k` of `f` is global row `kept_rows[k]`).
    pub fn restore_rows(&self, f: &Mat<f64>) -> Mat<f64> {
        assert_eq!(f.rows(), self.kept_rows.len(), "restore_rows: factor mismatch");
        let mut out = Mat::zeros(self.full_m, f.cols());
        for (k, &g) in self.kept_rows.iter().enumerate() {
            out.row_mut(g).copy_from_slice(f.row(k));
        }
        out
    }

    /// Re-insert zero columns into an `r × n'` factor of the pruned
    /// matrix (column `k` of `f` is global column `kept_cols[k]`).
    pub fn restore_cols(&self, f: &Mat<f64>) -> Mat<f64> {
        assert_eq!(f.cols(), self.kept_cols.len(), "restore_cols: factor mismatch");
        let mut out = Mat::zeros(f.rows(), self.full_n);
        for i in 0..f.rows() {
            for (k, &g) in self.kept_cols.iter().enumerate() {
                out[(i, g)] = f[(i, k)];
            }
        }
        out
    }
}

/// Collective detection of all-zero global rows/columns of the
/// distributed `m × n` matrix whose local `MatGrid` block is `x`.
///
/// Every rank contributes its block's absolute row/column sums into one
/// zero-padded `m + n` vector; a single deterministic `all_reduce` makes
/// the sums (and therefore the kept sets) rank-identical. Detection is
/// `O(m + n)` doubles of reduce traffic per call — fine for the stage
/// matrices the drivers feed it, but worth keeping `prune` off for
/// extreme aspect ratios where `m + n` rivals the local block size.
pub fn detect_zeros(
    x: &Mat<f64>,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
) -> PruneMap {
    detect_zeros_xref(XRef::Dense(x), m, n, grid, world)
}

/// [`detect_zeros`] on a dense-or-sparse block. On a sparse block the
/// sums walk the CSR nonzeros in the same row-major order the dense scan
/// uses; skipped exact zeros contribute `+0.0` to non-negative sums, so
/// both paths produce bitwise-identical sums (hence identical kept sets).
pub fn detect_zeros_x(
    x: &DenseOrSparse,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
) -> PruneMap {
    detect_zeros_xref(xref_of(x), m, n, grid, world)
}

pub(crate) fn detect_zeros_xref(
    x: XRef<'_>,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
) -> PruneMap {
    let (i, j) = grid.coords(world.rank());
    let rows = BlockDim::new(m, grid.pr);
    let cols = BlockDim::new(n, grid.pc);
    debug_assert_eq!((x.rows(), x.cols()), (rows.size_of(i), cols.size_of(j)));
    let t0 = Instant::now();
    // sums[0..m] = per-row |·| sums, sums[m..m+n] = per-column.
    let mut sums = vec![0.0; m + n];
    match x {
        XRef::Dense(x) => {
            for li in 0..x.rows() {
                let mut s = 0.0;
                for (lj, &v) in x.row(li).iter().enumerate() {
                    let a = v.abs();
                    s += a;
                    sums[m + cols.start_of(j) + lj] += a;
                }
                sums[rows.start_of(i) + li] = s;
            }
        }
        XRef::Sparse(x) => {
            for li in 0..x.rows() {
                let (jx, vx) = x.row(li);
                let mut s = 0.0;
                for (&lj, &v) in jx.iter().zip(vx) {
                    let a = v.abs();
                    s += a;
                    sums[m + cols.start_of(j) + lj] += a;
                }
                sums[rows.start_of(i) + li] = s;
            }
        }
    }
    world.breakdown.add_secs(Cat::Norm, t0.elapsed().as_secs_f64());
    world.all_reduce_sum(&mut sums);
    // Keep everything that is not exactly zero — in particular a NaN sum
    // (corrupt input) keeps its row/column so the NaN propagates visibly
    // instead of being silently pruned to zeros.
    PruneMap {
        kept_rows: (0..m).filter(|&g| sums[g] != 0.0).collect(),
        kept_cols: (0..n).filter(|&g| sums[m + g] != 0.0).collect(),
        full_m: m,
        full_n: n,
    }
}

/// Publish this rank's chunk (either representation), aborting the world
/// on a divergent failure (same discipline as `dist_reshape`).
fn publish_or_abort(
    world: &mut Comm,
    store: &SharedStore,
    name: &str,
    layout: &Layout,
    data: TensorBlock,
) -> Result<()> {
    let t0 = Instant::now();
    if let Err(e) = store.publish_block(name, layout, world.rank(), data) {
        world.abort(&format!("{name}: publish failed: {e}"));
        return Err(e);
    }
    world.breakdown.add_secs(Cat::Io, t0.elapsed().as_secs_f64());
    Ok(())
}

/// Abort the world before propagating an error raised inside a
/// barrier-delimited section (a plain early return would strand peers in
/// the next barrier).
fn abort_on_err<T>(world: &mut Comm, what: &str, r: Result<T>) -> Result<T> {
    if let Err(e) = &r {
        world.abort(&format!("{what}: {e}"));
    }
    r
}

/// Run [`crate::nmf::dist_nmf`] with zero-row/column pruning applied first and
/// full-size distributed factors restored afterwards.
///
/// Collective over `world`; `x` is this rank's `MatGrid` block of the
/// `m × n` matrix, and the returned [`NmfOutput`] carries this rank's
/// blocks of the **full-size** `W`/`H` (pruned rows/columns are zero),
/// exactly as a plain `dist_nmf` call would. `tag` namespaces the store
/// round-trips and must be unique per concurrent call.
#[allow(clippy::too_many_arguments)]
pub fn dist_nmf_pruned(
    x: &Mat<f64>,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    backend: &dyn ComputeBackend,
    cfg: &NmfConfig,
    store: &SharedStore,
    tag: &str,
    enable: bool,
) -> Result<NmfOutput> {
    dist_nmf_pruned_ws(
        x, m, n, grid, world, row, col, backend, cfg, store, tag, enable,
        &mut NmfWorkspace::new(),
    )
}

/// [`dist_nmf_pruned`] with a caller-owned [`NmfWorkspace`] — the form
/// the TT/HT drivers use so every stage NMF shares one buffer set.
#[allow(clippy::too_many_arguments)]
pub fn dist_nmf_pruned_ws(
    x: &Mat<f64>,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    backend: &dyn ComputeBackend,
    cfg: &NmfConfig,
    store: &SharedStore,
    tag: &str,
    enable: bool,
    ws: &mut NmfWorkspace,
) -> Result<NmfOutput> {
    pruned_impl(
        XRef::Dense(x), m, n, grid, world, row, col, backend, cfg, store, tag, enable, ws, None,
    )
}

/// [`dist_nmf_pruned_ws`] on a dense-or-sparse block (the driver-facing
/// form). A sparse block stays sparse through the prune round-trip: its
/// chunks are published sparse, and the compressed matrix is rebuilt as
/// CSR from the surviving nonzeros.
#[allow(clippy::too_many_arguments)]
pub fn dist_nmf_pruned_x_ws(
    x: &DenseOrSparse,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    backend: &dyn ComputeBackend,
    cfg: &NmfConfig,
    store: &SharedStore,
    tag: &str,
    enable: bool,
    ws: &mut NmfWorkspace,
) -> Result<NmfOutput> {
    pruned_impl(
        xref_of(x), m, n, grid, world, row, col, backend, cfg, store, tag, enable, ws, None,
    )
}

/// [`dist_nmf_pruned_x_ws`] with the checkpoint subsystem's per-iteration
/// observer ([`crate::nmf::dist::IterObserver`]) threaded into whichever
/// inner NMF runs (pruned or pass-through). The observer never changes
/// the math; on the pruned path it sees the *pruned* factor blocks.
#[allow(clippy::too_many_arguments)]
pub fn dist_nmf_pruned_x_obs_ws(
    x: &DenseOrSparse,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    backend: &dyn ComputeBackend,
    cfg: &NmfConfig,
    store: &SharedStore,
    tag: &str,
    enable: bool,
    ws: &mut NmfWorkspace,
    obs: Option<&mut dyn IterObserver>,
) -> Result<NmfOutput> {
    pruned_impl(
        xref_of(x), m, n, grid, world, row, col, backend, cfg, store, tag, enable, ws, obs,
    )
}

#[allow(clippy::too_many_arguments)]
fn pruned_impl(
    x: XRef<'_>,
    m: usize,
    n: usize,
    grid: Grid2d,
    world: &mut Comm,
    row: &mut Comm,
    col: &mut Comm,
    backend: &dyn ComputeBackend,
    cfg: &NmfConfig,
    store: &SharedStore,
    tag: &str,
    enable: bool,
    ws: &mut NmfWorkspace,
    obs: Option<&mut dyn IterObserver>,
) -> Result<NmfOutput> {
    if !enable {
        return dist_nmf_xref_obs_ws(x, m, n, grid, world, row, col, backend, cfg, ws, obs);
    }
    let map = detect_zeros_xref(x, m, n, grid, world);
    if map.is_identity() || map.pruned_m() == 0 || map.pruned_n() == 0 {
        // Nothing to prune (or a fully zero matrix, which NMF handles).
        return dist_nmf_xref_obs_ws(x, m, n, grid, world, row, col, backend, cfg, ws, obs);
    }
    let (pm, pn) = (map.pruned_m(), map.pruned_n());
    let (i, j) = grid.coords(world.rank());
    log::debug!(
        "prune {tag}: {m}x{n} -> {pm}x{pn} ({} rows, {} cols dropped)",
        m - pm,
        n - pn
    );
    crate::obs::count(crate::obs::Ctr::PruneRowsDropped, (m - pm) as u64);
    crate::obs::count(crate::obs::Ctr::PruneColsDropped, (n - pn) as u64);

    // --- Compress: full MatGrid blocks -> pruned MatGrid blocks. --------
    // A sparse block keeps its representation through the round-trip:
    // sparse publish, then a CSR rebuild of the surviving nonzeros.
    let full = Layout::MatGrid { m, n, pr: grid.pr, pc: grid.pc };
    let name_x = format!("{tag}.prune.x");
    let prow = BlockDim::new(pm, grid.pr);
    let pcol = BlockDim::new(pn, grid.pc);
    let xp: DenseOrSparse = match x {
        XRef::Dense(x) => {
            let block = TensorBlock::Dense(x.as_slice().to_vec());
            publish_or_abort(world, store, &name_x, &full, block)?;
            world.barrier();
            let view = store.view(&name_x)?;
            let t0 = Instant::now();
            let mut xp = Mat::zeros(prow.size_of(i), pcol.size_of(j));
            for li in 0..xp.rows() {
                let gr = map.kept_rows[prow.start_of(i) + li];
                for lj in 0..xp.cols() {
                    let gc = map.kept_cols[pcol.start_of(j) + lj];
                    xp[(li, lj)] = view.get(gr * n + gc);
                }
            }
            world.breakdown.add_secs(Cat::Reshape, t0.elapsed().as_secs_f64());
            world.breakdown.add_bytes(Cat::Io, view.disk_bytes_read());
            drop(view);
            DenseOrSparse::Dense(xp)
        }
        XRef::Sparse(xs) => {
            // CSR iterates row-major, so the linear indices are sorted.
            let mut cidx = Vec::with_capacity(xs.nnz());
            let mut cvals = Vec::with_capacity(xs.nnz());
            xs.for_each_nz(|li, lj, v| {
                cidx.push(li * xs.cols() + lj);
                cvals.push(v);
            });
            let chunk = abort_on_err(
                world,
                &format!("{name_x}: sparse chunk build failed"),
                SparseChunk::new(xs.rows() * xs.cols(), cidx, cvals),
            )?;
            publish_or_abort(world, store, &name_x, &full, TensorBlock::Sparse(chunk))?;
            world.barrier();
            let view = store.view(&name_x)?;
            let t0 = Instant::now();
            let mut inv_cols = vec![usize::MAX; n];
            for (k, &g) in map.kept_cols.iter().enumerate() {
                inv_cols[g] = k;
            }
            let (c0p, widthp) = (pcol.start_of(j), pcol.size_of(j));
            let rowsp = prow.size_of(i);
            let mut idx = Vec::new();
            let mut vals = Vec::new();
            if widthp > 0 {
                // Scan only the global column window spanning this rank's
                // kept columns (kept_cols is sorted, so the window's kept
                // set is exactly kept_cols[c0p..c0p+widthp]) — the dense
                // path's locality, in sparse form. `k` ascends with the
                // column offset, so the indices stay sorted.
                let lo_g = map.kept_cols[c0p];
                let hi_g = map.kept_cols[c0p + widthp - 1] + 1;
                for li in 0..rowsp {
                    let gr = map.kept_rows[prow.start_of(i) + li];
                    view.read_nonzeros(gr * n + lo_g, hi_g - lo_g, |off, v| {
                        let k = inv_cols[lo_g + off];
                        if k != usize::MAX && k >= c0p && k < c0p + widthp {
                            idx.push(li * widthp + (k - c0p));
                            vals.push(v);
                        }
                    });
                }
            }
            world.breakdown.add_secs(Cat::Reshape, t0.elapsed().as_secs_f64());
            world.breakdown.add_bytes(Cat::Io, view.disk_bytes_read());
            drop(view);
            DenseOrSparse::Sparse(abort_on_err(
                world,
                &format!("{name_x}: pruned CSR build failed"),
                SparseMat::from_linear(rowsp, widthp, &idx, &vals),
            )?)
        }
    };
    world.barrier();
    if world.rank() == 0 {
        store.remove(&name_x);
    }
    world.barrier();

    // --- Factorize the pruned matrix. -----------------------------------
    let out =
        dist_nmf_xref_obs_ws(xref_of(&xp), pm, pn, grid, world, row, col, backend, cfg, ws, obs)?;
    let r = cfg.rank;

    // --- Restore W: pruned WGrid -> this rank's full-size row block. ----
    let mut inv_rows = vec![usize::MAX; m];
    for (k, &g) in map.kept_rows.iter().enumerate() {
        inv_rows[g] = k;
    }
    let name_w = format!("{tag}.prune.w");
    let wlay = Layout::WGrid { m: pm, r, pr: grid.pr, pc: grid.pc };
    publish_or_abort(world, store, &name_w, &wlay, TensorBlock::Dense(out.w.into_vec()))?;
    world.barrier();
    let view = store.view(&name_w)?;
    let rows = BlockDim::new(m, grid.pr);
    let wsub = BlockDim::new(rows.size_of(i), grid.pc);
    let w_g0 = rows.start_of(i) + wsub.start_of(j);
    let mw = wsub.size_of(j);
    let t0 = Instant::now();
    let mut w = Mat::zeros(mw, r);
    for lr in 0..mw {
        let k = inv_rows[w_g0 + lr];
        if k != usize::MAX {
            view.read_into(k * r, w.row_mut(lr));
        }
    }
    world.breakdown.add_secs(Cat::Reshape, t0.elapsed().as_secs_f64());
    world.breakdown.add_bytes(Cat::Io, view.disk_bytes_read());
    drop(view);
    world.barrier();
    if world.rank() == 0 {
        store.remove(&name_w);
    }
    world.barrier();

    // --- Restore H: pruned HtGrid -> this rank's full-size column block.
    let mut inv_cols = vec![usize::MAX; n];
    for (k, &g) in map.kept_cols.iter().enumerate() {
        inv_cols[g] = k;
    }
    let name_h = format!("{tag}.prune.h");
    let hlay = Layout::HtGrid { r, n: pn, pr: grid.pr, pc: grid.pc };
    publish_or_abort(world, store, &name_h, &hlay, TensorBlock::Dense(out.ht.into_vec()))?;
    world.barrier();
    let view = store.view(&name_h)?;
    let cols = BlockDim::new(n, grid.pc);
    let hsub = BlockDim::new(cols.size_of(j), grid.pr);
    let h_g0 = cols.start_of(j) + hsub.start_of(i);
    let nh = hsub.size_of(i);
    let t0 = Instant::now();
    let mut ht = Mat::zeros(nh, r);
    for lc in 0..nh {
        let k = inv_cols[h_g0 + lc];
        if k != usize::MAX {
            for rr in 0..r {
                // Logical array of the pruned HtGrid is H': r × pn.
                ht[(lc, rr)] = view.get(rr * pn + k);
            }
        }
    }
    world.breakdown.add_secs(Cat::Reshape, t0.elapsed().as_secs_f64());
    world.breakdown.add_bytes(Cat::Io, view.disk_bytes_read());
    drop(view);
    world.barrier();
    if world.rank() == 0 {
        store.remove(&name_h);
    }
    world.barrier();

    Ok(NmfOutput {
        w,
        ht,
        w_rows: (w_g0, w_g0 + mw),
        h_cols: (h_g0, h_g0 + nh),
        stats: out.stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::chunkstore::SpillMode;
    use crate::linalg::gemm::matmul;
    use crate::nmf::dist::dist_nmf;
    use crate::runtime::native::NativeBackend;
    use crate::util::rng::Rng;

    /// Block (i, j) of a full matrix under the MatGrid partition.
    fn block_of(x: &Mat<f64>, grid: Grid2d, rank: usize) -> Mat<f64> {
        let (m, n) = x.shape();
        let (i, j) = grid.coords(rank);
        let rows = BlockDim::new(m, grid.pr);
        let cols = BlockDim::new(n, grid.pc);
        Mat::from_fn(rows.size_of(i), cols.size_of(j), |a, b| {
            x[(rows.start_of(i) + a, cols.start_of(j) + b)]
        })
    }

    /// A low-rank non-negative matrix with zero rows/cols at `zr`/`zc`.
    fn holey_low_rank(m: usize, n: usize, r: usize, zr: &[usize], zc: &[usize], seed: u64) -> Mat<f64> {
        let mut rng = Rng::new(seed);
        let mut a = Mat::<f64>::rand_uniform(m, r, &mut rng);
        let mut b = Mat::<f64>::rand_uniform(r, n, &mut rng);
        for &g in zr {
            a.row_mut(g).iter_mut().for_each(|v| *v = 0.0);
        }
        for &g in zc {
            for k in 0..r {
                b[(k, g)] = 0.0;
            }
        }
        matmul(&a, &b)
    }

    #[test]
    fn detects_zero_rows_and_cols_on_a_grid() {
        let x = holey_low_rank(6, 8, 2, &[2, 5], &[0, 4], 1);
        let grid = Grid2d::new(2, 2);
        let outs = Comm::run(4, move |mut world| {
            let xb = block_of(&x, grid, world.rank());
            detect_zeros(&xb, 6, 8, grid, &mut world)
        });
        for map in &outs {
            assert_eq!(map, &outs[0], "kept sets must be rank-identical");
            assert_eq!(map.kept_rows, vec![0, 1, 3, 4]);
            assert_eq!(map.kept_cols, vec![1, 2, 3, 5, 6, 7]);
            assert!(!map.is_identity());
            assert_eq!((map.pruned_m(), map.pruned_n()), (4, 6));
        }
    }

    #[test]
    fn nan_rows_and_cols_are_kept_not_pruned() {
        let mut x = holey_low_rank(4, 4, 2, &[1], &[], 3);
        x[(2, 2)] = f64::NAN;
        let grid = Grid2d::new(1, 1);
        let outs = Comm::run(1, move |mut world| detect_zeros(&x, 4, 4, grid, &mut world));
        // The zero row is pruned; the NaN row/column stays so the NaN
        // propagates instead of being silently replaced by zeros.
        assert_eq!(outs[0].kept_rows, vec![0, 2, 3]);
        assert_eq!(outs[0].kept_cols, vec![0, 1, 2, 3]);
    }

    #[test]
    fn restore_helpers_reinsert_zeros() {
        let map = PruneMap { kept_rows: vec![0, 2], kept_cols: vec![1], full_m: 3, full_n: 2 };
        let f = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let rf = map.restore_rows(&f);
        assert_eq!(rf.shape(), (3, 2));
        assert_eq!(rf.as_slice(), &[1.0, 2.0, 0.0, 0.0, 3.0, 4.0]);
        let h = Mat::from_vec(2, 1, vec![5.0, 6.0]);
        let rh = map.restore_cols(&h);
        assert_eq!(rh.shape(), (2, 2));
        assert_eq!(rh.as_slice(), &[0.0, 5.0, 0.0, 6.0]);
    }

    /// Factors from the pruned path reassemble to a good fit with exact
    /// zeros at the pruned rows/columns.
    #[test]
    fn pruned_nmf_fits_and_zero_fills() {
        let (m, n) = (9, 11);
        let x = holey_low_rank(m, n, 2, &[4], &[3, 7], 5);
        let grid = Grid2d::new(2, 2);
        let cfg = NmfConfig { rank: 2, max_iters: 200, ..Default::default() };
        let x2 = x.clone();
        let cfg2 = cfg.clone();
        let store = SharedStore::new(SpillMode::Memory);
        let outs = Comm::run(4, move |mut world| {
            let xb = block_of(&x2, grid, world.rank());
            let (mut row, mut col) = grid.make_subcomms(&mut world);
            dist_nmf_pruned(
                &xb, m, n, grid, &mut world, &mut row, &mut col, &NativeBackend, &cfg2,
                &store, "t", true,
            )
            .unwrap()
        });
        let mut w = Mat::zeros(m, 2);
        let mut h = Mat::zeros(2, n);
        for o in &outs {
            assert_eq!(o.w.rows(), o.w_rows.1 - o.w_rows.0);
            for (li, gi) in (o.w_rows.0..o.w_rows.1).enumerate() {
                w.row_mut(gi).copy_from_slice(o.w.row(li));
            }
            for (lb, gb) in (o.h_cols.0..o.h_cols.1).enumerate() {
                for c in 0..2 {
                    h[(c, gb)] = o.ht[(lb, c)];
                }
            }
        }
        // Pruned rows/cols restored as exact zeros.
        assert!(w.row(4).iter().all(|&v| v == 0.0));
        assert!((0..2).all(|k| h[(k, 3)] == 0.0 && h[(k, 7)] == 0.0));
        let mut d = matmul(&w, &h);
        d.sub_assign(&x);
        let rel = d.fro_norm() / x.fro_norm();
        assert!(rel < 0.05, "pruned fit rel err {rel}");
    }

    /// With no zero rows/cols, the wrapper is bitwise-identical to the
    /// plain dist_nmf (the detection reduces do not perturb the math).
    #[test]
    fn identity_passthrough_matches_plain_nmf() {
        let (m, n) = (8, 10);
        let x = holey_low_rank(m, n, 2, &[], &[], 9);
        let grid = Grid2d::new(2, 2);
        let cfg = NmfConfig { rank: 2, max_iters: 40, ..Default::default() };
        let run = |pruned: bool| {
            let x = x.clone();
            let cfg = cfg.clone();
            let store = SharedStore::new(SpillMode::Memory);
            Comm::run(4, move |mut world| {
                let xb = block_of(&x, grid, world.rank());
                let (mut row, mut col) = grid.make_subcomms(&mut world);
                if pruned {
                    dist_nmf_pruned(
                        &xb, m, n, grid, &mut world, &mut row, &mut col, &NativeBackend,
                        &cfg, &store, "t", true,
                    )
                    .unwrap()
                } else {
                    dist_nmf(&xb, m, n, grid, &mut world, &mut row, &mut col, &NativeBackend, &cfg)
                        .unwrap()
                }
            })
        };
        let a = run(true);
        let b = run(false);
        for (oa, ob) in a.iter().zip(&b) {
            assert_eq!(oa.w_rows, ob.w_rows);
            assert_eq!(oa.h_cols, ob.h_cols);
            assert_eq!(oa.w.as_slice(), ob.w.as_slice());
            assert_eq!(oa.ht.as_slice(), ob.ht.as_slice());
        }
    }
}
