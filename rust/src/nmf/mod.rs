//! Distributed non-negative matrix factorization (Algs 3–6 of the paper).
//!
//! `X ≈ W·H` with `X: m×n` 2-D block-distributed over a `p_r × p_c` grid,
//! `W: m×r` row-distributed over all `p` ranks and `H: r×n`
//! column-distributed over all `p` ranks (stored transposed — see
//! [`crate::dist::Layout::HtGrid`]). Three update rules share one SPMD
//! skeleton:
//!
//! * **BCD** (Alg 3): block-coordinate descent with Nesterov-style
//!   extrapolation and an objective-regression correction/restart — the
//!   paper's primary algorithm (Xu & Yin [33]);
//! * **MU**: Lee–Seung multiplicative updates — the paper's comparison
//!   algorithm in Figs 5 and 8c;
//! * **HALS**: hierarchical ALS — the update rule of the NTT-HALS prior
//!   work [25], included as an ablation.
//!
//! One deliberate deviation from the paper's pseudocode: Alg 3 line 9
//! (`W /= ‖W‖₁`) as written rescales `W` without compensating `H`, which
//! changes the objective between lines. We implement the norm-preserving
//! version from the authors' dist-NMF codebase [32]: per-column L1
//! normalization of `W` with the scale folded into the `H`-side state.
//! Disable with `normalize: false` to match the literal pseudocode.

pub mod dist;
pub mod prune;
pub mod workspace;

pub use dist::{dist_nmf, dist_nmf_sparse_ws, dist_nmf_ws, dist_nmf_x_ws, IterObserver, NmfOutput};
pub use prune::{
    detect_zeros, detect_zeros_x, dist_nmf_pruned, dist_nmf_pruned_ws, dist_nmf_pruned_x_obs_ws,
    dist_nmf_pruned_x_ws, PruneMap,
};
pub use workspace::NmfWorkspace;

/// Which update rule to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NmfAlgo {
    /// Block coordinate descent with extrapolation + correction (Alg 3).
    Bcd,
    /// Multiplicative updates (Lee–Seung).
    Mu,
    /// Hierarchical alternating least squares.
    Hals,
}

impl NmfAlgo {
    pub fn name(self) -> &'static str {
        match self {
            NmfAlgo::Bcd => "bcd",
            NmfAlgo::Mu => "mu",
            NmfAlgo::Hals => "hals",
        }
    }
}

impl std::str::FromStr for NmfAlgo {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "bcd" => Ok(NmfAlgo::Bcd),
            "mu" => Ok(NmfAlgo::Mu),
            "hals" => Ok(NmfAlgo::Hals),
            _ => Err(format!("unknown NMF algorithm '{s}' (bcd|mu|hals)")),
        }
    }
}

/// NMF hyper-parameters.
#[derive(Clone, Debug)]
pub struct NmfConfig {
    /// Factorization rank `r`.
    pub rank: usize,
    /// Iteration budget (the paper fixes 100 for the scaling runs).
    pub max_iters: usize,
    /// Extrapolation cap `δ` (Alg 3 lines 23–24).
    pub delta: f64,
    /// Early-stop tolerance on relative objective change (0 = run all
    /// iterations, matching the paper's fixed-iteration timing runs).
    pub tol: f64,
    /// RNG seed for factor initialization.
    pub seed: u64,
    /// Update rule.
    pub algo: NmfAlgo,
    /// Per-column L1 normalization of W (see module docs).
    pub normalize: bool,
}

impl Default for NmfConfig {
    fn default() -> Self {
        NmfConfig {
            rank: 10,
            max_iters: 100,
            delta: 0.9999,
            tol: 0.0,
            seed: 42,
            algo: NmfAlgo::Bcd,
            normalize: true,
        }
    }
}

/// Convergence statistics returned by every rank (identical across ranks).
#[derive(Clone, Debug)]
pub struct NmfStats {
    /// Iterations actually executed.
    pub iters: usize,
    /// Final objective `½‖X − WH‖²`.
    pub objective: f64,
    /// Final relative error `‖X − WH‖ / ‖X‖`.
    pub rel_err: f64,
    /// Number of correction restarts (Alg 3 lines 17–20).
    pub restarts: usize,
    /// Objective after every iteration.
    pub history: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_parse_roundtrip() {
        for a in [NmfAlgo::Bcd, NmfAlgo::Mu, NmfAlgo::Hals] {
            assert_eq!(a.name().parse::<NmfAlgo>().unwrap(), a);
        }
        assert!("xx".parse::<NmfAlgo>().is_err());
    }

    #[test]
    fn default_config_sane() {
        let c = NmfConfig::default();
        assert!(c.rank > 0 && c.max_iters > 0 && c.delta < 1.0);
    }
}
