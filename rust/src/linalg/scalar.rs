//! Scalar abstraction: the library's numeric kernels are generic over
//! `f32`/`f64`. The native backend defaults to `f64` (matching the paper's
//! NumPy implementation); the PJRT/XLA path runs `f32` (the artifact dtype),
//! and parity between the two is asserted in tests.

use std::fmt::{Debug, Display};
use std::iter::Sum;

/// Floating-point element type for all linear-algebra kernels.
pub trait Scalar:
    num_traits::Float
    + num_traits::NumAssign
    + num_traits::FromPrimitive
    + Copy
    + Send
    + Sync
    + Debug
    + Display
    + Default
    + Sum
    + 'static
{
    const NAME: &'static str;

    fn fromf(x: f64) -> Self;
    fn tof(self) -> f64;

    /// Fused multiply-add when available.
    #[inline]
    fn fma(self, a: Self, b: Self) -> Self {
        self.mul_add(a, b)
    }
}

impl Scalar for f32 {
    const NAME: &'static str = "f32";
    #[inline]
    fn fromf(x: f64) -> Self {
        x as f32
    }
    #[inline]
    fn tof(self) -> f64 {
        self as f64
    }
}

impl Scalar for f64 {
    const NAME: &'static str = "f64";
    #[inline]
    fn fromf(x: f64) -> Self {
        x
    }
    #[inline]
    fn tof(self) -> f64 {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn generic_sum<T: Scalar>() -> f64 {
        let xs = [T::fromf(1.5), T::fromf(2.5)];
        xs.iter().copied().sum::<T>().tof()
    }

    #[test]
    fn works_for_both_widths() {
        assert_eq!(generic_sum::<f32>(), 4.0);
        assert_eq!(generic_sum::<f64>(), 4.0);
        assert_eq!(f32::NAME, "f32");
        assert_eq!(f64::NAME, "f64");
    }

    #[test]
    fn fma_matches() {
        let x = 2.0f64;
        assert_eq!(x.fma(3.0, 4.0), 10.0);
    }
}
