//! Runtime-dispatched SIMD microkernels and the kernel-policy surface.
//!
//! This module owns everything about *which* inner kernel runs: the
//! [`KernelPath`] enum (scalar / AVX2 / AVX-512 / NEON), the user-facing
//! [`KernelPolicy`] (`auto` plus forced paths, overridable through the
//! `DNTT_KERNEL` environment variable), the resolved per-call
//! [`KernelCfg`] (path + intra-rank thread count), and the raw-intrinsic
//! tile kernels themselves. `gemm.rs` and `sparse.rs` call back into the
//! dispatchers here; `runtime::kernel` re-exports the policy types for
//! the coordinator/CLI layer.
//!
//! ## Bitwise contract
//!
//! Every path performs the **identical IEEE-754 operation sequence per
//! output element**: load the running value, then for ascending `k` a
//! separate multiply and a separate add (no FMA), then store. SIMD lanes
//! map across *output columns* (the NR direction of the register tile,
//! the `j` direction of the SpMM axpy), which are element-wise
//! independent, so vectorizing changes nothing about any single element's
//! accumulation chain. `_mm256_mul_pd`/`_mm256_add_pd` (and the NEON
//! equivalents) are correctly-rounded per lane exactly like the scalar
//! ops, and zero-padded tile lanes are never stored. Hence every path is
//! **bitwise identical** to the scalar reference — asserted exhaustively
//! in `tests/kernel_conformance.rs`.
//!
//! The pinned toolchain predates AVX-512 intrinsic stabilization, so the
//! `avx512` policy dispatches to the AVX2 tile (`avx512f` implies `avx2`);
//! the policy name is kept so configs stay forward-compatible (see
//! DESIGN.md §3.3).

use super::scalar::Scalar;
use std::any::TypeId;
use std::sync::OnceLock;

/// Microkernel register-tile rows (A sliver height).
pub const MR: usize = 8;
/// Microkernel register-tile columns (B sliver width) — also the f64 SIMD
/// lane count: vector lanes map across output columns.
pub const NR: usize = 4;

/// Environment variable forcing the kernel policy process-wide. Takes
/// precedence over `JobConfig.kernel` / CLI `--kernel` so a CI matrix can
/// force every test through one path. Values: `auto`, `scalar`, `avx2`,
/// `avx512`, `neon`; unknown values warn and are ignored.
pub const DNTT_KERNEL_ENV: &str = "DNTT_KERNEL";

/// An executable microkernel implementation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    /// Portable scalar tile — always available, the bitwise reference.
    Scalar,
    /// AVX2 256-bit tile (x86_64).
    Avx2,
    /// AVX-512 policy name; executes the AVX2 tile on this toolchain
    /// (`avx512f` implies `avx2`, see the module docs).
    Avx512,
    /// NEON 128-bit tile (aarch64).
    Neon,
}

impl KernelPath {
    /// Every path name, in preference order (best last).
    pub const ALL: [KernelPath; 4] =
        [KernelPath::Scalar, KernelPath::Avx2, KernelPath::Avx512, KernelPath::Neon];

    pub fn name(self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Avx2 => "avx2",
            KernelPath::Avx512 => "avx512",
            KernelPath::Neon => "neon",
        }
    }

    /// True when this host can execute the path (runtime feature
    /// detection; cached internally by std).
    pub fn is_available(self) -> bool {
        match self {
            KernelPath::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            KernelPath::Avx2 => std::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            KernelPath::Avx512 => std::is_x86_feature_detected!("avx512f"),
            #[cfg(target_arch = "aarch64")]
            KernelPath::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            _ => false,
        }
    }

    /// Paths this host can execute (always includes `Scalar`).
    pub fn available() -> Vec<KernelPath> {
        Self::ALL.into_iter().filter(|p| p.is_available()).collect()
    }

    /// The best path the host supports — what the `auto` policy picks.
    pub fn best_available() -> KernelPath {
        #[cfg(target_arch = "x86_64")]
        {
            if KernelPath::Avx512.is_available() {
                return KernelPath::Avx512;
            }
            if KernelPath::Avx2.is_available() {
                return KernelPath::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if KernelPath::Neon.is_available() {
                return KernelPath::Neon;
            }
        }
        KernelPath::Scalar
    }

    /// Downgrade to `Scalar` when the host lacks the feature. The kernel
    /// entry points call this once per GEMM/SpMM, which makes any
    /// hand-constructed [`KernelCfg`] safe to execute.
    pub fn validated(self) -> KernelPath {
        if self.is_available() {
            self
        } else {
            KernelPath::Scalar
        }
    }
}

/// User-facing kernel selection: `auto` or a forced path. Set per job
/// (`JobConfig.kernel`, CLI `--kernel`) or process-wide through
/// [`DNTT_KERNEL_ENV`] (which wins). Bitwise-neutral by the module
/// contract, so it is excluded from job fingerprints.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelPolicy {
    /// Pick the best available path at runtime.
    #[default]
    Auto,
    Scalar,
    Avx2,
    Avx512,
    Neon,
}

impl KernelPolicy {
    pub const ALL: [KernelPolicy; 5] = [
        KernelPolicy::Auto,
        KernelPolicy::Scalar,
        KernelPolicy::Avx2,
        KernelPolicy::Avx512,
        KernelPolicy::Neon,
    ];

    pub fn name(self) -> &'static str {
        match self {
            KernelPolicy::Auto => "auto",
            KernelPolicy::Scalar => "scalar",
            KernelPolicy::Avx2 => "avx2",
            KernelPolicy::Avx512 => "avx512",
            KernelPolicy::Neon => "neon",
        }
    }

    /// Parse a policy name (case-insensitive, trimmed).
    pub fn parse(s: &str) -> Option<KernelPolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(KernelPolicy::Auto),
            "scalar" => Some(KernelPolicy::Scalar),
            "avx2" => Some(KernelPolicy::Avx2),
            "avx512" => Some(KernelPolicy::Avx512),
            "neon" => Some(KernelPolicy::Neon),
            _ => None,
        }
    }

    /// The policy forced by [`DNTT_KERNEL_ENV`], if set. Unset or empty
    /// means "no override"; an unknown value warns and is ignored.
    pub fn from_env() -> Option<KernelPolicy> {
        let v = std::env::var(DNTT_KERNEL_ENV).ok()?;
        if v.trim().is_empty() {
            return None;
        }
        let parsed = Self::parse(&v);
        if parsed.is_none() {
            log::warn!(
                "ignoring unknown {DNTT_KERNEL_ENV}={v:?} \
                 (expected auto|scalar|avx2|avx512|neon)"
            );
        }
        parsed
    }

    /// Resolve to an executable path on this host. `Auto` picks the best
    /// available; a forced path the host lacks warns and falls back to
    /// scalar (results are bitwise identical either way).
    pub fn resolve(self) -> KernelPath {
        let forced = |p: KernelPath| {
            if p.is_available() {
                p
            } else {
                log::warn!(
                    "kernel path {} unavailable on this host; falling back to scalar",
                    p.name()
                );
                KernelPath::Scalar
            }
        };
        match self {
            KernelPolicy::Auto => KernelPath::best_available(),
            KernelPolicy::Scalar => KernelPath::Scalar,
            KernelPolicy::Avx2 => forced(KernelPath::Avx2),
            KernelPolicy::Avx512 => forced(KernelPath::Avx512),
            KernelPolicy::Neon => forced(KernelPath::Neon),
        }
    }
}

impl std::str::FromStr for KernelPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::parse(s).ok_or_else(|| {
            format!("unknown kernel policy {s:?} (expected auto|scalar|avx2|avx512|neon)")
        })
    }
}

/// Process-wide default kernel path: the [`DNTT_KERNEL_ENV`] override
/// when set, otherwise `auto`. Cached after first use, so it is what a
/// default-constructed workspace dispatches through.
pub fn default_path() -> KernelPath {
    static DEFAULT: OnceLock<KernelPath> = OnceLock::new();
    *DEFAULT.get_or_init(|| KernelPolicy::from_env().unwrap_or(KernelPolicy::Auto).resolve())
}

/// Resolved per-call kernel selection: which microkernel path runs and how
/// many intra-rank threads partition the output row panels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelCfg {
    pub path: KernelPath,
    /// Intra-rank worker threads over output row panels (1 = serial —
    /// the default and the seed behavior).
    pub threads: usize,
}

impl KernelCfg {
    pub fn new(path: KernelPath, threads: usize) -> Self {
        KernelCfg { path, threads: threads.max(1) }
    }

    /// The always-available reference selection.
    pub fn scalar() -> Self {
        KernelCfg { path: KernelPath::Scalar, threads: 1 }
    }
}

impl Default for KernelCfg {
    /// Env-aware auto path, single-threaded.
    fn default() -> Self {
        KernelCfg { path: default_path(), threads: 1 }
    }
}

// ---------------------------------------------------------------------------
// Type-dispatch plumbing.
// ---------------------------------------------------------------------------

#[inline(always)]
fn is_t<T: 'static, U: 'static>() -> bool {
    TypeId::of::<T>() == TypeId::of::<U>()
}

/// Reinterpret a slice of `T` as `U`. Callers must have proven `T == U`
/// via [`is_t`], which makes the layouts identical.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
fn cast<T: 'static, U: 'static>(s: &[T]) -> &[U] {
    debug_assert!(is_t::<T, U>());
    unsafe { std::slice::from_raw_parts(s.as_ptr() as *const U, s.len()) }
}

/// Reinterpret the accumulator tile. Same `T == U` requirement as [`cast`].
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
fn cast_acc<T: 'static, U: 'static>(acc: &mut [[T; NR]; MR]) -> &mut [[U; NR]; MR] {
    debug_assert!(is_t::<T, U>());
    unsafe { &mut *(acc as *mut [[T; NR]; MR] as *mut [[U; NR]; MR]) }
}

// ---------------------------------------------------------------------------
// GEMM register-tile microkernels.
// ---------------------------------------------------------------------------

/// Scalar reference tile — the exact operation sequence every SIMD path
/// must reproduce bitwise. `pa` holds `kc` groups of [`MR`] A values,
/// `pb` holds `kc` groups of [`NR`] B values; `acc` carries the running C
/// tile. Separate multiply/add (no FMA), ascending `k`.
#[inline(always)]
pub(crate) fn microkernel_scalar<T: Scalar>(
    kc: usize,
    pa: &[T],
    pb: &[T],
    acc: &mut [[T; NR]; MR],
) {
    debug_assert!(pa.len() >= kc * MR && pb.len() >= kc * NR);
    for k in 0..kc {
        let a = &pa[k * MR..k * MR + MR];
        let b = &pb[k * NR..k * NR + NR];
        for i in 0..MR {
            let ai = a[i];
            for j in 0..NR {
                acc[i][j] = acc[i][j] + ai * b[j];
            }
        }
    }
}

/// AVX2 8×4 f64 tile: one 256-bit register (4 lanes = [`NR`] output
/// columns) per tile row. `_mm256_mul_pd`/`_mm256_add_pd` round each lane
/// exactly like the scalar ops, so the tile is bitwise equal to
/// [`microkernel_scalar`].
///
/// # Safety
/// Requires AVX2 (the dispatcher validates the path first).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mk_avx2_f64(kc: usize, pa: &[f64], pb: &[f64], acc: &mut [[f64; NR]; MR]) {
    use std::arch::x86_64::*;
    let mut c = [_mm256_setzero_pd(); MR];
    for (ci, row) in c.iter_mut().zip(acc.iter()) {
        *ci = _mm256_loadu_pd(row.as_ptr());
    }
    for k in 0..kc {
        let b = _mm256_loadu_pd(pb.as_ptr().add(k * NR));
        let a = pa.as_ptr().add(k * MR);
        for (i, ci) in c.iter_mut().enumerate() {
            let ai = _mm256_set1_pd(*a.add(i));
            *ci = _mm256_add_pd(*ci, _mm256_mul_pd(ai, b));
        }
    }
    for (ci, row) in c.iter().zip(acc.iter_mut()) {
        _mm256_storeu_pd(row.as_mut_ptr(), *ci);
    }
}

/// x86 8×4 f32 tile: [`NR`] = 4 f32 lanes fit one 128-bit register, so
/// the f32 tile uses SSE ops (baseline on x86_64) under the AVX2 path.
///
/// # Safety
/// Requires AVX2 (implies SSE; the dispatcher validates the path first).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mk_x86_f32(kc: usize, pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR]) {
    use std::arch::x86_64::*;
    let mut c = [_mm_setzero_ps(); MR];
    for (ci, row) in c.iter_mut().zip(acc.iter()) {
        *ci = _mm_loadu_ps(row.as_ptr());
    }
    for k in 0..kc {
        let b = _mm_loadu_ps(pb.as_ptr().add(k * NR));
        let a = pa.as_ptr().add(k * MR);
        for (i, ci) in c.iter_mut().enumerate() {
            let ai = _mm_set1_ps(*a.add(i));
            *ci = _mm_add_ps(*ci, _mm_mul_ps(ai, b));
        }
    }
    for (ci, row) in c.iter().zip(acc.iter_mut()) {
        _mm_storeu_ps(row.as_mut_ptr(), *ci);
    }
}

/// NEON 8×4 f64 tile: two 128-bit registers (2 lanes each) per tile row.
///
/// # Safety
/// Requires NEON (the dispatcher validates the path first).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn mk_neon_f64(kc: usize, pa: &[f64], pb: &[f64], acc: &mut [[f64; NR]; MR]) {
    use std::arch::aarch64::*;
    let mut lo = [vdupq_n_f64(0.0); MR];
    let mut hi = [vdupq_n_f64(0.0); MR];
    for i in 0..MR {
        lo[i] = vld1q_f64(acc[i].as_ptr());
        hi[i] = vld1q_f64(acc[i].as_ptr().add(2));
    }
    for k in 0..kc {
        let b0 = vld1q_f64(pb.as_ptr().add(k * NR));
        let b1 = vld1q_f64(pb.as_ptr().add(k * NR + 2));
        let a = pa.as_ptr().add(k * MR);
        for i in 0..MR {
            let ai = vdupq_n_f64(*a.add(i));
            lo[i] = vaddq_f64(lo[i], vmulq_f64(ai, b0));
            hi[i] = vaddq_f64(hi[i], vmulq_f64(ai, b1));
        }
    }
    for i in 0..MR {
        vst1q_f64(acc[i].as_mut_ptr(), lo[i]);
        vst1q_f64(acc[i].as_mut_ptr().add(2), hi[i]);
    }
}

/// NEON 8×4 f32 tile: one 128-bit register (4 lanes = [`NR`]) per row.
///
/// # Safety
/// Requires NEON (the dispatcher validates the path first).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn mk_neon_f32(kc: usize, pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR]) {
    use std::arch::aarch64::*;
    let mut c = [vdupq_n_f32(0.0); MR];
    for (ci, row) in c.iter_mut().zip(acc.iter()) {
        *ci = vld1q_f32(row.as_ptr());
    }
    for k in 0..kc {
        let b = vld1q_f32(pb.as_ptr().add(k * NR));
        let a = pa.as_ptr().add(k * MR);
        for (i, ci) in c.iter_mut().enumerate() {
            let ai = vdupq_n_f32(*a.add(i));
            *ci = vaddq_f32(*ci, vmulq_f32(ai, b));
        }
    }
    for (ci, row) in c.iter().zip(acc.iter_mut()) {
        vst1q_f32(row.as_mut_ptr(), *ci);
    }
}

/// Dispatch the 8×4 register-tile microkernel for `path`. `T` other than
/// f32/f64 always runs the scalar tile. Callers must pass a path the host
/// supports (use [`KernelPath::validated`] once per GEMM call).
#[inline]
pub(crate) fn microkernel<T: Scalar>(
    path: KernelPath,
    kc: usize,
    pa: &[T],
    pb: &[T],
    acc: &mut [[T; NR]; MR],
) {
    debug_assert!(path.is_available());
    match path {
        #[cfg(target_arch = "x86_64")]
        KernelPath::Avx2 | KernelPath::Avx512 => {
            if is_t::<T, f64>() {
                unsafe { mk_avx2_f64(kc, cast(pa), cast(pb), cast_acc(acc)) }
            } else if is_t::<T, f32>() {
                unsafe { mk_x86_f32(kc, cast(pa), cast(pb), cast_acc(acc)) }
            } else {
                microkernel_scalar(kc, pa, pb, acc)
            }
        }
        #[cfg(target_arch = "aarch64")]
        KernelPath::Neon => {
            if is_t::<T, f64>() {
                unsafe { mk_neon_f64(kc, cast(pa), cast(pb), cast_acc(acc)) }
            } else if is_t::<T, f32>() {
                unsafe { mk_neon_f32(kc, cast(pa), cast(pb), cast_acc(acc)) }
            } else {
                microkernel_scalar(kc, pa, pb, acc)
            }
        }
        _ => microkernel_scalar(kc, pa, pb, acc),
    }
}

// ---------------------------------------------------------------------------
// SpMM axpy kernels (lanes across output columns).
// ---------------------------------------------------------------------------

fn axpy_scalar_f64(v: f64, x: &[f64], y: &mut [f64]) {
    for (yj, &xj) in y.iter_mut().zip(x) {
        *yj += v * xj;
    }
}

/// # Safety
/// Requires AVX2; `x.len() >= y.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2_f64(v: f64, x: &[f64], y: &mut [f64]) {
    use std::arch::x86_64::*;
    let n = y.len();
    let vv = _mm256_set1_pd(v);
    let mut j = 0;
    while j + 4 <= n {
        let xj = _mm256_loadu_pd(x.as_ptr().add(j));
        let yj = _mm256_loadu_pd(y.as_ptr().add(j));
        _mm256_storeu_pd(y.as_mut_ptr().add(j), _mm256_add_pd(yj, _mm256_mul_pd(vv, xj)));
        j += 4;
    }
    while j < n {
        *y.get_unchecked_mut(j) += v * *x.get_unchecked(j);
        j += 1;
    }
}

/// # Safety
/// Requires NEON; `x.len() >= y.len()`.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy_neon_f64(v: f64, x: &[f64], y: &mut [f64]) {
    use std::arch::aarch64::*;
    let n = y.len();
    let vv = vdupq_n_f64(v);
    let mut j = 0;
    while j + 2 <= n {
        let xj = vld1q_f64(x.as_ptr().add(j));
        let yj = vld1q_f64(y.as_ptr().add(j));
        vst1q_f64(y.as_mut_ptr().add(j), vaddq_f64(yj, vmulq_f64(vv, xj)));
        j += 2;
    }
    while j < n {
        *y.get_unchecked_mut(j) += v * *x.get_unchecked(j);
        j += 1;
    }
}

/// `y[j] += v·x[j]` over contiguous slices — the SpMM inner loop. Lanes
/// map across output columns with an ascending-`j` scalar tail; every
/// element sees the same single multiply/add as the scalar loop, so all
/// paths are bitwise identical.
#[inline]
pub(crate) fn axpy_f64(path: KernelPath, v: f64, x: &[f64], y: &mut [f64]) {
    debug_assert!(x.len() >= y.len());
    match path {
        #[cfg(target_arch = "x86_64")]
        KernelPath::Avx2 | KernelPath::Avx512 => unsafe { axpy_avx2_f64(v, x, y) },
        #[cfg(target_arch = "aarch64")]
        KernelPath::Neon => unsafe { axpy_neon_f64(v, x, y) },
        _ => axpy_scalar_f64(v, x, y),
    }
}

fn axpy_strided_scalar_f64(v: f64, x: &[f64], stride: usize, y: &mut [f64]) {
    for (j, yj) in y.iter_mut().enumerate() {
        *yj += v * x[j * stride];
    }
}

/// # Safety
/// Requires AVX2; `x` must cover index `(y.len()-1)·stride`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_strided_avx2_f64(v: f64, x: &[f64], stride: usize, y: &mut [f64]) {
    use std::arch::x86_64::*;
    let n = y.len();
    let vv = _mm256_set1_pd(v);
    let xp = x.as_ptr();
    let mut j = 0;
    while j + 4 <= n {
        let xj = _mm256_set_pd(
            *xp.add((j + 3) * stride),
            *xp.add((j + 2) * stride),
            *xp.add((j + 1) * stride),
            *xp.add(j * stride),
        );
        let yj = _mm256_loadu_pd(y.as_ptr().add(j));
        _mm256_storeu_pd(y.as_mut_ptr().add(j), _mm256_add_pd(yj, _mm256_mul_pd(vv, xj)));
        j += 4;
    }
    while j < n {
        *y.get_unchecked_mut(j) += v * *xp.add(j * stride);
        j += 1;
    }
}

/// # Safety
/// Requires NEON; `x` must cover index `(y.len()-1)·stride`.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy_strided_neon_f64(v: f64, x: &[f64], stride: usize, y: &mut [f64]) {
    use std::arch::aarch64::*;
    let n = y.len();
    let vv = vdupq_n_f64(v);
    let xp = x.as_ptr();
    let mut j = 0;
    while j + 2 <= n {
        let pair = [*xp.add(j * stride), *xp.add((j + 1) * stride)];
        let xj = vld1q_f64(pair.as_ptr());
        let yj = vld1q_f64(y.as_ptr().add(j));
        vst1q_f64(y.as_mut_ptr().add(j), vaddq_f64(yj, vmulq_f64(vv, xj)));
        j += 2;
    }
    while j < n {
        *y.get_unchecked_mut(j) += v * *xp.add(j * stride);
        j += 1;
    }
}

/// `y[j] += v·x[j·stride]` — the A·Bᵀ column gather. The strided loads
/// stay scalar (gathered into a vector high-to-low so lane `j` holds
/// `x[j·stride]`); only the multiply/add vectorizes, so the per-element
/// sequence still matches the scalar loop bitwise.
#[inline]
pub(crate) fn axpy_strided_f64(path: KernelPath, v: f64, x: &[f64], stride: usize, y: &mut [f64]) {
    debug_assert!(y.is_empty() || (y.len() - 1) * stride < x.len());
    match path {
        #[cfg(target_arch = "x86_64")]
        KernelPath::Avx2 | KernelPath::Avx512 => unsafe { axpy_strided_avx2_f64(v, x, stride, y) },
        #[cfg(target_arch = "aarch64")]
        KernelPath::Neon => unsafe { axpy_strided_neon_f64(v, x, stride, y) },
        _ => axpy_strided_scalar_f64(v, x, stride, y),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn policy_parse_roundtrip_and_rejects_unknown() {
        for p in KernelPolicy::ALL {
            assert_eq!(KernelPolicy::parse(p.name()), Some(p));
            assert_eq!(p.name().parse::<KernelPolicy>().unwrap(), p);
        }
        assert_eq!(KernelPolicy::parse(" AVX2 "), Some(KernelPolicy::Avx2));
        assert!(KernelPolicy::parse("sse9").is_none());
        assert!("sse9".parse::<KernelPolicy>().is_err());
        assert_eq!(KernelPolicy::default(), KernelPolicy::Auto);
    }

    #[test]
    fn availability_is_coherent() {
        assert!(KernelPath::Scalar.is_available());
        let avail = KernelPath::available();
        assert!(avail.contains(&KernelPath::Scalar));
        let best = KernelPath::best_available();
        assert!(best.is_available());
        assert!(avail.contains(&best));
        // Auto resolves to the best path; forced-unavailable downgrades.
        assert_eq!(KernelPolicy::Auto.resolve(), best);
        for p in KernelPath::ALL {
            assert!(p.validated().is_available());
        }
    }

    #[test]
    fn cfg_defaults_and_clamping() {
        let d = KernelCfg::default();
        assert!(d.path.is_available());
        assert_eq!(d.threads, 1);
        assert_eq!(KernelCfg::new(KernelPath::Scalar, 0).threads, 1);
        assert_eq!(KernelCfg::scalar().path, KernelPath::Scalar);
    }

    /// Every available path's tile must be bitwise equal to the scalar
    /// tile on identical packed slivers (mixed-sign data, partial kc).
    #[test]
    fn microkernel_paths_match_scalar_bitwise() {
        let mut rng = Rng::new(42);
        for &kc in &[0usize, 1, 3, 17, 64, 257] {
            let pa: Vec<f64> = (0..kc * MR).map(|_| rng.uniform() * 2.0 - 1.0).collect();
            let pb: Vec<f64> = (0..kc * NR).map(|_| rng.uniform() * 2.0 - 1.0).collect();
            let init: Vec<f64> = (0..MR * NR).map(|_| rng.uniform() * 2.0 - 1.0).collect();
            let load = |acc: &mut [[f64; NR]; MR]| {
                for i in 0..MR {
                    for j in 0..NR {
                        acc[i][j] = init[i * NR + j];
                    }
                }
            };
            let mut reference = [[0.0; NR]; MR];
            load(&mut reference);
            microkernel_scalar(kc, &pa, &pb, &mut reference);
            for path in KernelPath::available() {
                let mut acc = [[0.0; NR]; MR];
                load(&mut acc);
                microkernel(path, kc, &pa, &pb, &mut acc);
                assert_eq!(acc, reference, "path {} kc {}", path.name(), kc);
            }
        }
    }

    #[test]
    fn microkernel_paths_match_scalar_bitwise_f32() {
        let mut rng = Rng::new(43);
        for &kc in &[1usize, 5, 33] {
            let pa: Vec<f32> = (0..kc * MR).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect();
            let pb: Vec<f32> = (0..kc * NR).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect();
            let mut reference = [[0.0f32; NR]; MR];
            microkernel_scalar(kc, &pa, &pb, &mut reference);
            for path in KernelPath::available() {
                let mut acc = [[0.0f32; NR]; MR];
                microkernel(path, kc, &pa, &pb, &mut acc);
                assert_eq!(acc, reference, "path {} kc {}", path.name(), kc);
            }
        }
    }

    /// Contiguous and strided axpy: every path bitwise equal to scalar,
    /// including the non-multiple-of-lane tails.
    #[test]
    fn axpy_paths_match_scalar_bitwise() {
        let mut rng = Rng::new(44);
        for &n in &[0usize, 1, 2, 3, 4, 5, 7, 8, 31, 100] {
            let v = rng.uniform() * 2.0 - 1.0;
            let x: Vec<f64> = (0..n).map(|_| rng.uniform() * 2.0 - 1.0).collect();
            let y0: Vec<f64> = (0..n).map(|_| rng.uniform() * 2.0 - 1.0).collect();
            let mut reference = y0.clone();
            axpy_scalar_f64(v, &x, &mut reference);
            for path in KernelPath::available() {
                let mut y = y0.clone();
                axpy_f64(path, v, &x, &mut y);
                assert_eq!(y, reference, "axpy path {} n {}", path.name(), n);
            }
            // Strided: x laid out with stride 3.
            let stride = 3;
            let xs: Vec<f64> =
                (0..n.saturating_mul(stride)).map(|_| rng.uniform() * 2.0 - 1.0).collect();
            let mut sref = y0.clone();
            axpy_strided_scalar_f64(v, &xs, stride, &mut sref);
            for path in KernelPath::available() {
                let mut y = y0.clone();
                axpy_strided_f64(path, v, &xs, stride, &mut y);
                assert_eq!(y, sref, "strided axpy path {} n {}", path.name(), n);
            }
        }
    }
}
