//! Sparse (CSR) matrices and the SpMM kernels of the NMF hot path.
//!
//! [`SparseMat`] mirrors [`Mat`] for the local stage-matrix block `X`
//! when the input tensor is sparse: row-major CSR (`row_ptr` /
//! `col_idx` / `vals`, columns sorted within each row). The three SpMM
//! kernels mirror the dense GEMM layout suite — `A·B` (X·Hᵀ) and
//! `Aᵀ·B` (Wᵀ·X, transposed) are what the NMF dispatch routes through
//! the backend; `A·Bᵀ` completes the layout set for parity with
//! [`crate::linalg::gemm`] (no NMF consumer yet). Each has an `_into`
//! form that writes a caller buffer with **zero allocation**, so they
//! slot into the [`crate::nmf::NmfWorkspace`] discipline unchanged.
//!
//! ## Reproducibility contract
//!
//! Each kernel accumulates every output element in ascending `k` order
//! with separate multiply and add (no FMA), exactly like
//! [`crate::linalg::gemm::matmul_naive`], merely *skipping* terms whose
//! `A` entry is an exact zero. A skipped term contributes `+0.0` to a
//! non-negative running sum, which leaves the sum bitwise unchanged — so
//! on non-negative operands (the NMF case: `X ≥ 0`, factors ≥ 0) the
//! sparse kernels are **bitwise identical** to the dense naive/packed
//! kernels (asserted in the unit tests below and relied on by
//! `tests/sparse_equivalence.rs`). On mixed-sign operands agreement is
//! exact-to-roundoff but the `-0.0`/`+0.0` distinction may differ.
//!
//! Each kernel also has a `_with` form taking a
//! [`KernelCfg`]: the inner axpy dispatches through the runtime-selected
//! SIMD path (lanes across output columns) and the output rows are
//! optionally partitioned over a scoped thread pool. Both knobs preserve
//! the per-element accumulation sequence, so every path/thread
//! combination stays bitwise identical to the serial scalar `_into` form
//! (asserted in `tests/kernel_conformance.rs`).
//!
//! [`DenseOrSparse`] is the per-chunk dispatch type: one local block,
//! stored whichever way the reshape decided (see
//! [`crate::dist::dist_reshape_x`]), with the NMF choosing the kernel
//! per call.

use super::matrix::Mat;
use super::simd::{axpy_f64, axpy_strided_f64, KernelCfg, KernelPath};
use crate::error::{DnttError, Result};

/// Row-major CSR sparse matrix of `f64` (the local sparse `X` block).
#[derive(Clone, Debug, PartialEq)]
pub struct SparseMat {
    rows: usize,
    cols: usize,
    /// `rows + 1` offsets into `col_idx` / `vals`.
    row_ptr: Vec<usize>,
    /// Column of each nonzero, sorted within each row.
    col_idx: Vec<usize>,
    vals: Vec<f64>,
}

impl SparseMat {
    /// Build from COO triplets (any order). Duplicate coordinates are
    /// rejected; explicit zeros are dropped after the duplicate check.
    pub fn from_coo(
        rows: usize,
        cols: usize,
        mut entries: Vec<(usize, usize, f64)>,
    ) -> Result<SparseMat> {
        for &(i, j, _) in &entries {
            if i >= rows || j >= cols {
                return Err(DnttError::shape(format!(
                    "sparse mat: coordinate ({i}, {j}) out of range for {rows}x{cols}"
                )));
            }
        }
        entries.sort_unstable_by_key(|&(i, j, _)| (i, j));
        for pair in entries.windows(2) {
            if (pair[0].0, pair[0].1) == (pair[1].0, pair[1].1) {
                return Err(DnttError::shape(format!(
                    "sparse mat: duplicate coordinate ({}, {})",
                    pair[0].0, pair[0].1
                )));
            }
        }
        let mut row_ptr = vec![0usize; rows + 1];
        let mut col_idx = Vec::with_capacity(entries.len());
        let mut vals = Vec::with_capacity(entries.len());
        for (i, j, v) in entries {
            if v != 0.0 {
                row_ptr[i + 1] += 1;
                col_idx.push(j);
                vals.push(v);
            }
        }
        for i in 0..rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        Ok(SparseMat { rows, cols, row_ptr, col_idx, vals })
    }

    /// Build from sorted row-major *linear* indices (`lin = i·cols + j`,
    /// strictly increasing) — the form sparse chunks arrive in from the
    /// chunk store. Explicit zeros are dropped.
    pub fn from_linear(rows: usize, cols: usize, idx: &[usize], vals: &[f64]) -> Result<SparseMat> {
        if idx.len() != vals.len() {
            return Err(DnttError::shape(format!(
                "sparse mat: {} indices vs {} values",
                idx.len(),
                vals.len()
            )));
        }
        let total = rows * cols;
        let mut prev: Option<usize> = None;
        for &lin in idx {
            if lin >= total {
                return Err(DnttError::shape(format!(
                    "sparse mat: linear index {lin} out of range for {rows}x{cols}"
                )));
            }
            if let Some(p) = prev {
                if lin <= p {
                    return Err(DnttError::shape(
                        "sparse mat: linear indices not strictly increasing",
                    ));
                }
            }
            prev = Some(lin);
        }
        let mut row_ptr = vec![0usize; rows + 1];
        let mut col_idx = Vec::with_capacity(idx.len());
        let mut out_vals = Vec::with_capacity(idx.len());
        for (&lin, &v) in idx.iter().zip(vals) {
            if v != 0.0 {
                row_ptr[lin / cols + 1] += 1;
                col_idx.push(lin % cols);
                out_vals.push(v);
            }
        }
        for i in 0..rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        Ok(SparseMat { rows, cols, row_ptr, col_idx, vals: out_vals })
    }

    /// Sparsify a dense matrix (exact zeros dropped).
    pub fn from_dense(m: &Mat<f64>) -> SparseMat {
        let mut row_ptr = Vec::with_capacity(m.rows() + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0);
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(j);
                    vals.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        SparseMat { rows: m.rows(), cols: m.cols(), row_ptr, col_idx, vals }
    }

    /// Densify.
    pub fn to_dense(&self) -> Mat<f64> {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let orow = out.row_mut(i);
            for (&j, &v) in cols.iter().zip(vals) {
                orow[j] = v;
            }
        }
        out
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// `nnz / (rows·cols)` (1.0 for an empty shape).
    pub fn density(&self) -> f64 {
        let total = self.rows * self.cols;
        if total == 0 {
            1.0
        } else {
            self.nnz() as f64 / total as f64
        }
    }

    /// Row `i`'s nonzeros as `(sorted columns, values)`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (a, b) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[a..b], &self.vals[a..b])
    }

    /// Element `(i, j)` (0.0 when not stored).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&j) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    /// Visit every nonzero in row-major order.
    pub fn for_each_nz(&self, mut f: impl FnMut(usize, usize, f64)) {
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                f(i, j, v);
            }
        }
    }

    /// Squared Frobenius norm.
    pub fn fro_norm_sq(&self) -> f64 {
        self.vals.iter().map(|&v| v * v).sum()
    }

    /// True if all stored entries are ≥ 0 (the nTT invariant).
    pub fn is_nonneg(&self) -> bool {
        self.vals.iter().all(|&v| v >= 0.0)
    }
}

// ---------------------------------------------------------------------------
// SpMM kernels (the three NMF GEMM layouts).
// ---------------------------------------------------------------------------

/// `C = A · B` (sparse `A: m×k`, dense `B: k×n`) into a caller buffer.
/// Zeroes `C` first; per output element the accumulation runs in
/// ascending `k` order with separate multiply/add (see the module-level
/// reproducibility contract). No allocation.
pub fn sp_matmul_into(a: &SparseMat, b: &Mat<f64>, c: &mut Mat<f64>) {
    assert_eq!(a.cols(), b.rows(), "sp_matmul: inner dims");
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.cols()), "sp_matmul: bad out shape");
    let n = b.cols();
    for i in 0..a.rows() {
        let crow = c.row_mut(i);
        crow.fill(0.0);
        let (cols, vals) = a.row(i);
        for (&k, &v) in cols.iter().zip(vals) {
            let brow = b.row(k);
            for j in 0..n {
                crow[j] += v * brow[j];
            }
        }
    }
}

/// `C = A · B` into a fresh matrix.
pub fn sp_matmul(a: &SparseMat, b: &Mat<f64>) -> Mat<f64> {
    let mut c = Mat::zeros(a.rows(), b.cols());
    sp_matmul_into(a, b, &mut c);
    c
}

/// `C = Aᵀ · B` (sparse `A: k×m`, dense `B: k×n`) into a caller buffer —
/// the `Xᵀ·W` layout. Zeroes `C` first; ascending-`k` accumulation; no
/// allocation.
pub fn sp_matmul_at_b_into(a: &SparseMat, b: &Mat<f64>, c: &mut Mat<f64>) {
    assert_eq!(a.rows(), b.rows(), "sp_matmul_at_b: inner dims");
    assert_eq!((c.rows(), c.cols()), (a.cols(), b.cols()), "sp_matmul_at_b: bad out shape");
    for x in c.as_mut_slice() {
        *x = 0.0;
    }
    let n = b.cols();
    for k in 0..a.rows() {
        let (cols, vals) = a.row(k);
        let brow = b.row(k);
        for (&p, &v) in cols.iter().zip(vals) {
            let crow = c.row_mut(p);
            for j in 0..n {
                crow[j] += v * brow[j];
            }
        }
    }
}

/// `C = Aᵀ · B` into a fresh matrix.
pub fn sp_matmul_at_b(a: &SparseMat, b: &Mat<f64>) -> Mat<f64> {
    let mut c = Mat::zeros(a.cols(), b.cols());
    sp_matmul_at_b_into(a, b, &mut c);
    c
}

/// `C = A · Bᵀ` (sparse `A: m×k`, dense `B: q×k`) into a caller buffer.
/// Zeroes `C` first; ascending-`k` accumulation; no allocation.
pub fn sp_matmul_a_bt_into(a: &SparseMat, b: &Mat<f64>, c: &mut Mat<f64>) {
    assert_eq!(a.cols(), b.cols(), "sp_matmul_a_bt: inner dims");
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.rows()), "sp_matmul_a_bt: bad out shape");
    for i in 0..a.rows() {
        let crow = c.row_mut(i);
        crow.fill(0.0);
        let (cols, vals) = a.row(i);
        for (&k, &v) in cols.iter().zip(vals) {
            for (j, cj) in crow.iter_mut().enumerate() {
                *cj += v * b[(j, k)];
            }
        }
    }
}

/// `C = A · Bᵀ` into a fresh matrix.
pub fn sp_matmul_a_bt(a: &SparseMat, b: &Mat<f64>) -> Mat<f64> {
    let mut c = Mat::zeros(a.rows(), b.rows());
    sp_matmul_a_bt_into(a, b, &mut c);
    c
}

// ---------------------------------------------------------------------------
// Kernel-dispatched SpMM (`_with` forms): SIMD axpy + intra-rank threads.
// ---------------------------------------------------------------------------

/// Worker-thread count for a row partition: at least 1, at most one
/// thread per output row (deterministic in `(threads, rows)` only).
fn thread_count(threads: usize, rows: usize) -> usize {
    threads.clamp(1, rows.max(1))
}

/// Rows `[r0, r1)` of `C = A·B` into `out` (row-major `(r1-r0)×n`).
fn sp_matmul_rows(
    a: &SparseMat,
    b: &Mat<f64>,
    out: &mut [f64],
    r0: usize,
    r1: usize,
    path: KernelPath,
) {
    let n = b.cols();
    for i in r0..r1 {
        let crow = &mut out[(i - r0) * n..(i - r0) * n + n];
        crow.fill(0.0);
        let (cols, vals) = a.row(i);
        for (&k, &v) in cols.iter().zip(vals) {
            axpy_f64(path, v, b.row(k), crow);
        }
    }
}

/// [`sp_matmul_into`] with an explicit kernel selection: the inner axpy
/// runs on the selected SIMD path (lanes across output columns) and the
/// output rows split over `sel.threads` scoped threads. Bitwise identical
/// to the serial scalar form for every selection.
pub fn sp_matmul_with(a: &SparseMat, b: &Mat<f64>, c: &mut Mat<f64>, sel: KernelCfg) {
    assert_eq!(a.cols(), b.rows(), "sp_matmul: inner dims");
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.cols()), "sp_matmul: bad out shape");
    let path = sel.path.validated();
    let nt = thread_count(sel.threads, a.rows());
    if nt <= 1 {
        sp_matmul_rows(a, b, c.as_mut_slice(), 0, a.rows(), path);
        return;
    }
    let n = b.cols();
    let chunk = a.rows().div_ceil(nt);
    std::thread::scope(|s| {
        let mut rest = c.as_mut_slice();
        let mut base = 0;
        while base < a.rows() {
            let rows = chunk.min(a.rows() - base);
            let (mine, tail) = std::mem::take(&mut rest).split_at_mut(rows * n);
            rest = tail;
            let r0 = base;
            s.spawn(move || sp_matmul_rows(a, b, mine, r0, r0 + rows, path));
            base += rows;
        }
    });
}

/// Output rows `[p0, p1)` of `C = Aᵀ·B` into `out` (row-major
/// `(p1-p0)×n`): scan every CSR row `k` in ascending order and apply only
/// the nonzeros whose column lands in this chunk (binary search on the
/// sorted per-row columns). Per output element the contribution order is
/// ascending `k` — identical to the serial kernel.
fn sp_at_b_cols(
    a: &SparseMat,
    b: &Mat<f64>,
    out: &mut [f64],
    p0: usize,
    p1: usize,
    path: KernelPath,
) {
    out.fill(0.0);
    let n = b.cols();
    for k in 0..a.rows() {
        let (cols, vals) = a.row(k);
        let lo = cols.partition_point(|&p| p < p0);
        let hi = cols.partition_point(|&p| p < p1);
        if lo == hi {
            continue;
        }
        let brow = b.row(k);
        for (&p, &v) in cols[lo..hi].iter().zip(&vals[lo..hi]) {
            let crow = &mut out[(p - p0) * n..(p - p0) * n + n];
            axpy_f64(path, v, brow, crow);
        }
    }
}

/// [`sp_matmul_at_b_into`] with an explicit kernel selection. Threads own
/// disjoint *output*-row ranges (columns of the CSR matrix), each
/// scanning all CSR rows in ascending `k`, so the per-element order — and
/// hence the result — is bitwise identical to the serial scalar form.
pub fn sp_matmul_at_b_with(a: &SparseMat, b: &Mat<f64>, c: &mut Mat<f64>, sel: KernelCfg) {
    assert_eq!(a.rows(), b.rows(), "sp_matmul_at_b: inner dims");
    assert_eq!((c.rows(), c.cols()), (a.cols(), b.cols()), "sp_matmul_at_b: bad out shape");
    let path = sel.path.validated();
    let nt = thread_count(sel.threads, a.cols());
    if nt <= 1 {
        sp_at_b_cols(a, b, c.as_mut_slice(), 0, a.cols(), path);
        return;
    }
    let n = b.cols();
    let chunk = a.cols().div_ceil(nt);
    std::thread::scope(|s| {
        let mut rest = c.as_mut_slice();
        let mut base = 0;
        while base < a.cols() {
            let rows = chunk.min(a.cols() - base);
            let (mine, tail) = std::mem::take(&mut rest).split_at_mut(rows * n);
            rest = tail;
            let p0 = base;
            s.spawn(move || sp_at_b_cols(a, b, mine, p0, p0 + rows, path));
            base += rows;
        }
    });
}

/// Rows `[r0, r1)` of `C = A·Bᵀ` into `out` (row-major `(r1-r0)×q`): the
/// column gather runs through the strided axpy.
fn sp_a_bt_rows(
    a: &SparseMat,
    b: &Mat<f64>,
    out: &mut [f64],
    r0: usize,
    r1: usize,
    path: KernelPath,
) {
    let q = b.rows();
    let stride = b.cols();
    out.fill(0.0);
    if q == 0 {
        return;
    }
    for i in r0..r1 {
        let crow = &mut out[(i - r0) * q..(i - r0) * q + q];
        let (cols, vals) = a.row(i);
        for (&k, &v) in cols.iter().zip(vals) {
            axpy_strided_f64(path, v, &b.as_slice()[k..], stride, crow);
        }
    }
}

/// [`sp_matmul_a_bt_into`] with an explicit kernel selection (row
/// partition like [`sp_matmul_with`]; strided-gather axpy). Bitwise
/// identical to the serial scalar form for every selection.
pub fn sp_matmul_a_bt_with(a: &SparseMat, b: &Mat<f64>, c: &mut Mat<f64>, sel: KernelCfg) {
    assert_eq!(a.cols(), b.cols(), "sp_matmul_a_bt: inner dims");
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.rows()), "sp_matmul_a_bt: bad out shape");
    let path = sel.path.validated();
    let nt = thread_count(sel.threads, a.rows());
    if nt <= 1 {
        sp_a_bt_rows(a, b, c.as_mut_slice(), 0, a.rows(), path);
        return;
    }
    let q = b.rows();
    let chunk = a.rows().div_ceil(nt);
    std::thread::scope(|s| {
        let mut rest = c.as_mut_slice();
        let mut base = 0;
        while base < a.rows() {
            let rows = chunk.min(a.rows() - base);
            let (mine, tail) = std::mem::take(&mut rest).split_at_mut(rows * q);
            rest = tail;
            let r0 = base;
            s.spawn(move || sp_a_bt_rows(a, b, mine, r0, r0 + rows, path));
            base += rows;
        }
    });
}

// ---------------------------------------------------------------------------
// Per-chunk dispatch.
// ---------------------------------------------------------------------------

/// One local matrix block, dense or sparse — the per-chunk dispatch type
/// the distributed NMF consumes (see [`crate::nmf::dist_nmf_x_ws`]).
pub enum DenseOrSparse {
    Dense(Mat<f64>),
    Sparse(SparseMat),
}

impl DenseOrSparse {
    pub fn rows(&self) -> usize {
        match self {
            DenseOrSparse::Dense(m) => m.rows(),
            DenseOrSparse::Sparse(s) => s.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            DenseOrSparse::Dense(m) => m.cols(),
            DenseOrSparse::Sparse(s) => s.cols(),
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows(), self.cols())
    }

    /// Stored nonzeros (dense blocks count every element).
    pub fn nnz(&self) -> usize {
        match self {
            DenseOrSparse::Dense(m) => m.len(),
            DenseOrSparse::Sparse(s) => s.nnz(),
        }
    }

    /// Storage density (1.0 for dense blocks).
    pub fn density(&self) -> f64 {
        match self {
            DenseOrSparse::Dense(_) => 1.0,
            DenseOrSparse::Sparse(s) => s.density(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, DenseOrSparse::Sparse(_))
    }

    pub fn fro_norm_sq(&self) -> f64 {
        match self {
            DenseOrSparse::Dense(m) => m.fro_norm_sq(),
            DenseOrSparse::Sparse(s) => s.fro_norm_sq(),
        }
    }

    /// Densified copy (the sparse → dense escape hatch, e.g. for the SVD
    /// rank selection which has no sparse path).
    pub fn to_dense(&self) -> Mat<f64> {
        match self {
            DenseOrSparse::Dense(m) => m.clone(),
            DenseOrSparse::Sparse(s) => s.to_dense(),
        }
    }

    /// Borrow the dense form, materializing a sparse block only when
    /// needed — the drivers' rank-selection path (the SVD has no sparse
    /// implementation). Densifying a sparse block allocates its full
    /// dense size, so callers on the out-of-core path should prefer
    /// fixed ranks; a warning is logged when the escape hatch fires.
    pub fn dense_view(&self) -> std::borrow::Cow<'_, Mat<f64>> {
        match self {
            DenseOrSparse::Dense(m) => std::borrow::Cow::Borrowed(m),
            DenseOrSparse::Sparse(s) => {
                log::warn!(
                    "densifying a sparse {}x{} block (no sparse SVD path); \
                     pass fixed ranks to avoid the dense allocation",
                    s.rows(),
                    s.cols()
                );
                std::borrow::Cow::Owned(s.to_dense())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_naive;
    use crate::util::rng::Rng;

    /// Dense non-negative matrix with exact zeros at the given density.
    fn sparse_rand(rows: usize, cols: usize, density: f64, rng: &mut Rng) -> Mat<f64> {
        Mat::from_fn(rows, cols, |_, _| {
            if rng.uniform() < density {
                rng.uniform() + 0.1
            } else {
                0.0
            }
        })
    }

    #[test]
    fn from_coo_rejects_duplicates_and_ranges() {
        assert!(SparseMat::from_coo(2, 2, vec![(0, 0, 1.0), (0, 0, 2.0)]).is_err());
        assert!(SparseMat::from_coo(2, 2, vec![(2, 0, 1.0)]).is_err());
        assert!(SparseMat::from_coo(2, 2, vec![(0, 2, 1.0)]).is_err());
        // Duplicate rejected even when one value is an explicit zero.
        assert!(SparseMat::from_coo(2, 2, vec![(1, 1, 0.0), (1, 1, 3.0)]).is_err());
        let m = SparseMat::from_coo(2, 3, vec![(1, 2, 3.0), (0, 1, 2.0), (1, 0, 0.0)]).unwrap();
        assert_eq!(m.nnz(), 2); // explicit zero dropped
        assert_eq!(m.get(0, 1), 2.0);
        assert_eq!(m.get(1, 0), 0.0);
        assert_eq!(m.to_dense().as_slice(), &[0.0, 2.0, 0.0, 0.0, 0.0, 3.0]);
    }

    #[test]
    fn from_linear_matches_from_dense() {
        let mut rng = Rng::new(3);
        let d = sparse_rand(7, 5, 0.4, &mut rng);
        let s1 = SparseMat::from_dense(&d);
        let mut idx = Vec::new();
        let mut vals = Vec::new();
        for (k, &v) in d.as_slice().iter().enumerate() {
            if v != 0.0 {
                idx.push(k);
                vals.push(v);
            }
        }
        let s2 = SparseMat::from_linear(7, 5, &idx, &vals).unwrap();
        assert_eq!(s1, s2);
        assert!(SparseMat::from_linear(2, 2, &[1, 1], &[1.0, 2.0]).is_err());
        assert!(SparseMat::from_linear(2, 2, &[4], &[1.0]).is_err());
    }

    #[test]
    fn density_edges() {
        let empty = SparseMat::from_coo(3, 4, vec![]).unwrap();
        assert_eq!((empty.nnz(), empty.density()), (0, 0.0));
        assert!(empty.is_nonneg());
        let full = SparseMat::from_dense(&Mat::filled(3, 4, 2.0));
        assert_eq!(full.density(), 1.0);
        let degenerate = SparseMat::from_coo(0, 5, vec![]).unwrap();
        assert_eq!(degenerate.density(), 1.0);
    }

    /// On non-negative operands every kernel is bitwise equal to the dense
    /// naive reference (same ascending-k mul/add sequence, skipped terms
    /// contribute +0.0).
    #[test]
    fn kernels_match_naive_bitwise_on_nonneg() {
        let mut rng = Rng::new(11);
        for &density in &[0.0, 0.05, 0.3, 1.0] {
            let a = sparse_rand(13, 17, density, &mut rng);
            let sa = SparseMat::from_dense(&a);
            let b = Mat::<f64>::rand_uniform(17, 6, &mut rng);
            assert_eq!(
                sp_matmul(&sa, &b).as_slice(),
                matmul_naive(&a, &b).as_slice(),
                "A*B at density {density}"
            );
            let bt = Mat::<f64>::rand_uniform(13, 6, &mut rng);
            assert_eq!(
                sp_matmul_at_b(&sa, &bt).as_slice(),
                matmul_naive(&a.transpose(), &bt).as_slice(),
                "At*B at density {density}"
            );
            let bq = Mat::<f64>::rand_uniform(6, 17, &mut rng);
            assert_eq!(
                sp_matmul_a_bt(&sa, &bq).as_slice(),
                matmul_naive(&a, &bq.transpose()).as_slice(),
                "A*Bt at density {density}"
            );
        }
    }

    /// Every kernel path × thread count must reproduce the serial scalar
    /// `_into` kernels bitwise (same ascending-k per-element order).
    #[test]
    fn with_kernels_match_into_bitwise_all_paths() {
        let mut rng = Rng::new(17);
        for &density in &[0.0, 0.1, 0.6] {
            let a = sparse_rand(29, 23, density, &mut rng);
            let sa = SparseMat::from_dense(&a);
            let b = Mat::<f64>::rand_uniform(23, 9, &mut rng);
            let bt = Mat::<f64>::rand_uniform(29, 9, &mut rng);
            let bq = Mat::<f64>::rand_uniform(9, 23, &mut rng);
            let (r1, r2, r3) = (sp_matmul(&sa, &b), sp_matmul_at_b(&sa, &bt), sp_matmul_a_bt(&sa, &bq));
            for path in KernelPath::available() {
                for threads in [1usize, 2, 4, 8] {
                    let sel = KernelCfg::new(path, threads);
                    let mut c = Mat::filled(29, 9, 5.0);
                    sp_matmul_with(&sa, &b, &mut c, sel);
                    assert_eq!(c.as_slice(), r1.as_slice(), "A*B {} t{threads}", path.name());
                    let mut c = Mat::filled(23, 9, 5.0);
                    sp_matmul_at_b_with(&sa, &bt, &mut c, sel);
                    assert_eq!(c.as_slice(), r2.as_slice(), "At*B {} t{threads}", path.name());
                    let mut c = Mat::filled(29, 9, 5.0);
                    sp_matmul_a_bt_with(&sa, &bq, &mut c, sel);
                    assert_eq!(c.as_slice(), r3.as_slice(), "A*Bt {} t{threads}", path.name());
                }
            }
        }
    }

    #[test]
    fn into_kernels_overwrite_stale_buffers() {
        let mut rng = Rng::new(21);
        let a = sparse_rand(9, 8, 0.3, &mut rng);
        let sa = SparseMat::from_dense(&a);
        let b = Mat::<f64>::rand_uniform(8, 4, &mut rng);
        let mut c = Mat::filled(9, 4, 7.0); // stale contents must vanish
        sp_matmul_into(&sa, &b, &mut c);
        assert_eq!(c.as_slice(), matmul_naive(&a, &b).as_slice());
        let bt = Mat::<f64>::rand_uniform(9, 4, &mut rng);
        let mut c2 = Mat::filled(8, 4, -3.0);
        sp_matmul_at_b_into(&sa, &bt, &mut c2);
        assert_eq!(c2.as_slice(), matmul_naive(&a.transpose(), &bt).as_slice());
    }

    #[test]
    fn dense_or_sparse_dispatch() {
        let mut rng = Rng::new(31);
        let d = sparse_rand(5, 6, 0.2, &mut rng);
        let x = DenseOrSparse::Sparse(SparseMat::from_dense(&d));
        assert_eq!(x.shape(), (5, 6));
        assert!(x.is_sparse());
        assert!(x.density() < 1.0);
        assert_eq!(x.fro_norm_sq(), d.fro_norm_sq());
        assert_eq!(x.to_dense().as_slice(), d.as_slice());
        let y = DenseOrSparse::Dense(d.clone());
        assert!(!y.is_sparse());
        assert_eq!(y.density(), 1.0);
        assert_eq!(y.nnz(), 30);
    }
}
