//! Dense linear algebra substrate.
//!
//! The paper's implementation leans on NumPy/LAPACK; everything it uses is
//! re-implemented here: row-major matrices, blocked GEMM variants shaped
//! like the NMF kernels (`X·Hᵀ`, `Wᵀ·X`, Gram products), Jacobi symmetric
//! eigendecomposition, one-sided-Jacobi thin SVD, Householder QR.

pub mod eig;
pub mod gemm;
pub mod matrix;
pub mod qr;
pub mod scalar;
pub mod svd;

pub use gemm::GemmWorkspace;
pub use matrix::Mat;
pub use scalar::Scalar;
