//! Dense linear algebra substrate.
//!
//! The paper's implementation leans on NumPy/LAPACK; everything it uses is
//! re-implemented here: row-major matrices, blocked GEMM variants shaped
//! like the NMF kernels (`X·Hᵀ`, `Wᵀ·X`, Gram products), CSR sparse
//! matrices with the matching SpMM kernels ([`sparse`]), Jacobi symmetric
//! eigendecomposition, one-sided-Jacobi thin SVD, Householder QR. The
//! GEMM/SpMM inner kernels dispatch through runtime-selected SIMD paths
//! with optional intra-rank threading ([`simd`]) — every path is bitwise
//! identical to the scalar reference.

pub mod eig;
pub mod gemm;
pub mod matrix;
pub mod qr;
pub mod scalar;
pub mod simd;
pub mod sparse;
pub mod svd;

pub use gemm::GemmWorkspace;
pub use matrix::Mat;
pub use scalar::Scalar;
pub use simd::{KernelCfg, KernelPath, KernelPolicy};
pub use sparse::{DenseOrSparse, SparseMat};
