//! Matrix-multiplication kernels.
//!
//! These are the MM/GR hot paths of the distributed NMF (Algs 3–6): local
//! `X·Hᵀ`, `Wᵀ·X`, and Gram products `M·Mᵀ` / `Mᵀ·M`. The implementation is
//! a cache-blocked i-k-j loop with the innermost loop written over
//! contiguous rows so LLVM autovectorizes it; `matmul_at_b` avoids an
//! explicit transpose by walking A column-wise per block. Tuning history
//! lives in EXPERIMENTS.md §Perf.

use super::matrix::Mat;
use super::scalar::Scalar;

/// Cache block size along the k dimension (L1-friendly for f64).
const KB: usize = 64;
/// Cache block size along the i dimension.
const IB: usize = 64;

/// `C = A · B` into a fresh matrix.
pub fn matmul<T: Scalar>(a: &Mat<T>, b: &Mat<T>) -> Mat<T> {
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// `C = A · B` into a caller-provided buffer (zeroed first; no allocation).
pub fn matmul_into<T: Scalar>(a: &Mat<T>, b: &Mat<T>, c: &mut Mat<T>) {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dims {}x{} · {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.cols()), "matmul: bad out shape");
    for x in c.as_mut_slice() {
        *x = T::zero();
    }
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    // Blocked i-k-j: C[i,:] += A[i,kk] * B[kk,:]; inner loop contiguous in C and B.
    for i0 in (0..m).step_by(IB) {
        let i1 = (i0 + IB).min(m);
        for k0 in (0..k).step_by(KB) {
            let k1 = (k0 + KB).min(k);
            for i in i0..i1 {
                let arow = a.row(i);
                let crow = c.row_mut(i);
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == T::zero() {
                        continue;
                    }
                    let brow = b.row(kk);
                    // Contiguous axpy over row of B into row of C.
                    for j in 0..n {
                        crow[j] = brow[j].fma(aik, crow[j]);
                    }
                }
            }
        }
    }
}

/// `C = Aᵀ · B` (A is m×r stored row-major; result r×n). Used for `Wᵀ·X`.
pub fn matmul_at_b<T: Scalar>(a: &Mat<T>, b: &Mat<T>) -> Mat<T> {
    let mut c = Mat::zeros(a.cols(), b.cols());
    matmul_at_b_into(a, b, &mut c);
    c
}

/// `C = Aᵀ · B` into a caller buffer.
pub fn matmul_at_b_into<T: Scalar>(a: &Mat<T>, b: &Mat<T>, c: &mut Mat<T>) {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b: inner dims");
    assert_eq!((c.rows(), c.cols()), (a.cols(), b.cols()));
    for x in c.as_mut_slice() {
        *x = T::zero();
    }
    let (k, r, n) = (a.rows(), a.cols(), b.cols());
    // For each shared row `kk`: C[p,:] += A[kk,p] * B[kk,:]  — all contiguous.
    for kk in 0..k {
        let arow = a.row(kk);
        let brow = b.row(kk);
        for p in 0..r {
            let apk = arow[p];
            if apk == T::zero() {
                continue;
            }
            let crow = c.row_mut(p);
            for j in 0..n {
                crow[j] = brow[j].fma(apk, crow[j]);
            }
        }
    }
}

/// `C = A · Bᵀ` (dot products of rows; result m×q). Used for `X·Hᵀ`.
pub fn matmul_a_bt<T: Scalar>(a: &Mat<T>, b: &Mat<T>) -> Mat<T> {
    let mut c = Mat::zeros(a.rows(), b.rows());
    matmul_a_bt_into(a, b, &mut c);
    c
}

/// `C = A · Bᵀ` into a caller buffer.
pub fn matmul_a_bt_into<T: Scalar>(a: &Mat<T>, b: &Mat<T>, c: &mut Mat<T>) {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt: inner dims");
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.rows()));
    let (m, k, q) = (a.rows(), a.cols(), b.rows());
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..q {
            let brow = b.row(j);
            // 4-way unrolled dot product over contiguous rows.
            let mut s0 = T::zero();
            let mut s1 = T::zero();
            let mut s2 = T::zero();
            let mut s3 = T::zero();
            let chunks = k / 4 * 4;
            let mut t = 0;
            while t < chunks {
                s0 = arow[t].fma(brow[t], s0);
                s1 = arow[t + 1].fma(brow[t + 1], s1);
                s2 = arow[t + 2].fma(brow[t + 2], s2);
                s3 = arow[t + 3].fma(brow[t + 3], s3);
                t += 4;
            }
            let mut s = (s0 + s1) + (s2 + s3);
            while t < k {
                s = arow[t].fma(brow[t], s);
                t += 1;
            }
            crow[j] = s;
        }
    }
}

/// Gram `G = M · Mᵀ` (q×q, symmetric — only the upper triangle is computed
/// then mirrored). The local GR kernel of Alg 4 when M = H-block.
pub fn gram_m_mt<T: Scalar>(m: &Mat<T>) -> Mat<T> {
    let q = m.rows();
    let k = m.cols();
    let mut g = Mat::zeros(q, q);
    for i in 0..q {
        let ri = m.row(i);
        for j in i..q {
            let rj = m.row(j);
            let mut s = T::zero();
            for t in 0..k {
                s = ri[t].fma(rj[t], s);
            }
            g[(i, j)] = s;
            g[(j, i)] = s;
        }
    }
    g
}

/// Gram `G = Mᵀ · M` (r×r). The local GR kernel when M = W-block (m×r).
///
/// Accumulates full rank-1 outer products (`G[p,:] += row[p] * row`) rather
/// than only the upper triangle: for the small `r` of NMF factors the
/// contiguous full-row inner loop vectorizes, which beats halving the flop
/// count (§Perf log: 1.5→3.9 GFLOP/s at r=10).
pub fn gram_mt_m<T: Scalar>(m: &Mat<T>) -> Mat<T> {
    let r = m.cols();
    let mut g = Mat::zeros(r, r);
    for i in 0..m.rows() {
        let row = m.row(i);
        for p in 0..r {
            let v = row[p];
            if v == T::zero() {
                continue;
            }
            let grow = g.row_mut(p);
            for q in 0..r {
                grow[q] = row[q].fma(v, grow[q]);
            }
        }
    }
    g
}

/// Naive reference matmul (for tests only).
pub fn matmul_naive<T: Scalar>(a: &Mat<T>, b: &Mat<T>) -> Mat<T> {
    assert_eq!(a.cols(), b.rows());
    Mat::from_fn(a.rows(), b.cols(), |i, j| {
        let mut s = T::zero();
        for t in 0..a.cols() {
            s += a[(i, t)] * b[(t, j)];
        }
        s
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, check};

    fn to64(m: &Mat<f64>) -> Vec<f64> {
        m.as_slice().to_vec()
    }

    #[test]
    fn matmul_matches_naive_random_shapes() {
        check(101, |rng| {
            let m = 1 + rng.below(40);
            let k = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let a = Mat::<f64>::rand_uniform(m, k, rng);
            let b = Mat::<f64>::rand_uniform(k, n, rng);
            assert_close(&to64(&matmul(&a, &b)), &to64(&matmul_naive(&a, &b)), 1e-10)
        });
    }

    #[test]
    fn at_b_matches_transpose_then_matmul() {
        check(102, |rng| {
            let k = 1 + rng.below(30);
            let r = 1 + rng.below(10);
            let n = 1 + rng.below(30);
            let a = Mat::<f64>::rand_uniform(k, r, rng);
            let b = Mat::<f64>::rand_uniform(k, n, rng);
            assert_close(&to64(&matmul_at_b(&a, &b)), &to64(&matmul(&a.transpose(), &b)), 1e-10)
        });
    }

    #[test]
    fn a_bt_matches_transpose_then_matmul() {
        check(103, |rng| {
            let m = 1 + rng.below(30);
            let k = 1 + rng.below(30);
            let q = 1 + rng.below(10);
            let a = Mat::<f64>::rand_uniform(m, k, rng);
            let b = Mat::<f64>::rand_uniform(q, k, rng);
            assert_close(&to64(&matmul_a_bt(&a, &b)), &to64(&matmul(&a, &b.transpose())), 1e-10)
        });
    }

    #[test]
    fn gram_kernels_match() {
        check(104, |rng| {
            let r = 1 + rng.below(12);
            let n = 1 + rng.below(50);
            let h = Mat::<f64>::rand_uniform(r, n, rng);
            assert_close(&to64(&gram_m_mt(&h)), &to64(&matmul(&h, &h.transpose())), 1e-10)?;
            let w = Mat::<f64>::rand_uniform(n, r, rng);
            assert_close(&to64(&gram_mt_m(&w)), &to64(&matmul(&w.transpose(), &w)), 1e-10)
        });
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = crate::util::rng::Rng::new(7);
        let m = Mat::<f64>::rand_uniform(5, 20, &mut rng);
        let g = gram_m_mt(&m);
        for i in 0..5 {
            assert!(g[(i, i)] >= 0.0);
            for j in 0..5 {
                assert!((g[(i, j)] - g[(j, i)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn empty_inner_dim() {
        let a = Mat::<f64>::zeros(3, 0);
        let b = Mat::<f64>::zeros(0, 2);
        let c = matmul(&a, &b);
        assert_eq!(c.shape(), (3, 2));
        assert!(c.as_slice().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn identity_multiplication() {
        let mut rng = crate::util::rng::Rng::new(9);
        let a = Mat::<f64>::rand_uniform(8, 8, &mut rng);
        let i = Mat::<f64>::eye(8);
        assert_close(&to64(&matmul(&a, &i)), &to64(&a), 1e-12).unwrap();
        assert_close(&to64(&matmul(&i, &a)), &to64(&a), 1e-12).unwrap();
    }

    #[test]
    fn f32_path_works() {
        let mut rng = crate::util::rng::Rng::new(11);
        let a = Mat::<f32>::rand_uniform(16, 9, &mut rng);
        let b = Mat::<f32>::rand_uniform(9, 12, &mut rng);
        let c = matmul(&a, &b);
        let c64 = matmul(&a.cast::<f64>(), &b.cast::<f64>());
        for (x, y) in c.as_slice().iter().zip(c64.as_slice()) {
            assert!((x.tof() - y).abs() < 1e-4);
        }
    }
}
