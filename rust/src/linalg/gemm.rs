//! Matrix-multiplication kernels.
//!
//! These are the MM/GR hot paths of the distributed NMF (Algs 3–6): local
//! `X·Hᵀ`, `Wᵀ·X`, and Gram products `M·Mᵀ` / `Mᵀ·M`. Two implementations
//! coexist:
//!
//! * **Packed register-blocked microkernel** (`*_packed_into`): the BLIS
//!   loop nest — A and B are repacked into contiguous [`MR`]×`kc` /
//!   `kc`×[`NR`] panel slivers held in a reusable [`GemmWorkspace`], and an
//!   8×4 register tile accumulates the inner product. This is the fast
//!   path for every shape big enough to amortize the packing copy.
//! * **Cache-blocked i-k-j loop** (`*_blocked_into`): the original seed
//!   kernel, kept as the fallback for tiny shapes (packing overhead would
//!   dominate) and as the baseline the `micro_gemm` bench measures the
//!   microkernel against.
//!
//! The public entry points (`matmul_into`, `matmul_at_b_into`,
//! `matmul_a_bt_into` and the allocating wrappers) dispatch between the two
//! by problem volume (`use_packed`). Tuning history lives in
//! EXPERIMENTS.md §Perf.
//!
//! ## Kernel dispatch and intra-rank threading
//!
//! The packed path runs its inner register tile through a runtime-selected
//! [`KernelPath`](super::simd::KernelPath) (scalar / AVX2 / AVX-512 / NEON
//! — see [`super::simd`]) and can partition the output row panels over a
//! scoped thread pool. Both knobs live in the workspace's [`KernelCfg`]
//! (default: env-aware auto path, 1 thread); the `_with` entry points take
//! an explicit selection. SIMD lanes map across output *columns* (the NR
//! tile direction) and threads own disjoint MC-aligned output *row*
//! chunks, so neither changes any element's accumulation sequence.
//!
//! ## Reproducibility contract
//!
//! The packed microkernel accumulates each output element strictly in
//! ascending `k` order with separate multiply and add (no FMA), starting
//! from the zeroed output and carrying the running value across `kc`
//! panels. That is exactly the operation sequence of [`matmul_naive`], so
//! the packed kernels are **bitwise identical** to the naive reference for
//! both `f32` and `f64` — for every kernel path and thread count
//! (asserted in `tests/gemm_kernels.rs` and `tests/kernel_conformance.rs`).
//! The blocked fallback uses FMA and a zero-skip, so it agrees only to
//! rounding.

use super::matrix::Mat;
use super::scalar::Scalar;
use super::simd::{microkernel, KernelCfg, KernelPath};

pub use super::simd::{MR, NR};

/// Cache block size along the k dimension (L1-friendly for f64) — blocked
/// fallback kernel.
const KB: usize = 64;
/// Cache block size along the i dimension — blocked fallback kernel.
const IB: usize = 64;

/// Rows of A packed per panel (sized so an `MC×KC` f64 A-panel fits L2).
const MC: usize = 128;
/// Depth packed per panel.
const KC: usize = 256;
/// Columns of B packed per panel.
const NC: usize = 2048;

/// Below this flop volume (`m·k·n` multiply-adds) the packing copy costs
/// more than the register tile saves; the blocked loop wins.
const PACK_MIN_VOLUME: usize = 32 * 32 * 32;

/// Reusable packing buffers for the microkernel path, plus the kernel
/// selection its packed entry points dispatch through.
///
/// Holding one of these across calls makes repeated GEMMs allocation-free
/// after warm-up: the buffers grow to the high-water panel size and are
/// then reused. Every packed entry point takes `&mut GemmWorkspace`; the
/// allocating wrappers create a transient one. Under intra-rank threading
/// each worker thread owns its own pack-buffer pair (`peers`), also reused
/// across calls.
pub struct GemmWorkspace<T: Scalar> {
    pack_a: Vec<T>,
    pack_b: Vec<T>,
    /// Pack-buffer pairs for worker threads `1..` (the calling thread uses
    /// the primary buffers above); grown on demand by the threaded driver.
    peers: Vec<(Vec<T>, Vec<T>)>,
    /// Kernel path + intra-rank thread count used by the packed entries.
    sel: KernelCfg,
}

impl<T: Scalar> GemmWorkspace<T> {
    /// Default selection: env-aware auto path (`DNTT_KERNEL` wins),
    /// single-threaded.
    pub fn new() -> Self {
        Self::with_kernel(KernelCfg::default())
    }

    /// Workspace pinned to an explicit kernel selection.
    pub fn with_kernel(sel: KernelCfg) -> Self {
        GemmWorkspace { pack_a: Vec::new(), pack_b: Vec::new(), peers: Vec::new(), sel }
    }

    /// Kernel selection the packed entry points dispatch through.
    pub fn kernel(&self) -> KernelCfg {
        self.sel
    }

    pub fn set_kernel(&mut self, sel: KernelCfg) {
        self.sel = sel;
    }

    /// Bytes currently reserved by the packing buffers.
    pub fn capacity_bytes(&self) -> usize {
        let peer: usize = self.peers.iter().map(|(a, b)| a.capacity() + b.capacity()).sum();
        (self.pack_a.capacity() + self.pack_b.capacity() + peer) * std::mem::size_of::<T>()
    }
}

impl<T: Scalar> Default for GemmWorkspace<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Dispatch rule shared by the public entry points: pack when the volume
/// amortizes the copy and the tile is not mostly padding.
#[inline]
fn use_packed(m: usize, k: usize, n: usize) -> bool {
    m >= MR && n >= NR && m.saturating_mul(k).saturating_mul(n) >= PACK_MIN_VOLUME
}

// ---------------------------------------------------------------------------
// Public entry points (shape-dispatched).
// ---------------------------------------------------------------------------

/// `C = A · B` into a fresh matrix.
pub fn matmul<T: Scalar>(a: &Mat<T>, b: &Mat<T>) -> Mat<T> {
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// `C = A · B` into a caller-provided buffer (zeroed first; allocates only
/// a transient packing workspace on the packed path).
pub fn matmul_into<T: Scalar>(a: &Mat<T>, b: &Mat<T>, c: &mut Mat<T>) {
    if use_packed(a.rows(), a.cols(), b.cols()) {
        matmul_packed_into(a, b, c, &mut GemmWorkspace::new());
    } else {
        matmul_blocked_into(a, b, c);
    }
}

/// `C = A · B` reusing the caller's packing workspace — zero heap
/// allocation once `ws` has warmed up to the largest panel seen.
pub fn matmul_into_ws<T: Scalar>(
    a: &Mat<T>,
    b: &Mat<T>,
    c: &mut Mat<T>,
    ws: &mut GemmWorkspace<T>,
) {
    if use_packed(a.rows(), a.cols(), b.cols()) {
        matmul_packed_into(a, b, c, ws);
    } else {
        matmul_blocked_into(a, b, c);
    }
}

/// `C = Aᵀ · B` (A is m×r stored row-major; result r×n). Used for `Wᵀ·X`.
pub fn matmul_at_b<T: Scalar>(a: &Mat<T>, b: &Mat<T>) -> Mat<T> {
    let mut c = Mat::zeros(a.cols(), b.cols());
    matmul_at_b_into(a, b, &mut c);
    c
}

/// `C = Aᵀ · B` into a caller buffer.
pub fn matmul_at_b_into<T: Scalar>(a: &Mat<T>, b: &Mat<T>, c: &mut Mat<T>) {
    matmul_at_b_into_ws(a, b, c, &mut GemmWorkspace::new());
}

/// `C = Aᵀ · B` reusing the caller's packing workspace.
pub fn matmul_at_b_into_ws<T: Scalar>(
    a: &Mat<T>,
    b: &Mat<T>,
    c: &mut Mat<T>,
    ws: &mut GemmWorkspace<T>,
) {
    if use_packed(a.cols(), a.rows(), b.cols()) {
        matmul_at_b_packed_into(a, b, c, ws);
    } else {
        matmul_at_b_blocked_into(a, b, c);
    }
}

/// `C = A · Bᵀ` (dot products of rows; result m×q). Used for `X·Hᵀ`.
pub fn matmul_a_bt<T: Scalar>(a: &Mat<T>, b: &Mat<T>) -> Mat<T> {
    let mut c = Mat::zeros(a.rows(), b.rows());
    matmul_a_bt_into(a, b, &mut c);
    c
}

/// `C = A · Bᵀ` into a caller buffer.
pub fn matmul_a_bt_into<T: Scalar>(a: &Mat<T>, b: &Mat<T>, c: &mut Mat<T>) {
    matmul_a_bt_into_ws(a, b, c, &mut GemmWorkspace::new());
}

/// `C = A · Bᵀ` reusing the caller's packing workspace.
pub fn matmul_a_bt_into_ws<T: Scalar>(
    a: &Mat<T>,
    b: &Mat<T>,
    c: &mut Mat<T>,
    ws: &mut GemmWorkspace<T>,
) {
    if use_packed(a.rows(), a.cols(), b.rows()) {
        matmul_a_bt_packed_into(a, b, c, ws);
    } else {
        matmul_a_bt_blocked_into(a, b, c);
    }
}

// ---------------------------------------------------------------------------
// Packed register-blocked path.
// ---------------------------------------------------------------------------

/// The shared BLIS-style loop nest: `C += op(A)·op(B)` with `op` expressed
/// through the element loaders `la(i, k)` / `lb(k, j)` on the *logical*
/// `m×k · k×n` problem. `c` is a row-major `m×n` slice pre-zeroed by the
/// caller (the nest accumulates). Partial edge tiles are zero-padded
/// during packing and masked on the C store, so any shape is handled. The
/// register tile dispatches through `path` (validated by the driver).
#[allow(clippy::too_many_arguments)]
fn gemm_packed_nest<T: Scalar>(
    m: usize,
    k: usize,
    n: usize,
    la: impl Fn(usize, usize) -> T + Copy,
    lb: impl Fn(usize, usize) -> T + Copy,
    c: &mut [T],
    path: KernelPath,
    pack_a: &mut Vec<T>,
    pack_b: &mut Vec<T>,
) {
    debug_assert_eq!(c.len(), m * n);
    for jc in (0..n).step_by(NC) {
        let nc = (n - jc).min(NC);
        let nr_tiles = nc.div_ceil(NR);
        for pc in (0..k).step_by(KC) {
            let kc = (k - pc).min(KC);
            // Pack B[pc..pc+kc, jc..jc+nc] into NR-column slivers,
            // zero-padding the ragged last sliver.
            pack_b.clear();
            pack_b.resize(nr_tiles * kc * NR, T::zero());
            for jt in 0..nr_tiles {
                let base = jt * kc * NR;
                let j0 = jc + jt * NR;
                let jlim = (n - j0).min(NR);
                for kk in 0..kc {
                    let row = base + kk * NR;
                    for j in 0..jlim {
                        pack_b[row + j] = lb(pc + kk, j0 + j);
                    }
                }
            }
            for ic in (0..m).step_by(MC) {
                let mc = (m - ic).min(MC);
                let mr_tiles = mc.div_ceil(MR);
                // Pack A[ic..ic+mc, pc..pc+kc] into MR-row slivers.
                pack_a.clear();
                pack_a.resize(mr_tiles * kc * MR, T::zero());
                for it in 0..mr_tiles {
                    let base = it * kc * MR;
                    let i0 = ic + it * MR;
                    let ilim = (m - i0).min(MR);
                    for i in 0..ilim {
                        for kk in 0..kc {
                            pack_a[base + kk * MR + i] = la(i0 + i, pc + kk);
                        }
                    }
                }
                // Macro tile: every (jr, ir) pair runs the microkernel.
                for jt in 0..nr_tiles {
                    let pb = &pack_b[jt * kc * NR..(jt + 1) * kc * NR];
                    let j0 = jc + jt * NR;
                    let jlim = (n - j0).min(NR);
                    for it in 0..mr_tiles {
                        let pa = &pack_a[it * kc * MR..(it + 1) * kc * MR];
                        let i0 = ic + it * MR;
                        let ilim = (m - i0).min(MR);
                        let mut acc = [[T::zero(); NR]; MR];
                        for i in 0..ilim {
                            let crow = &c[(i0 + i) * n..(i0 + i) * n + n];
                            for j in 0..jlim {
                                acc[i][j] = crow[j0 + j];
                            }
                        }
                        microkernel(path, kc, pa, pb, &mut acc);
                        for i in 0..ilim {
                            let crow = &mut c[(i0 + i) * n..(i0 + i) * n + n];
                            for j in 0..jlim {
                                crow[j0 + j] = acc[i][j];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Shared packed-path driver: zeroes `C`, validates the kernel path, and
/// either runs the loop nest serially or partitions the output row panels
/// over a scoped thread pool (`sel.threads` workers, capped at one per MC
/// panel). Threads own disjoint MC-aligned row chunks of `C` plus their
/// own pack buffers, so every output element is produced by exactly one
/// thread running the identical serial operation sequence — the threaded
/// result is bitwise equal to the serial (and naive) one, and the
/// partition depends only on `(m, sel.threads)`, never on scheduling.
#[allow(clippy::too_many_arguments)]
fn gemm_packed_driver<T: Scalar>(
    m: usize,
    k: usize,
    n: usize,
    la: impl Fn(usize, usize) -> T + Copy + Send + Sync,
    lb: impl Fn(usize, usize) -> T + Copy + Send + Sync,
    c: &mut Mat<T>,
    ws: &mut GemmWorkspace<T>,
    sel: KernelCfg,
) {
    debug_assert_eq!((c.rows(), c.cols()), (m, n));
    for x in c.as_mut_slice() {
        *x = T::zero();
    }
    let path = sel.path.validated();
    let panels = m.div_ceil(MC);
    let nt = sel.threads.clamp(1, panels.max(1));
    let GemmWorkspace { pack_a, pack_b, peers, .. } = ws;
    if nt <= 1 {
        gemm_packed_nest(m, k, n, la, lb, c.as_mut_slice(), path, pack_a, pack_b);
        return;
    }
    // MC-aligned row chunks, one per thread; the calling thread takes
    // chunk 0 with the primary pack buffers, spawned threads use peers.
    let chunk = panels.div_ceil(nt) * MC;
    if peers.len() < nt - 1 {
        peers.resize_with(nt - 1, Default::default);
    }
    let (c0, mut rest) = c.as_mut_slice().split_at_mut(chunk.min(m) * n);
    let mut jobs = Vec::new();
    let mut base = chunk.min(m);
    for (pa, pb) in peers.iter_mut() {
        if base >= m {
            break;
        }
        let rows = chunk.min(m - base);
        let (mine, tail) = std::mem::take(&mut rest).split_at_mut(rows * n);
        rest = tail;
        jobs.push((base, rows, mine, pa, pb));
        base += rows;
    }
    std::thread::scope(|s| {
        for (b0, rows, mine, pa, pb) in jobs {
            s.spawn(move || {
                gemm_packed_nest(rows, k, n, move |i, kk| la(b0 + i, kk), lb, mine, path, pa, pb);
            });
        }
        gemm_packed_nest(chunk.min(m), k, n, la, lb, c0, path, pack_a, pack_b);
    });
}

/// `C = A · B` through the packed microkernel with an explicit kernel
/// selection (any shape; every path and thread count bitwise equal to
/// [`matmul_naive`]).
pub fn matmul_packed_with<T: Scalar>(
    a: &Mat<T>,
    b: &Mat<T>,
    c: &mut Mat<T>,
    ws: &mut GemmWorkspace<T>,
    sel: KernelCfg,
) {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dims {}x{} · {}x{}",
        a.rows(), a.cols(), b.rows(), b.cols());
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.cols()), "matmul: bad out shape");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    gemm_packed_driver(m, k, n, |i, kk| a[(i, kk)], |kk, j| b[(kk, j)], c, ws, sel);
}

/// `C = A · B` through the packed microkernel (any shape; bitwise equal to
/// [`matmul_naive`]). Dispatches through the workspace's kernel selection.
pub fn matmul_packed_into<T: Scalar>(
    a: &Mat<T>,
    b: &Mat<T>,
    c: &mut Mat<T>,
    ws: &mut GemmWorkspace<T>,
) {
    let sel = ws.kernel();
    matmul_packed_with(a, b, c, ws, sel);
}

/// `C = Aᵀ · B` through the packed microkernel with an explicit kernel
/// selection (bitwise equal to `matmul_naive(&a.transpose(), b)`).
pub fn matmul_at_b_packed_with<T: Scalar>(
    a: &Mat<T>,
    b: &Mat<T>,
    c: &mut Mat<T>,
    ws: &mut GemmWorkspace<T>,
    sel: KernelCfg,
) {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b: inner dims");
    assert_eq!((c.rows(), c.cols()), (a.cols(), b.cols()));
    let (m, k, n) = (a.cols(), a.rows(), b.cols());
    gemm_packed_driver(m, k, n, |i, kk| a[(kk, i)], |kk, j| b[(kk, j)], c, ws, sel);
}

/// `C = Aᵀ · B` through the packed microkernel (bitwise equal to
/// `matmul_naive(&a.transpose(), b)`). Uses the workspace's selection.
pub fn matmul_at_b_packed_into<T: Scalar>(
    a: &Mat<T>,
    b: &Mat<T>,
    c: &mut Mat<T>,
    ws: &mut GemmWorkspace<T>,
) {
    let sel = ws.kernel();
    matmul_at_b_packed_with(a, b, c, ws, sel);
}

/// `C = A · Bᵀ` through the packed microkernel with an explicit kernel
/// selection (bitwise equal to `matmul_naive(a, &b.transpose())`).
pub fn matmul_a_bt_packed_with<T: Scalar>(
    a: &Mat<T>,
    b: &Mat<T>,
    c: &mut Mat<T>,
    ws: &mut GemmWorkspace<T>,
    sel: KernelCfg,
) {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt: inner dims");
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.rows()));
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    gemm_packed_driver(m, k, n, |i, kk| a[(i, kk)], |kk, j| b[(j, kk)], c, ws, sel);
}

/// `C = A · Bᵀ` through the packed microkernel (bitwise equal to
/// `matmul_naive(a, &b.transpose())`). Uses the workspace's selection.
pub fn matmul_a_bt_packed_into<T: Scalar>(
    a: &Mat<T>,
    b: &Mat<T>,
    c: &mut Mat<T>,
    ws: &mut GemmWorkspace<T>,
) {
    let sel = ws.kernel();
    matmul_a_bt_packed_with(a, b, c, ws, sel);
}

// ---------------------------------------------------------------------------
// Blocked fallback (the seed kernel, unchanged numerics).
// ---------------------------------------------------------------------------

/// `C = A · B` with the cache-blocked i-k-j loop (the seed kernel):
/// innermost loop contiguous over rows of C and B so LLVM autovectorizes
/// the axpy. Fallback for tiny shapes and the `micro_gemm` baseline.
pub fn matmul_blocked_into<T: Scalar>(a: &Mat<T>, b: &Mat<T>, c: &mut Mat<T>) {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dims {}x{} · {}x{}",
        a.rows(), a.cols(), b.rows(), b.cols());
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.cols()), "matmul: bad out shape");
    for x in c.as_mut_slice() {
        *x = T::zero();
    }
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    // Blocked i-k-j: C[i,:] += A[i,kk] * B[kk,:]; inner loop contiguous in C and B.
    for i0 in (0..m).step_by(IB) {
        let i1 = (i0 + IB).min(m);
        for k0 in (0..k).step_by(KB) {
            let k1 = (k0 + KB).min(k);
            for i in i0..i1 {
                let arow = a.row(i);
                let crow = c.row_mut(i);
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == T::zero() {
                        continue;
                    }
                    let brow = b.row(kk);
                    // Contiguous axpy over row of B into row of C.
                    for j in 0..n {
                        crow[j] = brow[j].fma(aik, crow[j]);
                    }
                }
            }
        }
    }
}

/// `C = Aᵀ · B` with the seed rank-1 loop (fallback / baseline).
pub fn matmul_at_b_blocked_into<T: Scalar>(a: &Mat<T>, b: &Mat<T>, c: &mut Mat<T>) {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b: inner dims");
    assert_eq!((c.rows(), c.cols()), (a.cols(), b.cols()));
    for x in c.as_mut_slice() {
        *x = T::zero();
    }
    let (k, r, n) = (a.rows(), a.cols(), b.cols());
    // For each shared row `kk`: C[p,:] += A[kk,p] * B[kk,:]  — all contiguous.
    for kk in 0..k {
        let arow = a.row(kk);
        let brow = b.row(kk);
        for p in 0..r {
            let apk = arow[p];
            if apk == T::zero() {
                continue;
            }
            let crow = c.row_mut(p);
            for j in 0..n {
                crow[j] = brow[j].fma(apk, crow[j]);
            }
        }
    }
}

/// `C = A · Bᵀ` with the seed unrolled-dot loop (fallback / baseline).
pub fn matmul_a_bt_blocked_into<T: Scalar>(a: &Mat<T>, b: &Mat<T>, c: &mut Mat<T>) {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt: inner dims");
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.rows()));
    let (m, k, q) = (a.rows(), a.cols(), b.rows());
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..q {
            let brow = b.row(j);
            // 4-way unrolled dot product over contiguous rows.
            let mut s0 = T::zero();
            let mut s1 = T::zero();
            let mut s2 = T::zero();
            let mut s3 = T::zero();
            let chunks = k / 4 * 4;
            let mut t = 0;
            while t < chunks {
                s0 = arow[t].fma(brow[t], s0);
                s1 = arow[t + 1].fma(brow[t + 1], s1);
                s2 = arow[t + 2].fma(brow[t + 2], s2);
                s3 = arow[t + 3].fma(brow[t + 3], s3);
                t += 4;
            }
            let mut s = (s0 + s1) + (s2 + s3);
            while t < k {
                s = arow[t].fma(brow[t], s);
                t += 1;
            }
            crow[j] = s;
        }
    }
}

// ---------------------------------------------------------------------------
// Gram kernels.
// ---------------------------------------------------------------------------

/// Gram `G = M · Mᵀ` (q×q, symmetric — only the upper triangle is computed
/// then mirrored). The local GR kernel of Alg 4 when M = H-block.
pub fn gram_m_mt<T: Scalar>(m: &Mat<T>) -> Mat<T> {
    let q = m.rows();
    let k = m.cols();
    let mut g = Mat::zeros(q, q);
    for i in 0..q {
        let ri = m.row(i);
        for j in i..q {
            let rj = m.row(j);
            let mut s = T::zero();
            for t in 0..k {
                s = ri[t].fma(rj[t], s);
            }
            g[(i, j)] = s;
            g[(j, i)] = s;
        }
    }
    g
}

/// Gram `G = Mᵀ · M` (r×r). The local GR kernel when M = W-block (m×r).
///
/// Accumulates full rank-1 outer products (`G[p,:] += row[p] * row`) rather
/// than only the upper triangle: for the small `r` of NMF factors the
/// contiguous full-row inner loop vectorizes, which beats halving the flop
/// count (§Perf log: 1.5→3.9 GFLOP/s at r=10).
pub fn gram_mt_m<T: Scalar>(m: &Mat<T>) -> Mat<T> {
    let mut g = Mat::zeros(m.cols(), m.cols());
    gram_mt_m_into(m, &mut g);
    g
}

/// `G = Mᵀ · M` into a caller buffer (zeroed first; no allocation).
pub fn gram_mt_m_into<T: Scalar>(m: &Mat<T>, g: &mut Mat<T>) {
    let r = m.cols();
    assert_eq!((g.rows(), g.cols()), (r, r), "gram_mt_m: bad out shape");
    for x in g.as_mut_slice() {
        *x = T::zero();
    }
    for i in 0..m.rows() {
        let row = m.row(i);
        for p in 0..r {
            let v = row[p];
            if v == T::zero() {
                continue;
            }
            let grow = g.row_mut(p);
            for q in 0..r {
                grow[q] = row[q].fma(v, grow[q]);
            }
        }
    }
}

/// Naive reference matmul (for tests only).
pub fn matmul_naive<T: Scalar>(a: &Mat<T>, b: &Mat<T>) -> Mat<T> {
    assert_eq!(a.cols(), b.rows());
    Mat::from_fn(a.rows(), b.cols(), |i, j| {
        let mut s = T::zero();
        for t in 0..a.cols() {
            s += a[(i, t)] * b[(t, j)];
        }
        s
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, check};

    fn to64(m: &Mat<f64>) -> Vec<f64> {
        m.as_slice().to_vec()
    }

    #[test]
    fn matmul_matches_naive_random_shapes() {
        check(101, |rng| {
            let m = 1 + rng.below(40);
            let k = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let a = Mat::<f64>::rand_uniform(m, k, rng);
            let b = Mat::<f64>::rand_uniform(k, n, rng);
            assert_close(&to64(&matmul(&a, &b)), &to64(&matmul_naive(&a, &b)), 1e-10)
        });
    }

    #[test]
    fn packed_matches_naive_bitwise_random_shapes() {
        check(107, |rng| {
            let m = 1 + rng.below(70);
            let k = 1 + rng.below(70);
            let n = 1 + rng.below(70);
            let a = Mat::<f64>::rand_uniform(m, k, rng);
            let b = Mat::<f64>::rand_uniform(k, n, rng);
            let mut c = Mat::zeros(m, n);
            matmul_packed_into(&a, &b, &mut c, &mut GemmWorkspace::new());
            let naive = matmul_naive(&a, &b);
            if c.as_slice() != naive.as_slice() {
                return Err("packed != naive bitwise".into());
            }
            Ok(())
        });
    }

    #[test]
    fn packed_crosses_panel_boundaries() {
        // Shapes straddling MC/KC/NC panel edges exercise the carry of the
        // running C value across kc panels.
        let mut rng = crate::util::rng::Rng::new(55);
        for &(m, k, n) in
            &[(MC + 3, KC + 5, NR + 1), (MR, 2 * KC + 1, NR), (2 * MC + 1, KC, 2 * NR + 3)]
        {
            let a = Mat::<f64>::rand_uniform(m, k, &mut rng);
            let b = Mat::<f64>::rand_uniform(k, n, &mut rng);
            let mut c = Mat::zeros(m, n);
            matmul_packed_into(&a, &b, &mut c, &mut GemmWorkspace::new());
            assert_eq!(c.as_slice(), matmul_naive(&a, &b).as_slice());
        }
    }

    #[test]
    fn workspace_reuse_is_bitwise_stable() {
        // One workspace across many shapes: stale panel contents must never
        // leak into a later product.
        let mut rng = crate::util::rng::Rng::new(77);
        let mut ws = GemmWorkspace::new();
        for &(m, k, n) in &[(40, 50, 20), (9, 300, 5), (65, 65, 65), (8, 4, 4), (33, 17, 29)] {
            let a = Mat::<f64>::rand_uniform(m, k, &mut rng);
            let b = Mat::<f64>::rand_uniform(k, n, &mut rng);
            let mut c = Mat::zeros(m, n);
            matmul_packed_into(&a, &b, &mut c, &mut ws);
            assert_eq!(c.as_slice(), matmul_naive(&a, &b).as_slice());
        }
    }

    #[test]
    fn at_b_matches_transpose_then_matmul() {
        check(102, |rng| {
            let k = 1 + rng.below(30);
            let r = 1 + rng.below(10);
            let n = 1 + rng.below(30);
            let a = Mat::<f64>::rand_uniform(k, r, rng);
            let b = Mat::<f64>::rand_uniform(k, n, rng);
            assert_close(&to64(&matmul_at_b(&a, &b)), &to64(&matmul(&a.transpose(), &b)), 1e-10)
        });
    }

    #[test]
    fn a_bt_matches_transpose_then_matmul() {
        check(103, |rng| {
            let m = 1 + rng.below(30);
            let k = 1 + rng.below(30);
            let q = 1 + rng.below(10);
            let a = Mat::<f64>::rand_uniform(m, k, rng);
            let b = Mat::<f64>::rand_uniform(q, k, rng);
            assert_close(&to64(&matmul_a_bt(&a, &b)), &to64(&matmul(&a, &b.transpose())), 1e-10)
        });
    }

    #[test]
    fn packed_transpose_variants_match_naive_bitwise() {
        let mut rng = crate::util::rng::Rng::new(66);
        let mut ws = GemmWorkspace::new();
        // At·B: logical 37×90 · 90×21.
        let a = Mat::<f64>::rand_uniform(90, 37, &mut rng);
        let b = Mat::<f64>::rand_uniform(90, 21, &mut rng);
        let mut c = Mat::zeros(37, 21);
        matmul_at_b_packed_into(&a, &b, &mut c, &mut ws);
        assert_eq!(c.as_slice(), matmul_naive(&a.transpose(), &b).as_slice());
        // A·Bt: logical 41×70 · 70×13.
        let a = Mat::<f64>::rand_uniform(41, 70, &mut rng);
        let b = Mat::<f64>::rand_uniform(13, 70, &mut rng);
        let mut c = Mat::zeros(41, 13);
        matmul_a_bt_packed_into(&a, &b, &mut c, &mut ws);
        assert_eq!(c.as_slice(), matmul_naive(&a, &b.transpose()).as_slice());
    }

    #[test]
    fn gram_kernels_match() {
        check(104, |rng| {
            let r = 1 + rng.below(12);
            let n = 1 + rng.below(50);
            let h = Mat::<f64>::rand_uniform(r, n, rng);
            assert_close(&to64(&gram_m_mt(&h)), &to64(&matmul(&h, &h.transpose())), 1e-10)?;
            let w = Mat::<f64>::rand_uniform(n, r, rng);
            assert_close(&to64(&gram_mt_m(&w)), &to64(&matmul(&w.transpose(), &w)), 1e-10)
        });
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = crate::util::rng::Rng::new(7);
        let m = Mat::<f64>::rand_uniform(5, 20, &mut rng);
        let g = gram_m_mt(&m);
        for i in 0..5 {
            assert!(g[(i, i)] >= 0.0);
            for j in 0..5 {
                assert!((g[(i, j)] - g[(j, i)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn empty_inner_dim() {
        let a = Mat::<f64>::zeros(3, 0);
        let b = Mat::<f64>::zeros(0, 2);
        let c = matmul(&a, &b);
        assert_eq!(c.shape(), (3, 2));
        assert!(c.as_slice().iter().all(|&x| x == 0.0));
        // The packed entry point handles the degenerate shape directly too.
        let mut cp = Mat::<f64>::filled(3, 2, 7.0);
        matmul_packed_into(&a, &b, &mut cp, &mut GemmWorkspace::new());
        assert!(cp.as_slice().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn identity_multiplication() {
        let mut rng = crate::util::rng::Rng::new(9);
        let a = Mat::<f64>::rand_uniform(8, 8, &mut rng);
        let i = Mat::<f64>::eye(8);
        assert_close(&to64(&matmul(&a, &i)), &to64(&a), 1e-12).unwrap();
        assert_close(&to64(&matmul(&i, &a)), &to64(&a), 1e-12).unwrap();
    }

    #[test]
    fn threaded_and_forced_paths_are_bitwise_identical() {
        use crate::linalg::simd::{KernelCfg, KernelPath};
        let mut rng = crate::util::rng::Rng::new(88);
        let mut ws = GemmWorkspace::new();
        // Shapes straddling the MC panel boundary so 2/4/8 threads all get
        // real work (and some get none).
        for &(m, k, n) in &[(2 * MC + 3, 65, 9), (MC, 40, NR), (17, 300, 33)] {
            let a = Mat::<f64>::rand_uniform(m, k, &mut rng);
            let b = Mat::<f64>::rand_uniform(k, n, &mut rng);
            let naive = matmul_naive(&a, &b);
            for path in KernelPath::available() {
                for threads in [1usize, 2, 4, 8] {
                    let mut c = Mat::zeros(m, n);
                    matmul_packed_with(&a, &b, &mut c, &mut ws, KernelCfg::new(path, threads));
                    assert_eq!(
                        c.as_slice(),
                        naive.as_slice(),
                        "path {} threads {threads} shape {m}x{k}x{n}",
                        path.name()
                    );
                }
            }
        }
    }

    #[test]
    fn unavailable_path_downgrades_to_scalar() {
        use crate::linalg::simd::{KernelCfg, KernelPath};
        let mut rng = crate::util::rng::Rng::new(89);
        let a = Mat::<f64>::rand_uniform(20, 30, &mut rng);
        let b = Mat::<f64>::rand_uniform(30, 10, &mut rng);
        let naive = matmul_naive(&a, &b);
        // Every path, available on this host or not, must execute safely
        // and produce the bitwise-identical result.
        for path in KernelPath::ALL {
            let mut c = Mat::zeros(20, 10);
            matmul_packed_with(&a, &b, &mut c, &mut GemmWorkspace::new(), KernelCfg::new(path, 2));
            assert_eq!(c.as_slice(), naive.as_slice(), "path {}", path.name());
        }
    }

    #[test]
    fn f32_path_works() {
        let mut rng = crate::util::rng::Rng::new(11);
        let a = Mat::<f32>::rand_uniform(16, 9, &mut rng);
        let b = Mat::<f32>::rand_uniform(9, 12, &mut rng);
        let c = matmul(&a, &b);
        let c64 = matmul(&a.cast::<f64>(), &b.cast::<f64>());
        for (x, y) in c.as_slice().iter().zip(c64.as_slice()) {
            assert!((x.tof() - y).abs() < 1e-4);
        }
    }
}
