//! Singular value decomposition.
//!
//! Two entry points, matching the two uses in the paper:
//!
//! * [`singular_values_gram`] — singular values only, computed from the
//!   small Gram matrix. This is what the *distributed* SVD of Alg 2 reduces
//!   to: ranks all-reduce `G = X Xᵀ` (whose side is the short dimension
//!   `r_{l-1}·n_l`), then every rank takes `sqrt(eig(G))` locally. Fast and
//!   exactly what the ε-threshold rank selection needs.
//! * [`thin_svd`] — full thin SVD via one-sided Jacobi (Hestenes), used by
//!   the TT-SVD baseline where the factors themselves are needed.

use super::eig::sym_eig;
use super::gemm::{gram_m_mt, gram_mt_m, matmul};
use super::matrix::Mat;
use super::scalar::Scalar;

/// Thin SVD `A = U diag(s) Vᵀ`, `U: m×k`, `s: k`, `Vt: k×n`, `k = min(m,n)`.
#[derive(Clone, Debug)]
pub struct Svd<T: Scalar> {
    pub u: Mat<T>,
    pub s: Vec<f64>,
    pub vt: Mat<T>,
}

/// Singular values of `A` via the Gram-matrix route (descending, length
/// `min(m, n)`). Negative eigenvalues from roundoff are clamped to zero.
pub fn singular_values_gram<T: Scalar>(a: &Mat<T>) -> Vec<f64> {
    let g = if a.rows() <= a.cols() { gram_m_mt(a) } else { gram_mt_m(a) };
    sym_eig(&g).values.into_iter().map(|l| l.max(0.0).sqrt()).collect()
}

/// Singular values from a precomputed Gram matrix (the distributed path:
/// the Gram has already been all-reduced across ranks).
pub fn singular_values_of_gram<T: Scalar>(g: &Mat<T>) -> Vec<f64> {
    sym_eig(g).values.into_iter().map(|l| l.max(0.0).sqrt()).collect()
}

/// The paper's ε-threshold rank selection: smallest `k` such that
/// `sqrt(σ_{k+1}² + … + σ_N²) / sqrt(σ_1² + … + σ_N²) ≤ ε`.
///
/// Returns at least 1 (a rank-0 factorization is meaningless) and at most N.
pub fn rank_for_eps(singular_values: &[f64], eps: f64) -> usize {
    let n = singular_values.len();
    if n == 0 {
        return 1;
    }
    let total: f64 = singular_values.iter().map(|s| s * s).sum();
    if total <= 0.0 {
        return 1;
    }
    // tail(k) = sum_{i>k} σ_i²; find smallest k with sqrt(tail/total) <= eps.
    let mut tail = total;
    for k in 1..=n {
        tail -= singular_values[k - 1] * singular_values[k - 1];
        if (tail.max(0.0) / total).sqrt() <= eps {
            return k;
        }
    }
    n
}

/// Thin SVD via one-sided Jacobi (Hestenes) with eigen-fallback for rank
/// deficiency. Operates on the transpose when `m < n` so the rotated matrix
/// always has at least as many rows as columns.
pub fn thin_svd<T: Scalar>(a: &Mat<T>) -> Svd<T> {
    // Extreme aspect ratios (the TT sweep's `m × n_rest` unfoldings):
    // the Gram route costs O(min²·max) for the product + O(min³) for the
    // eig, vs O(min²·max·sweeps) for one-sided Jacobi — an ~8x win on the
    // Fig-8c stage matrices (§Perf log).
    let (m, n) = a.shape();
    let (lo, hi) = (m.min(n), m.max(n));
    if lo > 0 && lo <= 512 && hi >= 4 * lo {
        return thin_svd_gram(a);
    }
    if m >= n {
        thin_svd_tall(a)
    } else {
        // A = U S Vᵀ  ⇔  Aᵀ = V S Uᵀ.
        let s = thin_svd_tall(&a.transpose());
        Svd { u: s.vt.transpose(), s: s.s, vt: s.u.transpose() }
    }
}

/// Gram-route thin SVD for strongly rectangular matrices:
/// `G = A·Aᵀ = U Λ Uᵀ` (small side), `σ = sqrt(λ)`, `Vᵀ = Σ⁻¹·Uᵀ·A`.
/// Columns with σ below the roundoff floor are zeroed (rank deficiency).
fn thin_svd_gram<T: Scalar>(a: &Mat<T>) -> Svd<T> {
    if a.rows() <= a.cols() {
        let g = gram_m_mt(a); // m×m
        let e = sym_eig(&g);
        let s: Vec<f64> = e.values.iter().map(|&l| l.max(0.0).sqrt()).collect();
        let u = e.vectors; // m×m
        // Vᵀ = Σ⁻¹ Uᵀ A, zero rows for negligible σ.
        let mut vt = crate::linalg::gemm::matmul_at_b(&u, a); // m×n
        let floor = s.first().copied().unwrap_or(0.0) * 1e-14;
        for (i, &si) in s.iter().enumerate() {
            let inv = if si > floor && si > 0.0 { T::fromf(1.0 / si) } else { T::zero() };
            for v in vt.row_mut(i) {
                *v *= inv;
            }
        }
        Svd { u, s, vt }
    } else {
        let t = thin_svd_gram(&a.transpose());
        Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() }
    }
}

/// One-sided Jacobi on a tall matrix (m ≥ n): rotate column pairs of a
/// working copy until all pairs are orthogonal; then σ_j = ‖a_j‖,
/// U = A·diag(1/σ), V = accumulated rotations.
fn thin_svd_tall<T: Scalar>(a: &Mat<T>) -> Svd<T> {
    let m = a.rows();
    let n = a.cols();
    if n == 0 || m == 0 {
        return Svd { u: Mat::zeros(m, 0), s: vec![], vt: Mat::zeros(0, n) };
    }
    // Work column-major in f64: cols[j] is column j.
    let mut cols: Vec<Vec<f64>> = (0..n).map(|j| a.col(j).iter().map(|x| x.tof()).collect()).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let frob: f64 = cols.iter().flat_map(|c| c.iter()).map(|x| x * x).sum::<f64>();
    let tol = 1e-28 * frob.max(1e-300); // on |aᵢ·aⱼ|² relative to ‖A‖⁴-ish scale
    let max_sweeps = 60;
    for _ in 0..max_sweeps {
        let mut converged = true;
        for p in 0..n - 1 {
            for q in p + 1..n {
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                let (cp, cq) = (&cols[p], &cols[q]);
                for i in 0..m {
                    app += cp[i] * cp[i];
                    aqq += cq[i] * cq[i];
                    apq += cp[i] * cq[i];
                }
                if apq * apq <= tol * 1e-2 || apq.abs() <= 1e-30 {
                    continue;
                }
                if apq * apq > 1e-30 * app * aqq {
                    converged = false;
                }
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate the column pair.
                let (left, right) = cols.split_at_mut(q);
                let cp = &mut left[p];
                let cq = &mut right[0];
                for i in 0..m {
                    let xp = cp[i];
                    let xq = cq[i];
                    cp[i] = c * xp - s * xq;
                    cq[i] = s * xp + c * xq;
                }
                for i in 0..n {
                    let vp = v[i * n + p];
                    let vq = v[i * n + q];
                    v[i * n + p] = c * vp - s * vq;
                    v[i * n + q] = s * vp + c * vq;
                }
            }
        }
        if converged {
            break;
        }
    }

    // Extract singular values and sort descending.
    let mut sig: Vec<(f64, usize)> =
        (0..n).map(|j| (cols[j].iter().map(|x| x * x).sum::<f64>().sqrt(), j)).collect();
    sig.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let s: Vec<f64> = sig.iter().map(|&(x, _)| x).collect();

    let mut u = Mat::<T>::zeros(m, n);
    let mut vt = Mat::<T>::zeros(n, n);
    let smax = s.first().copied().unwrap_or(0.0);
    for (jj, &(sj, j)) in sig.iter().enumerate() {
        if sj > smax * 1e-300 && sj > 0.0 {
            for i in 0..m {
                u[(i, jj)] = T::fromf(cols[j][i] / sj);
            }
        } // else leave a zero column (rank-deficient tail).
        for i in 0..n {
            vt[(jj, i)] = T::fromf(v[i * n + j]);
        }
    }
    Svd { u, s, vt: vt.rows_slice(0, n) }
}

impl<T: Scalar> Svd<T> {
    /// Keep only the leading `k` triplets.
    pub fn truncate(&self, k: usize) -> Svd<T> {
        let k = k.min(self.s.len());
        Svd {
            u: self.u.cols_slice(0, k),
            s: self.s[..k].to_vec(),
            vt: self.vt.rows_slice(0, k),
        }
    }

    /// Reconstruct `U diag(s) Vt` (for tests / baselines).
    pub fn reconstruct(&self) -> Mat<T> {
        let k = self.s.len();
        let mut us = self.u.clone();
        for i in 0..us.rows() {
            let row = us.row_mut(i);
            for j in 0..k {
                row[j] *= T::fromf(self.s[j]);
            }
        }
        matmul(&us, &self.vt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn rel_err(a: &Mat<f64>, b: &Mat<f64>) -> f64 {
        let mut d = a.clone();
        d.sub_assign(b);
        d.fro_norm() / a.fro_norm().max(1e-300)
    }

    #[test]
    fn svd_reconstructs_random_matrices() {
        check(301, |rng| {
            let m = 1 + rng.below(25);
            let n = 1 + rng.below(25);
            let a = Mat::<f64>::rand_uniform(m, n, rng);
            let svd = thin_svd(&a);
            let err = rel_err(&a, &svd.reconstruct());
            if err > 1e-8 {
                return Err(format!("{m}x{n}: reconstruction error {err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn singular_values_sorted_and_match_gram_route() {
        check(302, |rng| {
            let m = 1 + rng.below(20);
            let n = 1 + rng.below(20);
            let a = Mat::<f64>::rand_uniform(m, n, rng);
            let s1 = thin_svd(&a).s;
            let s2 = singular_values_gram(&a);
            for w in s1.windows(2) {
                if w[0] < w[1] - 1e-10 {
                    return Err("unsorted".into());
                }
            }
            for (x, y) in s1.iter().zip(s2.iter()) {
                let scale = 1.0_f64.max(*x);
                if (x - y).abs() > 1e-7 * scale {
                    return Err(format!("σ mismatch {x} vs {y}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn u_v_orthonormal() {
        let mut rng = Rng::new(3);
        let a = Mat::<f64>::rand_uniform(30, 12, &mut rng);
        let svd = thin_svd(&a);
        let utu = matmul(&svd.u.transpose(), &svd.u);
        let vvt = matmul(&svd.vt, &svd.vt.transpose());
        for i in 0..12 {
            for j in 0..12 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((utu[(i, j)] - want).abs() < 1e-8);
                assert!((vvt[(i, j)] - want).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn low_rank_matrix_detected() {
        let mut rng = Rng::new(4);
        // rank-3 matrix
        let b = Mat::<f64>::rand_uniform(20, 3, &mut rng);
        let c = Mat::<f64>::rand_uniform(3, 15, &mut rng);
        let a = matmul(&b, &c);
        let s = thin_svd(&a).s;
        assert!(s[2] > 1e-6);
        assert!(s[3] < 1e-8 * s[0], "s[3]={} s[0]={}", s[3], s[0]);
        assert_eq!(rank_for_eps(&s, 1e-6), 3);
    }

    #[test]
    fn truncation_gives_best_rank_k_error() {
        let mut rng = Rng::new(5);
        let a = Mat::<f64>::rand_uniform(15, 10, &mut rng);
        let svd = thin_svd(&a);
        let k = 4;
        let tr = svd.truncate(k);
        let err = rel_err(&a, &tr.reconstruct());
        // Eckart–Young: error² = tail of σ².
        let tail: f64 = svd.s[k..].iter().map(|s| s * s).sum();
        let want = (tail / a.fro_norm_sq()).sqrt();
        assert!((err - want).abs() < 1e-8, "err={err} want={want}");
    }

    #[test]
    fn rank_for_eps_edges() {
        assert_eq!(rank_for_eps(&[], 0.1), 1);
        assert_eq!(rank_for_eps(&[0.0, 0.0], 0.1), 1);
        // All energy in first value → rank 1 at any reasonable eps.
        assert_eq!(rank_for_eps(&[10.0, 0.0, 0.0], 1e-9), 1);
        // eps = 0 → full rank.
        assert_eq!(rank_for_eps(&[3.0, 2.0, 1.0], 0.0), 3);
        // eps = 1 → rank 1 (threshold met immediately... sqrt(tail/total) <= 1 always).
        assert_eq!(rank_for_eps(&[3.0, 2.0, 1.0], 1.0), 1);
    }

    #[test]
    fn wide_matrix_svd() {
        let mut rng = Rng::new(6);
        let a = Mat::<f64>::rand_uniform(5, 40, &mut rng);
        let svd = thin_svd(&a);
        assert_eq!(svd.u.shape(), (5, 5));
        assert_eq!(svd.vt.shape(), (5, 40));
        assert!(rel_err(&a, &svd.reconstruct()) < 1e-8);
    }

    #[test]
    fn rank_deficient_zero_columns() {
        let a = Mat::<f64>::zeros(6, 4);
        let svd = thin_svd(&a);
        assert!(svd.s.iter().all(|&s| s == 0.0));
        assert_eq!(svd.reconstruct().fro_norm(), 0.0);
    }
}
