//! Symmetric eigendecomposition (cyclic Jacobi).
//!
//! Used by the distributed SVD: ranks all-reduce a small Gram matrix
//! `G = X·Xᵀ` (size `r_{l-1}·n_l` — at most a few thousand) and each rank
//! solves the symmetric eigenproblem locally; `σ_i = sqrt(λ_i)`. Jacobi is
//! chosen over QR iteration for its simplicity, unconditional stability and
//! high relative accuracy on the small clustered spectra the rank-selection
//! heuristic inspects.

use super::matrix::Mat;
use super::scalar::Scalar;

/// Result of a symmetric eigendecomposition: `A = V diag(λ) Vᵀ` with
/// eigenvalues sorted in descending order and eigenvectors as columns of V.
#[derive(Clone, Debug)]
pub struct SymEig<T: Scalar> {
    pub values: Vec<f64>,
    pub vectors: Mat<T>,
}

/// Cyclic-Jacobi eigendecomposition of a symmetric matrix.
///
/// Panics if `a` is not square. Symmetry is assumed (the lower triangle is
/// ignored when sweeping but rotations keep the working copy symmetric).
pub fn sym_eig<T: Scalar>(a: &Mat<T>) -> SymEig<T> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "sym_eig: matrix must be square");
    if n == 0 {
        return SymEig { values: vec![], vectors: Mat::zeros(0, 0) };
    }
    // Work in f64 regardless of input width for accuracy.
    let mut m: Vec<f64> = a.as_slice().iter().map(|&x| x.tof()).collect();
    let idx = |i: usize, j: usize| i * n + j;
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[idx(i, i)] = 1.0;
    }

    let max_sweeps = 60;
    let tol = 1e-14 * off_diag_norm(&m, n).max(1e-300);
    for _sweep in 0..max_sweeps {
        let off = off_diag_norm(&m, n);
        if off <= tol || off == 0.0 {
            break;
        }
        for p in 0..n - 1 {
            for q in p + 1..n {
                let apq = m[idx(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[idx(p, p)];
                let aqq = m[idx(q, q)];
                // Stable rotation computation (Golub & Van Loan §8.4).
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply rotation J(p,q,θ) on both sides.
                for k in 0..n {
                    let akp = m[idx(k, p)];
                    let akq = m[idx(k, q)];
                    m[idx(k, p)] = c * akp - s * akq;
                    m[idx(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[idx(p, k)];
                    let aqk = m[idx(q, k)];
                    m[idx(p, k)] = c * apk - s * aqk;
                    m[idx(q, k)] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[idx(k, p)];
                    let vkq = v[idx(k, q)];
                    v[idx(k, p)] = c * vkp - s * vkq;
                    v[idx(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract and sort descending.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[idx(i, i)], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let values: Vec<f64> = pairs.iter().map(|&(l, _)| l).collect();
    let vectors = Mat::from_fn(n, n, |i, j| T::fromf(v[idx(i, pairs[j].1)]));
    SymEig { values, vectors }
}

fn off_diag_norm(m: &[f64], n: usize) -> f64 {
    let mut s = 0.0;
    for i in 0..n {
        for j in 0..n {
            if i != j {
                s += m[i * n + j] * m[i * n + j];
            }
        }
    }
    s.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gram_mt_m, matmul};
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_matrix() {
        let a = Mat::<f64>::from_fn(3, 3, |i, j| if i == j { (3 - i) as f64 } else { 0.0 });
        let e = sym_eig(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reconstructs_matrix() {
        check(201, |rng| {
            let n = 1 + rng.below(12);
            let b = Mat::<f64>::rand_uniform(n + 2, n, rng);
            let a = gram_mt_m(&b); // symmetric PSD
            let e = sym_eig(&a);
            // A ≈ V Λ Vᵀ
            let mut lam = Mat::<f64>::zeros(n, n);
            for i in 0..n {
                lam[(i, i)] = e.values[i];
            }
            let rec = matmul(&matmul(&e.vectors, &lam), &e.vectors.transpose());
            let err = {
                let mut d = rec.clone();
                d.sub_assign(&a);
                d.fro_norm() / a.fro_norm().max(1e-300)
            };
            if err > 1e-9 {
                return Err(format!("reconstruction error {err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut rng = Rng::new(5);
        let b = Mat::<f64>::rand_uniform(20, 8, &mut rng);
        let a = gram_mt_m(&b);
        let e = sym_eig(&a);
        let vtv = matmul(&e.vectors.transpose(), &e.vectors);
        for i in 0..8 {
            for j in 0..8 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv[(i, j)] - want).abs() < 1e-9, "vtv[{i},{j}]={}", vtv[(i, j)]);
            }
        }
    }

    #[test]
    fn values_sorted_descending() {
        let mut rng = Rng::new(6);
        let b = Mat::<f64>::rand_uniform(30, 10, &mut rng);
        let e = sym_eig(&gram_mt_m(&b));
        for w in e.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }

    #[test]
    fn psd_eigenvalues_nonnegative() {
        let mut rng = Rng::new(8);
        let b = Mat::<f64>::rand_uniform(15, 6, &mut rng);
        let e = sym_eig(&gram_mt_m(&b));
        assert!(e.values.iter().all(|&l| l > -1e-10));
    }

    #[test]
    fn empty_and_single() {
        let e = sym_eig(&Mat::<f64>::zeros(0, 0));
        assert!(e.values.is_empty());
        let a = Mat::<f64>::from_vec(1, 1, vec![4.0]);
        let e = sym_eig(&a);
        assert_eq!(e.values, vec![4.0]);
    }
}
