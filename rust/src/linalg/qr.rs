//! Thin Householder QR.
//!
//! Needed by the Tucker/HOOI baseline (orthonormal factor bases) and usable
//! as a preprocessing step for tall-skinny SVDs (`A = QR`, SVD of small R).

use super::matrix::Mat;
use super::scalar::Scalar;

/// Thin QR: `A = Q · R` with `Q: m×k` orthonormal columns, `R: k×n` upper
/// triangular, `k = min(m, n)`.
pub struct Qr<T: Scalar> {
    pub q: Mat<T>,
    pub r: Mat<T>,
}

/// Householder QR (working in f64 internally).
pub fn thin_qr<T: Scalar>(a: &Mat<T>) -> Qr<T> {
    let m = a.rows();
    let n = a.cols();
    let k = m.min(n);
    // Working copy in f64, row-major.
    let mut r: Vec<f64> = a.as_slice().iter().map(|&x| x.tof()).collect();
    let idx = |i: usize, j: usize| i * n + j;
    // Householder vectors, stored per reflection.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(k);

    for j in 0..k {
        // Compute the reflector for column j, rows j..m.
        let mut normx = 0.0;
        for i in j..m {
            normx += r[idx(i, j)] * r[idx(i, j)];
        }
        let normx = normx.sqrt();
        let mut v = vec![0.0; m - j];
        if normx == 0.0 {
            vs.push(v); // zero column: identity reflector
            continue;
        }
        let alpha = if r[idx(j, j)] >= 0.0 { -normx } else { normx };
        for i in j..m {
            v[i - j] = r[idx(i, j)];
        }
        v[0] -= alpha;
        let vnorm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if vnorm > 0.0 {
            for x in &mut v {
                *x /= vnorm;
            }
            // Apply H = I - 2vvᵀ to R[j.., j..].
            for c in j..n {
                let mut dot = 0.0;
                for i in j..m {
                    dot += v[i - j] * r[idx(i, c)];
                }
                for i in j..m {
                    r[idx(i, c)] -= 2.0 * v[i - j] * dot;
                }
            }
        }
        vs.push(v);
    }

    // R is the top k×n of the working copy (zero the sub-diagonal noise).
    let mut rm = Mat::<T>::zeros(k, n);
    for i in 0..k {
        for j in 0..n {
            rm[(i, j)] = if j >= i { T::fromf(r[idx(i, j)]) } else { T::zero() };
        }
    }

    // Accumulate Q by applying reflections to the first k columns of I.
    let mut q = vec![0.0f64; m * k];
    for j in 0..k {
        q[j * k + j] = 1.0;
    }
    for (j, v) in vs.iter().enumerate().rev() {
        if v.iter().all(|&x| x == 0.0) {
            continue;
        }
        for c in 0..k {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i - j] * q[i * k + c];
            }
            for i in j..m {
                q[i * k + c] -= 2.0 * v[i - j] * dot;
            }
        }
    }
    let qm = Mat::<T>::from_fn(m, k, |i, j| T::fromf(q[i * k + j]));
    Qr { q: qm, r: rm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn rel_err(a: &Mat<f64>, b: &Mat<f64>) -> f64 {
        let mut d = a.clone();
        d.sub_assign(b);
        d.fro_norm() / a.fro_norm().max(1e-300)
    }

    #[test]
    fn qr_reconstructs() {
        check(401, |rng| {
            let m = 1 + rng.below(30);
            let n = 1 + rng.below(30);
            let a = Mat::<f64>::rand_uniform(m, n, rng);
            let qr = thin_qr(&a);
            let err = rel_err(&a, &matmul(&qr.q, &qr.r));
            if err > 1e-10 {
                return Err(format!("{m}x{n}: err {err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn q_orthonormal() {
        let mut rng = Rng::new(2);
        let a = Mat::<f64>::rand_uniform(25, 10, &mut rng);
        let qr = thin_qr(&a);
        let qtq = matmul(&qr.q.transpose(), &qr.q);
        for i in 0..10 {
            for j in 0..10 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq[(i, j)] - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn r_upper_triangular() {
        let mut rng = Rng::new(3);
        let a = Mat::<f64>::rand_uniform(12, 8, &mut rng);
        let qr = thin_qr(&a);
        for i in 0..qr.r.rows() {
            for j in 0..i.min(qr.r.cols()) {
                assert_eq!(qr.r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn zero_column_handled() {
        let mut a = Mat::<f64>::zeros(5, 3);
        a[(0, 0)] = 1.0;
        a[(1, 2)] = 2.0; // middle column zero
        let qr = thin_qr(&a);
        assert!(rel_err(&a, &matmul(&qr.q, &qr.r)) < 1e-12);
    }
}
