//! Dense row-major matrix.
//!
//! The workhorse container for every local (per-rank) computation: NMF
//! factor blocks, Gram matrices, unfolded tensor blocks. Deliberately
//! minimal — heavy kernels live in [`crate::linalg::gemm`] and friends so
//! they can be profiled and tuned in isolation.

use super::scalar::Scalar;
use crate::util::rng::Rng;
use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix of `Scalar` elements. `Default` is the empty
/// `0×0` matrix — the seed state of reusable workspace buffers.
#[derive(Clone, Default, PartialEq)]
pub struct Mat<T: Scalar = f64> {
    rows: usize,
    cols: usize,
    data: Vec<T>,
}

impl<T: Scalar> Mat<T> {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![T::zero(); rows * cols] }
    }

    /// Constant-filled matrix.
    pub fn filled(rows: usize, cols: usize, v: T) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = T::one();
        }
        m
    }

    /// Take ownership of a row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> T) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Uniform [0,1) entries — the factor initialization used by Alg 3.
    pub fn rand_uniform(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Mat::from_fn(rows, cols, |_, _| T::fromf(rng.uniform()))
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn as_slice(&self) -> &[T] {
        &self.data
    }
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.data
    }
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[T] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [T] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<T> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Sub-matrix of rows [r0, r1).
    pub fn rows_slice(&self, r0: usize, r1: usize) -> Mat<T> {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat::from_vec(r1 - r0, self.cols, self.data[r0 * self.cols..r1 * self.cols].to_vec())
    }

    /// Sub-matrix of columns [c0, c1).
    pub fn cols_slice(&self, c0: usize, c1: usize) -> Mat<T> {
        assert!(c0 <= c1 && c1 <= self.cols);
        Mat::from_fn(self.rows, c1 - c0, |i, j| self[(i, c0 + j)])
    }

    /// Transposed copy (blocked for cache friendliness).
    pub fn transpose(&self) -> Mat<T> {
        const B: usize = 32;
        let mut out = Mat::zeros(self.cols, self.rows);
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out[(j, i)] = self[(i, j)];
                    }
                }
            }
        }
        out
    }

    /// Re-shape in place to `rows × cols`, zero-filled, reusing the
    /// backing allocation when it is large enough. The workhorse of the
    /// NMF workspace: after warm-up to the high-water size, `reset` never
    /// touches the allocator.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.data.clear();
        self.data.resize(rows * cols, T::zero());
        self.rows = rows;
        self.cols = cols;
    }

    /// Like [`Mat::reset`] but skips the zero-fill for the retained
    /// prefix: existing element values are **unspecified** (stale data or
    /// zeros). Only for buffers the caller fully overwrites before any
    /// read — e.g. a GEMM output whose kernel zeroes C itself — where
    /// `reset`'s extra memory pass would be pure waste on the hot path.
    pub fn resize_for_overwrite(&mut self, rows: usize, cols: usize) {
        self.data.resize(rows * cols, T::zero());
        self.rows = rows;
        self.cols = cols;
    }

    /// Copy `other`'s contents into `self` (shapes must match; no
    /// allocation). The reuse-friendly replacement for `*self = other.clone()`.
    pub fn copy_from(&mut self, other: &Mat<T>) {
        assert_eq!(self.shape(), other.shape(), "copy_from: shape mismatch");
        self.data.copy_from_slice(&other.data);
    }

    /// Reinterpret as a new shape (row-major order preserved, zero-copy).
    pub fn reshaped(self, rows: usize, cols: usize) -> Mat<T> {
        assert_eq!(rows * cols, self.data.len(), "reshape size mismatch");
        Mat { rows, cols, data: self.data }
    }

    /// Element-wise map into a new matrix.
    pub fn map(&self, f: impl Fn(T) -> T) -> Mat<T> {
        Mat { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Element-wise in-place map.
    pub fn map_inplace(&mut self, f: impl Fn(T) -> T) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// `self += alpha * other`.
    pub fn axpy(&mut self, alpha: T, other: &Mat<T>) {
        assert_eq!(self.shape(), other.shape());
        for (x, &y) in self.data.iter_mut().zip(other.data.iter()) {
            *x = y.fma(alpha, *x);
        }
    }

    /// `self -= other`.
    pub fn sub_assign(&mut self, other: &Mat<T>) {
        assert_eq!(self.shape(), other.shape());
        for (x, &y) in self.data.iter_mut().zip(other.data.iter()) {
            *x -= y;
        }
    }

    /// Scale all elements.
    pub fn scale(&mut self, alpha: T) {
        for x in &mut self.data {
            *x *= alpha;
        }
    }

    /// Project onto the non-negative orthant: `max(0, x)` element-wise.
    pub fn project_nonneg(&mut self) {
        for x in &mut self.data {
            if *x < T::zero() {
                *x = T::zero();
            }
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| x.tof() * x.tof()).sum::<f64>().sqrt()
    }

    /// Squared Frobenius norm.
    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|&x| x.tof() * x.tof()).sum::<f64>()
    }

    /// Entry-wise L1 norm.
    pub fn l1_norm(&self) -> f64 {
        self.data.iter().map(|&x| x.tof().abs()).sum::<f64>()
    }

    /// Max |x|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().map(|&x| x.tof().abs()).fold(0.0, f64::max)
    }

    /// Minimum element.
    pub fn min_elem(&self) -> f64 {
        self.data.iter().map(|&x| x.tof()).fold(f64::INFINITY, f64::min)
    }

    /// True if all entries are ≥ 0 (the nTT invariant).
    pub fn is_nonneg(&self) -> bool {
        self.data.iter().all(|&x| x >= T::zero())
    }

    /// Convert the element type.
    pub fn cast<U: Scalar>(&self) -> Mat<U> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| U::fromf(x.tof())).collect(),
        }
    }

    /// Stack vertically: rows of `self` then rows of `other`.
    pub fn vstack(&self, other: &Mat<T>) -> Mat<T> {
        assert_eq!(self.cols, other.cols);
        let mut data = Vec::with_capacity((self.rows + other.rows) * self.cols);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Mat { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Stack horizontally: columns of `self` then columns of `other`.
    pub fn hstack(&self, other: &Mat<T>) -> Mat<T> {
        assert_eq!(self.rows, other.rows);
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }
}

impl<T: Scalar> Index<(usize, usize)> for Mat<T> {
    type Output = T;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &T {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl<T: Scalar> IndexMut<(usize, usize)> for Mat<T> {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut T {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl<T: Scalar> fmt::Debug for Mat<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat<{}> {}x{}", T::NAME, self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  [")?;
            for j in 0..show_c {
                write!(f, "{:>10.4}", self[(i, j)].tof())?;
            }
            writeln!(f, "{}]", if self.cols > show_c { " …" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let mut m = Mat::<f64>::zeros(2, 3);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn from_fn_layout() {
        let m = Mat::<f64>::from_fn(2, 2, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.as_slice(), &[0.0, 1.0, 10.0, 11.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let m = Mat::<f64>::rand_uniform(37, 53, &mut rng);
        let t = m.transpose().transpose();
        assert_eq!(m, t);
    }

    #[test]
    fn transpose_correct() {
        let m = Mat::<f64>::from_fn(3, 2, |i, j| (i * 2 + j) as f64);
        let t = m.transpose();
        assert_eq!(t.shape(), (2, 3));
        for i in 0..3 {
            for j in 0..2 {
                assert_eq!(m[(i, j)], t[(j, i)]);
            }
        }
    }

    #[test]
    fn norms() {
        let m = Mat::<f64>::from_vec(1, 2, vec![3.0, -4.0]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-12);
        assert_eq!(m.l1_norm(), 7.0);
        assert_eq!(m.max_abs(), 4.0);
        assert_eq!(m.min_elem(), -4.0);
    }

    #[test]
    fn project_nonneg() {
        let mut m = Mat::<f64>::from_vec(1, 3, vec![-1.0, 0.0, 2.0]);
        assert!(!m.is_nonneg());
        m.project_nonneg();
        assert_eq!(m.as_slice(), &[0.0, 0.0, 2.0]);
        assert!(m.is_nonneg());
    }

    #[test]
    fn axpy_and_sub() {
        let mut a = Mat::<f64>::filled(2, 2, 1.0);
        let b = Mat::<f64>::filled(2, 2, 2.0);
        a.axpy(0.5, &b);
        assert_eq!(a.as_slice(), &[2.0; 4]);
        a.sub_assign(&b);
        assert_eq!(a.as_slice(), &[0.0; 4]);
    }

    #[test]
    fn stacking() {
        let a = Mat::<f64>::filled(1, 2, 1.0);
        let b = Mat::<f64>::filled(2, 2, 2.0);
        let v = a.vstack(&b);
        assert_eq!(v.shape(), (3, 2));
        assert_eq!(v.row(2), &[2.0, 2.0]);
        let h = a.hstack(&Mat::filled(1, 3, 3.0));
        assert_eq!(h.shape(), (1, 5));
        assert_eq!(h.row(0), &[1.0, 1.0, 3.0, 3.0, 3.0]);
    }

    #[test]
    fn slices() {
        let m = Mat::<f64>::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        let r = m.rows_slice(1, 3);
        assert_eq!(r.shape(), (2, 3));
        assert_eq!(r[(0, 0)], 3.0);
        let c = m.cols_slice(1, 3);
        assert_eq!(c.shape(), (4, 2));
        assert_eq!(c[(0, 0)], 1.0);
    }

    #[test]
    fn reset_reuses_allocation_and_zeroes() {
        let mut m = Mat::<f64>::filled(4, 5, 3.0);
        m.reset(2, 3);
        assert_eq!(m.shape(), (2, 3));
        assert!(m.as_slice().iter().all(|&x| x == 0.0));
        // Growing within capacity must still zero every element.
        m.as_mut_slice()[0] = 9.0;
        m.reset(4, 5);
        assert_eq!(m.shape(), (4, 5));
        assert!(m.as_slice().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn copy_from_matches_clone() {
        let mut rng = Rng::new(3);
        let a = Mat::<f64>::rand_uniform(6, 7, &mut rng);
        let mut b = Mat::<f64>::zeros(6, 7);
        b.copy_from(&a);
        assert_eq!(a, b);
    }

    #[test]
    fn reshape_preserves_order() {
        let m = Mat::<f64>::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        let r = m.clone().reshaped(3, 2);
        assert_eq!(r.as_slice(), m.as_slice());
        assert_eq!(r[(2, 1)], 5.0);
    }

    #[test]
    fn cast_widths() {
        let m = Mat::<f64>::from_vec(1, 2, vec![1.5, 2.5]);
        let f: Mat<f32> = m.cast();
        assert_eq!(f.as_slice(), &[1.5f32, 2.5f32]);
    }

    #[test]
    fn eye() {
        let m = Mat::<f64>::eye(3);
        assert_eq!(m[(1, 1)], 1.0);
        assert_eq!(m[(0, 1)], 0.0);
        assert!((m.fro_norm_sq() - 3.0).abs() < 1e-12);
    }
}
