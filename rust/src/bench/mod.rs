//! Benchmark infrastructure: the mini-criterion harness plus the shared
//! workload definitions used by the per-figure bench targets.

pub mod harness;
pub mod workloads;
