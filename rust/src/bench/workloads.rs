//! Shared experiment harness: one function per paper figure.
//!
//! Both the `cargo bench` targets (`rust/benches/fig*.rs`) and the CLI
//! (`dntt sweep|scaling|denoise`) call into these, so the numbers in
//! EXPERIMENTS.md are regenerable from either entry point. Sizes default
//! to laptop-scale (this image has one core); `--scale`-style parameters
//! accept the paper's full sizes.

use crate::baselines::{ntucker_eps, tt_svd, tt_svd_fixed, tucker_hooi};
use crate::coordinator::{run_job, Decomposition, InputSpec, JobConfig};
use crate::data::{
    add_gaussian_noise, generate_faces, generate_video, mean_ssim_images, FaceConfig, VideoConfig,
};
use crate::dist::{CostModel, ProcGrid};
use crate::error::Result;
use crate::ht::{ht_serial, HtConfig};
use crate::nmf::{NmfAlgo, NmfConfig};
use crate::tensor::DenseTensor;
use crate::ttrain::{ntt_serial, SyntheticTt, TtConfig};
use crate::util::json::Json;
use crate::util::timer::Breakdown;
use std::time::Instant;

/// The ε schedule used for the paper's compression sweeps (§IV-C2).
pub const PAPER_EPS: [f64; 7] = [0.5, 0.25, 0.125, 0.075, 0.01, 0.005, 0.001];

// ===========================================================================
// Fig 2 / Fig 8 — compression vs relative error
// ===========================================================================

/// One point of a compression-vs-error curve.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub algo: String,
    pub eps: f64,
    pub compression: f64,
    pub rel_err: f64,
    pub secs: f64,
}

impl SweepRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("algo", Json::Str(self.algo.clone())),
            ("eps", Json::Num(self.eps)),
            ("compression", Json::Num(self.compression)),
            ("rel_err", Json::Num(self.rel_err)),
            ("secs", Json::Num(self.secs)),
        ])
    }
}

pub fn print_sweep(rows: &[SweepRow]) {
    println!("{:<10} {:>8} {:>14} {:>12} {:>9}", "algo", "eps", "compression", "rel_err", "secs");
    for r in rows {
        println!(
            "{:<10} {:>8.4} {:>14.4} {:>12.6} {:>9.3}",
            r.algo, r.eps, r.compression, r.rel_err, r.secs
        );
    }
}

fn ntt_cfg(eps: f64, iters: usize, algo: NmfAlgo) -> TtConfig {
    TtConfig {
        eps,
        nmf: NmfConfig { max_iters: iters, tol: 1e-10, algo, ..Default::default() },
        ..Default::default()
    }
}

/// Fig 2: TT vs nTT vs Tucker vs nTucker on an `n⁴` synthetic tensor.
pub fn fig2_sweep(n: usize, eps_list: &[f64], nmf_iters: usize) -> Result<Vec<SweepRow>> {
    let syn = SyntheticTt::new(vec![n; 4], vec![5, 5, 5], 32323232);
    let t = syn.dense();
    let mut rows = Vec::new();
    for &eps in eps_list {
        // TT-SVD.
        let t0 = Instant::now();
        let tt = tt_svd(&t, eps)?;
        rows.push(SweepRow {
            algo: "TT".into(),
            eps,
            compression: tt.compression_ratio(),
            rel_err: tt.rel_error(&t),
            secs: t0.elapsed().as_secs_f64(),
        });
        // nTT (BCD).
        let t0 = Instant::now();
        let out = ntt_serial(&t, &ntt_cfg(eps, nmf_iters, NmfAlgo::Bcd))?;
        rows.push(SweepRow {
            algo: "nTT".into(),
            eps,
            compression: out.tt.compression_ratio(),
            rel_err: out.tt.rel_error(&t),
            secs: t0.elapsed().as_secs_f64(),
        });
        // Tucker.
        let t0 = Instant::now();
        let tk = tucker_hooi(&t, eps, 2)?;
        rows.push(SweepRow {
            algo: "Tucker".into(),
            eps,
            compression: tk.compression_ratio(),
            rel_err: t.rel_error(&tk.reconstruct()),
            secs: t0.elapsed().as_secs_f64(),
        });
        // nTucker.
        let t0 = Instant::now();
        let ntk = ntucker_eps(&t, eps, nmf_iters, 99)?;
        rows.push(SweepRow {
            algo: "nTucker".into(),
            eps,
            compression: ntk.compression_ratio(),
            rel_err: t.rel_error(&ntk.reconstruct()),
            secs: t0.elapsed().as_secs_f64(),
        });
    }
    Ok(rows)
}

/// Which Fig-8 dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fig8Data {
    /// 8a — Yale-like faces (48×42×64×38 by default; `scale` shrinks).
    Faces,
    /// 8b — video (100×260×3×85 by default).
    Video,
    /// 8c — large synthetic (1024×512³ at scale=1; default scale shrinks).
    LargeSynthetic,
}

/// Fig 8: TT vs nTT compression curves on the real-world-style datasets.
/// For 8c the paper also contrasts BCD vs MU — both are emitted.
pub fn fig8_sweep(
    which: Fig8Data,
    eps_list: &[f64],
    nmf_iters: usize,
    scale: usize,
) -> Result<Vec<SweepRow>> {
    let s = scale.max(1);
    let t: DenseTensor<f64> = match which {
        Fig8Data::Faces => generate_faces(&FaceConfig {
            height: 48 / s.min(4),
            width: 42 / s.min(3),
            illuminations: 64 / s,
            subjects: (38 / s).max(2),
            ..Default::default()
        }),
        Fig8Data::Video => generate_video(&VideoConfig {
            height: (100 / s).max(8),
            width: (260 / s).max(8),
            channels: 3,
            frames: (85 / s).max(4),
            ..Default::default()
        }),
        Fig8Data::LargeSynthetic => {
            let nd = |x: usize| (x / s).max(8);
            SyntheticTt::new(
                vec![nd(1024), nd(512), nd(512), nd(512)],
                vec![20usize, 30, 40].iter().map(|&r| r.min(nd(512) / 2)).collect(),
                500_000_000,
            )
            .dense()
        }
    };
    let mut rows = Vec::new();
    for &eps in eps_list {
        let t0 = Instant::now();
        let tt = tt_svd(&t, eps)?;
        rows.push(SweepRow {
            algo: "TT".into(),
            eps,
            compression: tt.compression_ratio(),
            rel_err: tt.rel_error(&t),
            secs: t0.elapsed().as_secs_f64(),
        });
        let t0 = Instant::now();
        let out = ntt_serial(&t, &ntt_cfg(eps, nmf_iters, NmfAlgo::Bcd))?;
        rows.push(SweepRow {
            algo: "nTT-BCD".into(),
            eps,
            compression: out.tt.compression_ratio(),
            rel_err: out.tt.rel_error(&t),
            secs: t0.elapsed().as_secs_f64(),
        });
        if which == Fig8Data::LargeSynthetic {
            let t0 = Instant::now();
            let out = ntt_serial(&t, &ntt_cfg(eps, nmf_iters, NmfAlgo::Mu))?;
            rows.push(SweepRow {
                algo: "nTT-MU".into(),
                eps,
                compression: out.tt.compression_ratio(),
                rel_err: out.tt.rel_error(&t),
                secs: t0.elapsed().as_secs_f64(),
            });
        }
    }
    Ok(rows)
}

/// nTT vs nHT compression curves on an `n⁴` synthetic tensor (the HT
/// workload mirroring Fig 2's sweep): both serial drivers at each ε.
pub fn ht_vs_tt_sweep(n: usize, eps_list: &[f64], nmf_iters: usize) -> Result<Vec<SweepRow>> {
    let syn = SyntheticTt::new(vec![n; 4], vec![5, 5, 5], 32323232);
    let t = syn.dense();
    let mut rows = Vec::new();
    for &eps in eps_list {
        let t0 = Instant::now();
        let out = ntt_serial(&t, &ntt_cfg(eps, nmf_iters, NmfAlgo::Bcd))?;
        rows.push(SweepRow {
            algo: "nTT".into(),
            eps,
            compression: out.tt.compression_ratio(),
            rel_err: out.tt.rel_error(&t),
            secs: t0.elapsed().as_secs_f64(),
        });
        let t0 = Instant::now();
        let cfg = HtConfig {
            eps,
            nmf: NmfConfig { max_iters: nmf_iters, tol: 1e-10, ..Default::default() },
            ..Default::default()
        };
        let out = ht_serial(&t, &cfg)?;
        rows.push(SweepRow {
            algo: "nHT".into(),
            eps,
            compression: out.ht.compression_ratio(),
            rel_err: out.ht.rel_error(&t),
            secs: t0.elapsed().as_secs_f64(),
        });
    }
    Ok(rows)
}

// ===========================================================================
// Figs 5–7 — scaling
// ===========================================================================

/// One point of a scaling series.
pub struct ScalePoint {
    pub p: usize,
    pub grid: Vec<usize>,
    pub dims: Vec<usize>,
    pub tt_ranks: Vec<usize>,
    pub algo: String,
    pub wall_secs: f64,
    pub measured: Breakdown,
    pub modeled: Breakdown,
}

impl ScalePoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("p", Json::Num(self.p as f64)),
            ("grid", Json::arr_usize(&self.grid)),
            ("dims", Json::arr_usize(&self.dims)),
            ("tt_ranks", Json::arr_usize(&self.tt_ranks)),
            ("algo", Json::Str(self.algo.clone())),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("measured_total", Json::Num(self.measured.total_secs())),
            ("modeled_total", Json::Num(self.modeled.total_secs())),
            ("modeled_compute", Json::Num(self.modeled.compute_secs())),
            ("modeled_comm", Json::Num(self.modeled.comm_secs())),
        ])
    }
}

pub fn print_scaling(points: &[ScalePoint]) {
    println!(
        "{:<6} {:<14} {:<8} {:>10} {:>12} {:>12} {:>12}",
        "p", "grid", "algo", "wall(s)", "model_tot", "model_comp", "model_comm"
    );
    for pt in points {
        println!(
            "{:<6} {:<14} {:<8} {:>10.3} {:>12.4} {:>12.4} {:>12.4}",
            pt.p,
            format!("{:?}", pt.grid),
            pt.algo,
            pt.wall_secs,
            pt.modeled.total_secs(),
            pt.modeled.compute_secs(),
            pt.modeled.comm_secs()
        );
    }
}

/// Scaling mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScalingMode {
    /// Fig 5: fixed tensor, grids 2^k×2×2×2.
    Strong,
    /// Fig 6: per-rank data fixed — first dim grows with p.
    Weak,
    /// Fig 7: p fixed, TT rank sweeps {2,4,8,16}.
    Ranks,
}

/// Parameters for a scaling study.
pub struct ScalingParams {
    /// Which decomposition to scale (the HT series mirrors the paper's TT
    /// studies on the same tensors and grids).
    pub decomp: Decomposition,
    /// Mode-size divisor vs the paper's 256 (default 4 → 64⁴ base tensor).
    pub shrink: usize,
    /// 2^k first-dim grid exponents to sweep (paper: 1..=5).
    pub ks: Vec<usize>,
    /// NMF iterations (paper fixes 100).
    pub iters: usize,
    /// BCD and/or MU.
    pub algos: Vec<NmfAlgo>,
    /// TT ranks (paper: 10,10,10 for Figs 5–6).
    pub ranks: Vec<usize>,
    /// Fixed 2^k exponent for the rank sweep (Fig 7; paper: 5 → 256 ranks).
    pub ranks_p_exp: usize,
    /// TT-rank values for Fig 7.
    pub rank_sweep: Vec<usize>,
    pub cost_model: CostModel,
}

impl Default for ScalingParams {
    fn default() -> Self {
        ScalingParams {
            decomp: Decomposition::Tt,
            shrink: 4,
            ks: vec![1, 2, 3, 4, 5],
            iters: 10,
            algos: vec![NmfAlgo::Bcd, NmfAlgo::Mu],
            ranks: vec![10, 10, 10],
            ranks_p_exp: 5,
            rank_sweep: vec![2, 4, 8, 16],
            cost_model: CostModel::default(),
        }
    }
}

/// Run a scaling study (Figs 5, 6 or 7).
pub fn scaling_run(mode: ScalingMode, params: &ScalingParams) -> Result<Vec<ScalePoint>> {
    let base = (256 / params.shrink.max(1)).max(4);
    let mut points = Vec::new();
    let cases: Vec<(usize, Vec<usize>, Vec<usize>)> = match mode {
        ScalingMode::Strong => params
            .ks
            .iter()
            .map(|&k| (k, vec![base; 4], params.ranks.clone()))
            .collect(),
        ScalingMode::Weak => params
            .ks
            .iter()
            .map(|&k| {
                let mut dims = vec![base; 4];
                dims[0] = base << (k - 1); // per-rank volume constant
                (k, dims, params.ranks.clone())
            })
            .collect(),
        ScalingMode::Ranks => params
            .rank_sweep
            .iter()
            .map(|&r| (params.ranks_p_exp, vec![base; 4], vec![r; 3]))
            .collect(),
    };
    for (k, dims, ranks) in cases {
        let grid = ProcGrid::paper_grid(k, 4)?;
        for &algo in &params.algos {
            // HT needs two fixed edge ranks per interior node; cycle the
            // requested TT-rank list over the 2(d−1) tree edges.
            let ht_ranks: Vec<usize> =
                ranks.iter().cycle().take(2 * (dims.len() - 1)).cloned().collect();
            let nmf = NmfConfig { max_iters: params.iters, algo, ..Default::default() };
            let job = JobConfig {
                decomp: params.decomp,
                tt: TtConfig {
                    fixed_ranks: Some(ranks.clone()),
                    nmf: nmf.clone(),
                    ..Default::default()
                },
                ht: HtConfig { fixed_ranks: Some(ht_ranks), nmf, ..Default::default() },
                check_error: false,
                cost_model: Some(params.cost_model),
                ..JobConfig::new(
                    InputSpec::Synthetic(SyntheticTt::new(dims.clone(), ranks.clone(), 20190020)),
                    grid.clone(),
                )
            };
            let rep = run_job(&job)?;
            points.push(ScalePoint {
                p: grid.size(),
                grid: grid.dims().to_vec(),
                dims: dims.clone(),
                tt_ranks: ranks.clone(),
                algo: algo.name().into(),
                wall_secs: rep.wall_secs,
                measured: rep.measured.clone(),
                modeled: rep.modeled.clone().unwrap(),
            });
        }
    }
    Ok(points)
}

// ===========================================================================
// Fig 9 — denoising (SSIM)
// ===========================================================================

/// One row of the denoising comparison.
pub struct DenoiseRow {
    pub rank: usize,
    pub compression_tt: f64,
    pub compression_ntt: f64,
    pub ssim_noisy: f64,
    pub ssim_tt: f64,
    pub ssim_ntt: f64,
}

impl DenoiseRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rank", Json::Num(self.rank as f64)),
            ("compression_tt", Json::Num(self.compression_tt)),
            ("compression_ntt", Json::Num(self.compression_ntt)),
            ("ssim_noisy", Json::Num(self.ssim_noisy)),
            ("ssim_tt", Json::Num(self.ssim_tt)),
            ("ssim_ntt", Json::Num(self.ssim_ntt)),
        ])
    }
}

pub fn print_denoise(rows: &[DenoiseRow]) {
    println!(
        "{:<6} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "rank", "comp_TT", "comp_nTT", "ssim_in", "ssim_TT", "ssim_nTT"
    );
    for r in rows {
        println!(
            "{:<6} {:>10.2} {:>10.2} {:>10.4} {:>10.4} {:>10.4}",
            r.rank, r.compression_tt, r.compression_ntt, r.ssim_noisy, r.ssim_tt, r.ssim_ntt
        );
    }
}

/// Fig 9: decompose noisy faces at decreasing TT ranks; SSIM of the
/// reconstruction vs the clean tensor, for SVD-TT vs NMF-TT.
pub fn denoise_run(
    faces: &FaceConfig,
    sigma_frac: f64,
    rank_sweep: &[usize],
    nmf_iters: usize,
) -> Result<Vec<DenoiseRow>> {
    let clean = generate_faces(faces);
    let peak = clean.as_slice().iter().cloned().fold(0.0f64, f64::max);
    let noisy = add_gaussian_noise(&clean, sigma_frac * peak, 900);
    let ssim_noisy = mean_ssim_images(&clean, &noisy);
    let mut rows = Vec::new();
    for &r in rank_sweep {
        let ranks = vec![r, r, r];
        let tt = tt_svd_fixed(&noisy, &ranks)?;
        let mut cfg = ntt_cfg(0.0, nmf_iters, NmfAlgo::Bcd);
        cfg.fixed_ranks = Some(ranks.clone());
        let ntt = ntt_serial(&noisy, &cfg)?;
        rows.push(DenoiseRow {
            rank: r,
            compression_tt: tt.compression_ratio(),
            compression_ntt: ntt.tt.compression_ratio(),
            ssim_noisy,
            ssim_tt: mean_ssim_images(&clean, &tt.reconstruct()),
            ssim_ntt: mean_ssim_images(&clean, &ntt.tt.reconstruct()),
        });
    }
    Ok(rows)
}

/// Save any JSON rows under `bench_results/BENCH_<label>.json` (dntt-bench-v1 envelope).
pub fn save_rows(label: &str, rows: Vec<Json>) -> std::io::Result<()> {
    std::fs::create_dir_all("bench_results")?;
    let path = format!("bench_results/BENCH_{label}.json");
    // Same `dntt-bench-v1` envelope as `harness::Bench::save`, with the
    // figure series under "rows" instead of harness "cases".
    let envelope = Json::obj(vec![
        ("schema", Json::Str("dntt-bench-v1".to_string())),
        ("label", Json::Str(label.to_string())),
        ("git_sha", Json::Str(crate::bench::harness::git_sha())),
        ("smoke", Json::Bool(crate::bench::harness::smoke_requested())),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write(&path, envelope.to_pretty())?;
    println!("(series written to {path})");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_tiny_sweep_shapes() {
        let rows = fig2_sweep(6, &[0.5, 0.01], 25).unwrap();
        assert_eq!(rows.len(), 8); // 4 algos × 2 eps
        // Tight eps must not have worse error than loose for the SVD-TT.
        let tt: Vec<&SweepRow> = rows.iter().filter(|r| r.algo == "TT").collect();
        assert!(tt[1].rel_err <= tt[0].rel_err + 1e-9);
        assert!(tt[1].compression <= tt[0].compression + 1e-9);
    }

    #[test]
    fn scaling_strong_tiny() {
        let params = ScalingParams {
            shrink: 32, // 8^4 tensor
            ks: vec![1, 2],
            iters: 3,
            algos: vec![NmfAlgo::Bcd],
            ranks: vec![2, 2, 2],
            ..Default::default()
        };
        let pts = scaling_run(ScalingMode::Strong, &params).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].p, 16);
        assert_eq!(pts[1].p, 32);
    }

    #[test]
    fn ht_sweep_tiny() {
        let rows = ht_vs_tt_sweep(6, &[0.5], 20).unwrap();
        assert_eq!(rows.len(), 2); // nTT + nHT
        assert_eq!(rows[0].algo, "nTT");
        assert_eq!(rows[1].algo, "nHT");
        assert!(rows.iter().all(|r| r.compression > 0.0 && r.rel_err.is_finite()));
    }

    #[test]
    fn scaling_ht_tiny() {
        let params = ScalingParams {
            decomp: Decomposition::Ht,
            shrink: 32, // 8^4 tensor
            ks: vec![1],
            iters: 3,
            algos: vec![NmfAlgo::Bcd],
            ranks: vec![2, 2, 2],
            ..Default::default()
        };
        let pts = scaling_run(ScalingMode::Strong, &params).unwrap();
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].p, 16);
        assert!(pts[0].modeled.total_secs() > 0.0);
    }

    #[test]
    fn denoise_tiny() {
        let faces = FaceConfig { height: 16, width: 14, illuminations: 6, subjects: 4, seed: 2 };
        let rows = denoise_run(&faces, 0.1, &[6, 2], 40).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.ssim_tt > 0.0 && r.ssim_tt <= 1.0);
            assert!(r.ssim_ntt > 0.0 && r.ssim_ntt <= 1.0);
        }
    }
}
