//! TT orthogonalization and truncation — prepare a train for serving.
//!
//! A TT is *left-orthogonal* when every core but the last, viewed as the
//! tall `(r_m·n_m) × r_{m+1}` matrix, has orthonormal columns; it is
//! *right-orthogonal* when every core but the first, viewed as the wide
//! `r_m × (n_m·r_{m+1})` matrix, has orthonormal rows. Either form makes
//! the represented tensor's norm equal the norm of the single
//! non-orthogonal core, which is what makes local SVD truncation
//! globally near-optimal (Oseledets 2011, Alg. 2):
//!
//! * [`left_orthogonalize`] — left-to-right QR sweep, remainder folded
//!   forward into the next core.
//! * [`right_orthogonalize`] — right-to-left RQ sweep (QR of the
//!   transposed wide view), remainder folded backward.
//! * [`truncate`] — right-orthogonalize, then a left-to-right SVD sweep
//!   keeping the smallest rank meeting the per-stage tolerance `eps`
//!   *and* an optional hard `max_rank` budget. Per-stage `eps` bounds the
//!   total relative error by `eps·√(d−1)`; a pure rank-budget truncation
//!   is `truncate(tt, 0.0, Some(r))`.
//!
//! All three return a new train representing the same tensor (truncation:
//! up to the requested tolerance); ranks never grow. SVD/QR do not
//! preserve the non-negativity of nTT cores — serve artifacts trade the
//! invariant for storage, as documented on `crate::ttrain::tt_round`
//! (which delegates to [`truncate`] with no rank budget).

use crate::error::Result;
use crate::linalg::gemm::matmul;
use crate::linalg::qr::thin_qr;
use crate::linalg::svd::{rank_for_eps, thin_svd};
use crate::linalg::Mat;
use crate::tensor::TTensor;

/// Left-to-right QR sweep: cores `0..d−1` become left-orthogonal, the
/// last core absorbs every remainder.
pub fn left_orthogonalize(tt: &TTensor<f64>) -> Result<TTensor<f64>> {
    let d = tt.dims().len();
    let dims = tt.dims().to_vec();
    let mut cores: Vec<Mat<f64>> = tt.cores().to_vec();
    let mut ranks = tt.ranks().to_vec();
    for i in 0..d.saturating_sub(1) {
        // Core i is already the tall (r_i·n_i) × r_{i+1} matrix.
        let qr = thin_qr(&cores[i]);
        let k = qr.q.cols(); // = min(r_i·n_i, r_{i+1})
        cores[i] = qr.q;
        // Fold R (k × r_{i+1}) forward: core i+1 viewed r_{i+1} × (n·r).
        let view = cores[i + 1].clone().reshaped(ranks[i + 1], dims[i + 1] * ranks[i + 2]);
        cores[i + 1] = matmul(&qr.r, &view).reshaped(k * dims[i + 1], ranks[i + 2]);
        ranks[i + 1] = k;
    }
    TTensor::new(dims, cores)
}

/// Right-to-left RQ sweep: cores `1..d` become right-orthogonal, core 0
/// absorbs every remainder. (RQ is computed as QR of the transposed
/// `r_i × (n_i·r_{i+1})` view.)
pub fn right_orthogonalize(tt: &TTensor<f64>) -> Result<TTensor<f64>> {
    let dims = tt.dims().to_vec();
    let (cores, _) = right_ortho_cores(tt);
    TTensor::new(dims, cores)
}

/// Shared right-orthogonalization sweep; returns the new cores and rank
/// chain.
fn right_ortho_cores(tt: &TTensor<f64>) -> (Vec<Mat<f64>>, Vec<usize>) {
    let d = tt.dims().len();
    let dims = tt.dims();
    let mut cores: Vec<Mat<f64>> = tt.cores().to_vec();
    let mut ranks = tt.ranks().to_vec();
    for i in (1..d).rev() {
        let r_prev = ranks[i];
        let r_next = ranks[i + 1];
        // View core i as r_prev × (n_i·r_next); QR of the transpose gives
        // ci = Rᵀ·Qᵀ with Qᵀ row-orthonormal.
        let ci = cores[i].clone().reshaped(r_prev, dims[i] * r_next);
        let qr = thin_qr(&ci.transpose());
        let k = qr.q.cols(); // = min(r_prev, n_i·r_next)
        cores[i] = qr.q.transpose().reshaped(k * dims[i], r_next);
        cores[i - 1] = matmul(&cores[i - 1], &qr.r.transpose());
        ranks[i] = k;
    }
    (cores, ranks)
}

/// Recompress to per-stage tolerance `eps`, with an optional hard cap on
/// every internal rank (Oseledets Alg. 2 + budget). `eps = 0` with a
/// `max_rank` gives a pure rank-budget truncation.
///
/// ```
/// use dntt::serve::truncate;
/// use dntt::tensor::TTensor;
/// use dntt::util::rng::Rng;
///
/// let mut rng = Rng::new(11);
/// let tt = TTensor::<f64>::rand_uniform(&[6, 6, 6], &[4, 4], &mut rng).unwrap();
/// let capped = truncate(&tt, 0.0, Some(2)).unwrap();
/// assert!(capped.ranks().iter().all(|&r| r <= 2));
/// ```
pub fn truncate(tt: &TTensor<f64>, eps: f64, max_rank: Option<usize>) -> Result<TTensor<f64>> {
    let d = tt.dims().len();
    if d == 1 {
        return TTensor::new(tt.dims().to_vec(), tt.cores().to_vec());
    }
    let dims = tt.dims().to_vec();
    let cap = max_rank.map(|r| r.max(1));
    let (mut cores, mut ranks) = right_ortho_cores(tt);

    // Left-to-right truncation sweep.
    for i in 0..d - 1 {
        let rows = ranks[i] * dims[i];
        let ci = cores[i].clone().reshaped(rows, ranks[i + 1]);
        let svd = thin_svd(&ci);
        let mut r_new = rank_for_eps(&svd.s, eps).min(svd.s.len()).max(1);
        if let Some(cap) = cap {
            r_new = r_new.min(cap);
        }
        let tr = svd.truncate(r_new);
        cores[i] = tr.u.clone();
        // Carry Σ·Vᵀ into the next core: (r_new × r_old) · core-view.
        let mut sv = tr.vt.clone();
        for c in 0..r_new {
            let s = tr.s[c];
            for v in sv.row_mut(c) {
                *v *= s;
            }
        }
        let next = cores[i + 1].clone().reshaped(ranks[i + 1], dims[i + 1] * ranks[i + 2]);
        cores[i + 1] = matmul(&sv, &next).reshaped(r_new * dims[i + 1], ranks[i + 2]);
        ranks[i + 1] = r_new;
    }
    TTensor::new(dims, cores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gram_m_mt, gram_mt_m};
    use crate::util::rng::Rng;

    fn assert_eye(g: &Mat<f64>, tol: f64) {
        for i in 0..g.rows() {
            for j in 0..g.cols() {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g[(i, j)] - want).abs() < tol, "G[{i},{j}] = {}", g[(i, j)]);
            }
        }
    }

    #[test]
    fn left_sweep_leaves_tensor_and_orthogonalizes() {
        let mut rng = Rng::new(21);
        let tt = TTensor::<f64>::rand_uniform(&[4, 5, 3], &[3, 2], &mut rng).unwrap();
        let full = tt.reconstruct();
        let lo = left_orthogonalize(&tt).unwrap();
        assert!(lo.rel_error(&full) < 1e-12);
        for i in 0..2 {
            // Tall view has orthonormal columns: GᵀG = I.
            assert_eye(&gram_mt_m(lo.core(i)), 1e-10);
        }
    }

    #[test]
    fn right_sweep_leaves_tensor_and_orthogonalizes() {
        let mut rng = Rng::new(22);
        let tt = TTensor::<f64>::rand_uniform(&[4, 5, 3], &[3, 2], &mut rng).unwrap();
        let full = tt.reconstruct();
        let ro = right_orthogonalize(&tt).unwrap();
        assert!(ro.rel_error(&full) < 1e-12);
        for i in 1..3 {
            // Wide view has orthonormal rows: GGᵀ = I.
            let wide = ro.core(i).clone().reshaped(ro.ranks()[i], ro.dims()[i] * ro.ranks()[i + 1]);
            assert_eye(&gram_m_mt(&wide), 1e-10);
        }
    }

    #[test]
    fn rank_budget_caps_every_internal_rank() {
        let mut rng = Rng::new(23);
        let tt = TTensor::<f64>::rand_uniform(&[5, 6, 4, 3], &[4, 5, 3], &mut rng).unwrap();
        let capped = truncate(&tt, 0.0, Some(2)).unwrap();
        assert!(capped.ranks()[1..4].iter().all(|&r| r <= 2), "ranks {:?}", capped.ranks());
        // eps-only path unchanged vs the cap=∞ path.
        let a = truncate(&tt, 1e-10, None).unwrap();
        let b = truncate(&tt, 1e-10, Some(usize::MAX)).unwrap();
        assert_eq!(a.ranks(), b.ranks());
    }

    #[test]
    fn budget_of_true_rank_is_lossless() {
        let mut rng = Rng::new(24);
        let tt = TTensor::<f64>::rand_uniform(&[4, 4, 4], &[2, 2], &mut rng).unwrap();
        let full = tt.reconstruct();
        let capped = truncate(&tt, 0.0, Some(2)).unwrap();
        assert!(capped.rel_error(&full) < 1e-10);
    }
}
