//! `serve` — the read side of the system: turn a *finished* decomposition
//! into a servable, batch-queryable artifact.
//!
//! The decomposition pipeline (`crate::ttrain`, `crate::ht`) ends with a
//! compressed tensor network; this layer is what makes that network
//! *useful* without ever densifying it (Cichocki's tensor-network program,
//! arXiv:1403.2048 / 1609.00893): the ROADMAP's "heavy read traffic"
//! scenario — decompose once, answer millions of point/fiber/slice
//! queries against the cores.
//!
//! * [`TtHandle`] / [`HtHandle`] — immutable, read-optimized wrappers
//!   around [`TTensor`](crate::tensor::TTensor) /
//!   [`HtTensor`](crate::tensor::HtTensor) with batched element lookup,
//!   fiber and slice extraction. Batched queries are sorted
//!   lexicographically and evaluated with per-prefix caching of partial
//!   contraction products, so a batch over a coherent index region costs
//!   far fewer core-row contractions than `q` independent evaluations
//!   (see `DESIGN.md` §2.9 for the complexity contract). The hot loop is
//!   zero-allocation given a warm [`QueryWorkspace`] /
//!   [`HtQueryWorkspace`].
//! * [`contract`] — TT×vector and TT×matrix contraction
//!   ([`tt_contract_vec`], [`tt_contract_matrix`], [`tt_contract_all`]):
//!   reduce or transform individual modes while staying in TT form.
//! * [`ortho`] — left/right orthogonalization sweeps (QR/RQ) and
//!   ε-or-rank-budget truncation ([`truncate`]) so an artifact can be
//!   recompressed before serving; `crate::ttrain::tt_round` is the
//!   `eps`-only special case and delegates here.
//! * [`cache`] — [`ResultCache`], the fingerprint-keyed on-disk map from
//!   a [`JobConfig::fingerprint`](crate::coordinator::JobConfig::fingerprint)
//!   to the committed `.dntt` artifact plus its `dntt-ckpt-v1` resume
//!   state — how the job server serves finished work without recompute
//!   (`DESIGN.md` §2.11).
//!
//! Every query path reproduces `TTensor::element` / `HtTensor::reconstruct`
//! **bitwise** (same scalar op sequence: ascending-`k` fused
//! multiply-adds with the same zero-skips) — proven by
//! `tests/serve_equivalence.rs` against dense reconstruction.
//!
//! Artifacts are persisted through the versioned `dntt-tt-v1` container in
//! [`crate::tensor::io`] (`save_artifact`/`load_artifact`); the CLI's
//! `query` subcommand is the end-to-end consumer.

pub mod cache;
pub mod contract;
pub mod handle;
pub mod ht_handle;
pub mod ortho;

pub use cache::{CacheEntry, ResultCache};
pub use contract::{tt_contract_all, tt_contract_matrix, tt_contract_vec};
pub use handle::{QueryWorkspace, TtHandle};
pub use ht_handle::{HtHandle, HtQueryWorkspace};
pub use ortho::{left_orthogonalize, right_orthogonalize, truncate};

use crate::error::{DnttError, Result};

/// Append the point list of the mode-`mode` fiber through `at` to `buf`
/// (flattened `n_mode × d`, lexicographically sorted by construction).
pub(crate) fn fiber_queries(
    dims: &[usize],
    mode: usize,
    at: &[usize],
    buf: &mut Vec<usize>,
) -> Result<()> {
    let d = dims.len();
    if mode >= d {
        return Err(DnttError::shape(format!("fiber: mode {mode} out of range for order {d}")));
    }
    if at.len() != d {
        return Err(DnttError::shape(format!("fiber: anchor has {} modes, tensor {d}", at.len())));
    }
    for (m, (&i, &n)) in at.iter().zip(dims).enumerate() {
        if m != mode && i >= n {
            return Err(DnttError::shape(format!("fiber: anchor index {i} out of range {n}")));
        }
    }
    buf.clear();
    buf.reserve(dims[mode] * d);
    for i in 0..dims[mode] {
        for (m, &a) in at.iter().enumerate() {
            buf.push(if m == mode { i } else { a });
        }
    }
    Ok(())
}

/// Append the point list of the slice `mode = index` to `buf` (flattened,
/// row-major over the remaining modes — lexicographically sorted by
/// construction). Returns the slice's dims (`d − 1` modes).
pub(crate) fn slice_queries(
    dims: &[usize],
    mode: usize,
    index: usize,
    buf: &mut Vec<usize>,
) -> Result<Vec<usize>> {
    let d = dims.len();
    if mode >= d {
        return Err(DnttError::shape(format!("slice: mode {mode} out of range for order {d}")));
    }
    if index >= dims[mode] {
        return Err(DnttError::shape(format!("slice: index {index} out of range {}", dims[mode])));
    }
    if d < 2 {
        return Err(DnttError::config("slice: need at least 2 modes (use element/fiber)"));
    }
    let rest: Vec<usize> =
        dims.iter().enumerate().filter(|&(m, _)| m != mode).map(|(_, &n)| n).collect();
    let total: usize = rest.iter().product();
    buf.clear();
    buf.reserve(total * d);
    let mut idx = vec![0usize; d - 1];
    for _ in 0..total {
        let mut it = idx.iter();
        for m in 0..d {
            buf.push(if m == mode { index } else { *it.next().expect("d-1 free modes") });
        }
        // Row-major increment over the free modes.
        for m in (0..d - 1).rev() {
            idx[m] += 1;
            if idx[m] < rest[m] {
                break;
            }
            idx[m] = 0;
        }
    }
    Ok(rest)
}
