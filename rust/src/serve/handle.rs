//! Read-optimized TT handle: batched point/fiber/slice queries with
//! prefix-cached chained core contractions.
//!
//! A point query against a TT is the chain `v ← v·G_m[·, i_m, ·]`
//! (cost `O(d·r²)`, [`TTensor::element`]). For a *batch*, sorting the
//! queries lexicographically makes consecutive queries share index
//! prefixes, and a prefix `(i_0..i_m)` fully determines the partial
//! product `v_m : 1 × r_{m+1}` — so the handle keeps one cached row
//! vector per mode and recomputes only from the first mode where the
//! sorted query differs from its predecessor. A batch that enumerates a
//! fiber or slice touches each prefix exactly once, dropping the cost
//! from `O(q·d·r²)` to `O(Σ_m (#distinct prefixes of length m)·r²)`.
//!
//! The scalar op sequence per recomputed mode is *identical* to
//! [`TTensor::element`] (ascending-`k` `fma` with zero-skip on the
//! carried scalar), which is itself identical to the blocked-GEMM
//! reconstruction path — so batched results are **bitwise equal** to both
//! single-element evaluation and (on blocked-path shapes) dense
//! reconstruction; `tests/serve_equivalence.rs` holds this to `to_bits`
//! equality.
//!
//! With a warm [`QueryWorkspace`] and a reused output buffer,
//! [`TtHandle::batch_into`] performs **zero heap allocations** (the sort
//! is in-place `sort_unstable_by`; all scratch is capacity-reused),
//! mirroring the `NmfWorkspace` discipline of the write side.

use crate::error::{DnttError, Result};
use crate::linalg::Scalar;
use crate::tensor::{DenseTensor, TTensor};

/// Reusable scratch for [`TtHandle`] batch queries: the sort permutation,
/// the per-mode prefix row vectors, and the previous sorted query.
/// Create once, pass to every [`TtHandle::batch_into`] call; after the
/// first call on a given handle the hot loop allocates nothing.
#[derive(Debug, Default)]
pub struct QueryWorkspace {
    perm: Vec<usize>,
    prefix: Vec<f64>,
    prev: Vec<usize>,
    qbuf: Vec<usize>,
    /// Prefix vectors reused from the cache across all batches through
    /// this workspace (a sorted query sharing its first `s` modes with
    /// its predecessor reuses `s` cached prefixes).
    modes_reused: u64,
    /// Prefix vectors recomputed across all batches.
    modes_computed: u64,
}

impl QueryWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Currently reserved heap, for capacity-stability assertions.
    pub fn capacity_bytes(&self) -> usize {
        self.perm.capacity() * std::mem::size_of::<usize>()
            + self.prefix.capacity() * std::mem::size_of::<f64>()
            + self.prev.capacity() * std::mem::size_of::<usize>()
            + self.qbuf.capacity() * std::mem::size_of::<usize>()
    }

    /// Prefix-cache hits: per-mode partial products reused instead of
    /// recomputed, accumulated over every batch served by this workspace.
    pub fn prefix_modes_reused(&self) -> u64 {
        self.modes_reused
    }

    /// Prefix-cache misses: per-mode partial products recomputed.
    pub fn prefix_modes_computed(&self) -> u64 {
        self.modes_computed
    }

    /// Fraction of per-mode contractions served from the prefix cache
    /// (0.0 when nothing has been queried yet).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.modes_reused + self.modes_computed;
        if total == 0 {
            0.0
        } else {
            self.modes_reused as f64 / total as f64
        }
    }
}

/// Immutable, read-optimized view of a finished [`TTensor`].
///
/// ```
/// use dntt::serve::{QueryWorkspace, TtHandle};
/// use dntt::tensor::TTensor;
/// use dntt::util::rng::Rng;
///
/// let mut rng = Rng::new(7);
/// let tt = TTensor::<f64>::rand_uniform(&[3, 4, 5], &[2, 2], &mut rng).unwrap();
/// let handle = TtHandle::new(tt);
/// let mut ws = QueryWorkspace::new();
/// let mut out = Vec::new();
/// // Two point queries in one batch (flattened index tuples).
/// handle.batch_into(&[2, 3, 4, 0, 0, 0], &mut ws, &mut out).unwrap();
/// assert_eq!(out[0], handle.tt().element(&[2, 3, 4]));
/// assert_eq!(out[1], handle.tt().element(&[0, 0, 0]));
/// ```
#[derive(Clone, Debug)]
pub struct TtHandle {
    tt: TTensor<f64>,
    /// `off[m]` = start of mode `m`'s prefix vector (length `r_{m+1}`)
    /// in the packed prefix buffer.
    off: Vec<usize>,
    prefix_len: usize,
}

impl TtHandle {
    /// Wrap a finished train (shape chain already validated by
    /// [`TTensor::new`]).
    pub fn new(tt: TTensor<f64>) -> Self {
        let d = tt.dims().len();
        let mut off = Vec::with_capacity(d);
        let mut acc = 0usize;
        for m in 0..d {
            off.push(acc);
            acc += tt.ranks()[m + 1];
        }
        TtHandle { tt, off, prefix_len: acc }
    }

    /// The wrapped train.
    pub fn tt(&self) -> &TTensor<f64> {
        &self.tt
    }

    /// Unwrap.
    pub fn into_inner(self) -> TTensor<f64> {
        self.tt
    }

    pub fn dims(&self) -> &[usize] {
        self.tt.dims()
    }

    pub fn ranks(&self) -> &[usize] {
        self.tt.ranks()
    }

    fn check_point(&self, idx: &[usize]) -> Result<()> {
        let dims = self.tt.dims();
        if idx.len() != dims.len() {
            return Err(DnttError::shape(format!(
                "query has {} modes, tensor {}",
                idx.len(),
                dims.len()
            )));
        }
        for (m, (&i, &n)) in idx.iter().zip(dims).enumerate() {
            if i >= n {
                return Err(DnttError::shape(format!("query index {i} out of range {n} (mode {m})")));
            }
        }
        Ok(())
    }

    /// Single point query (bounds-checked [`TTensor::element`]).
    pub fn element(&self, idx: &[usize]) -> Result<f64> {
        self.check_point(idx)?;
        Ok(self.tt.element(idx))
    }

    /// Batched point queries: `queries` holds `q` index tuples flattened
    /// back-to-back (`len == q·d`); `out` receives the `q` values in the
    /// *caller's* order (duplicates allowed, input order preserved).
    ///
    /// Zero-allocation once `ws` and `out` are warm.
    pub fn batch_into(
        &self,
        queries: &[usize],
        ws: &mut QueryWorkspace,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        let dims = self.tt.dims();
        let ranks = self.tt.ranks();
        let d = dims.len();
        if queries.len() % d != 0 {
            return Err(DnttError::shape(format!(
                "batch of {} indices is not a multiple of order {d}",
                queries.len()
            )));
        }
        let q = queries.len() / d;
        for (m, &i) in queries.iter().enumerate() {
            let n = dims[m % d];
            if i >= n {
                return Err(DnttError::shape(format!(
                    "query {}: index {i} out of range {n} (mode {})",
                    m / d,
                    m % d
                )));
            }
        }
        out.clear();
        out.resize(q, 0.0);
        if q == 0 {
            return Ok(());
        }
        let span = crate::obs::span_begin();
        let (mut reused, mut computed) = (0u64, 0u64);
        ws.perm.clear();
        ws.perm.extend(0..q);
        ws.perm
            .sort_unstable_by(|&a, &b| queries[a * d..(a + 1) * d].cmp(&queries[b * d..(b + 1) * d]));
        ws.prefix.clear();
        ws.prefix.resize(self.prefix_len, 0.0);
        // usize::MAX never equals a valid index, so the first sorted query
        // recomputes every mode.
        ws.prev.clear();
        ws.prev.resize(d, usize::MAX);

        for &qi in &ws.perm {
            let idx = &queries[qi * d..(qi + 1) * d];
            // First mode whose index differs from the previous sorted query:
            // prefixes 0..s are still cached.
            let mut s = 0;
            while s < d && idx[s] == ws.prev[s] {
                s += 1;
            }
            reused += s as u64;
            computed += (d - s) as u64;
            for m in s..d {
                let r_next = ranks[m + 1];
                if m == 0 {
                    ws.prefix[..r_next].copy_from_slice(self.tt.core(0).row(idx[0]));
                } else {
                    let core = self.tt.core(m);
                    let (lo, hi) = ws.prefix.split_at_mut(self.off[m]);
                    let src = &lo[self.off[m - 1]..self.off[m - 1] + ranks[m]];
                    let dst = &mut hi[..r_next];
                    dst.fill(0.0);
                    // Same op sequence as `TTensor::element`: ascending k,
                    // zero-skip on the carried scalar, fused multiply-add.
                    for (k, &vk) in src.iter().enumerate() {
                        if vk == 0.0 {
                            continue;
                        }
                        let row = core.row(k * dims[m] + idx[m]);
                        for (j, o) in dst.iter_mut().enumerate() {
                            *o = row[j].fma(vk, *o);
                        }
                    }
                }
            }
            ws.prev[s..].copy_from_slice(&idx[s..]);
            out[qi] = ws.prefix[self.off[d - 1]];
        }
        ws.modes_reused += reused;
        ws.modes_computed += computed;
        crate::obs::end_query_batch(span, q as u64, reused, computed);
        Ok(())
    }

    /// Convenience [`TtHandle::batch_into`] with fresh scratch.
    pub fn batch(&self, queries: &[usize]) -> Result<Vec<f64>> {
        let mut ws = QueryWorkspace::new();
        let mut out = Vec::new();
        self.batch_into(queries, &mut ws, &mut out)?;
        Ok(out)
    }

    /// The mode-`mode` fiber through anchor `at` (the anchor's own
    /// `mode` coordinate is ignored): `n_mode` values, evaluated as one
    /// sorted batch so the shared prefix is contracted once.
    pub fn fiber(&self, mode: usize, at: &[usize], ws: &mut QueryWorkspace) -> Result<Vec<f64>> {
        let mut qbuf = std::mem::take(&mut ws.qbuf);
        super::fiber_queries(self.tt.dims(), mode, at, &mut qbuf)?;
        let mut out = Vec::with_capacity(self.tt.dims()[mode]);
        let res = self.batch_into(&qbuf, ws, &mut out);
        ws.qbuf = qbuf;
        res?;
        Ok(out)
    }

    /// The `(d−1)`-mode slice `mode = index`, row-major over the
    /// remaining modes, evaluated as one sorted batch.
    pub fn slice(
        &self,
        mode: usize,
        index: usize,
        ws: &mut QueryWorkspace,
    ) -> Result<DenseTensor<f64>> {
        let mut qbuf = std::mem::take(&mut ws.qbuf);
        let rest = super::slice_queries(self.tt.dims(), mode, index, &mut qbuf)?;
        let mut out = Vec::new();
        let res = self.batch_into(&qbuf, ws, &mut out);
        ws.qbuf = qbuf;
        res?;
        DenseTensor::from_vec(&rest, out)
    }
}
