//! Read-optimized HT handle: batched point/fiber/slice queries over the
//! dimension tree with per-node caching.
//!
//! An HT point query contracts the tree bottom-up: every node `t`
//! contributes the single row of its matrix `V_t : n_{S_t} × r_t`
//! selected by the query's coordinates on the node's mode range
//! `S_t = [lo, hi)` — a leaf row is read straight from the factor `U`,
//! and an interior row is the two-step transfer contraction
//! `m2 = b_2·B_t` (row of `M2 = U2·B_t`), then
//! `out[k] = Σ_{j1} m2[j1·r_t + k]·b_1[j1]` (the `H1` un-permutation
//! fused into the row product). Cost `O(tree·r²)` per query.
//!
//! For a *batch*, queries are sorted lexicographically and each node
//! caches its last row: node `t`'s row depends only on coordinates in
//! `[lo, hi)`, so it is recomputed only when the sorted query differs
//! from its predecessor at some mode `< hi`. Nodes are walked in
//! reverse-BFS id order (children before parents — BFS ids grow down the
//! tree), so recomputed parents always see fresh child rows.
//!
//! The per-row op sequence is identical to the blocked-GEMM path of
//! `HtTensor::reconstruct` (ascending-`k` `fma`, zero-skip on the carried
//! scalar), so batched results are **bitwise equal** to dense
//! reconstruction on blocked-path shapes — held to `to_bits` equality by
//! `tests/serve_equivalence.rs`.

use crate::error::{DnttError, Result};
use crate::linalg::Scalar;
use crate::tensor::ht::HtNode;
use crate::tensor::{DenseTensor, HtTensor};

/// Reusable scratch for [`HtHandle`] batch queries: sort permutation,
/// packed per-node row cache, one transfer-row scratch, previous query.
/// Zero-allocation hot loop once warm.
#[derive(Debug, Default)]
pub struct HtQueryWorkspace {
    perm: Vec<usize>,
    rows: Vec<f64>,
    m2: Vec<f64>,
    prev: Vec<usize>,
    qbuf: Vec<usize>,
    /// Node rows reused from the cache across all batches through this
    /// workspace (a node whose mode range lies left of the changed
    /// suffix keeps its cached row).
    modes_reused: u64,
    /// Node rows recomputed across all batches.
    modes_computed: u64,
}

impl HtQueryWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Currently reserved heap, for capacity-stability assertions.
    pub fn capacity_bytes(&self) -> usize {
        self.perm.capacity() * std::mem::size_of::<usize>()
            + self.rows.capacity() * std::mem::size_of::<f64>()
            + self.m2.capacity() * std::mem::size_of::<f64>()
            + self.prev.capacity() * std::mem::size_of::<usize>()
            + self.qbuf.capacity() * std::mem::size_of::<usize>()
    }

    /// Row-cache hits: per-node rows reused instead of recomputed,
    /// accumulated over every batch served by this workspace.
    pub fn prefix_modes_reused(&self) -> u64 {
        self.modes_reused
    }

    /// Row-cache misses: per-node rows recomputed.
    pub fn prefix_modes_computed(&self) -> u64 {
        self.modes_computed
    }

    /// Fraction of per-node row contractions served from the cache
    /// (0.0 when nothing has been queried yet).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.modes_reused + self.modes_computed;
        if total == 0 {
            0.0
        } else {
            self.modes_reused as f64 / total as f64
        }
    }
}

/// Immutable, read-optimized view of a finished [`HtTensor`].
///
/// ```
/// use dntt::serve::{HtHandle, HtQueryWorkspace};
/// use dntt::tensor::HtTensor;
/// use dntt::util::rng::Rng;
///
/// let mut rng = Rng::new(7);
/// let ht = HtTensor::<f64>::rand_uniform(&[3, 4, 2], 2, &mut rng).unwrap();
/// let full = ht.reconstruct();
/// let handle = HtHandle::new(ht);
/// let mut ws = HtQueryWorkspace::new();
/// let mut out = Vec::new();
/// handle.batch_into(&[1, 2, 0], &mut ws, &mut out).unwrap();
/// assert_eq!(out[0], full.get(&[1, 2, 0]));
/// ```
#[derive(Clone, Debug)]
pub struct HtHandle {
    ht: HtTensor<f64>,
    /// `row_off[t]` = start of node `t`'s cached row (length `ranks[t]`)
    /// in the packed row buffer.
    row_off: Vec<usize>,
    rows_len: usize,
    /// Largest interior `r1·rt` — the transfer-row scratch size.
    m2_max: usize,
}

impl HtHandle {
    /// Wrap a finished HT tensor (tree already validated by
    /// [`HtTensor::new`]).
    pub fn new(ht: HtTensor<f64>) -> Self {
        let nn = ht.tree().len();
        let mut row_off = Vec::with_capacity(nn);
        let mut acc = 0usize;
        for t in 0..nn {
            row_off.push(acc);
            acc += ht.ranks()[t];
        }
        let mut m2_max = 0usize;
        for t in 0..nn {
            if let Some((lc, _)) = ht.tree().node(t).children {
                m2_max = m2_max.max(ht.ranks()[lc] * ht.ranks()[t]);
            }
        }
        HtHandle { ht, row_off, rows_len: acc, m2_max }
    }

    /// The wrapped HT tensor.
    pub fn ht(&self) -> &HtTensor<f64> {
        &self.ht
    }

    /// Unwrap.
    pub fn into_inner(self) -> HtTensor<f64> {
        self.ht
    }

    pub fn dims(&self) -> &[usize] {
        self.ht.dims()
    }

    /// Single point query (contract the tree once for this index).
    pub fn element(&self, idx: &[usize]) -> Result<f64> {
        let mut ws = HtQueryWorkspace::new();
        let mut out = Vec::with_capacity(1);
        self.batch_into(idx, &mut ws, &mut out)?;
        Ok(out[0])
    }

    /// Batched point queries: `queries` holds `q` index tuples flattened
    /// back-to-back; `out` receives the values in the caller's order.
    /// Zero-allocation once `ws` and `out` are warm.
    pub fn batch_into(
        &self,
        queries: &[usize],
        ws: &mut HtQueryWorkspace,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        let dims = self.ht.dims();
        let ranks = self.ht.ranks();
        let tree = self.ht.tree();
        let d = dims.len();
        if queries.len() % d != 0 {
            return Err(DnttError::shape(format!(
                "batch of {} indices is not a multiple of order {d}",
                queries.len()
            )));
        }
        let q = queries.len() / d;
        for (m, &i) in queries.iter().enumerate() {
            let n = dims[m % d];
            if i >= n {
                return Err(DnttError::shape(format!(
                    "query {}: index {i} out of range {n} (mode {})",
                    m / d,
                    m % d
                )));
            }
        }
        out.clear();
        out.resize(q, 0.0);
        if q == 0 {
            return Ok(());
        }
        let span = crate::obs::span_begin();
        let (mut reused, mut computed) = (0u64, 0u64);
        ws.perm.clear();
        ws.perm.extend(0..q);
        ws.perm
            .sort_unstable_by(|&a, &b| queries[a * d..(a + 1) * d].cmp(&queries[b * d..(b + 1) * d]));
        ws.rows.clear();
        ws.rows.resize(self.rows_len, 0.0);
        ws.m2.clear();
        ws.m2.resize(self.m2_max, 0.0);
        ws.prev.clear();
        ws.prev.resize(d, usize::MAX);
        let mut last = 0.0f64;

        for &qi in &ws.perm {
            let idx = &queries[qi * d..(qi + 1) * d];
            let mut s = 0;
            while s < d && idx[s] == ws.prev[s] {
                s += 1;
            }
            if s == d {
                // Exact duplicate of the previous sorted query.
                reused += tree.len() as u64;
                out[qi] = last;
                continue;
            }
            // Children before parents; nodes whose mode range lies left of
            // the changed suffix [s, d) keep their cached rows.
            for t in (0..tree.len()).rev() {
                let node = tree.node(t);
                if node.hi <= s {
                    reused += 1;
                    continue;
                }
                computed += 1;
                match node.children {
                    None => {
                        let u = self.ht.node(t).mat();
                        let dst =
                            &mut ws.rows[self.row_off[t]..self.row_off[t] + ranks[t]];
                        dst.copy_from_slice(u.row(idx[node.lo]));
                    }
                    Some((lc, rc)) => {
                        let (r1, r2, rt) = (ranks[lc], ranks[rc], ranks[t]);
                        let b = match self.ht.node(t) {
                            HtNode::Transfer(b) => b,
                            HtNode::Leaf(_) => unreachable!("validated in HtTensor::new"),
                        };
                        // Row of M2 = U2·B for this query: ascending j2,
                        // zero-skip, fma — the blocked-GEMM op sequence.
                        let m2 = &mut ws.m2[..r1 * rt];
                        m2.fill(0.0);
                        let b2 = &ws.rows[self.row_off[rc]..self.row_off[rc] + r2];
                        for (j2, &a) in b2.iter().enumerate() {
                            if a == 0.0 {
                                continue;
                            }
                            let brow = b.row(j2);
                            for (c, o) in m2.iter_mut().enumerate() {
                                *o = brow[c].fma(a, *o);
                            }
                        }
                        // Row of V_t = U1·H1 with the H1 un-permutation
                        // fused: H1[j1, (i2, k)] = M2[i2, (j1, k)]. The
                        // left child's cached row lives at a higher offset
                        // (BFS: child ids > parent id), so split after the
                        // parent's block.
                        let (dst_part, b1_part) = ws.rows.split_at_mut(self.row_off[t] + rt);
                        let dst = &mut dst_part[self.row_off[t]..];
                        let b1 =
                            &b1_part[self.row_off[lc] - self.row_off[t] - rt..][..r1];
                        dst.fill(0.0);
                        for (j1, &a) in b1.iter().enumerate() {
                            if a == 0.0 {
                                continue;
                            }
                            let mrow = &m2[j1 * rt..(j1 + 1) * rt];
                            for (k, o) in dst.iter_mut().enumerate() {
                                *o = mrow[k].fma(a, *o);
                            }
                        }
                    }
                }
            }
            ws.prev[s..].copy_from_slice(&idx[s..]);
            last = ws.rows[self.row_off[0]];
            out[qi] = last;
        }
        ws.modes_reused += reused;
        ws.modes_computed += computed;
        crate::obs::end_query_batch(span, q as u64, reused, computed);
        Ok(())
    }

    /// Convenience [`HtHandle::batch_into`] with fresh scratch.
    pub fn batch(&self, queries: &[usize]) -> Result<Vec<f64>> {
        let mut ws = HtQueryWorkspace::new();
        let mut out = Vec::new();
        self.batch_into(queries, &mut ws, &mut out)?;
        Ok(out)
    }

    /// The mode-`mode` fiber through anchor `at` (anchor's own `mode`
    /// coordinate ignored), evaluated as one sorted batch.
    pub fn fiber(&self, mode: usize, at: &[usize], ws: &mut HtQueryWorkspace) -> Result<Vec<f64>> {
        let mut qbuf = std::mem::take(&mut ws.qbuf);
        super::fiber_queries(self.ht.dims(), mode, at, &mut qbuf)?;
        let mut out = Vec::with_capacity(self.ht.dims()[mode]);
        let res = self.batch_into(&qbuf, ws, &mut out);
        ws.qbuf = qbuf;
        res?;
        Ok(out)
    }

    /// The `(d−1)`-mode slice `mode = index`, row-major over the
    /// remaining modes, evaluated as one sorted batch.
    pub fn slice(
        &self,
        mode: usize,
        index: usize,
        ws: &mut HtQueryWorkspace,
    ) -> Result<DenseTensor<f64>> {
        let mut qbuf = std::mem::take(&mut ws.qbuf);
        let rest = super::slice_queries(self.ht.dims(), mode, index, &mut qbuf)?;
        let mut out = Vec::new();
        let res = self.batch_into(&qbuf, ws, &mut out);
        ws.qbuf = qbuf;
        res?;
        DenseTensor::from_vec(&rest, out)
    }
}
