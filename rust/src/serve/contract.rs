//! TT×vector / TT×matrix contraction — transform or reduce modes while
//! staying in TT form (never densifying).
//!
//! * [`tt_contract_matrix`] — the mode product `A ×_m U`: replace mode
//!   `m` (size `n_m`) by `U`'s row space (size `p`), e.g. projecting a
//!   mode onto a basis. Ranks are unchanged; only core `m` is rebuilt.
//! * [`tt_contract_vec`] — contract mode `m` against a vector: the
//!   `r_m × r_{m+1}` matrix `Σ_j v_j·G_m[·, j, ·]` is absorbed into a
//!   neighboring core, yielding a `(d−1)`-mode train.
//! * [`tt_contract_all`] — contract *every* mode against a vector:
//!   `⟨A, v_1 ⊗ … ⊗ v_d⟩`, cost `O(Σ n_m·r_m·r_{m+1})` — the TT inner
//!   product against a rank-1 tensor, without materializing anything.
//!
//! These are the Cichocki tensor-network primitives (arXiv:1609.00893
//! §4); results carry normal floating-point tolerance (they reassociate
//! sums), unlike the bitwise-exact query paths in
//! [`handle`](crate::serve::handle).

use crate::error::{DnttError, Result};
use crate::linalg::gemm::matmul;
use crate::linalg::{Mat, Scalar};
use crate::tensor::TTensor;

/// The `r_m × r_{m+1}` contraction matrix `Σ_j v[j]·G_m[·, j, ·]`.
fn mode_matrix(tt: &TTensor<f64>, mode: usize, v: &[f64]) -> Mat<f64> {
    let (r_prev, n, r_next) = (tt.ranks()[mode], tt.dims()[mode], tt.ranks()[mode + 1]);
    let core = tt.core(mode);
    let mut m = Mat::zeros(r_prev, r_next);
    for k in 0..r_prev {
        let mrow = m.row_mut(k);
        for (j, &vj) in v.iter().enumerate().take(n) {
            if vj == 0.0 {
                continue;
            }
            let row = core.row(k * n + j);
            for (c, o) in mrow.iter_mut().enumerate() {
                *o = row[c].fma(vj, *o);
            }
        }
    }
    m
}

fn check_mode(tt: &TTensor<f64>, mode: usize) -> Result<()> {
    if mode >= tt.dims().len() {
        return Err(DnttError::shape(format!(
            "mode {mode} out of range for order {}",
            tt.dims().len()
        )));
    }
    Ok(())
}

/// Mode product `A ×_mode U` with `U: p × n_mode`: mode `mode`'s size
/// becomes `p`, all ranks unchanged.
///
/// ```
/// use dntt::linalg::Mat;
/// use dntt::serve::tt_contract_matrix;
/// use dntt::tensor::TTensor;
/// use dntt::util::rng::Rng;
///
/// let mut rng = Rng::new(5);
/// let tt = TTensor::<f64>::rand_uniform(&[3, 4, 2], &[2, 2], &mut rng).unwrap();
/// let u = Mat::<f64>::rand_uniform(6, 4, &mut rng);
/// let prod = tt_contract_matrix(&tt, 1, &u).unwrap();
/// assert_eq!(prod.dims(), &[3, 6, 2]);
/// assert_eq!(prod.ranks(), tt.ranks());
/// ```
pub fn tt_contract_matrix(tt: &TTensor<f64>, mode: usize, u: &Mat<f64>) -> Result<TTensor<f64>> {
    check_mode(tt, mode)?;
    let (r_prev, n, r_next) = (tt.ranks()[mode], tt.dims()[mode], tt.ranks()[mode + 1]);
    if u.cols() != n {
        return Err(DnttError::shape(format!(
            "mode product: U has {} cols, mode {mode} has size {n}",
            u.cols()
        )));
    }
    if u.rows() == 0 {
        return Err(DnttError::shape("mode product: U must have at least one row"));
    }
    let core = tt.core(mode);
    // Per left-rank block: (n × r_next) slab → (p × r_next).
    let mut new_core = Mat::zeros(r_prev * u.rows(), r_next);
    for a in 0..r_prev {
        let block = core.rows_slice(a * n, (a + 1) * n);
        let prod = matmul(u, &block);
        for i in 0..u.rows() {
            new_core.row_mut(a * u.rows() + i).copy_from_slice(prod.row(i));
        }
    }
    let mut dims = tt.dims().to_vec();
    dims[mode] = u.rows();
    let mut cores = tt.cores().to_vec();
    cores[mode] = new_core;
    TTensor::new(dims, cores)
}

/// Contract mode `mode` against `v` (length `n_mode`), absorbing the
/// resulting `r_mode × r_{mode+1}` matrix into the next core (previous
/// core for the last mode). Returns the `(d−1)`-mode train.
pub fn tt_contract_vec(tt: &TTensor<f64>, mode: usize, v: &[f64]) -> Result<TTensor<f64>> {
    check_mode(tt, mode)?;
    let d = tt.dims().len();
    if d == 1 {
        return Err(DnttError::config(
            "cannot contract the only mode of a 1-mode train (use tt_contract_all)",
        ));
    }
    if v.len() != tt.dims()[mode] {
        return Err(DnttError::shape(format!(
            "contract: vector has {} entries, mode {mode} has size {}",
            v.len(),
            tt.dims()[mode]
        )));
    }
    let m = mode_matrix(tt, mode, v);
    let mut dims = tt.dims().to_vec();
    let mut cores = tt.cores().to_vec();
    dims.remove(mode);
    if mode + 1 < d {
        // Fold left into the next core: M·(core viewed r × (n·r')).
        let (r_old, n_next, r_after) =
            (tt.ranks()[mode + 1], tt.dims()[mode + 1], tt.ranks()[mode + 2]);
        let view = cores[mode + 1].clone().reshaped(r_old, n_next * r_after);
        cores[mode + 1] = matmul(&m, &view).reshaped(tt.ranks()[mode] * n_next, r_after);
        cores.remove(mode);
    } else {
        // Last mode: fold right into the previous core.
        cores[mode - 1] = matmul(&cores[mode - 1], &m);
        cores.remove(mode);
    }
    TTensor::new(dims, cores)
}

/// Full contraction `⟨A, v_1 ⊗ … ⊗ v_d⟩` — one vector per mode.
///
/// ```
/// use dntt::serve::tt_contract_all;
/// use dntt::tensor::TTensor;
/// use dntt::util::rng::Rng;
///
/// let mut rng = Rng::new(5);
/// let tt = TTensor::<f64>::rand_uniform(&[3, 4], &[2], &mut rng).unwrap();
/// // Indicator vectors pick out a single element.
/// let mut e1 = vec![0.0; 3];
/// let mut e2 = vec![0.0; 4];
/// e1[2] = 1.0;
/// e2[1] = 1.0;
/// let got = tt_contract_all(&tt, &[e1, e2]).unwrap();
/// assert!((got - tt.element(&[2, 1])).abs() < 1e-12);
/// ```
pub fn tt_contract_all(tt: &TTensor<f64>, vecs: &[Vec<f64>]) -> Result<f64> {
    let d = tt.dims().len();
    if vecs.len() != d {
        return Err(DnttError::shape(format!("need {d} vectors, got {}", vecs.len())));
    }
    for (m, v) in vecs.iter().enumerate() {
        if v.len() != tt.dims()[m] {
            return Err(DnttError::shape(format!(
                "vector {m} has {} entries, mode has size {}",
                v.len(),
                tt.dims()[m]
            )));
        }
    }
    // t: 1 × r_m carried left to right through the contraction matrices.
    let mut t = Mat::filled(1, 1, 1.0f64);
    for mode in 0..d {
        let a = mode_matrix(tt, mode, &vecs[mode]);
        t = matmul(&t, &a);
    }
    debug_assert_eq!((t.rows(), t.cols()), (1, 1));
    Ok(t[(0, 0)])
}
