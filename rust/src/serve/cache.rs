//! The fingerprint-keyed result cache backing the job server.
//!
//! Layout (one directory per [`JobConfig::fingerprint`](crate::coordinator::JobConfig::fingerprint),
//! rendered as 16 lower-hex digits):
//!
//! ```text
//! <cache_dir>/
//!   a59d1f0c33e0b771/
//!     artifact.dntt   # the finished network (versioned .dntt container)
//!     meta.json       # dntt-cache-v1 descriptor — written LAST (commit marker)
//!     ckpt/           # dntt-ckpt-v1 snapshots while the job is in flight
//! ```
//!
//! Both files are written atomically (tmp + rename + fsync, reusing the
//! checkpoint durability helpers), and `meta.json` is written only after
//! the artifact rename succeeds, so the presence of a parseable
//! `meta.json` *is* the commit point: [`ResultCache::lookup`] treats an
//! entry without it (a crashed or in-flight job) as a miss. Re-commits
//! retract the old `meta.json` *before* touching the artifact — a crash
//! between the new artifact landing and the new meta landing is a pure
//! miss, never a stale-meta/new-artifact pairing — and `meta.json`
//! records `artifact_bytes`, which `lookup` checks against the file so a
//! torn artifact can never be served.
//! An interrupted job leaves its `ckpt/` directory behind, which is how a
//! resubmitted identical config resumes instead of starting over (the
//! server points the job's [`CheckpointPolicy`](crate::dist::CheckpointPolicy)
//! at [`ResultCache::ckpt_dir`]).
//!
//! Fingerprint semantics — what "identical config" means, including the
//! knobs deliberately *excluded* because they are output-neutral — are
//! documented on `JobConfig::fingerprint` and in `DESIGN.md` §2.11.

use crate::dist::checkpoint::{sync_dir, write_bytes_durable};
use crate::error::{DnttError, Result};
use crate::tensor::io::{load_artifact, save_artifact, Artifact};
use crate::util::json::Json;
use std::fs;
use std::path::{Path, PathBuf};

/// One committed cache entry (artifact + parsed `meta.json`).
pub struct CacheEntry {
    pub fingerprint: u64,
    /// The entry's directory under the cache root.
    pub dir: PathBuf,
    /// Path of the servable `.dntt` artifact.
    pub artifact: PathBuf,
    /// The `dntt-cache-v1` descriptor.
    pub meta: Json,
}

impl CacheEntry {
    /// Load and validate the cached artifact.
    pub fn load(&self) -> Result<Artifact> {
        load_artifact(&self.artifact)
    }
}

/// An on-disk map `fingerprint → finished decomposition`.
pub struct ResultCache {
    dir: PathBuf,
}

/// `meta.json` format tag.
pub const CACHE_META_FORMAT: &str = "dntt-cache-v1";

impl ResultCache {
    /// Open (creating if needed) a cache rooted at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<ResultCache> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        Ok(ResultCache { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The entry directory for a fingerprint (16 lower-hex digits).
    pub fn entry_dir(&self, fp: u64) -> PathBuf {
        self.dir.join(format!("{fp:016x}"))
    }

    pub fn artifact_path(&self, fp: u64) -> PathBuf {
        self.entry_dir(fp).join("artifact.dntt")
    }

    pub fn meta_path(&self, fp: u64) -> PathBuf {
        self.entry_dir(fp).join("meta.json")
    }

    /// Where an in-flight job for this fingerprint keeps its
    /// `dntt-ckpt-v1` snapshots (survives the job for resume-on-resubmit).
    pub fn ckpt_dir(&self, fp: u64) -> PathBuf {
        self.entry_dir(fp).join("ckpt")
    }

    /// A committed entry for `fp`, if one exists. Entries whose
    /// `meta.json` is missing or unparseable (in-flight or torn) are
    /// misses, never errors, and an artifact whose size disagrees with
    /// the meta's `artifact_bytes` stamp (a tear the commit ordering
    /// can't rule out for media-level truncation) is a miss too.
    pub fn lookup(&self, fp: u64) -> Option<CacheEntry> {
        let artifact = self.artifact_path(fp);
        let meta_path = self.meta_path(fp);
        let art_len = fs::metadata(&artifact).ok()?.len();
        let meta = fs::read_to_string(&meta_path).ok()?;
        let meta = Json::parse(&meta).ok()?;
        if meta.get("format").as_str() != Some(CACHE_META_FORMAT) {
            return None;
        }
        match meta.get("artifact_bytes").as_usize() {
            Some(want) if want as u64 != art_len => return None,
            // Pre-stamp entries carry no size; keep serving them.
            _ => {}
        }
        Some(CacheEntry { fingerprint: fp, dir: self.entry_dir(fp), artifact, meta })
    }

    /// Commit a finished decomposition under `fp`.
    ///
    /// `meta` is the caller's descriptor object; the `format`,
    /// `fingerprint` and `artifact_bytes` fields are stamped here.
    /// Commit protocol (crash-safe at every boundary):
    ///
    /// 1. retract any existing `meta.json` (re-puts decommit first, so a
    ///    later crash can never pair stale meta with the new artifact);
    /// 2. write + fsync the artifact to a tmp name, rename into place;
    /// 3. write + fsync `meta.json` the same way — the commit point;
    /// 4. fsync the entry directory so the renames are durable.
    pub fn put(&self, fp: u64, artifact: &Artifact, meta: Json) -> Result<CacheEntry> {
        let dir = self.entry_dir(fp);
        fs::create_dir_all(&dir)?;
        let meta_path = self.meta_path(fp);
        if meta_path.exists() {
            fs::remove_file(&meta_path)?;
            sync_dir(&dir);
        }
        let art_path = self.artifact_path(fp);
        let art_tmp = dir.join("artifact.dntt.tmp");
        save_artifact(artifact, &art_tmp)?;
        if let Ok(f) = fs::File::open(&art_tmp) {
            f.sync_all()?;
        }
        fs::rename(&art_tmp, &art_path)?;
        let art_bytes = fs::metadata(&art_path)?.len();
        let mut fields = match meta {
            Json::Obj(m) => m,
            other => {
                let mut m = std::collections::BTreeMap::new();
                if other != Json::Null {
                    m.insert("note".to_string(), other);
                }
                m
            }
        };
        fields.insert("format".to_string(), Json::Str(CACHE_META_FORMAT.into()));
        fields.insert("fingerprint".to_string(), Json::Str(format!("{fp:016x}")));
        fields.insert("artifact_bytes".to_string(), Json::Num(art_bytes as f64));
        let meta = Json::Obj(fields);
        let meta_tmp = dir.join("meta.json.tmp");
        write_bytes_durable(&meta_tmp, meta.to_pretty().as_bytes())?;
        fs::rename(&meta_tmp, &meta_path)?;
        sync_dir(&dir);
        Ok(CacheEntry { fingerprint: fp, dir, artifact: art_path, meta })
    }

    /// Load the committed artifact for `fp`, erroring on a miss (the
    /// `query --cache --fp` path).
    pub fn load(&self, fp: u64) -> Result<Artifact> {
        match self.lookup(fp) {
            Some(e) => e.load(),
            None => Err(DnttError::Artifact(format!(
                "no committed cache entry {fp:016x} under {:?}",
                self.dir
            ))),
        }
    }

    /// Every committed entry, sorted by fingerprint (deterministic for
    /// listings and tests). Unparseable directory names are skipped.
    pub fn entries(&self) -> Vec<CacheEntry> {
        let mut fps: Vec<u64> = match fs::read_dir(&self.dir) {
            Ok(rd) => rd
                .filter_map(|e| e.ok())
                .filter_map(|e| e.file_name().into_string().ok())
                .filter(|n| n.len() == 16)
                .filter_map(|n| u64::from_str_radix(&n, 16).ok())
                .collect(),
            Err(_) => Vec::new(),
        };
        fps.sort_unstable();
        fps.dedup();
        fps.into_iter().filter_map(|fp| self.lookup(fp)).collect()
    }

    /// Drop an entry (artifact, meta, and any checkpoints). Returns
    /// whether anything existed. The operator-facing `evict` runbook
    /// step; in-flight jobs are not protected — evict only idle entries.
    pub fn evict(&self, fp: u64) -> Result<bool> {
        let dir = self.entry_dir(fp);
        if !dir.exists() {
            return Ok(false);
        }
        fs::remove_dir_all(&dir)?;
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TTensor;

    fn tiny_artifact(seed: u64) -> Artifact {
        // A deterministic rank-1 TT over dims [2, 3].
        let s = seed as f64 + 1.0;
        let cores = vec![
            crate::linalg::Mat::from_vec(2, 1, vec![s, 2.0 * s]),
            crate::linalg::Mat::from_vec(3, 1, vec![1.0, 0.5, 0.25]),
        ];
        Artifact::Tt(TTensor::new(vec![2, 3], cores).unwrap())
    }

    fn temp_cache(tag: &str) -> ResultCache {
        let dir = std::env::temp_dir().join(format!(
            "dntt-cache-test-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        ResultCache::open(dir).unwrap()
    }

    #[test]
    fn put_lookup_roundtrip() {
        let cache = temp_cache("roundtrip");
        assert!(cache.lookup(7).is_none());
        let meta = Json::obj(vec![("label", Json::Str("t".into()))]);
        let entry = cache.put(7, &tiny_artifact(0), meta).unwrap();
        assert_eq!(entry.fingerprint, 7);
        let hit = cache.lookup(7).expect("committed entry");
        assert_eq!(hit.meta.get("format").as_str(), Some(CACHE_META_FORMAT));
        assert_eq!(hit.meta.get("fingerprint").as_str(), Some("0000000000000007"));
        assert_eq!(hit.meta.get("label").as_str(), Some("t"));
        let art = hit.load().unwrap();
        assert_eq!(art.dims(), &[2, 3]);
        let _ = fs::remove_dir_all(cache.dir());
    }

    #[test]
    fn artifact_without_meta_is_a_miss() {
        let cache = temp_cache("uncommitted");
        let fp = 0xabcdu64;
        fs::create_dir_all(cache.entry_dir(fp)).unwrap();
        save_artifact(&tiny_artifact(1), &cache.artifact_path(fp)).unwrap();
        assert!(cache.lookup(fp).is_none(), "no meta.json means not committed");
        assert!(cache.load(fp).is_err());
        // Committing over the torn entry repairs it.
        cache.put(fp, &tiny_artifact(1), Json::obj(vec![])).unwrap();
        assert!(cache.lookup(fp).is_some());
        let _ = fs::remove_dir_all(cache.dir());
    }

    #[test]
    fn torn_commits_are_pure_misses() {
        let cache = temp_cache("torn");
        let fp = 0x51u64;
        let committed = cache.put(fp, &tiny_artifact(1), Json::obj(vec![])).unwrap();
        assert!(committed.meta.get("artifact_bytes").as_usize().is_some());
        // Crash mid-re-put: the retract step removed meta.json and the
        // new artifact landed, but the new meta never did.
        fs::remove_file(cache.meta_path(fp)).unwrap();
        save_artifact(&tiny_artifact(2), &cache.artifact_path(fp)).unwrap();
        assert!(cache.lookup(fp).is_none(), "no meta means not committed");
        assert!(cache.load(fp).is_err());
        assert!(cache.entries().is_empty(), "orphan dirs are ignored in listings");
        // Re-putting repairs the entry.
        cache.put(fp, &tiny_artifact(2), Json::obj(vec![])).unwrap();
        assert_eq!(cache.entries().len(), 1);
        // Media-level tear: meta committed but the artifact truncated on
        // disk afterwards — the artifact_bytes stamp catches it.
        let art = cache.artifact_path(fp);
        let bytes = fs::read(&art).unwrap();
        fs::write(&art, &bytes[..bytes.len() - 4]).unwrap();
        assert!(cache.lookup(fp).is_none(), "size mismatch must not serve");
        assert!(cache.entries().is_empty());
        let _ = fs::remove_dir_all(cache.dir());
    }

    #[test]
    fn entries_sorted_and_evict() {
        let cache = temp_cache("entries");
        for fp in [9u64, 3, 12] {
            cache.put(fp, &tiny_artifact(fp), Json::obj(vec![])).unwrap();
        }
        let fps: Vec<u64> = cache.entries().iter().map(|e| e.fingerprint).collect();
        assert_eq!(fps, vec![3, 9, 12]);
        assert!(cache.evict(9).unwrap());
        assert!(!cache.evict(9).unwrap());
        let fps: Vec<u64> = cache.entries().iter().map(|e| e.fingerprint).collect();
        assert_eq!(fps, vec![3, 12]);
        let _ = fs::remove_dir_all(cache.dir());
    }
}
