//! `dntt` — the distributed non-negative tensor-train coordinator CLI.
//!
//! Subcommands map one-to-one onto the paper's experiments:
//!
//! * `decompose` — run the dnTT on a synthetic/sparse/faces/video tensor;
//! * `submit`    — append a job to the on-disk spool (service front door);
//! * `serve`     — run queued jobs on a shared rank pool with the
//!   fingerprint result cache (`dntt::coordinator::server`);
//! * `jobs`      — list the spool and the result cache;
//! * `query`     — serve batched point/fiber/slice queries from a saved
//!   `.dntt` artifact or a cache entry (the read side — see `dntt::serve`);
//! * `scaling`   — Figs 5/6/7 series (strong / weak / TT-rank scaling);
//! * `sweep`     — Figs 2/8a/8b/8c compression-vs-error curves;
//! * `denoise`   — Fig 9 SSIM comparison (SVD-TT vs NMF-TT);
//! * `info`      — platform + artifact manifest report.
//!
//! The operator walkthrough (submit → serve → query, runbooks) lives in
//! `rust/OPERATIONS.md`; the full flag reference in `rust/docs/CLI.md`.

use dntt::bench::workloads::{self, Fig8Data, ScalingMode, ScalingParams, PAPER_EPS};
use dntt::coordinator::{run_job, BackendChoice, Decomposition, InputSpec, JobConfig, ResumeMode};
use dntt::data::FaceConfig;
use dntt::dist::checkpoint::CheckpointPolicy;
use dntt::dist::chunkstore::SpillMode;
use dntt::dist::{faults, FaultPlan, ProcGrid};
use dntt::ht::HtConfig;
use dntt::nmf::{NmfAlgo, NmfConfig};
use dntt::ttrain::{SyntheticSparse, SyntheticTt, TtConfig};
use dntt::util::argparse::ArgSpec;
use std::path::PathBuf;
use std::process::exit;

fn main() {
    dntt::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("{}", top_usage());
            exit(2);
        }
    };
    let result = match cmd {
        "decompose" => cmd_decompose(&rest),
        "datagen" => cmd_datagen(&rest),
        "submit" => cmd_submit(&rest),
        "serve" => cmd_serve(&rest),
        "jobs" => cmd_jobs(&rest),
        "inspect" => cmd_inspect(&rest),
        "query" => cmd_query(&rest),
        "scaling" => cmd_scaling(&rest),
        "sweep" => cmd_sweep(&rest),
        "denoise" => cmd_denoise(&rest),
        "info" => cmd_info(&rest),
        "--help" | "-h" | "help" => {
            println!("{}", top_usage());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n\n{}", top_usage())),
    };
    if let Err(e) = result {
        eprintln!("{e}");
        exit(1);
    }
}

fn top_usage() -> String {
    "dntt — distributed non-negative tensor-train decomposition\n\n\
     USAGE: dntt <COMMAND> [OPTIONS]\n\n\
     COMMANDS:\n\
     \x20 decompose   decompose a tensor (synthetic | faces | video | file)\n\
     \x20 datagen     write a synthetic tensor to disk as a dntt-chunks-v1 chunk set\n\
     \x20 submit      queue a decomposition job in the on-disk spool\n\
     \x20 serve       run queued jobs on a shared rank pool (result cache)\n\
     \x20 jobs        list spooled jobs and cached results\n\
     \x20 inspect     inspect / evaluate a saved .dntt tensor train\n\
     \x20 query       serve point/fiber/slice queries from a .dntt artifact\n\
     \x20 scaling     strong/weak/TT-rank scaling series (Figs 5-7)\n\
     \x20 sweep       compression-vs-error curves (Figs 2, 8a-c)\n\
     \x20 denoise     SSIM denoising comparison (Fig 9)\n\
     \x20 info        platform + artifact info\n\n\
     Run `dntt <COMMAND> --help` for options."
        .into()
}

fn parse_grid(s: &str, d: usize) -> Result<ProcGrid, String> {
    let dims: Vec<usize> = s
        .split('x')
        .map(|x| x.trim().parse().map_err(|_| format!("bad grid '{s}'")))
        .collect::<Result<_, _>>()?;
    if dims.len() != d {
        return Err(format!("grid '{s}' has {} modes; tensor has {d}", dims.len()));
    }
    ProcGrid::new(dims).map_err(|e| e.to_string())
}

/// Nearest-rank percentile of an ascending-sorted sample (0.0 if empty).
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[i.min(sorted.len() - 1)]
}

fn cmd_decompose(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("dntt decompose", "run the distributed nTT/nHT on a tensor")
        .opt("input", "synthetic", "input kind: synthetic|sparse|faces|video|file")
        .opt("decomp", "tt", "decomposition: tt (tensor train) | ht (hierarchical Tucker)")
        .opt("dims", "16,16,16,16", "tensor dims (synthetic|sparse)")
        .opt("true-ranks", "4,4,4", "generator TT ranks (synthetic)")
        .opt("density", "0.01", "nonzero fraction in (0,1] (sparse input)")
        .opt("file", "", "dntt-chunks-v1 chunk-set directory (--input file; see `dntt datagen`)")
        .opt("budget-mb", "0", "chunk-store memory budget in MiB (0 = unbounded; streams reshapes and maps chunks)")
        .opt("grid", "1x1x1x1", "processor grid, e.g. 2x2x2x2")
        .opt("eps", "0.01", "per-stage rank-selection threshold")
        .opt("ranks", "", "fixed ranks (skip SVD): d-1 for tt, 2(d-1) for ht")
        .opt("algo", "bcd", "NMF update rule: bcd|mu|hals")
        .opt("iters", "100", "NMF iterations per stage")
        .opt("backend", "native", "compute backend: native|pjrt")
        .opt("artifacts", "artifacts", "artifact dir for --backend pjrt")
        .opt("spill", "", "spill chunks to this directory (out-of-core)")
        .flag("mmap", "with --spill: mmap chunks on read instead of buffered loads")
        .opt("checkpoint-dir", "", "write dntt-ckpt-v1 snapshots into this directory")
        .opt("ckpt-stages", "1", "snapshot after every N completed stages (0 = off)")
        .opt("ckpt-iters", "0", "in-flight W/H snapshot every N NMF iterations (0 = off)")
        .opt("resume", "off", "off|auto: resume from the checkpoint dir and relaunch on rank loss")
        .opt("fault-plan", "", "kills 'rank:op[,rank:op…]' or 'seed:<u64>' (fault-inject builds)")
        .opt("seed", "42", "random seed")
        .opt("save-tt", "", "write the decomposition to this .dntt file (tt only)")
        .opt("out", "", "persist the decomposition (tt or ht) as a servable .dntt artifact")
        .opt("round", "", "TT-round the result to this tolerance (SVD; drops non-negativity)")
        .opt("trace-out", "", "export a Chrome/Perfetto trace of the run to this JSON file")
        .opt("metrics-out", "", "write the dntt-metrics-v1 envelope to this JSON file")
        .opt("kernel", "auto", "GEMM/SpMM kernel: auto|scalar|avx2|avx512|neon (DNTT_KERNEL wins)")
        .opt("threads-per-rank", "1", "intra-rank worker threads for the packed GEMM/SpMM loop")
        .flag("smoke", "CI preset: tiny synthetic 4-mode tensor on a 2x2x1x1 grid")
        .flag("prune", "prune all-zero rows/cols of each stage matrix before the NMF")
        .flag("keep-spill", "leave spill chunk files on disk after the job")
        .flag("json", "emit the report as JSON")
        .flag("no-check", "skip reconstruction-error check");
    let a = spec.parse(argv)?;

    let mut input = match a.get("input") {
        "synthetic" => {
            let dims = a.usize_list("dims")?;
            let ranks = a.usize_list("true-ranks")?;
            if ranks.len() + 1 != dims.len() {
                return Err("--true-ranks must have dims-1 entries".into());
            }
            InputSpec::Synthetic(SyntheticTt::new(dims, ranks, a.usize("seed")? as u64))
        }
        "sparse" => {
            let density = a.f64("density")?;
            if !(density > 0.0 && density <= 1.0) {
                return Err(format!("--density must be in (0, 1], got {density}"));
            }
            InputSpec::SyntheticSparse(SyntheticSparse::new(
                a.usize_list("dims")?,
                density,
                a.usize("seed")? as u64,
            ))
        }
        "faces" => InputSpec::Faces(FaceConfig::default()),
        "video" => InputSpec::Video(dntt::data::VideoConfig::default()),
        "file" => {
            if a.get("file").is_empty() {
                return Err("--input file needs --file <chunk-set dir>".into());
            }
            InputSpec::from_chunks(std::path::Path::new(a.get("file")))
                .map_err(|e| e.to_string())?
        }
        other => return Err(format!("unknown input '{other}'")),
    };
    // --smoke: the fixed CI perf-smoke workload — small enough to finish
    // in seconds, yet a genuine 4-rank distributed run (2x2x1x1 grid) so
    // an exported trace carries one timeline per rank.
    if a.flag("smoke") {
        input = InputSpec::Synthetic(SyntheticTt::new(
            vec![8, 8, 8, 8],
            vec![3, 3, 3],
            a.usize("seed")? as u64,
        ));
    }
    let d = input.dims().len();
    let grid = if a.flag("smoke") {
        ProcGrid::new(vec![2, 2, 1, 1]).map_err(|e| e.to_string())?
    } else {
        parse_grid(a.get("grid"), d)?
    };
    let decomp: Decomposition = a.get("decomp").parse()?;
    if decomp == Decomposition::Ht && (!a.get("round").is_empty() || !a.get("save-tt").is_empty()) {
        // Fail before the (possibly long) decomposition, not after.
        return Err("--round/--save-tt are only supported with --decomp tt".into());
    }
    let algo: NmfAlgo = a.get("algo").parse()?;
    let fixed_ranks =
        if a.get("ranks").is_empty() { None } else { Some(a.usize_list("ranks")?) };
    let nmf = NmfConfig {
        max_iters: a.usize("iters")?,
        algo,
        seed: a.usize("seed")? as u64,
        ..Default::default()
    };
    let job = JobConfig {
        decomp,
        tt: TtConfig {
            eps: a.f64("eps")?,
            fixed_ranks: fixed_ranks.clone(),
            nmf: nmf.clone(),
            prune: a.flag("prune"),
            ..Default::default()
        },
        ht: HtConfig {
            eps: a.f64("eps")?,
            fixed_ranks,
            nmf,
            prune: a.flag("prune"),
            ..Default::default()
        },
        backend: match a.get("backend") {
            "native" => BackendChoice::Native,
            "pjrt" => BackendChoice::Pjrt(PathBuf::from(a.get("artifacts"))),
            other => return Err(format!("unknown backend '{other}'")),
        },
        spill: if a.get("spill").is_empty() {
            if a.flag("mmap") {
                return Err("--mmap needs --spill <dir> (or just --budget-mb, which \
                            picks a temp spill dir itself)"
                    .into());
            }
            SpillMode::Memory
        } else if a.flag("mmap") {
            SpillMode::Mmap(PathBuf::from(a.get("spill")))
        } else {
            SpillMode::Disk(PathBuf::from(a.get("spill")))
        },
        budget: {
            let mb = a.usize("budget-mb")? as u64;
            (mb > 0).then(|| mb << 20)
        },
        check_error: !a.flag("no-check"),
        checkpoint: if a.get("checkpoint-dir").is_empty() {
            None
        } else {
            Some(CheckpointPolicy {
                dir: PathBuf::from(a.get("checkpoint-dir")),
                every_stages: a.usize("ckpt-stages")?,
                every_iters: a.usize("ckpt-iters")?,
            })
        },
        resume: a.get("resume").parse()?,
        keep_spill: a.flag("keep-spill"),
        // Either export flag turns the event ring on; the trace is also
        // what fills the `counters`/`trace` sections of the envelope.
        trace: if a.get("trace-out").is_empty() && a.get("metrics-out").is_empty() {
            None
        } else {
            Some(dntt::obs::TraceConfig::default())
        },
        kernel: a.get("kernel").parse()?,
        threads_per_rank: a.usize("threads-per-rank")?.max(1),
        ..JobConfig::new(input, grid)
    };
    if job.checkpoint.is_none() && job.resume == ResumeMode::Auto {
        return Err("--resume auto needs --checkpoint-dir".into());
    }
    if job.trace.is_some() && !dntt::obs::TRACE_ENABLED {
        eprintln!(
            "warning: --trace-out/--metrics-out given but this binary was built with \
             `--no-default-features`; the trace and counter sections will be empty"
        );
    }
    // Deterministic fault injection (replayable rank deaths): only a
    // fault-inject build actually fires the plan.
    let plan = if a.get("fault-plan").is_empty() {
        None
    } else {
        if !faults::FAULT_INJECT_ENABLED {
            eprintln!(
                "warning: --fault-plan given but this binary was built without \
                 `--features fault-inject`; the plan will not fire"
            );
        }
        let plan = FaultPlan::from_cli(a.get("fault-plan"), job.grid.size())?;
        faults::arm(&plan);
        Some(plan)
    };
    let rep = run_job(&job);
    if let Some(plan) = &plan {
        faults::disarm();
        if let Some(kill) = plan.last_fired() {
            eprintln!("fault plan fired: rank {} died at collective #{}", kill.rank, kill.op);
        }
    }
    let rep = rep.map_err(|e| e.to_string())?;
    if a.flag("json") {
        println!("{}", rep.to_json().to_pretty());
    } else {
        println!("{}", rep.summary());
    }
    if !a.get("trace-out").is_empty() {
        let obs = rep.obs.as_ref().expect("trace config was set");
        let path = std::path::PathBuf::from(a.get("trace-out"));
        std::fs::write(&path, obs.chrome_trace_json().to_pretty())
            .map_err(|e| format!("writing {path:?}: {e}"))?;
        println!(
            "wrote trace to {path:?} ({} events, {} rank timeline(s), {} dropped)",
            obs.events_total(),
            obs.rank_ids().len(),
            obs.dropped_total()
        );
    }
    if !a.get("metrics-out").is_empty() {
        let path = std::path::PathBuf::from(a.get("metrics-out"));
        std::fs::write(&path, rep.metrics_json().to_pretty())
            .map_err(|e| format!("writing {path:?}: {e}"))?;
        println!("wrote dntt-metrics-v1 envelope to {path:?}");
    }
    if !a.get("round").is_empty() || !a.get("save-tt").is_empty() {
        let Some(tt_out) = rep.output.tt() else {
            return Err("--round/--save-tt are only supported with --decomp tt".into());
        };
        let mut tt = tt_out.tt.clone();
        if !a.get("round").is_empty() {
            let eps: f64 = a.f64("round")?;
            tt = dntt::ttrain::tt_round(&tt, eps).map_err(|e| e.to_string())?;
            println!(
                "rounded to eps {eps}: ranks {:?}, compression {:.4}x (cores now signed)",
                tt.ranks(),
                tt.compression_ratio()
            );
        }
        if !a.get("save-tt").is_empty() {
            let path = std::path::PathBuf::from(a.get("save-tt"));
            dntt::tensor::io::save_tt(&tt, &path).map_err(|e| e.to_string())?;
            println!("saved TT to {path:?} ({} params)", tt.num_params());
        }
    }
    if !a.get("out").is_empty() {
        // Servable artifact for `dntt query` — works for both networks
        // (unlike --save-tt, kept for backwards compatibility).
        let path = std::path::PathBuf::from(a.get("out"));
        let artifact = rep.output.artifact();
        dntt::tensor::io::save_artifact(&artifact, &path).map_err(|e| e.to_string())?;
        println!(
            "saved {} artifact to {path:?} ({} params)",
            artifact.kind_name(),
            artifact.num_params()
        );
    }
    Ok(())
}

fn cmd_datagen(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new(
        "dntt datagen",
        "write a synthetic tensor to disk as a dntt-chunks-v1 chunk set",
    )
    .opt("out", "chunks", "output chunk-set directory (must not already hold a manifest)")
    .opt("input", "synthetic", "generator: synthetic|sparse")
    .opt("dims", "16,16,16,16", "tensor dims")
    .opt("true-ranks", "4,4,4", "generator TT ranks (synthetic)")
    .opt("density", "0.01", "nonzero fraction in (0,1] (sparse)")
    .opt("grid", "1x1x1x1", "chunk grid — must equal the consuming job's processor grid")
    .opt("seed", "42", "random seed")
    .flag("json", "emit the chunk-set summary as JSON");
    let a = spec.parse(argv)?;
    let dims = a.usize_list("dims")?;
    let grid = parse_grid(a.get("grid"), dims.len())?;
    let dir = PathBuf::from(a.get("out"));
    let cs = match a.get("input") {
        "synthetic" => {
            let ranks = a.usize_list("true-ranks")?;
            if ranks.len() + 1 != dims.len() {
                return Err("--true-ranks must have dims-1 entries".into());
            }
            SyntheticTt::new(dims, ranks, a.usize("seed")? as u64).write_chunks(&dir, &grid)
        }
        "sparse" => {
            let density = a.f64("density")?;
            if !(density > 0.0 && density <= 1.0) {
                return Err(format!("--density must be in (0, 1], got {density}"));
            }
            SyntheticSparse::new(dims, density, a.usize("seed")? as u64)
                .write_chunks(&dir, &grid)
        }
        other => return Err(format!("unknown generator '{other}' (synthetic|sparse)")),
    }
    .map_err(|e| e.to_string())?;
    if a.flag("json") {
        use dntt::util::json::Json;
        let j = Json::obj(vec![
            ("dir", Json::Str(dir.to_string_lossy().into_owned())),
            ("format", Json::Str("dntt-chunks-v1".into())),
            ("dims", Json::arr_usize(cs.dims())),
            ("grid", Json::arr_usize(cs.grid())),
            ("chunks", Json::Num(cs.num_chunks() as f64)),
            ("total_bytes", Json::Num(cs.total_bytes() as f64)),
            ("identity", Json::Str(format!("{:016x}", cs.identity()))),
        ]);
        println!("{}", j.to_pretty());
    } else {
        println!(
            "wrote {} chunk(s) to {dir:?}: dims {:?}, grid {:?}, {:.1} MiB, identity {:016x}",
            cs.num_chunks(),
            cs.dims(),
            cs.grid(),
            cs.total_bytes() as f64 / (1u64 << 20) as f64,
            cs.identity()
        );
        println!(
            "decompose it with: dntt decompose --input file --file {} --grid {} --budget-mb <N>",
            dir.display(),
            a.get("grid")
        );
    }
    Ok(())
}

fn cmd_submit(argv: &[String]) -> Result<(), String> {
    use dntt::coordinator::{JobSpec, Spool};
    let spec_args = ArgSpec::new("dntt submit", "queue a decomposition job in the on-disk spool")
        .opt("spool", "spool", "spool directory (shared with `dntt serve`)")
        .opt("input", "synthetic", "input kind: synthetic|sparse|faces|video|file")
        .opt("decomp", "tt", "decomposition: tt (tensor train) | ht (hierarchical Tucker)")
        .opt("dims", "16,16,16,16", "tensor dims (synthetic|sparse)")
        .opt("true-ranks", "4,4,4", "generator TT ranks (synthetic)")
        .opt("density", "0.01", "nonzero fraction in (0,1] (sparse input)")
        .opt("file", "", "dntt-chunks-v1 chunk-set directory (--input file)")
        .opt("budget-mb", "0", "chunk-store memory budget in MiB (0 = unbounded)")
        .opt("grid", "1x1x1x1", "processor grid, e.g. 2x2x1x1")
        .opt("eps", "0.01", "per-stage rank-selection threshold")
        .opt("ranks", "", "fixed ranks (skip SVD): d-1 for tt, 2(d-1) for ht")
        .opt("algo", "bcd", "NMF update rule: bcd|mu|hals")
        .opt("iters", "100", "NMF iterations per stage")
        .opt("seed", "42", "random seed")
        .opt("kernel", "auto", "GEMM/SpMM kernel: auto|scalar|avx2|avx512|neon (serving host's DNTT_KERNEL wins)")
        .opt("threads-per-rank", "1", "intra-rank worker threads for the packed GEMM/SpMM loop")
        .opt("priority", "normal", "admission priority: low|normal|high")
        .opt("tenant", "default", "fair-share accounting bucket (user/team name)")
        .opt("label", "", "display label for listings (default: the input's label)")
        .flag("smoke", "CI preset: same tensor/grid as `decompose --smoke`")
        .flag("prune", "prune all-zero rows/cols of each stage matrix before the NMF")
        .flag("trace", "record per-rank traces (fills the job's metrics envelope)")
        .flag("no-check", "skip reconstruction-error check")
        .flag("json", "emit the queued spec as JSON");
    let a = spec_args.parse(argv)?;
    let mut spec = if a.flag("smoke") {
        JobSpec::smoke(a.usize("seed")? as u64)
    } else {
        // For file inputs the chunk-set manifest is the source of truth for
        // dims; the CLI --dims default would otherwise mis-size --grid.
        let dims = if a.get("input") == "file" {
            if a.get("file").is_empty() {
                return Err("--input file needs --file <chunk-set dir>".into());
            }
            dntt::coordinator::InputSpec::from_chunks(std::path::Path::new(a.get("file")))
                .map_err(|e| e.to_string())?
                .dims()
        } else {
            a.usize_list("dims")?
        };
        let d = dims.len();
        JobSpec {
            input: a.get("input").into(),
            dims,
            true_ranks: a.usize_list("true-ranks")?,
            density: a.f64("density")?,
            seed: a.usize("seed")? as u64,
            decomp: a.get("decomp").parse()?,
            grid: parse_grid(a.get("grid"), d)?.dims().to_vec(),
            eps: a.f64("eps")?,
            fixed_ranks: if a.get("ranks").is_empty() { None } else { Some(a.usize_list("ranks")?) },
            algo: a.get("algo").into(),
            iters: a.usize("iters")?,
            prune: a.flag("prune"),
            ..JobSpec::default()
        }
    };
    // The scheduling envelope applies to presets and explicit specs alike.
    spec.priority = a.get("priority").parse()?;
    spec.tenant = a.get("tenant").into();
    spec.label = if a.get("label").is_empty() { None } else { Some(a.get("label").into()) };
    spec.trace = a.flag("trace");
    spec.check_error = !a.flag("no-check");
    spec.kernel = a.get("kernel").into();
    spec.threads_per_rank = a.usize("threads-per-rank")?.max(1);
    spec.file = (!a.get("file").is_empty()).then(|| PathBuf::from(a.get("file")));
    spec.budget_mb = a.usize("budget-mb")? as u64;
    // Validate now (bad specs should fail at the submitter's terminal,
    // not inside the server) and surface the cache key.
    let job = spec.to_config().map_err(|e| e.to_string())?;
    let fp = job.fingerprint();
    let spool = Spool::open(a.get("spool")).map_err(|e| e.to_string())?;
    let seq = spool.submit(&spec).map_err(|e| e.to_string())?;
    if a.flag("json") {
        let mut j = spec.to_json();
        if let dntt::util::json::Json::Obj(m) = &mut j {
            m.insert("seq".into(), dntt::util::json::Json::Num(seq as f64));
            m.insert("fingerprint".into(), dntt::util::json::Json::Str(format!("{fp:016x}")));
        }
        println!("{}", j.to_pretty());
    } else {
        println!(
            "queued job{seq:06} in {:?} (fingerprint {fp:016x}, priority {}, tenant {})",
            spool.pending_dir(),
            spec.priority.name(),
            spec.tenant
        );
        println!("run `dntt serve --spool {}` to execute it", a.get("spool"));
    }
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<(), String> {
    use dntt::coordinator::{JobServer, ServerConfig, Spool};
    use dntt::util::json::Json;
    let spec = ArgSpec::new(
        "dntt serve",
        "run all queued jobs on a shared rank pool, then exit",
    )
    .opt("spool", "spool", "spool directory (shared with `dntt submit`)")
    .opt("cache-dir", "cache", "fingerprint result-cache directory")
    .opt("pool-ranks", "8", "worker ranks in the shared pool (max single-job grid size)")
    .opt(
        "metrics-dir",
        "",
        "write METRICS_job<seq>.json (dntt-metrics-v1) here for traced executed jobs",
    )
    .flag("no-checkpoint", "do not checkpoint server jobs into the cache (disables resume)")
    .flag("json", "emit outcomes, stats and the admission log as JSON");
    let a = spec.parse(argv)?;
    let spool = Spool::open(a.get("spool")).map_err(|e| e.to_string())?;
    let pending = spool.pending().map_err(|e| e.to_string())?;
    if pending.is_empty() {
        println!("spool {:?}: no pending jobs", spool.pending_dir());
        return Ok(());
    }
    let mut cfg = ServerConfig::new(a.usize("pool-ranks")?, a.get("cache-dir"));
    cfg.checkpoint = !a.flag("no-checkpoint");
    let srv = JobServer::new(cfg).map_err(|e| e.to_string())?;
    // Submit everything up front (spool order = submission order), then
    // drain the pool. A spec the server rejects (e.g. oversized grid) is
    // resolved straight to a failed outcome row.
    let mut accepted = Vec::new();
    for p in &pending {
        let req = match p.spec.to_request() {
            Ok(r) => r,
            Err(e) => {
                spool
                    .mark_done(p.seq, &Json::obj(vec![("error", Json::Str(e.to_string()))]))
                    .map_err(|e| e.to_string())?;
                eprintln!("job{:06}: rejected: {e}", p.seq);
                continue;
            }
        };
        let traced = p.spec.trace;
        match srv.submit(req) {
            Ok(id) => accepted.push((p.seq, id, traced)),
            Err(e) => {
                spool
                    .mark_done(p.seq, &Json::obj(vec![("error", Json::Str(e.to_string()))]))
                    .map_err(|e| e.to_string())?;
                eprintln!("job{:06}: rejected: {e}", p.seq);
            }
        }
    }
    srv.drain();
    let mut rows = Vec::new();
    for (seq, id, traced) in &accepted {
        let o = srv.outcome(*id).expect("drained job has an outcome");
        spool.mark_done(*seq, &o.to_json()).map_err(|e| e.to_string())?;
        if *traced && !a.get("metrics-dir").is_empty() {
            if let Some(rep) = &o.report {
                let dir = PathBuf::from(a.get("metrics-dir"));
                std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
                let path = dir.join(format!("METRICS_job{seq:06}.json"));
                std::fs::write(&path, rep.metrics_json().to_pretty())
                    .map_err(|e| format!("writing {path:?}: {e}"))?;
            }
        }
        rows.push((*seq, o));
    }
    let stats = srv.stats();
    if a.flag("json") {
        let jobs: Vec<Json> = rows
            .iter()
            .map(|(seq, o)| {
                let mut j = o.to_json();
                if let Json::Obj(m) = &mut j {
                    m.insert("seq".into(), Json::Num(*seq as f64));
                }
                j
            })
            .collect();
        let out = Json::obj(vec![
            ("jobs", Json::Arr(jobs)),
            (
                "stats",
                Json::obj(vec![
                    ("submitted", Json::Num(stats.submitted as f64)),
                    ("executed", Json::Num(stats.executed as f64)),
                    ("cache_hits", Json::Num(stats.cache_hits as f64)),
                    ("coalesced", Json::Num(stats.coalesced as f64)),
                    ("leases_granted", Json::Num(stats.leases_granted as f64)),
                ]),
            ),
            (
                "admission_log",
                Json::Arr(srv.admission_log().into_iter().map(Json::Str).collect()),
            ),
        ]);
        println!("{}", out.to_pretty());
    } else {
        for (seq, o) in &rows {
            let how = if o.cache_hit {
                "cache hit"
            } else if o.coalesced {
                "coalesced"
            } else {
                "executed"
            };
            match (&o.error, &o.artifact) {
                (Some(e), _) => println!("job{seq:06} [{how}] {} FAILED: {e}", o.label),
                (None, Some(art)) => println!(
                    "job{seq:06} [{how}] {} fp={:016x} -> {}",
                    o.label,
                    o.fingerprint,
                    art.display()
                ),
                (None, None) => println!("job{seq:06} [{how}] {}", o.label),
            }
        }
        println!(
            "served {} job(s): {} executed, {} cache hit(s), {} coalesced, {} lease(s) granted",
            stats.submitted, stats.executed, stats.cache_hits, stats.coalesced,
            stats.leases_granted
        );
    }
    Ok(())
}

fn cmd_jobs(argv: &[String]) -> Result<(), String> {
    use dntt::coordinator::Spool;
    use dntt::serve::ResultCache;
    use dntt::util::json::Json;
    let spec = ArgSpec::new("dntt jobs", "list spooled jobs and cached results")
        .opt("spool", "spool", "spool directory")
        .opt("cache-dir", "cache", "fingerprint result-cache directory")
        .flag("json", "emit the listing as JSON");
    let a = spec.parse(argv)?;
    let spool = Spool::open(a.get("spool")).map_err(|e| e.to_string())?;
    let pending = spool.pending().map_err(|e| e.to_string())?;
    let cache = ResultCache::open(a.get("cache-dir")).map_err(|e| e.to_string())?;
    let entries = cache.entries();
    if a.flag("json") {
        let pend: Vec<Json> = pending
            .iter()
            .map(|p| {
                let mut j = p.spec.to_json();
                if let Json::Obj(m) = &mut j {
                    m.insert("seq".into(), Json::Num(p.seq as f64));
                }
                j
            })
            .collect();
        let cached: Vec<Json> = entries
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("fingerprint", Json::Str(format!("{:016x}", e.fingerprint))),
                    ("artifact", Json::Str(e.artifact.display().to_string())),
                    ("meta", e.meta.clone()),
                ])
            })
            .collect();
        println!(
            "{}",
            Json::obj(vec![("pending", Json::Arr(pend)), ("cached", Json::Arr(cached))])
                .to_pretty()
        );
        return Ok(());
    }
    println!("pending ({} in {:?}):", pending.len(), spool.pending_dir());
    for p in &pending {
        println!(
            "  job{:06}  {:<8} {:<6} dims {:?} grid {:?} prio {} tenant {}",
            p.seq,
            p.spec.label.clone().unwrap_or_else(|| p.spec.input.clone()),
            p.spec.decomp.name(),
            p.spec.dims,
            p.spec.grid,
            p.spec.priority.name(),
            p.spec.tenant
        );
    }
    println!("cached ({} in {:?}):", entries.len(), cache.dir());
    for e in &entries {
        let label = e.meta.get("label").as_str().unwrap_or("?");
        let wall = e.meta.get("wall_secs").as_f64().unwrap_or(0.0);
        println!(
            "  {:016x}  {:<12} {:.3}s  {}",
            e.fingerprint,
            label,
            wall,
            e.artifact.display()
        );
    }
    Ok(())
}

fn cmd_query(argv: &[String]) -> Result<(), String> {
    use dntt::serve::{HtHandle, HtQueryWorkspace, QueryWorkspace, TtHandle};
    use dntt::tensor::io::{load_artifact, Artifact};
    use dntt::util::json::Json;

    let spec = ArgSpec::new("dntt query", "serve batched queries from a saved .dntt artifact")
        .pos("file", "path to a .dntt artifact (tt or ht); omit with --cache/--fp")
        .opt("at", "", "one point query, e.g. --at 3,1,4,1")
        .opt("fiber", "", "fiber along this mode through the --at anchor")
        .opt("slice", "", "slice 'mode:index', e.g. --slice 2:5")
        .opt("points", "0", "time N random point queries (batched; seeded)")
        .opt("batch", "4096", "batch size for --points")
        .opt("seed", "7", "random-query seed")
        .opt("round", "", "TT-round to this tolerance before serving (tt only)")
        .opt("max-rank", "", "cap every TT rank before serving (tt only)")
        .opt("cache", "", "serve from this result cache instead of a file (with --fp)")
        .opt("fp", "", "fingerprint (hex) of the cache entry to serve")
        .flag("compare", "with --points: also time naive per-element evaluation")
        .flag("json", "emit results as JSON");
    let a = spec.parse(argv)?;
    let (path, mut artifact) = if !a.get("cache").is_empty() || !a.get("fp").is_empty() {
        // Cache addressing: the artifact is looked up by job fingerprint,
        // exactly as `dntt serve` committed it.
        if a.get("cache").is_empty() || a.get("fp").is_empty() {
            return Err("--cache and --fp must be given together".into());
        }
        let fp = u64::from_str_radix(a.get("fp"), 16)
            .map_err(|_| format!("bad --fp '{}': want 16 hex digits", a.get("fp")))?;
        let cache =
            dntt::serve::ResultCache::open(a.get("cache")).map_err(|e| e.to_string())?;
        let art = cache.load(fp).map_err(|e| e.to_string())?;
        (format!("{}:{fp:016x}", a.get("cache")), art)
    } else {
        let path = a
            .positionals()
            .first()
            .ok_or_else(|| format!("missing <file> (or --cache/--fp)\n\n{}", spec.usage()))?;
        let art = load_artifact(std::path::Path::new(path)).map_err(|e| e.to_string())?;
        (path.clone(), art)
    };

    // Optional recompression before serving (TT only).
    if !a.get("round").is_empty() || !a.get("max-rank").is_empty() {
        let Artifact::Tt(tt) = &artifact else {
            return Err("--round/--max-rank are only supported for tt artifacts".into());
        };
        let eps = if a.get("round").is_empty() { 0.0 } else { a.f64("round")? };
        let cap =
            if a.get("max-rank").is_empty() { None } else { Some(a.usize("max-rank")?) };
        let rounded = dntt::serve::truncate(tt, eps, cap).map_err(|e| e.to_string())?;
        println!(
            "truncated (eps {eps}, max-rank {cap:?}): ranks {:?}, {} params",
            rounded.ranks(),
            rounded.num_params()
        );
        artifact = Artifact::Tt(rounded);
    }

    let dims = artifact.dims().to_vec();
    let d = dims.len();
    println!(
        "artifact      : {path} ({}, dims {:?}, {} params)",
        artifact.kind_name(),
        dims,
        artifact.num_params()
    );

    // Dispatch one batch through whichever handle the artifact needs.
    enum Served {
        Tt(TtHandle, QueryWorkspace),
        Ht(HtHandle, HtQueryWorkspace),
    }
    let mut served = match artifact {
        Artifact::Tt(tt) => Served::Tt(TtHandle::new(tt), QueryWorkspace::new()),
        Artifact::Ht(ht) => Served::Ht(HtHandle::new(ht), HtQueryWorkspace::new()),
    };

    let at: Option<Vec<usize>> = if a.get("at").is_empty() {
        None
    } else {
        let idx = a.usize_list("at")?;
        if idx.len() != d {
            return Err(format!("--at needs {d} indices"));
        }
        Some(idx)
    };

    if let Some(idx) = &at {
        if a.get("fiber").is_empty() {
            let v = match &mut served {
                Served::Tt(h, ws) => {
                    let mut out = Vec::new();
                    h.batch_into(idx, ws, &mut out).map_err(|e| e.to_string())?;
                    out[0]
                }
                Served::Ht(h, ws) => {
                    let mut out = Vec::new();
                    h.batch_into(idx, ws, &mut out).map_err(|e| e.to_string())?;
                    out[0]
                }
            };
            println!("A{idx:?} = {v}");
        }
    }
    if !a.get("fiber").is_empty() {
        let mode = a.usize("fiber")?;
        let anchor = at.clone().ok_or("--fiber needs an --at anchor")?;
        let fib = match &mut served {
            Served::Tt(h, ws) => h.fiber(mode, &anchor, ws).map_err(|e| e.to_string())?,
            Served::Ht(h, ws) => h.fiber(mode, &anchor, ws).map_err(|e| e.to_string())?,
        };
        println!("fiber(mode {mode} through {anchor:?}) = {fib:?}");
    }
    if !a.get("slice").is_empty() {
        let (ms, is) = a
            .get("slice")
            .split_once(':')
            .ok_or("--slice wants 'mode:index'")?;
        let mode: usize = ms.trim().parse().map_err(|_| format!("bad slice mode '{ms}'"))?;
        let index: usize = is.trim().parse().map_err(|_| format!("bad slice index '{is}'"))?;
        let sl = match &mut served {
            Served::Tt(h, ws) => h.slice(mode, index, ws).map_err(|e| e.to_string())?,
            Served::Ht(h, ws) => h.slice(mode, index, ws).map_err(|e| e.to_string())?,
        };
        println!(
            "slice(mode {mode} = {index}): dims {:?}, fro norm {:.6e}",
            sl.dims(),
            sl.as_slice().iter().map(|x| x * x).sum::<f64>().sqrt()
        );
    }

    let points = a.usize("points")?;
    if points > 0 {
        let batch = a.usize("batch")?.max(1);
        let mut rng = dntt::util::rng::Rng::new(a.usize("seed")? as u64);
        let queries: Vec<usize> =
            (0..points * d).map(|i| rng.below(dims[i % d])).collect();
        let mut out = Vec::new();
        let mut batch_secs = Vec::with_capacity(queries.len() / (batch * d) + 1);
        let t0 = std::time::Instant::now();
        for chunk in queries.chunks(batch * d) {
            let tb = std::time::Instant::now();
            match &mut served {
                Served::Tt(h, ws) => h.batch_into(chunk, ws, &mut out),
                Served::Ht(h, ws) => h.batch_into(chunk, ws, &mut out),
            }
            .map_err(|e| e.to_string())?;
            batch_secs.push(tb.elapsed().as_secs_f64());
        }
        let batched_s = t0.elapsed().as_secs_f64();
        batch_secs.sort_unstable_by(|x, y| x.total_cmp(y));
        let p50 = percentile(&batch_secs, 0.50);
        let p99 = percentile(&batch_secs, 0.99);
        // Serve-side cache/workspace counters, identical across handles.
        let (hits, misses, hit_rate, cap_bytes) = match &served {
            Served::Tt(_, ws) => (
                ws.prefix_modes_reused(),
                ws.prefix_modes_computed(),
                ws.prefix_hit_rate(),
                ws.capacity_bytes(),
            ),
            Served::Ht(_, ws) => (
                ws.prefix_modes_reused(),
                ws.prefix_modes_computed(),
                ws.prefix_hit_rate(),
                ws.capacity_bytes(),
            ),
        };
        let qps = points as f64 / batched_s;
        let naive_s = if a.flag("compare") {
            let t1 = std::time::Instant::now();
            let mut acc = 0.0f64;
            for q in queries.chunks(d) {
                acc += match &served {
                    Served::Tt(h, _) => h.tt().element(q),
                    Served::Ht(h, _) => h.element(q).map_err(|e| e.to_string())?,
                };
            }
            std::hint::black_box(acc);
            Some(t1.elapsed().as_secs_f64())
        } else {
            None
        };
        if a.flag("json") {
            let mut pairs = vec![
                ("points", Json::Num(points as f64)),
                ("batch", Json::Num(batch as f64)),
                ("batched_secs", Json::Num(batched_s)),
                ("queries_per_sec", Json::Num(qps)),
            ];
            if let Some(ns) = naive_s {
                pairs.push(("naive_secs", Json::Num(ns)));
                pairs.push(("speedup", Json::Num(ns / batched_s)));
            }
            pairs.push((
                "serve",
                Json::obj(vec![
                    ("prefix_modes_reused", Json::Num(hits as f64)),
                    ("prefix_modes_computed", Json::Num(misses as f64)),
                    ("prefix_hit_rate", Json::Num(hit_rate)),
                    ("workspace_capacity_bytes", Json::Num(cap_bytes as f64)),
                    ("batch_p50_secs", Json::Num(p50)),
                    ("batch_p99_secs", Json::Num(p99)),
                ]),
            ));
            println!("{}", Json::obj(pairs).to_pretty());
        } else {
            println!(
                "{points} point queries in batches of {batch}: {batched_s:.4}s ({qps:.0} q/s)"
            );
            println!(
                "serve: prefix-cache hit rate {:.1}% ({hits} reused / {misses} computed), \
                 workspace {cap_bytes} B, batch p50 {p50:.4e}s p99 {p99:.4e}s",
                100.0 * hit_rate
            );
            if let Some(ns) = naive_s {
                println!(
                    "naive per-element: {ns:.4}s — batched speedup {:.2}x",
                    ns / batched_s
                );
            }
        }
    }
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("dntt inspect", "inspect a saved .dntt tensor train")
        .pos("file", "path to a .dntt tensor-train file")
        .opt("at", "", "evaluate one element, e.g. --at 3,1,4,1")
        .opt("round", "", "TT-round to this tolerance and report new ranks");
    let a = spec.parse(argv)?;
    let path = a
        .positionals()
        .first()
        .ok_or_else(|| format!("missing <file>\n\n{}", spec.usage()))?;
    let tt = dntt::tensor::io::load_tt(std::path::Path::new(path)).map_err(|e| e.to_string())?;
    println!("file          : {path}");
    println!("dims          : {:?}", tt.dims());
    println!("TT ranks      : {:?}", tt.ranks());
    println!("parameters    : {}", tt.num_params());
    println!("compression   : {:.4}x", tt.compression_ratio());
    println!("non-negative  : {}", tt.is_nonneg());
    if !a.get("at").is_empty() {
        let idx = a.usize_list("at")?;
        if idx.len() != tt.dims().len() {
            return Err(format!("--at needs {} indices", tt.dims().len()));
        }
        println!("A{idx:?}       = {}", tt.element(&idx));
    }
    if !a.get("round").is_empty() {
        let eps = a.f64("round")?;
        let r = dntt::ttrain::tt_round(&tt, eps).map_err(|e| e.to_string())?;
        println!(
            "rounded(ε={eps}) : ranks {:?}, compression {:.4}x",
            r.ranks(),
            r.compression_ratio()
        );
    }
    Ok(())
}

fn cmd_scaling(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("dntt scaling", "scaling series (Figs 5-7)")
        .opt("mode", "strong", "strong|weak|ranks")
        .opt("decomp", "tt", "decomposition under test: tt|ht")
        .opt("shrink", "4", "divide the paper's 256 mode size by this")
        .opt("ks", "1,2,3,4,5", "grid exponents k (grid 2^k x2x2x2)")
        .opt("iters", "10", "NMF iterations (paper: 100)")
        .opt("algos", "bcd,mu", "update rules to run")
        .opt("ranks", "10,10,10", "fixed TT ranks (Figs 5-6)")
        .opt("rank-sweep", "2,4,8,16", "rank values (Fig 7)")
        .opt("rank-p-exp", "5", "grid exponent for Fig 7 (5 = 256 ranks)")
        .flag("json", "emit the series as JSON")
        .opt("save", "", "save series under bench_results/BENCH_<label>.json");
    let a = spec.parse(argv)?;
    let mode = match a.get("mode") {
        "strong" => ScalingMode::Strong,
        "weak" => ScalingMode::Weak,
        "ranks" => ScalingMode::Ranks,
        other => return Err(format!("unknown mode '{other}'")),
    };
    let algos: Vec<NmfAlgo> =
        a.get("algos").split(',').map(|s| s.trim().parse()).collect::<Result<_, _>>()?;
    let params = ScalingParams {
        decomp: a.get("decomp").parse()?,
        shrink: a.usize("shrink")?,
        ks: a.usize_list("ks")?,
        iters: a.usize("iters")?,
        algos,
        ranks: a.usize_list("ranks")?,
        ranks_p_exp: a.usize("rank-p-exp")?,
        rank_sweep: a.usize_list("rank-sweep")?,
        ..Default::default()
    };
    let points = workloads::scaling_run(mode, &params).map_err(|e| e.to_string())?;
    if a.flag("json") {
        let rows: Vec<_> = points.iter().map(|p| p.to_json()).collect();
        println!("{}", dntt::util::json::Json::Arr(rows).to_pretty());
    } else {
        workloads::print_scaling(&points);
    }
    if !a.get("save").is_empty() {
        workloads::save_rows(a.get("save"), points.iter().map(|p| p.to_json()).collect())
            .map_err(|e| e.to_string())?;
    }
    Ok(())
}

fn cmd_sweep(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("dntt sweep", "compression-vs-error curves (Figs 2, 8a-c, ht)")
        .opt("figure", "2", "which figure: 2|8a|8b|8c|ht (nTT-vs-nHT comparison)")
        .opt("size", "16", "mode size for Fig 2 (paper: 32)")
        .opt("scale", "4", "shrink factor for Fig 8 datasets")
        .opt("iters", "100", "NMF iterations")
        .opt("eps", "", "comma-separated eps list (default: paper schedule)")
        .flag("json", "emit rows as JSON")
        .opt("save", "", "save series under bench_results/BENCH_<label>.json");
    let a = spec.parse(argv)?;
    let eps: Vec<f64> =
        if a.get("eps").is_empty() { PAPER_EPS.to_vec() } else { a.f64_list("eps")? };
    let iters = a.usize("iters")?;
    let rows = match a.get("figure") {
        "2" => workloads::fig2_sweep(a.usize("size")?, &eps, iters),
        "8a" => workloads::fig8_sweep(Fig8Data::Faces, &eps, iters, a.usize("scale")?),
        "8b" => workloads::fig8_sweep(Fig8Data::Video, &eps, iters, a.usize("scale")?),
        "8c" => workloads::fig8_sweep(Fig8Data::LargeSynthetic, &eps, iters, a.usize("scale")?),
        "ht" => workloads::ht_vs_tt_sweep(a.usize("size")?, &eps, iters),
        other => return Err(format!("unknown figure '{other}'")),
    }
    .map_err(|e| e.to_string())?;
    if a.flag("json") {
        let out: Vec<_> = rows.iter().map(|r| r.to_json()).collect();
        println!("{}", dntt::util::json::Json::Arr(out).to_pretty());
    } else {
        workloads::print_sweep(&rows);
    }
    if !a.get("save").is_empty() {
        workloads::save_rows(a.get("save"), rows.iter().map(|r| r.to_json()).collect())
            .map_err(|e| e.to_string())?;
    }
    Ok(())
}

fn cmd_denoise(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("dntt denoise", "denoising SSIM comparison (Fig 9)")
        .opt("scale", "2", "shrink factor for the face dataset")
        .opt("sigma", "0.12", "noise std as a fraction of the data peak")
        .opt("ranks", "16,12,8,6,4,2", "TT ranks to sweep (uniform)")
        .opt("iters", "150", "NMF iterations")
        .flag("json", "emit rows as JSON")
        .opt("save", "", "save series under bench_results/BENCH_<label>.json");
    let a = spec.parse(argv)?;
    let s = a.usize("scale")?.max(1);
    let faces = FaceConfig {
        height: 48 / s.min(4),
        width: 42 / s.min(3),
        illuminations: (64 / s).max(4),
        subjects: (38 / s).max(2),
        ..Default::default()
    };
    let rows = workloads::denoise_run(
        &faces,
        a.f64("sigma")?,
        &a.usize_list("ranks")?,
        a.usize("iters")?,
    )
    .map_err(|e| e.to_string())?;
    if a.flag("json") {
        let out: Vec<_> = rows.iter().map(|r| r.to_json()).collect();
        println!("{}", dntt::util::json::Json::Arr(out).to_pretty());
    } else {
        workloads::print_denoise(&rows);
    }
    if !a.get("save").is_empty() {
        workloads::save_rows(a.get("save"), rows.iter().map(|r| r.to_json()).collect())
            .map_err(|e| e.to_string())?;
    }
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("dntt info", "platform + artifact info")
        .opt("artifacts", "artifacts", "artifact directory");
    let a = spec.parse(argv)?;
    println!("dntt {}", env!("CARGO_PKG_VERSION"));
    let dir = PathBuf::from(a.get("artifacts"));
    match dntt::runtime::Manifest::load(&dir) {
        Ok(m) if !m.is_empty() => {
            println!("artifacts     : {} ops in {:?}", m.len(), dir);
        }
        _ => println!("artifacts     : none (run `make artifacts`)"),
    }
    match dntt::runtime::PjrtEngine::start(&dir) {
        Ok(_) => println!("pjrt client   : ok (cpu)"),
        Err(e) => println!("pjrt client   : unavailable ({e})"),
    }
    println!("logical ranks : thread-based (see DESIGN.md §Substitutions)");
    Ok(())
}
