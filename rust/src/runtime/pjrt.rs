//! PJRT execution backend: runs the AOT-compiled JAX/Pallas artifacts.
//!
//! The `xla` crate's PJRT handles are raw C++ pointers without `Send`
//! impls, so a single **engine thread** owns the client and all compiled
//! executables; rank threads submit `(op key, input buffers)` requests over
//! a channel and block on the reply. On this single-core image the
//! serialization costs nothing; on a real deployment there is one engine
//! (= one PJRT device) per process, exactly like one GPU stream.
//!
//! Executables are compiled lazily from `artifacts/*.hlo.txt` on first use
//! and cached for the life of the engine. Any op/shape not present in the
//! manifest transparently falls back to the native Rust backend (and is
//! counted in [`PjrtStats`], so tests can assert the hot path really ran
//! on XLA).

use super::backend::ComputeBackend;
use super::manifest::Manifest;
use super::native::NativeBackend;
use crate::error::{DnttError, Result};
use crate::linalg::Mat;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

/// One input tensor for an execution request.
struct TensorArg {
    data: Vec<f32>,
    dims: Vec<i64>,
}

struct ExecRequest {
    key: String,
    args: Vec<TensorArg>,
    /// Number of outputs expected (the lowered fns return tuples).
    outputs: usize,
    reply: Sender<Result<Vec<Vec<f32>>>>,
}

enum Msg {
    Exec(ExecRequest),
    Shutdown,
}

/// Hit/miss counters (miss = native fallback).
#[derive(Default, Debug)]
pub struct PjrtStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
}

/// Handle to the engine thread. Cheap to clone via `Arc`.
pub struct PjrtEngine {
    tx: Mutex<Sender<Msg>>,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
    manifest: Manifest,
    pub stats: PjrtStats,
}

impl PjrtEngine {
    /// Start the engine for the artifact directory (conventionally
    /// `artifacts/`). Fails fast if the PJRT client cannot initialize.
    pub fn start(artifact_dir: &Path) -> Result<Arc<PjrtEngine>> {
        let manifest = Manifest::load(artifact_dir)?;
        let (tx, rx) = channel::<Msg>();
        let man = manifest.clone();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                let client = match xla::PjRtClient::cpu() {
                    Ok(c) => {
                        let _ = ready_tx.send(Ok(()));
                        c
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(DnttError::Xla(e.to_string())));
                        return;
                    }
                };
                let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Shutdown => break,
                        Msg::Exec(req) => {
                            let result = serve(&client, &man, &mut cache, &req);
                            let _ = req.reply.send(result);
                        }
                    }
                }
            })
            .map_err(|e| DnttError::Other(format!("spawn pjrt engine: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| DnttError::Xla("pjrt engine died during init".into()))??;
        Ok(Arc::new(PjrtEngine {
            tx: Mutex::new(tx),
            join: Mutex::new(Some(join)),
            manifest,
            stats: PjrtStats::default(),
        }))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute artifact `key` with the given (data, dims) inputs.
    fn exec(&self, key: &str, args: Vec<TensorArg>, outputs: usize) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = channel();
        {
            let tx = self.tx.lock().unwrap();
            tx.send(Msg::Exec(ExecRequest { key: key.to_string(), args, outputs, reply }))
                .map_err(|_| DnttError::Xla("pjrt engine gone".into()))?;
        }
        rx.recv().map_err(|_| DnttError::Xla("pjrt engine dropped request".into()))?
    }
}

impl Drop for PjrtEngine {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(Msg::Shutdown);
        }
        if let Some(j) = self.join.lock().unwrap().take() {
            let _ = j.join();
        }
    }
}

/// Engine-thread service loop body: compile (cached) + execute.
fn serve(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    req: &ExecRequest,
) -> Result<Vec<Vec<f32>>> {
    if !cache.contains_key(&req.key) {
        let artifact = manifest
            .get(&req.key)
            .ok_or_else(|| DnttError::Artifact(format!("no artifact for {}", req.key)))?;
        let proto = xla::HloModuleProto::from_text_file(&artifact.path)
            .map_err(|e| DnttError::Xla(format!("{}: {e}", req.key)))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| DnttError::Xla(format!("compile {}: {e}", req.key)))?;
        cache.insert(req.key.clone(), exe);
        log::debug!("pjrt: compiled {}", req.key);
    }
    let exe = cache.get(&req.key).unwrap();
    let literals: Vec<xla::Literal> = req
        .args
        .iter()
        .map(|a| {
            xla::Literal::vec1(&a.data)
                .reshape(&a.dims)
                .map_err(|e| DnttError::Xla(format!("literal reshape: {e}")))
        })
        .collect::<Result<_>>()?;
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| DnttError::Xla(format!("execute {}: {e}", req.key)))?;
    let mut tuple = result[0][0]
        .to_literal_sync()
        .map_err(|e| DnttError::Xla(format!("fetch {}: {e}", req.key)))?;
    // Lowered with return_tuple=True: decompose.
    let elems = tuple
        .decompose_tuple()
        .map_err(|e| DnttError::Xla(format!("untuple {}: {e}", req.key)))?;
    if elems.len() != req.outputs {
        return Err(DnttError::Xla(format!(
            "{}: expected {} outputs, got {}",
            req.key,
            req.outputs,
            elems.len()
        )));
    }
    elems
        .into_iter()
        .map(|l| l.to_vec::<f32>().map_err(|e| DnttError::Xla(e.to_string())))
        .collect()
}

/// `ComputeBackend` running on the PJRT engine with native fallback.
pub struct PjrtBackend {
    engine: Arc<PjrtEngine>,
    native: NativeBackend,
}

impl PjrtBackend {
    pub fn new(engine: Arc<PjrtEngine>) -> Self {
        PjrtBackend { engine, native: NativeBackend }
    }

    /// Convenience: start an engine on `artifacts/` and wrap it.
    pub fn from_dir(dir: &Path) -> Result<Self> {
        Ok(Self::new(PjrtEngine::start(dir)?))
    }

    pub fn engine(&self) -> &Arc<PjrtEngine> {
        &self.engine
    }

    fn arg(m: &Mat<f64>) -> TensorArg {
        TensorArg {
            data: m.as_slice().iter().map(|&x| x as f32).collect(),
            dims: vec![m.rows() as i64, m.cols() as i64],
        }
    }

    fn back(data: &[f32], rows: usize, cols: usize) -> Mat<f64> {
        Mat::from_vec(rows, cols, data.iter().map(|&x| x as f64).collect())
    }

    /// Try the artifact path; fall back to native on a missing key.
    fn run1(
        &self,
        key: &str,
        args: Vec<TensorArg>,
        rows: usize,
        cols: usize,
        fallback: impl FnOnce() -> Mat<f64>,
    ) -> Mat<f64> {
        if self.engine.manifest.contains(key) {
            match self.engine.exec(key, args, 1) {
                Ok(outs) => {
                    self.engine.stats.hits.fetch_add(1, Ordering::Relaxed);
                    return Self::back(&outs[0], rows, cols);
                }
                Err(e) => log::warn!("pjrt {key} failed ({e}); using native"),
            }
        }
        self.engine.stats.misses.fetch_add(1, Ordering::Relaxed);
        fallback()
    }
}

impl ComputeBackend for PjrtBackend {
    fn gram(&self, f: &Mat<f64>) -> Mat<f64> {
        let r = f.cols();
        let key = Manifest::key_gram(f.rows(), r);
        self.run1(&key, vec![Self::arg(f)], r, r, || self.native.gram(f))
    }

    fn xht(&self, x: &Mat<f64>, ht: &Mat<f64>) -> Mat<f64> {
        let key = Manifest::key_xht(x.rows(), x.cols(), ht.cols());
        self.run1(&key, vec![Self::arg(x), Self::arg(ht)], x.rows(), ht.cols(), || {
            self.native.xht(x, ht)
        })
    }

    fn wtx(&self, x: &Mat<f64>, w: &Mat<f64>) -> Mat<f64> {
        let key = Manifest::key_wtx(x.rows(), x.cols(), w.cols());
        self.run1(&key, vec![Self::arg(x), Self::arg(w)], x.cols(), w.cols(), || {
            self.native.wtx(x, w)
        })
    }

    fn bcd_update(&self, fm: &Mat<f64>, g: &Mat<f64>, p: &Mat<f64>, lip: f64) -> Mat<f64> {
        let key = Manifest::key_bcd(fm.rows(), fm.cols());
        let lip_arg = TensorArg { data: vec![lip as f32], dims: vec![1, 1] };
        self.run1(
            &key,
            vec![Self::arg(fm), Self::arg(g), Self::arg(p), lip_arg],
            fm.rows(),
            fm.cols(),
            || self.native.bcd_update(fm, g, p, lip),
        )
    }

    fn mu_update(&self, f: &Mat<f64>, g: &Mat<f64>, p: &Mat<f64>) -> Mat<f64> {
        let key = Manifest::key_mu(f.rows(), f.cols());
        self.run1(&key, vec![Self::arg(f), Self::arg(g), Self::arg(p)], f.rows(), f.cols(), || {
            self.native.mu_update(f, g, p)
        })
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Fused serial BCD iteration (see `python/compile/model.py::nmf_iter_bcd`).
/// Returns `(w_new, ht_new, cross, quad)`; `None` if the shape has no
/// artifact.
pub fn pjrt_nmf_iter(
    backend: &PjrtBackend,
    x: &Mat<f64>,
    wm: &Mat<f64>,
    htm: &Mat<f64>,
) -> Option<(Mat<f64>, Mat<f64>, f64, f64)> {
    let (m, n) = x.shape();
    let r = wm.cols();
    let key = Manifest::key_nmf_iter(m, n, r);
    if !backend.engine.manifest.contains(&key) {
        return None;
    }
    let args = vec![PjrtBackend::arg(x), PjrtBackend::arg(wm), PjrtBackend::arg(htm)];
    match backend.engine.exec(&key, args, 4) {
        Ok(outs) => {
            backend.engine.stats.hits.fetch_add(1, Ordering::Relaxed);
            Some((
                PjrtBackend::back(&outs[0], m, r),
                PjrtBackend::back(&outs[1], n, r),
                outs[2][0] as f64,
                outs[3][0] as f64,
            ))
        }
        Err(e) => {
            log::warn!("pjrt {key} failed: {e}");
            None
        }
    }
}
