//! Execution backends: the `ComputeBackend` trait, the pure-Rust native
//! backend, the artifact manifest, and the PJRT backend that runs the
//! AOT-compiled JAX/Pallas artifacts through the `xla` crate.

pub mod backend;
pub mod kernel;
pub mod manifest;
pub mod native;
pub mod pjrt;

pub use backend::ComputeBackend;
pub use kernel::{KernelCfg, KernelPath, KernelPolicy};
pub use manifest::Manifest;
pub use native::NativeBackend;
pub use pjrt::{PjrtBackend, PjrtEngine};
